//! The artifact manifest written by `python/compile/aot.py`.

use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        let w = match self.dtype.as_str() {
            "f64" | "i64" | "u64" => 8,
            "f32" | "i32" | "u32" => 4,
            "bf16" | "f16" | "i16" => 2,
            _ => 1,
        };
        self.elements() * w
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct EntrySpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArtifactManifest {
    pub entries: Vec<EntrySpec>,
}

impl ArtifactManifest {
    pub fn read(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let entries = v
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow!("manifest missing entries"))?;
        let parse_tensors = |v: Option<&Json>| -> Result<Vec<TensorSpec>> {
            v.and_then(|t| t.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(|t| {
                    Ok(TensorSpec {
                        dtype: t
                            .get("dtype")
                            .and_then(|d| d.as_str())
                            .ok_or_else(|| anyhow!("tensor missing dtype"))?
                            .to_string(),
                        shape: t
                            .get("shape")
                            .and_then(|s| s.as_arr())
                            .ok_or_else(|| anyhow!("tensor missing shape"))?
                            .iter()
                            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                            .collect::<Result<Vec<_>>>()?,
                    })
                })
                .collect()
        };
        let mut out = Vec::new();
        for e in entries {
            out.push(EntrySpec {
                name: e
                    .get("name")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| anyhow!("entry missing name"))?
                    .to_string(),
                file: e
                    .get("file")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| anyhow!("entry missing file"))?
                    .to_string(),
                inputs: parse_tensors(e.get("inputs"))?,
                outputs: parse_tensors(e.get("outputs"))?,
            });
        }
        Ok(ArtifactManifest { entries: out })
    }

    pub fn entry(&self, name: &str) -> Option<&EntrySpec> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest() {
        let text = r#"{"entries":[
            {"name":"xs_lookup_event","file":"xs_lookup_event.hlo.txt",
             "inputs":[{"dtype":"f32","shape":[4096]},{"dtype":"f32","shape":[512,3]}],
             "outputs":[{"dtype":"f32","shape":[4096,3]}]}
        ]}"#;
        let m = ArtifactManifest::parse(text).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.entry("xs_lookup_event").unwrap();
        assert_eq!(e.file, "xs_lookup_event.hlo.txt");
        assert_eq!(e.inputs[1].shape, vec![512, 3]);
        assert_eq!(e.inputs[1].elements(), 1536);
        assert_eq!(e.inputs[1].bytes(), 6144);
        assert!(m.entry("missing").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(ArtifactManifest::parse("{}").is_err());
        assert!(ArtifactManifest::parse(r#"{"entries":[{"file":"x"}]}"#).is_err());
    }
}
