//! PJRT runtime: loads and executes the AOT-compiled JAX/Pallas artifacts.
//!
//! The compile path (`python/compile/aot.py`, run once by `make
//! artifacts`) lowers each L2 model to **HLO text** — the interchange
//! format this crate's bundled XLA (xla_extension 0.5.1) accepts from
//! jax ≥ 0.5, whose serialized protos it rejects (64-bit instruction
//! ids). Here we compile each artifact once on the PJRT CPU client and
//! execute it from the request path with no Python anywhere.

pub mod manifest;

pub use manifest::{ArtifactManifest, EntrySpec, TensorSpec};

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// A loaded, compiled artifact collection.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    pub manifest: Option<ArtifactManifest>,
}

impl Runtime {
    /// CPU PJRT client (the reproduction's "device" for offloaded kernels).
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?,
            exes: Mutex::new(HashMap::new()),
            manifest: None,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact under `name`.
    pub fn load_hlo_text(&self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.exes.lock().unwrap().insert(name.to_string(), exe);
        Ok(())
    }

    /// Load every entry of `artifacts/manifest.json`.
    pub fn load_manifest_dir(&mut self, dir: &Path) -> Result<ArtifactManifest> {
        let manifest = ArtifactManifest::read(&dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?}"))?;
        for e in &manifest.entries {
            self.load_hlo_text(&e.name, &dir.join(&e.file))?;
        }
        self.manifest = Some(manifest.clone());
        Ok(manifest)
    }

    pub fn has(&self, name: &str) -> bool {
        self.exes.lock().unwrap().contains_key(name)
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.exes.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Execute `name` with literal inputs; returns the flattened tuple of
    /// output literals.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exes = self.exes.lock().unwrap();
        let exe = exes.get(name).ok_or_else(|| anyhow!("unknown executable {name}"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let mut lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        lit.decompose_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    /// Convenience: f32 tensors in, first f32 tensor out.
    pub fn execute_f32(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let lits = inputs
            .iter()
            .map(|(data, shape)| {
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let outs = self.execute(name, &lits)?;
        outs[0].to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT smoke tests live in rust/tests/integration_runtime.rs (they
    // need artifacts). Here: manifest-independent error paths.
    #[test]
    fn unknown_executable_errors() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.execute("nope", &[]).is_err());
        assert!(!rt.has("nope"));
    }
}
