//! The single-thread *generic* allocator (paper §3.4).
//!
//! "The single-thread generic allocator tracks all allocations in two linked
//! lists: an allocation list and a free list. Each thread can use the entire
//! heap space if necessary, but access to the lists has to be mutually
//! exclusive, which can become a performance bottleneck for applications
//! that allocate heap memory concurrently."
//!
//! We keep the same structure — one lock, an allocation map, a free list
//! with first-fit and coalescing — with the lists held host-side (the
//! simulator's equivalent of metadata in device memory).

use super::{align_up, AllocCtx, AllocError, AllocStats, DeviceAllocator, ObjRecord, ALIGN};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

struct Inner {
    /// base -> size of live allocations.
    allocs: BTreeMap<u64, u64>,
    /// base -> size of free holes (coalesced, address ordered).
    free: BTreeMap<u64, u64>,
    live_bytes: u64,
    peak_live_bytes: u64,
}

pub struct GenericAllocator {
    base: u64,
    size: u64,
    inner: Mutex<Inner>,
    mallocs: AtomicU64,
    frees: AtomicU64,
    failed: AtomicU64,
}

impl GenericAllocator {
    pub fn new(base: u64, size: u64) -> Self {
        let base = align_up(base, ALIGN);
        Self {
            base,
            size,
            inner: Mutex::new(Inner {
                allocs: BTreeMap::new(),
                free: BTreeMap::from([(base, size)]),
                live_bytes: 0,
                peak_live_bytes: 0,
            }),
            mallocs: AtomicU64::new(0),
            frees: AtomicU64::new(0),
            failed: AtomicU64::new(0),
        }
    }

    /// Whole-heap invariant check (tests): free holes + live allocations
    /// tile the heap without overlap.
    pub fn check_invariants(&self) {
        let g = self.inner.lock().unwrap();
        let mut regions: Vec<(u64, u64, bool)> = g
            .allocs
            .iter()
            .map(|(&b, &s)| (b, s, true))
            .chain(g.free.iter().map(|(&b, &s)| (b, s, false)))
            .collect();
        regions.sort_by_key(|r| r.0);
        let mut cursor = self.base;
        let mut prev_free = false;
        for (b, s, used) in regions {
            assert!(b >= cursor, "overlap at {b:#x} (cursor {cursor:#x})");
            if !used {
                assert!(!prev_free || b > cursor, "adjacent uncoalesced free holes");
            }
            cursor = b + s;
            prev_free = !used;
        }
        assert!(cursor <= self.base + self.size, "region past heap end");
    }
}

impl DeviceAllocator for GenericAllocator {
    fn name(&self) -> &'static str {
        "generic"
    }

    fn malloc(&self, _ctx: AllocCtx, size: u64) -> Result<u64, AllocError> {
        let size = align_up(size.max(1), ALIGN);
        self.mallocs.fetch_add(1, Ordering::Relaxed);
        let mut g = self.inner.lock().unwrap();
        // First fit over the address-ordered free list.
        let found = g.free.iter().find(|(_, &s)| s >= size).map(|(&b, &s)| (b, s));
        match found {
            Some((hole_base, hole_size)) => {
                g.free.remove(&hole_base);
                if hole_size > size {
                    g.free.insert(hole_base + size, hole_size - size);
                }
                g.allocs.insert(hole_base, size);
                g.live_bytes += size;
                g.peak_live_bytes = g.peak_live_bytes.max(g.live_bytes);
                Ok(hole_base)
            }
            None => {
                self.failed.fetch_add(1, Ordering::Relaxed);
                Err(AllocError::OutOfMemory { requested: size })
            }
        }
    }

    fn free(&self, addr: u64) -> Result<(), AllocError> {
        self.frees.fetch_add(1, Ordering::Relaxed);
        let mut g = self.inner.lock().unwrap();
        let size = g.allocs.remove(&addr).ok_or(AllocError::InvalidFree { addr })?;
        g.live_bytes -= size;
        // Insert into free list, coalescing with neighbours.
        let mut base = addr;
        let mut len = size;
        if let Some((&pb, &ps)) = g.free.range(..addr).next_back() {
            if pb + ps == addr {
                g.free.remove(&pb);
                base = pb;
                len += ps;
            }
        }
        if let Some(&ns) = g.free.get(&(addr + size)) {
            g.free.remove(&(addr + size));
            len += ns;
        }
        g.free.insert(base, len);
        Ok(())
    }

    fn lookup(&self, addr: u64) -> Option<ObjRecord> {
        let g = self.inner.lock().unwrap();
        let (&base, &size) = g.allocs.range(..=addr).next_back()?;
        if addr < base + size {
            Some(ObjRecord { base, size })
        } else {
            None
        }
    }

    fn stats(&self) -> AllocStats {
        let g = self.inner.lock().unwrap();
        let mallocs = self.mallocs.load(Ordering::Relaxed);
        let frees = self.frees.load(Ordering::Relaxed);
        AllocStats {
            mallocs,
            frees,
            failed: self.failed.load(Ordering::Relaxed),
            per_lock_ops: vec![mallocs + frees],
            live_bytes: g.live_bytes,
            peak_live_bytes: g.peak_live_bytes,
        }
    }

    fn reset(&self) {
        let mut g = self.inner.lock().unwrap();
        g.allocs.clear();
        g.free = BTreeMap::from([(self.base, self.size)]);
        g.live_bytes = 0;
        g.peak_live_bytes = 0;
        self.mallocs.store(0, Ordering::Relaxed);
        self.frees.store(0, Ordering::Relaxed);
        self.failed.store(0, Ordering::Relaxed);
    }

    /// List traversal + lock, but no vendor-runtime overhead; calibrated in
    /// `perfmodel::a100`.
    fn per_op_ns(&self) -> f64 {
        crate::perfmodel::a100::GENERIC_ALLOC_OP_NS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alloc() -> GenericAllocator {
        GenericAllocator::new(0x1000, 1 << 20)
    }

    #[test]
    fn alloc_free_cycle() {
        let a = alloc();
        let ctx = AllocCtx::default();
        let p1 = a.malloc(ctx, 100).unwrap();
        let p2 = a.malloc(ctx, 200).unwrap();
        assert_ne!(p1, p2);
        assert!(p1 % ALIGN == 0 && p2 % ALIGN == 0);
        a.free(p1).unwrap();
        a.free(p2).unwrap();
        a.check_invariants();
        // Whole heap coalesced: a huge allocation fits again.
        let p3 = a.malloc(ctx, (1 << 20) - 64).unwrap();
        a.free(p3).unwrap();
    }

    #[test]
    fn lookup_interior_pointer() {
        let a = alloc();
        let p = a.malloc(AllocCtx::default(), 256).unwrap();
        let rec = a.lookup(p + 100).unwrap();
        assert_eq!(rec.base, p);
        assert_eq!(rec.size, 256);
        assert!(a.lookup(p + 256).is_none());
        a.free(p).unwrap();
        assert!(a.lookup(p).is_none());
    }

    #[test]
    fn double_free_rejected() {
        let a = alloc();
        let p = a.malloc(AllocCtx::default(), 8).unwrap();
        a.free(p).unwrap();
        assert_eq!(a.free(p), Err(AllocError::InvalidFree { addr: p }));
    }

    #[test]
    fn oom_reported() {
        let a = GenericAllocator::new(0x1000, 1024);
        assert!(matches!(
            a.malloc(AllocCtx::default(), 4096),
            Err(AllocError::OutOfMemory { .. })
        ));
        assert_eq!(a.stats().failed, 1);
    }

    #[test]
    fn reuse_after_free_first_fit() {
        let a = alloc();
        let ctx = AllocCtx::default();
        let p1 = a.malloc(ctx, 128).unwrap();
        let _p2 = a.malloc(ctx, 128).unwrap();
        a.free(p1).unwrap();
        let p3 = a.malloc(ctx, 64).unwrap();
        assert_eq!(p3, p1, "first-fit should reuse the freed hole");
        a.check_invariants();
    }

    #[test]
    fn concurrent_stress_preserves_invariants() {
        use std::sync::Arc;
        let a = Arc::new(alloc());
        let handles: Vec<_> = (0..8usize)
            .map(|t| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    let ctx = AllocCtx { thread_id: t, team_id: 0 };
                    let mut ptrs = Vec::new();
                    for i in 0..500u64 {
                        ptrs.push(a.malloc(ctx, 16 + (i % 7) * 24).unwrap());
                        if i % 3 == 0 {
                            a.free(ptrs.remove(0)).unwrap();
                        }
                    }
                    for p in ptrs {
                        a.free(p).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        a.check_invariants();
        assert_eq!(a.stats().live_bytes, 0);
    }
}
