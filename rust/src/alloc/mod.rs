//! Device heap allocators (paper §3.4).
//!
//! The paper ships two configurable allocators selected via
//! `-fopenmp-target-allocator={generic,balanced[N,M]}`:
//!
//! * [`generic::GenericAllocator`] — single-lock free-list allocator; any
//!   thread can use the whole heap, list access is mutually exclusive.
//! * [`balanced::BalancedAllocator`] — the paper's contribution: the heap is
//!   split into N×M chunks keyed by `(thread id mod N, team id mod M)`, one
//!   lock per chunk, watermark bump allocation with lazy reclamation of the
//!   top entry, and an oversized first chunk for the initial thread.
//! * [`vendor::VendorAllocator`] — a model of the NVIDIA-provided device
//!   `malloc` (globally serializing, high fixed per-op cost), the Fig. 6
//!   baseline.
//!
//! All allocators also maintain the **allocation tracking** records that the
//! RPC pass's dynamic underlying-object lookup (`_FindObj`, paper §3.2)
//! queries at runtime via [`DeviceAllocator::lookup`].

pub mod generic;
pub mod balanced;
pub mod vendor;

pub use balanced::{BalancedAllocator, BalancedConfig};
pub use generic::GenericAllocator;
pub use vendor::VendorAllocator;

use std::fmt;

/// Alignment of every allocation (GPU-friendly 16B).
pub const ALIGN: u64 = 16;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AllocError {
    OutOfMemory { requested: u64 },
    /// Balanced allocator: the thread's chunk is exhausted even though other
    /// chunks may be mostly empty (paper §3.4 discusses exactly this mode).
    OutOfChunk { chunk: usize, requested: u64 },
    InvalidFree { addr: u64 },
}

impl fmt::Display for AllocError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AllocError::OutOfMemory { requested } => {
                write!(f, "out of device heap ({requested} B)")
            }
            AllocError::OutOfChunk { chunk, requested } => {
                write!(f, "chunk {chunk} exhausted ({requested} B requested)")
            }
            AllocError::InvalidFree { addr } => write!(f, "invalid free of {addr:#x}"),
        }
    }
}

impl std::error::Error for AllocError {}

/// An allocation record: the *underlying object* the RPC pass resolves
/// pointers against at runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObjRecord {
    pub base: u64,
    pub size: u64,
}

/// Aggregate operation statistics, used by the Fig. 6 cost model: vendor and
/// generic allocators serialize on one lock, balanced on one lock per chunk.
#[derive(Debug, Clone, Default)]
pub struct AllocStats {
    pub mallocs: u64,
    pub frees: u64,
    pub failed: u64,
    /// Operations per lock domain (len 1 for the single-lock allocators).
    pub per_lock_ops: Vec<u64>,
    pub live_bytes: u64,
    pub peak_live_bytes: u64,
}

impl AllocStats {
    /// Modeled serialized time: each lock domain serializes its operations;
    /// domains proceed in parallel ⇒ the critical path is the busiest lock.
    pub fn modeled_ns(&self, per_op_ns: f64) -> f64 {
        self.per_lock_ops
            .iter()
            .map(|&ops| ops as f64 * per_op_ns)
            .fold(0.0, f64::max)
    }
}

/// Identity of the simulated thread performing an allocator call; the
/// balanced allocator derives the chunk from it.
#[derive(Debug, Clone, Copy, Default)]
pub struct AllocCtx {
    pub thread_id: usize,
    pub team_id: usize,
}

pub trait DeviceAllocator: Send + Sync {
    fn name(&self) -> &'static str;

    fn malloc(&self, ctx: AllocCtx, size: u64) -> Result<u64, AllocError>;

    fn free(&self, addr: u64) -> Result<(), AllocError>;

    /// Dynamic underlying-object lookup (`_FindObj`): given an interior
    /// pointer, return the containing allocation if the address belongs to a
    /// live heap object.
    fn lookup(&self, addr: u64) -> Option<ObjRecord>;

    fn stats(&self) -> AllocStats;

    /// Reset heap to empty (between bench iterations).
    fn reset(&self);

    /// Modeled cost of one allocator operation, excluding serialization
    /// (which `AllocStats::modeled_ns` derives from lock-domain traffic).
    fn per_op_ns(&self) -> f64;
}

pub(crate) fn align_up(x: u64, align: u64) -> u64 {
    (x + align - 1) & !(align - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn align_up_works() {
        assert_eq!(align_up(0, 16), 0);
        assert_eq!(align_up(1, 16), 16);
        assert_eq!(align_up(16, 16), 16);
        assert_eq!(align_up(17, 16), 32);
    }

    #[test]
    fn modeled_ns_is_max_over_domains() {
        let s = AllocStats { per_lock_ops: vec![10, 50, 20], ..Default::default() };
        assert_eq!(s.modeled_ns(2.0), 100.0);
    }
}
