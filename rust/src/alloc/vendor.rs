//! Model of the NVIDIA-provided device `malloc` — the Fig. 6 baseline.
//!
//! CUDA's in-kernel heap allocator serializes concurrent allocations on
//! global structures and pays a large fixed per-operation cost. We model it
//! as the generic free-list allocator behind one global lock, with a
//! per-operation cost constant calibrated so the paper's measured gaps
//! (balanced 3.3× faster at 1×1 up to 30× at 32×256) are reproduced by the
//! lock-domain serialization model in [`super::AllocStats::modeled_ns`].

use super::{AllocCtx, AllocError, AllocStats, DeviceAllocator, GenericAllocator, ObjRecord};

pub struct VendorAllocator {
    inner: GenericAllocator,
}

impl VendorAllocator {
    pub fn new(base: u64, size: u64) -> Self {
        Self { inner: GenericAllocator::new(base, size) }
    }
}

impl DeviceAllocator for VendorAllocator {
    fn name(&self) -> &'static str {
        "vendor-malloc"
    }

    fn malloc(&self, ctx: AllocCtx, size: u64) -> Result<u64, AllocError> {
        self.inner.malloc(ctx, size)
    }

    fn free(&self, addr: u64) -> Result<(), AllocError> {
        self.inner.free(addr)
    }

    fn lookup(&self, addr: u64) -> Option<ObjRecord> {
        self.inner.lookup(addr)
    }

    fn stats(&self) -> AllocStats {
        self.inner.stats()
    }

    fn reset(&self) {
        self.inner.reset()
    }

    fn per_op_ns(&self) -> f64 {
        crate::perfmodel::a100::VENDOR_ALLOC_OP_NS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn behaves_like_generic_with_higher_cost() {
        let v = VendorAllocator::new(0x1000, 1 << 20);
        let p = v.malloc(AllocCtx::default(), 128).unwrap();
        assert!(v.lookup(p + 4).is_some());
        v.free(p).unwrap();
        assert!(v.per_op_ns() > GenericAllocator::new(0x1000, 1 << 20).per_op_ns());
        assert_eq!(v.name(), "vendor-malloc");
    }
}
