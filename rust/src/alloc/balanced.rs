//! The *balanced* allocator (paper §3.4, Fig. 5) — the paper's
//! domain-specific contribution for massively parallel alloc/dealloc.
//!
//! The heap is divided into `N × M` chunks; a thread with `(tid, team)` uses
//! chunk `(tid mod N) + (team mod M) * N`. One lock per chunk; different
//! chunks are fully independent. Within a chunk, allocation bumps a
//! *watermark*; deallocation marks the entry unused without touching the
//! encoding. When the **top** entry is unused, the watermark is moved back
//! (repeatedly), reclaiming space with minimal overhead — ideal for the
//! balanced alloc/dealloc-at-region-boundary pattern of the SPEC OMP codes.
//! If the watermark hits the chunk end, a linear traversal tries to reuse an
//! unreclaimed hole.
//!
//! Because large serial-phase allocations are performed by the initial
//! thread (always thread 0 of team 0), the **first chunk is larger** than
//! the rest by a configurable ratio.

use super::{align_up, AllocCtx, AllocError, AllocStats, DeviceAllocator, ObjRecord, ALIGN};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Debug, Clone, Copy)]
pub struct BalancedConfig {
    /// Thread slots (N).
    pub n: usize,
    /// Team slots (M).
    pub m: usize,
    /// Fraction of the heap reserved for chunk 0 (the initial thread's).
    pub first_chunk_ratio: f64,
}

impl Default for BalancedConfig {
    fn default() -> Self {
        // The paper's evaluation uses balanced[32,16].
        Self { n: 32, m: 16, first_chunk_ratio: 0.25 }
    }
}

#[derive(Debug, Clone, Copy)]
struct Entry {
    base: u64,
    /// Hole size (allocation rounded up; reused holes keep their size).
    size: u64,
    used: bool,
}

struct Chunk {
    base: u64,
    size: u64,
    /// Address-ordered entries below the watermark (bases strictly
    /// increasing; entries are never moved, matching the in-heap encoding).
    entries: Vec<Entry>,
    watermark: u64,
    ops: u64,
    live_bytes: u64,
}

impl Chunk {
    fn new(base: u64, size: u64) -> Self {
        Self { base, size, entries: Vec::new(), watermark: base, ops: 0, live_bytes: 0 }
    }

    fn malloc(&mut self, size: u64) -> Option<u64> {
        self.ops += 1;
        // Fast path: bump the watermark.
        if self.watermark + size <= self.base + self.size {
            let addr = self.watermark;
            self.watermark += size;
            self.entries.push(Entry { base: addr, size, used: true });
            self.live_bytes += size;
            return Some(addr);
        }
        // Slow path: linear traversal for an unreclaimed hole (paper: "we
        // need to traverse the list until a suitable entry is found, which
        // can be costly in practice").
        for e in self.entries.iter_mut() {
            if !e.used && e.size >= size {
                e.used = true;
                self.live_bytes += e.size;
                return Some(e.base);
            }
        }
        None
    }

    fn free(&mut self, addr: u64) -> Result<(), AllocError> {
        self.ops += 1;
        // Entries are base-ordered: binary search.
        let idx = self
            .entries
            .binary_search_by(|e| e.base.cmp(&addr))
            .map_err(|_| AllocError::InvalidFree { addr })?;
        if !self.entries[idx].used {
            return Err(AllocError::InvalidFree { addr });
        }
        self.entries[idx].used = false;
        self.live_bytes -= self.entries[idx].size;
        // Reclaim from the top while the top entry is unused (Fig. 5 bottom).
        while let Some(top) = self.entries.last() {
            if top.used {
                break;
            }
            self.watermark = top.base;
            self.entries.pop();
        }
        Ok(())
    }

    fn lookup(&self, addr: u64) -> Option<ObjRecord> {
        let idx = match self.entries.binary_search_by(|e| e.base.cmp(&addr)) {
            Ok(i) => i,
            Err(0) => return None,
            Err(i) => i - 1,
        };
        let e = &self.entries[idx];
        if e.used && addr < e.base + e.size {
            Some(ObjRecord { base: e.base, size: e.size })
        } else {
            None
        }
    }
}

pub struct BalancedAllocator {
    cfg: BalancedConfig,
    base: u64,
    size: u64,
    chunks: Vec<Mutex<Chunk>>,
    /// Chunk boundaries for address→chunk lookup: chunk i covers
    /// `[starts[i], starts[i+1])`.
    starts: Vec<u64>,
    mallocs: AtomicU64,
    frees: AtomicU64,
    failed: AtomicU64,
}

impl BalancedAllocator {
    pub fn new(base: u64, size: u64, cfg: BalancedConfig) -> Self {
        assert!(cfg.n >= 1 && cfg.m >= 1);
        assert!((0.0..1.0).contains(&cfg.first_chunk_ratio));
        let base = align_up(base, ALIGN);
        let total = cfg.n * cfg.m;
        let mut sizes = vec![0u64; total];
        if total == 1 {
            sizes[0] = size;
        } else {
            let first = align_up((size as f64 * cfg.first_chunk_ratio) as u64, ALIGN);
            let rest = (size - first) / (total as u64 - 1);
            let rest = rest & !(ALIGN - 1);
            sizes[0] = first;
            for s in sizes.iter_mut().skip(1) {
                *s = rest;
            }
        }
        let mut chunks = Vec::with_capacity(total);
        let mut starts = Vec::with_capacity(total + 1);
        let mut cursor = base;
        for &s in &sizes {
            starts.push(cursor);
            chunks.push(Mutex::new(Chunk::new(cursor, s)));
            cursor += s;
        }
        starts.push(cursor);
        Self {
            cfg,
            base,
            size,
            chunks,
            starts,
            mallocs: AtomicU64::new(0),
            frees: AtomicU64::new(0),
            failed: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> BalancedConfig {
        self.cfg
    }

    /// Total managed heap bytes.
    pub fn heap_size(&self) -> u64 {
        self.size
    }

    #[inline]
    fn chunk_of(&self, ctx: AllocCtx) -> usize {
        (ctx.thread_id % self.cfg.n) + (ctx.team_id % self.cfg.m) * self.cfg.n
    }

    fn chunk_by_addr(&self, addr: u64) -> Option<usize> {
        if addr < self.base || addr >= self.starts[self.starts.len() - 1] {
            return None;
        }
        match self.starts.binary_search(&addr) {
            Ok(i) => Some(i.min(self.chunks.len() - 1)),
            Err(i) => Some(i - 1),
        }
    }

    /// Test hook: per-chunk (watermark offset, live entries, total entries).
    pub fn chunk_debug(&self, idx: usize) -> (u64, usize, usize) {
        let c = self.chunks[idx].lock().unwrap();
        (c.watermark - c.base, c.entries.iter().filter(|e| e.used).count(), c.entries.len())
    }

    /// Invariant check for tests: entries base-ordered, disjoint, below the
    /// watermark, inside the chunk.
    pub fn check_invariants(&self) {
        for (i, ch) in self.chunks.iter().enumerate() {
            let c = ch.lock().unwrap();
            let mut cursor = c.base;
            for e in &c.entries {
                assert!(e.base >= cursor, "chunk {i}: overlapping entries");
                cursor = e.base + e.size;
            }
            assert!(cursor <= c.watermark, "chunk {i}: entry past watermark");
            assert!(c.watermark <= c.base + c.size, "chunk {i}: watermark past end");
            if let Some(top) = c.entries.last() {
                assert!(top.used, "chunk {i}: unreclaimed unused top entry");
            }
        }
    }
}

impl DeviceAllocator for BalancedAllocator {
    fn name(&self) -> &'static str {
        "balanced"
    }

    fn malloc(&self, ctx: AllocCtx, size: u64) -> Result<u64, AllocError> {
        let size = align_up(size.max(1), ALIGN);
        self.mallocs.fetch_add(1, Ordering::Relaxed);
        let idx = self.chunk_of(ctx);
        let mut c = self.chunks[idx].lock().unwrap();
        match c.malloc(size) {
            Some(addr) => Ok(addr),
            None => {
                self.failed.fetch_add(1, Ordering::Relaxed);
                Err(AllocError::OutOfChunk { chunk: idx, requested: size })
            }
        }
    }

    fn free(&self, addr: u64) -> Result<(), AllocError> {
        self.frees.fetch_add(1, Ordering::Relaxed);
        let idx = self.chunk_by_addr(addr).ok_or(AllocError::InvalidFree { addr })?;
        self.chunks[idx].lock().unwrap().free(addr)
    }

    fn lookup(&self, addr: u64) -> Option<ObjRecord> {
        let idx = self.chunk_by_addr(addr)?;
        self.chunks[idx].lock().unwrap().lookup(addr)
    }

    fn stats(&self) -> AllocStats {
        let mut per_lock_ops = Vec::with_capacity(self.chunks.len());
        let mut live = 0;
        for ch in &self.chunks {
            let c = ch.lock().unwrap();
            per_lock_ops.push(c.ops);
            live += c.live_bytes;
        }
        AllocStats {
            mallocs: self.mallocs.load(Ordering::Relaxed),
            frees: self.frees.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            per_lock_ops,
            live_bytes: live,
            peak_live_bytes: 0, // not tracked per chunk
        }
    }

    fn reset(&self) {
        for ch in &self.chunks {
            let mut c = ch.lock().unwrap();
            c.entries.clear();
            c.watermark = c.base;
            c.ops = 0;
            c.live_bytes = 0;
        }
        self.mallocs.store(0, Ordering::Relaxed);
        self.frees.store(0, Ordering::Relaxed);
        self.failed.store(0, Ordering::Relaxed);
    }

    fn per_op_ns(&self) -> f64 {
        crate::perfmodel::a100::BALANCED_ALLOC_OP_NS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn balanced() -> BalancedAllocator {
        BalancedAllocator::new(
            0x1000,
            4 << 20,
            BalancedConfig { n: 4, m: 2, first_chunk_ratio: 0.25 },
        )
    }

    #[test]
    fn different_slots_get_disjoint_chunks() {
        let a = balanced();
        let p0 = a.malloc(AllocCtx { thread_id: 0, team_id: 0 }, 64).unwrap();
        let p1 = a.malloc(AllocCtx { thread_id: 1, team_id: 0 }, 64).unwrap();
        let p2 = a.malloc(AllocCtx { thread_id: 0, team_id: 1 }, 64).unwrap();
        assert_ne!(a.chunk_by_addr(p0), a.chunk_by_addr(p1));
        assert_ne!(a.chunk_by_addr(p0), a.chunk_by_addr(p2));
        a.check_invariants();
    }

    #[test]
    fn first_chunk_is_larger() {
        let a = balanced();
        let c0 = a.chunks[0].lock().unwrap().size;
        let c1 = a.chunks[1].lock().unwrap().size;
        assert!(c0 > 2 * c1, "first chunk {c0} should dwarf {c1}");
    }

    #[test]
    fn watermark_reclaims_top_lazily() {
        let a = balanced();
        let ctx = AllocCtx { thread_id: 2, team_id: 0 };
        let p1 = a.malloc(ctx, 100).unwrap();
        let p2 = a.malloc(ctx, 100).unwrap();
        let p3 = a.malloc(ctx, 100).unwrap();
        let idx = a.chunk_of(ctx);
        // Free the middle: encoding unchanged (3 entries, one unused).
        a.free(p2).unwrap();
        let (_, used, total) = a.chunk_debug(idx);
        assert_eq!((used, total), (2, 3));
        // Free the top: the top AND the previously-freed middle reclaim.
        a.free(p3).unwrap();
        let (wm_off, used, total) = a.chunk_debug(idx);
        assert_eq!((used, total), (1, 1));
        assert_eq!(wm_off, align_up(100, ALIGN));
        a.free(p1).unwrap();
        let (wm_off, _, total) = a.chunk_debug(idx);
        assert_eq!((wm_off, total), (0, 0));
        a.check_invariants();
    }

    #[test]
    fn hole_reuse_after_exhaustion() {
        let a = BalancedAllocator::new(
            0x1000,
            64 * 1024,
            BalancedConfig { n: 1, m: 1, first_chunk_ratio: 0.5 },
        );
        let ctx = AllocCtx::default();
        // Fill the chunk.
        let mut ptrs = Vec::new();
        loop {
            match a.malloc(ctx, 1024) {
                Ok(p) => ptrs.push(p),
                Err(_) => break,
            }
        }
        assert!(ptrs.len() >= 32);
        // Free a middle entry; the next alloc must reuse its hole.
        let victim = ptrs[ptrs.len() / 2];
        a.free(victim).unwrap();
        let p = a.malloc(ctx, 512).unwrap();
        assert_eq!(p, victim, "slow path should reuse the unreclaimed hole");
        a.check_invariants();
    }

    #[test]
    fn out_of_chunk_while_others_empty() {
        let a = balanced();
        let ctx = AllocCtx { thread_id: 3, team_id: 1 };
        let chunk_size = {
            let idx = a.chunk_of(ctx);
            a.chunks[idx].lock().unwrap().size
        };
        // One chunk exhausted even though the heap is mostly empty.
        assert!(matches!(
            a.malloc(ctx, chunk_size + 1024),
            Err(AllocError::OutOfChunk { .. })
        ));
    }

    #[test]
    fn lookup_resolves_interior_pointers() {
        let a = balanced();
        let ctx = AllocCtx { thread_id: 1, team_id: 1 };
        let p = a.malloc(ctx, 256).unwrap();
        assert_eq!(a.lookup(p + 128).unwrap().base, p);
        a.free(p).unwrap();
        assert!(a.lookup(p + 128).is_none());
    }

    #[test]
    fn concurrent_balanced_stress() {
        use std::sync::Arc;
        let a = Arc::new(BalancedAllocator::new(0x1000, 32 << 20, BalancedConfig::default()));
        let handles: Vec<_> = (0..16usize)
            .map(|t| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    let ctx = AllocCtx { thread_id: t, team_id: t / 4 };
                    for _ in 0..200 {
                        // The SPEC OMP pattern: alloc at region start, free at end.
                        let ps: Vec<u64> =
                            (0..8).map(|i| a.malloc(ctx, 64 + i * 32).unwrap()).collect();
                        for p in ps.into_iter().rev() {
                            a.free(p).unwrap();
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        a.check_invariants();
        assert_eq!(a.stats().live_bytes, 0);
        // Balanced pattern with LIFO frees ⇒ full reclamation everywhere.
        for i in 0..a.chunks.len() {
            assert_eq!(a.chunk_debug(i).0, 0, "chunk {i} not fully reclaimed");
        }
    }

    #[test]
    fn stats_report_per_chunk_lock_domains() {
        let a = balanced();
        let _ = a.malloc(AllocCtx { thread_id: 0, team_id: 0 }, 64).unwrap();
        let _ = a.malloc(AllocCtx { thread_id: 1, team_id: 0 }, 64).unwrap();
        let s = a.stats();
        assert_eq!(s.per_lock_ops.len(), 8);
        assert_eq!(s.per_lock_ops.iter().sum::<u64>(), 2);
        assert_eq!(s.modeled_ns(10.0), 10.0, "independent chunks don't serialize");
    }
}
