//! Log-bucketed latency histograms: lock-free to record, mergeable to
//! report.
//!
//! A [`Hist`] is a fixed array of atomic counters with four buckets per
//! octave (relative bucket width ≤ 25%), so recording is two relaxed
//! `fetch_add`s plus a `fetch_max` — cheap enough to stay always-on in
//! the RPC hot path. The exact maximum rides a separate atomic because
//! the top bucket alone would quantize it.
//!
//! [`HistSnapshot`] is the plain-data form: mergeable across shards
//! (the host-I/O lock tables keep one histogram per shard) and
//! queryable for p50/p90/p99 quantiles, where a quantile resolves to
//! the lower bound of the bucket containing that rank.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket count: values 0..3 exact, then 4 sub-buckets for each octave
/// `[2^l, 2^(l+1))` up to `l = 63` (the full `u64` range — recording
/// never saturates into a lossy overflow bucket).
pub const BUCKETS: usize = 252;

/// Bucket index of `v`: exact below 4, then `4·(l-1) + sub` where `l`
/// is the octave and `sub` the top-two mantissa bits.
pub fn bucket_of(v: u64) -> usize {
    if v < 4 {
        return v as usize;
    }
    let l = 63 - v.leading_zeros();
    let sub = (v >> (l - 2)) & 3;
    ((l - 1) * 4) as usize + sub as usize
}

/// Inclusive lower bound of bucket `idx` (the value a quantile falling
/// in this bucket reports).
pub fn bucket_lo(idx: usize) -> u64 {
    if idx < 4 {
        return idx as u64;
    }
    let l = idx as u32 / 4 + 1;
    let sub = (idx % 4) as u64;
    (4 + sub) << (l - 2)
}

/// A concurrent log-bucketed histogram (see module docs).
#[derive(Debug)]
pub struct Hist {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Hist {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl Hist {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation (relaxed atomics; safe from any thread).
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Plain-data copy for merging / quantile queries.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            counts: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// The mergeable, queryable form of a [`Hist`]. `Default` is the empty
/// histogram (every quantile reports 0).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistSnapshot {
    counts: Vec<u64>,
    pub count: u64,
    pub sum: u64,
    pub max: u64,
}

impl HistSnapshot {
    /// Combine two snapshots (shard merging; commutative, associative).
    pub fn merge(&self, other: &HistSnapshot) -> HistSnapshot {
        let n = self.counts.len().max(other.counts.len());
        let mut counts = vec![0u64; n];
        for (i, c) in self.counts.iter().enumerate() {
            counts[i] += c;
        }
        for (i, c) in other.counts.iter().enumerate() {
            counts[i] += c;
        }
        HistSnapshot {
            counts,
            count: self.count + other.count,
            sum: self.sum.wrapping_add(other.sum),
            max: self.max.max(other.max),
        }
    }

    /// The value at percentile `p` (0..=100): the lower bound of the
    /// bucket holding the `ceil(p% · count)`-th observation. 0 when
    /// empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_lo(i);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.percentile(50.0)
    }

    pub fn p90(&self) -> u64 {
        self.percentile(90.0)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(99.0)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// One-line human form with adaptive units.
    pub fn summary(&self) -> String {
        format!(
            "n={} p50={} p90={} p99={} max={}",
            self.count,
            crate::util::fmt_ns(self.p50() as f64),
            crate::util::fmt_ns(self.p90() as f64),
            crate::util::fmt_ns(self.p99() as f64),
            crate::util::fmt_ns(self.max as f64),
        )
    }

    /// The JSON form `RunMetrics::to_json` embeds per histogram.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("p50_ns", Json::num(self.p50() as f64)),
            ("p90_ns", Json::num(self.p90() as f64)),
            ("p99_ns", Json::num(self.p99() as f64)),
            ("max_ns", Json::num(self.max as f64)),
            ("mean_ns", Json::num(self.mean())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_are_consistent() {
        // Every value lands in a bucket whose lower bound is <= it, and
        // the next bucket's bound is > it.
        for v in [0u64, 1, 2, 3, 4, 5, 7, 8, 63, 64, 100, 1000, 1 << 20, u64::MAX] {
            let i = bucket_of(v);
            assert!(bucket_lo(i) <= v, "lo({i}) <= {v}");
            if i + 1 < BUCKETS {
                assert!(bucket_lo(i + 1) > v, "lo({}) > {v}", i + 1);
            }
        }
        // Bucket lower bounds are strictly increasing.
        for i in 1..BUCKETS {
            assert!(bucket_lo(i) > bucket_lo(i - 1), "monotone at {i}");
        }
    }

    #[test]
    fn saturation_top_of_range() {
        let h = Hist::new();
        h.record(u64::MAX);
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        assert_eq!(s.max, u64::MAX);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1, "top value fits the last bucket");
        assert!(s.p99() <= u64::MAX);
    }

    #[test]
    fn empty_snapshot_reports_zeros() {
        let s = HistSnapshot::default();
        assert!(s.is_empty());
        assert_eq!(s.p50(), 0);
        assert_eq!(s.p99(), 0);
        assert_eq!(s.max, 0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn percentiles_on_known_data() {
        let h = Hist::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.max, 100);
        // Quantiles resolve to the containing bucket's lower bound: the
        // relative error is bounded by the 25% bucket width.
        let p50 = s.p50();
        assert!((40..=50).contains(&p50), "p50 = {p50}");
        let p99 = s.p99();
        assert!((80..=99).contains(&p99), "p99 = {p99}");
        assert_eq!(s.mean(), 50.5);
    }

    #[test]
    fn merge_is_equivalent_to_single_recording() {
        let a = Hist::new();
        let b = Hist::new();
        let all = Hist::new();
        for v in 0..500u64 {
            if v % 2 == 0 {
                a.record(v * 3)
            } else {
                b.record(v * 3)
            }
            all.record(v * 3);
        }
        let merged = a.snapshot().merge(&b.snapshot());
        assert_eq!(merged, all.snapshot());
        // Merging with the empty snapshot is the identity.
        assert_eq!(merged.merge(&HistSnapshot::default()), merged);
        assert_eq!(HistSnapshot::default().merge(&merged), merged);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        use std::sync::Arc;
        let h = Arc::new(Hist::new());
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let h = Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    h.record(t * 1000 + i);
                }
            }));
        }
        for hdl in handles {
            hdl.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, 8000);
        assert_eq!(s.max, 7999);
    }
}
