//! Chrome trace-event export (Perfetto / `chrome://tracing` loadable).
//!
//! Every [`Span`] becomes one complete event (`"ph": "X"`) with
//! microsecond `ts`/`dur`, `pid` 0, and a `tid` derived from the
//! span's kind and track (`kind.track_base() + track`), so each lane,
//! worker, launch slot, team and pass renders as its own named track.
//! A `thread_name` metadata event (`"ph": "M"`) labels every distinct
//! track.

use super::span::{Span, SpanKind};
use crate::util::json::Json;
use std::collections::BTreeSet;

/// Human label of one `(kind, track)` pair — the exported
/// `thread_name` and the run-end summary table share it.
pub fn track_label(kind: SpanKind, track: u64) -> String {
    match kind {
        SpanKind::Lane => format!("lane {track}"),
        SpanKind::Worker => format!("worker {track}"),
        SpanKind::LaunchSlot => format!("launch-slot {track}"),
        SpanKind::Interp => format!("interp team {track}"),
        SpanKind::Pass => "passes".to_string(),
        SpanKind::Session => format!("session {track}"),
    }
}

/// Render `spans` as a Chrome trace-event JSON document.
pub fn chrome_trace(spans: &[Span]) -> Json {
    let mut events = Vec::new();
    let mut tracks: BTreeSet<(SpanKind, u64)> = BTreeSet::new();
    for s in spans {
        tracks.insert((s.kind, s.track));
    }
    for (kind, track) in &tracks {
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num((kind.track_base() + track) as f64)),
            ("args", Json::obj(vec![("name", Json::str(track_label(*kind, *track)))])),
        ]));
    }
    for s in spans {
        events.push(Json::obj(vec![
            ("name", Json::str(s.name.clone())),
            ("cat", Json::str(s.kind.category())),
            ("ph", Json::str("X")),
            ("ts", Json::num(s.start_ns as f64 / 1e3)),
            ("dur", Json::num(s.dur_ns as f64 / 1e3)),
            ("pid", Json::num(0.0)),
            ("tid", Json::num((s.kind.track_base() + s.track) as f64)),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// The `n` slowest spans, longest first (the run-end summary table).
pub fn slowest(spans: &[Span], n: usize) -> Vec<&Span> {
    let mut by_dur: Vec<&Span> = spans.iter().collect();
    by_dur.sort_by(|a, b| b.dur_ns.cmp(&a.dur_ns));
    by_dur.truncate(n);
    by_dur
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &str, kind: SpanKind, track: u64, start: u64, dur: u64) -> Span {
        Span { name: name.to_string(), kind, track, start_ns: start, dur_ns: dur }
    }

    #[test]
    fn trace_round_trips_through_the_json_parser() {
        let spans = vec![
            span("rpc", SpanKind::Lane, 0, 1000, 500),
            span("serve", SpanKind::Worker, 1, 1200, 200),
            span("run", SpanKind::LaunchSlot, 2, 2000, 9000),
            span("rpcgen", SpanKind::Pass, 2, 0, 700),
        ];
        let doc = chrome_trace(&spans);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 4 spans + 4 distinct-track metadata events.
        assert_eq!(events.len(), 8);
        let complete: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .collect();
        assert_eq!(complete.len(), 4);
        let cats: BTreeSet<&str> = complete
            .iter()
            .filter_map(|e| e.get("cat").and_then(Json::as_str))
            .collect();
        assert_eq!(cats.len(), 4, "one category per kind: {cats:?}");
        // ts/dur are microseconds.
        assert_eq!(complete[0].get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(complete[0].get("dur").unwrap().as_f64(), Some(0.5));
        // Metadata names every track.
        let meta: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .collect();
        assert_eq!(meta.len(), 4);
        assert!(meta
            .iter()
            .any(|e| e.get("args").unwrap().get("name").unwrap().as_str() == Some("lane 0")));
    }

    #[test]
    fn slowest_orders_by_duration() {
        let spans = vec![
            span("a", SpanKind::Lane, 0, 0, 10),
            span("b", SpanKind::Lane, 0, 0, 30),
            span("c", SpanKind::Lane, 0, 0, 20),
        ];
        let top = slowest(&spans, 2);
        assert_eq!(top.iter().map(|s| s.name.as_str()).collect::<Vec<_>>(), vec!["b", "c"]);
    }
}
