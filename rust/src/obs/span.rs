//! Fixed-capacity span recording for the run timeline.
//!
//! A [`Span`] is one timed interval on a named track: an RPC round
//! trip on its mailbox lane, a worker sweep, a launch-slot queue wait,
//! an interpreter phase. The [`SpanRecorder`] keeps spans in sharded
//! drop-oldest ring buffers (bounded memory however long the run) with
//! a dropped-span counter, and is **disabled by default**: the only
//! cost on the hot path is then one relaxed atomic load —
//! [`SpanRecorder::start`] returns `None` without reading the clock.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};
use std::time::Instant;

/// Which track family a span belongs to (one Chrome-trace `cat` and
/// `tid` block per kind — see [`super::trace`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Device-side RPC lifecycle on a mailbox lane.
    Lane,
    /// Host poll-worker activity.
    Worker,
    /// Kernel-split launch executor activity per arena slot.
    LaunchSlot,
    /// Interpreter phases (per-callee RPC waits, kernel execution).
    Interp,
    /// Middle-end passes (parse + the pass-manager pipeline).
    Pass,
    /// Serving-daemon session lifecycle (queue wait, compile-or-cache,
    /// run) — `track` is the session id, so every session owns one
    /// timeline row in the exported trace.
    Session,
}

impl SpanKind {
    /// Chrome-trace category string.
    pub fn category(self) -> &'static str {
        match self {
            SpanKind::Lane => "lane",
            SpanKind::Worker => "worker",
            SpanKind::LaunchSlot => "launch-slot",
            SpanKind::Interp => "interp",
            SpanKind::Pass => "pass",
            SpanKind::Session => "session",
        }
    }

    /// Base of this kind's `tid` block in the exported trace (one
    /// thousand ids per kind keeps tracks grouped and collision-free).
    pub fn track_base(self) -> u64 {
        match self {
            SpanKind::Lane => 1000,
            SpanKind::Worker => 2000,
            SpanKind::LaunchSlot => 3000,
            SpanKind::Interp => 4000,
            SpanKind::Pass => 5000,
            SpanKind::Session => 6000,
        }
    }
}

/// One recorded interval. `track` is the id within the kind (lane
/// index, worker index, arena slot, team id, pass ordinal).
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub name: String,
    pub kind: SpanKind,
    pub track: u64,
    pub start_ns: u64,
    pub dur_ns: u64,
}

const SHARDS: usize = 16;

/// Default per-shard ring capacity (~64Ki spans total across shards).
pub const DEFAULT_CAPACITY: usize = 4096;

/// Sharded drop-oldest span storage (see module docs).
#[derive(Debug)]
pub struct SpanRecorder {
    enabled: AtomicBool,
    zero: Instant,
    shards: Vec<Mutex<VecDeque<Span>>>,
    capacity: usize,
    dropped: AtomicU64,
}

impl Default for SpanRecorder {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_CAPACITY)
    }
}

impl SpanRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// A recorder with an explicit per-shard ring capacity (tests use
    /// tiny rings to exercise the drop-oldest path).
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            enabled: AtomicBool::new(false),
            zero: Instant::now(),
            shards: (0..SHARDS).map(|_| Mutex::new(VecDeque::new())).collect(),
            capacity: capacity.max(1),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn enable(&self) {
        self.enabled.store(true, Ordering::Relaxed);
    }

    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Nanoseconds since the recorder's epoch (device creation).
    pub fn now_ns(&self) -> u64 {
        self.zero.elapsed().as_nanos() as u64
    }

    /// Begin a gated measurement: `None` when disabled (the zero-cost
    /// path — no clock read), else the span's prospective `start_ns`.
    pub fn start(&self) -> Option<u64> {
        if self.is_enabled() {
            Some(self.now_ns())
        } else {
            None
        }
    }

    /// Close a measurement opened by [`SpanRecorder::start`]; a no-op
    /// for `None` (recorder was disabled at the open).
    pub fn finish(&self, started: Option<u64>, name: &str, kind: SpanKind, track: u64) {
        if let Some(start_ns) = started {
            let dur_ns = self.now_ns().saturating_sub(start_ns);
            self.record(name, kind, track, start_ns, dur_ns);
        }
    }

    /// Record a fully-formed span (no-op when disabled).
    pub fn record(&self, name: &str, kind: SpanKind, track: u64, start_ns: u64, dur_ns: u64) {
        if !self.is_enabled() {
            return;
        }
        self.push(Span { name: name.to_string(), kind, track, start_ns, dur_ns });
    }

    fn push(&self, span: Span) {
        let shard = (span.kind.track_base() + span.track) as usize % SHARDS;
        let mut ring = self.shards[shard].lock().unwrap_or_else(PoisonError::into_inner);
        if ring.len() >= self.capacity {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(span);
    }

    /// Spans dropped to the ring bound so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Recorded spans so far (non-destructive), ordered by start time.
    pub fn snapshot(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let ring = shard.lock().unwrap_or_else(PoisonError::into_inner);
            out.extend(ring.iter().cloned());
        }
        out.sort_by(|a, b| a.start_ns.cmp(&b.start_ns).then(a.track.cmp(&b.track)));
        out
    }

    /// Take every recorded span (export path), ordered by start time.
    pub fn drain(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let mut ring = shard.lock().unwrap_or_else(PoisonError::into_inner);
            out.extend(ring.drain(..));
        }
        out.sort_by(|a, b| a.start_ns.cmp(&b.start_ns).then(a.track.cmp(&b.track)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let r = SpanRecorder::new();
        assert!(!r.is_enabled());
        assert_eq!(r.start(), None, "no clock read when disabled");
        r.record("x", SpanKind::Lane, 0, 0, 10);
        r.finish(None, "x", SpanKind::Lane, 0);
        assert!(r.snapshot().is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let r = SpanRecorder::with_capacity(4);
        r.enable();
        // Same kind+track => one shard => the per-shard bound applies.
        for i in 0..10u64 {
            r.record("s", SpanKind::Worker, 0, i, 1);
        }
        assert_eq!(r.dropped(), 6);
        let spans = r.snapshot();
        assert_eq!(spans.len(), 4);
        // Oldest were dropped: the survivors are the last four.
        assert_eq!(spans.iter().map(|s| s.start_ns).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
    }

    #[test]
    fn start_finish_round_trip() {
        let r = SpanRecorder::new();
        r.enable();
        let t0 = r.start();
        assert!(t0.is_some());
        r.finish(t0, "op", SpanKind::Interp, 3);
        let spans = r.drain();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].name, "op");
        assert_eq!(spans[0].kind, SpanKind::Interp);
        assert_eq!(spans[0].track, 3);
        assert!(r.drain().is_empty(), "drain empties the rings");
    }
}
