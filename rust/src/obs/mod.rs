//! Observability: span tracing, latency histograms, and leveled
//! diagnostic events — the telemetry spine under `--trace-out` /
//! `--metrics-out` and the `RunMetrics` histogram section.
//!
//! One [`Obs`] bundle lives on every
//! [`DeviceMemory`](crate::gpu::memory::DeviceMemory) (every
//! instrumented layer — RPC client, engine workers, launch executor,
//! interpreter, loader — already holds the device memory), so
//! instrumentation needs no extra plumbing:
//!
//! * [`SpanRecorder`] — the run timeline. **Disabled by default**; the
//!   hot-path cost is then a single relaxed atomic load. `--trace` /
//!   `--trace-out` enable it, and [`trace::chrome_trace`] exports the
//!   recorded spans as Perfetto-loadable Chrome trace-event JSON.
//! * [`Hist`] latency histograms — always on (lock-free relaxed
//!   atomics): RPC round-trip (total and per callee), launch-executor
//!   queue wait and kernel run time; the host-I/O lock tables keep
//!   their own per-table histograms merged via [`HistSnapshot`].
//! * [`EventLog`] — structured warn-once diagnostics with counts
//!   (unresolved callees, unsupported format conversions).

pub mod event;
pub mod hist;
pub mod span;
pub mod trace;

pub use event::{EventLog, EventRecord, Level};
pub use hist::{Hist, HistSnapshot};
pub use span::{Span, SpanKind, SpanRecorder};

use std::collections::BTreeMap;
use std::sync::{Mutex, PoisonError};

/// The per-device observability bundle (see module docs).
#[derive(Debug, Default)]
pub struct Obs {
    pub spans: SpanRecorder,
    pub events: EventLog,
    /// Device-observed RPC round-trip wall time (claim → writeback).
    pub rpc_round_trip: Hist,
    /// Launch-executor queue wait (submit → executor pickup).
    pub launch_queue_wait: Hist,
    /// Launch-executor kernel run time.
    pub launch_run: Hist,
    per_callee: Mutex<BTreeMap<u64, Hist>>,
}

impl Obs {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one RPC round trip, attributed to `callee_id`.
    pub fn record_rpc(&self, callee_id: u64, dur_ns: u64) {
        self.rpc_round_trip.record(dur_ns);
        let mut map = self.per_callee.lock().unwrap_or_else(PoisonError::into_inner);
        map.entry(callee_id).or_default().record(dur_ns);
    }

    /// Per-callee round-trip histograms, keyed by registry callee id.
    pub fn per_callee_rpc(&self) -> BTreeMap<u64, HistSnapshot> {
        let map = self.per_callee.lock().unwrap_or_else(PoisonError::into_inner);
        map.iter().map(|(id, h)| (*id, h.snapshot())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rpc_recording_feeds_total_and_per_callee() {
        let obs = Obs::new();
        obs.record_rpc(3, 100);
        obs.record_rpc(3, 200);
        obs.record_rpc(7, 50);
        assert_eq!(obs.rpc_round_trip.count(), 3);
        let per = obs.per_callee_rpc();
        assert_eq!(per.len(), 2);
        assert_eq!(per[&3].count, 2);
        assert_eq!(per[&7].count, 1);
        assert_eq!(per[&3].max, 200);
    }

    #[test]
    fn spans_default_disabled() {
        let obs = Obs::new();
        assert!(!obs.spans.is_enabled());
    }
}
