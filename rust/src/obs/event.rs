//! Leveled, warn-once diagnostic events.
//!
//! Replaces the ad-hoc `eprintln!` warn-once idiom scattered through
//! the runtime (unresolved-callee traps, unsupported format
//! conversions) with one structured path: an event is keyed by
//! `(code, detail)`, printed to stderr only on its first occurrence,
//! and counted on every occurrence — so `RunMetrics` can report the
//! totals and the message stream stays bounded.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock, PoisonError};

/// Event severity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Info,
    Warn,
    Error,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }
}

/// One distinct event with its occurrence count.
#[derive(Debug, Clone, PartialEq)]
pub struct EventRecord {
    pub level: Level,
    /// Stable machine-readable class, e.g. `unresolved-symbol`.
    pub code: String,
    /// The instance within the class, e.g. the symbol name.
    pub detail: String,
    /// The human message printed on first occurrence.
    pub message: String,
    pub count: u64,
}

/// The structured warn-once log (see module docs).
#[derive(Debug, Default)]
pub struct EventLog {
    entries: Mutex<BTreeMap<(String, String), EventRecord>>,
}

impl EventLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one occurrence of `(code, detail)`. The first occurrence
    /// prints `message` to stderr (the warn-once contract) and returns
    /// true; repeats only bump the count.
    pub fn emit(&self, level: Level, code: &str, detail: &str, message: &str) -> bool {
        let mut map = self.entries.lock().unwrap_or_else(PoisonError::into_inner);
        let key = (code.to_string(), detail.to_string());
        if let Some(rec) = map.get_mut(&key) {
            rec.count += 1;
            return false;
        }
        eprintln!(";; gpu-first: [{}] {message}", level.as_str());
        map.insert(
            key,
            EventRecord {
                level,
                code: code.to_string(),
                detail: detail.to_string(),
                message: message.to_string(),
                count: 1,
            },
        );
        true
    }

    /// Total occurrences across every `detail` of `code`.
    pub fn count_code(&self, code: &str) -> u64 {
        let map = self.entries.lock().unwrap_or_else(PoisonError::into_inner);
        map.values().filter(|r| r.code == code).map(|r| r.count).sum()
    }

    /// Total occurrences at `level` across all events.
    pub fn count_level(&self, level: Level) -> u64 {
        let map = self.entries.lock().unwrap_or_else(PoisonError::into_inner);
        map.values().filter(|r| r.level == level).map(|r| r.count).sum()
    }

    /// Every distinct event with counts, ordered by `(code, detail)`.
    pub fn snapshot(&self) -> Vec<EventRecord> {
        let map = self.entries.lock().unwrap_or_else(PoisonError::into_inner);
        map.values().cloned().collect()
    }
}

/// The process-global log for diagnostics raised from free functions
/// with no device in scope (e.g. format-conversion warnings inside the
/// host wrapper formatting core).
pub fn global() -> &'static EventLog {
    static GLOBAL: OnceLock<EventLog> = OnceLock::new();
    GLOBAL.get_or_init(EventLog::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warn_once_counts_every_occurrence() {
        let log = EventLog::new();
        assert!(log.emit(Level::Warn, "unresolved-symbol", "dgemm", "dgemm degraded"));
        assert!(!log.emit(Level::Warn, "unresolved-symbol", "dgemm", "dgemm degraded"));
        assert!(log.emit(Level::Warn, "unresolved-symbol", "sgemm", "sgemm degraded"));
        assert_eq!(log.count_code("unresolved-symbol"), 3);
        assert_eq!(log.count_level(Level::Warn), 3);
        assert_eq!(log.count_level(Level::Error), 0);
        let snap = log.snapshot();
        assert_eq!(snap.len(), 2, "one record per (code, detail)");
        assert_eq!(snap[0].detail, "dgemm");
        assert_eq!(snap[0].count, 2);
        assert_eq!(snap[1].detail, "sgemm");
        assert_eq!(snap[1].count, 1);
    }

    #[test]
    fn distinct_codes_do_not_alias() {
        let log = EventLog::new();
        log.emit(Level::Warn, "a", "x", "m1");
        log.emit(Level::Info, "b", "x", "m2");
        assert_eq!(log.count_code("a"), 1);
        assert_eq!(log.count_code("b"), 1);
        assert_eq!(log.count_level(Level::Info), 1);
    }
}
