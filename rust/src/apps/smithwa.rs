//! 372.smithwa (Fig. 10c): Smith-Waterman local alignment. The workload
//! is distributed across threads which communicate through a
//! producer-consumer scheme of shared variables **followed by barriers**
//! (one wave per DP row) — conceptually inefficient on GPUs. Each region
//! also allocates/frees per-thread scratch on the device heap, which is
//! why the paper notes the run is allocator-bound without the balanced
//! allocator.
//!
//! Fig. 10c's x-axis is the SPEC "sequence length" exponent; the DP
//! problem is `n = 2^(l/2)` so the cell count is `2^l`. The paper sees
//! stable relative performance until length 26, then exponentially
//! growing slowdown: the full benchmark's working set (~640 B per cell
//! row-block across its report structures) exceeds the A100's 40 GB at
//! l ≥ 26 and managed memory starts thrashing. We model that
//! oversubscription term explicitly; the DP itself is computed for real
//! (sub-sampled above `REAL_CELL_CAP`, with counts scaled analytically).

use super::common::{self, AppResult, Mode};
use crate::gpu::grid::{AllocatorKind, Device, LaunchConfig};
use crate::gpu::memory::MemConfig;
use crate::gpu::stats::{LaunchStats, Pattern};
use crate::perfmodel::a100;
use crate::util::rng::Xoshiro256;

/// Real-compute cap: above this many DP cells, compute a sample and scale
/// the operation counts (the modeled time drives the figure).
const REAL_CELL_CAP: u64 = 1 << 24;
/// Full-benchmark bytes per DP cell (matrix + report structures).
const BYTES_PER_CELL: f64 = 640.0;
const DEVICE_MEM_BYTES: f64 = 40.0 * 1024.0 * 1024.0 * 1024.0;

#[derive(Debug, Clone, Copy)]
pub struct SmithwaWorkload {
    /// SPEC-style "sequence length" exponent (Fig. 10c x-axis).
    pub length_exp: u32,
    pub threads: usize,
}

impl SmithwaWorkload {
    pub fn new(length_exp: u32) -> Self {
        Self { length_exp, threads: 64 }
    }

    pub fn n(&self) -> u64 {
        1u64 << (self.length_exp / 2)
    }

    pub fn cells(&self) -> u64 {
        self.n() * self.n()
    }

    pub fn working_set_bytes(&self) -> f64 {
        self.cells() as f64 * BYTES_PER_CELL
    }
}

/// Smith-Waterman DP over anti-ordered rows with a barrier per row wave
/// (the producer-consumer structure). Returns (best score, stats).
fn wavefront_dp(
    dev: &Device,
    w: &SmithwaWorkload,
    n: usize,
    a: &[u8],
    b: &[u8],
) -> (i32, LaunchStats) {
    use std::sync::atomic::{AtomicI32, Ordering};
    let prev: Vec<AtomicI32> = (0..=n).map(|_| AtomicI32::new(0)).collect();
    let cur: Vec<AtomicI32> = (0..=n).map(|_| AtomicI32::new(0)).collect();
    let best = AtomicI32::new(0);
    let threads = w.threads.min(n.max(1));
    let cfg = LaunchConfig::new(1, threads);
    let chunk = n.div_ceil(threads);

    // One phase per DP row: threads fill disjoint column chunks of `cur`
    // from `prev` (the wave structure makes within-row cells depend only
    // on the previous row in this banded variant), then barrier.
    let stats = dev.launch_phased(cfg, n, |ctx, row| {
        let t = ctx.global_tid();
        // Region-boundary allocation (the paper's allocator stress): a
        // per-thread scratch line allocated and freed each wave.
        let scratch = ctx.malloc(64).ok();
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(n);
        let ca = a[row % a.len()];
        let mut local_best = 0;
        for j in lo..hi {
            let m = if ca == b[j % b.len()] { 3 } else { -1 };
            let diag = prev[j].load(Ordering::Relaxed);
            let up = prev[j + 1].load(Ordering::Relaxed);
            let v = (diag + m).max(up - 2).max(0);
            cur[j + 1].store(v, Ordering::Relaxed);
            local_best = local_best.max(v);
        }
        best.fetch_max(local_best, Ordering::Relaxed);
        ctx.mem((hi - lo) as u64 * 12, Pattern::Strided);
        ctx.int_ops((hi - lo) as u64 * 10);
        if row + 1 < n {
            // Producer-consumer handoff: copy cur -> prev in our chunk.
            for j in lo..hi {
                prev[j + 1].store(cur[j + 1].load(Ordering::Relaxed), Ordering::Relaxed);
            }
            ctx.mem((hi - lo) as u64 * 8, Pattern::Strided);
        }
        if let Some(p) = scratch {
            ctx.free(p).ok();
        }
    });
    (best.load(Ordering::Relaxed), stats)
}

pub fn run_with_allocator(mode: Mode, w: &SmithwaWorkload, alloc: AllocatorKind) -> AppResult {
    let n_real = (w.n().min((REAL_CELL_CAP as f64).sqrt() as u64)) as usize;
    let scale = (w.cells() as f64 / (n_real as f64 * n_real as f64)).max(1.0);
    let mut rng = Xoshiro256::new(0x57A7);
    let a: Vec<u8> = (0..n_real).map(|_| rng.next_below(20) as u8).collect();
    let b: Vec<u8> = (0..n_real).map(|_| rng.next_below(20) as u8).collect();
    let t0 = std::time::Instant::now();

    let dev = Device::new(MemConfig::small(), alloc);
    let (score, mut stats) = wavefront_dp(&dev, w, n_real, &a, &b);

    // Scale the sampled counts to the full problem.
    stats.bytes_strided = (stats.bytes_strided as f64 * scale) as u64;
    stats.int_ops = (stats.int_ops as f64 * scale) as u64;
    stats.barriers_global = (stats.barriers_global as f64 * scale.sqrt()) as u64;
    stats.allocs = (stats.allocs as f64 * scale.sqrt()) as u64;
    stats.frees = stats.allocs;

    let wall_ns = t0.elapsed().as_nanos() as f64;
    // Allocator serialization: real per-lock traffic, modeled per-op cost.
    let alloc_stats = dev.heap.stats();
    let alloc_ns = alloc_stats.modeled_ns(dev.heap.per_op_ns()) * scale.sqrt();

    let modeled_ns = match mode {
        Mode::Cpu => common::cpu_modeled_ns(&stats, common::CPU_THREADS.min(w.threads)),
        Mode::Offload => panic!("no manual offload exists for 372.smithwa"),
        _ => {
            let mut t = common::gpu_modeled_ns(&stats, w.threads as u64, 1)
                + a100::KERNEL_SPLIT_RPC_NS
                + alloc_ns;
            // Managed-memory oversubscription: past device capacity every
            // extra byte pays migration, growing exponentially with the
            // oversubscription ratio.
            let ratio = w.working_set_bytes() / DEVICE_MEM_BYTES;
            if ratio > 1.0 {
                // Each doubling of oversubscription roughly quadruples the
                // page-migration traffic; saturates once everything faults.
                t *= (2.0f64).powf((ratio - 1.0).min(5.0) * 2.0);
            }
            t
        }
    };
    AppResult {
        app: "smithwa".into(),
        mode,
        workload: format!("length 2^{} ({} alloc)", w.length_exp, dev.heap.name()),
        modeled_ns,
        wall_ns,
        checksum: score as f64,
        stats,
    }
}

pub fn run(mode: Mode, w: &SmithwaWorkload) -> AppResult {
    run_with_allocator(mode, w, AllocatorKind::Balanced(Default::default()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_deterministic_across_allocators() {
        let w = SmithwaWorkload { length_exp: 16, threads: 16 };
        let a = run_with_allocator(Mode::GpuFirst, &w, AllocatorKind::Balanced(Default::default()));
        let b = run_with_allocator(Mode::GpuFirst, &w, AllocatorKind::Generic);
        assert_eq!(a.checksum, b.checksum);
        assert!(a.checksum > 0.0);
    }

    #[test]
    fn fig10c_stable_then_blowup_after_26() {
        let rel = |l: u32| {
            let w = SmithwaWorkload::new(l);
            let cpu = run(Mode::Cpu, &w);
            let gpu = run(Mode::GpuFirst, &w);
            gpu.modeled_ns / cpu.modeled_ns
        };
        let r20 = rel(20);
        let r24 = rel(24);
        let r28 = rel(28);
        let r30 = rel(30);
        // Stable region: within 2x of each other.
        assert!((r24 / r20) < 3.0, "stable region drifts: {r20} -> {r24}");
        // Blow-up region: super-linear growth past 26.
        assert!(r28 > 3.0 * r24, "no blowup at 28: {r24} -> {r28}");
        assert!(r30 > 3.0 * r28, "not exponential: {r28} -> {r30}");
    }

    #[test]
    fn balanced_allocator_removes_alloc_domination() {
        // Paper: "without the balanced allocator the performance is
        // dominated by the massively parallel allocations".
        let w = SmithwaWorkload { length_exp: 20, threads: 64 };
        let bal =
            run_with_allocator(Mode::GpuFirst, &w, AllocatorKind::Balanced(Default::default()));
        let vendor = run_with_allocator(Mode::GpuFirst, &w, AllocatorKind::Vendor);
        assert!(
            vendor.modeled_ns > 1.5 * bal.modeled_ns,
            "vendor {} vs balanced {}",
            vendor.modeled_ns,
            bal.modeled_ns
        );
    }
}
