//! XSBench (Fig. 8a): macroscopic cross-section lookup, event- and
//! history-based, in CPU / GPU-First / manual-offload variants.
//!
//! Faithful port of the v20 lookup kernel: bisection over the unionized
//! energy grid, linear interpolation of the reaction channels, material
//! scaling. History mode chains each particle's next energy off the
//! previous macroscopic total (the serial dependence that distinguishes
//! it); the offload comparator executes the AOT Pallas artifact
//! (`xs_event_*`) through PJRT.
//!
//! Modeling choices that produce the paper's Fig. 8 shapes (DESIGN.md §2):
//! * GPU occupancy: event parallelism = all lookups, history = particles.
//! * Temporal locality: a particle's sequential lookups hit nearby grid
//!   cells, so history-mode gathers get an L2-resident discount when the
//!   (full-application-scaled) table fits the A100's 40 MB L2; the paper
//!   observes exactly this "history outperforms event for the small
//!   input, event catches up / surpasses for the large input".

use super::common::{self, checksum, grid_for, AppResult, Mode};
use crate::gpu::stats::{LaunchStats, Pattern};
use crate::perfmodel::a100;
use crate::util::rng::SplitMix64;

pub const CHANNELS: usize = 5;
pub const MATERIALS: usize = 12;
/// XSBench's real unionized table carries per-nuclide data (~68 nuclides
/// in the large problem); our artifact-sized table models the gather
/// footprint scaled by this factor for the cache model.
const NUCLIDE_SCALE: u64 = 68;
const A100_L2_BYTES: f64 = 40.0 * 1024.0 * 1024.0;
/// The paper-sized run performs this many batches of our artifact-sized
/// batch (XSBench large does ~17M lookups; we compute one batch for real
/// and scale the counts).
pub const BATCHES: f64 = 1024.0;
/// L2-resident gather discount (history mode, table fits).
const L2_RESIDENT_FACTOR: f64 = 0.15;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LookupMode {
    Event,
    History,
}

#[derive(Debug, Clone)]
pub struct XsWorkload {
    pub label: &'static str,
    pub gridpoints: usize,
    pub event_lookups: usize,
    pub particles: usize,
    pub history_steps: usize,
}

impl XsWorkload {
    /// Matches the `xs_*_small` artifacts.
    pub fn small() -> Self {
        Self {
            label: "small",
            gridpoints: 2048,
            event_lookups: 4096,
            particles: 4096,
            history_steps: 8,
        }
    }

    /// Matches the `xs_*_large` artifacts.
    pub fn large() -> Self {
        Self {
            label: "large",
            gridpoints: 32768,
            event_lookups: 4096,
            particles: 4096,
            history_steps: 8,
        }
    }

    /// Deterministic inputs shared by every mode (and by the artifact).
    pub fn generate(&self) -> XsData {
        let g = self.gridpoints;
        let mut egrid = Vec::with_capacity(g);
        let mut acc = 0.0f32;
        for i in 0..g {
            acc += 1e-4 + (SplitMix64::at(11, i as u64) % 1000) as f32 * 1e-6;
            egrid.push(acc);
        }
        let lo = egrid[0];
        let span = egrid[g - 1] - lo;
        for v in egrid.iter_mut() {
            *v = (*v - lo) / span;
        }
        let xs: Vec<f32> = (0..g * CHANNELS)
            .map(|i| 0.1 + (SplitMix64::at(13, i as u64) % 997) as f32 * 0.01)
            .collect();
        let scale: Vec<f32> = (0..MATERIALS)
            .map(|i| 0.5 + (SplitMix64::at(17, i as u64) % 100) as f32 * 0.015)
            .collect();
        let n = self.event_lookups.max(self.particles);
        let e: Vec<f32> =
            (0..n).map(|i| (SplitMix64::at(19, i as u64) % 999_983) as f32 / 1e6).collect();
        let mats: Vec<i32> =
            (0..n).map(|i| (SplitMix64::at(23, i as u64) % MATERIALS as u64) as i32).collect();
        XsData { egrid, xs, scale, e, mats }
    }

    fn table_bytes_scaled(&self) -> f64 {
        (self.gridpoints * CHANNELS * 4) as f64 * NUCLIDE_SCALE as f64
    }
}

pub struct XsData {
    pub egrid: Vec<f32>,
    pub xs: Vec<f32>,
    pub scale: Vec<f32>,
    pub e: Vec<f32>,
    pub mats: Vec<i32>,
}

/// The lookup kernel itself — identical code on every substrate.
#[inline]
pub fn lookup(data: &XsData, energy: f32, mat: usize) -> [f32; CHANNELS] {
    let g = data.egrid.len();
    // upper_bound - 1, as jnp.searchsorted(side="right") - 1.
    let mut lo = 0usize;
    let mut hi = g;
    while lo < hi {
        let mid = (lo + hi) / 2;
        if data.egrid[mid] <= energy {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    let idx = lo.saturating_sub(1).min(g - 2);
    let e0 = data.egrid[idx];
    let e1 = data.egrid[idx + 1];
    let w = (energy - e0) / (e1 - e0);
    let sc = data.scale[mat];
    let mut out = [0f32; CHANNELS];
    for (ch, o) in out.iter_mut().enumerate() {
        let l = data.xs[idx * CHANNELS + ch];
        let h = data.xs[(idx + 1) * CHANNELS + ch];
        *o = (l * (1.0 - w) + h * w) * sc;
    }
    out
}

/// Per-lookup operation counts for the cost models.
fn count_lookup(stats: &mut LaunchStats, g: usize, n_lookups: u64) {
    let log_g = (usize::BITS - g.leading_zeros()) as u64;
    stats.bytes_random += n_lookups * (log_g * 4 + 2 * CHANNELS as u64 * 4 + 8);
    stats.int_ops += n_lookups * (log_g * 6 + 10);
    stats.flops_f32 += n_lookups * (3 * CHANNELS as u64 + 4);
}

fn history_chain(data: &XsData, p: usize, steps: usize) -> f32 {
    let mut e = data.e[p];
    let mut acc = 0f32;
    for _ in 0..steps {
        let out = lookup(data, e, data.mats[p] as usize);
        let total: f32 = out.iter().sum();
        acc += total;
        e = (e * 0.618_034 + total * 1e-3).rem_euclid(1.0);
    }
    acc
}

/// Run one (mode × lookup-mode × workload) cell of Fig. 8a.
pub fn run(mode: Mode, lm: LookupMode, w: &XsWorkload) -> AppResult {
    let data = w.generate();
    let t0 = std::time::Instant::now();
    let mut stats = LaunchStats::default();
    let cs;
    let workload =
        format!("{}/{}", w.label, if lm == LookupMode::Event { "event" } else { "history" });

    match (mode, lm) {
        (Mode::Offload, LookupMode::History) => {
            // Paper: "In the offloading version history-based mode was not
            // implemented" — we surface the same gap.
            panic!("manual offload of history mode does not exist (paper §5.3.1)");
        }
        (Mode::Offload, LookupMode::Event) => {
            // The manually offloaded kernel: the AOT Pallas artifact.
            let name = format!("xs_event_{}", w.label);
            let b = w.event_lookups;
            let out: Vec<f32> = common::with_runtime(|rt| {
                let lits = vec![
                    xla::Literal::vec1(&data.e[..b]).reshape(&[b as i64]).unwrap(),
                    xla::Literal::vec1(&data.mats[..b]).reshape(&[b as i64]).unwrap(),
                    xla::Literal::vec1(&data.egrid).reshape(&[w.gridpoints as i64]).unwrap(),
                    xla::Literal::vec1(&data.xs)
                        .reshape(&[w.gridpoints as i64, CHANNELS as i64])
                        .unwrap(),
                    xla::Literal::vec1(&data.scale).reshape(&[MATERIALS as i64]).unwrap(),
                ];
                rt.execute(&name, &lits).unwrap()[0].to_vec().unwrap()
            })
            .expect("offload mode needs artifacts");
            cs = checksum(out.chunks(CHANNELS).map(|c| c.iter().sum::<f32>() as f64));
            count_lookup(&mut stats, w.gridpoints, b as u64);
        }
        (Mode::Cpu, lm) => {
            let sums = match lm {
                LookupMode::Event => parallel_map_cpu(w.event_lookups, |i| {
                    lookup(&data, data.e[i], data.mats[i] as usize).iter().sum::<f32>() as f64
                }),
                LookupMode::History => parallel_map_cpu(w.particles, |p| {
                    history_chain(&data, p, w.history_steps) as f64
                }),
            };
            cs = checksum(sums);
            let n = match lm {
                LookupMode::Event => w.event_lookups as u64,
                LookupMode::History => (w.particles * w.history_steps) as u64,
            };
            count_lookup(&mut stats, w.gridpoints, n);
        }
        (gpu_mode, lm) => {
            // GPU First: the expanded multi-team region on the simulator.
            let dev = common::shared_device();
            let cfg = grid_for(gpu_mode, 64);
            let log_g = (usize::BITS - w.gridpoints.leading_zeros()) as u64;
            let items = match lm {
                LookupMode::Event => w.event_lookups,
                LookupMode::History => w.particles,
            };
            let outsums: std::sync::Mutex<Vec<(usize, f64)>> = std::sync::Mutex::new(Vec::new());
            let ls = dev.launch(cfg, |ctx| {
                let n = ctx.num_threads_global();
                let mut local = Vec::new();
                let mut i = ctx.global_tid();
                while i < items {
                    match lm {
                        LookupMode::Event => {
                            let out = lookup(&data, data.e[i], data.mats[i] as usize);
                            local.push((i, out.iter().sum::<f32>() as f64));
                            ctx.mem(log_g * 4 + 48, Pattern::Random);
                            ctx.int_ops(log_g * 6 + 10);
                            ctx.flops32(19);
                        }
                        LookupMode::History => {
                            local.push((i, history_chain(&data, i, w.history_steps) as f64));
                            let h = w.history_steps as u64;
                            ctx.mem(h * (log_g * 4 + 48), Pattern::Random);
                            ctx.int_ops(h * (log_g * 6 + 10));
                            ctx.flops32(h * 19);
                        }
                    }
                    i += n;
                }
                outsums.lock().unwrap().extend(local);
            });
            let mut sums = outsums.into_inner().unwrap();
            sums.sort_by_key(|&(i, _)| i);
            cs = checksum(sums.into_iter().map(|(_, s)| s));
            stats = ls;
        }
    }

    let wall_ns = t0.elapsed().as_nanos() as f64;
    let modeled_ns = model_time(mode, lm, w, &stats);
    AppResult { app: "xsbench".into(), mode, workload, modeled_ns, wall_ns, checksum: cs, stats }
}

fn model_time(mode: Mode, lm: LookupMode, w: &XsWorkload, stats: &LaunchStats) -> f64 {
    let scaled = common::scale_stats(stats, BATCHES);
    match mode {
        Mode::Cpu => common::cpu_modeled_ns(&scaled, common::CPU_THREADS),
        _ => {
            let mut s = scaled;
            let active = match lm {
                // All lookups of the full run are independent threads.
                LookupMode::Event => (w.event_lookups as f64 * BATCHES) as u64,
                LookupMode::History => {
                    // Temporal locality discount when the scaled table is
                    // L2-resident; only the particles run concurrently.
                    let f = (w.table_bytes_scaled() / A100_L2_BYTES).clamp(L2_RESIDENT_FACTOR, 1.0);
                    s.bytes_random = (s.bytes_random as f64 * f) as u64;
                    w.particles as u64
                }
            };
            // Fig. 8 times the compute kernel only (no transfers). GPU
            // First's data initialization also ran on the device, so for
            // L2-resident tables its gathers start warm (paper: "the GPU
            // First versions are likely to benefit from cache re-use").
            if mode != Mode::Offload && w.table_bytes_scaled() < A100_L2_BYTES {
                s.bytes_random = (s.bytes_random as f64 * 0.6) as u64;
            }
            let mut t = common::gpu_modeled_ns(&s, active, 1);
            if mode != Mode::Offload {
                t += a100::KERNEL_SPLIT_RPC_NS; // the expanded region's launch
            }
            t
        }
    }
}

pub(crate) fn parallel_map_cpu<F: Fn(usize) -> f64 + Sync>(n: usize, f: F) -> Vec<f64> {
    let threads = common::CPU_THREADS
        .min(std::thread::available_parallelism().map(|x| x.get()).unwrap_or(8));
    let mut out = vec![0f64; n];
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (j, v) in slice.iter_mut().enumerate() {
                    *v = f(t * chunk + j);
                }
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::common::close;

    #[test]
    fn cpu_and_gpufirst_agree_on_checksum() {
        let w = XsWorkload::small();
        let cpu = run(Mode::Cpu, LookupMode::Event, &w);
        let gpu = run(Mode::GpuFirst, LookupMode::Event, &w);
        assert!(close(cpu.checksum, gpu.checksum, 1e-9), "{} vs {}", cpu.checksum, gpu.checksum);
    }

    #[test]
    fn history_checksums_agree_across_substrates() {
        let w = XsWorkload::small();
        let cpu = run(Mode::Cpu, LookupMode::History, &w);
        let gpu = run(Mode::GpuFirst, LookupMode::History, &w);
        assert!(close(cpu.checksum, gpu.checksum, 1e-9));
    }

    #[test]
    fn lookup_interpolates_linearly() {
        let w = XsWorkload::small();
        let data = w.generate();
        let idx = 100;
        let e_mid = 0.5 * (data.egrid[idx] + data.egrid[idx + 1]);
        let out = lookup(&data, e_mid, 0);
        for ch in 0..CHANNELS {
            let want = 0.5 * (data.xs[idx * CHANNELS + ch] + data.xs[(idx + 1) * CHANNELS + ch])
                * data.scale[0];
            assert!((out[ch] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn fig8a_shape_history_wins_small_event_wins_large() {
        // The paper's headline insight for XSBench.
        let per_lookup = |r: &AppResult, n: u64| r.modeled_ns / n as f64;
        let rel = |w: &XsWorkload, lm: LookupMode| {
            let n = match lm {
                LookupMode::Event => w.event_lookups as u64,
                LookupMode::History => (w.particles * w.history_steps) as u64,
            };
            let gpu = run(Mode::GpuFirst, lm, w);
            let cpu = run(Mode::Cpu, lm, w);
            per_lookup(&cpu, n) / per_lookup(&gpu, n)
        };
        let small = XsWorkload::small();
        let large = XsWorkload::large();
        let (ev_s, hi_s) = (rel(&small, LookupMode::Event), rel(&small, LookupMode::History));
        let (ev_l, hi_l) = (rel(&large, LookupMode::Event), rel(&large, LookupMode::History));
        assert!(hi_s > ev_s, "small input: history {hi_s:.3} should beat event {ev_s:.3}");
        assert!(ev_l > hi_l, "large input: event {ev_l:.3} should surpass history {hi_l:.3}");
    }

    #[test]
    #[should_panic(expected = "history mode does not exist")]
    fn offload_history_not_implemented_like_paper() {
        run(Mode::Offload, LookupMode::History, &XsWorkload::small());
    }
}
