//! RSBench (Fig. 8b): windowed-multipole resonance cross sections — the
//! reduced-data-movement alternative to XSBench's table lookup. Compute
//! bound (complex pole arithmetic), tiny tables.

use super::common::{self, checksum, grid_for, AppResult, Mode};
use super::xsbench::parallel_map_cpu;
use crate::gpu::stats::{LaunchStats, Pattern};
use crate::perfmodel::a100;
use crate::util::rng::SplitMix64;

pub const WINDOW: usize = 16;
/// Full-run scale factor (see xsbench::BATCHES).
pub const BATCHES: f64 = 256.0;

#[derive(Debug, Clone)]
pub struct RsWorkload {
    pub label: &'static str,
    pub poles: usize,
    pub lookups: usize,
    pub particles: usize,
    pub history_steps: usize,
}

impl RsWorkload {
    pub fn small() -> Self {
        Self { label: "small", poles: 1024, lookups: 2048, particles: 2048, history_steps: 8 }
    }

    pub fn large() -> Self {
        Self { label: "large", poles: 8192, lookups: 2048, particles: 2048, history_steps: 8 }
    }

    pub fn generate(&self) -> RsData {
        let p = self.poles;
        let poles: Vec<f32> = (0..p * 4)
            .map(|i| {
                let v = (SplitMix64::at(31, i as u64) % 2000) as f32 / 1000.0 - 1.0;
                if i % 4 == 3 {
                    v.abs() + 0.1 // keep poles off the real axis
                } else {
                    v
                }
            })
            .collect();
        let n = self.lookups.max(self.particles);
        let e: Vec<f32> =
            (0..n).map(|i| 0.1 + (SplitMix64::at(37, i as u64) % 800) as f32 / 1000.0).collect();
        let win: Vec<i32> = (0..n * WINDOW)
            .map(|i| (SplitMix64::at(41, i as u64) % p as u64) as i32)
            .collect();
        RsData { poles, e, win }
    }
}

pub struct RsData {
    /// [P,4] rows: re_num, im_num, re_pole, im_pole.
    pub poles: Vec<f32>,
    pub e: Vec<f32>,
    /// [N, WINDOW] pole indices.
    pub win: Vec<i32>,
}

/// One resonance evaluation — identical code on every substrate; mirrors
/// `ref.rs_lookup_ref`.
#[inline]
pub fn eval(data: &RsData, i: usize) -> f32 {
    let e = data.e[i];
    let mut acc = 0f32;
    for k in 0..WINDOW {
        let p = data.win[i * WINDOW + k] as usize * 4;
        let (nr, ni, pr, pi) =
            (data.poles[p], data.poles[p + 1], data.poles[p + 2], data.poles[p + 3]);
        let dr = e - pr;
        let di = -pi;
        let den = (dr * dr + di * di).max(1e-30);
        acc += (nr * dr + ni * di) / den;
    }
    acc
}

fn count_eval(stats: &mut LaunchStats, n: u64) {
    stats.bytes_random += n * (WINDOW as u64 * 20);
    stats.flops_f32 += n * (WINDOW as u64 * 10);
    stats.int_ops += n * (WINDOW as u64 * 4);
}

fn history_chain(data: &RsData, p: usize, steps: usize, n_poles: usize) -> f32 {
    let mut acc = 0f32;
    let mut i = p;
    for _ in 0..steps {
        let v = eval(data, i);
        acc += v;
        // Next window depends on the previous result (serial dependence).
        i = (i + (v.abs() * 997.0) as usize) % data.e.len().min(n_poles.max(1));
    }
    acc
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LookupMode {
    Event,
    History,
}

pub fn run(mode: Mode, lm: LookupMode, w: &RsWorkload) -> AppResult {
    let data = w.generate();
    let t0 = std::time::Instant::now();
    let mut stats = LaunchStats::default();
    let cs;
    let workload =
        format!("{}/{}", w.label, if lm == LookupMode::Event { "event" } else { "history" });

    match (mode, lm) {
        (Mode::Offload, LookupMode::History) => {
            panic!("manual offload of history mode does not exist (paper §5.3.1)")
        }
        (Mode::Offload, LookupMode::Event) => {
            let name = format!("rs_lookup_{}", w.label);
            let b = w.lookups;
            let out: Vec<f32> = common::with_runtime(|rt| {
                let lits = vec![
                    xla::Literal::vec1(&data.e[..b]).reshape(&[b as i64]).unwrap(),
                    xla::Literal::vec1(&data.win[..b * WINDOW])
                        .reshape(&[b as i64, WINDOW as i64])
                        .unwrap(),
                    xla::Literal::vec1(&data.poles).reshape(&[w.poles as i64, 4]).unwrap(),
                ];
                rt.execute(&name, &lits).unwrap()[0].to_vec().unwrap()
            })
            .expect("offload mode needs artifacts");
            cs = checksum(out.iter().map(|&x| x as f64));
            count_eval(&mut stats, b as u64);
        }
        (Mode::Cpu, lm) => {
            let sums = match lm {
                LookupMode::Event => parallel_map_cpu(w.lookups, |i| eval(&data, i) as f64),
                LookupMode::History => parallel_map_cpu(w.particles, |p| {
                    history_chain(&data, p, w.history_steps, w.poles) as f64
                }),
            };
            cs = checksum(sums);
            let n = match lm {
                LookupMode::Event => w.lookups as u64,
                LookupMode::History => (w.particles * w.history_steps) as u64,
            };
            count_eval(&mut stats, n);
        }
        (gpu_mode, lm) => {
            let dev = common::shared_device();
            let cfg = grid_for(gpu_mode, 64);
            let items = match lm {
                LookupMode::Event => w.lookups,
                LookupMode::History => w.particles,
            };
            let outsums: std::sync::Mutex<Vec<(usize, f64)>> = std::sync::Mutex::new(Vec::new());
            let ls = dev.launch(cfg, |ctx| {
                let n = ctx.num_threads_global();
                let mut local = Vec::new();
                let mut i = ctx.global_tid();
                while i < items {
                    match lm {
                        LookupMode::Event => {
                            local.push((i, eval(&data, i) as f64));
                            ctx.mem(WINDOW as u64 * 20, Pattern::Random);
                            ctx.flops32(WINDOW as u64 * 10);
                            ctx.int_ops(WINDOW as u64 * 4);
                        }
                        LookupMode::History => {
                            local.push((
                                i,
                                history_chain(&data, i, w.history_steps, w.poles) as f64,
                            ));
                            let h = w.history_steps as u64;
                            ctx.mem(h * WINDOW as u64 * 20, Pattern::Random);
                            ctx.flops32(h * WINDOW as u64 * 10);
                            ctx.int_ops(h * WINDOW as u64 * 4);
                        }
                    }
                    i += n;
                }
                outsums.lock().unwrap().extend(local);
            });
            let mut sums = outsums.into_inner().unwrap();
            sums.sort_by_key(|&(i, _)| i);
            cs = checksum(sums.into_iter().map(|(_, s)| s));
            stats = ls;
        }
    }

    let wall_ns = t0.elapsed().as_nanos() as f64;
    let modeled_ns = match mode {
        Mode::Cpu => {
            common::cpu_modeled_ns(&common::scale_stats(&stats, BATCHES), common::CPU_THREADS)
        }
        _ => {
            let mut stats = common::scale_stats(&stats, BATCHES);
            let active = match lm {
                LookupMode::Event => (w.lookups as f64 * BATCHES) as u64,
                LookupMode::History => {
                    // Same temporal-locality discount as XSBench: a
                    // particle's sequential windows stay L2-resident while
                    // the (full-app-scaled) pole table fits 40 MB. RSBench
                    // stores ~300 doubles of multipole data per pole.
                    let scaled = (w.poles * 16 * 300) as f64;
                    let f = (scaled / (40.0 * 1024.0 * 1024.0)).clamp(0.15, 1.0);
                    stats.bytes_random = (stats.bytes_random as f64 * f) as u64;
                    // Unlike XSBench's pointer-chase, the window loop gives
                    // each particle ~4-wide memory-level parallelism.
                    w.particles as u64 * 4
                }
            };
            // Fig. 8 times the compute kernel only (no transfers).
            let mut t = common::gpu_modeled_ns(&stats, active, 1);
            if mode != Mode::Offload {
                t += a100::KERNEL_SPLIT_RPC_NS;
            }
            t
        }
    };
    AppResult { app: "rsbench".into(), mode, workload, modeled_ns, wall_ns, checksum: cs, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::common::close;

    #[test]
    fn substrates_agree_on_checksum() {
        let w = RsWorkload::small();
        let cpu = run(Mode::Cpu, LookupMode::Event, &w);
        let gpu = run(Mode::GpuFirst, LookupMode::Event, &w);
        assert!(close(cpu.checksum, gpu.checksum, 1e-9));
    }

    #[test]
    fn eval_is_finite_and_window_dependent() {
        let w = RsWorkload::small();
        let data = w.generate();
        let a = eval(&data, 0);
        let b = eval(&data, 1);
        assert!(a.is_finite() && b.is_finite());
        assert_ne!(a, b);
    }

    #[test]
    fn fig8b_large_input_event_catches_up() {
        // RSBench is compute-bound: event and history converge at the
        // large size (event "has caught up" rather than surpassing).
        let rel = |w: &RsWorkload, lm: LookupMode| {
            let n = match lm {
                LookupMode::Event => w.lookups as u64,
                LookupMode::History => (w.particles * w.history_steps) as u64,
            };
            let gpu = run(Mode::GpuFirst, lm, w);
            let cpu = run(Mode::Cpu, lm, w);
            (cpu.modeled_ns / n as f64) / (gpu.modeled_ns / n as f64)
        };
        let small = RsWorkload::small();
        let large = RsWorkload::large();
        let (ev_s, hi_s) = (rel(&small, LookupMode::Event), rel(&small, LookupMode::History));
        let (ev_l, hi_l) = (rel(&large, LookupMode::Event), rel(&large, LookupMode::History));
        assert!(hi_s > ev_s, "small: history {hi_s:.3} vs event {ev_s:.3}");
        let gap_small = hi_s / ev_s;
        let gap_large = hi_l / ev_l;
        assert!(gap_large < gap_small, "event should close the gap at large size");
    }
}
