//! HeCBench "interleaved" (Fig. 9a): array-of-structs vs struct-of-arrays
//! memory layouts. On the GPU, SoA accesses coalesce and AoS do not — the
//! benchmark whose entire point is the coalescing class our simulator
//! tracks. The paper notes GPU First needed the number of teams
//! *explicitly matched* to reproduce the manual-offload result exactly —
//! hence the `Mode::GpuFirstMatching` series.

use super::common::{self, checksum, grid_for, AppResult, Mode};
use super::xsbench::parallel_map_cpu;
use crate::gpu::stats::{LaunchStats, Pattern};
use crate::perfmodel::a100;
use crate::util::rng::SplitMix64;

/// Paper-scale arrays are ~16M elements; counts scale accordingly.
pub const MODEL_SCALE: f64 = 16.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    /// struct-of-arrays: coalesced on GPU.
    Soa,
    /// array-of-structs: strided on GPU.
    Aos,
}

#[derive(Debug, Clone)]
pub struct InterleavedWorkload {
    pub n: usize,
    /// Teams the manual offload version uses (the "matching" count).
    pub offload_teams: usize,
}

impl Default for InterleavedWorkload {
    fn default() -> Self {
        Self { n: 1 << 20, offload_teams: 64 }
    }
}

impl InterleavedWorkload {
    pub fn generate(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let gen = |seed: u64| -> Vec<f32> {
            (0..self.n)
                .map(|i| (SplitMix64::at(seed, i as u64) % 2000) as f32 / 500.0 - 2.0)
                .collect()
        };
        (gen(51), gen(52), gen(53), gen(54))
    }
}

/// The per-element compute — mirrors `ref.interleaved_ref`.
#[inline]
pub fn element(a: f32, b: f32, c: f32, d: f32) -> f32 {
    (a + b) * c - d * 0.5 + ((a * d).abs() + 1.0).sqrt()
}

pub fn run(mode: Mode, layout: Layout, w: &InterleavedWorkload) -> AppResult {
    let (a, b, c, d) = w.generate();
    // AoS packing: the physically interleaved buffer.
    let packed: Vec<f32> = (0..w.n).flat_map(|i| [a[i], b[i], c[i], d[i]]).collect();
    let t0 = std::time::Instant::now();
    let mut stats = LaunchStats::default();
    let cs;
    let workload = format!("{:?}", layout).to_lowercase();

    let pattern = match layout {
        Layout::Soa => Pattern::Coalesced,
        Layout::Aos => Pattern::Strided,
    };

    match mode {
        Mode::Cpu => {
            let sums = parallel_map_cpu(w.n, |i| match layout {
                Layout::Soa => element(a[i], b[i], c[i], d[i]) as f64,
                Layout::Aos => {
                    let p = &packed[i * 4..i * 4 + 4];
                    element(p[0], p[1], p[2], p[3]) as f64
                }
            });
            cs = checksum(sums);
            // CPU caches make both layouts unit-stride-ish (AoS is in fact
            // MORE cache friendly per element group).
            stats.bytes_coalesced = w.n as u64 * 20;
            stats.flops_f32 = w.n as u64 * 9;
        }
        Mode::Offload => {
            let out: Vec<f32> = common::with_runtime(|rt| match layout {
                Layout::Soa => rt
                    .execute_f32(
                        "interleaved_soa",
                        &[(&a, &[w.n]), (&b, &[w.n]), (&c, &[w.n]), (&d, &[w.n])],
                    )
                    .unwrap(),
                Layout::Aos => rt
                    .execute_f32("interleaved_aos", &[(&packed, &[w.n, 4])])
                    .unwrap(),
            })
            .expect("offload mode needs artifacts");
            cs = checksum(out.iter().map(|&x| x as f64));
            stats.mem_add(w.n as u64 * 20, pattern);
            stats.flops_f32 = w.n as u64 * 9;
        }
        gpu_mode => {
            let dev = common::shared_device();
            let cfg = grid_for(gpu_mode, w.offload_teams);
            let outsums: std::sync::Mutex<Vec<(usize, f64)>> = std::sync::Mutex::new(Vec::new());
            let ls = dev.launch(cfg, |ctx| {
                let nt = ctx.num_threads_global();
                let mut local = Vec::new();
                let mut i = ctx.global_tid();
                while i < w.n {
                    let v = match layout {
                        Layout::Soa => element(a[i], b[i], c[i], d[i]),
                        Layout::Aos => {
                            let p = &packed[i * 4..i * 4 + 4];
                            element(p[0], p[1], p[2], p[3])
                        }
                    };
                    local.push((i, v as f64));
                    ctx.mem(20, pattern);
                    ctx.flops32(9);
                    i += nt;
                }
                outsums.lock().unwrap().extend(local);
            });
            let mut sums = outsums.into_inner().unwrap();
            sums.sort_by_key(|&(i, _)| i);
            cs = checksum(sums.into_iter().map(|(_, s)| s));
            stats = ls;
        }
    }

    let wall_ns = t0.elapsed().as_nanos() as f64;
    let scaled = common::scale_stats(&stats, MODEL_SCALE);
    let modeled_ns = match mode {
        Mode::Cpu => common::cpu_modeled_ns(&scaled, common::CPU_THREADS),
        Mode::Offload => {
            // Fig. 9a times the parallel region / kernel only.
            let active = (w.offload_teams * common::DEFAULT_TEAM_SIZE) as u64;
            common::gpu_modeled_ns(&scaled, active, 1) + a100::LAUNCH_OVERHEAD_NS
        }
        Mode::GpuFirstMatching => {
            let active = (w.offload_teams * common::DEFAULT_TEAM_SIZE) as u64;
            common::gpu_modeled_ns(&scaled, active, 1) + a100::KERNEL_SPLIT_RPC_NS
        }
        _ => {
            let active = (common::DEFAULT_TEAMS * common::DEFAULT_TEAM_SIZE) as u64;
            common::gpu_modeled_ns(&scaled, active, 1) + a100::KERNEL_SPLIT_RPC_NS
        }
    };
    AppResult {
        app: "interleaved".into(),
        mode,
        workload,
        modeled_ns,
        wall_ns,
        checksum: cs,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::common::close;

    #[test]
    fn layouts_compute_identical_results() {
        let w = InterleavedWorkload { n: 1 << 14, ..Default::default() };
        let soa = run(Mode::GpuFirst, Layout::Soa, &w);
        let aos = run(Mode::GpuFirst, Layout::Aos, &w);
        assert!(close(soa.checksum, aos.checksum, 1e-9));
    }

    #[test]
    fn cpu_matches_gpufirst_checksum() {
        let w = InterleavedWorkload { n: 1 << 14, ..Default::default() };
        let cpu = run(Mode::Cpu, Layout::Soa, &w);
        let gpu = run(Mode::GpuFirst, Layout::Soa, &w);
        assert!(close(cpu.checksum, gpu.checksum, 1e-9));
    }

    #[test]
    fn fig9a_soa_beats_aos_on_gpu_only() {
        let w = InterleavedWorkload::default();
        let gpu_soa = run(Mode::GpuFirst, Layout::Soa, &w);
        let gpu_aos = run(Mode::GpuFirst, Layout::Aos, &w);
        assert!(
            gpu_soa.modeled_ns < gpu_aos.modeled_ns,
            "SoA {} should beat AoS {} on GPU",
            gpu_soa.modeled_ns,
            gpu_aos.modeled_ns
        );
        let cpu_soa = run(Mode::Cpu, Layout::Soa, &w);
        let cpu_aos = run(Mode::Cpu, Layout::Aos, &w);
        let cpu_gap = (cpu_soa.modeled_ns - cpu_aos.modeled_ns).abs() / cpu_aos.modeled_ns;
        assert!(cpu_gap < 0.05, "CPU should be layout-insensitive (gap {cpu_gap})");
    }

    #[test]
    fn matching_teams_tracks_offload_grid() {
        // The paper: "we needed to explicitly match the number of teams to
        // perfectly match the result".
        let w = InterleavedWorkload::default();
        let matching = run(Mode::GpuFirstMatching, Layout::Soa, &w);
        let default = run(Mode::GpuFirst, Layout::Soa, &w);
        // Matching uses fewer teams than the whole device here.
        assert!(matching.modeled_ns >= default.modeled_ns * 0.5);
        assert_ne!(matching.modeled_ns, default.modeled_ns);
    }
}
