//! Shared app machinery: execution modes, result records, helpers.

use crate::gpu::grid::{AllocatorKind, Device, LaunchConfig};
use crate::gpu::memory::MemConfig;
use crate::gpu::stats::LaunchStats;
use crate::perfmodel::{a100, epyc};
use crate::runtime::Runtime;
use std::sync::OnceLock;

/// CPU thread count of the paper's testbed (EPYC 7532, SMT off).
pub const CPU_THREADS: usize = 32;
/// Default GPU First grid: whole-device expansion (A100: 108 SMs, two
/// 128-thread teams resident per SM).
pub const DEFAULT_TEAMS: usize = 216;
pub const DEFAULT_TEAM_SIZE: usize = 128;

/// Lazily-created shared device for app runs (generic allocator; apps
/// that exercise the allocator construct their own).
pub fn shared_device() -> &'static Device {
    static DEV: OnceLock<Device> = OnceLock::new();
    DEV.get_or_init(|| Device::new(MemConfig::small(), AllocatorKind::Generic))
}

/// Run `f` against the lazily-loaded PJRT runtime (thread-local: the xla
/// crate's client is not `Send`). Returns `None` when `make artifacts`
/// has not been run — offload modes then skip.
pub fn with_runtime<R>(f: impl FnOnce(&Runtime) -> R) -> Option<R> {
    thread_local! {
        static RT: std::cell::OnceCell<Option<Runtime>> = const { std::cell::OnceCell::new() };
    }
    RT.with(|cell| {
        cell.get_or_init(|| {
            let dir = std::env::var("GPU_FIRST_ARTIFACTS")
                .map(std::path::PathBuf::from)
                .unwrap_or_else(|_| {
                    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
                });
            if !dir.join("manifest.json").exists() {
                eprintln!("note: no artifacts at {dir:?}; offload mode unavailable");
                return None;
            }
            let mut rt = Runtime::cpu().ok()?;
            rt.load_manifest_dir(&dir).ok()?;
            Some(rt)
        })
        .as_ref()
        .map(f)
    })
}

/// Grid for a GPU First expanded region.
pub fn grid_for(mode: Mode, matching_teams: usize) -> LaunchConfig {
    match mode {
        Mode::GpuFirstMatching => LaunchConfig::new(matching_teams, DEFAULT_TEAM_SIZE),
        _ => LaunchConfig::new(DEFAULT_TEAMS, DEFAULT_TEAM_SIZE),
    }
}

/// Which implementation variant to run (the series of Figs. 8-10).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Legacy CPU OpenMP implementation on the host model.
    Cpu,
    /// GPU First: transparently compiled for the device, multi-team.
    GpuFirst,
    /// GPU First pinned to the same #teams as the manual offload
    /// (the "matching teams" series of Fig. 9a).
    GpuFirstMatching,
    /// Manually offloaded kernel (AOT Pallas/JAX artifact via PJRT).
    Offload,
}

impl Mode {
    pub fn parse(s: &str) -> Result<Mode, String> {
        match s {
            "cpu" => Ok(Mode::Cpu),
            "gpu-first" | "gpufirst" => Ok(Mode::GpuFirst),
            "gpu-first-matching" | "matching" => Ok(Mode::GpuFirstMatching),
            "offload" => Ok(Mode::Offload),
            _ => Err(format!("unknown mode {s:?} (cpu|gpu-first|matching|offload)")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Mode::Cpu => "cpu",
            Mode::GpuFirst => "gpu-first",
            Mode::GpuFirstMatching => "gpu-first (matching teams)",
            Mode::Offload => "offload",
        }
    }
}

/// Result of one timed region / kernel execution.
#[derive(Debug, Clone)]
pub struct AppResult {
    pub app: String,
    pub mode: Mode,
    pub workload: String,
    /// Modeled time on the paper's testbed (A100 or EPYC per mode).
    pub modeled_ns: f64,
    /// Real wallclock of our implementation on this host.
    pub wall_ns: f64,
    /// A checksum of the computed output for cross-mode validation.
    pub checksum: f64,
    pub stats: LaunchStats,
}

impl AppResult {
    /// Speedup of this result relative to a baseline (paper figures plot
    /// GPU time relative to CPU).
    pub fn speedup_vs(&self, baseline: &AppResult) -> f64 {
        baseline.modeled_ns / self.modeled_ns
    }
}

/// Modeled CPU time for a measured stat set on the paper's 32-core EPYC.
pub fn cpu_modeled_ns(stats: &LaunchStats, threads: usize) -> f64 {
    epyc::cpu_time(stats, threads).total_ns()
}

/// Modeled device time for a launch with `active_threads` in flight.
pub fn gpu_modeled_ns(stats: &LaunchStats, active_threads: u64, launches: u64) -> f64 {
    a100::device_time(stats, active_threads, launches).total_ns()
}

/// Scale measured operation counts to the full paper-sized problem that
/// our artifact-sized run subsamples (DESIGN.md §2: real compute stays
/// CPU-feasible; the cost models see the full workload). Synchronization
/// and allocator counts are left unscaled unless the app scales them.
pub fn scale_stats(stats: &LaunchStats, f: f64) -> LaunchStats {
    let mut s = *stats;
    s.flops_f64 = (s.flops_f64 as f64 * f) as u64;
    s.flops_f32 = (s.flops_f32 as f64 * f) as u64;
    s.int_ops = (s.int_ops as f64 * f) as u64;
    s.bytes_coalesced = (s.bytes_coalesced as f64 * f) as u64;
    s.bytes_strided = (s.bytes_strided as f64 * f) as u64;
    s.bytes_random = (s.bytes_random as f64 * f) as u64;
    s
}

/// Checksum helper: order-insensitive sum with magnitude folding.
pub fn checksum(xs: impl IntoIterator<Item = f64>) -> f64 {
    let mut sum = 0.0f64;
    for (i, x) in xs.into_iter().enumerate() {
        sum += x * (1.0 + ((i % 7) as f64) * 1e-3);
    }
    sum
}

/// Relative-tolerance comparison for cross-mode checksum validation.
pub fn close(a: f64, b: f64, rel: f64) -> bool {
    let denom = a.abs().max(b.abs()).max(1e-12);
    ((a - b) / denom).abs() < rel
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_round_trip() {
        assert_eq!(Mode::parse("cpu").unwrap(), Mode::Cpu);
        assert_eq!(Mode::parse("gpu-first").unwrap(), Mode::GpuFirst);
        assert_eq!(Mode::parse("matching").unwrap(), Mode::GpuFirstMatching);
        assert_eq!(Mode::parse("offload").unwrap(), Mode::Offload);
        assert!(Mode::parse("tpu").is_err());
    }

    #[test]
    fn close_tolerance() {
        assert!(close(100.0, 100.05, 1e-3));
        assert!(!close(100.0, 101.0, 1e-3));
        assert!(close(0.0, 0.0, 1e-9));
    }

    #[test]
    fn speedup_direction() {
        let mk = |ns: f64| AppResult {
            app: "t".into(),
            mode: Mode::Cpu,
            workload: "w".into(),
            modeled_ns: ns,
            wall_ns: ns,
            checksum: 0.0,
            stats: LaunchStats::default(),
        };
        assert_eq!(mk(50.0).speedup_vs(&mk(100.0)), 2.0);
    }
}
