//! HeCBench "hypterm" (Fig. 9b): the ExpCNS compressible-Navier-Stokes
//! stencil, three parallel regions (PR1/PR2/PR3 = x/y/z flux directions).

use super::common::{self, checksum, grid_for, AppResult, Mode};
use crate::gpu::stats::{LaunchStats, Pattern};
use crate::perfmodel::a100;
use crate::util::rng::SplitMix64;

pub const H: usize = 4;
/// The paper-scale grid is 128^3; we compute 32^3 for real and scale the
/// counts by (128/32)^3.
pub const MODEL_SCALE: f64 = 64.0;
const MODEL_N: usize = 128;
pub const COEFFS: [f32; 4] = [0.8, -0.2, 0.038095238095238, -0.003571428571429];

#[derive(Debug, Clone, Copy)]
pub struct HyptermWorkload {
    /// Interior cells per dimension (artifact: 32).
    pub n: usize,
}

impl Default for HyptermWorkload {
    fn default() -> Self {
        Self { n: 32 }
    }
}

impl HyptermWorkload {
    pub fn generate(&self) -> Vec<f32> {
        let nh = self.n + 2 * H;
        (0..nh * nh * nh)
            .map(|i| (SplitMix64::at(61, i as u64) % 2000) as f32 / 1000.0 - 1.0)
            .collect()
    }

    fn nh(&self) -> usize {
        self.n + 2 * H
    }
}

/// Scalar stencil at interior cell (i,j,k) along `axis` — the kernel body
/// shared by CPU and GPU First variants; mirrors `ref.stencil1d_ref`.
#[inline]
pub fn flux_at(q: &[f32], nh: usize, axis: usize, i: usize, j: usize, k: usize) -> f32 {
    let idx = |x: usize, y: usize, z: usize| (x * nh + y) * nh + z;
    let (mut x, mut y, mut z) = (i + H, j + H, k + H);
    let mut acc = 0f32;
    for (c, coef) in COEFFS.iter().enumerate() {
        let off = c + 1;
        let (px, py, pz, mx, my, mz);
        match axis {
            0 => {
                px = x + off;
                mx = x - off;
                py = y;
                my = y;
                pz = z;
                mz = z;
            }
            1 => {
                px = x;
                mx = x;
                py = y + off;
                my = y - off;
                pz = z;
                mz = z;
            }
            _ => {
                px = x;
                mx = x;
                py = y;
                my = y;
                pz = z + off;
                mz = z - off;
            }
        }
        acc += coef * (q[idx(px, py, pz)] - q[idx(mx, my, mz)]);
        // keep borrowck happy about unused mut warnings
        let _ = (&mut x, &mut y, &mut z);
    }
    acc
}

fn count_region(stats: &mut LaunchStats, n: usize) {
    let cells = (n * n * n) as u64;
    // 8 taps + center traffic; z-direction is unit stride (coalesced),
    // x/y strided — approximate the blend as strided.
    stats.bytes_strided += cells * 9 * 4;
    stats.flops_f32 += cells * 12;
    stats.int_ops += cells * 16;
}

/// Run one parallel region (PR = axis) in the given mode.
pub fn run(mode: Mode, region: usize, w: &HyptermWorkload) -> AppResult {
    assert!(region < 3);
    let q = w.generate();
    let nh = w.nh();
    let n = w.n;
    let t0 = std::time::Instant::now();
    let mut stats = LaunchStats::default();
    let cs;
    let workload = format!("PR{}", region + 1);

    match mode {
        Mode::Cpu => {
            let sums = super::xsbench::parallel_map_cpu(n, |i| {
                let mut s = 0f64;
                for j in 0..n {
                    for k in 0..n {
                        s += flux_at(&q, nh, region, i, j, k) as f64;
                    }
                }
                s
            });
            cs = checksum(sums);
            count_region(&mut stats, n);
        }
        Mode::Offload => {
            let v: Vec<f32> = common::with_runtime(|rt| {
                let outs = rt
                    .execute(
                        "hypterm3",
                        &[xla::Literal::vec1(&q)
                            .reshape(&[nh as i64, nh as i64, nh as i64])
                            .unwrap()],
                    )
                    .unwrap();
                outs[region].to_vec().unwrap()
            })
            .expect("offload mode needs artifacts");
            // Plane sums to mirror the CPU checksum structure.
            cs = checksum(v.chunks(n * n).map(|p| p.iter().map(|&x| x as f64).sum::<f64>()));
            count_region(&mut stats, n);
        }
        gpu_mode => {
            let dev = common::shared_device();
            let cfg = grid_for(gpu_mode, 48);
            let outsums: std::sync::Mutex<Vec<(usize, f64)>> = std::sync::Mutex::new(Vec::new());
            let ls = dev.launch(cfg, |ctx| {
                let nt = ctx.num_threads_global();
                let mut local = Vec::new();
                let mut plane = ctx.global_tid();
                while plane < n {
                    let mut s = 0f64;
                    for j in 0..n {
                        for k in 0..n {
                            s += flux_at(&q, nh, region, plane, j, k) as f64;
                        }
                    }
                    local.push((plane, s));
                    let cells = (n * n) as u64;
                    ctx.mem(cells * 9 * 4, Pattern::Strided);
                    ctx.flops32(cells * 12);
                    ctx.int_ops(cells * 16);
                    plane += nt;
                }
                outsums.lock().unwrap().extend(local);
            });
            let mut sums = outsums.into_inner().unwrap();
            sums.sort_by_key(|&(i, _)| i);
            cs = checksum(sums.into_iter().map(|(_, s)| s));
            stats = ls;
        }
    }

    let wall_ns = t0.elapsed().as_nanos() as f64;
    let scaled = common::scale_stats(&stats, MODEL_SCALE);
    let cells_model = (MODEL_N * MODEL_N * MODEL_N) as u64;
    let modeled_ns = match mode {
        Mode::Cpu => common::cpu_modeled_ns(&scaled, common::CPU_THREADS),
        Mode::Offload => {
            // thread-per-cell CUDA kernel over the paper-scale grid;
            // Fig. 9b times the kernel only.
            common::gpu_modeled_ns(&scaled, cells_model, 1) + a100::LAUNCH_OVERHEAD_NS
        }
        _ => {
            // GPU First expands the plane loop: MODEL_N-way outer
            // parallelism times the inner row work fanned over the grid.
            let active = (MODEL_N * MODEL_N) as u64;
            common::gpu_modeled_ns(&scaled, active, 1) + a100::KERNEL_SPLIT_RPC_NS
        }
    };
    AppResult { app: "hypterm".into(), mode, workload, modeled_ns, wall_ns, checksum: cs, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::common::close;

    #[test]
    fn cpu_and_gpufirst_checksums_agree_all_regions() {
        let w = HyptermWorkload { n: 16 };
        for region in 0..3 {
            let cpu = run(Mode::Cpu, region, &w);
            let gpu = run(Mode::GpuFirst, region, &w);
            assert!(close(cpu.checksum, gpu.checksum, 1e-9), "PR{}", region + 1);
        }
    }

    #[test]
    fn constant_field_zero_flux() {
        let w = HyptermWorkload { n: 8 };
        let q = vec![2.5f32; w.nh() * w.nh() * w.nh()];
        for axis in 0..3 {
            assert!(flux_at(&q, w.nh(), axis, 3, 4, 5).abs() < 1e-6);
        }
    }

    #[test]
    fn fig9b_gpu_first_predicts_offload_behaviour() {
        // The paper: "the overall performance behavior matches the GPU
        // First prediction" — both GPU variants beat the CPU on every
        // region and agree within a small factor.
        let w = HyptermWorkload::default();
        for region in 0..3 {
            let cpu = run(Mode::Cpu, region, &w);
            let gf = run(Mode::GpuFirst, region, &w);
            assert!(gf.modeled_ns < cpu.modeled_ns * 4.0, "PR{} not in range", region + 1);
        }
    }
}
