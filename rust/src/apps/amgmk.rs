//! AMGmk relax kernel (Fig. 9c): Jacobi smoothing over a 27-point ELL
//! matrix — the CORAL proxy's timed hot loop.

use super::common::{self, checksum, grid_for, AppResult, Mode};
use crate::gpu::stats::{LaunchStats, Pattern};
use crate::perfmodel::a100;
use crate::util::rng::SplitMix64;

/// Paper-scale AMGmk solves ~262k-row systems; counts scale accordingly.
pub const MODEL_SCALE: f64 = 16.0;

#[derive(Debug, Clone, Copy)]
pub struct AmgmkWorkload {
    pub rows: usize,
    pub ell_width: usize,
    pub sweeps: usize,
}

impl Default for AmgmkWorkload {
    /// Matches the `amgmk_relax` artifact.
    fn default() -> Self {
        Self { rows: 16384, ell_width: 27, sweeps: 4 }
    }
}

pub struct EllMatrix {
    pub vals: Vec<f32>,
    pub cols: Vec<i32>,
    pub diag: Vec<f32>,
    pub b: Vec<f32>,
}

impl AmgmkWorkload {
    /// Diagonally dominant 27-point-ish system (Jacobi converges).
    pub fn generate(&self) -> EllMatrix {
        let (r, k) = (self.rows, self.ell_width);
        let mut vals = vec![0f32; r * k];
        let mut cols = vec![0i32; r * k];
        let mut diag = vec![0f32; r];
        for row in 0..r {
            cols[row * k] = row as i32;
            let d = k as f32 + (SplitMix64::at(71, row as u64) % 100) as f32 * 0.05;
            vals[row * k] = d;
            diag[row] = d;
            for slot in 1..k {
                let col = SplitMix64::at(73, (row * k + slot) as u64) % r as u64;
                cols[row * k + slot] = col as i32;
                vals[row * k + slot] =
                    ((SplitMix64::at(79, (row * k + slot) as u64) % 200) as f32 / 1000.0) - 0.1;
            }
        }
        let b = (0..r)
            .map(|i| (SplitMix64::at(83, i as u64) % 1000) as f32 / 500.0 - 1.0)
            .collect();
        EllMatrix { vals, cols, diag, b }
    }
}

/// One Jacobi relax row: x'[r] = x[r] + w*(b[r] - (Ax)[r]) / diag[r].
#[inline]
pub fn relax_row(m: &EllMatrix, k: usize, x: &[f32], row: usize) -> f32 {
    let mut ax = 0f32;
    for slot in 0..k {
        let c = m.cols[row * k + slot] as usize;
        ax += m.vals[row * k + slot] * x[c];
    }
    x[row] + 0.9 * (m.b[row] - ax) / m.diag[row]
}

fn count_sweep(stats: &mut LaunchStats, rows: u64, k: u64) {
    stats.bytes_coalesced += rows * k * 8; // vals+cols stream
    stats.bytes_random += rows * k * 4; // x gather
    stats.flops_f32 += rows * (2 * k + 4);
    stats.int_ops += rows * k * 2;
}

pub fn run(mode: Mode, w: &AmgmkWorkload) -> AppResult {
    let m = w.generate();
    let (r, k) = (w.rows, w.ell_width);
    let t0 = std::time::Instant::now();
    let mut stats = LaunchStats::default();
    let mut x = vec![0f32; r];
    let cs;

    match mode {
        Mode::Cpu => {
            for _ in 0..w.sweeps {
                let xr = &x;
                let next =
                    super::xsbench::parallel_map_cpu(r, |row| relax_row(&m, k, xr, row) as f64);
                x = next.into_iter().map(|v| v as f32).collect();
                count_sweep(&mut stats, r as u64, k as u64);
            }
            cs = checksum(x.iter().map(|&v| v as f64));
        }
        Mode::Offload => {
            x = common::with_runtime(|rt| {
                let mut x = x.clone();
                for _ in 0..w.sweeps {
                    let lits = vec![
                        xla::Literal::vec1(&m.vals).reshape(&[r as i64, k as i64]).unwrap(),
                        xla::Literal::vec1(&m.cols).reshape(&[r as i64, k as i64]).unwrap(),
                        xla::Literal::vec1(&m.diag).reshape(&[r as i64]).unwrap(),
                        xla::Literal::vec1(&m.b).reshape(&[r as i64]).unwrap(),
                        xla::Literal::vec1(&x).reshape(&[r as i64]).unwrap(),
                    ];
                    x = rt.execute("amgmk_relax", &lits).unwrap()[0].to_vec().unwrap();
                }
                x
            })
            .expect("offload mode needs artifacts");
            for _ in 0..w.sweeps {
                count_sweep(&mut stats, r as u64, k as u64);
            }
            cs = checksum(x.iter().map(|&v| v as f64));
        }
        gpu_mode => {
            let dev = common::shared_device();
            let cfg = grid_for(gpu_mode, 64);
            for _ in 0..w.sweeps {
                let next = std::sync::Mutex::new(vec![0f32; r]);
                let xr = &x;
                let ls = dev.launch(cfg, |ctx| {
                    let nt = ctx.num_threads_global();
                    let mut local = Vec::new();
                    let mut row = ctx.global_tid();
                    while row < r {
                        local.push((row, relax_row(&m, k, xr, row)));
                        ctx.mem(k as u64 * 8, Pattern::Coalesced);
                        ctx.mem(k as u64 * 4, Pattern::Random);
                        ctx.flops32(2 * k as u64 + 4);
                        ctx.int_ops(k as u64 * 2);
                        row += nt;
                    }
                    let mut g = next.lock().unwrap();
                    for (i, v) in local {
                        g[i] = v;
                    }
                });
                x = next.into_inner().unwrap();
                stats = stats.add(&ls);
            }
            cs = checksum(x.iter().map(|&v| v as f64));
        }
    }

    let wall_ns = t0.elapsed().as_nanos() as f64;
    let scaled = common::scale_stats(&stats, MODEL_SCALE);
    let rows_model = (r as f64 * MODEL_SCALE) as u64;
    let modeled_ns = match mode {
        Mode::Cpu => common::cpu_modeled_ns(&scaled, common::CPU_THREADS),
        Mode::Offload => {
            // Fig. 9c times the relax kernel only.
            common::gpu_modeled_ns(&scaled, rows_model, w.sweeps as u64)
        }
        _ => {
            common::gpu_modeled_ns(&scaled, rows_model, w.sweeps as u64)
                + w.sweeps as f64 * a100::KERNEL_SPLIT_RPC_NS
        }
    };
    AppResult {
        app: "amgmk".into(),
        mode,
        workload: format!("relax x{}", w.sweeps),
        modeled_ns,
        wall_ns,
        checksum: cs,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::common::close;

    #[test]
    fn substrates_agree() {
        let w = AmgmkWorkload { rows: 1024, ell_width: 9, sweeps: 2 };
        let cpu = run(Mode::Cpu, &w);
        let gpu = run(Mode::GpuFirst, &w);
        assert!(close(cpu.checksum, gpu.checksum, 1e-6));
    }

    #[test]
    fn jacobi_reduces_residual() {
        let w = AmgmkWorkload { rows: 512, ell_width: 9, sweeps: 1 };
        let m = w.generate();
        let x0 = vec![0f32; w.rows];
        let res = |x: &[f32]| -> f64 {
            (0..w.rows)
                .map(|row| {
                    let mut ax = 0f32;
                    for s in 0..w.ell_width {
                        ax += m.vals[row * w.ell_width + s]
                            * x[m.cols[row * w.ell_width + s] as usize];
                    }
                    ((m.b[row] - ax) as f64).powi(2)
                })
                .sum::<f64>()
                .sqrt()
        };
        let mut x = x0.clone();
        for _ in 0..6 {
            let next: Vec<f32> =
                (0..w.rows).map(|row| relax_row(&m, w.ell_width, &x, row)).collect();
            x = next;
        }
        assert!(res(&x) < 0.2 * res(&x0), "{} vs {}", res(&x), res(&x0));
    }

    #[test]
    fn fig9c_gpu_beats_cpu() {
        let w = AmgmkWorkload::default();
        let cpu = run(Mode::Cpu, &w);
        let gpu = run(Mode::GpuFirst, &w);
        assert!(gpu.modeled_ns < cpu.modeled_ns * 2.0);
    }
}
