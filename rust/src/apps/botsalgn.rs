//! 358.botsalgn (Fig. 10a): protein sequence alignment with OpenMP tasks
//! (the BOTS "alignment" kernel). An outer parallel region distributes
//! sequences; each thread spawns tasks performing the pairwise alignment.
//!
//! On the GPU, LLVM/OpenMP has no tasking: "tasks are executed immediately
//! by the encountering thread", so concurrency collapses to the number of
//! sequences — the paper's explanation for the big slowdowns. We model
//! exactly that: GPU-First active threads = #sequences, while the CPU
//! uses its cores for task execution.

use super::common::{self, checksum, AppResult, Mode};
use crate::gpu::grid::LaunchConfig;
use crate::gpu::stats::{LaunchStats, Pattern};
use crate::perfmodel::a100;
use crate::util::rng::Xoshiro256;

#[derive(Debug, Clone, Copy)]
pub struct BotsalgnWorkload {
    pub sequences: usize,
    pub length: usize,
}

impl BotsalgnWorkload {
    pub fn new(sequences: usize) -> Self {
        Self { sequences, length: 96 }
    }

    pub fn generate(&self) -> Vec<Vec<u8>> {
        let mut rng = Xoshiro256::new(0xA11C);
        (0..self.sequences)
            .map(|_| (0..self.length).map(|_| (rng.next_below(20)) as u8).collect())
            .collect()
    }

    pub fn pairs(&self) -> usize {
        self.sequences * (self.sequences - 1) / 2
    }
}

/// Needleman-Wunsch-style global alignment score (two-row DP) — the task
/// body of the benchmark.
pub fn align(a: &[u8], b: &[u8]) -> i32 {
    const GAP: i32 = -2;
    let n = b.len();
    let mut prev: Vec<i32> = (0..=n as i32).map(|j| j * GAP).collect();
    let mut cur = vec![0i32; n + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = (i as i32 + 1) * GAP;
        for j in 0..n {
            let m = if ca == b[j] { 3 } else { -1 };
            cur[j + 1] = (prev[j] + m).max(prev[j + 1] + GAP).max(cur[j] + GAP);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[n]
}

fn count_pair(stats: &mut LaunchStats, len: u64) {
    let cells = len * len;
    stats.int_ops += cells * 8;
    stats.bytes_coalesced += cells * 6;
}

pub fn run(mode: Mode, w: &BotsalgnWorkload) -> AppResult {
    let seqs = w.generate();
    let pairs: Vec<(usize, usize)> = (0..w.sequences)
        .flat_map(|i| ((i + 1)..w.sequences).map(move |j| (i, j)))
        .collect();
    let t0 = std::time::Instant::now();
    let mut stats = LaunchStats::default();
    let cs;

    match mode {
        Mode::Cpu => {
            // Tasks steal across all cores: idle threads of the outer
            // region execute spawned tasks concurrently.
            let scores = super::xsbench::parallel_map_cpu(pairs.len(), |p| {
                let (i, j) = pairs[p];
                align(&seqs[i], &seqs[j]) as f64
            });
            cs = checksum(scores);
            for _ in &pairs {
                count_pair(&mut stats, w.length as u64);
            }
        }
        Mode::Offload => {
            panic!("no manual offload exists for the tasking benchmarks (paper §5.3.5)")
        }
        _ => {
            // GPU First: outer region distributes sequences; each
            // sequence's tasks run IMMEDIATELY on the encountering thread
            // (no GPU tasking) => parallelism == #sequences.
            let dev = common::shared_device();
            let cfg = LaunchConfig::new(
                w.sequences.div_ceil(common::DEFAULT_TEAM_SIZE).max(1),
                common::DEFAULT_TEAM_SIZE.min(w.sequences),
            );
            let out: std::sync::Mutex<Vec<(usize, f64)>> = std::sync::Mutex::new(Vec::new());
            let ls = dev.launch(cfg, |ctx| {
                let i = ctx.global_tid();
                if i >= w.sequences {
                    return;
                }
                // The thread owning sequence i immediately executes all of
                // the tasks it would have spawned (pairs (i, j>i)).
                let mut local = Vec::new();
                for j in (i + 1)..w.sequences {
                    local.push((i * w.sequences + j, align(&seqs[i], &seqs[j]) as f64));
                    let cells = (w.length * w.length) as u64;
                    ctx.int_ops(cells * 8);
                    ctx.mem(cells * 6, Pattern::Strided);
                    ctx.divergent(w.length as u64);
                }
                out.lock().unwrap().extend(local);
            });
            let mut scores = out.into_inner().unwrap();
            scores.sort_by_key(|&(k, _)| k);
            cs = checksum(scores.into_iter().map(|(_, s)| s));
            stats = ls;
        }
    }

    let wall_ns = t0.elapsed().as_nanos() as f64;
    let modeled_ns = match mode {
        Mode::Cpu => common::cpu_modeled_ns(&stats, common::CPU_THREADS),
        _ => {
            // Only #sequences GPU threads ever run concurrently.
            common::gpu_modeled_ns(&stats, w.sequences as u64, 1) + a100::KERNEL_SPLIT_RPC_NS
        }
    };
    AppResult {
        app: "botsalgn".into(),
        mode,
        workload: format!("{} sequences", w.sequences),
        modeled_ns,
        wall_ns,
        checksum: cs,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::common::close;

    #[test]
    fn align_identical_and_disjoint() {
        let a = vec![1u8, 2, 3, 4, 5, 6, 7, 8];
        assert_eq!(align(&a, &a), 3 * 8);
        let b = vec![10u8; 8];
        assert!(align(&a, &b) < 0);
    }

    #[test]
    fn substrates_agree() {
        let w = BotsalgnWorkload { sequences: 8, length: 32 };
        let cpu = run(Mode::Cpu, &w);
        let gpu = run(Mode::GpuFirst, &w);
        assert!(close(cpu.checksum, gpu.checksum, 1e-9));
    }

    #[test]
    fn fig10a_gpu_slowdown_from_task_starvation() {
        // Few sequences => the GPU runs a handful of threads and loses
        // badly; the CPU keeps its cores busy via task stealing.
        let w = BotsalgnWorkload::new(8);
        let cpu = run(Mode::Cpu, &w);
        let gpu = run(Mode::GpuFirst, &w);
        assert!(
            gpu.modeled_ns > 3.0 * cpu.modeled_ns,
            "gpu {} should be much slower than cpu {}",
            gpu.modeled_ns,
            cpu.modeled_ns
        );
        // More sequences narrow the gap.
        let w2 = BotsalgnWorkload::new(48);
        let cpu2 = run(Mode::Cpu, &w2);
        let gpu2 = run(Mode::GpuFirst, &w2);
        let gap1 = gpu.modeled_ns / cpu.modeled_ns;
        let gap2 = gpu2.modeled_ns / cpu2.modeled_ns;
        assert!(gap2 < gap1, "gap should shrink with more sequences ({gap1} -> {gap2})");
    }
}
