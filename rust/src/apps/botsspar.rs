//! 359.botsspar (Fig. 10b): BOTS "sparselu" — LU decomposition of a
//! sparse blocked matrix with OpenMP tasks.
//!
//! In the original, one thread creates tasks while the region's other
//! threads execute them; with no GPU tasking this degenerates to SERIAL
//! execution, so (like the paper) we evaluate the *rewritten* variant:
//! the task regions become `parallel for` over the per-step block lists.
//! The slowdown the paper observes comes from insufficient parallelism —
//! each elimination step exposes only O(remaining-blocks) work.

use super::common::{self, checksum, AppResult, Mode};
use crate::gpu::stats::LaunchStats;
use crate::perfmodel::a100;
use crate::util::rng::Xoshiro256;

#[derive(Debug, Clone, Copy)]
pub struct BotssparWorkload {
    /// Matrix is nb × nb blocks.
    pub nb: usize,
    /// Each block is bs × bs.
    pub bs: usize,
}

impl BotssparWorkload {
    pub fn new(nb: usize, bs: usize) -> Self {
        Self { nb, bs }
    }

    /// BOTS-style sparse block structure: diagonal plus ~40% fill.
    pub fn generate(&self) -> Vec<Option<Vec<f32>>> {
        let mut rng = Xoshiro256::new(0x5BA5);
        let (nb, bs) = (self.nb, self.bs);
        let mut blocks: Vec<Option<Vec<f32>>> = vec![None; nb * nb];
        for i in 0..nb {
            for j in 0..nb {
                if i == j || rng.next_f64() < 0.4 {
                    let mut b: Vec<f32> =
                        (0..bs * bs).map(|_| rng.next_f32() * 0.1 - 0.05).collect();
                    if i == j {
                        for d in 0..bs {
                            b[d * bs + d] += bs as f32; // diagonally dominant
                        }
                    }
                    blocks[i * nb + j] = Some(b);
                }
            }
        }
        blocks
    }
}

fn lu0(a: &mut [f32], bs: usize) {
    for k in 0..bs {
        let piv = a[k * bs + k];
        for i in (k + 1)..bs {
            a[i * bs + k] /= piv;
            for j in (k + 1)..bs {
                a[i * bs + j] -= a[i * bs + k] * a[k * bs + j];
            }
        }
    }
}

fn bdiv(diag: &[f32], row: &mut [f32], bs: usize) {
    for i in 0..bs {
        for k in 0..bs {
            row[i * bs + k] /= diag[k * bs + k];
            for j in (k + 1)..bs {
                row[i * bs + j] -= row[i * bs + k] * diag[k * bs + j];
            }
        }
    }
}

fn fwd(diag: &[f32], col: &mut [f32], bs: usize) {
    for j in 0..bs {
        for k in 0..bs {
            for i in (k + 1)..bs {
                col[i * bs + j] -= diag[i * bs + k] * col[k * bs + j];
            }
        }
    }
}

fn bmod(row: &[f32], col: &[f32], inner: &mut [f32], bs: usize) {
    for i in 0..bs {
        for k in 0..bs {
            let r = row[i * bs + k];
            if r != 0.0 {
                for j in 0..bs {
                    inner[i * bs + j] -= r * col[k * bs + j];
                }
            }
        }
    }
}

fn count_block_op(stats: &mut LaunchStats, bs: u64) {
    stats.flops_f32 += bs * bs * bs * 2;
    stats.bytes_strided += bs * bs * 12;
    stats.int_ops += bs * bs * 4;
}

/// Factorize; `par` applies each wave's independent block ops through the
/// given executor (CPU pool or simulated grid), returning per-wave stats.
pub fn run(mode: Mode, w: &BotssparWorkload) -> AppResult {
    let mut blocks = w.generate();
    let (nb, bs) = (w.nb, w.bs);
    let t0 = std::time::Instant::now();
    let mut stats = LaunchStats::default();
    let mut waves = 0u64;
    let mut max_wave_par = 0usize;
    let mut total_ops = 0u64;

    for kk in 0..nb {
        // lu0 on the diagonal block (serial on every substrate).
        let mut diag = blocks[kk * nb + kk].take().expect("diagonal block");
        lu0(&mut diag, bs);
        count_block_op(&mut stats, bs as u64);
        total_ops += 1;

        // Wave 1: bdiv row panels + fwd column panels (independent).
        let mut wave1: Vec<(usize, bool)> = Vec::new();
        for jj in (kk + 1)..nb {
            if blocks[kk * nb + jj].is_some() {
                wave1.push((jj, true)); // fwd on U row
            }
            if blocks[jj * nb + kk].is_some() {
                wave1.push((jj, false)); // bdiv on L column
            }
        }
        max_wave_par = max_wave_par.max(wave1.len());
        for &(jj, is_row) in &wave1 {
            if is_row {
                let mut b = blocks[kk * nb + jj].take().unwrap();
                fwd(&diag, &mut b, bs);
                blocks[kk * nb + jj] = Some(b);
            } else {
                let mut b = blocks[jj * nb + kk].take().unwrap();
                bdiv(&diag, &mut b, bs);
                blocks[jj * nb + kk] = Some(b);
            }
            count_block_op(&mut stats, bs as u64);
            total_ops += 1;
        }
        waves += 1;

        // Wave 2: bmod on the trailing submatrix (independent).
        let mut wave2: Vec<(usize, usize)> = Vec::new();
        for ii in (kk + 1)..nb {
            for jj in (kk + 1)..nb {
                if blocks[ii * nb + kk].is_some() && blocks[kk * nb + jj].is_some() {
                    wave2.push((ii, jj));
                }
            }
        }
        max_wave_par = max_wave_par.max(wave2.len());
        for &(ii, jj) in &wave2 {
            let row = blocks[ii * nb + kk].clone().unwrap();
            let col = blocks[kk * nb + jj].clone().unwrap();
            let mut inner = blocks[ii * nb + jj]
                .take()
                .unwrap_or_else(|| vec![0f32; bs * bs]);
            bmod(&row, &col, &mut inner, bs);
            blocks[ii * nb + jj] = Some(inner);
            count_block_op(&mut stats, bs as u64);
            total_ops += 1;
        }
        waves += 1;
        blocks[kk * nb + kk] = Some(diag);
    }

    let cs = checksum(
        blocks
            .iter()
            .flatten()
            .map(|b| b.iter().map(|&x| x as f64).sum::<f64>()),
    );
    let wall_ns = t0.elapsed().as_nanos() as f64;

    // Parallelism exposed per wave decides the modeled time.
    let avg_par = (total_ops as f64 / waves as f64).max(1.0);
    let modeled_ns = match mode {
        Mode::Cpu => {
            let threads = common::CPU_THREADS.min(avg_par.ceil() as usize);
            common::cpu_modeled_ns(&stats, threads.max(1))
        }
        Mode::Offload => panic!("no manual offload exists for the tasking benchmarks"),
        _ => {
            // parallel-for rewrite: each wave is a kernel over its blocks.
            common::gpu_modeled_ns(&stats, avg_par.ceil() as u64, waves)
                + waves as f64 * a100::KERNEL_SPLIT_RPC_NS
        }
    };
    AppResult {
        app: "botsspar".into(),
        mode,
        workload: format!("{}x{} blocks of {}x{}", nb, nb, bs, bs),
        modeled_ns,
        wall_ns,
        checksum: cs,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::common::close;

    #[test]
    fn lu_reconstructs_dense_matrix() {
        // For a single dense block, lu0 must satisfy A = L*U.
        let bs = 8;
        let w = BotssparWorkload::new(1, bs);
        let a0 = w.generate()[0].clone().unwrap();
        let mut lu = a0.clone();
        lu0(&mut lu, bs);
        // Reconstruct.
        for i in 0..bs {
            for j in 0..bs {
                let mut sum = 0f64;
                for k in 0..=i.min(j) {
                    let l = if k == i { 1.0 } else { lu[i * bs + k] as f64 };
                    let u = lu[k * bs + j] as f64;
                    if k <= j && k <= i {
                        sum += if k == i { u } else { l * u };
                    }
                }
                assert!(
                    (sum - a0[i * bs + j] as f64).abs() < 1e-3,
                    "A[{i}][{j}] {} vs {}",
                    sum,
                    a0[i * bs + j]
                );
            }
        }
    }

    #[test]
    fn modes_agree_on_checksum() {
        let w = BotssparWorkload::new(4, 8);
        let cpu = run(Mode::Cpu, &w);
        let gpu = run(Mode::GpuFirst, &w);
        assert!(close(cpu.checksum, gpu.checksum, 1e-9));
    }

    #[test]
    fn fig10b_insufficient_parallelism_slows_gpu() {
        let w = BotssparWorkload::new(6, 16);
        let cpu = run(Mode::Cpu, &w);
        let gpu = run(Mode::GpuFirst, &w);
        assert!(
            gpu.modeled_ns > cpu.modeled_ns,
            "gpu {} should trail cpu {} at this size",
            gpu.modeled_ns,
            cpu.modeled_ns
        );
    }
}
