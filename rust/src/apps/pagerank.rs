//! Page-rank propagation step (Fig. 9c): the HeCBench graph micro
//! benchmark; the timed region is one damped propagation over an ELL
//! adjacency structure.

use super::common::{self, checksum, grid_for, AppResult, Mode};
use crate::gpu::stats::{LaunchStats, Pattern};
use crate::perfmodel::a100;
use crate::util::rng::SplitMix64;

pub const DAMPING: f32 = 0.85;
/// Paper-scale graphs are ~1M nodes; counts scale accordingly.
pub const MODEL_SCALE: f64 = 128.0;

#[derive(Debug, Clone, Copy)]
pub struct PagerankWorkload {
    pub nodes: usize,
    pub ell_width: usize,
    pub iterations: usize,
}

impl Default for PagerankWorkload {
    /// Matches the `pagerank_step` artifact.
    fn default() -> Self {
        Self { nodes: 8192, ell_width: 16, iterations: 4 }
    }
}

impl PagerankWorkload {
    /// Random graph in ELL transpose form: vals[r,k] = 1/outdeg(src).
    pub fn generate(&self) -> (Vec<f32>, Vec<i32>) {
        let (n, k) = (self.nodes, self.ell_width);
        let mut cols = vec![0i32; n * k];
        let mut outdeg = vec![0u32; n];
        for i in 0..n * k {
            let src = (SplitMix64::at(91, i as u64) % n as u64) as usize;
            cols[i] = src as i32;
            outdeg[src] += 1;
        }
        let vals: Vec<f32> = cols
            .iter()
            .map(|&c| 1.0 / outdeg[c as usize].max(1) as f32)
            .collect();
        (vals, cols)
    }
}

#[inline]
pub fn propagate_row(vals: &[f32], cols: &[i32], k: usize, rank: &[f32], row: usize) -> f32 {
    let n = rank.len() as f32;
    let mut acc = 0f32;
    for slot in 0..k {
        acc += vals[row * k + slot] * rank[cols[row * k + slot] as usize];
    }
    DAMPING * acc + (1.0 - DAMPING) / n
}

fn count_iter(stats: &mut LaunchStats, n: u64, k: u64) {
    stats.bytes_coalesced += n * k * 8;
    stats.bytes_random += n * k * 4;
    stats.flops_f32 += n * (2 * k + 3);
    stats.int_ops += n * k * 2;
}

pub fn run(mode: Mode, w: &PagerankWorkload) -> AppResult {
    let (vals, cols) = w.generate();
    let (n, k) = (w.nodes, w.ell_width);
    let t0 = std::time::Instant::now();
    let mut stats = LaunchStats::default();
    let mut rank = vec![1.0 / n as f32; n];
    let cs;

    match mode {
        Mode::Cpu => {
            for _ in 0..w.iterations {
                let r = &rank;
                let next = super::xsbench::parallel_map_cpu(n, |row| {
                    propagate_row(&vals, &cols, k, r, row) as f64
                });
                rank = next.into_iter().map(|v| v as f32).collect();
                count_iter(&mut stats, n as u64, k as u64);
            }
            cs = checksum(rank.iter().map(|&v| v as f64));
        }
        Mode::Offload => {
            rank = common::with_runtime(|rt| {
                let mut rank = rank.clone();
                for _ in 0..w.iterations {
                    let lits = vec![
                        xla::Literal::vec1(&vals).reshape(&[n as i64, k as i64]).unwrap(),
                        xla::Literal::vec1(&cols).reshape(&[n as i64, k as i64]).unwrap(),
                        xla::Literal::vec1(&rank).reshape(&[n as i64]).unwrap(),
                    ];
                    rank = rt.execute("pagerank_step", &lits).unwrap()[0].to_vec().unwrap();
                }
                rank
            })
            .expect("offload mode needs artifacts");
            for _ in 0..w.iterations {
                count_iter(&mut stats, n as u64, k as u64);
            }
            cs = checksum(rank.iter().map(|&v| v as f64));
        }
        gpu_mode => {
            let dev = common::shared_device();
            let cfg = grid_for(gpu_mode, 64);
            for _ in 0..w.iterations {
                let next = std::sync::Mutex::new(vec![0f32; n]);
                let r = &rank;
                let ls = dev.launch(cfg, |ctx| {
                    let nt = ctx.num_threads_global();
                    let mut local = Vec::new();
                    let mut row = ctx.global_tid();
                    while row < n {
                        local.push((row, propagate_row(&vals, &cols, k, r, row)));
                        ctx.mem(k as u64 * 8, Pattern::Coalesced);
                        ctx.mem(k as u64 * 4, Pattern::Random);
                        ctx.flops32(2 * k as u64 + 3);
                        ctx.int_ops(k as u64 * 2);
                        row += nt;
                    }
                    let mut g = next.lock().unwrap();
                    for (i, v) in local {
                        g[i] = v;
                    }
                });
                rank = next.into_inner().unwrap();
                stats = stats.add(&ls);
            }
            cs = checksum(rank.iter().map(|&v| v as f64));
        }
    }

    let wall_ns = t0.elapsed().as_nanos() as f64;
    let scaled = common::scale_stats(&stats, MODEL_SCALE);
    let nodes_model = (n as f64 * MODEL_SCALE) as u64;
    let modeled_ns = match mode {
        Mode::Cpu => common::cpu_modeled_ns(&scaled, common::CPU_THREADS),
        Mode::Offload => {
            // Fig. 9c times the propagation kernel only.
            common::gpu_modeled_ns(&scaled, nodes_model, w.iterations as u64)
        }
        _ => {
            common::gpu_modeled_ns(&scaled, nodes_model, w.iterations as u64)
                + w.iterations as f64 * a100::KERNEL_SPLIT_RPC_NS
        }
    };
    AppResult {
        app: "pagerank".into(),
        mode,
        workload: format!("propagate x{}", w.iterations),
        modeled_ns,
        wall_ns,
        checksum: cs,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::common::close;

    #[test]
    fn substrates_agree() {
        let w = PagerankWorkload { nodes: 1024, ell_width: 8, iterations: 2 };
        let cpu = run(Mode::Cpu, &w);
        let gpu = run(Mode::GpuFirst, &w);
        assert!(close(cpu.checksum, gpu.checksum, 1e-6));
    }

    #[test]
    fn rank_mass_roughly_conserved() {
        let w = PagerankWorkload { nodes: 512, ell_width: 8, iterations: 1 };
        let (vals, cols) = w.generate();
        let rank = vec![1.0 / 512f32; 512];
        let next: Vec<f32> =
            (0..512).map(|r| propagate_row(&vals, &cols, w.ell_width, &rank, r)).collect();
        let mass: f32 = next.iter().sum();
        assert!((mass - 1.0).abs() < 0.2, "mass {mass}");
        assert!(next.iter().all(|&v| v > 0.0));
    }
}
