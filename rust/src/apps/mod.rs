//! The evaluation applications (paper §5.3), each in three variants:
//!
//! * `cpu`     — the legacy CPU-parallel implementation (the baseline the
//!               paper compares against), run natively multi-threaded.
//! * `gpufirst`— the GPU First port: the same code executed on the
//!               simulated device with expanded multi-team parallel
//!               regions, device allocator + libc, and modeled A100 time.
//! * `offload` — the manually written offload version: the AOT-compiled
//!               Pallas/JAX kernel executed through [`crate::runtime`],
//!               plus modeled host↔device transfers.
//!
//! Shared machinery (workload generators, mode plumbing, result records)
//! lives in [`common`].

pub mod common;
pub mod xsbench;
pub mod rsbench;
pub mod interleaved;
pub mod hypterm;
pub mod amgmk;
pub mod pagerank;
pub mod botsalgn;
pub mod botsspar;
pub mod smithwa;

pub use common::{AppResult, Mode};
