//! `gpu_first` — a reproduction of *"GPU First — Execution of Legacy CPU
//! Codes on GPUs"* (Tian, Scogland, Chapman, Doerfert; 2023).
//!
//! The crate implements the paper's full system against a simulated GPU
//! substrate (see `DESIGN.md` for the substitution table):
//!
//! * [`ir`] / [`analysis`] / [`transform`] — the compiler: a small typed IR,
//!   an Attributor-style underlying-object analysis, the automatic **RPC
//!   generation** pass (paper §3.2) and the **multi-team expansion / kernel
//!   split** pass (paper §3.3).
//! * [`gpu`] — the SIMT device simulator (teams × threads, address-spaced
//!   memory, cross-team barriers, coalescing classification).
//! * [`rpc`] — the synchronous, stateless host-RPC protocol over managed
//!   memory (client stubs, host server, landing-pad registry, single-level
//!   memory migration), plus [`rpc::engine`]: the **multi-lane mailbox
//!   arena** (one cache-line-padded lane per team), the **worker-pool
//!   host server** (disjoint lane sets with race-free work stealing) and
//!   the **batching layer** that dispatches homogeneous calls of a poll
//!   sweep as one landing-pad invocation. The paper's single-threaded
//!   single-slot server (§4.4) remains the `lanes=1, workers=1`
//!   degenerate case.
//! * [`alloc`] — the device heap allocators (paper §3.4): *generic*
//!   free-list, *balanced* N×M chunk allocator, and a vendor-malloc model,
//!   plus allocation tracking for dynamic object lookup.
//! * [`libc_gpu`] — the partial libc that runs "natively" on the device.
//! * [`runtime`] — PJRT loading/execution of the AOT JAX/Pallas artifacts
//!   (HLO text interchange).
//! * [`coordinator`] — the loader + host process tying it all together.
//! * [`perfmodel`] — A100/EPYC roofline cost models converting executed
//!   operation counts into modeled device time.
//! * [`apps`] — the evaluation applications (XSBench, RSBench, HeCBench
//!   micro benchmarks, SPEC-OMP-style kernels) in CPU / GPU-First / manual
//!   offload variants.
//! * [`obs`] — observability: span tracing (`--trace-out` Chrome
//!   trace-event export), log-bucketed latency histograms, and the
//!   structured warn-once event log.
//! * [`util`] — offline substrate: RNG, CLI, JSON, stats, tables, property
//!   testing, bench harness.

pub mod util;
pub mod obs;
pub mod alloc;
pub mod gpu;
pub mod rpc;
pub mod libc_gpu;
pub mod ir;
pub mod analysis;
pub mod transform;
pub mod runtime;
pub mod perfmodel;
pub mod coordinator;
pub mod apps;
