//! Simulated device memory.
//!
//! A single flat, byte-addressable store backed by `AtomicU64` words, so
//! concurrently executing simulated GPU threads (which run on real OS
//! threads) can exhibit hardware-like racy behaviour without Rust-level
//! undefined behaviour. Relaxed atomics compile to plain loads/stores on
//! x86, so the substrate stays fast.
//!
//! The address space is segmented like a discrete-GPU system:
//!
//! ```text
//!   0x0000_0000 .. 0x0000_1000   null guard page (never mapped)
//!   GLOBAL_BASE ..               device heap (managed by `alloc::`)
//!   MANAGED_BASE ..              managed/unified memory, host-visible:
//!                                the RPC mailbox arena (one cache-line
//!                                padded lane per team, see
//!                                `rpc::engine::arena`) sits at the base,
//!                                migrated objects and `managed_alloc`
//!                                carve the rest
//!   STACK_BASE ..                per-thread stack frames (IR interpreter)
//! ```
//!
//! The *host* (RPC server / engine worker threads) accesses managed memory
//! through the same [`DeviceMemory`]; the paper's CPU→GPU visibility
//! latency (Fig. 7's 89% "notification gap") is charged by the cost model,
//! not by delaying writes.

use std::sync::atomic::{AtomicU64, Ordering};

pub const GLOBAL_BASE: u64 = 0x1000_0000;
pub const MANAGED_BASE: u64 = 0x8000_0000;
pub const STACK_BASE: u64 = 0xC000_0000;

/// Which segment an address falls into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Segment {
    NullPage,
    Global,
    Managed,
    Stack,
    /// Host pointer range (addresses above all device segments): values that
    /// were host pointers all along and must not be translated by RPC.
    Host,
}

#[derive(Debug, Clone, Copy)]
pub struct MemConfig {
    pub global_size: u64,
    pub managed_size: u64,
    pub stack_size: u64,
}

impl Default for MemConfig {
    fn default() -> Self {
        Self {
            global_size: 256 << 20,
            managed_size: 32 << 20,
            stack_size: 32 << 20,
        }
    }
}

impl MemConfig {
    pub fn small() -> Self {
        Self {
            global_size: 16 << 20,
            managed_size: 4 << 20,
            stack_size: 4 << 20,
        }
    }
}

pub struct DeviceMemory {
    cfg: MemConfig,
    global: Box<[AtomicU64]>,
    managed: Box<[AtomicU64]>,
    stack: Box<[AtomicU64]>,
    /// The device's observability bundle (span recorder + latency
    /// histograms + event log). Every layer that holds the memory — RPC
    /// client, engine workers, launch executor, interpreter — records
    /// through this shared handle.
    pub obs: std::sync::Arc<crate::obs::Obs>,
}

fn alloc_words(bytes: u64) -> Box<[AtomicU64]> {
    let words = (bytes as usize + 7) / 8;
    let mut v = Vec::with_capacity(words);
    v.resize_with(words, || AtomicU64::new(0));
    v.into_boxed_slice()
}

impl DeviceMemory {
    pub fn new(cfg: MemConfig) -> Self {
        Self {
            global: alloc_words(cfg.global_size),
            managed: alloc_words(cfg.managed_size),
            stack: alloc_words(cfg.stack_size),
            cfg,
            obs: std::sync::Arc::new(crate::obs::Obs::new()),
        }
    }

    pub fn config(&self) -> &MemConfig {
        &self.cfg
    }

    pub fn segment(&self, addr: u64) -> Segment {
        if addr < 0x1000 {
            Segment::NullPage
        } else if (GLOBAL_BASE..GLOBAL_BASE + self.cfg.global_size).contains(&addr) {
            Segment::Global
        } else if (MANAGED_BASE..MANAGED_BASE + self.cfg.managed_size).contains(&addr) {
            Segment::Managed
        } else if (STACK_BASE..STACK_BASE + self.cfg.stack_size).contains(&addr) {
            Segment::Stack
        } else {
            Segment::Host
        }
    }

    /// Map an address to (segment slice, byte offset). Panics on unmapped
    /// addresses — the simulator's equivalent of a device-side fault.
    fn locate(&self, addr: u64, len: u64) -> (&[AtomicU64], u64) {
        match self.segment(addr) {
            Segment::Global => {
                assert!(
                    addr + len <= GLOBAL_BASE + self.cfg.global_size,
                    "global OOB {addr:#x}+{len}"
                );
                (&self.global, addr - GLOBAL_BASE)
            }
            Segment::Managed => {
                assert!(
                    addr + len <= MANAGED_BASE + self.cfg.managed_size,
                    "managed OOB {addr:#x}+{len}"
                );
                (&self.managed, addr - MANAGED_BASE)
            }
            Segment::Stack => {
                assert!(
                    addr + len <= STACK_BASE + self.cfg.stack_size,
                    "stack OOB {addr:#x}+{len}"
                );
                (&self.stack, addr - STACK_BASE)
            }
            seg => panic!("device fault: access to {seg:?} address {addr:#x} (len {len})"),
        }
    }

    // ---- word-aligned fast paths ----

    pub fn read_u64(&self, addr: u64) -> u64 {
        if addr % 8 == 0 {
            let (seg, off) = self.locate(addr, 8);
            seg[(off / 8) as usize].load(Ordering::Relaxed)
        } else {
            let mut b = [0u8; 8];
            self.read_bytes(addr, &mut b);
            u64::from_le_bytes(b)
        }
    }

    pub fn write_u64(&self, addr: u64, v: u64) {
        if addr % 8 == 0 {
            let (seg, off) = self.locate(addr, 8);
            seg[(off / 8) as usize].store(v, Ordering::Relaxed);
        } else {
            self.write_bytes(addr, &v.to_le_bytes());
        }
    }

    pub fn read_u32(&self, addr: u64) -> u32 {
        let mut b = [0u8; 4];
        self.read_bytes(addr, &mut b);
        u32::from_le_bytes(b)
    }

    pub fn write_u32(&self, addr: u64, v: u32) {
        self.write_bytes(addr, &v.to_le_bytes());
    }

    pub fn read_u8(&self, addr: u64) -> u8 {
        let (seg, off) = self.locate(addr, 1);
        let w = seg[(off / 8) as usize].load(Ordering::Relaxed);
        (w >> ((off % 8) * 8)) as u8
    }

    pub fn write_u8(&self, addr: u64, v: u8) {
        self.write_bytes(addr, &[v]);
    }

    pub fn read_f64(&self, addr: u64) -> f64 {
        f64::from_bits(self.read_u64(addr))
    }

    pub fn write_f64(&self, addr: u64, v: f64) {
        self.write_u64(addr, v.to_bits());
    }

    pub fn read_f32(&self, addr: u64) -> f32 {
        f32::from_bits(self.read_u32(addr))
    }

    pub fn write_f32(&self, addr: u64, v: f32) {
        self.write_u32(addr, v.to_bits());
    }

    pub fn read_i64(&self, addr: u64) -> i64 {
        self.read_u64(addr) as i64
    }

    pub fn write_i64(&self, addr: u64, v: i64) {
        self.write_u64(addr, v as u64);
    }

    // ---- bulk ----

    pub fn read_bytes(&self, addr: u64, out: &mut [u8]) {
        let (seg, off) = self.locate(addr, out.len() as u64);
        for (i, byte) in out.iter_mut().enumerate() {
            let o = off + i as u64;
            let w = seg[(o / 8) as usize].load(Ordering::Relaxed);
            *byte = (w >> ((o % 8) * 8)) as u8;
        }
    }

    pub fn write_bytes(&self, addr: u64, data: &[u8]) {
        if data.is_empty() {
            return;
        }
        let (seg, off) = self.locate(addr, data.len() as u64);
        let mut i = 0usize;
        while i < data.len() {
            let o = off + i as u64;
            let word_idx = (o / 8) as usize;
            let shift = (o % 8) * 8;
            let in_word = (8 - (o % 8) as usize).min(data.len() - i);
            if in_word == 8 {
                let v = u64::from_le_bytes(data[i..i + 8].try_into().unwrap());
                seg[word_idx].store(v, Ordering::Relaxed);
            } else {
                // Sub-word write: CAS loop so concurrent neighbours survive.
                let mut mask = 0u64;
                let mut val = 0u64;
                for k in 0..in_word {
                    mask |= 0xffu64 << (shift + (k as u64) * 8);
                    val |= (data[i + k] as u64) << (shift + (k as u64) * 8);
                }
                let cell = &seg[word_idx];
                let mut cur = cell.load(Ordering::Relaxed);
                loop {
                    let new = (cur & !mask) | val;
                    match cell.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed)
                    {
                        Ok(_) => break,
                        Err(c) => cur = c,
                    }
                }
            }
            i += in_word;
        }
    }

    pub fn read_vec(&self, addr: u64, len: usize) -> Vec<u8> {
        let mut v = vec![0u8; len];
        self.read_bytes(addr, &mut v);
        v
    }

    /// Read a NUL-terminated string (bounded).
    pub fn read_cstr(&self, addr: u64, max: usize) -> String {
        let mut out = Vec::new();
        for i in 0..max as u64 {
            let b = self.read_u8(addr + i);
            if b == 0 {
                break;
            }
            out.push(b);
        }
        String::from_utf8_lossy(&out).into_owned()
    }

    pub fn write_cstr(&self, addr: u64, s: &str) {
        self.write_bytes(addr, s.as_bytes());
        self.write_u8(addr + s.len() as u64, 0);
    }

    // ---- atomics (device-wide, SeqCst to model GPU global atomics) ----

    pub fn atomic_add_u64(&self, addr: u64, v: u64) -> u64 {
        assert_eq!(addr % 8, 0, "atomic on unaligned address {addr:#x}");
        let (seg, off) = self.locate(addr, 8);
        seg[(off / 8) as usize].fetch_add(v, Ordering::SeqCst)
    }

    pub fn atomic_cas_u64(&self, addr: u64, expect: u64, new: u64) -> Result<u64, u64> {
        assert_eq!(addr % 8, 0, "atomic on unaligned address {addr:#x}");
        let (seg, off) = self.locate(addr, 8);
        seg[(off / 8) as usize].compare_exchange(expect, new, Ordering::SeqCst, Ordering::SeqCst)
    }

    pub fn atomic_load_u64(&self, addr: u64) -> u64 {
        assert_eq!(addr % 8, 0);
        let (seg, off) = self.locate(addr, 8);
        seg[(off / 8) as usize].load(Ordering::SeqCst)
    }

    pub fn atomic_store_u64(&self, addr: u64, v: u64) {
        assert_eq!(addr % 8, 0);
        let (seg, off) = self.locate(addr, 8);
        seg[(off / 8) as usize].store(v, Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem() -> DeviceMemory {
        DeviceMemory::new(MemConfig::small())
    }

    #[test]
    fn segments_classified() {
        let m = mem();
        assert_eq!(m.segment(0x10), Segment::NullPage);
        assert_eq!(m.segment(GLOBAL_BASE), Segment::Global);
        assert_eq!(m.segment(MANAGED_BASE + 8), Segment::Managed);
        assert_eq!(m.segment(STACK_BASE), Segment::Stack);
        assert_eq!(m.segment(0xFFFF_FFFF_0000), Segment::Host);
    }

    #[test]
    fn rw_round_trip_all_widths() {
        let m = mem();
        let a = GLOBAL_BASE + 64;
        m.write_u64(a, 0x1122334455667788);
        assert_eq!(m.read_u64(a), 0x1122334455667788);
        m.write_u32(a + 16, 0xDEADBEEF);
        assert_eq!(m.read_u32(a + 16), 0xDEADBEEF);
        m.write_u8(a + 25, 0xAB);
        assert_eq!(m.read_u8(a + 25), 0xAB);
        m.write_f64(a + 32, -1.5);
        assert_eq!(m.read_f64(a + 32), -1.5);
        m.write_f32(a + 40, 2.25);
        assert_eq!(m.read_f32(a + 40), 2.25);
        m.write_i64(a + 48, -42);
        assert_eq!(m.read_i64(a + 48), -42);
    }

    #[test]
    fn unaligned_access_round_trips() {
        let m = mem();
        let a = GLOBAL_BASE + 3; // crosses a word boundary
        m.write_u64(a, 0xA1B2C3D4E5F60718);
        assert_eq!(m.read_u64(a), 0xA1B2C3D4E5F60718);
        // Neighbours untouched beyond the 8 bytes written.
        assert_eq!(m.read_u8(GLOBAL_BASE + 2), 0);
        assert_eq!(m.read_u8(a + 8), 0);
    }

    #[test]
    fn bulk_and_cstr() {
        let m = mem();
        let a = MANAGED_BASE + 100; // unaligned on purpose
        let data: Vec<u8> = (0..33).collect();
        m.write_bytes(a, &data);
        assert_eq!(m.read_vec(a, 33), data);
        m.write_cstr(a + 64, "hello, GPU");
        assert_eq!(m.read_cstr(a + 64, 64), "hello, GPU");
    }

    #[test]
    fn atomics() {
        let m = mem();
        let a = GLOBAL_BASE + 1024;
        assert_eq!(m.atomic_add_u64(a, 5), 0);
        assert_eq!(m.atomic_add_u64(a, 3), 5);
        assert_eq!(m.atomic_load_u64(a), 8);
        assert!(m.atomic_cas_u64(a, 8, 100).is_ok());
        assert_eq!(m.atomic_cas_u64(a, 8, 1), Err(100));
    }

    #[test]
    #[should_panic(expected = "device fault")]
    fn null_deref_faults() {
        mem().read_u64(0x8);
    }

    #[test]
    fn concurrent_subword_writes_do_not_clobber() {
        use std::sync::Arc;
        let m = Arc::new(mem());
        let a = GLOBAL_BASE + 2048;
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.write_u8(a + t, t as u8 + 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        for t in 0..8u64 {
            assert_eq!(m.read_u8(a + t), t as u8 + 1);
        }
    }
}
