//! Grid execution engine: runs simulated GPU threads on a host worker pool.
//!
//! Three launch shapes cover the paper's execution modes:
//!
//! * [`Device::launch`] — data-parallel grid (no cross-thread communication
//!   inside the body). Simulated threads are partitioned over a worker
//!   pool; this is how expanded multi-team parallel regions execute.
//! * [`Device::launch_phased`] — bulk-synchronous: the body is called once
//!   per phase per simulated thread with an implicit **global barrier**
//!   between phases (the paper's cross-team barrier via global atomic
//!   counters). Used by wavefront codes (smithwa).
//! * [`Device::launch_coop`] — one real OS thread per simulated thread with
//!   a true [`GridCtx::barrier_global`]; bounded to small grids, used where
//!   arbitrary barrier placement is required.

use super::memory::{DeviceMemory, MemConfig, GLOBAL_BASE, MANAGED_BASE};
use super::stats::{Counters, LaunchStats, Pattern, SharedCounters};
use crate::alloc::{
    AllocCtx, AllocError, BalancedAllocator, BalancedConfig, DeviceAllocator, GenericAllocator,
    VendorAllocator,
};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaunchConfig {
    pub teams: usize,
    pub threads_per_team: usize,
}

impl LaunchConfig {
    pub fn new(teams: usize, threads_per_team: usize) -> Self {
        assert!(teams >= 1 && threads_per_team >= 1);
        Self { teams, threads_per_team }
    }

    pub fn total_threads(&self) -> usize {
        self.teams * self.threads_per_team
    }
}

/// Allocator selection — the paper's
/// `-fopenmp-target-allocator={generic,balanced[N,M]}` flag, plus the
/// vendor baseline.
#[derive(Debug, Clone, Copy)]
pub enum AllocatorKind {
    Generic,
    Balanced(BalancedConfig),
    Vendor,
}

impl AllocatorKind {
    /// Parse the paper's flag syntax: `generic`, `vendor`, `balanced`,
    /// `balanced[N,M]`.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "generic" => Ok(AllocatorKind::Generic),
            "vendor" => Ok(AllocatorKind::Vendor),
            "balanced" => Ok(AllocatorKind::Balanced(BalancedConfig::default())),
            _ => {
                let inner = s
                    .strip_prefix("balanced[")
                    .and_then(|r| r.strip_suffix(']'))
                    .ok_or_else(|| format!("unknown allocator {s:?}"))?;
                let (n, m) = inner.split_once(',').ok_or("balanced[N,M] expects two ints")?;
                Ok(AllocatorKind::Balanced(BalancedConfig {
                    n: n.trim().parse().map_err(|e| format!("bad N: {e}"))?,
                    m: m.trim().parse().map_err(|e| format!("bad M: {e}"))?,
                    ..BalancedConfig::default()
                }))
            }
        }
    }
}

/// The simulated device: memory + heap allocator + worker pool size.
pub struct Device {
    pub mem: Arc<DeviceMemory>,
    pub heap: Arc<dyn DeviceAllocator>,
    workers: usize,
    arena: crate::rpc::engine::ArenaLayout,
    managed_bump: Mutex<u64>,
    managed_end: u64,
    /// Launches performed (for the cost model's launch-overhead term).
    pub launches: AtomicU64,
}

impl Device {
    /// Device with the legacy single-slot RPC reservation (paper §4.4).
    pub fn new(mem_cfg: MemConfig, alloc_kind: AllocatorKind) -> Self {
        Self::with_arena(mem_cfg, alloc_kind, crate::rpc::engine::ArenaLayout::legacy())
    }

    /// Device reserving a multi-lane RPC mailbox arena at the base of
    /// the managed segment (see `rpc::engine::arena`); managed
    /// allocations start above it.
    pub fn with_arena(
        mem_cfg: MemConfig,
        alloc_kind: AllocatorKind,
        arena: crate::rpc::engine::ArenaLayout,
    ) -> Self {
        let mem = Arc::new(DeviceMemory::new(mem_cfg));
        let heap_base = GLOBAL_BASE;
        let heap_size = mem_cfg.global_size;
        let heap: Arc<dyn DeviceAllocator> = match alloc_kind {
            AllocatorKind::Generic => Arc::new(GenericAllocator::new(heap_base, heap_size)),
            AllocatorKind::Balanced(cfg) => {
                Arc::new(BalancedAllocator::new(heap_base, heap_size, cfg))
            }
            AllocatorKind::Vendor => Arc::new(VendorAllocator::new(heap_base, heap_size)),
        };
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8).min(16);
        // Leave at least 1 MiB of managed headroom above the arena for
        // migrated objects and managed_alloc callers.
        assert!(
            arena.reserved_bytes() + (1 << 20) <= mem_cfg.managed_size,
            "RPC arena ({} lanes + {}-slot launch ring, {} B each) does not fit the \
             managed segment; lower --rpc-lanes/--rpc-launch-slots or raise managed_size",
            arena.lanes,
            arena.launch_slots,
            arena.lane_stride(),
        );
        Self {
            mem,
            heap,
            workers,
            arena,
            // Reserve the low managed region for the RPC mailbox arena.
            managed_bump: Mutex::new(MANAGED_BASE + arena.reserved_bytes()),
            managed_end: MANAGED_BASE + mem_cfg.managed_size,
            launches: AtomicU64::new(0),
        }
    }

    pub fn small() -> Self {
        Self::new(MemConfig::small(), AllocatorKind::Generic)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Shape of the RPC mailbox arena this device reserved.
    pub fn arena(&self) -> crate::rpc::engine::ArenaLayout {
        self.arena
    }

    /// Bump-allocate managed (host-visible) memory; freed only wholesale.
    pub fn managed_alloc(&self, size: u64) -> u64 {
        let size = crate::alloc::align_up(size.max(1), 16);
        let mut g = self.managed_bump.lock().unwrap();
        assert!(*g + size <= self.managed_end, "managed segment exhausted");
        let addr = *g;
        *g += size;
        addr
    }

    /// Data-parallel launch. Returns aggregated launch statistics.
    pub fn launch<F>(&self, cfg: LaunchConfig, body: F) -> LaunchStats
    where
        F: Fn(&mut GridCtx) + Sync,
    {
        self.launches.fetch_add(1, Ordering::Relaxed);
        let shared = SharedCounters::default();
        let total = cfg.total_threads();
        let next = AtomicUsize::new(0);
        // Perf (§Perf L3-2): spawning a worker costs ~1.5 us; small grids
        // use fewer workers so launch overhead tracks grid size.
        let workers = self.workers.min(total.div_ceil(64)).max(1);
        let chunk = (total / (workers * 8)).max(1);
        std::thread::scope(|s| {
            for _ in 0..workers.min(total) {
                s.spawn(|| {
                    let mut local = Counters::default();
                    loop {
                        let start = next.fetch_add(chunk, Ordering::Relaxed);
                        if start >= total {
                            break;
                        }
                        for gtid in start..(start + chunk).min(total) {
                            let mut ctx = GridCtx {
                                team_id: gtid / cfg.threads_per_team,
                                thread_id: gtid % cfg.threads_per_team,
                                cfg,
                                counters: Counters::default(),
                                device: self,
                                coop_barrier: None,
                            };
                            body(&mut ctx);
                            local.merge_from(&ctx.counters);
                        }
                    }
                    shared.absorb(&local);
                });
            }
        });
        shared.snapshot()
    }

    /// Batched data-parallel launch: `make` materializes per-lane state
    /// once for every simulated thread of a worker's chunk, then `step`
    /// advances each live lane by one bounded quantum per round,
    /// round-robin, until every lane reports done (`step` returns
    /// `true`). Per-lane counters are merged exactly like [`Self::launch`]
    /// and the launch-overhead term is charged once.
    ///
    /// This is the engine half of the bytecode executor's batched team
    /// stepping: instead of re-entering the execution body per lane per
    /// step, one dispatch round sweeps the whole team batch, amortizing
    /// frame setup and RPC-wait polling across the team loop. Bodies
    /// must not use [`GridCtx::barrier_global`] (lanes share a worker
    /// thread; use [`Self::launch_coop`] for barrier codes).
    pub fn launch_batched<S, M, F>(&self, cfg: LaunchConfig, make: M, step: F) -> LaunchStats
    where
        M: Fn(&mut GridCtx) -> S + Sync,
        F: Fn(&mut GridCtx, &mut S) -> bool + Sync,
    {
        self.launches.fetch_add(1, Ordering::Relaxed);
        let shared = SharedCounters::default();
        let total = cfg.total_threads();
        let next = AtomicUsize::new(0);
        let workers = self.workers.min(total.div_ceil(64)).max(1);
        let chunk = (total / (workers * 8)).max(1);
        std::thread::scope(|s| {
            for _ in 0..workers.min(total) {
                s.spawn(|| {
                    let mut local = Counters::default();
                    loop {
                        let start = next.fetch_add(chunk, Ordering::Relaxed);
                        if start >= total {
                            break;
                        }
                        // Materialize every lane of the chunk up front…
                        let mut lanes: Vec<(GridCtx, S, bool)> = (start
                            ..(start + chunk).min(total))
                            .map(|gtid| {
                                let mut ctx = GridCtx {
                                    team_id: gtid / cfg.threads_per_team,
                                    thread_id: gtid % cfg.threads_per_team,
                                    cfg,
                                    counters: Counters::default(),
                                    device: self,
                                    coop_barrier: None,
                                };
                                let state = make(&mut ctx);
                                (ctx, state, false)
                            })
                            .collect();
                        // …then sweep: one quantum per live lane per
                        // round until the whole batch drains.
                        let mut live = lanes.len();
                        while live > 0 {
                            for (ctx, state, done) in lanes.iter_mut() {
                                if *done {
                                    continue;
                                }
                                if step(ctx, state) {
                                    *done = true;
                                    live -= 1;
                                }
                            }
                        }
                        for (ctx, _, _) in &lanes {
                            local.merge_from(&ctx.counters);
                        }
                    }
                    shared.absorb(&local);
                });
            }
        });
        shared.snapshot()
    }

    /// Bulk-synchronous launch: `phases` rounds with a global barrier after
    /// each. The barrier cost is charged once per phase per thread.
    pub fn launch_phased<F>(&self, cfg: LaunchConfig, phases: usize, body: F) -> LaunchStats
    where
        F: Fn(&mut GridCtx, usize) + Sync,
    {
        self.launches.fetch_add(1, Ordering::Relaxed);
        let shared = SharedCounters::default();
        let total = cfg.total_threads();
        for phase in 0..phases {
            let next = AtomicUsize::new(0);
            let chunk = (total / (self.workers * 8)).max(1);
            std::thread::scope(|s| {
                for _ in 0..self.workers.min(total) {
                    s.spawn(|| {
                        let mut local = Counters::default();
                        loop {
                            let start = next.fetch_add(chunk, Ordering::Relaxed);
                            if start >= total {
                                break;
                            }
                            for gtid in start..(start + chunk).min(total) {
                                let mut ctx = GridCtx {
                                    team_id: gtid / cfg.threads_per_team,
                                    thread_id: gtid % cfg.threads_per_team,
                                    cfg,
                                    counters: Counters::default(),
                                    device: self,
                                    coop_barrier: None,
                                };
                                body(&mut ctx, phase);
                                ctx.counters.barriers_global += 1;
                                local.merge_from(&ctx.counters);
                            }
                        }
                        shared.absorb(&local);
                    });
                }
            });
        }
        shared.snapshot()
    }

    /// Cooperative launch: real OS thread per simulated thread so the body
    /// may call [`GridCtx::barrier_global`] anywhere. Grid bounded to 1024.
    pub fn launch_coop<F>(&self, cfg: LaunchConfig, body: F) -> LaunchStats
    where
        F: Fn(&mut GridCtx) + Sync,
    {
        let total = cfg.total_threads();
        assert!(total <= 1024, "launch_coop bounded to 1024 simulated threads (got {total})");
        self.launches.fetch_add(1, Ordering::Relaxed);
        let shared = SharedCounters::default();
        let barrier = Barrier::new(total);
        std::thread::scope(|s| {
            for gtid in 0..total {
                let barrier = &barrier;
                let shared = &shared;
                let body = &body;
                s.spawn(move || {
                    let mut ctx = GridCtx {
                        team_id: gtid / cfg.threads_per_team,
                        thread_id: gtid % cfg.threads_per_team,
                        cfg,
                        counters: Counters::default(),
                        device: self,
                        coop_barrier: Some(barrier),
                    };
                    body(&mut ctx);
                    shared.absorb(&ctx.counters);
                });
            }
        });
        shared.snapshot()
    }
}

/// Per-simulated-thread execution context.
pub struct GridCtx<'a> {
    pub team_id: usize,
    pub thread_id: usize,
    pub cfg: LaunchConfig,
    pub counters: Counters,
    pub device: &'a Device,
    coop_barrier: Option<&'a Barrier>,
}

impl<'a> GridCtx<'a> {
    /// Continuous global thread id across teams (paper §3.3: teams "are
    /// bulked together as one large team, ensuring that all the threads
    /// have continuous thread IDs").
    #[inline]
    pub fn global_tid(&self) -> usize {
        self.team_id * self.cfg.threads_per_team + self.thread_id
    }

    #[inline]
    pub fn num_threads_global(&self) -> usize {
        self.cfg.total_threads()
    }

    // ---- counter shorthands ----

    #[inline]
    pub fn flops(&mut self, n: u64) {
        self.counters.flops_f64 += n;
    }

    #[inline]
    pub fn flops32(&mut self, n: u64) {
        self.counters.flops_f32 += n;
    }

    #[inline]
    pub fn int_ops(&mut self, n: u64) {
        self.counters.int_ops += n;
    }

    #[inline]
    pub fn mem(&mut self, bytes: u64, p: Pattern) {
        self.counters.mem(bytes, p);
    }

    #[inline]
    pub fn divergent(&mut self, n: u64) {
        self.counters.divergent_branches += n;
        // A divergent warp serializes both sides: charge the ALU proxy.
        self.counters.int_ops += n * 32;
    }

    // ---- heap ----

    pub fn malloc(&mut self, size: u64) -> Result<u64, AllocError> {
        self.counters.allocs += 1;
        self.counters.charge_ns(self.device.heap.per_op_ns());
        self.device.heap.malloc(AllocCtx { thread_id: self.thread_id, team_id: self.team_id }, size)
    }

    pub fn free(&mut self, addr: u64) -> Result<(), AllocError> {
        self.counters.frees += 1;
        self.counters.charge_ns(self.device.heap.per_op_ns());
        self.device.heap.free(addr)
    }

    // ---- synchronization ----

    /// Cross-team barrier. Real synchronization in coop mode; in
    /// data-parallel mode only legal as a no-op at thread exit, so it
    /// panics to catch misuse early.
    pub fn barrier_global(&mut self) {
        self.counters.barriers_global += 1;
        match self.coop_barrier {
            Some(b) => {
                b.wait();
            }
            None => panic!(
                "barrier_global requires launch_coop (data-parallel launches \
                 must use launch_phased for bulk-synchronous patterns)"
            ),
        }
    }

    /// In-team barrier: counted for the cost model; simulation-level
    /// ordering is provided by phase structure.
    pub fn barrier_team(&mut self) {
        self.counters.barriers_team += 1;
        if let Some(b) = self.coop_barrier {
            // Coop grids are small; a full barrier conservatively preserves
            // in-team ordering too.
            b.wait();
        }
    }

    pub fn atomic_add_u64(&mut self, addr: u64, v: u64) -> u64 {
        self.counters.atomics_global += 1;
        self.device.mem.atomic_add_u64(addr, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn launch_covers_every_thread_exactly_once() {
        let dev = Device::small();
        let cfg = LaunchConfig::new(8, 16);
        let hits: Vec<AtomicU64> = (0..cfg.total_threads()).map(|_| AtomicU64::new(0)).collect();
        dev.launch(cfg, |ctx| {
            hits[ctx.global_tid()].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn global_tid_continuous_across_teams() {
        let dev = Device::small();
        let cfg = LaunchConfig::new(4, 4);
        let seen = Mutex::new(Vec::new());
        dev.launch(cfg, |ctx| {
            seen.lock().unwrap().push((ctx.team_id, ctx.thread_id, ctx.global_tid()));
        });
        for (team, thr, gtid) in seen.into_inner().unwrap() {
            assert_eq!(gtid, team * 4 + thr);
        }
    }

    #[test]
    fn stats_aggregate_flops_and_mem() {
        let dev = Device::small();
        let stats = dev.launch(LaunchConfig::new(2, 8), |ctx| {
            ctx.flops(10);
            ctx.mem(64, Pattern::Coalesced);
            ctx.mem(8, Pattern::Random);
        });
        assert_eq!(stats.flops_f64, 160);
        assert_eq!(stats.bytes_coalesced, 1024);
        assert_eq!(stats.bytes_random, 128);
    }

    #[test]
    fn batched_launch_steps_every_lane_to_completion() {
        let dev = Device::small();
        let cfg = LaunchConfig::new(4, 16);
        let before = dev.launches.load(Ordering::Relaxed);
        let hits: Vec<AtomicU64> = (0..cfg.total_threads()).map(|_| AtomicU64::new(0)).collect();
        // Lanes need different step counts (tid % 5 + 1) so the sweep
        // must keep revisiting a shrinking live set.
        let stats = dev.launch_batched(
            cfg,
            |ctx| (ctx.global_tid(), 0usize),
            |ctx, (tid, steps)| {
                assert_eq!(*tid, ctx.global_tid(), "state stays with its lane");
                ctx.int_ops(1);
                *steps += 1;
                if *steps == *tid % 5 + 1 {
                    hits[*tid].fetch_add(1, Ordering::Relaxed);
                    true
                } else {
                    false
                }
            },
        );
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "each lane done once");
        let want: u64 = (0..cfg.total_threads()).map(|t| (t % 5 + 1) as u64).sum();
        assert_eq!(stats.int_ops, want, "per-lane counters merge");
        assert_eq!(dev.launches.load(Ordering::Relaxed), before + 1, "one launch charge");
    }

    #[test]
    fn phased_launch_orders_phases() {
        let dev = Device::small();
        let cfg = LaunchConfig::new(2, 4);
        // Phase 1 reads what phase 0 wrote by a *different* thread.
        let a = GLOBAL_BASE + 4096;
        let stats = dev.launch_phased(cfg, 2, |ctx, phase| {
            let n = ctx.num_threads_global() as u64;
            let t = ctx.global_tid() as u64;
            if phase == 0 {
                ctx.device.mem.write_u64(a + t * 8, t + 1);
            } else {
                let peer = (t + 1) % n;
                assert_eq!(ctx.device.mem.read_u64(a + peer * 8), peer + 1);
            }
        });
        assert_eq!(stats.barriers_global, 2 * cfg.total_threads() as u64);
    }

    #[test]
    fn coop_barrier_synchronizes() {
        let dev = Device::small();
        let cfg = LaunchConfig::new(2, 8);
        let a = GLOBAL_BASE + 8192;
        dev.launch_coop(cfg, |ctx| {
            let t = ctx.global_tid() as u64;
            ctx.device.mem.write_u64(a + t * 8, t * 10);
            ctx.barrier_global();
            let peer = ((t + 5) % 16) * 8;
            assert_eq!(ctx.device.mem.read_u64(a + peer), (peer / 8) * 10);
        });
    }

    #[test]
    #[should_panic] // worker-thread panic resurfaces at scope join
    fn barrier_in_data_parallel_panics() {
        let dev = Device::small();
        dev.launch(LaunchConfig::new(1, 2), |ctx| {
            ctx.barrier_global();
        });
    }

    #[test]
    fn malloc_through_ctx_counts() {
        let dev = Device::small();
        let stats = dev.launch(LaunchConfig::new(1, 4), |ctx| {
            let p = ctx.malloc(128).unwrap();
            ctx.free(p).unwrap();
        });
        assert_eq!(stats.allocs, 4);
        assert_eq!(stats.frees, 4);
        assert!(stats.charged_ns_max > 0.0);
    }

    #[test]
    fn allocator_kind_parses_paper_flag() {
        assert!(matches!(AllocatorKind::parse("generic"), Ok(AllocatorKind::Generic)));
        assert!(matches!(AllocatorKind::parse("vendor"), Ok(AllocatorKind::Vendor)));
        match AllocatorKind::parse("balanced[8,4]").unwrap() {
            AllocatorKind::Balanced(c) => {
                assert_eq!((c.n, c.m), (8, 4));
            }
            _ => panic!(),
        }
        assert!(AllocatorKind::parse("bogus").is_err());
    }

    #[test]
    fn managed_alloc_bumps() {
        let dev = Device::small();
        let a = dev.managed_alloc(100);
        let b = dev.managed_alloc(100);
        assert!(b >= a + 100);
        assert_eq!(dev.mem.segment(a), super::super::memory::Segment::Managed);
    }

    #[test]
    fn arena_reservation_pushes_managed_allocs_up() {
        let arena = crate::rpc::engine::ArenaLayout::for_lanes(4);
        let dev = Device::with_arena(MemConfig::small(), AllocatorKind::Generic, arena);
        assert_eq!(dev.arena(), arena);
        let a = dev.managed_alloc(64);
        assert!(
            a >= MANAGED_BASE + arena.reserved_bytes(),
            "managed allocations must start above the {}-lane arena",
            arena.lanes
        );
        // Legacy device keeps the historical single-slot reservation.
        let legacy = Device::small();
        let b = legacy.managed_alloc(64);
        assert!(b >= MANAGED_BASE + crate::rpc::mailbox::MAILBOX_RESERVED);
    }
}
