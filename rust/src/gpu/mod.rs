//! The simulated GPU substrate.
//!
//! The paper runs on an NVIDIA A100; this reproduction substitutes a SIMT
//! device simulator (see DESIGN.md §2): segmented device memory
//! ([`memory`]), a teams×threads grid execution engine ([`grid`]) and
//! executed-operation counters ([`stats`]) consumed by the
//! [`crate::perfmodel`] roofline to produce modeled device time.

pub mod memory;
pub mod stats;
pub mod grid;

pub use grid::{AllocatorKind, Device, GridCtx, LaunchConfig};
pub use memory::{DeviceMemory, MemConfig, Segment, GLOBAL_BASE, MANAGED_BASE, STACK_BASE};
pub use stats::{Counters, LaunchStats, Pattern};
