//! Executed-operation counters.
//!
//! The simulator does not cycle-accurately model an A100; instead every
//! simulated thread records *what it did* (flops, bytes moved by coalescing
//! class, barriers, atomics, allocator traffic, RPC waits) and the
//! [`crate::perfmodel`] roofline converts the aggregate into modeled device
//! time. Counters are plain `u64`s accumulated thread-locally and merged
//! into a [`SharedCounters`] at the end of each simulated thread, so the hot
//! path is increment-only.

use std::sync::atomic::{AtomicU64, Ordering};

/// Memory-access pattern, per warp, as the multi-team transform classifies
/// it (index linear in tid → coalesced; constant stride → strided; data
/// dependent → random).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    Coalesced,
    Strided,
    Random,
}

/// Per-thread counters (not shared; merged on completion).
#[derive(Debug, Clone, Copy, Default)]
pub struct Counters {
    pub flops_f64: u64,
    pub flops_f32: u64,
    pub int_ops: u64,
    pub bytes_coalesced: u64,
    pub bytes_strided: u64,
    pub bytes_random: u64,
    pub barriers_team: u64,
    pub barriers_global: u64,
    pub atomics_global: u64,
    pub allocs: u64,
    pub frees: u64,
    /// Modeled nanoseconds charged directly (allocator serialization, RPC
    /// wait, vendor-malloc fixed costs).
    pub charged_ns: f64,
    pub rpc_calls: u64,
    pub divergent_branches: u64,
}

impl Counters {
    #[inline]
    pub fn flops(&mut self, n: u64) {
        self.flops_f64 += n;
    }

    #[inline]
    pub fn flops32(&mut self, n: u64) {
        self.flops_f32 += n;
    }

    #[inline]
    pub fn mem(&mut self, bytes: u64, p: Pattern) {
        match p {
            Pattern::Coalesced => self.bytes_coalesced += bytes,
            Pattern::Strided => self.bytes_strided += bytes,
            Pattern::Random => self.bytes_random += bytes,
        }
    }

    #[inline]
    pub fn charge_ns(&mut self, ns: f64) {
        self.charged_ns += ns;
    }

    pub fn merge_from(&mut self, o: &Counters) {
        self.flops_f64 += o.flops_f64;
        self.flops_f32 += o.flops_f32;
        self.int_ops += o.int_ops;
        self.bytes_coalesced += o.bytes_coalesced;
        self.bytes_strided += o.bytes_strided;
        self.bytes_random += o.bytes_random;
        self.barriers_team += o.barriers_team;
        self.barriers_global += o.barriers_global;
        self.atomics_global += o.atomics_global;
        self.allocs += o.allocs;
        self.frees += o.frees;
        self.charged_ns += o.charged_ns;
        self.rpc_calls += o.rpc_calls;
        self.divergent_branches += o.divergent_branches;
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_coalesced + self.bytes_strided + self.bytes_random
    }
}

/// Atomic accumulator shared across the worker pool.
#[derive(Debug, Default)]
pub struct SharedCounters {
    pub flops_f64: AtomicU64,
    pub flops_f32: AtomicU64,
    pub int_ops: AtomicU64,
    pub bytes_coalesced: AtomicU64,
    pub bytes_strided: AtomicU64,
    pub bytes_random: AtomicU64,
    pub barriers_team: AtomicU64,
    pub barriers_global: AtomicU64,
    pub atomics_global: AtomicU64,
    pub allocs: AtomicU64,
    pub frees: AtomicU64,
    /// Max over threads of charged ns (critical-path approximation), stored
    /// as f64 bits.
    pub charged_ns_max: AtomicU64,
    /// Sum over threads of charged ns (serialization approximation).
    pub charged_ns_sum: AtomicU64,
    pub rpc_calls: AtomicU64,
    pub divergent_branches: AtomicU64,
}

impl SharedCounters {
    pub fn absorb(&self, c: &Counters) {
        let r = Ordering::Relaxed;
        self.flops_f64.fetch_add(c.flops_f64, r);
        self.flops_f32.fetch_add(c.flops_f32, r);
        self.int_ops.fetch_add(c.int_ops, r);
        self.bytes_coalesced.fetch_add(c.bytes_coalesced, r);
        self.bytes_strided.fetch_add(c.bytes_strided, r);
        self.bytes_random.fetch_add(c.bytes_random, r);
        self.barriers_team.fetch_add(c.barriers_team, r);
        self.barriers_global.fetch_add(c.barriers_global, r);
        self.atomics_global.fetch_add(c.atomics_global, r);
        self.allocs.fetch_add(c.allocs, r);
        self.frees.fetch_add(c.frees, r);
        self.rpc_calls.fetch_add(c.rpc_calls, r);
        self.divergent_branches.fetch_add(c.divergent_branches, r);
        // f64 max via CAS on bits.
        let mut cur = self.charged_ns_max.load(r);
        loop {
            if c.charged_ns <= f64::from_bits(cur) {
                break;
            }
            match self.charged_ns_max.compare_exchange_weak(
                cur,
                c.charged_ns.to_bits(),
                r,
                r,
            ) {
                Ok(_) => break,
                Err(x) => cur = x,
            }
        }
        // f64 sum via CAS on bits.
        let mut cur = self.charged_ns_sum.load(r);
        loop {
            let new = f64::from_bits(cur) + c.charged_ns;
            match self
                .charged_ns_sum
                .compare_exchange_weak(cur, new.to_bits(), r, r)
            {
                Ok(_) => break,
                Err(x) => cur = x,
            }
        }
    }

    pub fn snapshot(&self) -> LaunchStats {
        let r = Ordering::Relaxed;
        LaunchStats {
            flops_f64: self.flops_f64.load(r),
            flops_f32: self.flops_f32.load(r),
            int_ops: self.int_ops.load(r),
            bytes_coalesced: self.bytes_coalesced.load(r),
            bytes_strided: self.bytes_strided.load(r),
            bytes_random: self.bytes_random.load(r),
            barriers_team: self.barriers_team.load(r),
            barriers_global: self.barriers_global.load(r),
            atomics_global: self.atomics_global.load(r),
            allocs: self.allocs.load(r),
            frees: self.frees.load(r),
            charged_ns_max: f64::from_bits(self.charged_ns_max.load(r)),
            charged_ns_sum: f64::from_bits(self.charged_ns_sum.load(r)),
            rpc_calls: self.rpc_calls.load(r),
            divergent_branches: self.divergent_branches.load(r),
        }
    }
}

/// Immutable aggregate of one launch, input to the cost model.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LaunchStats {
    pub flops_f64: u64,
    pub flops_f32: u64,
    pub int_ops: u64,
    pub bytes_coalesced: u64,
    pub bytes_strided: u64,
    pub bytes_random: u64,
    pub barriers_team: u64,
    pub barriers_global: u64,
    pub atomics_global: u64,
    pub allocs: u64,
    pub frees: u64,
    pub charged_ns_max: f64,
    pub charged_ns_sum: f64,
    pub rpc_calls: u64,
    pub divergent_branches: u64,
}

impl LaunchStats {
    /// Add memory traffic under a coalescing class.
    pub fn mem_add(&mut self, bytes: u64, p: Pattern) {
        match p {
            Pattern::Coalesced => self.bytes_coalesced += bytes,
            Pattern::Strided => self.bytes_strided += bytes,
            Pattern::Random => self.bytes_random += bytes,
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_coalesced + self.bytes_strided + self.bytes_random
    }

    pub fn add(&self, o: &LaunchStats) -> LaunchStats {
        LaunchStats {
            flops_f64: self.flops_f64 + o.flops_f64,
            flops_f32: self.flops_f32 + o.flops_f32,
            int_ops: self.int_ops + o.int_ops,
            bytes_coalesced: self.bytes_coalesced + o.bytes_coalesced,
            bytes_strided: self.bytes_strided + o.bytes_strided,
            bytes_random: self.bytes_random + o.bytes_random,
            barriers_team: self.barriers_team + o.barriers_team,
            barriers_global: self.barriers_global + o.barriers_global,
            atomics_global: self.atomics_global + o.atomics_global,
            allocs: self.allocs + o.allocs,
            frees: self.frees + o.frees,
            charged_ns_max: self.charged_ns_max.max(o.charged_ns_max),
            charged_ns_sum: self.charged_ns_sum + o.charged_ns_sum,
            rpc_calls: self.rpc_calls + o.rpc_calls,
            divergent_branches: self.divergent_branches + o.divergent_branches,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_merge() {
        let mut a = Counters::default();
        a.flops(10);
        a.mem(64, Pattern::Coalesced);
        a.mem(32, Pattern::Random);
        let mut b = Counters::default();
        b.flops(5);
        b.mem(8, Pattern::Strided);
        b.charge_ns(100.0);
        a.merge_from(&b);
        assert_eq!(a.flops_f64, 15);
        assert_eq!(a.total_bytes(), 104);
        assert_eq!(a.charged_ns, 100.0);
    }

    #[test]
    fn shared_absorb_and_snapshot() {
        let s = SharedCounters::default();
        let mut c1 = Counters::default();
        c1.charge_ns(50.0);
        c1.flops(7);
        let mut c2 = Counters::default();
        c2.charge_ns(80.0);
        s.absorb(&c1);
        s.absorb(&c2);
        let snap = s.snapshot();
        assert_eq!(snap.flops_f64, 7);
        assert_eq!(snap.charged_ns_max, 80.0);
        assert!((snap.charged_ns_sum - 130.0).abs() < 1e-9);
    }

    #[test]
    fn shared_concurrent_absorb() {
        use std::sync::Arc;
        let s = Arc::new(SharedCounters::default());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        let mut c = Counters::default();
                        c.flops(1);
                        c.charge_ns(1.0);
                        s.absorb(&c);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let snap = s.snapshot();
        assert_eq!(snap.flops_f64, 8000);
        assert!((snap.charged_ns_sum - 8000.0).abs() < 1e-6);
    }
}
