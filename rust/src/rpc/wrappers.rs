//! Host landing-pad wrappers and the host environment they close over.
//!
//! The RPC generation pass ([`crate::transform::rpcgen`]) knows *which*
//! library function a call site targets and the argument-type signature at
//! that site; it asks this module to synthesize the matching non-variadic
//! landing pad (the `__fscanf_ip_fp_ip`-style functions of Fig. 3b) and
//! registers it under the mangled name. The wrappers run against an
//! in-memory [`HostEnv`] (files, stdout/stderr capture, process state) so
//! host-side effects are observable in tests.

use super::server::{BatchWrapperFn, RpcFrame, StreamDir, WrapperFn, WrapperRegistry};
use std::cell::Cell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, TryLockError};

/// Lock `m`, recovering from a poisoned mutex instead of cascading the
/// panic: a landing pad that panicked while holding a `HostEnv` lock
/// used to turn every later RPC on that lock into a permanent
/// `PoisonError` panic — one bad wrapper poisoned the whole host
/// environment. The data under these locks (byte streams, maps,
/// counters) stays structurally valid across an unwound wrapper, so the
/// inner guard is safe to hand out; `recoveries` counts how often it
/// happened (surfaced through [`HostIoSnapshot::poison_recoveries`]).
fn lock_or_recover<'a, T>(m: &'a Mutex<T>, recoveries: &AtomicU64) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|poisoned| {
        recoveries.fetch_add(1, Ordering::Relaxed);
        poisoned.into_inner()
    })
}

pub const FD_STDIN: u64 = 0;
pub const FD_STDOUT: u64 = 1;
pub const FD_STDERR: u64 = 2;

thread_local! {
    /// Which arena slot the currently-executing landing pad is serving.
    /// Set by the engine's dispatch/executor threads; `None` on the
    /// legacy single-threaded server and in direct test invocations.
    static LANE_CTX: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Run `f` with the serving-lane context set to `lane` (restores the
/// previous context afterwards). [`HostEnv`] uses the context to pick a
/// per-lane file-table shard for `fopen`.
pub fn with_lane_ctx<R>(lane: usize, f: impl FnOnce() -> R) -> R {
    LANE_CTX.with(|c| {
        let prev = c.replace(Some(lane));
        let r = f();
        c.set(prev);
        r
    })
}

fn current_lane() -> Option<usize> {
    LANE_CTX.with(|c| c.get())
}

/// Bit position of the shard tag inside a sharded fd: `fd =
/// (shard + 1) << FD_SHARD_SHIFT | seq`. Tag 0 (plain small fds) is the
/// shared fallback table, which keeps legacy fd numbering byte-identical
/// on unsharded environments.
const FD_SHARD_SHIFT: u32 = 32;

struct OpenFile {
    path: String,
    pos: usize,
    writable: bool,
}

/// Per-key shard count of the file *content* map. A fixed power of two:
/// the shard is picked by hashing the file path, so writers to distinct
/// files (almost always distinct shards) never touch the same lock —
/// unlike the open-handle tables, which shard per serving *lane*.
pub const CONTENT_SHARDS: usize = 16;

/// One shard of the file-content map with its own lock and contention
/// counter.
#[derive(Default)]
struct ContentShard {
    map: Mutex<HashMap<String, Vec<u8>>>,
    contended: AtomicU64,
    /// Time spent blocked on this shard's lock (contended path only).
    wait: crate::obs::Hist,
}

/// The per-file-key sharded content map behind `HostEnv`'s in-memory
/// filesystem. PR 2 sharded only the open-handle tables; this removes
/// the last global lock on the host I/O path — concurrent writers to
/// distinct files proceed in parallel, same-file writers serialize on
/// one shard.
struct ContentMap {
    shards: Vec<ContentShard>,
}

impl ContentMap {
    fn new() -> Self {
        Self { shards: (0..CONTENT_SHARDS).map(|_| ContentShard::default()).collect() }
    }

    /// Which shard holds `path`: FNV-1a placement, deterministic across
    /// runs (std's seeded `RandomState` would make contention tests
    /// flaky). Exposed through [`HostEnv::content_shard_of`] so tests
    /// can pick paths in distinct shards.
    fn shard_of(path: &str) -> usize {
        (crate::util::fnv1a(path) % CONTENT_SHARDS as u64) as usize
    }

    /// Lock the shard holding `path`, counting acquisitions that had to
    /// wait (the per-shard lock-contention metric) and recovering from
    /// poisoned locks (`recoveries`).
    fn lock(
        &self,
        path: &str,
        recoveries: &AtomicU64,
    ) -> MutexGuard<'_, HashMap<String, Vec<u8>>> {
        let shard = &self.shards[Self::shard_of(path)];
        match shard.map.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(poisoned)) => {
                recoveries.fetch_add(1, Ordering::Relaxed);
                poisoned.into_inner()
            }
            Err(TryLockError::WouldBlock) => {
                shard.contended.fetch_add(1, Ordering::Relaxed);
                let t0 = std::time::Instant::now();
                let g = lock_or_recover(&shard.map, recoveries);
                shard.wait.record(t0.elapsed().as_nanos() as u64);
                g
            }
        }
    }

    fn contention(&self) -> u64 {
        self.shards.iter().map(|s| s.contended.load(Ordering::Relaxed)).sum()
    }
}

/// One open-file table: a shard of [`HostEnv`]'s fd space with its own
/// lock and contention counters.
#[derive(Default)]
struct FdTable {
    open: Mutex<HashMap<u64, OpenFile>>,
    opens: AtomicU64,
    contended: AtomicU64,
    /// Time spent blocked on this table's lock (contended path only).
    wait: crate::obs::Hist,
}

impl FdTable {
    /// Lock the table, counting the acquisitions that had to wait (the
    /// per-shard lock-contention metric) and recovering from poisoned
    /// locks (`recoveries`).
    fn lock(&self, recoveries: &AtomicU64) -> MutexGuard<'_, HashMap<u64, OpenFile>> {
        match self.open.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(poisoned)) => {
                recoveries.fetch_add(1, Ordering::Relaxed);
                poisoned.into_inner()
            }
            Err(TryLockError::WouldBlock) => {
                self.contended.fetch_add(1, Ordering::Relaxed);
                let t0 = std::time::Instant::now();
                let g = lock_or_recover(&self.open, recoveries);
                self.wait.record(t0.elapsed().as_nanos() as u64);
                g
            }
        }
    }
}

/// Copyable aggregate of [`HostEnv`]'s file-table shard counters for
/// `RunMetrics`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HostIoSnapshot {
    /// Per-lane shard count (0 = unsharded: shared table only).
    pub shards: usize,
    /// `fopen`s placed in per-lane shards.
    pub sharded_opens: u64,
    /// `fopen`s that fell back to the shared table (no lane context).
    pub shared_opens: u64,
    /// Lock acquisitions that had to wait, summed over every
    /// open-handle table.
    pub lock_contention: u64,
    /// Per-file-key shard count of the content map
    /// ([`CONTENT_SHARDS`]).
    pub content_shards: usize,
    /// Content-map lock acquisitions that had to wait, summed over
    /// every shard (0 ⇒ concurrent file traffic never collided).
    pub content_contention: u64,
    /// Poisoned-lock recoveries: a landing pad panicked while holding a
    /// `HostEnv` lock and a later RPC recovered the inner guard instead
    /// of cascading the panic.
    pub poison_recoveries: u64,
    /// Writes committed through the batched `fwrite` landing pad
    /// (engine per-sweep coalescing; each counts one frame).
    pub batched_writes: u64,
    /// Reads served through the batched `fread` landing pad
    /// (engine per-sweep coalescing; each counts one frame).
    pub batched_reads: u64,
    /// Frames that joined a batch run **across a callee boundary**: the
    /// engine's sweep grouping merged consecutive `fwrite`/`fread` pad
    /// runs because they target the same stream, even though the callees
    /// differ (subset of `batched_writes + batched_reads`).
    pub batched_cross_callee: u64,
}

/// Host process state backing the landing pads: an in-memory filesystem,
/// captured standard streams, environment variables, a monotonic clock and
/// the kernel-split launch hook (paper §3.3).
///
/// The open-file table is **sharded per serving lane**
/// ([`HostEnv::with_shards`]): `fopen` served on lane L places the
/// handle in shard `L % shards` and tags the returned fd with its shard,
/// so any later access — including from another lane (cross-lane
/// handles) — resolves the owning table straight from the fd without
/// touching the other shards' locks. Opens with no lane context (the
/// legacy single-threaded server, direct host calls) use the shared
/// fallback table, whose fd numbering is byte-identical to the
/// pre-sharding implementation.
///
/// The file *content* map is additionally **sharded per file key**
/// ([`CONTENT_SHARDS`], path-hash placement): writers to distinct files
/// take distinct locks, so a session writing `a.txt` never waits on a
/// session streaming `b.txt`. Same-file access serializes on one shard,
/// preserving write ordering.
pub struct HostEnv {
    /// Per-file-key sharded content map (the in-memory filesystem).
    files: ContentMap,
    /// Shared fallback open-file table (tag 0; legacy fd numbering).
    shared: FdTable,
    /// Per-lane open-file shards; empty = unsharded.
    shards: Vec<FdTable>,
    next_fd: AtomicU64,
    pub stdout: Mutex<Vec<u8>>,
    pub stderr: Mutex<Vec<u8>>,
    pub exited: Mutex<Option<i32>>,
    env_vars: Mutex<HashMap<String, String>>,
    clock_ns: AtomicU64,
    /// Poisoned-lock recoveries across every `HostEnv` lock (a panicked
    /// wrapper no longer condemns later RPCs — see [`lock_or_recover`]).
    poison_recoveries: AtomicU64,
    /// Frames committed through the batched `fwrite` landing pad.
    batched_writes: AtomicU64,
    /// Frames served through the batched `fread` landing pad.
    batched_reads: AtomicU64,
    /// Frames batched across a callee boundary (same-stream merge).
    batched_cross_callee: AtomicU64,
    /// Kernel-split hook: `(region_id, arg_ptr) -> ret`. The coordinator
    /// installs a closure that launches the multi-team parallel kernel.
    #[allow(clippy::type_complexity)]
    pub region_launcher: Mutex<Option<Box<dyn Fn(u64, u64) -> i64 + Send + Sync>>>,
}

impl Default for HostEnv {
    fn default() -> Self {
        Self::new()
    }
}

impl HostEnv {
    /// Unsharded host environment (shared open-file table only) — the
    /// legacy shape, byte-identical fd numbering included.
    pub fn new() -> Self {
        Self::with_shards(0)
    }

    /// Host environment with `shards` per-lane open-file tables (the
    /// loader passes the engine's lane count). `0` disables sharding.
    pub fn with_shards(shards: usize) -> Self {
        Self {
            files: ContentMap::new(),
            shared: FdTable::default(),
            shards: (0..shards).map(|_| FdTable::default()).collect(),
            next_fd: AtomicU64::new(16),
            stdout: Mutex::new(Vec::new()),
            stderr: Mutex::new(Vec::new()),
            exited: Mutex::new(None),
            env_vars: Mutex::new(HashMap::new()),
            clock_ns: AtomicU64::new(1_700_000_000_000_000_000),
            poison_recoveries: AtomicU64::new(0),
            batched_writes: AtomicU64::new(0),
            batched_reads: AtomicU64::new(0),
            batched_cross_callee: AtomicU64::new(0),
            region_launcher: Mutex::new(None),
        }
    }

    /// Resolve the table an fd lives in from its shard tag. `None` for
    /// fds carrying a tag no shard backs (stale/forged handles).
    fn table_for(&self, fd: u64) -> Option<&FdTable> {
        match (fd >> FD_SHARD_SHIFT) as usize {
            0 => Some(&self.shared),
            tag => self.shards.get(tag - 1),
        }
    }

    /// File-table shard counters (engine `RunMetrics`).
    pub fn io_snapshot(&self) -> HostIoSnapshot {
        let r = Ordering::Relaxed;
        HostIoSnapshot {
            shards: self.shards.len(),
            sharded_opens: self.shards.iter().map(|s| s.opens.load(r)).sum(),
            shared_opens: self.shared.opens.load(r),
            lock_contention: self.shared.contended.load(r)
                + self.shards.iter().map(|s| s.contended.load(r)).sum::<u64>(),
            content_shards: CONTENT_SHARDS,
            content_contention: self.files.contention(),
            poison_recoveries: self.poison_recoveries.load(r),
            batched_writes: self.batched_writes.load(r),
            batched_reads: self.batched_reads.load(r),
            batched_cross_callee: self.batched_cross_callee.load(r),
        }
    }

    /// Merged histogram of the time landing pads spent **blocked** on
    /// `HostEnv` lock acquisitions that had to wait — every open-handle
    /// table plus every content-map shard. Empty while
    /// [`HostIoSnapshot::lock_contention`] and
    /// [`HostIoSnapshot::content_contention`] are both 0 (the fast
    /// `try_lock` path records nothing).
    pub fn io_lock_wait(&self) -> crate::obs::HistSnapshot {
        let mut snap = self.shared.wait.snapshot();
        for t in &self.shards {
            snap = snap.merge(&t.wait.snapshot());
        }
        for s in &self.files.shards {
            snap = snap.merge(&s.wait.snapshot());
        }
        snap
    }

    /// Per-shard lock-contention counts (index = shard; shared fallback
    /// table excluded).
    pub fn shard_contention(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.contended.load(Ordering::Relaxed)).collect()
    }

    /// Which content-map shard `path` lives in (deterministic; lets
    /// tests choose paths with disjoint — or colliding — shards).
    pub fn content_shard_of(path: &str) -> usize {
        ContentMap::shard_of(path)
    }

    /// Total content-map lock acquisitions that had to wait. Stays 0
    /// while concurrent traffic only ever touches distinct shards.
    pub fn content_contention(&self) -> u64 {
        self.files.contention()
    }

    pub fn put_file(&self, path: &str, content: &[u8]) {
        self.files
            .lock(path, &self.poison_recoveries)
            .insert(path.to_string(), content.to_vec());
    }

    pub fn file(&self, path: &str) -> Option<Vec<u8>> {
        self.files.lock(path, &self.poison_recoveries).get(path).cloned()
    }

    pub fn set_env(&self, k: &str, v: &str) {
        lock_or_recover(&self.env_vars, &self.poison_recoveries)
            .insert(k.to_string(), v.to_string());
    }

    pub fn stdout_string(&self) -> String {
        String::from_utf8_lossy(&lock_or_recover(&self.stdout, &self.poison_recoveries))
            .into_owned()
    }

    pub fn stderr_string(&self) -> String {
        String::from_utf8_lossy(&lock_or_recover(&self.stderr, &self.poison_recoveries))
            .into_owned()
    }

    /// Record `frames` committed through a batched write pad.
    fn count_batched_writes(&self, frames: u64) {
        self.batched_writes.fetch_add(frames, Ordering::Relaxed);
    }

    /// Record `frames` served through a batched read pad.
    fn count_batched_reads(&self, frames: u64) {
        self.batched_reads.fetch_add(frames, Ordering::Relaxed);
    }

    /// Record `frames` that joined a batch run **across a callee
    /// boundary** (the engine's cross-callee same-stream merge).
    pub(crate) fn count_batched_cross_callee(&self, frames: u64) {
        self.batched_cross_callee.fetch_add(frames, Ordering::Relaxed);
    }

    fn write_stream(&self, fd: u64, bytes: &[u8]) -> i64 {
        match fd {
            FD_STDOUT => lock_or_recover(&self.stdout, &self.poison_recoveries)
                .extend_from_slice(bytes),
            FD_STDERR => lock_or_recover(&self.stderr, &self.poison_recoveries)
                .extend_from_slice(bytes),
            fd => {
                let Some(table) = self.table_for(fd) else { return -1 };
                let mut open = table.lock(&self.poison_recoveries);
                let Some(of) = open.get_mut(&fd) else { return -1 };
                if !of.writable {
                    return -1;
                }
                let mut files = self.files.lock(&of.path, &self.poison_recoveries);
                let content = files.entry(of.path.clone()).or_default();
                write_at(content, of, bytes);
            }
        }
        bytes.len() as i64
    }

    /// Batched stream/file append: items commit **in order**, with lock
    /// acquisitions amortized over runs of consecutive same-fd items —
    /// a run to a standard stream takes that stream's lock once, and a
    /// run to a file fd resolves its open-handle table and content
    /// shard once instead of once per call. This is the host-side win
    /// of the engine's coalesced printf/fwrite dispatch; results are
    /// identical to calling [`write_stream`](Self::write_stream) per
    /// item.
    pub fn write_stream_many(&self, items: &[(u64, Vec<u8>)]) -> Vec<i64> {
        let mut rets = Vec::with_capacity(items.len());
        let mut i = 0;
        while i < items.len() {
            let fd = items[i].0;
            let mut j = i + 1;
            while j < items.len() && items[j].0 == fd {
                j += 1;
            }
            let run = &items[i..j];
            match fd {
                FD_STDOUT | FD_STDERR => {
                    let stream = if fd == FD_STDOUT { &self.stdout } else { &self.stderr };
                    let mut guard = lock_or_recover(stream, &self.poison_recoveries);
                    for (_, bytes) in run {
                        guard.extend_from_slice(bytes);
                        rets.push(bytes.len() as i64);
                    }
                }
                fd => match self.table_for(fd) {
                    None => rets.extend(run.iter().map(|_| -1)),
                    Some(table) => {
                        let mut open = table.lock(&self.poison_recoveries);
                        match open.get_mut(&fd) {
                            Some(of) if of.writable => {
                                let mut files =
                                    self.files.lock(&of.path, &self.poison_recoveries);
                                let content = files.entry(of.path.clone()).or_default();
                                for (_, bytes) in run {
                                    write_at(content, of, bytes);
                                    rets.push(bytes.len() as i64);
                                }
                            }
                            _ => rets.extend(run.iter().map(|_| -1)),
                        }
                    }
                },
            }
            i = j;
        }
        rets
    }

    fn read_stream(&self, fd: u64, out: &mut [u8]) -> i64 {
        let Some(table) = self.table_for(fd) else { return -1 };
        let mut open = table.lock(&self.poison_recoveries);
        let Some(of) = open.get_mut(&fd) else { return -1 };
        let files = self.files.lock(&of.path, &self.poison_recoveries);
        let Some(content) = files.get(&of.path) else { return -1 };
        let avail = content.len().saturating_sub(of.pos);
        let n = avail.min(out.len());
        out[..n].copy_from_slice(&content[of.pos..of.pos + n]);
        of.pos += n;
        n as i64
    }

    /// Batched stream read, the symmetric twin of
    /// [`write_stream_many`](Self::write_stream_many): items fill **in
    /// order**, with handle-table and content-shard lock acquisitions
    /// amortized over runs of consecutive same-fd items. Each item
    /// advances the handle's shared position exactly like a scalar
    /// [`read_stream`](Self::read_stream) call would, so a short file
    /// splits across the items byte-identically to scalar dispatch.
    pub fn read_stream_many(&self, items: &mut [(u64, &mut [u8])]) -> Vec<i64> {
        let mut rets = Vec::with_capacity(items.len());
        let mut i = 0;
        while i < items.len() {
            let fd = items[i].0;
            let mut j = i + 1;
            while j < items.len() && items[j].0 == fd {
                j += 1;
            }
            let run = &mut items[i..j];
            match self.table_for(fd) {
                None => rets.extend(run.iter().map(|_| -1)),
                Some(table) => {
                    let mut open = table.lock(&self.poison_recoveries);
                    match open.get_mut(&fd) {
                        None => rets.extend(run.iter().map(|_| -1)),
                        Some(of) => {
                            let files = self.files.lock(&of.path, &self.poison_recoveries);
                            match files.get(&of.path) {
                                None => rets.extend(run.iter().map(|_| -1)),
                                Some(content) => {
                                    for (_, out) in run.iter_mut() {
                                        let avail = content.len().saturating_sub(of.pos);
                                        let n = avail.min(out.len());
                                        out[..n].copy_from_slice(
                                            &content[of.pos..of.pos + n],
                                        );
                                        of.pos += n;
                                        rets.push(n as i64);
                                    }
                                }
                            }
                        }
                    }
                }
            }
            i = j;
        }
        rets
    }

    fn fopen(&self, path: &str, mode: &str) -> i64 {
        let writable = mode.starts_with('w') || mode.starts_with('a');
        {
            let mut files = self.files.lock(path, &self.poison_recoveries);
            if writable && mode.starts_with('w') {
                files.insert(path.to_string(), Vec::new());
            } else if !files.contains_key(path) {
                return 0; // NULL
            }
        }
        let pos = if mode.starts_with('a') {
            self.files
                .lock(path, &self.poison_recoveries)
                .get(path)
                .map(|c| c.len())
                .unwrap_or(0)
        } else {
            0
        };
        // Place the handle in the serving lane's shard when one exists;
        // the fd's tag records the table for all later accesses.
        let seq = self.next_fd.fetch_add(1, Ordering::Relaxed);
        let (table, fd) = match current_lane() {
            Some(lane) if !self.shards.is_empty() => {
                let shard = lane % self.shards.len();
                (&self.shards[shard], ((shard as u64 + 1) << FD_SHARD_SHIFT) | seq)
            }
            _ => (&self.shared, seq),
        };
        table.opens.fetch_add(1, Ordering::Relaxed);
        table
            .lock(&self.poison_recoveries)
            .insert(fd, OpenFile { path: path.to_string(), pos, writable });
        fd as i64
    }

    fn fclose(&self, fd: u64) -> i64 {
        match self.table_for(fd) {
            Some(table) if table.lock(&self.poison_recoveries).remove(&fd).is_some() => 0,
            _ => -1,
        }
    }

    /// `fscanf`-style consumption: read from the current position,
    /// returning the consumed text for the scanner.
    fn remaining(&self, fd: u64) -> String {
        let Some(table) = self.table_for(fd) else { return String::new() };
        let open = table.lock(&self.poison_recoveries);
        let Some(of) = open.get(&fd) else { return String::new() };
        let files = self.files.lock(&of.path, &self.poison_recoveries);
        files
            .get(&of.path)
            .map(|c| String::from_utf8_lossy(&c[of.pos.min(c.len())..]).into_owned())
            .unwrap_or_default()
    }

    fn advance(&self, fd: u64, by: usize) {
        if let Some(table) = self.table_for(fd) {
            if let Some(of) = table.lock(&self.poison_recoveries).get_mut(&fd) {
                of.pos += by;
            }
        }
    }
}

/// Overwrite-at-position write of `bytes` into `content` at the
/// handle's position, growing (zero-filled) as needed and advancing the
/// position — the one committed-write primitive [`HostEnv::write_stream`]
/// and the batched [`HostEnv::write_stream_many`] share.
fn write_at(content: &mut Vec<u8>, of: &mut OpenFile, bytes: &[u8]) {
    if of.pos > content.len() {
        content.resize(of.pos, 0);
    }
    let end = of.pos + bytes.len();
    if end > content.len() {
        content.resize(end, 0);
    }
    content[of.pos..end].copy_from_slice(bytes);
    of.pos = end;
}

// ---- the C format machinery (printf/scanf subset the benchmarks use) ----

/// Conversions the format machinery could not honor and degraded to
/// their literal text instead of aborting the run (glibc prints unknown
/// conversions literally). Covers unsupported `%` specifiers in
/// [`parse_format`] and argument/conversion mismatches in the device
/// `snprintf` ([`crate::libc_gpu::stdio`]).
static FORMAT_WARNINGS: AtomicU64 = AtomicU64::new(0);

/// Total format degradations so far (process-wide, monotonic).
pub fn format_warnings() -> u64 {
    FORMAT_WARNINGS.load(Ordering::Relaxed)
}

/// Record one degraded conversion (also used by the device-side
/// `snprintf` on argument/conversion mismatches). The flat counter is
/// the stable delta-based API; the process-global event log adds the
/// warn-once diagnostic and per-code count for telemetry export.
pub fn count_format_warning() {
    FORMAT_WARNINGS.fetch_add(1, Ordering::Relaxed);
    crate::obs::event::global().emit(
        crate::obs::Level::Warn,
        "format-conversion",
        "",
        "unsupported format conversion degraded to its literal text",
    );
}

/// One parsed `%` conversion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Conv {
    Int,
    Uint,
    Hex,
    Float,
    Str,
    Char,
    Percent,
}

/// Split a C format string into literal runs and conversions. Width and
/// precision are parsed (and applied for floats) but length modifiers are
/// accepted and ignored — device ints are 64-bit anyway.
///
/// Unsupported conversions (`%q`, a trailing `%`, ...) degrade
/// glibc-style: the conversion's literal text is emitted unchanged and a
/// process-wide warning counter ([`format_warnings`]) is bumped — a bad
/// format string in one call never aborts the whole run.
pub fn parse_format(fmt: &str) -> Vec<(String, Option<(Conv, Option<usize>, Option<usize>)>)> {
    let mut out = Vec::new();
    let mut lit = String::new();
    let bytes: Vec<char> = fmt.chars().collect();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] != '%' {
            lit.push(bytes[i]);
            i += 1;
            continue;
        }
        let pct_start = i;
        i += 1;
        // flags/width
        let mut width = String::new();
        while i < bytes.len() && (bytes[i].is_ascii_digit() || "-+ 0".contains(bytes[i])) {
            if bytes[i].is_ascii_digit() {
                width.push(bytes[i]);
            }
            i += 1;
        }
        let mut prec = None;
        if i < bytes.len() && bytes[i] == '.' {
            i += 1;
            let mut p = String::new();
            while i < bytes.len() && bytes[i].is_ascii_digit() {
                p.push(bytes[i]);
                i += 1;
            }
            prec = p.parse().ok();
        }
        while i < bytes.len() && "lhzjt".contains(bytes[i]) {
            i += 1;
        }
        let conv = match bytes.get(i) {
            Some('d') | Some('i') => Conv::Int,
            Some('u') => Conv::Uint,
            Some('x') | Some('X') => Conv::Hex,
            Some('f') | Some('e') | Some('g') | Some('E') | Some('G') => Conv::Float,
            Some('s') => Conv::Str,
            Some('c') => Conv::Char,
            Some('%') => Conv::Percent,
            other => {
                // Unsupported conversion: emit its literal text
                // (including the consumed flags/width/length chars) and
                // keep going instead of aborting the run.
                count_format_warning();
                let end = if other.is_some() { i + 1 } else { i };
                lit.extend(&bytes[pct_start..end.min(bytes.len())]);
                i = end;
                continue;
            }
        };
        i += 1;
        out.push((std::mem::take(&mut lit), Some((conv, width.parse().ok(), prec))));
    }
    if !lit.is_empty() {
        out.push((lit, None));
    }
    out
}

/// Render `fmt` pulling conversion arguments from the frame starting at
/// `first_arg`.
pub fn format_c(frame: &RpcFrame, fmt: &str, first_arg: usize) -> String {
    let mut out = String::new();
    let mut ai = first_arg;
    for (lit, conv) in parse_format(fmt) {
        out.push_str(&lit);
        let Some((conv, width, prec)) = conv else { continue };
        let rendered = match conv {
            Conv::Percent => "%".to_string(),
            Conv::Int => (frame.val(ai) as i64).to_string(),
            Conv::Uint => frame.val(ai).to_string(),
            Conv::Hex => format!("{:x}", frame.val(ai)),
            Conv::Float => {
                let v = f64::from_bits(frame.val(ai));
                match prec {
                    Some(p) => format!("{v:.p$}"),
                    None => format!("{v:.6}"),
                }
            }
            Conv::Str => frame.cstr(ai),
            Conv::Char => char::from_u32(frame.val(ai) as u32).unwrap_or('?').to_string(),
        };
        if conv != Conv::Percent {
            ai += 1;
        }
        match width {
            Some(w) if rendered.len() < w => {
                out.push_str(&" ".repeat(w - rendered.len()));
                out.push_str(&rendered);
            }
            _ => out.push_str(&rendered),
        }
    }
    out
}

/// `sscanf` over `input` guided by `fmt`, writing results into the frame's
/// out-pointer args starting at `first_arg`. Returns (#converted, bytes
/// consumed).
pub fn scan_c(frame: &mut RpcFrame, input: &str, fmt: &str, first_arg: usize) -> (i64, usize) {
    let mut ai = first_arg;
    let mut converted = 0i64;
    let bytes = input.as_bytes();
    let mut pos = 0usize;
    let skip_ws = |pos: &mut usize| {
        while *pos < bytes.len() && (bytes[*pos] as char).is_whitespace() {
            *pos += 1;
        }
    };
    for (lit, conv) in parse_format(fmt) {
        for c in lit.chars() {
            if c.is_whitespace() {
                skip_ws(&mut pos);
            } else {
                if pos >= bytes.len() || bytes[pos] as char != c {
                    return (converted, pos);
                }
                pos += 1;
            }
        }
        let Some((conv, _, _)) = conv else { continue };
        skip_ws(&mut pos);
        let start = pos;
        match conv {
            Conv::Int | Conv::Uint | Conv::Hex => {
                if pos < bytes.len() && (bytes[pos] == b'-' || bytes[pos] == b'+') {
                    pos += 1;
                }
                while pos < bytes.len() && bytes[pos].is_ascii_digit() {
                    pos += 1;
                }
                let Ok(v) = input[start..pos].parse::<i64>() else {
                    return (converted, start);
                };
                frame.write_i32(ai, v as i32);
                ai += 1;
                converted += 1;
            }
            Conv::Float => {
                if pos < bytes.len() && (bytes[pos] == b'-' || bytes[pos] == b'+') {
                    pos += 1;
                }
                while pos < bytes.len()
                    && (bytes[pos].is_ascii_digit()
                        || bytes[pos] == b'.'
                        || bytes[pos] == b'e'
                        || bytes[pos] == b'E'
                        || ((bytes[pos] == b'-' || bytes[pos] == b'+')
                            && pos > start
                            && (bytes[pos - 1] == b'e' || bytes[pos - 1] == b'E')))
                {
                    pos += 1;
                }
                let Ok(v) = input[start..pos].parse::<f64>() else {
                    return (converted, start);
                };
                // Width of the out slot decides f32 vs f64.
                if frame.bytes(ai).len() >= 8 {
                    frame.write_f64(ai, v);
                } else {
                    frame.write_f32(ai, v as f32);
                }
                ai += 1;
                converted += 1;
            }
            Conv::Str => {
                while pos < bytes.len() && !(bytes[pos] as char).is_whitespace() {
                    pos += 1;
                }
                if pos == start {
                    return (converted, start);
                }
                let s = &input[start..pos];
                let buf = frame.bytes_mut(ai);
                let n = s.len().min(buf.len().saturating_sub(1));
                buf[..n].copy_from_slice(&s.as_bytes()[..n]);
                buf[n] = 0;
                ai += 1;
                converted += 1;
            }
            Conv::Char => {
                if pos >= bytes.len() {
                    return (converted, pos);
                }
                frame.bytes_mut(ai)[0] = bytes[pos];
                pos += 1;
                ai += 1;
                converted += 1;
            }
            Conv::Percent => {
                if pos >= bytes.len() || bytes[pos] != b'%' {
                    return (converted, pos);
                }
                pos += 1;
            }
        }
    }
    (converted, pos)
}

// ---- host function models for synthesis ----

/// What the RPC pass knows about a host library function: enough to
/// synthesize a landing pad for any call-site signature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostFnKind {
    /// `fprintf(FILE*, fmt, ...)` / `printf(fmt, ...)`.
    Printf { has_fd: bool },
    /// `fscanf(FILE*, fmt, &outs...)` / `sscanf`-like.
    Scanf { has_fd: bool },
    Fopen,
    Fclose,
    Fread,
    Fwrite,
    Puts,
    Exit,
    Time,
    Getenv,
    /// Kernel-split launch: `(region_id, arg_ptr)`.
    LaunchKernel,
}

/// The library-knowledge table the pass consults (the reproduction's
/// stand-in for annotated headers / libc knowledge in LLVM) — the
/// host-RPC half of the `libcres` resolution table (the device-native
/// half lives in [`crate::libc_gpu::registry`]). The single source both
/// [`host_function`] and name listings derive from.
pub const HOST_FUNCTIONS: &[(&str, HostFnKind)] = &[
    ("printf", HostFnKind::Printf { has_fd: false }),
    ("fprintf", HostFnKind::Printf { has_fd: true }),
    ("scanf", HostFnKind::Scanf { has_fd: false }),
    ("fscanf", HostFnKind::Scanf { has_fd: true }),
    ("fopen", HostFnKind::Fopen),
    ("fclose", HostFnKind::Fclose),
    ("fread", HostFnKind::Fread),
    ("fwrite", HostFnKind::Fwrite),
    ("puts", HostFnKind::Puts),
    ("exit", HostFnKind::Exit),
    ("time", HostFnKind::Time),
    ("getenv", HostFnKind::Getenv),
    ("__gpu_first_launch_kernel", HostFnKind::LaunchKernel),
];

/// Look up `name` in [`HOST_FUNCTIONS`].
pub fn host_function(name: &str) -> Option<HostFnKind> {
    HOST_FUNCTIONS.iter().find(|(n, _)| *n == name).map(|(_, k)| *k)
}

/// Synthesize the landing pad for `kind`.
pub fn synthesize(kind: HostFnKind) -> WrapperFn {
    match kind {
        HostFnKind::Printf { has_fd } => Box::new(move |f, env| {
            let (fd, fmt_i) = if has_fd { (f.val(0), 1) } else { (FD_STDOUT, 0) };
            let fmt = f.cstr(fmt_i);
            let s = format_c(f, &fmt, fmt_i + 1);
            env.write_stream(fd, s.as_bytes())
        }),
        HostFnKind::Scanf { has_fd } => Box::new(move |f, env| {
            let (fd, fmt_i) = if has_fd { (f.val(0), 1) } else { (FD_STDIN, 0) };
            let fmt = f.cstr(fmt_i);
            let input = env.remaining(fd);
            let (n, consumed) = scan_c(f, &input, &fmt, fmt_i + 1);
            env.advance(fd, consumed);
            n
        }),
        HostFnKind::Fopen => Box::new(|f, env| {
            let path = f.cstr(0);
            let mode = f.cstr(1);
            env.fopen(&path, &mode)
        }),
        HostFnKind::Fclose => Box::new(|f, env| env.fclose(f.val(0))),
        HostFnKind::Fread => Box::new(|f, env| {
            // fread(buf, size, count, fd)
            let size = f.val(1) as usize;
            let count = f.val(2) as usize;
            let fd = f.val(3);
            let buf = f.bytes_mut(0);
            let want = (size * count).min(buf.len());
            let n = env.read_stream(fd, &mut buf[..want]);
            if n < 0 || size == 0 {
                0
            } else {
                n / size as i64
            }
        }),
        HostFnKind::Fwrite => Box::new(|f, env| {
            let size = f.val(1) as usize;
            let count = f.val(2) as usize;
            let fd = f.val(3);
            // Guest-controlled size×count: clamp to the staged object
            // (rpcgen sizes the ref from the underlying object) so an
            // oversized request is a short write, never a slice panic
            // that would kill the serving worker.
            let want = size.saturating_mul(count).min(f.bytes(0).len());
            let data = f.bytes(0)[..want].to_vec();
            let n = env.write_stream(fd, &data);
            if n < 0 || size == 0 {
                0
            } else {
                n / size as i64
            }
        }),
        HostFnKind::Puts => Box::new(|f, env| {
            let mut s = f.cstr(0);
            s.push('\n');
            env.write_stream(FD_STDOUT, s.as_bytes())
        }),
        HostFnKind::Exit => Box::new(|f, env| {
            *lock_or_recover(&env.exited, &env.poison_recoveries) = Some(f.val(0) as i32);
            0
        }),
        HostFnKind::Time => Box::new(|_, env| {
            (env.clock_ns.fetch_add(1_000_000, Ordering::Relaxed) / 1_000_000_000) as i64
        }),
        HostFnKind::Getenv => Box::new(|f, env| {
            let k = f.cstr(0);
            let vars = lock_or_recover(&env.env_vars, &env.poison_recoveries);
            match vars.get(&k) {
                Some(v) => {
                    let buf = f.bytes_mut(1);
                    let n = v.len().min(buf.len() - 1);
                    buf[..n].copy_from_slice(&v.as_bytes()[..n]);
                    buf[n] = 0;
                    1
                }
                None => 0,
            }
        }),
        HostFnKind::LaunchKernel => Box::new(|f, env| {
            let region = f.val(0);
            let arg = f.val(1);
            let launcher = lock_or_recover(&env.region_launcher, &env.poison_recoveries);
            match launcher.as_ref() {
                Some(l) => l(region, arg),
                None => -1,
            }
        }),
    }
}

/// Synthesize the *batched* landing pad for `kind`, if one exists.
///
/// Only callees whose host effect is an order-preserving stream access
/// benefit: the printf family and `puts` render every frame, and
/// `fwrite` stages every frame's payload, then the whole batch commits
/// through [`HostEnv::write_stream_many`]; `fread` stages every frame's
/// destination buffer and fills the batch through
/// [`HostEnv::read_stream_many`]. In both directions, runs of same-fd
/// items amortize the stream/file lock acquisitions to one per run
/// instead of one per call. Stateful callees (fopen/fscanf/...) return
/// `None` and keep their scalar pads — the engine then amortizes only
/// the registry dispatch.
pub fn synthesize_batch(kind: HostFnKind) -> Option<BatchWrapperFn> {
    match kind {
        HostFnKind::Printf { has_fd } => Some(Box::new(move |frames, env| {
            let rendered: Vec<(u64, Vec<u8>)> = frames
                .iter()
                .map(|f| {
                    let (fd, fmt_i) = if has_fd { (f.val(0), 1) } else { (FD_STDOUT, 0) };
                    let fmt = f.cstr(fmt_i);
                    (fd, format_c(f, &fmt, fmt_i + 1).into_bytes())
                })
                .collect();
            env.write_stream_many(&rendered)
        })),
        HostFnKind::Puts => Some(Box::new(|frames, env| {
            let rendered: Vec<(u64, Vec<u8>)> = frames
                .iter()
                .map(|f| {
                    let mut s = f.cstr(0);
                    s.push('\n');
                    (FD_STDOUT, s.into_bytes())
                })
                .collect();
            env.write_stream_many(&rendered)
        })),
        HostFnKind::Fread => Some(Box::new(|frames, env| {
            // fread(buf, size, count, fd) per frame; same-fd runs of a
            // sweep fill under one handle+content lock acquisition.
            // The request clamps exactly like the scalar pad, and each
            // item advances the handle's shared position in frame
            // order, so the bytes landing in every buffer — and every
            // return value — are identical to scalar dispatch.
            let mut sizes = Vec::with_capacity(frames.len());
            let mut staged: Vec<(u64, &mut [u8])> = Vec::with_capacity(frames.len());
            for f in frames.iter_mut() {
                let size = f.val(1) as usize;
                let count = f.val(2) as usize;
                let fd = f.val(3);
                sizes.push(size as i64);
                let buf = f.bytes_mut(0);
                let want = (size * count).min(buf.len());
                staged.push((fd, &mut buf[..want]));
            }
            let ns = env.read_stream_many(&mut staged);
            // Only frames that actually filled count as batched.
            env.count_batched_reads(ns.iter().filter(|&&n| n >= 0).count() as u64);
            sizes
                .iter()
                .zip(ns)
                .map(|(&size, n)| {
                    // Item-return semantics identical to the scalar pad.
                    if n < 0 || size == 0 {
                        0
                    } else {
                        n / size
                    }
                })
                .collect()
        })),
        HostFnKind::Fwrite => Some(Box::new(|frames, env| {
            // fwrite(buf, size, count, fd) per frame; same-fd runs of a
            // sweep commit under one handle+content lock acquisition.
            // size×count clamps to the staged object exactly like the
            // scalar pad (short write, never a worker-killing panic).
            let staged: Vec<(u64, Vec<u8>)> = frames
                .iter()
                .map(|f| {
                    let size = f.val(1) as usize;
                    let count = f.val(2) as usize;
                    let want = size.saturating_mul(count).min(f.bytes(0).len());
                    (f.val(3), f.bytes(0)[..want].to_vec())
                })
                .collect();
            let ns = env.write_stream_many(&staged);
            // Only frames that actually committed count as batched.
            env.count_batched_writes(ns.iter().filter(|&&n| n >= 0).count() as u64);
            frames
                .iter()
                .zip(ns)
                .map(|(f, n)| {
                    let size = f.val(1) as i64;
                    // Item-return semantics identical to the scalar pad.
                    if n < 0 || size == 0 {
                        0
                    } else {
                        n / size
                    }
                })
                .collect()
        })),
        _ => None,
    }
}

/// Register the scalar pad for `(mangled, kind)` plus its batched
/// variant (when one exists), marking kernel-split launch pads in the
/// registry so the engine routes them to the dedicated launch executor.
/// Shared by [`register_common`] and the RPC generation pass.
pub fn register_pad(registry: &WrapperRegistry, mangled: &str, kind: HostFnKind) -> u64 {
    let id = registry.register(mangled, synthesize(kind));
    if let Some(batch) = synthesize_batch(kind) {
        registry.register_batch(mangled, batch);
    }
    match kind {
        // Stream pads share a frame layout per direction, so the engine
        // may merge their runs across callee boundaries.
        HostFnKind::Fwrite => {
            registry.mark_stream(mangled, StreamDir::Write);
        }
        HostFnKind::Fread => {
            registry.mark_stream(mangled, StreamDir::Read);
        }
        HostFnKind::LaunchKernel => {
            registry.mark_launch(mangled);
        }
        _ => {}
    }
    id
}

/// Register the canonical signatures the hand-written apps and tests use.
/// (IR programs get theirs registered by the RPC pass instead.)
pub fn register_common(registry: &WrapperRegistry) -> HashMap<&'static str, u64> {
    let mut ids = HashMap::new();
    for (mangled, kind) in [
        ("__fprintf_p_cp", HostFnKind::Printf { has_fd: true }),
        ("__fprintf_p_cp_cp", HostFnKind::Printf { has_fd: true }),
        ("__fprintf_p_cp_i", HostFnKind::Printf { has_fd: true }),
        ("__fprintf_p_cp_f", HostFnKind::Printf { has_fd: true }),
        ("__fprintf_p_cp_i_i", HostFnKind::Printf { has_fd: true }),
        ("__fprintf_p_cp_f_f", HostFnKind::Printf { has_fd: true }),
        ("__printf_cp", HostFnKind::Printf { has_fd: false }),
        ("__printf_cp_i", HostFnKind::Printf { has_fd: false }),
        ("__printf_cp_f", HostFnKind::Printf { has_fd: false }),
        ("__printf_cp_i_i", HostFnKind::Printf { has_fd: false }),
        ("__fscanf_p_cp_ip", HostFnKind::Scanf { has_fd: true }),
        ("__fscanf_p_cp_fp", HostFnKind::Scanf { has_fd: true }),
        ("__fscanf_p_cp_fp_ip_ip", HostFnKind::Scanf { has_fd: true }),
        ("__fopen_cp_cp", HostFnKind::Fopen),
        ("__fclose_p", HostFnKind::Fclose),
        ("__fread_vp_i_i_p", HostFnKind::Fread),
        ("__fwrite_vp_i_i_p", HostFnKind::Fwrite),
        ("__puts_cp", HostFnKind::Puts),
        ("__exit_i", HostFnKind::Exit),
        ("__time", HostFnKind::Time),
        ("__launch_kernel_i_i", HostFnKind::LaunchKernel),
    ] {
        ids.insert(mangled, register_pad(registry, mangled, kind));
    }
    ids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::server::HostArg;
    use crate::rpc::ArgMode;
    use std::sync::Arc;

    fn buf_arg(bytes: &[u8]) -> HostArg {
        HostArg::Buf { bytes: bytes.to_vec(), offset: 0, mode: ArgMode::ReadWrite }
    }

    #[test]
    fn host_function_table_is_duplicate_free_and_disjoint_from_device_libc() {
        let mut names: Vec<&str> = HOST_FUNCTIONS.iter().map(|(n, _)| *n).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), HOST_FUNCTIONS.len(), "duplicate host-function entry");
        // The two tables of the libcres dichotomy are disjoint: a symbol
        // is device-native or host-RPC, never both.
        for name in crate::libc_gpu::registry::names() {
            assert!(host_function(name).is_none(), "{name} is device-native AND host-RPC");
        }
    }

    fn cstr_arg(s: &str) -> HostArg {
        let mut b = s.as_bytes().to_vec();
        b.push(0);
        HostArg::Buf { bytes: b, offset: 0, mode: ArgMode::Read }
    }

    #[test]
    fn format_c_mixed() {
        let frame = RpcFrame {
            args: vec![
                cstr_arg("n=%d pi=%.2f s=%s %%"),
                HostArg::Val(42),
                HostArg::Val(std::f64::consts::PI.to_bits()),
                cstr_arg("str"),
            ],
        };
        let fmt = frame.cstr(0);
        assert_eq!(format_c(&frame, &fmt, 1), "n=42 pi=3.14 s=str %");
    }

    #[test]
    fn format_width_padding() {
        let frame = RpcFrame { args: vec![HostArg::Val(7)] };
        assert_eq!(format_c(&frame, "[%4d]", 0), "[   7]");
    }

    #[test]
    fn scan_c_fig3_shape() {
        // fscanf(fd, "%f %i %i", &s.f, &i, p) — the Fig. 3a call.
        let mut frame = RpcFrame {
            args: vec![buf_arg(&[0u8; 4]), buf_arg(&[0u8; 4]), buf_arg(&[0u8; 4])],
        };
        let (n, _) = scan_c(&mut frame, "2.5 -7 11", "%f %i %i", 0);
        assert_eq!(n, 3);
        assert_eq!(f32::from_le_bytes(frame.bytes(0)[..4].try_into().unwrap()), 2.5);
        assert_eq!(frame.read_i32(1), -7);
        assert_eq!(frame.read_i32(2), 11);
    }

    #[test]
    fn scan_c_partial_match() {
        let mut frame = RpcFrame { args: vec![buf_arg(&[0u8; 4]), buf_arg(&[0u8; 4])] };
        let (n, _) = scan_c(&mut frame, "5 oops", "%d %d", 0);
        assert_eq!(n, 1);
        assert_eq!(frame.read_i32(0), 5);
    }

    #[test]
    fn scan_c_string_and_literals() {
        let mut frame = RpcFrame { args: vec![buf_arg(&[0u8; 16])] };
        let (n, _) = scan_c(&mut frame, "name: xsbench", "name: %s", 0);
        assert_eq!(n, 1);
        let end = frame.bytes(0).iter().position(|&b| b == 0).unwrap();
        assert_eq!(&frame.bytes(0)[..end], b"xsbench");
    }

    #[test]
    fn hostenv_file_lifecycle() {
        let env = HostEnv::new();
        env.put_file("input.dat", b"1 2 3");
        let fd = env.fopen("input.dat", "r");
        assert!(fd > 2);
        let mut buf = [0u8; 3];
        assert_eq!(env.read_stream(fd as u64, &mut buf), 3);
        assert_eq!(&buf, b"1 2");
        assert_eq!(env.fclose(fd as u64), 0);
        assert_eq!(env.fopen("missing", "r"), 0);
    }

    #[test]
    fn hostenv_write_and_append() {
        let env = HostEnv::new();
        let fd = env.fopen("out.txt", "w") as u64;
        env.write_stream(fd, b"hello ");
        env.write_stream(fd, b"world");
        env.fclose(fd);
        assert_eq!(env.file("out.txt").unwrap(), b"hello world");
        let fd = env.fopen("out.txt", "a") as u64;
        env.write_stream(fd, b"!");
        assert_eq!(env.file("out.txt").unwrap(), b"hello world!");
    }

    #[test]
    fn printf_wrapper_writes_stderr() {
        let env = HostEnv::new();
        let w = synthesize(HostFnKind::Printf { has_fd: true });
        let mut frame = RpcFrame {
            args: vec![HostArg::Val(FD_STDERR), cstr_arg("fread reads: %s.\n"), cstr_arg("abc")],
        };
        let n = w(&mut frame, &env);
        assert_eq!(env.stderr_string(), "fread reads: abc.\n");
        assert_eq!(n, "fread reads: abc.\n".len() as i64);
    }

    #[test]
    fn exit_wrapper_records_code() {
        let env = HostEnv::new();
        let w = synthesize(HostFnKind::Exit);
        let mut frame = RpcFrame { args: vec![HostArg::Val(3)] };
        w(&mut frame, &env);
        assert_eq!(*env.exited.lock().unwrap(), Some(3));
    }

    #[test]
    fn launch_kernel_dispatches_to_hook() {
        let env = HostEnv::new();
        *env.region_launcher.lock().unwrap() = Some(Box::new(|r, a| (r * 100 + a) as i64));
        let w = synthesize(HostFnKind::LaunchKernel);
        let mut frame = RpcFrame { args: vec![HostArg::Val(4), HostArg::Val(7)] };
        assert_eq!(w(&mut frame, &env), 407);
    }

    #[test]
    fn register_common_is_idempotent() {
        let reg = WrapperRegistry::new();
        let a = register_common(&reg);
        let b = register_common(&reg);
        assert_eq!(a, b);
    }

    #[test]
    fn batch_printf_pad_matches_scalar_pads() {
        let env_scalar = HostEnv::new();
        let env_batch = HostEnv::new();
        let scalar = synthesize(HostFnKind::Printf { has_fd: true });
        let batch = synthesize_batch(HostFnKind::Printf { has_fd: true }).unwrap();
        let mk = |fd: u64, msg: &str| RpcFrame {
            args: vec![HostArg::Val(fd), cstr_arg("[%s]"), cstr_arg(msg)],
        };
        let mut frames = vec![mk(FD_STDOUT, "a"), mk(FD_STDERR, "b"), mk(FD_STDOUT, "c")];
        let batch_rets = batch(&mut frames, &env_batch);
        let scalar_rets: Vec<i64> = frames.iter_mut().map(|f| scalar(f, &env_scalar)).collect();
        assert_eq!(batch_rets, scalar_rets);
        assert_eq!(env_batch.stdout_string(), env_scalar.stdout_string());
        assert_eq!(env_batch.stderr_string(), env_scalar.stderr_string());
        assert_eq!(env_batch.stdout_string(), "[a][c]");
        assert_eq!(env_batch.stderr_string(), "[b]");
    }

    #[test]
    fn stateful_callees_have_no_batch_pad() {
        assert!(synthesize_batch(HostFnKind::Fopen).is_none());
        assert!(synthesize_batch(HostFnKind::Scanf { has_fd: true }).is_none());
        assert!(synthesize_batch(HostFnKind::Exit).is_none());
        // Order-preserving stream accesses do batch.
        assert!(synthesize_batch(HostFnKind::Fwrite).is_some());
        assert!(synthesize_batch(HostFnKind::Fread).is_some());
        assert!(synthesize_batch(HostFnKind::Puts).is_some());
    }

    fn fwrite_frame(payload: &[u8], fd: u64) -> RpcFrame {
        RpcFrame {
            args: vec![
                buf_arg(payload),
                HostArg::Val(1),
                HostArg::Val(payload.len() as u64),
                HostArg::Val(fd),
            ],
        }
    }

    #[test]
    fn batch_fwrite_pad_matches_scalar_pads_byte_identically() {
        // Interleaved writers into one shared file (two fds, "w" then
        // "a") plus a third file and a bad fd, under a sharded HostEnv:
        // the batched dispatch must produce byte-identical files and
        // identical per-item returns to scalar dispatch in the same
        // order.
        let run = |batched: bool| {
            let env = HostEnv::with_shards(4);
            let fd_w = with_lane_ctx(1, || env.fopen("shared.txt", "w")) as u64;
            env.write_stream(fd_w, b"0123456789"); // gives the appender a tail
            let fd_a = with_lane_ctx(2, || env.fopen("shared.txt", "a")) as u64;
            let fd_o = with_lane_ctx(3, || env.fopen("other.txt", "w")) as u64;
            env.fclose(fd_w);
            let fd_w = env.fopen("shared.txt", "r") as u64; // read-only: fwrite must fail
            let mut frames = vec![
                fwrite_frame(b"AA", fd_a),
                fwrite_frame(b"BB", fd_a), // same-fd run of two
                fwrite_frame(b"oo", fd_o),
                fwrite_frame(b"xx", fd_w), // not writable -> 0 items written
                fwrite_frame(b"CC", fd_a),
            ];
            let rets: Vec<i64> = if batched {
                let pad = synthesize_batch(HostFnKind::Fwrite).unwrap();
                pad(&mut frames, &env)
            } else {
                let pad = synthesize(HostFnKind::Fwrite);
                frames.iter_mut().map(|f| pad(f, &env)).collect()
            };
            (env.file("shared.txt").unwrap(), env.file("other.txt").unwrap(), rets)
        };
        let (shared_b, other_b, rets_b) = run(true);
        let (shared_s, other_s, rets_s) = run(false);
        assert_eq!(shared_b, shared_s);
        assert_eq!(other_b, other_s);
        assert_eq!(rets_b, rets_s);
        assert_eq!(shared_b, b"0123456789AABBCC");
        assert_eq!(other_b, b"oo");
        assert_eq!(rets_b, vec![2, 2, 2, 0, 2]);
    }

    #[test]
    fn oversized_fwrite_clamps_to_the_staged_object() {
        // size×count beyond the staged buffer is a short write (the C
        // contract for a failed transfer), never a slice panic that
        // would take down the serving engine worker.
        let env = HostEnv::new();
        let fd = env.fopen("clamp.bin", "w") as u64;
        let scalar = synthesize(HostFnKind::Fwrite);
        let mut f = RpcFrame {
            args: vec![buf_arg(b"ab"), HostArg::Val(1), HostArg::Val(100), HostArg::Val(fd)],
        };
        assert_eq!(scalar(&mut f, &env), 2, "short write, not a panic");
        let batch = synthesize_batch(HostFnKind::Fwrite).unwrap();
        let mut frames = vec![RpcFrame {
            args: vec![buf_arg(b"cd"), HostArg::Val(1), HostArg::Val(100), HostArg::Val(fd)],
        }];
        assert_eq!(batch(&mut frames, &env), vec![2]);
        assert_eq!(env.file("clamp.bin").unwrap(), b"abcd");
    }

    #[test]
    fn batched_fwrite_counter_rides_the_snapshot() {
        let env = HostEnv::new();
        let fd = env.fopen("log.bin", "w") as u64;
        let pad = synthesize_batch(HostFnKind::Fwrite).unwrap();
        let mut frames = vec![fwrite_frame(b"ab", fd), fwrite_frame(b"cd", fd)];
        assert_eq!(pad(&mut frames, &env), vec![2, 2]);
        assert_eq!(env.io_snapshot().batched_writes, 2, "one per committed frame");
        assert_eq!(env.file("log.bin").unwrap(), b"abcd");
    }

    fn fread_frame(cap: usize, size: u64, count: u64, fd: u64) -> RpcFrame {
        RpcFrame {
            args: vec![
                buf_arg(&vec![0u8; cap]),
                HostArg::Val(size),
                HostArg::Val(count),
                HostArg::Val(fd),
            ],
        }
    }

    #[test]
    fn batch_fread_pad_matches_scalar_pads_byte_identically() {
        // Two independent handles on one file (separate positions), a
        // second file that runs dry mid-batch, and a bad fd, under a
        // sharded HostEnv: batched dispatch must fill every buffer,
        // advance every position, and return per-item counts identical
        // to scalar dispatch in the same order.
        let run = |batched: bool| {
            let env = HostEnv::with_shards(4);
            env.put_file("data.bin", b"abcdefghij");
            env.put_file("tiny.bin", b"xyz");
            let fd_a = with_lane_ctx(1, || env.fopen("data.bin", "r")) as u64;
            let fd_b = with_lane_ctx(2, || env.fopen("data.bin", "r")) as u64;
            let fd_t = env.fopen("tiny.bin", "r") as u64;
            let mut frames = vec![
                fread_frame(4, 1, 4, fd_a),
                fread_frame(4, 1, 4, fd_a), // same-fd run of two
                fread_frame(6, 2, 3, fd_b), // independent position, same file
                fread_frame(4, 1, 4, fd_t), // short read: 3 bytes left...
                fread_frame(4, 1, 4, fd_t), // ...then dry (0 items)
                fread_frame(4, 1, 4, 9999), // bad fd -> 0 items
            ];
            let rets: Vec<i64> = if batched {
                let pad = synthesize_batch(HostFnKind::Fread).unwrap();
                pad(&mut frames, &env)
            } else {
                let pad = synthesize(HostFnKind::Fread);
                frames.iter_mut().map(|f| pad(f, &env)).collect()
            };
            let bufs: Vec<Vec<u8>> = frames.iter().map(|f| f.bytes(0).to_vec()).collect();
            (rets, bufs)
        };
        let (rets_b, bufs_b) = run(true);
        let (rets_s, bufs_s) = run(false);
        assert_eq!(rets_b, rets_s);
        assert_eq!(bufs_b, bufs_s);
        assert_eq!(rets_b, vec![4, 4, 3, 3, 0, 0]);
        assert_eq!(bufs_b[0], b"abcd");
        assert_eq!(bufs_b[1], b"efgh");
        assert_eq!(bufs_b[2], b"abcdef");
        assert_eq!(bufs_b[3], b"xyz\0", "short read leaves the tail untouched");
    }

    #[test]
    fn batched_fread_counter_rides_the_snapshot() {
        let env = HostEnv::new();
        env.put_file("in.bin", b"abcd");
        let fd = env.fopen("in.bin", "r") as u64;
        let pad = synthesize_batch(HostFnKind::Fread).unwrap();
        // count=50 over a 2-byte buffer: the request clamps to the
        // staged object exactly like the scalar pad.
        let mut frames = vec![fread_frame(2, 1, 50, fd), fread_frame(2, 1, 50, fd)];
        assert_eq!(pad(&mut frames, &env), vec![2, 2]);
        assert_eq!(env.io_snapshot().batched_reads, 2, "one per served frame");
        assert_eq!(frames[0].bytes(0), b"ab");
        assert_eq!(frames[1].bytes(0), b"cd");
    }

    #[test]
    fn poisoned_stream_lock_recovers_instead_of_cascading() {
        let env = Arc::new(HostEnv::new());
        // Poison the stdout lock: a "landing pad" panics while holding it.
        let poisoner = Arc::clone(&env);
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.stdout.lock().unwrap();
            panic!("wrapper panicked mid-write");
        })
        .join();
        assert!(env.stdout.lock().is_err(), "lock really is poisoned");
        // Later RPCs recover the inner guard and keep serving.
        assert_eq!(env.write_stream(FD_STDOUT, b"still alive"), 11);
        assert_eq!(env.stdout_string(), "still alive");
        let snap = env.io_snapshot();
        assert!(snap.poison_recoveries >= 2, "recoveries are counted: {snap:?}");
    }

    #[test]
    fn poisoned_content_shard_recovers_for_file_io() {
        let env = Arc::new(HostEnv::new());
        env.put_file("data.txt", b"payload");
        let poisoner = Arc::clone(&env);
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.files.lock("data.txt", &poisoner.poison_recoveries);
            panic!("pad died holding the content shard");
        })
        .join();
        assert_eq!(env.file("data.txt").unwrap(), b"payload", "shard usable again");
        assert!(env.io_snapshot().poison_recoveries >= 1);
    }

    #[test]
    fn unsupported_conversion_degrades_to_literal_text() {
        let before = format_warnings();
        let frame =
            RpcFrame { args: vec![cstr_arg("a=%d b=%q c=%s"), HostArg::Val(1), cstr_arg("x")] };
        let fmt = frame.cstr(0);
        // %q is not supported: its literal text survives, the following
        // conversions still consume their arguments in order.
        assert_eq!(format_c(&frame, &fmt, 1), "a=1 b=%q c=x");
        assert!(format_warnings() > before, "degradation is counted");
        // A trailing bare '%' degrades too instead of panicking.
        let frame = RpcFrame { args: vec![HostArg::Val(7)] };
        assert_eq!(format_c(&frame, "%d 100%", 0), "7 100%");
    }

    #[test]
    fn sharded_fopen_tags_fds_and_resolves_cross_lane() {
        let env = HostEnv::with_shards(4);
        env.put_file("in.txt", b"payload");
        // No lane context: shared table, legacy numbering.
        let shared_fd = env.fopen("in.txt", "r") as u64;
        assert!(shared_fd < 1 << FD_SHARD_SHIFT);
        // Opened under lane 2's context: lands in shard 2, tagged fd.
        let fd = with_lane_ctx(2, || env.fopen("out.txt", "w")) as u64;
        assert_eq!(fd >> FD_SHARD_SHIFT, 3, "shard tag = lane % shards + 1");
        // Cross-lane use: any lane (or none) resolves the handle from
        // the fd tag alone.
        with_lane_ctx(0, || assert_eq!(env.write_stream(fd, b"abc"), 3));
        assert_eq!(env.write_stream(fd, b"de"), 2);
        assert_eq!(env.fclose(fd), 0);
        assert_eq!(env.file("out.txt").unwrap(), b"abcde");
        let snap = env.io_snapshot();
        assert_eq!(snap.shards, 4);
        assert_eq!(snap.sharded_opens, 1);
        assert_eq!(snap.shared_opens, 1);
        assert_eq!(env.shard_contention().len(), 4);
        // A forged tag no shard backs is rejected, not a panic.
        assert_eq!(env.fclose((99u64 << FD_SHARD_SHIFT) | 5), -1);
    }

    #[test]
    fn unsharded_env_keeps_legacy_fd_numbering() {
        let env = HostEnv::new();
        env.put_file("a", b"1");
        // Even with a lane context set, an unsharded env uses the shared
        // table and plain sequential fds (bit-identical legacy shape).
        let fd = with_lane_ctx(3, || env.fopen("a", "r"));
        assert_eq!(fd, 16);
        assert_eq!(env.io_snapshot().shards, 0);
        assert_eq!(env.io_snapshot().shared_opens, 1);
    }

    #[test]
    fn content_map_shard_placement_is_deterministic_and_spreads() {
        let a = HostEnv::content_shard_of("alpha.txt");
        assert_eq!(a, HostEnv::content_shard_of("alpha.txt"), "placement is stable");
        assert!(a < CONTENT_SHARDS);
        // The path hash spreads keys over many shards (FNV over 64
        // probe paths must not degenerate to a single bucket).
        let shards: std::collections::HashSet<usize> =
            (0..64).map(|i| HostEnv::content_shard_of(&format!("f{i}.txt"))).collect();
        assert!(shards.len() > CONTENT_SHARDS / 2, "only {} shards used", shards.len());
    }

    #[test]
    fn io_snapshot_reports_content_map_counters() {
        let env = HostEnv::new();
        env.put_file("x", b"1");
        assert_eq!(env.file("x").unwrap(), b"1");
        let snap = env.io_snapshot();
        assert_eq!(snap.content_shards, CONTENT_SHARDS);
        assert_eq!(snap.content_contention, 0, "single-thread traffic never waits");
        assert_eq!(env.content_contention(), 0);
    }

    #[test]
    fn write_stream_many_commits_mixed_fds_in_order() {
        let env = HostEnv::new();
        let fd = env.fopen("mix.txt", "w") as u64;
        let rets = env.write_stream_many(&[
            (FD_STDOUT, b"out".to_vec()),
            (fd, b"fi".to_vec()),
            (fd, b"le".to_vec()), // same-fd run: one lock acquisition
            (FD_STDERR, b"err".to_vec()),
            (999, b"nope".to_vec()), // unknown fd: per-item -1, run intact
        ]);
        assert_eq!(rets, vec![3, 2, 2, 3, -1]);
        env.fclose(fd);
        assert_eq!(env.stdout_string(), "out");
        assert_eq!(env.stderr_string(), "err");
        assert_eq!(env.file("mix.txt").unwrap(), b"file");
    }
}
