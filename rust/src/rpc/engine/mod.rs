//! Multi-lane async RPC engine (the scalability successor to the
//! paper's single-threaded, single-slot server of §4.4 / Fig. 7).
//!
//! Four pieces:
//!
//! * [`arena`] — the **multi-lane mailbox arena**: one cache-line-padded
//!   RPC slot per lane at the base of the managed segment; device
//!   threads pick a lane by team id (`team % lanes`) and fall over to
//!   neighbouring lanes under contention. A **launch ring**
//!   (`--rpc-launch-slots` dedicated slots) after the lanes carries
//!   kernel-split launch RPCs so they never contend with the RPCs a
//!   running kernel issues — and so N launches can be in flight at
//!   once.
//! * [`server`] — the **worker-pool host server**: N host threads poll
//!   disjoint lane sets (plus the launch ring), claim requests with a
//!   `REQUEST -> SERVING` CAS (race-free **work stealing** when a
//!   worker's own lanes are quiet), and expose per-lane occupancy /
//!   batch-size metrics.
//! * [`executor`] — the **dedicated launch executor**: poll workers
//!   hand claimed kernel-split launch frames to a bounded queue drained
//!   by `--rpc-launch-threads` threads; the executor performs the
//!   completion writeback on the owning slot when the kernel finishes,
//!   and tracks ring occupancy (`ring_in_flight`/`ring_peak`) plus
//!   per-ring-slot completion/latency counters. Workers are therefore
//!   never occupied by a launch, which makes **in-kernel RPCs correct
//!   at every `lanes × workers` shape** — including the default
//!   `lanes=1, workers=1` that used to deadlock.
//! * The **batching layer** inside [`server`]: each poll sweep drains
//!   every ready lane and dispatches homogeneous calls (same callee id)
//!   as one batched landing-pad invocation — see
//!   [`crate::rpc::wrappers::synthesize_batch`] for the vectorized
//!   printf-family pads.
//!
//! The legacy path is the degenerate case: `lanes=1, workers=1` over
//! [`ArenaLayout::legacy`] polls the same single slot as
//! [`crate::rpc::server::RpcServer`], keeping the paper's Fig. 7 numbers
//! reproducible bit-for-bit for kernels that issue no RPCs.

pub mod arena;
pub mod executor;
pub mod server;

pub use arena::{ArenaLayout, MULTI_LANE_DATA_CAP};
pub use executor::{LaunchExecutor, LaunchJob};
pub use server::{EngineConfig, EngineMetrics, EngineSnapshot, RpcEngine};
