//! Multi-lane async RPC engine (the scalability successor to the
//! paper's single-threaded, single-slot server of §4.4 / Fig. 7).
//!
//! Three pieces:
//!
//! * [`arena`] — the **multi-lane mailbox arena**: one cache-line-padded
//!   RPC slot per lane at the base of the managed segment; device
//!   threads pick a lane by team id (`team % lanes`) and fall over to
//!   neighbouring lanes under contention.
//! * [`server`] — the **worker-pool host server**: N host threads poll
//!   disjoint lane sets, claim requests with a `REQUEST -> SERVING` CAS
//!   (race-free **work stealing** when a worker's own lanes are quiet),
//!   and expose per-lane occupancy / batch-size metrics.
//! * The **batching layer** inside [`server`]: each poll sweep drains
//!   every ready lane and dispatches homogeneous calls (same callee id)
//!   as one batched landing-pad invocation — see
//!   [`crate::rpc::wrappers::synthesize_batch`] for the vectorized
//!   printf-family pads.
//!
//! The legacy path is the degenerate case: `lanes=1, workers=1` over
//! [`ArenaLayout::legacy`] polls the same single slot as
//! [`crate::rpc::server::RpcServer`], keeping the paper's Fig. 7 numbers
//! reproducible bit-for-bit.
//!
//! ## Nested RPCs need `workers >= 2`
//!
//! A kernel-split launch RPC runs the whole kernel *inside* the worker
//! that claimed it (the launcher wrapper is synchronous, exactly like
//! the paper's single-threaded server). RPCs issued from inside that
//! kernel therefore need a *different* worker to answer them: with
//! `workers = 1` they spin until the client times out, regardless of
//! how many lanes exist — the same limitation the legacy server has.
//! Run RPC-issuing kernels with `--rpc-workers 2` or more; the idle
//! workers' stealing then guarantees progress.

pub mod arena;
pub mod server;

pub use arena::{ArenaLayout, MULTI_LANE_DATA_CAP};
pub use server::{EngineConfig, EngineMetrics, EngineSnapshot, RpcEngine};
