//! The worker-pool host RPC server over the multi-lane arena.
//!
//! N host threads poll disjoint lane sets (`lane % workers == worker`),
//! claim ready lanes with a `ST_REQUEST -> ST_SERVING` CAS (which makes
//! **work-stealing** between workers race-free), and drain *every* ready
//! lane of a poll sweep before dispatching. Homogeneous calls in one
//! sweep — same callee id, the per-thread `fprintf` storm of Fig. 7 —
//! are dispatched as **one batched landing-pad invocation** through the
//! registry's batch pad (or, lacking one, the scalar pad already
//! fetched — together with its launch flag — by the sweep's single
//! per-frame registry lookup). Consecutive `fwrite`/`fread` frames that
//! target the same stream additionally merge **across callee
//! boundaries** (distinct call-site pads of one direction share a frame
//! layout); frames that joined that way are counted in
//! `HostIoSnapshot::batched_cross_callee`.
//!
//! Stage table for the batched path (the Fig. 7 pipeline, per sweep):
//!
//! ```text
//! stage                         single-slot server      engine (per sweep)
//! 1  poll                       1 slot                  own lanes + steal CAS
//! 2  copy RPCInfo to host       1 frame                 all ready frames
//! 3  invoke host wrapper        1 scalar pad            1 batch pad / group
//! 4  copy-back + notify         1 slot -> DONE          each lane -> DONE
//! ```
//!
//! `lanes=1, workers=1` degenerates to the paper's single-threaded
//! single-slot server: one lane, one poller, batches of one.
//!
//! Every worker additionally polls the arena's **launch ring**
//! (`--rpc-launch-slots` dedicated slots); claimed kernel-split launch
//! frames (and launch callees arriving on regular lanes) are handed to
//! the [`executor`] instead of being served inline, so a running kernel
//! never occupies a poll worker and its in-kernel RPCs are answered at
//! every engine shape — with a ring and executor pool wider than one,
//! N kernel-split launches are genuinely in flight at once.
//!
//! [`executor`]: super::executor

use super::arena::ArenaLayout;
use super::executor::{LaunchExecutor, LaunchJob};
use crate::gpu::memory::DeviceMemory;
use crate::rpc::mailbox::{ST_DONE, ST_IDLE, ST_REQUEST, ST_SERVING};
use crate::rpc::server::{
    unpack_frame, writeback_frame, HostArg, RpcFrame, StreamDir, WrapperFn, WrapperRegistry,
};
use crate::rpc::wrappers::{with_lane_ctx, HostEnv};
use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Engine shape: `--rpc-lanes` × `--rpc-workers` ×
/// `--rpc-launch-threads` × `--rpc-launch-slots`, plus the batching
/// toggle (`--no-rpc-batch` clears it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineConfig {
    pub lanes: usize,
    pub workers: usize,
    /// Dedicated kernel-split launch executor threads
    /// (`--rpc-launch-threads`). Launches never occupy poll workers.
    pub launch_threads: usize,
    /// Launch ring width (`--rpc-launch-slots`): how many kernel-split
    /// launches can be in flight at once. Must match the arena's ring.
    pub launch_slots: usize,
    /// Coalesce same-callee requests of one sweep into one dispatch.
    pub batch: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self { lanes: 1, workers: 1, launch_threads: 1, launch_slots: 1, batch: true }
    }
}

/// Per-lane occupancy/serve counters.
#[derive(Debug, Default)]
pub struct LaneCounters {
    pub served: AtomicU64,
    /// Owner-worker polls of this lane.
    pub polls: AtomicU64,
    /// Polls that found the lane non-idle (occupancy numerator).
    pub polls_busy: AtomicU64,
}

/// Per-launch-ring-slot completion/latency counters.
#[derive(Debug, Default)]
pub struct RingSlotCounters {
    /// Launches completed on this ring slot.
    pub completions: AtomicU64,
    /// Total ns those launches spent queued for the executor.
    pub wait_ns: AtomicU64,
    /// Total ns the executor spent running them.
    pub run_ns: AtomicU64,
}

/// Live engine counters (atomics shared with the worker threads and the
/// launch executor).
#[derive(Debug)]
pub struct EngineMetrics {
    lanes_n: usize,
    workers_n: usize,
    launch_threads_n: usize,
    launch_slots_n: usize,
    pub served: AtomicU64,
    /// Coalesced dispatches (groups of ≥ 2 same-callee requests).
    pub batches: AtomicU64,
    /// Requests that rode in those coalesced dispatches.
    pub batched_calls: AtomicU64,
    pub max_batch: AtomicU64,
    /// Requests a worker claimed from a lane it does not own.
    pub steals: AtomicU64,
    /// Kernel-split launches completed by the executor.
    pub launches: AtomicU64,
    /// Launch jobs currently queued/being handed to the executor.
    pub launch_queued: AtomicU64,
    /// High-water mark of the executor queue depth.
    pub launch_queue_peak: AtomicU64,
    /// Claims re-armed (`ST_SERVING -> ST_REQUEST`) because the executor
    /// queue was full.
    pub launch_requeues: AtomicU64,
    /// Total ns launch jobs spent waiting in the executor queue.
    pub launch_wait_ns: AtomicU64,
    /// Total ns the executor spent running launch wrappers.
    pub launch_run_ns: AtomicU64,
    /// Launches running on executor threads right now (ring occupancy).
    pub ring_in_flight: AtomicU64,
    /// High-water mark of `ring_in_flight` — peak launch concurrency.
    pub ring_peak: AtomicU64,
    pub lanes: Vec<LaneCounters>,
    /// Per-launch-ring-slot counters (index = ring position).
    pub ring: Vec<RingSlotCounters>,
}

impl EngineMetrics {
    pub(crate) fn new(cfg: EngineConfig) -> Self {
        Self {
            lanes_n: cfg.lanes,
            workers_n: cfg.workers,
            launch_threads_n: cfg.launch_threads,
            launch_slots_n: cfg.launch_slots,
            served: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_calls: AtomicU64::new(0),
            max_batch: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            launches: AtomicU64::new(0),
            launch_queued: AtomicU64::new(0),
            launch_queue_peak: AtomicU64::new(0),
            launch_requeues: AtomicU64::new(0),
            launch_wait_ns: AtomicU64::new(0),
            launch_run_ns: AtomicU64::new(0),
            ring_in_flight: AtomicU64::new(0),
            ring_peak: AtomicU64::new(0),
            lanes: (0..cfg.lanes).map(|_| LaneCounters::default()).collect(),
            ring: (0..cfg.launch_slots).map(|_| RingSlotCounters::default()).collect(),
        }
    }

    pub fn snapshot(&self) -> EngineSnapshot {
        let r = Ordering::Relaxed;
        EngineSnapshot {
            lanes: self.lanes_n,
            workers: self.workers_n,
            launch_threads: self.launch_threads_n,
            launch_slots: self.launch_slots_n,
            served: self.served.load(r),
            batches: self.batches.load(r),
            batched_calls: self.batched_calls.load(r),
            max_batch: self.max_batch.load(r),
            steals: self.steals.load(r),
            launches: self.launches.load(r),
            launch_queue_depth: self.launch_queued.load(r),
            launch_queue_peak: self.launch_queue_peak.load(r),
            launch_requeues: self.launch_requeues.load(r),
            launch_wait_ns: self.launch_wait_ns.load(r),
            launch_run_ns: self.launch_run_ns.load(r),
            ring_in_flight: self.ring_in_flight.load(r),
            ring_peak: self.ring_peak.load(r),
            polls: self.lanes.iter().map(|l| l.polls.load(r)).sum(),
            polls_busy: self.lanes.iter().map(|l| l.polls_busy.load(r)).sum(),
        }
    }

    /// Launches completed per ring slot (index = ring position).
    pub fn ring_completions(&self) -> Vec<u64> {
        self.ring.iter().map(|s| s.completions.load(Ordering::Relaxed)).collect()
    }

    /// Per-ring-slot (completions, mean end-to-end latency ns) gauges —
    /// what the fig07 bench table and `BENCH_fig07.json` print per slot.
    pub fn ring_slot_gauges(&self) -> Vec<(u64, f64)> {
        let r = Ordering::Relaxed;
        self.ring
            .iter()
            .map(|s| {
                let n = s.completions.load(r);
                let total = (s.wait_ns.load(r) + s.run_ns.load(r)) as f64;
                (n, if n == 0 { 0.0 } else { total / n as f64 })
            })
            .collect()
    }

    pub fn lane_served(&self) -> Vec<u64> {
        self.lanes.iter().map(|l| l.served.load(Ordering::Relaxed)).collect()
    }

    /// Machine-readable report including the per-lane breakdown.
    pub fn to_json(&self) -> Json {
        let r = Ordering::Relaxed;
        let s = self.snapshot();
        let lanes: Vec<Json> = self
            .lanes
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let polls = l.polls.load(r);
                let busy = l.polls_busy.load(r);
                Json::obj(vec![
                    ("lane", Json::num(i as f64)),
                    ("served", Json::num(l.served.load(r) as f64)),
                    (
                        "occupancy",
                        Json::num(if polls == 0 { 0.0 } else { busy as f64 / polls as f64 }),
                    ),
                ])
            })
            .collect();
        let ring: Vec<Json> = self
            .ring_slot_gauges()
            .iter()
            .enumerate()
            .map(|(i, (n, mean_ns))| {
                Json::obj(vec![
                    ("slot", Json::num(i as f64)),
                    ("completions", Json::num(*n as f64)),
                    ("mean_latency_ns", Json::num(*mean_ns)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("lanes", Json::num(s.lanes as f64)),
            ("workers", Json::num(s.workers as f64)),
            ("launch_threads", Json::num(s.launch_threads as f64)),
            ("launch_slots", Json::num(s.launch_slots as f64)),
            ("served", Json::num(s.served as f64)),
            ("batches", Json::num(s.batches as f64)),
            ("batched_calls", Json::num(s.batched_calls as f64)),
            ("max_batch", Json::num(s.max_batch as f64)),
            ("steals", Json::num(s.steals as f64)),
            ("launches", Json::num(s.launches as f64)),
            ("launch_queue_peak", Json::num(s.launch_queue_peak as f64)),
            ("launch_requeues", Json::num(s.launch_requeues as f64)),
            ("launch_wait_ns", Json::num(s.launch_wait_ns as f64)),
            ("launch_run_ns", Json::num(s.launch_run_ns as f64)),
            ("ring_peak", Json::num(s.ring_peak as f64)),
            ("occupancy", Json::num(s.occupancy())),
            ("per_lane", Json::Arr(lanes)),
            ("per_ring_slot", Json::Arr(ring)),
        ])
    }
}

/// Copyable aggregate of [`EngineMetrics`] for `RunMetrics`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EngineSnapshot {
    pub lanes: usize,
    pub workers: usize,
    pub launch_threads: usize,
    /// Launch ring width (in-flight launch capacity).
    pub launch_slots: usize,
    pub served: u64,
    pub batches: u64,
    pub batched_calls: u64,
    pub max_batch: u64,
    pub steals: u64,
    /// Kernel-split launches completed by the dedicated executor.
    pub launches: u64,
    /// Executor queue depth at snapshot time.
    pub launch_queue_depth: u64,
    /// Executor queue depth high-water mark.
    pub launch_queue_peak: u64,
    pub launch_requeues: u64,
    pub launch_wait_ns: u64,
    pub launch_run_ns: u64,
    /// Launches running on executor threads at snapshot time.
    pub ring_in_flight: u64,
    /// Peak concurrent launches (ring occupancy high-water mark).
    pub ring_peak: u64,
    pub polls: u64,
    pub polls_busy: u64,
}

impl EngineSnapshot {
    /// Fraction of owner polls that found the lane occupied.
    pub fn occupancy(&self) -> f64 {
        if self.polls == 0 {
            0.0
        } else {
            self.polls_busy as f64 / self.polls as f64
        }
    }

    /// Mean end-to-end executor latency (queue wait + wrapper run) per
    /// completed launch, in ns.
    pub fn launch_latency_ns(&self) -> f64 {
        if self.launches == 0 {
            0.0
        } else {
            (self.launch_wait_ns + self.launch_run_ns) as f64 / self.launches as f64
        }
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "rpc_engine lanes={} workers={} served={} batches={} batched={} max_batch={} steals={} occupancy={:.3}",
            self.lanes,
            self.workers,
            self.served,
            self.batches,
            self.batched_calls,
            self.max_batch,
            self.steals,
            self.occupancy(),
        );
        if self.launches > 0 {
            s.push_str(&format!(
                " launches={} launch_threads={} launch_qpeak={} launch_lat={} ring_peak={}/{}",
                self.launches,
                self.launch_threads,
                self.launch_queue_peak,
                crate::util::fmt_ns(self.launch_latency_ns()),
                self.ring_peak,
                self.launch_slots,
            ));
        }
        s
    }
}

/// Handle to the running worker pool + launch executor.
pub struct RpcEngine {
    cfg: EngineConfig,
    shutdown: Arc<AtomicBool>,
    handles: Vec<std::thread::JoinHandle<()>>,
    executor: Option<Arc<LaunchExecutor>>,
    pub metrics: Arc<EngineMetrics>,
}

impl RpcEngine {
    /// Spawn `cfg.workers` poller threads over `arena` (plus
    /// `cfg.launch_threads` launch-executor threads), dispatching to
    /// `registry` with `env` as the host state.
    pub fn start(
        mem: Arc<DeviceMemory>,
        arena: ArenaLayout,
        registry: Arc<WrapperRegistry>,
        env: Arc<HostEnv>,
        cfg: EngineConfig,
    ) -> Self {
        assert!(cfg.workers >= 1, "engine needs at least one worker");
        assert_eq!(cfg.lanes, arena.lanes, "engine config and arena disagree on lane count");
        assert_eq!(
            cfg.launch_slots, arena.launch_slots,
            "engine config and arena disagree on launch ring width"
        );
        let shutdown = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(EngineMetrics::new(cfg));
        let executor = Arc::new(LaunchExecutor::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&registry),
            Arc::clone(&env),
            cfg.launch_threads.max(1),
            Arc::clone(&metrics),
        ));
        let mut handles = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let mem = Arc::clone(&mem);
            let registry = Arc::clone(&registry);
            let env = Arc::clone(&env);
            let metrics = Arc::clone(&metrics);
            let shutdown = Arc::clone(&shutdown);
            let executor = Arc::clone(&executor);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("rpc-engine-{w}"))
                    .spawn(move || {
                        worker_loop(
                            w, &mem, arena, &registry, &env, cfg, &metrics, &shutdown, &executor,
                        )
                    })
                    .expect("spawn rpc engine worker"),
            );
        }
        Self { cfg, shutdown, handles, executor: Some(executor), metrics }
    }

    pub fn config(&self) -> EngineConfig {
        self.cfg
    }

    pub fn stop(mut self) {
        self.join_workers();
    }

    fn join_workers(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // Workers are gone; dropping the last executor handle drains the
        // launch queue and joins the pool.
        drop(self.executor.take());
    }
}

impl Drop for RpcEngine {
    fn drop(&mut self) {
        self.join_workers();
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker: usize,
    mem: &DeviceMemory,
    arena: ArenaLayout,
    registry: &WrapperRegistry,
    env: &HostEnv,
    cfg: EngineConfig,
    metrics: &EngineMetrics,
    shutdown: &AtomicBool,
    executor: &LaunchExecutor,
) {
    let own: Vec<usize> = (0..cfg.lanes).filter(|i| i % cfg.workers == worker).collect();
    let mut idle_sweeps = 0u64;
    let mut claimed: Vec<usize> = Vec::with_capacity(arena.slot_count());
    loop {
        claimed.clear();
        // Sweep the lanes this worker owns, claiming every ready one.
        // (Engine shutdown is the atomic flag only — a lane stuck at
        // ST_SHUTDOWN is just "busy" here, never a reason to abandon
        // lanes already claimed in this sweep.)
        for &i in &own {
            let mb = arena.lane(mem, i);
            let lc = &metrics.lanes[i];
            lc.polls.fetch_add(1, Ordering::Relaxed);
            match mb.status() {
                ST_IDLE => {}
                _ => {
                    lc.polls_busy.fetch_add(1, Ordering::Relaxed);
                    if mb.cas_status(ST_REQUEST, ST_SERVING) {
                        claimed.push(i);
                    }
                }
            }
        }
        // The whole launch ring is polled by every worker; the claim
        // CAS keeps that race-free. A plain status read gates the CAS so
        // the idle fast path never takes a cache line exclusive.
        // Claimed launches are handed to the executor in dispatch_sweep,
        // so this never occupies the worker — and with a multi-slot ring
        // several launches can be claimed in one sweep.
        for idx in arena.launch_index()..arena.slot_count() {
            let launch = arena.slot(mem, idx);
            if launch.status() == ST_REQUEST && launch.cas_status(ST_REQUEST, ST_SERVING) {
                claimed.push(idx);
            }
        }
        // Nothing of our own: steal one ready request from a foreign lane
        // (the claim CAS makes this race-free against its owner).
        if claimed.is_empty() && cfg.lanes > own.len() {
            for i in 0..cfg.lanes {
                if i % cfg.workers == worker {
                    continue;
                }
                if arena.lane(mem, i).cas_status(ST_REQUEST, ST_SERVING) {
                    metrics.steals.fetch_add(1, Ordering::Relaxed);
                    claimed.push(i);
                    break;
                }
            }
        }
        if claimed.is_empty() {
            if shutdown.load(Ordering::Relaxed) {
                break;
            }
            // Perf (§Perf L3-1): brief hot window after the last request,
            // then hand the core back.
            std::hint::spin_loop();
            idle_sweeps += 1;
            if idle_sweeps > 4 {
                std::thread::yield_now();
            }
            continue;
        }
        idle_sweeps = 0;
        dispatch_sweep(worker, mem, arena, registry, env, cfg.batch, metrics, &claimed, executor);
    }
}

/// Serve every claimed slot of one sweep: launch callees are handed to
/// the dedicated executor (which owns their completion writeback);
/// everything else dispatches inline, coalescing same-callee groups.
#[allow(clippy::too_many_arguments)]
fn dispatch_sweep(
    worker: usize,
    mem: &DeviceMemory,
    arena: ArenaLayout,
    registry: &WrapperRegistry,
    env: &HostEnv,
    batch: bool,
    metrics: &EngineMetrics,
    claimed: &[usize],
    executor: &LaunchExecutor,
) {
    // Stage 2: copy every ready RPCInfo to the host, peeling launch
    // frames off to the executor as they are identified. One registry
    // lock acquisition per frame fetches the pad and the launch flag
    // together; the group dispatch below reuses the fetched pads.
    let mut slots = Vec::with_capacity(claimed.len());
    let mut callees = Vec::with_capacity(claimed.len());
    let mut frames: Vec<RpcFrame> = Vec::with_capacity(claimed.len());
    let mut pads: Vec<Option<Arc<WrapperFn>>> = Vec::with_capacity(claimed.len());
    for &slot in claimed {
        let mb = arena.slot(mem, slot);
        let (callee, frame) = unpack_frame(&mb);
        let entry = registry.get_entry(callee);
        if matches!(entry, Some((_, true))) {
            let depth = metrics.launch_queued.fetch_add(1, Ordering::Relaxed) + 1;
            metrics.launch_queue_peak.fetch_max(depth, Ordering::Relaxed);
            if executor.try_submit(LaunchJob::new(slot, callee, frame)).is_err() {
                // Queue full: re-arm the slot and let a later sweep
                // retry. The client just keeps spinning on ST_DONE.
                metrics.launch_queued.fetch_sub(1, Ordering::Relaxed);
                metrics.launch_requeues.fetch_add(1, Ordering::Relaxed);
                mb.set_status(ST_REQUEST);
            }
            continue;
        }
        slots.push(slot);
        callees.push(callee);
        frames.push(frame);
        pads.push(entry.map(|(w, _)| w));
    }
    // Group by callee, preserving claim order within a group — and
    // merge **consecutive** stream-pad frames (`fwrite`/`fread`) that
    // target the same stream into one batch run even across a callee
    // boundary: every pad of one direction shares the
    // `(buf, size, count, fd)` frame layout, so the merged run commits
    // through one batch-pad invocation (and one stream-lock
    // acquisition) exactly like a homogeneous group.
    struct Group {
        callee: u64,
        members: Vec<usize>,
        /// The `(direction, fd)` every member shares while the group is
        /// still extendable by the cross-callee merge; `None` once it
        /// mixes streams or never was a stream run.
        stream: Option<(StreamDir, u64)>,
        /// Members that joined from a different callee than `callee`.
        cross: u64,
    }
    let stream_key = |k: usize| -> Option<(StreamDir, u64)> {
        if !batch {
            return None;
        }
        let dir = registry.stream_dir(callees[k])?;
        match frames[k].args.get(3) {
            Some(HostArg::Val(fd)) => Some((dir, *fd)),
            _ => None,
        }
    };
    let mut groups: Vec<Group> = Vec::new();
    let mut prev: Option<usize> = None;
    for (k, &c) in callees.iter().enumerate() {
        let key = stream_key(k);
        // Same stream as the immediately preceding frame: extend its
        // group, whatever the callee.
        if key.is_some() {
            if let Some(gi) = prev {
                if groups[gi].stream == key {
                    if groups[gi].callee != c {
                        groups[gi].cross += 1;
                    }
                    groups[gi].members.push(k);
                    continue;
                }
            }
        }
        match groups.iter().position(|g| g.callee == c) {
            Some(gi) => {
                if groups[gi].stream != key {
                    groups[gi].stream = None;
                }
                groups[gi].members.push(k);
                prev = Some(gi);
            }
            None => {
                groups.push(Group { callee: c, members: vec![k], stream: key, cross: 0 });
                prev = Some(groups.len() - 1);
            }
        }
    }
    // Stage 3: one landing-pad invocation per group, run under the
    // (first) owning slot's lane context so HostEnv shard selection
    // follows the serving lane.
    for Group { callee, members, cross, .. } in groups {
        let serve_span = mem.obs.spans.start();
        let coalesced = batch && members.len() > 1;
        if coalesced {
            metrics.batches.fetch_add(1, Ordering::Relaxed);
            metrics.batched_calls.fetch_add(members.len() as u64, Ordering::Relaxed);
            metrics.max_batch.fetch_max(members.len() as u64, Ordering::Relaxed);
        }
        let rets: Vec<(i64, u64)> = match (
            coalesced.then(|| registry.get_batch(callee)).flatten(),
            pads[members[0]].clone(),
        ) {
            (Some(batch_pad), _) => {
                // True batch pad: the whole group in one invocation.
                let mut group_frames: Vec<RpcFrame> =
                    members.iter().map(|&k| std::mem::take(&mut frames[k])).collect();
                let rs = with_lane_ctx(slots[members[0]], || batch_pad(&mut group_frames, env));
                for (j, &k) in members.iter().enumerate() {
                    frames[k] = std::mem::take(&mut group_frames[j]);
                }
                (0..members.len()).map(|j| (rs.get(j).copied().unwrap_or(-1), 0)).collect()
            }
            (None, Some(pad)) => {
                // Scalar pad: still a single registry dispatch for the group.
                members
                    .iter()
                    .map(|&k| (with_lane_ctx(slots[k], || pad(&mut frames[k], env)), 0))
                    .collect()
            }
            (None, None) => members.iter().map(|_| (-1i64, 1u64)).collect(),
        };
        // Stage 4: copy-back + notify, per slot.
        for (j, &k) in members.iter().enumerate() {
            let slot = slots[k];
            let mb = arena.slot(mem, slot);
            writeback_frame(&mb, &frames[k]);
            let (ret, flags) = rets[j];
            mb.set_ret(ret);
            mb.set_flags(flags);
            if let Some(lc) = metrics.lanes.get(slot) {
                lc.served.fetch_add(1, Ordering::Relaxed);
            }
            metrics.served.fetch_add(1, Ordering::Relaxed);
            mb.set_status(ST_DONE);
        }
        if cross > 0 {
            env.count_batched_cross_callee(cross);
        }
        if serve_span.is_some() {
            // Spans are enabled: the name lookup is off the default path.
            let label = registry.name_of(callee).unwrap_or_else(|| format!("callee {callee}"));
            let name = format!("serve {label}");
            mem.obs.spans.finish(serve_span, &name, crate::obs::SpanKind::Worker, worker as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::memory::{MemConfig, GLOBAL_BASE};
    use crate::rpc::arginfo::{ArgMode, RpcArgInfo};
    use crate::rpc::client::RpcClient;
    use crate::rpc::mailbox::{WireArg, KIND_REF, KIND_VAL};
    use crate::rpc::server::RpcServer;
    use crate::rpc::wrappers::register_common;

    fn setup(lanes: usize) -> (Arc<DeviceMemory>, ArenaLayout, Arc<WrapperRegistry>, Arc<HostEnv>) {
        (
            Arc::new(DeviceMemory::new(MemConfig::small())),
            ArenaLayout::for_lanes(lanes),
            Arc::new(WrapperRegistry::new()),
            Arc::new(HostEnv::new()),
        )
    }

    #[test]
    fn multi_lane_round_trip_across_teams() {
        let (mem, arena, reg, env) = setup(4);
        let id = reg.register("__id_i", Box::new(|f, _| f.val(0) as i64));
        let engine = RpcEngine::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&reg),
            env,
            EngineConfig { lanes: 4, workers: 2, ..EngineConfig::default() },
        );
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let mem = &mem;
                s.spawn(move || {
                    let mut client = RpcClient::for_team(mem, arena, t as usize);
                    for k in 0..25u64 {
                        let mut info = RpcArgInfo::new();
                        info.add_val(t * 1000 + k);
                        assert_eq!(client.call(id, &info, None), (t * 1000 + k) as i64);
                    }
                });
            }
        });
        let snap = engine.metrics.snapshot();
        assert_eq!(snap.served, 200);
        assert_eq!(engine.metrics.lane_served().iter().sum::<u64>(), 200);
        // Teams hash over all four lanes, so no lane saw everything.
        assert!(engine.metrics.lane_served().iter().all(|&n| n < 200));
        engine.stop();
    }

    #[test]
    fn degenerate_engine_matches_legacy_server_observably() {
        // lanes=1, workers=1 must behave exactly like the single-slot
        // server: same rets, same host effects, same modeled breakdown.
        let run = |legacy: bool| {
            let (mem, arena, reg, env) = setup(1);
            let ids = register_common(&reg);
            let id = ids["__fprintf_p_cp_cp"];
            let server: Box<dyn FnOnce()> = if legacy {
                let s = RpcServer::start(Arc::clone(&mem), Arc::clone(&reg), Arc::clone(&env));
                Box::new(move || s.stop())
            } else {
                let e = RpcEngine::start(
                    Arc::clone(&mem),
                    arena,
                    Arc::clone(&reg),
                    Arc::clone(&env),
                    EngineConfig::default(),
                );
                Box::new(move || e.stop())
            };
            let fmt = GLOBAL_BASE + 256;
            mem.write_cstr(fmt, "v=%s\n");
            let buf = GLOBAL_BASE + 512;
            mem.write_cstr(buf, "payload");
            let mut client = RpcClient::for_team(&mem, arena, 0);
            let mut info = RpcArgInfo::new();
            info.add_val(2);
            info.add_ref(fmt, ArgMode::Read, 6, 0);
            info.add_ref(buf, ArgMode::ReadWrite, 8, 0);
            let ret = client.call(id, &info, None);
            let bd = client.last;
            server();
            (ret, env.stderr_string(), bd.device_total_ns())
        };
        let (ret_l, err_l, ns_l) = run(true);
        let (ret_e, err_e, ns_e) = run(false);
        assert_eq!(ret_l, ret_e);
        assert_eq!(err_l, err_e);
        assert_eq!(err_e, "v=payload\n");
        assert_eq!(ns_l, ns_e, "modeled Fig. 7 stage totals must be identical");
    }

    #[test]
    fn sweep_batches_homogeneous_requests() {
        // Pre-fill all four lanes before the engine starts: the first
        // sweep then sees four ready same-callee requests and must
        // dispatch them as one coalesced group.
        let (mem, arena, reg, env) = setup(4);
        let id = reg.register("__id_i", Box::new(|f, _| f.val(0) as i64));
        for lane in 0..4 {
            let mb = arena.lane(&mem, lane);
            mb.set_callee(id);
            mb.set_nargs(1);
            mb.write_arg(
                0,
                WireArg { kind: KIND_VAL, value: 70 + lane as u64, mode: 0, size: 0, offset: 0 },
            );
            mb.set_status(ST_REQUEST);
        }
        let engine = RpcEngine::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&reg),
            env,
            EngineConfig { lanes: 4, workers: 1, ..EngineConfig::default() },
        );
        for lane in 0..4 {
            let mb = arena.lane(&mem, lane);
            let mut spins = 0u64;
            while mb.status() != ST_DONE {
                std::thread::yield_now();
                spins += 1;
                assert!(spins < 50_000_000, "lane {lane} never served");
            }
            assert_eq!(mb.ret(), 70 + lane as i64);
            mb.set_status(ST_IDLE);
        }
        let snap = engine.metrics.snapshot();
        assert_eq!(snap.served, 4);
        assert_eq!(snap.batches, 1, "one coalesced dispatch");
        assert_eq!(snap.batched_calls, 4);
        assert_eq!(snap.max_batch, 4);
        engine.stop();
    }

    #[test]
    fn printf_batch_pad_appends_in_claim_order() {
        let (mem, arena, reg, env) = setup(3);
        let ids = register_common(&reg);
        let id = ids["__printf_cp"];
        for lane in 0..3 {
            let mb = arena.lane(&mem, lane);
            let msg = format!("line{lane}\n\0");
            mb.write_data(0, msg.as_bytes());
            mb.set_callee(id);
            mb.set_nargs(1);
            mb.write_arg(
                0,
                WireArg {
                    kind: KIND_REF,
                    value: 0,
                    mode: ArgMode::Read.encode(),
                    size: msg.len() as u64,
                    offset: 0,
                },
            );
            mb.set_status(ST_REQUEST);
        }
        let engine = RpcEngine::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&reg),
            Arc::clone(&env),
            EngineConfig { lanes: 3, workers: 1, ..EngineConfig::default() },
        );
        for lane in 0..3 {
            let mb = arena.lane(&mem, lane);
            while mb.status() != ST_DONE {
                std::thread::yield_now();
            }
            assert_eq!(mb.ret(), 6, "printf returns bytes written");
        }
        assert_eq!(env.stdout_string(), "line0\nline1\nline2\n");
        assert_eq!(engine.metrics.snapshot().batches, 1);
        engine.stop();
    }

    #[test]
    fn consecutive_same_stream_frames_merge_across_callees() {
        use crate::rpc::wrappers::{register_pad, HostFnKind, FD_STDERR, FD_STDOUT};
        // Two distinct fwrite call-site pads (different callee ids, one
        // frame layout). Both lanes ready before the engine starts, both
        // targeting stdout: the sweep must dispatch them as ONE batch
        // run, counting the second frame as a cross-callee join.
        let (mem, arena, reg, env) = setup(2);
        let id_a = register_pad(&reg, "__fwrite_site_a", HostFnKind::Fwrite);
        let id_b = register_pad(&reg, "__fwrite_site_b", HostFnKind::Fwrite);
        assert_ne!(id_a, id_b);
        let fill = |lane: usize, callee: u64, payload: &str, fd: u64| {
            let mb = arena.lane(&mem, lane);
            mb.write_data(0, payload.as_bytes());
            mb.set_callee(callee);
            mb.set_nargs(4);
            mb.write_arg(
                0,
                WireArg {
                    kind: KIND_REF,
                    value: 0,
                    mode: ArgMode::Read.encode(),
                    size: payload.len() as u64,
                    offset: 0,
                },
            );
            mb.write_arg(1, WireArg { kind: KIND_VAL, value: 1, mode: 0, size: 0, offset: 0 });
            mb.write_arg(
                2,
                WireArg { kind: KIND_VAL, value: payload.len() as u64, mode: 0, size: 0, offset: 0 },
            );
            mb.write_arg(3, WireArg { kind: KIND_VAL, value: fd, mode: 0, size: 0, offset: 0 });
            mb.set_status(ST_REQUEST);
        };
        let drain = |lane: usize, want_ret: i64| {
            let mb = arena.lane(&mem, lane);
            let mut spins = 0u64;
            while mb.status() != ST_DONE {
                std::thread::yield_now();
                spins += 1;
                assert!(spins < 50_000_000, "lane {lane} never served");
            }
            assert_eq!(mb.ret(), want_ret, "fwrite returns count on lane {lane}");
            mb.set_status(ST_IDLE);
        };
        fill(0, id_a, "AA", FD_STDOUT);
        fill(1, id_b, "BB", FD_STDOUT);
        let engine = RpcEngine::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&reg),
            Arc::clone(&env),
            EngineConfig { lanes: 2, workers: 1, ..EngineConfig::default() },
        );
        drain(0, 2);
        drain(1, 2);
        assert_eq!(env.stdout_string(), "AABB", "claim order preserved through the merge");
        let snap = engine.metrics.snapshot();
        assert_eq!(snap.served, 2);
        assert_eq!(snap.batches, 1, "one coalesced dispatch despite two callees");
        assert_eq!(snap.batched_calls, 2);
        let io = env.io_snapshot();
        assert_eq!(io.batched_writes, 2, "both frames committed through the batch pad");
        assert_eq!(io.batched_cross_callee, 1, "one frame joined across a callee boundary");
        // Different streams never merge: same two callees, stdout vs
        // stderr, whatever sweep(s) they land in.
        fill(0, id_a, "XX", FD_STDOUT);
        fill(1, id_b, "YY", FD_STDERR);
        drain(0, 2);
        drain(1, 2);
        assert_eq!(env.stdout_string(), "AABBXX");
        assert_eq!(env.stderr_string(), "YY");
        let io = env.io_snapshot();
        assert_eq!(io.batched_cross_callee, 1, "distinct streams stayed separate runs");
        engine.stop();
    }

    #[test]
    fn unknown_callee_in_sweep_sets_flag() {
        let (mem, arena, reg, env) = setup(2);
        let engine = RpcEngine::start(
            Arc::clone(&mem),
            arena,
            reg,
            env,
            EngineConfig { lanes: 2, workers: 1, ..EngineConfig::default() },
        );
        let mut client = RpcClient::for_team(&mem, arena, 0);
        let info = RpcArgInfo::new();
        assert_eq!(client.call(999, &info, None), -1);
        engine.stop();
    }

    #[test]
    fn idle_worker_steals_from_busy_workers_lanes() {
        // 4 lanes × 2 workers: w0 owns {0,2}, w1 owns {1,3}. Park w1 in a
        // slow wrapper on lane 1, then drive lane 3 (also w1's): only w0
        // can serve it, via stealing.
        let (mem, arena, reg, env) = setup(4);
        let slow = reg.register(
            "__slow",
            Box::new(|_, _| {
                std::thread::sleep(std::time::Duration::from_millis(60));
                0
            }),
        );
        let fast = reg.register("__id_i", Box::new(|f, _| f.val(0) as i64));
        let engine = RpcEngine::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&reg),
            env,
            EngineConfig { lanes: 4, workers: 2, ..EngineConfig::default() },
        );
        std::thread::scope(|s| {
            let mem_ref = &mem;
            s.spawn(move || {
                let mut client = RpcClient::for_team(mem_ref, arena, 1);
                assert_eq!(client.call(slow, &RpcArgInfo::new(), None), 0);
            });
            std::thread::sleep(std::time::Duration::from_millis(10));
            let mut client = RpcClient::for_team(&mem, arena, 3);
            for k in 0..5u64 {
                let mut info = RpcArgInfo::new();
                info.add_val(k);
                assert_eq!(client.call(fast, &info, None), k as i64);
            }
        });
        let snap = engine.metrics.snapshot();
        assert_eq!(snap.served, 6);
        assert!(snap.steals >= 1, "lane 3 requests were served while its owner slept");
        engine.stop();
    }

    #[test]
    fn launch_runs_on_executor_not_on_the_claiming_worker() {
        // The deadlock regression at the protocol level: a "launch" pad
        // that itself issues an RPC through the single lane, at
        // lanes=1, workers=1, launch_threads=1. Pre-executor this hung —
        // the only worker ran the launch and nobody answered the nested
        // call.
        let (mem, arena, reg, env) = setup(1);
        let inner = reg.register("__id_i", Box::new(|f, _| f.val(0) as i64));
        let mem_for_launch = Arc::clone(&mem);
        let launch_id = reg.register(
            "__nested_launch_i",
            Box::new(move |f, _| {
                // The "kernel": one nested RPC through the regular lane.
                let mut client = RpcClient::for_team(&mem_for_launch, ArenaLayout::legacy(), 0);
                let mut info = RpcArgInfo::new();
                info.add_val(f.val(0));
                client.call(inner, &info, None)
            }),
        );
        reg.mark_launch("__nested_launch_i");
        let engine = RpcEngine::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&reg),
            env,
            EngineConfig::default(),
        );
        let mut client = RpcClient::for_launch(&mem, arena);
        let mut info = RpcArgInfo::new();
        info.add_val(41);
        assert_eq!(client.call(launch_id, &info, None), 41);
        assert_eq!(client.last.lane, arena.launch_index());
        let snap = engine.metrics.snapshot();
        assert_eq!(snap.launches, 1, "launch went through the executor");
        assert_eq!(snap.served, 2, "launch + the nested call");
        assert_eq!(snap.launch_queue_depth, 0);
        assert!(snap.launch_queue_peak >= 1);
        assert!(snap.launch_latency_ns() > 0.0);
        engine.stop();
    }

    #[test]
    fn launch_ring_serves_concurrent_launch_clients() {
        // Two launch clients, a two-slot ring, two executor threads: the
        // launches must ride distinct ring slots and overlap in time
        // (ring occupancy peak >= 2). A rendezvous inside the pad makes
        // the overlap deterministic rather than probabilistic.
        let (mem, _, reg, env) = setup(1);
        let arena = ArenaLayout::for_shape(1, 2);
        let gate = Arc::new(AtomicU64::new(0));
        let gate_in_pad = Arc::clone(&gate);
        let id = reg.register(
            "__rendezvous_launch_i",
            Box::new(move |f, _| {
                gate_in_pad.fetch_add(1, Ordering::SeqCst);
                let t0 = std::time::Instant::now();
                while gate_in_pad.load(Ordering::SeqCst) < 2 {
                    if t0.elapsed() > std::time::Duration::from_secs(10) {
                        return -1;
                    }
                    std::thread::yield_now();
                }
                f.val(0) as i64
            }),
        );
        reg.mark_launch("__rendezvous_launch_i");
        let engine = RpcEngine::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&reg),
            env,
            EngineConfig { launch_slots: 2, launch_threads: 2, ..EngineConfig::default() },
        );
        let lanes_used: Vec<usize> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..2u64)
                .map(|session| {
                    let mem = &mem;
                    s.spawn(move || {
                        let mut client =
                            RpcClient::for_launch_session(mem, arena, session as usize);
                        let mut info = RpcArgInfo::new();
                        info.add_val(40 + session);
                        assert_eq!(client.call(id, &info, None), 40 + session as i64);
                        client.last.lane
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_ne!(lanes_used[0], lanes_used[1], "launches rode distinct ring slots");
        assert!(lanes_used.iter().all(|&l| arena.is_launch_slot(l)));
        let snap = engine.metrics.snapshot();
        assert_eq!(snap.launches, 2);
        assert!(snap.ring_peak >= 2, "two launches in flight at once: {snap:?}");
        assert_eq!(snap.ring_in_flight, 0);
        assert_eq!(engine.metrics.ring_completions().iter().sum::<u64>(), 2);
        assert!(engine.metrics.ring_completions().iter().all(|&n| n == 1));
        engine.stop();
    }

    #[test]
    fn launch_on_a_regular_lane_still_routes_to_executor() {
        // A launch callee arriving on a regular lane (generic client)
        // must also be handed to the executor, with completion written
        // back to that lane.
        let (mem, arena, reg, env) = setup(2);
        let id = reg.register("__fake_launch_i", Box::new(|f, _| f.val(0) as i64 + 100));
        reg.mark_launch("__fake_launch_i");
        let engine = RpcEngine::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&reg),
            env,
            EngineConfig { lanes: 2, workers: 1, ..EngineConfig::default() },
        );
        let mut client = RpcClient::for_team(&mem, arena, 1);
        let mut info = RpcArgInfo::new();
        info.add_val(7);
        assert_eq!(client.call(id, &info, None), 107);
        assert_eq!(client.last.lane, 1, "request rode lane 1");
        let snap = engine.metrics.snapshot();
        assert_eq!(snap.launches, 1);
        assert_eq!(snap.ring_peak, 0, "a lane-carried launch never occupies the ring");
        assert_eq!(engine.metrics.ring_completions(), vec![0]);
        engine.stop();
    }

    #[test]
    fn occupancy_and_json_report() {
        let (mem, arena, reg, env) = setup(2);
        let id = reg.register("__id_i", Box::new(|f, _| f.val(0) as i64));
        let engine = RpcEngine::start(
            Arc::clone(&mem),
            arena,
            reg,
            env,
            EngineConfig { lanes: 2, workers: 1, ..EngineConfig::default() },
        );
        let mut client = RpcClient::for_team(&mem, arena, 0);
        for k in 0..10u64 {
            let mut info = RpcArgInfo::new();
            info.add_val(k);
            client.call(id, &info, None);
        }
        let snap = engine.metrics.snapshot();
        assert_eq!(snap.served, 10);
        assert!(snap.polls > 0);
        assert!((0.0..=1.0).contains(&snap.occupancy()));
        let j = engine.metrics.to_json().to_string();
        assert!(j.contains("\"per_lane\""), "json report lists lanes: {j}");
        assert!(snap.summary().contains("served=10"));
        engine.stop();
    }
}
