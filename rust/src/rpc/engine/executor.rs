//! The dedicated kernel-split launch executor.
//!
//! The paper's single-threaded server runs a kernel-split launch RPC
//! (§3.3) *inside* the thread that claimed it; PR 1's worker pool
//! inherited that shape, so a kernel that itself issued RPCs needed a
//! second worker to answer them — and deadlocked at the default
//! `lanes=1, workers=1` configuration. This module removes the
//! constraint: poll workers hand launch frames to a small dedicated
//! thread pool over a bounded queue and immediately resume polling, so
//! the claiming worker is never occupied for the duration of a kernel.
//!
//! Completion writeback stays on the owning slot: when the launch
//! wrapper returns, the executor thread copies mutated objects back,
//! stores ret/flags and rings `ST_DONE` on the mailbox the request
//! arrived on — the device-side client protocol is unchanged.
//!
//! Paired with the arena's launch ring ([`ArenaLayout::launch_slot_at`]),
//! this makes in-kernel RPCs correct at every `lanes × workers ×
//! launch-threads × launch-slots` shape, including `1 × 1 × 1 × 1` —
//! and with a ring and pool wider than one, N kernel-split launches are
//! genuinely in flight at once (tracked by the ring-occupancy gauges
//! `ring_in_flight`/`ring_peak` and the per-slot completion/latency
//! counters).

use super::arena::ArenaLayout;
use super::server::EngineMetrics;
use crate::gpu::memory::DeviceMemory;
use crate::rpc::mailbox::ST_DONE;
use crate::rpc::server::{writeback_frame, RpcFrame, WrapperRegistry};
use crate::rpc::wrappers::{with_lane_ctx, HostEnv};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};

/// One claimed launch request, unpacked and ready to run. The slot index
/// identifies the mailbox the completion must be written back to.
pub struct LaunchJob {
    /// Arena slot the request arrived on (usually the dedicated launch
    /// slot, but a launch callee claimed on a regular lane routes here
    /// too).
    pub slot: usize,
    pub callee: u64,
    pub frame: RpcFrame,
    enqueued: std::time::Instant,
}

impl LaunchJob {
    pub fn new(slot: usize, callee: u64, frame: RpcFrame) -> Self {
        Self { slot, callee, frame, enqueued: std::time::Instant::now() }
    }
}

/// Dedicated launch thread pool: a bounded job queue drained by
/// `--rpc-launch-threads` host threads.
pub struct LaunchExecutor {
    tx: Option<SyncSender<LaunchJob>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl LaunchExecutor {
    /// Spawn `threads` executor threads serving launch frames against
    /// `registry`/`env`, writing completions back into `arena` slots.
    pub fn start(
        mem: Arc<DeviceMemory>,
        arena: ArenaLayout,
        registry: Arc<WrapperRegistry>,
        env: Arc<HostEnv>,
        threads: usize,
        metrics: Arc<EngineMetrics>,
    ) -> Self {
        assert!(threads >= 1, "launch executor needs at least one thread");
        // Capacity: one in-flight launch per arena slot is the most the
        // protocol can produce; `try_submit` still handles Full by
        // letting the worker re-arm the slot.
        let (tx, rx) = sync_channel::<LaunchJob>(arena.slot_count());
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(threads);
        for t in 0..threads {
            let mem = Arc::clone(&mem);
            let registry = Arc::clone(&registry);
            let env = Arc::clone(&env);
            let metrics = Arc::clone(&metrics);
            let rx = Arc::clone(&rx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("rpc-launch-{t}"))
                    .spawn(move || executor_loop(&mem, arena, &registry, &env, &metrics, &rx))
                    .expect("spawn rpc launch executor"),
            );
        }
        Self { tx: Some(tx), handles }
    }

    /// Hand a claimed launch frame to the pool without blocking. On a
    /// full queue the job is returned so the caller can re-arm the slot
    /// (`ST_SERVING -> ST_REQUEST`) and retry on a later sweep.
    pub fn try_submit(&self, job: LaunchJob) -> Result<(), LaunchJob> {
        match self.tx.as_ref().expect("executor running").try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(job)) | Err(TrySendError::Disconnected(job)) => Err(job),
        }
    }

    /// Drain the queue and join the pool (every queued launch still
    /// completes and notifies its slot).
    pub fn stop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for LaunchExecutor {
    fn drop(&mut self) {
        self.stop();
    }
}

fn executor_loop(
    mem: &DeviceMemory,
    arena: ArenaLayout,
    registry: &WrapperRegistry,
    env: &HostEnv,
    metrics: &EngineMetrics,
    rx: &Mutex<Receiver<LaunchJob>>,
) {
    loop {
        // Holding the lock only while *waiting*: the job is served with
        // the receiver released so a multi-thread pool runs launches
        // concurrently.
        let job = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => break,
        };
        let Ok(mut job) = job else { break };
        let wait_ns = job.enqueued.elapsed().as_nanos() as u64;
        metrics.launch_queued.fetch_sub(1, Ordering::Relaxed);
        // Ring occupancy: launches running on executor threads right
        // now. Only jobs that actually rode a ring slot count — a
        // launch callee arriving on a regular lane must not inflate the
        // gauge past what the ring provided. The high-water mark is the
        // proof of genuine launch concurrency (peak >= 2 needs a ring
        // and a pool wider than 1).
        let on_ring = job.slot >= arena.lanes;
        if on_ring {
            let in_flight = metrics.ring_in_flight.fetch_add(1, Ordering::Relaxed) + 1;
            metrics.ring_peak.fetch_max(in_flight, Ordering::Relaxed);
        }
        let t0 = std::time::Instant::now();
        // Invoke the launch wrapper under the owning slot's lane context
        // (HostEnv shard selection), exactly like a worker-served pad.
        let (ret, flags) = match registry.get(job.callee) {
            Some(w) => (with_lane_ctx(job.slot, || w(&mut job.frame, env)), 0),
            None => (-1, 1),
        };
        // Stage-4 completion writeback on the owning slot: copy-back,
        // ret/flags, then the ST_DONE doorbell the client spins on.
        let mb = arena.slot(mem, job.slot);
        writeback_frame(&mb, &job.frame);
        mb.set_ret(ret);
        mb.set_flags(flags);
        let run_ns = t0.elapsed().as_nanos() as u64;
        metrics.launches.fetch_add(1, Ordering::Relaxed);
        metrics.served.fetch_add(1, Ordering::Relaxed);
        metrics.launch_wait_ns.fetch_add(wait_ns, Ordering::Relaxed);
        metrics.launch_run_ns.fetch_add(run_ns, Ordering::Relaxed);
        mem.obs.launch_queue_wait.record(wait_ns);
        mem.obs.launch_run.record(run_ns);
        if mem.obs.spans.is_enabled() {
            let now = mem.obs.spans.now_ns();
            let kind = crate::obs::SpanKind::LaunchSlot;
            let track = job.slot as u64;
            mem.obs.spans.record(
                "queue-wait",
                kind,
                track,
                now.saturating_sub(run_ns + wait_ns),
                wait_ns,
            );
            mem.obs.spans.record("run", kind, track, now.saturating_sub(run_ns), run_ns);
        }
        // Per-ring-slot completion/latency gauges (launch callees that
        // arrived on a regular lane count in launches/served only).
        if on_ring {
            if let Some(rc) = metrics.ring.get(job.slot - arena.lanes) {
                rc.completions.fetch_add(1, Ordering::Relaxed);
                rc.wait_ns.fetch_add(wait_ns, Ordering::Relaxed);
                rc.run_ns.fetch_add(run_ns, Ordering::Relaxed);
            }
            metrics.ring_in_flight.fetch_sub(1, Ordering::Relaxed);
        }
        mb.set_status(ST_DONE);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::memory::MemConfig;
    use crate::rpc::engine::server::EngineConfig;
    use crate::rpc::mailbox::{WireArg, KIND_VAL, ST_REQUEST, ST_SERVING};
    use crate::rpc::server::{unpack_frame, HostArg};
    use crate::rpc::wrappers::register_common;
    use std::sync::atomic::AtomicU64;

    fn fill_launch_request(mb: &crate::rpc::mailbox::Mailbox<'_>, callee: u64, v: u64) {
        mb.set_callee(callee);
        mb.set_nargs(1);
        mb.write_arg(0, WireArg { kind: KIND_VAL, value: v, mode: 0, size: 0, offset: 0 });
        mb.set_status(ST_REQUEST);
    }

    #[test]
    fn completion_writes_back_to_owning_slot() {
        let mem = Arc::new(DeviceMemory::new(MemConfig::small()));
        let arena = ArenaLayout::legacy();
        let reg = Arc::new(WrapperRegistry::new());
        let id = reg.register(
            "__fake_launch_i",
            Box::new(|f: &mut RpcFrame, _: &HostEnv| f.val(0) as i64 * 2),
        );
        reg.mark_launch("__fake_launch_i");
        let env = Arc::new(HostEnv::new());
        let metrics = Arc::new(EngineMetrics::new(EngineConfig::default()));
        let mut exec = LaunchExecutor::start(
            Arc::clone(&mem),
            arena,
            Arc::clone(&reg),
            env,
            1,
            Arc::clone(&metrics),
        );
        let mb = arena.launch_slot(&mem);
        fill_launch_request(&mb, id, 21);
        // Simulate the worker's claim + hand-off.
        assert!(mb.cas_status(ST_REQUEST, ST_SERVING));
        let (callee, frame) = unpack_frame(&mb);
        metrics.launch_queued.fetch_add(1, Ordering::Relaxed);
        exec.try_submit(LaunchJob::new(arena.launch_index(), callee, frame)).unwrap();
        let mut spins = 0u64;
        while mb.status() != ST_DONE {
            std::thread::yield_now();
            spins += 1;
            assert!(spins < 50_000_000, "launch never completed");
        }
        assert_eq!(mb.ret(), 42);
        assert_eq!(mb.flags(), 0);
        let snap = metrics.snapshot();
        assert_eq!(snap.launches, 1);
        assert_eq!(snap.served, 1);
        exec.stop();
    }

    #[test]
    fn unknown_launch_callee_flags_failure() {
        let mem = Arc::new(DeviceMemory::new(MemConfig::small()));
        let arena = ArenaLayout::legacy();
        let reg = Arc::new(WrapperRegistry::new());
        register_common(&reg);
        let env = Arc::new(HostEnv::new());
        let metrics = Arc::new(EngineMetrics::new(EngineConfig::default()));
        let mut exec = LaunchExecutor::start(
            Arc::clone(&mem),
            arena,
            reg,
            env,
            1,
            Arc::clone(&metrics),
        );
        metrics.launch_queued.fetch_add(1, Ordering::Relaxed);
        exec.try_submit(LaunchJob::new(
            arena.launch_index(),
            9999,
            RpcFrame { args: vec![HostArg::Val(0)] },
        ))
        .unwrap();
        let mb = arena.launch_slot(&mem);
        while mb.status() != ST_DONE {
            std::thread::yield_now();
        }
        assert_eq!(mb.ret(), -1);
        assert_eq!(mb.flags(), 1);
        exec.stop();
    }

    #[test]
    fn stop_drains_queued_launches() {
        let mem = Arc::new(DeviceMemory::new(MemConfig::small()));
        let arena = ArenaLayout::for_lanes(2);
        let reg = Arc::new(WrapperRegistry::new());
        let id = reg.register(
            "__slow_launch_i",
            Box::new(|f: &mut RpcFrame, _: &HostEnv| {
                std::thread::sleep(std::time::Duration::from_millis(10));
                f.val(0) as i64
            }),
        );
        reg.mark_launch("__slow_launch_i");
        let env = Arc::new(HostEnv::new());
        let metrics = Arc::new(EngineMetrics::new(EngineConfig {
            lanes: 2,
            ..EngineConfig::default()
        }));
        let mut exec = LaunchExecutor::start(
            Arc::clone(&mem),
            arena,
            reg,
            env,
            1,
            Arc::clone(&metrics),
        );
        // Queue two jobs on distinct slots, then stop immediately: both
        // must still complete and notify.
        for (slot, v) in [(0usize, 5u64), (arena.launch_index(), 7u64)] {
            metrics.launch_queued.fetch_add(1, Ordering::Relaxed);
            exec.try_submit(LaunchJob::new(slot, id, RpcFrame { args: vec![HostArg::Val(v)] }))
                .unwrap();
        }
        exec.stop();
        assert_eq!(arena.slot(&mem, 0).ret(), 5);
        assert_eq!(arena.launch_slot(&mem).ret(), 7);
        assert_eq!(metrics.snapshot().launches, 2);
    }

    #[test]
    fn ring_peak_counts_concurrent_launches() {
        // Two launch jobs on distinct ring slots, two executor threads:
        // a rendezvous inside the pad proves both run simultaneously,
        // and the ring-occupancy peak must record it.
        let mem = Arc::new(DeviceMemory::new(MemConfig::small()));
        let arena = ArenaLayout::for_shape(1, 2);
        let reg = Arc::new(WrapperRegistry::new());
        let gate = Arc::new(AtomicU64::new(0));
        let gate_in_pad = Arc::clone(&gate);
        let id = reg.register(
            "__rendezvous_launch_i",
            Box::new(move |f: &mut RpcFrame, _: &HostEnv| {
                gate_in_pad.fetch_add(1, Ordering::SeqCst);
                let t0 = std::time::Instant::now();
                // Wait (bounded) until the other launch is running too.
                while gate_in_pad.load(Ordering::SeqCst) < 2 {
                    if t0.elapsed() > std::time::Duration::from_secs(10) {
                        return -1;
                    }
                    std::thread::yield_now();
                }
                f.val(0) as i64
            }),
        );
        reg.mark_launch("__rendezvous_launch_i");
        let env = Arc::new(HostEnv::new());
        let metrics = Arc::new(EngineMetrics::new(EngineConfig {
            launch_slots: 2,
            launch_threads: 2,
            ..EngineConfig::default()
        }));
        let mut exec = LaunchExecutor::start(
            Arc::clone(&mem),
            arena,
            reg,
            env,
            2,
            Arc::clone(&metrics),
        );
        for (ring, v) in [(0usize, 5u64), (1, 7)] {
            metrics.launch_queued.fetch_add(1, Ordering::Relaxed);
            exec.try_submit(LaunchJob::new(
                arena.launch_index() + ring,
                id,
                RpcFrame { args: vec![HostArg::Val(v)] },
            ))
            .unwrap();
        }
        exec.stop();
        assert_eq!(arena.launch_slot_at(&mem, 0).ret(), 5, "rendezvous reached on slot 0");
        assert_eq!(arena.launch_slot_at(&mem, 1).ret(), 7, "rendezvous reached on slot 1");
        let snap = metrics.snapshot();
        assert_eq!(snap.launches, 2);
        assert!(snap.ring_peak >= 2, "two launches were in flight at once: {snap:?}");
        assert_eq!(snap.ring_in_flight, 0, "nothing left running");
    }
}
