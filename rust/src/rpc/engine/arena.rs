//! The multi-lane mailbox arena: N independent RPC slots carved out of
//! the base of the managed segment, one lane per team (lane = `team_id %
//! lanes`), so device threads in different teams no longer serialize on
//! the paper's single slot.
//!
//! Layout: lanes are packed back to back, each `DATA_OFF + data_cap`
//! bytes. `DATA_OFF`, `data_cap` and `SLOT_BASE` are all 64-byte
//! multiples (const-asserted in [`mailbox`]), so every lane header sits
//! on its own cache line — concurrent polling by engine workers never
//! false-shares a line with a neighbouring lane's doorbell.
//!
//! After the regular lanes comes the **launch ring**
//! (`--rpc-launch-slots`, default 1): dedicated slots the mailbox
//! kernel-split launch RPCs (paper §3.3) ride on. Keeping launches off
//! the regular lanes is what makes in-kernel RPCs live at every engine
//! shape: while a launch is in flight (served by the [`executor`]),
//! every regular lane stays available for the RPCs the kernel itself
//! issues — even at `lanes=1`. A ring wider than one slot lets N
//! kernel-split launches be genuinely in flight at once (concurrent
//! sessions); launch clients claim a free ring slot with backpressure.
//!
//! ```text
//! SLOT_BASE                 + stride              + lanes*stride
//! | hdr | pad | DATA lane0 | hdr | pad | DATA l1 | ... | ring0 | ring1 | ... |
//!   ^--- stride = DATA_OFF + data_cap ---^
//! ```
//!
//! Each slot of [`ArenaLayout::legacy`] (1 lane × 1 MiB data, plus a
//! one-slot launch ring) has exactly the shape the single-slot prototype
//! reserved (`MAILBOX_RESERVED`), which is what keeps the default
//! `lanes=1,workers=1,launch_slots=1` path bit-identical to the paper's
//! Fig. 7 setup.
//!
//! [`mailbox`]: crate::rpc::mailbox
//! [`executor`]: super::executor

use crate::gpu::memory::DeviceMemory;
use crate::rpc::mailbox::{Mailbox, DATA_CAP, DATA_OFF, MAILBOX_RESERVED, SLOT_BASE};

/// Per-lane data capacity used by multi-lane arenas. Smaller than the
/// legacy 1 MiB so 8+ lanes fit comfortably in the managed segment;
/// still far above what the libc-style calls the evaluation issues ever
/// stage.
pub const MULTI_LANE_DATA_CAP: u64 = 256 << 10;

/// Shape of the mailbox arena. Copy-cheap; the [`Device`] owns one and
/// clients/engine workers carry copies.
///
/// [`Device`]: crate::gpu::grid::Device
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaLayout {
    pub lanes: usize,
    /// DATA region bytes per lane.
    pub data_cap: u64,
    /// Width of the kernel-split launch ring (`--rpc-launch-slots`):
    /// dedicated launch slots tiled after the lanes. 1 = the single
    /// dedicated launch slot (the byte-identical legacy arrangement).
    pub launch_slots: usize,
}

impl Default for ArenaLayout {
    fn default() -> Self {
        Self::legacy()
    }
}

impl ArenaLayout {
    /// The paper's single-slot layout: one lane, 1 MiB data region,
    /// one-slot launch ring.
    pub const fn legacy() -> Self {
        Self { lanes: 1, data_cap: DATA_CAP, launch_slots: 1 }
    }

    /// An arena with a single-slot launch ring (the pre-ring shape).
    pub fn new(lanes: usize, data_cap: u64) -> Self {
        Self::with_ring(lanes, data_cap, 1)
    }

    /// Fully explicit shape: `lanes` regular lanes of `data_cap` bytes
    /// each, followed by a `launch_slots`-wide launch ring of the same
    /// stride.
    pub fn with_ring(lanes: usize, data_cap: u64, launch_slots: usize) -> Self {
        assert!(lanes >= 1, "arena needs at least one lane");
        assert!(launch_slots >= 1, "launch ring needs at least one slot");
        assert!(
            data_cap > 0 && data_cap % 64 == 0,
            "lane data capacity must be a positive cache-line multiple"
        );
        Self { lanes, data_cap, launch_slots }
    }

    /// The default shape for a lane count: the legacy layout for one
    /// lane (Fig. 7 reproducibility), [`MULTI_LANE_DATA_CAP`] otherwise.
    pub fn for_lanes(lanes: usize) -> Self {
        Self::for_shape(lanes, 1)
    }

    /// The default shape for a `lanes × launch_slots` request: exactly
    /// [`ArenaLayout::legacy`] for `1 × 1` (the byte-identical paper
    /// layout), [`MULTI_LANE_DATA_CAP`] per slot for anything wider —
    /// a multi-slot ring trades the legacy 1 MiB staging region for
    /// fitting more concurrent sessions in the managed segment.
    pub fn for_shape(lanes: usize, launch_slots: usize) -> Self {
        if lanes <= 1 && launch_slots <= 1 {
            Self::legacy()
        } else {
            Self::with_ring(lanes.max(1), MULTI_LANE_DATA_CAP, launch_slots.max(1))
        }
    }

    /// Bytes from one lane's base to the next (header pad + data).
    pub const fn lane_stride(&self) -> u64 {
        DATA_OFF + self.data_cap
    }

    /// Total slots: the regular lanes plus the launch ring.
    pub const fn slot_count(&self) -> usize {
        self.lanes + self.launch_slots
    }

    /// Slot index of the launch ring's first slot (it sits after the
    /// last regular lane).
    pub const fn launch_index(&self) -> usize {
        self.lanes
    }

    /// Is `idx` one of the launch ring's slots?
    pub const fn is_launch_slot(&self, idx: usize) -> bool {
        idx >= self.lanes && idx < self.slot_count()
    }

    /// Managed bytes the whole arena occupies from `SLOT_BASE`
    /// (regular lanes + the launch ring).
    pub const fn reserved_bytes(&self) -> u64 {
        self.slot_count() as u64 * self.lane_stride()
    }

    pub fn lane_base(&self, lane: usize) -> u64 {
        assert!(lane < self.lanes, "lane {lane} out of range ({} lanes)", self.lanes);
        SLOT_BASE + lane as u64 * self.lane_stride()
    }

    /// Base address of the launch ring's first slot.
    pub const fn launch_base(&self) -> u64 {
        SLOT_BASE + self.lanes as u64 * self.lane_stride()
    }

    /// Base address of ring slot `ring` (`0..launch_slots`).
    pub fn launch_base_at(&self, ring: usize) -> u64 {
        assert!(
            ring < self.launch_slots,
            "ring slot {ring} out of range ({} launch slots)",
            self.launch_slots
        );
        self.launch_base() + ring as u64 * self.lane_stride()
    }

    /// A typed mailbox view over one lane.
    pub fn lane<'a>(&self, mem: &'a DeviceMemory, lane: usize) -> Mailbox<'a> {
        Mailbox::at(mem, self.lane_base(lane), self.data_cap)
    }

    /// A typed mailbox view over the launch ring's first slot (the
    /// whole ring on the default one-slot shape).
    pub fn launch_slot<'a>(&self, mem: &'a DeviceMemory) -> Mailbox<'a> {
        self.launch_slot_at(mem, 0)
    }

    /// A typed mailbox view over ring slot `ring` (`0..launch_slots`).
    pub fn launch_slot_at<'a>(&self, mem: &'a DeviceMemory, ring: usize) -> Mailbox<'a> {
        Mailbox::at(mem, self.launch_base_at(ring), self.data_cap)
    }

    /// A typed mailbox view over any slot: regular lanes at `0..lanes`,
    /// the launch ring at `lanes..lanes + launch_slots`
    /// ([`Self::launch_index`] onward).
    pub fn slot<'a>(&self, mem: &'a DeviceMemory, idx: usize) -> Mailbox<'a> {
        if idx >= self.lanes {
            self.launch_slot_at(mem, idx - self.lanes)
        } else {
            self.lane(mem, idx)
        }
    }
}

// Every slot of the degenerate arena has exactly the shape the
// single-slot prototype reserved, so the legacy lane keeps its
// historical managed-memory address and layout; the one-slot launch
// ring tiles right after it. The legacy RpcServer polls these addresses
// through this same layout value, so the two can never diverge.
const _: () = assert!(ArenaLayout::legacy().lane_stride() == MAILBOX_RESERVED);
const _: () = assert!(ArenaLayout::legacy().launch_slots == 1);
const _: () = assert!(ArenaLayout::legacy().reserved_bytes() == 2 * MAILBOX_RESERVED);
const _: () = assert!(ArenaLayout::legacy().launch_base() == SLOT_BASE + MAILBOX_RESERVED);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::memory::{MemConfig, Segment};
    use crate::rpc::mailbox::{ST_IDLE, ST_REQUEST};

    #[test]
    fn legacy_matches_single_slot_reservation() {
        let a = ArenaLayout::legacy();
        assert_eq!(a.lanes, 1);
        assert_eq!(a.lane_stride(), MAILBOX_RESERVED, "legacy lane = the prototype's slot");
        assert_eq!(a.reserved_bytes(), 2 * MAILBOX_RESERVED, "plus the launch slot");
        assert_eq!(a.lane_base(0), SLOT_BASE);
        assert_eq!(a.launch_base(), SLOT_BASE + MAILBOX_RESERVED);
        assert_eq!(a.launch_index(), 1);
        assert_eq!(a.launch_slots, 1);
        assert_eq!(ArenaLayout::for_lanes(1), a);
        assert_eq!(ArenaLayout::for_shape(1, 1), a);
        assert_eq!(ArenaLayout::default(), a);
    }

    #[test]
    fn launch_ring_tiles_after_the_lanes() {
        let a = ArenaLayout::for_shape(2, 3);
        assert_eq!(a.lanes, 2);
        assert_eq!(a.launch_slots, 3);
        assert_eq!(a.slot_count(), 5);
        assert_eq!(a.data_cap, MULTI_LANE_DATA_CAP, "rings wider than 1 use the multi-lane cap");
        for r in 0..3 {
            assert_eq!(a.launch_base_at(r), a.launch_base() + r as u64 * a.lane_stride());
            assert_eq!(a.launch_base_at(r) % 64, 0, "ring slot {r} base not cache-line aligned");
            assert!(a.is_launch_slot(a.lanes + r));
        }
        assert!(!a.is_launch_slot(0));
        assert!(!a.is_launch_slot(a.slot_count()));
        assert_eq!(a.launch_base_at(2) + a.lane_stride(), SLOT_BASE + a.reserved_bytes());
    }

    #[test]
    fn ring_slots_are_independent_mailboxes() {
        let mem = DeviceMemory::new(MemConfig::small());
        let a = ArenaLayout::for_shape(1, 2);
        let (r0, r1) = (a.launch_slot_at(&mem, 0), a.launch_slot_at(&mem, 1));
        r0.set_callee(10);
        r1.set_callee(11);
        r0.write_data(0, b"ring0");
        r1.write_data(0, b"ring1");
        assert!(r0.cas_status(ST_IDLE, ST_REQUEST));
        assert_eq!(r1.status(), ST_IDLE, "ring slot 1 unaffected by slot 0's doorbell");
        assert_eq!(r0.read_data(0, 5), b"ring0");
        assert_eq!(r1.read_data(0, 5), b"ring1");
        assert_eq!(a.slot(&mem, 1).base(), a.launch_base_at(0));
        assert_eq!(a.slot(&mem, 2).base(), a.launch_base_at(1));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn ring_index_bounds_checked() {
        ArenaLayout::for_shape(1, 2).launch_base_at(2);
    }

    #[test]
    fn lanes_tile_without_gaps_or_overlap() {
        let a = ArenaLayout::for_lanes(4);
        for i in 0..4 {
            assert_eq!(a.lane_base(i) % 64, 0, "lane {i} base not cache-line aligned");
            if i > 0 {
                // Lane i starts exactly where lane i-1's data region ends.
                assert_eq!(a.lane_base(i), a.lane_base(i - 1) + DATA_OFF + a.data_cap);
            }
        }
        // The launch slot tiles right after the last lane and closes the
        // reservation.
        assert_eq!(a.launch_base(), a.lane_base(3) + a.lane_stride());
        assert_eq!(a.launch_base() % 64, 0);
        assert_eq!(a.launch_base() + a.lane_stride(), SLOT_BASE + a.reserved_bytes());
    }

    #[test]
    fn launch_slot_is_independent_of_lanes() {
        let mem = DeviceMemory::new(MemConfig::small());
        let a = ArenaLayout::for_lanes(2);
        let launch = a.launch_slot(&mem);
        launch.set_callee(77);
        launch.write_data(0, b"launch");
        assert!(launch.cas_status(ST_IDLE, ST_REQUEST));
        for i in 0..2 {
            assert_eq!(a.lane(&mem, i).status(), ST_IDLE, "lane {i} unaffected");
        }
        assert_eq!(a.slot(&mem, a.launch_index()).callee(), 77);
        assert_eq!(a.slot(&mem, 0).base(), a.lane_base(0));
        assert_eq!(launch.read_data(0, 6), b"launch");
    }

    #[test]
    fn lanes_are_independent_slots() {
        let mem = DeviceMemory::new(MemConfig::small());
        let a = ArenaLayout::for_lanes(3);
        assert_eq!(mem.segment(a.lane_base(2) + a.lane_stride() - 1), Segment::Managed);
        for i in 0..3 {
            let mb = a.lane(&mem, i);
            mb.set_callee(100 + i as u64);
            mb.write_data(0, &[i as u8; 64]);
        }
        for i in 0..3 {
            let mb = a.lane(&mem, i);
            assert_eq!(mb.callee(), 100 + i as u64);
            assert_eq!(mb.read_data(0, 64), vec![i as u8; 64]);
            assert_eq!(mb.status(), ST_IDLE);
        }
        // Status transitions stay per-lane.
        assert!(a.lane(&mem, 1).cas_status(ST_IDLE, ST_REQUEST));
        assert_eq!(a.lane(&mem, 0).status(), ST_IDLE);
        assert_eq!(a.lane(&mem, 2).status(), ST_IDLE);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn lane_index_bounds_checked() {
        ArenaLayout::for_lanes(2).lane_base(2);
    }
}
