//! The multi-lane mailbox arena: N independent RPC slots carved out of
//! the base of the managed segment, one lane per team (lane = `team_id %
//! lanes`), so device threads in different teams no longer serialize on
//! the paper's single slot.
//!
//! Layout: lanes are packed back to back, each `DATA_OFF + data_cap`
//! bytes. `DATA_OFF`, `data_cap` and `SLOT_BASE` are all 64-byte
//! multiples (const-asserted in [`mailbox`]), so every lane header sits
//! on its own cache line — concurrent polling by engine workers never
//! false-shares a line with a neighbouring lane's doorbell.
//!
//! After the regular lanes comes one **dedicated launch slot**: the
//! mailbox kernel-split launch RPCs (paper §3.3) ride on. Keeping
//! launches off the regular lanes is what makes in-kernel RPCs live at
//! every engine shape: while a launch is in flight (served by the
//! [`executor`]), every regular lane stays available for the RPCs the
//! kernel itself issues — even at `lanes=1`.
//!
//! ```text
//! SLOT_BASE                 + stride              + lanes*stride
//! | hdr | pad | DATA lane0 | hdr | pad | DATA l1 | ... | launch slot |
//!   ^--- stride = DATA_OFF + data_cap ---^
//! ```
//!
//! Each slot of [`ArenaLayout::legacy`] (1 lane × 1 MiB data, plus the
//! launch slot) has exactly the shape the single-slot prototype reserved
//! (`MAILBOX_RESERVED`), which is what keeps the `lanes=1,workers=1`
//! path bit-identical to the paper's Fig. 7 setup.
//!
//! [`mailbox`]: crate::rpc::mailbox
//! [`executor`]: super::executor

use crate::gpu::memory::DeviceMemory;
use crate::rpc::mailbox::{Mailbox, DATA_CAP, DATA_OFF, MAILBOX_RESERVED, SLOT_BASE};

/// Per-lane data capacity used by multi-lane arenas. Smaller than the
/// legacy 1 MiB so 8+ lanes fit comfortably in the managed segment;
/// still far above what the libc-style calls the evaluation issues ever
/// stage.
pub const MULTI_LANE_DATA_CAP: u64 = 256 << 10;

/// Shape of the mailbox arena. Copy-cheap; the [`Device`] owns one and
/// clients/engine workers carry copies.
///
/// [`Device`]: crate::gpu::grid::Device
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaLayout {
    pub lanes: usize,
    /// DATA region bytes per lane.
    pub data_cap: u64,
}

impl Default for ArenaLayout {
    fn default() -> Self {
        Self::legacy()
    }
}

impl ArenaLayout {
    /// The paper's single-slot layout: one lane, 1 MiB data region.
    pub const fn legacy() -> Self {
        Self { lanes: 1, data_cap: DATA_CAP }
    }

    pub fn new(lanes: usize, data_cap: u64) -> Self {
        assert!(lanes >= 1, "arena needs at least one lane");
        assert!(
            data_cap > 0 && data_cap % 64 == 0,
            "lane data capacity must be a positive cache-line multiple"
        );
        Self { lanes, data_cap }
    }

    /// The default shape for a lane count: the legacy layout for one
    /// lane (Fig. 7 reproducibility), [`MULTI_LANE_DATA_CAP`] otherwise.
    pub fn for_lanes(lanes: usize) -> Self {
        if lanes <= 1 {
            Self::legacy()
        } else {
            Self::new(lanes, MULTI_LANE_DATA_CAP)
        }
    }

    /// Bytes from one lane's base to the next (header pad + data).
    pub const fn lane_stride(&self) -> u64 {
        DATA_OFF + self.data_cap
    }

    /// Total slots: the regular lanes plus the dedicated launch slot.
    pub const fn slot_count(&self) -> usize {
        self.lanes + 1
    }

    /// Slot index of the dedicated kernel-split launch slot (it sits
    /// after the last regular lane).
    pub const fn launch_index(&self) -> usize {
        self.lanes
    }

    /// Managed bytes the whole arena occupies from `SLOT_BASE`
    /// (regular lanes + the launch slot).
    pub const fn reserved_bytes(&self) -> u64 {
        self.slot_count() as u64 * self.lane_stride()
    }

    pub fn lane_base(&self, lane: usize) -> u64 {
        assert!(lane < self.lanes, "lane {lane} out of range ({} lanes)", self.lanes);
        SLOT_BASE + lane as u64 * self.lane_stride()
    }

    /// Base address of the dedicated launch slot.
    pub const fn launch_base(&self) -> u64 {
        SLOT_BASE + self.lanes as u64 * self.lane_stride()
    }

    /// A typed mailbox view over one lane.
    pub fn lane<'a>(&self, mem: &'a DeviceMemory, lane: usize) -> Mailbox<'a> {
        Mailbox::at(mem, self.lane_base(lane), self.data_cap)
    }

    /// A typed mailbox view over the dedicated launch slot.
    pub fn launch_slot<'a>(&self, mem: &'a DeviceMemory) -> Mailbox<'a> {
        Mailbox::at(mem, self.launch_base(), self.data_cap)
    }

    /// A typed mailbox view over any slot: regular lanes at `0..lanes`,
    /// the launch slot at [`Self::launch_index`].
    pub fn slot<'a>(&self, mem: &'a DeviceMemory, idx: usize) -> Mailbox<'a> {
        if idx == self.launch_index() {
            self.launch_slot(mem)
        } else {
            self.lane(mem, idx)
        }
    }
}

// Every slot of the degenerate arena has exactly the shape the
// single-slot prototype reserved, so the legacy lane keeps its
// historical managed-memory address and layout; the launch slot tiles
// right after it.
const _: () = assert!(ArenaLayout::legacy().lane_stride() == MAILBOX_RESERVED);
const _: () = assert!(ArenaLayout::legacy().reserved_bytes() == 2 * MAILBOX_RESERVED);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::memory::{MemConfig, Segment};
    use crate::rpc::mailbox::{ST_IDLE, ST_REQUEST};

    #[test]
    fn legacy_matches_single_slot_reservation() {
        let a = ArenaLayout::legacy();
        assert_eq!(a.lanes, 1);
        assert_eq!(a.lane_stride(), MAILBOX_RESERVED, "legacy lane = the prototype's slot");
        assert_eq!(a.reserved_bytes(), 2 * MAILBOX_RESERVED, "plus the launch slot");
        assert_eq!(a.lane_base(0), SLOT_BASE);
        assert_eq!(a.launch_base(), SLOT_BASE + MAILBOX_RESERVED);
        assert_eq!(a.launch_index(), 1);
        assert_eq!(ArenaLayout::for_lanes(1), a);
    }

    #[test]
    fn lanes_tile_without_gaps_or_overlap() {
        let a = ArenaLayout::for_lanes(4);
        for i in 0..4 {
            assert_eq!(a.lane_base(i) % 64, 0, "lane {i} base not cache-line aligned");
            if i > 0 {
                // Lane i starts exactly where lane i-1's data region ends.
                assert_eq!(a.lane_base(i), a.lane_base(i - 1) + DATA_OFF + a.data_cap);
            }
        }
        // The launch slot tiles right after the last lane and closes the
        // reservation.
        assert_eq!(a.launch_base(), a.lane_base(3) + a.lane_stride());
        assert_eq!(a.launch_base() % 64, 0);
        assert_eq!(a.launch_base() + a.lane_stride(), SLOT_BASE + a.reserved_bytes());
    }

    #[test]
    fn launch_slot_is_independent_of_lanes() {
        let mem = DeviceMemory::new(MemConfig::small());
        let a = ArenaLayout::for_lanes(2);
        let launch = a.launch_slot(&mem);
        launch.set_callee(77);
        launch.write_data(0, b"launch");
        assert!(launch.cas_status(ST_IDLE, ST_REQUEST));
        for i in 0..2 {
            assert_eq!(a.lane(&mem, i).status(), ST_IDLE, "lane {i} unaffected");
        }
        assert_eq!(a.slot(&mem, a.launch_index()).callee(), 77);
        assert_eq!(a.slot(&mem, 0).base(), a.lane_base(0));
        assert_eq!(launch.read_data(0, 6), b"launch");
    }

    #[test]
    fn lanes_are_independent_slots() {
        let mem = DeviceMemory::new(MemConfig::small());
        let a = ArenaLayout::for_lanes(3);
        assert_eq!(mem.segment(a.lane_base(2) + a.lane_stride() - 1), Segment::Managed);
        for i in 0..3 {
            let mb = a.lane(&mem, i);
            mb.set_callee(100 + i as u64);
            mb.write_data(0, &[i as u8; 64]);
        }
        for i in 0..3 {
            let mb = a.lane(&mem, i);
            assert_eq!(mb.callee(), 100 + i as u64);
            assert_eq!(mb.read_data(0, 64), vec![i as u8; 64]);
            assert_eq!(mb.status(), ST_IDLE);
        }
        // Status transitions stay per-lane.
        assert!(a.lane(&mem, 1).cas_status(ST_IDLE, ST_REQUEST));
        assert_eq!(a.lane(&mem, 0).status(), ST_IDLE);
        assert_eq!(a.lane(&mem, 2).status(), ST_IDLE);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn lane_index_bounds_checked() {
        ArenaLayout::for_lanes(2).lane_base(2);
    }
}
