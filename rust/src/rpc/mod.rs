//! Host remote procedure calls (paper §2.3, §3.2, Fig. 3).
//!
//! The GPU (client) sends requests to the host (server) over a synchronous,
//! stateless protocol in **managed memory** and busy-waits for completion.
//! The compile-time half (argument classification, landing-pad generation)
//! lives in [`crate::transform::rpcgen`]; this module is the runtime half:
//!
//! * [`arginfo`] — the `RPCArgInfo` object call sites fill in: value
//!   arguments and reference arguments with (mode, object size, offset).
//! * [`mailbox`] — the managed-memory slot layout (offsets derived from a
//!   `#[repr(C)]` mirror and const-asserted) and raw access, parameterized
//!   by base address so slots can tile into an arena.
//! * [`client`] — the device-side call-site-independent stub
//!   (`issueBlockingCall`): picks an arena lane by team id (falling over
//!   under contention), packs arguments, migrates underlying objects into
//!   the lane's data region, rings the doorbell, spins, copies writable
//!   objects back. Records the Fig. 7 stage breakdown.
//! * [`server`] — the single-threaded host RPC server (paper §4.4) that
//!   unpacks the frame and invokes the registered landing-pad wrapper;
//!   also home of the [`WrapperRegistry`] with its scalar and batched pads.
//! * [`engine`] — the multi-lane successor: mailbox **arena** (one lane
//!   per team plus a dedicated kernel-split launch slot), **worker-pool**
//!   server with race-free work stealing, the **launch executor** that
//!   runs kernel-split launches off the poll workers (in-kernel RPCs are
//!   live at every shape), and the **batching layer** that dispatches
//!   homogeneous calls of a poll sweep as one landing-pad invocation.
//!   `lanes=1, workers=1` degenerates to the legacy single-slot
//!   behaviour.
//! * [`wrappers`] — the host landing pads for the libc calls the
//!   evaluation needs (`fprintf`, `fscanf`, `fopen`, `fread`, ...), closed
//!   over an in-memory [`wrappers::HostEnv`], plus their batched variants.
//!
//! ## Fig. 7-style stage table, batched path
//!
//! One engine poll sweep over an N-lane arena serves up to N in-flight
//! calls; per sweep:
//!
//! ```text
//! stage                     single-slot (paper)   engine sweep
//! poll / claim              read 1 status word    own-lane CAS sweep + steal
//! copy RPCInfo to host      1 frame               all ready frames
//! invoke host wrapper       scalar pad            1 batched pad per callee group
//! copy-back + notify        1 slot                per lane, then ST_DONE each
//! client-visible wait       975 us modeled        unchanged per call; calls overlap
//! ```

pub mod arginfo;
pub mod mailbox;
pub mod client;
pub mod server;
pub mod engine;
pub mod wrappers;

pub use arginfo::{ArgMode, RpcArg, RpcArgInfo};
pub use client::{RpcBreakdown, RpcClient};
pub use engine::{ArenaLayout, EngineConfig, EngineMetrics, EngineSnapshot, RpcEngine};
pub use server::{BatchWrapperFn, RpcFrame, RpcServer, WrapperFn, WrapperRegistry};
pub use wrappers::{HostEnv, HostIoSnapshot, CONTENT_SHARDS};
