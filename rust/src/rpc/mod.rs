//! Host remote procedure calls (paper §2.3, §3.2, Fig. 3).
//!
//! The GPU (client) sends requests to the host (server) over a synchronous,
//! stateless protocol in **managed memory** and busy-waits for completion.
//! The compile-time half (argument classification, landing-pad generation)
//! lives in [`crate::transform::rpcgen`]; this module is the runtime half:
//!
//! * [`arginfo`] — the `RPCArgInfo` object call sites fill in: value
//!   arguments and reference arguments with (mode, object size, offset).
//! * [`mailbox`] — the managed-memory channel layout and raw access.
//! * [`client`] — the device-side call-site-independent stub
//!   (`issueBlockingCall`): packs arguments, migrates underlying objects
//!   into the mailbox data region, rings the doorbell, spins, copies
//!   writable objects back. Records the Fig. 7 stage breakdown.
//! * [`server`] — the single-threaded host RPC server (paper §4.4) that
//!   unpacks the frame and invokes the registered landing-pad wrapper.
//! * [`wrappers`] — the host landing pads for the libc calls the
//!   evaluation needs (`fprintf`, `fscanf`, `fopen`, `fread`, ...), closed
//!   over an in-memory [`wrappers::HostEnv`].

pub mod arginfo;
pub mod mailbox;
pub mod client;
pub mod server;
pub mod wrappers;

pub use arginfo::{ArgMode, RpcArg, RpcArgInfo};
pub use client::{RpcBreakdown, RpcClient};
pub use server::{RpcFrame, RpcServer, WrapperFn, WrapperRegistry};
pub use wrappers::HostEnv;
