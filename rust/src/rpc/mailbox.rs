//! The managed-memory RPC channel (paper §2.2: the runtime "communicates
//! with the GPU threads via 'shared', in our case, managed, memory").
//!
//! A *slot* is one request/response mailbox. The paper's prototype
//! features single-threaded RPC handling over a single slot (§4.4); the
//! [`super::engine`] generalizes this into a multi-lane arena of slots,
//! so the slot layout here is parameterized by base address and data
//! capacity. [`Mailbox::new`] is the legacy single slot at the base of
//! the managed segment.
//!
//! ```text
//! off   field
//! 0     STATUS   0 idle, 1 request, 2 done, 3 shutdown, 4 claimed, 5 serving
//! 8     CALLEE   enum value identifying the landing pad (Fig. 3c line 18)
//! 16    NARGS
//! 24    RET      i64 return value
//! 32    FLAGS    bit 0: wrapper failed (unknown callee / bad frame)
//! 40    ARGS     MAX_ARGS × 40 B: kind, value, mode, size, offset
//! 1024  DATA     migrated underlying objects (client packs, server reads)
//! ```
//!
//! The offsets are not hard-coded: they are derived below and checked at
//! compile time against the `#[repr(C)]` [`SlotHeader`] mirror, so the
//! header can never silently grow into the DATA region when `MAX_ARGS`
//! changes.

use crate::gpu::memory::{DeviceMemory, MANAGED_BASE};
use std::mem::{align_of, size_of};

pub const SLOT_BASE: u64 = MANAGED_BASE;
pub const MAX_ARGS: usize = 16;
pub const DATA_OFF: u64 = 1024;
pub const DATA_CAP: u64 = 1 << 20;
/// Bytes of one legacy-shaped slot (header pad + 1 MiB data). The
/// device reserves `ArenaLayout::reserved_bytes()` — the lanes plus the
/// dedicated kernel-split launch slot — at the base of the managed
/// segment (see `Device::with_arena`); the legacy arena's lane 0 covers
/// exactly these bytes at `SLOT_BASE`, preserving the prototype's slot
/// layout.
pub const MAILBOX_RESERVED: u64 = DATA_OFF + DATA_CAP;

pub const ST_IDLE: u64 = 0;
pub const ST_REQUEST: u64 = 1;
pub const ST_DONE: u64 = 2;
pub const ST_SHUTDOWN: u64 = 3;
/// A device thread won the slot and is filling the frame before ringing
/// the doorbell (client-side state, introduced by [`super::client`]).
pub const ST_CLAIMED: u64 = 4;
/// An engine worker CAS'd `ST_REQUEST -> ST_SERVING` to claim the
/// request; this is what makes work-stealing between workers race-free.
pub const ST_SERVING: u64 = 5;

pub const KIND_VAL: u64 = 0;
pub const KIND_REF: u64 = 1;

/// One argument descriptor as it sits in the slot (`ARGS[i]`). This is
/// both the wire view used by [`Mailbox::write_arg`]/[`read_arg`] and the
/// `#[repr(C)]` layout source of truth.
///
/// [`read_arg`]: Mailbox::read_arg
#[repr(C)]
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireArg {
    pub kind: u64,
    /// KIND_VAL: the opaque value. KIND_REF: offset of the *object base*
    /// within the DATA region.
    pub value: u64,
    pub mode: u64,
    pub size: u64,
    pub offset: u64,
}

/// `#[repr(C)]` mirror of the slot header. Nothing constructs this
/// type — it exists so the field offsets used for raw device-memory
/// access are *checked against the compiler's* layout rules instead of
/// being free-floating magic numbers.
#[repr(C)]
#[allow(dead_code)]
pub struct SlotHeader {
    pub status: u64,
    pub callee: u64,
    pub nargs: u64,
    pub ret: i64,
    pub flags: u64,
    pub args: [WireArg; MAX_ARGS],
}

// Offsets derived field-by-field (repr(C): no reordering, and with every
// field 8-aligned there is no padding — the const assertions below prove
// both claims against the real layout).
const OFF_STATUS: u64 = 0;
const OFF_CALLEE: u64 = OFF_STATUS + size_of::<u64>() as u64;
const OFF_NARGS: u64 = OFF_CALLEE + size_of::<u64>() as u64;
const OFF_RET: u64 = OFF_NARGS + size_of::<u64>() as u64;
const OFF_FLAGS: u64 = OFF_RET + size_of::<i64>() as u64;
const OFF_ARGS: u64 = OFF_FLAGS + size_of::<u64>() as u64;
const ARG_STRIDE: u64 = size_of::<WireArg>() as u64;
/// Total header bytes; everything from here to `DATA_OFF` is padding
/// that keeps the DATA region (and therefore every lane stride in the
/// arena) cache-line aligned.
pub const HEADER_BYTES: u64 = OFF_ARGS + MAX_ARGS as u64 * ARG_STRIDE;

const _: () = assert!(
    size_of::<SlotHeader>() as u64 == HEADER_BYTES,
    "derived offsets disagree with #[repr(C)] SlotHeader layout"
);
const _: () = assert!(align_of::<SlotHeader>() == 8 && align_of::<WireArg>() == 8);
const _: () = assert!(
    HEADER_BYTES <= DATA_OFF,
    "slot header overlaps the DATA region; raise DATA_OFF or shrink MAX_ARGS"
);
const _: () = assert!(DATA_OFF % 64 == 0, "DATA region must stay cache-line aligned");
const _: () = assert!(DATA_CAP % 64 == 0, "lane stride must stay cache-line aligned");
const _: () = assert!(SLOT_BASE % 64 == 0, "slot base must be cache-line aligned");

/// Raw typed view over one slot; both client (device thread) and server
/// (host thread) construct one over the same [`DeviceMemory`].
pub struct Mailbox<'a> {
    pub mem: &'a DeviceMemory,
    base: u64,
    data_cap: u64,
}

impl<'a> Mailbox<'a> {
    /// The legacy single slot at the base of the managed segment.
    pub fn new(mem: &'a DeviceMemory) -> Self {
        Self::at(mem, SLOT_BASE, DATA_CAP)
    }

    /// A slot at an arbitrary (cache-line aligned) managed address — one
    /// lane of the engine's mailbox arena.
    pub fn at(mem: &'a DeviceMemory, base: u64, data_cap: u64) -> Self {
        assert_eq!(base % 64, 0, "mailbox slot base {base:#x} not cache-line aligned");
        assert!(data_cap > 0, "mailbox data region must be non-empty");
        Self { mem, base, data_cap }
    }

    pub fn base(&self) -> u64 {
        self.base
    }

    pub fn data_cap(&self) -> u64 {
        self.data_cap
    }

    pub fn status(&self) -> u64 {
        self.mem.atomic_load_u64(self.base + OFF_STATUS)
    }

    pub fn set_status(&self, st: u64) {
        self.mem.atomic_store_u64(self.base + OFF_STATUS, st);
    }

    /// Doorbell with CAS so concurrent device threads serialize on the
    /// slot (FIFO not guaranteed, matching the prototype).
    pub fn try_acquire(&self) -> bool {
        self.mem.atomic_cas_u64(self.base + OFF_STATUS, ST_IDLE, ST_IDLE).is_ok()
    }

    pub fn cas_status(&self, from: u64, to: u64) -> bool {
        self.mem.atomic_cas_u64(self.base + OFF_STATUS, from, to).is_ok()
    }

    pub fn set_callee(&self, id: u64) {
        self.mem.write_u64(self.base + OFF_CALLEE, id);
    }

    pub fn callee(&self) -> u64 {
        self.mem.read_u64(self.base + OFF_CALLEE)
    }

    pub fn set_nargs(&self, n: u64) {
        assert!(n as usize <= MAX_ARGS);
        self.mem.write_u64(self.base + OFF_NARGS, n);
    }

    pub fn nargs(&self) -> u64 {
        self.mem.read_u64(self.base + OFF_NARGS)
    }

    pub fn set_ret(&self, v: i64) {
        self.mem.write_i64(self.base + OFF_RET, v);
    }

    pub fn ret(&self) -> i64 {
        self.mem.read_i64(self.base + OFF_RET)
    }

    pub fn set_flags(&self, v: u64) {
        self.mem.write_u64(self.base + OFF_FLAGS, v);
    }

    pub fn flags(&self) -> u64 {
        self.mem.read_u64(self.base + OFF_FLAGS)
    }

    pub fn write_arg(&self, i: usize, a: WireArg) {
        assert!(i < MAX_ARGS);
        let base = self.base + OFF_ARGS + i as u64 * ARG_STRIDE;
        self.mem.write_u64(base, a.kind);
        self.mem.write_u64(base + 8, a.value);
        self.mem.write_u64(base + 16, a.mode);
        self.mem.write_u64(base + 24, a.size);
        self.mem.write_u64(base + 32, a.offset);
    }

    pub fn read_arg(&self, i: usize) -> WireArg {
        assert!(i < MAX_ARGS);
        let base = self.base + OFF_ARGS + i as u64 * ARG_STRIDE;
        WireArg {
            kind: self.mem.read_u64(base),
            value: self.mem.read_u64(base + 8),
            mode: self.mem.read_u64(base + 16),
            size: self.mem.read_u64(base + 24),
            offset: self.mem.read_u64(base + 32),
        }
    }

    pub fn data_addr(&self, off: u64) -> u64 {
        assert!(off < self.data_cap, "mailbox data offset {off} out of range");
        self.base + DATA_OFF + off
    }

    pub fn write_data(&self, off: u64, bytes: &[u8]) {
        assert!(off + bytes.len() as u64 <= self.data_cap, "mailbox data overflow");
        self.mem.write_bytes(self.data_addr(off), bytes);
    }

    pub fn read_data(&self, off: u64, len: usize) -> Vec<u8> {
        assert!(off + len as u64 <= self.data_cap, "mailbox data overflow");
        self.mem.read_vec(self.data_addr(off), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::memory::MemConfig;

    #[test]
    fn wire_arg_round_trip() {
        let mem = DeviceMemory::new(MemConfig::small());
        let mb = Mailbox::new(&mem);
        let a = WireArg { kind: KIND_REF, value: 64, mode: 2, size: 128, offset: 8 };
        mb.write_arg(3, a);
        assert_eq!(mb.read_arg(3), a);
    }

    #[test]
    fn header_fields() {
        let mem = DeviceMemory::new(MemConfig::small());
        let mb = Mailbox::new(&mem);
        mb.set_callee(42);
        mb.set_nargs(5);
        mb.set_ret(-3);
        mb.set_flags(1);
        assert_eq!(mb.callee(), 42);
        assert_eq!(mb.nargs(), 5);
        assert_eq!(mb.ret(), -3);
        assert_eq!(mb.flags(), 1);
    }

    #[test]
    fn status_cas_protocol() {
        let mem = DeviceMemory::new(MemConfig::small());
        let mb = Mailbox::new(&mem);
        assert_eq!(mb.status(), ST_IDLE);
        assert!(mb.cas_status(ST_IDLE, ST_REQUEST));
        assert!(!mb.cas_status(ST_IDLE, ST_REQUEST), "slot is busy");
        mb.set_status(ST_DONE);
        assert_eq!(mb.status(), ST_DONE);
    }

    #[test]
    fn data_region_round_trip() {
        let mem = DeviceMemory::new(MemConfig::small());
        let mb = Mailbox::new(&mem);
        let payload: Vec<u8> = (0..200u32).map(|x| (x % 251) as u8).collect();
        mb.write_data(96, &payload);
        assert_eq!(mb.read_data(96, payload.len()), payload);
    }

    #[test]
    fn layout_header_fits_below_data() {
        assert!(HEADER_BYTES <= DATA_OFF);
        assert_eq!(std::mem::size_of::<SlotHeader>() as u64, HEADER_BYTES);
        assert_eq!(std::mem::size_of::<WireArg>(), 40);
    }

    #[test]
    fn slots_at_different_bases_do_not_alias() {
        let mem = DeviceMemory::new(MemConfig::small());
        let cap = 4096u64;
        let a = Mailbox::at(&mem, SLOT_BASE, cap);
        let b = Mailbox::at(&mem, SLOT_BASE + DATA_OFF + cap, cap);
        a.set_callee(7);
        b.set_callee(9);
        a.write_data(0, b"aaaa");
        b.write_data(0, b"bbbb");
        assert_eq!(a.callee(), 7);
        assert_eq!(b.callee(), 9);
        assert_eq!(a.read_data(0, 4), b"aaaa");
        assert_eq!(b.read_data(0, 4), b"bbbb");
    }

    #[test]
    #[should_panic(expected = "data overflow")]
    fn small_lane_data_cap_enforced() {
        let mem = DeviceMemory::new(MemConfig::small());
        let mb = Mailbox::at(&mem, SLOT_BASE, 128);
        mb.write_data(64, &[0u8; 128]);
    }
}
