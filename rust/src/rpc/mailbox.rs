//! The managed-memory RPC channel (paper §2.2: the runtime "communicates
//! with the GPU threads via 'shared', in our case, managed, memory").
//!
//! One slot (the paper's prototype features single-threaded RPC handling,
//! §4.4) at the base of the managed segment:
//!
//! ```text
//! off   field
//! 0     STATUS   0 = idle, 1 = request ready, 2 = done, 3 = shutdown
//! 8     CALLEE   enum value identifying the landing pad (Fig. 3c line 18)
//! 16    NARGS
//! 24    RET      i64 return value
//! 32    FLAGS    bit 0: wrapper failed (unknown callee / bad frame)
//! 40    ARGS     MAX_ARGS × 40 B: kind, value, mode, size, offset
//! 1024  DATA     migrated underlying objects (client packs, server reads)
//! ```

use crate::gpu::memory::{DeviceMemory, MANAGED_BASE};

pub const SLOT_BASE: u64 = MANAGED_BASE;
pub const MAX_ARGS: usize = 16;
pub const DATA_OFF: u64 = 1024;
pub const DATA_CAP: u64 = 1 << 20;
/// Managed bytes reserved for the mailbox (see `Device::new`).
pub const MAILBOX_RESERVED: u64 = DATA_OFF + DATA_CAP;

pub const ST_IDLE: u64 = 0;
pub const ST_REQUEST: u64 = 1;
pub const ST_DONE: u64 = 2;
pub const ST_SHUTDOWN: u64 = 3;

const OFF_STATUS: u64 = 0;
const OFF_CALLEE: u64 = 8;
const OFF_NARGS: u64 = 16;
const OFF_RET: u64 = 24;
const OFF_FLAGS: u64 = 32;
const OFF_ARGS: u64 = 40;
const ARG_STRIDE: u64 = 40;

pub const KIND_VAL: u64 = 0;
pub const KIND_REF: u64 = 1;

/// Raw typed view over the slot; both client (device thread) and server
/// (host thread) construct one over the same [`DeviceMemory`].
pub struct Mailbox<'a> {
    pub mem: &'a DeviceMemory,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireArg {
    pub kind: u64,
    /// KIND_VAL: the opaque value. KIND_REF: offset of the *object base*
    /// within the DATA region.
    pub value: u64,
    pub mode: u64,
    pub size: u64,
    pub offset: u64,
}

impl<'a> Mailbox<'a> {
    pub fn new(mem: &'a DeviceMemory) -> Self {
        Self { mem }
    }

    pub fn status(&self) -> u64 {
        self.mem.atomic_load_u64(SLOT_BASE + OFF_STATUS)
    }

    pub fn set_status(&self, st: u64) {
        self.mem.atomic_store_u64(SLOT_BASE + OFF_STATUS, st);
    }

    /// Doorbell with CAS so concurrent device threads serialize on the
    /// single slot (FIFO not guaranteed, matching the prototype).
    pub fn try_acquire(&self) -> bool {
        self.mem.atomic_cas_u64(SLOT_BASE + OFF_STATUS, ST_IDLE, ST_IDLE).is_ok()
    }

    pub fn cas_status(&self, from: u64, to: u64) -> bool {
        self.mem.atomic_cas_u64(SLOT_BASE + OFF_STATUS, from, to).is_ok()
    }

    pub fn set_callee(&self, id: u64) {
        self.mem.write_u64(SLOT_BASE + OFF_CALLEE, id);
    }

    pub fn callee(&self) -> u64 {
        self.mem.read_u64(SLOT_BASE + OFF_CALLEE)
    }

    pub fn set_nargs(&self, n: u64) {
        assert!(n as usize <= MAX_ARGS);
        self.mem.write_u64(SLOT_BASE + OFF_NARGS, n);
    }

    pub fn nargs(&self) -> u64 {
        self.mem.read_u64(SLOT_BASE + OFF_NARGS)
    }

    pub fn set_ret(&self, v: i64) {
        self.mem.write_i64(SLOT_BASE + OFF_RET, v);
    }

    pub fn ret(&self) -> i64 {
        self.mem.read_i64(SLOT_BASE + OFF_RET)
    }

    pub fn set_flags(&self, v: u64) {
        self.mem.write_u64(SLOT_BASE + OFF_FLAGS, v);
    }

    pub fn flags(&self) -> u64 {
        self.mem.read_u64(SLOT_BASE + OFF_FLAGS)
    }

    pub fn write_arg(&self, i: usize, a: WireArg) {
        assert!(i < MAX_ARGS);
        let base = SLOT_BASE + OFF_ARGS + i as u64 * ARG_STRIDE;
        self.mem.write_u64(base, a.kind);
        self.mem.write_u64(base + 8, a.value);
        self.mem.write_u64(base + 16, a.mode);
        self.mem.write_u64(base + 24, a.size);
        self.mem.write_u64(base + 32, a.offset);
    }

    pub fn read_arg(&self, i: usize) -> WireArg {
        assert!(i < MAX_ARGS);
        let base = SLOT_BASE + OFF_ARGS + i as u64 * ARG_STRIDE;
        WireArg {
            kind: self.mem.read_u64(base),
            value: self.mem.read_u64(base + 8),
            mode: self.mem.read_u64(base + 16),
            size: self.mem.read_u64(base + 24),
            offset: self.mem.read_u64(base + 32),
        }
    }

    pub fn data_addr(&self, off: u64) -> u64 {
        assert!(off < DATA_CAP, "mailbox data offset {off} out of range");
        SLOT_BASE + DATA_OFF + off
    }

    pub fn write_data(&self, off: u64, bytes: &[u8]) {
        assert!(off + bytes.len() as u64 <= DATA_CAP, "mailbox data overflow");
        self.mem.write_bytes(self.data_addr(off), bytes);
    }

    pub fn read_data(&self, off: u64, len: usize) -> Vec<u8> {
        assert!(off + len as u64 <= DATA_CAP, "mailbox data overflow");
        self.mem.read_vec(self.data_addr(off), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::memory::MemConfig;

    #[test]
    fn wire_arg_round_trip() {
        let mem = DeviceMemory::new(MemConfig::small());
        let mb = Mailbox::new(&mem);
        let a = WireArg { kind: KIND_REF, value: 64, mode: 2, size: 128, offset: 8 };
        mb.write_arg(3, a);
        assert_eq!(mb.read_arg(3), a);
    }

    #[test]
    fn header_fields() {
        let mem = DeviceMemory::new(MemConfig::small());
        let mb = Mailbox::new(&mem);
        mb.set_callee(42);
        mb.set_nargs(5);
        mb.set_ret(-3);
        mb.set_flags(1);
        assert_eq!(mb.callee(), 42);
        assert_eq!(mb.nargs(), 5);
        assert_eq!(mb.ret(), -3);
        assert_eq!(mb.flags(), 1);
    }

    #[test]
    fn status_cas_protocol() {
        let mem = DeviceMemory::new(MemConfig::small());
        let mb = Mailbox::new(&mem);
        assert_eq!(mb.status(), ST_IDLE);
        assert!(mb.cas_status(ST_IDLE, ST_REQUEST));
        assert!(!mb.cas_status(ST_IDLE, ST_REQUEST), "slot is busy");
        mb.set_status(ST_DONE);
        assert_eq!(mb.status(), ST_DONE);
    }

    #[test]
    fn data_region_round_trip() {
        let mem = DeviceMemory::new(MemConfig::small());
        let mb = Mailbox::new(&mem);
        let payload: Vec<u8> = (0..200u32).map(|x| (x % 251) as u8).collect();
        mb.write_data(96, &payload);
        assert_eq!(mb.read_data(96, payload.len()), payload);
    }
}
