//! Device-side RPC stub — the call-site *independent* code of Fig. 3c
//! (`issueBlockingCall`), plus the Fig. 7 stage accounting.
//!
//! The stub is lane-aware: a client constructed with
//! [`RpcClient::for_team`] prefers the lane `team_id % lanes` of the
//! mailbox arena and falls over to neighbouring lanes when its home lane
//! is contended. When every lane is busy the caller spins/yields — the
//! arena is the backpressure boundary, exactly like the paper's single
//! slot, just N-wide. [`RpcClient::new`] is the legacy single-lane
//! client over [`ArenaLayout::legacy`].

use super::arginfo::{RpcArg, RpcArgInfo};
use super::engine::arena::ArenaLayout;
use super::mailbox::{Mailbox, WireArg, KIND_REF, KIND_VAL, ST_DONE, ST_IDLE, ST_REQUEST};
use crate::gpu::memory::{DeviceMemory, Segment};
use crate::gpu::stats::Counters;
use crate::perfmodel::a100;

/// Additional claimed state so a device thread can fill the frame before
/// ringing the doorbell (re-exported from the mailbox layout).
pub use super::mailbox::ST_CLAIMED;

/// Modeled per-stage nanoseconds of one RPC (the Fig. 7 quantities).
#[derive(Debug, Clone, Copy, Default)]
pub struct RpcBreakdown {
    pub init_ns: f64,
    pub object_ident_ns: f64,
    pub wait_ns: f64,
    pub copy_back_ns: f64,
    /// Host-side decomposition of the window covered by `wait_ns`.
    pub host_info_copy_ns: f64,
    pub host_wrapper_ns: f64,
    pub host_ack_ns: f64,
    pub host_gap_ns: f64,
    /// Real wallclock of the whole call on this machine (perf tracking).
    pub real_ns: f64,
    /// Which arena lane carried the call.
    pub lane: usize,
}

impl RpcBreakdown {
    pub fn device_total_ns(&self) -> f64 {
        self.init_ns + self.object_ident_ns + self.wait_ns + self.copy_back_ns
    }
}

/// Per-object modeled identification cost (lookup + registration), from
/// Fig. 7: 9.1% of 975 us over the three pointer arguments of the
/// `fprintf` example.
const IDENT_PER_REF_NS: f64 = a100::RPC_TOTAL_NS * a100::RPC_OBJECT_IDENT_FRAC / 3.0;
/// Managed-memory copy throughput for staging bytes (B/ns).
const STAGE_COPY_BYTES_PER_NS: f64 = 8.0;

pub struct RpcClient<'a> {
    pub mem: &'a DeviceMemory,
    arena: ArenaLayout,
    home_lane: usize,
    /// Claim only the arena's launch ring (kernel-split launches never
    /// contend with regular lanes — see [`RpcClient::for_launch`]).
    launch_only: bool,
    pub last: RpcBreakdown,
}

impl<'a> RpcClient<'a> {
    /// Legacy single-lane client (the paper's single slot).
    pub fn new(mem: &'a DeviceMemory) -> Self {
        Self::for_team(mem, ArenaLayout::legacy(), 0)
    }

    /// Lane-aware client: home lane is `team_id % arena.lanes`.
    pub fn for_team(mem: &'a DeviceMemory, arena: ArenaLayout, team_id: usize) -> Self {
        Self {
            mem,
            arena,
            home_lane: team_id % arena.lanes.max(1),
            launch_only: false,
            last: RpcBreakdown::default(),
        }
    }

    /// Kernel-split launch client: claims only the arena's launch ring,
    /// leaving every regular lane free for the RPCs the launched kernel
    /// itself issues. This is what makes in-kernel RPCs live even at
    /// `lanes=1`.
    pub fn for_launch(mem: &'a DeviceMemory, arena: ArenaLayout) -> Self {
        Self::for_launch_session(mem, arena, 0)
    }

    /// Launch client with a home ring slot derived from `session`
    /// (`session % launch_slots`), so concurrent launch sessions spread
    /// over the ring instead of all probing slot 0 first. Falls over to
    /// the other ring slots when the home slot is busy; when the whole
    /// ring is claimed the caller spins — the ring is the launch
    /// backpressure boundary, exactly like the lanes are for regular
    /// RPCs.
    pub fn for_launch_session(mem: &'a DeviceMemory, arena: ArenaLayout, session: usize) -> Self {
        Self {
            mem,
            arena,
            home_lane: arena.launch_index() + session % arena.launch_slots.max(1),
            launch_only: true,
            last: RpcBreakdown::default(),
        }
    }

    pub fn home_lane(&self) -> usize {
        self.home_lane
    }

    /// Non-blocking lane acquisition: try the home lane, then every
    /// other lane once. `None` means the arena is exhausted and the
    /// caller must back off (lane backpressure). Launch clients probe
    /// only the launch ring, home slot first: up to `launch_slots`
    /// kernel-split launches are in flight at once, and further
    /// launchers back off here until a ring slot frees (on the default
    /// one-slot ring, launches serialize exactly like the paper's
    /// single in-flight kernel).
    pub fn try_claim(&self) -> Option<(usize, Mailbox<'a>)> {
        if self.launch_only {
            let ring = self.arena.launch_slots;
            let home = self.home_lane - self.arena.launch_index();
            for k in 0..ring {
                let idx = self.arena.launch_index() + (home + k) % ring;
                let mb = self.arena.slot(self.mem, idx);
                if mb.cas_status(ST_IDLE, ST_CLAIMED) {
                    return Some((idx, mb));
                }
            }
            return None;
        }
        for k in 0..self.arena.lanes {
            let lane = (self.home_lane + k) % self.arena.lanes;
            let mb = self.arena.lane(self.mem, lane);
            if mb.cas_status(ST_IDLE, ST_CLAIMED) {
                return Some((lane, mb));
            }
        }
        None
    }

    /// Issue a blocking RPC. `counters`, when given, receives the modeled
    /// device time (the thread is stalled for the whole breakdown).
    pub fn call(
        &mut self,
        callee: u64,
        info: &RpcArgInfo,
        mut counters: Option<&mut Counters>,
    ) -> i64 {
        let t0 = std::time::Instant::now();
        let obs = &self.mem.obs;
        let span_claim = obs.spans.start();
        let mut bd = RpcBreakdown {
            init_ns: a100::RPC_TOTAL_NS * a100::RPC_ARGINFO_INIT_FRAC,
            ..Default::default()
        };

        // Acquire a lane (serializes concurrent device callers only when
        // the arena is narrower than the caller count).
        // Perf (§Perf L3-1): brief spin for the multi-core fast path, then
        // yield aggressively — on core-starved hosts the server can only
        // answer once we give the core up.
        let mut spins = 0u64;
        let (lane, mb) = loop {
            if let Some(claim) = self.try_claim() {
                break claim;
            }
            std::hint::spin_loop();
            spins += 1;
            if spins > 4 {
                std::thread::yield_now();
            }
            if spins > 2_000_000_000 {
                panic!("RPC lane acquisition timed out (server dead?)");
            }
        };
        bd.lane = lane;
        let claim_name = if self.launch_only { "claim-ring" } else { "claim" };
        obs.spans.finish(span_claim, claim_name, crate::obs::SpanKind::Lane, lane as u64);
        let span_rpc = obs.spans.start();

        // ---- Stage 2: identify underlying objects, stage them in the
        // mailbox data region (paper: "copying the format string and buffer
        // to an RPC buffer where the host can access them").
        let mut data_off = 0u64;
        // (base, data_off, size) of already-staged objects: two args into
        // the same object share one staging slot.
        let mut staged: Vec<(u64, u64, u64)> = Vec::new();
        let mut bytes_in = 0u64;
        mb.set_callee(callee);
        mb.set_nargs(info.args.len() as u64);
        for (i, arg) in info.args.iter().enumerate() {
            match *arg {
                RpcArg::Val(v) => {
                    mb.write_arg(
                        i,
                        WireArg { kind: KIND_VAL, value: v, mode: 0, size: 0, offset: 0 },
                    );
                }
                RpcArg::Ref { ptr, mode, obj_size, offset } => {
                    bd.object_ident_ns += IDENT_PER_REF_NS;
                    let base = ptr - offset;
                    // Host-segment pointers are assumed host-valid already
                    // (paper: "the pointer is pointing to host memory
                    // already and consequently does not need translation").
                    if self.mem.segment(base) == Segment::Host {
                        mb.write_arg(
                            i,
                            WireArg { kind: KIND_VAL, value: ptr, mode: 0, size: 0, offset: 0 },
                        );
                        continue;
                    }
                    let slot = staged.iter().find(|&&(b, _, _)| b == base).copied();
                    let off = match slot {
                        Some((_, off, _)) => off,
                        None => {
                            let off = crate::alloc::align_up(data_off, 16);
                            assert!(
                                off + obj_size <= mb.data_cap(),
                                "RPC object too large to stage in lane data region"
                            );
                            if mode.copies_to_host() {
                                // Device→managed staging copy.
                                let obj = self.mem.read_vec(base, obj_size as usize);
                                mb.write_data(off, &obj);
                                bytes_in += obj_size;
                            }
                            staged.push((base, off, obj_size));
                            data_off = off + obj_size;
                            off
                        }
                    };
                    mb.write_arg(
                        i,
                        WireArg {
                            kind: KIND_REF,
                            value: off,
                            mode: mode.encode(),
                            size: obj_size,
                            offset,
                        },
                    );
                }
            }
        }
        bd.object_ident_ns += bytes_in as f64 / STAGE_COPY_BYTES_PER_NS;

        // ---- Stage 3: ring the doorbell, spin until the host acknowledges.
        assert!(mb.cas_status(ST_CLAIMED, ST_REQUEST));
        let mut spins = 0u64;
        while mb.status() != ST_DONE {
            std::hint::spin_loop();
            spins += 1;
            if spins > 4 {
                std::thread::yield_now();
            }
            if spins > 2_000_000_000 {
                panic!("RPC wait timed out (callee {callee})");
            }
        }
        // The wait is dominated by the managed-memory visibility gap; the
        // host-side work fits inside it (Fig. 7 bottom row).
        bd.host_info_copy_ns = a100::RPC_TOTAL_NS * a100::RPC_HOST_INFO_COPY_FRAC;
        bd.host_wrapper_ns = a100::RPC_TOTAL_NS * a100::RPC_HOST_WRAPPER_FRAC;
        bd.host_ack_ns = a100::RPC_TOTAL_NS * a100::RPC_HOST_ACK_FRAC;
        bd.host_gap_ns = a100::MANAGED_VISIBILITY_NS;
        bd.wait_ns = bd.host_info_copy_ns + bd.host_wrapper_ns + bd.host_ack_ns + bd.host_gap_ns;

        // ---- Stage 4: copy writable objects back to device memory (once
        // per underlying object, even if several args point into it).
        let ret = mb.ret();
        let mut bytes_back = 0u64;
        let mut copied_back: Vec<u64> = Vec::new();
        for arg in &info.args {
            if let RpcArg::Ref { mode, .. } = arg {
                if mode.copies_back() {
                    let base = arg.obj_base().unwrap();
                    if copied_back.contains(&base) {
                        continue;
                    }
                    // Host-segment args were degraded to values: not staged.
                    if let Some(&(b, off, size)) = staged.iter().find(|&&(b, _, _)| b == base) {
                        let data = mb.read_data(off, size as usize);
                        self.mem.write_bytes(b, &data);
                        bytes_back += size;
                        copied_back.push(b);
                    }
                }
            }
        }
        bd.copy_back_ns =
            a100::RPC_TOTAL_NS * a100::RPC_COPY_BACK_FRAC * (bytes_back as f64 / 128.0).min(4.0);
        mb.set_status(ST_IDLE);

        bd.real_ns = t0.elapsed().as_nanos() as f64;
        let rpc_name = if self.launch_only { "launch-rpc" } else { "rpc" };
        obs.spans.finish(span_rpc, rpc_name, crate::obs::SpanKind::Lane, lane as u64);
        obs.record_rpc(callee, bd.real_ns as u64);
        if let Some(c) = counters.as_deref_mut() {
            c.rpc_calls += 1;
            c.charge_ns(bd.device_total_ns());
        }
        self.last = bd;
        ret
    }
}

#[cfg(test)]
mod tests {
    // End-to-end client↔server round trips live in `super::server::tests`
    // and `super::engine::server::tests` (the client requires a live
    // server thread to acknowledge requests).
    use super::*;
    use crate::gpu::memory::MemConfig;

    #[test]
    fn breakdown_totals() {
        let bd = RpcBreakdown {
            init_ns: 1.0,
            object_ident_ns: 2.0,
            wait_ns: 3.0,
            copy_back_ns: 4.0,
            ..Default::default()
        };
        assert_eq!(bd.device_total_ns(), 10.0);
    }

    #[test]
    fn home_lane_follows_team_id() {
        let mem = DeviceMemory::new(MemConfig::small());
        let arena = ArenaLayout::for_lanes(4);
        assert_eq!(RpcClient::for_team(&mem, arena, 0).home_lane(), 0);
        assert_eq!(RpcClient::for_team(&mem, arena, 3).home_lane(), 3);
        assert_eq!(RpcClient::for_team(&mem, arena, 6).home_lane(), 2);
        assert_eq!(RpcClient::new(&mem).home_lane(), 0);
    }

    #[test]
    fn lane_exhaustion_backpressure_and_release() {
        // All lanes claimed -> try_claim refuses; freeing any lane lets
        // the caller in, preferring its home lane's probe order.
        let mem = DeviceMemory::new(MemConfig::small());
        let arena = ArenaLayout::for_lanes(2);
        for lane in 0..2 {
            assert!(arena.lane(&mem, lane).cas_status(ST_IDLE, ST_CLAIMED));
        }
        let client = RpcClient::for_team(&mem, arena, 1);
        assert!(client.try_claim().is_none(), "arena exhausted: caller must back off");
        // Lane 0 frees up; the team-1 client probes 1 then 0.
        arena.lane(&mem, 0).set_status(ST_IDLE);
        let (lane, mb) = client.try_claim().expect("a lane is idle again");
        assert_eq!(lane, 0);
        assert_eq!(mb.base(), arena.lane_base(0));
        assert_eq!(mb.status(), ST_CLAIMED, "claim transitions the slot");
        assert!(client.try_claim().is_none(), "claim is exclusive");
    }

    #[test]
    fn launch_client_claims_only_the_launch_slot() {
        let mem = DeviceMemory::new(MemConfig::small());
        let arena = ArenaLayout::for_lanes(2);
        let client = RpcClient::for_launch(&mem, arena);
        assert_eq!(client.home_lane(), arena.launch_index());
        let (slot, mb) = client.try_claim().unwrap();
        assert_eq!(slot, arena.launch_index());
        assert_eq!(mb.base(), arena.launch_base());
        // A second launch claim backs off even though every regular lane
        // is idle — launches never spill onto the lanes.
        assert!(client.try_claim().is_none());
        assert_eq!(arena.lane(&mem, 0).status(), ST_IDLE);
        assert_eq!(arena.lane(&mem, 1).status(), ST_IDLE);
    }

    #[test]
    fn launch_ring_admits_concurrent_sessions_with_backpressure() {
        let mem = DeviceMemory::new(MemConfig::small());
        let arena = ArenaLayout::for_shape(1, 3);
        // Sessions home onto distinct ring slots.
        let c0 = RpcClient::for_launch_session(&mem, arena, 0);
        let c1 = RpcClient::for_launch_session(&mem, arena, 1);
        let c4 = RpcClient::for_launch_session(&mem, arena, 4);
        assert_eq!(c0.home_lane(), arena.launch_index());
        assert_eq!(c1.home_lane(), arena.launch_index() + 1);
        assert_eq!(c4.home_lane(), arena.launch_index() + 1, "session % launch_slots");
        // Three claims land on three distinct ring slots; a fourth backs
        // off (ring backpressure), and never spills onto the lane.
        let (s0, _) = c0.try_claim().unwrap();
        let (s1, _) = c1.try_claim().unwrap();
        let (s4, _) = c4.try_claim().unwrap();
        let mut slots = [s0, s1, s4];
        slots.sort();
        assert_eq!(slots, [1, 2, 3], "ring slots sit after the single lane");
        assert!(c0.try_claim().is_none(), "ring exhausted: launcher must back off");
        assert_eq!(arena.lane(&mem, 0).status(), ST_IDLE, "regular lane untouched");
        // Freeing any ring slot readmits a launcher, whatever its home.
        arena.launch_slot_at(&mem, 2).set_status(ST_IDLE);
        let (s, _) = c0.try_claim().unwrap();
        assert_eq!(s, 3);
    }

    #[test]
    fn home_lane_preferred_when_idle() {
        let mem = DeviceMemory::new(MemConfig::small());
        let arena = ArenaLayout::for_lanes(4);
        let client = RpcClient::for_team(&mem, arena, 2);
        let (lane, _) = client.try_claim().unwrap();
        assert_eq!(lane, 2);
    }
}
