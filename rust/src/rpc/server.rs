//! The host-side RPC server (paper Fig. 1 right, §4.4: single-threaded).
//!
//! A host thread polls the mailbox; on a request it unpacks the frame
//! (copying staged objects out of managed memory into host buffers —
//! exactly what "the host wrapper ... unpacks the arguments passed from the
//! device and performs the original call on the host" describes), invokes
//! the registered landing pad, writes mutated buffers back into the data
//! region, stores the return value and acknowledges completion.

use super::arginfo::ArgMode;
use super::mailbox::{Mailbox, KIND_REF, ST_DONE, ST_REQUEST, ST_SHUTDOWN};
use super::wrappers::HostEnv;
use crate::gpu::memory::DeviceMemory;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A host argument as seen by a landing-pad wrapper.
#[derive(Debug, Clone)]
pub enum HostArg {
    Val(u64),
    /// A migrated underlying object plus the argument's offset into it.
    Buf { bytes: Vec<u8>, offset: usize, mode: ArgMode },
}

/// The unpacked call frame handed to a wrapper (Fig. 3b's `RPCInfo` view).
#[derive(Debug, Default)]
pub struct RpcFrame {
    pub args: Vec<HostArg>,
}

impl RpcFrame {
    pub fn nargs(&self) -> usize {
        self.args.len()
    }

    /// Opaque value argument (Fig. 3b: `(FILE*)RI.getArg(0)`).
    pub fn val(&self, i: usize) -> u64 {
        match &self.args[i] {
            HostArg::Val(v) => *v,
            a => panic!("arg {i} is not a value: {a:?}"),
        }
    }

    /// The argument pointer's view of its object (from its offset onward).
    pub fn bytes(&self, i: usize) -> &[u8] {
        match &self.args[i] {
            HostArg::Buf { bytes, offset, .. } => &bytes[*offset..],
            a => panic!("arg {i} is not a buffer: {a:?}"),
        }
    }

    pub fn bytes_mut(&mut self, i: usize) -> &mut [u8] {
        match &mut self.args[i] {
            HostArg::Buf { bytes, offset, .. } => &mut bytes[*offset..],
            a => panic!("arg {i} is not a buffer: {a:?}"),
        }
    }

    /// NUL-terminated string at the argument pointer.
    pub fn cstr(&self, i: usize) -> String {
        let b = self.bytes(i);
        let end = b.iter().position(|&c| c == 0).unwrap_or(b.len());
        String::from_utf8_lossy(&b[..end]).into_owned()
    }

    pub fn write_i32(&mut self, i: usize, v: i32) {
        self.bytes_mut(i)[..4].copy_from_slice(&v.to_le_bytes());
    }

    pub fn write_f32(&mut self, i: usize, v: f32) {
        self.bytes_mut(i)[..4].copy_from_slice(&v.to_le_bytes());
    }

    pub fn write_f64(&mut self, i: usize, v: f64) {
        self.bytes_mut(i)[..8].copy_from_slice(&v.to_le_bytes());
    }

    pub fn read_i32(&self, i: usize) -> i32 {
        i32::from_le_bytes(self.bytes(i)[..4].try_into().unwrap())
    }
}

/// A landing-pad wrapper: the host function generated per
/// (callee × argument-type signature) — `__fscanf_ip_fp_ip` in Fig. 3b.
pub type WrapperFn = Box<dyn Fn(&mut RpcFrame, &HostEnv) -> i64 + Send + Sync>;

/// A *batched* landing pad: one invocation serving every same-callee
/// frame an engine poll sweep drained, returning one value per frame.
/// See [`crate::rpc::wrappers::synthesize_batch`].
pub type BatchWrapperFn = Box<dyn Fn(&mut [RpcFrame], &HostEnv) -> Vec<i64> + Send + Sync>;

/// Transfer direction of an order-preserving *stream pad* (`fwrite` =
/// write, `fread` = read). Every pad of one direction shares the
/// `(buf, size, count, fd)` frame layout, which is what lets the
/// engine's sweep grouping merge consecutive same-stream frames into
/// one batch run even when their callee ids differ.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamDir {
    Write,
    Read,
}

/// Registry mapping compile-time callee enum values to wrappers.
#[derive(Default)]
pub struct WrapperRegistry {
    by_name: Mutex<HashMap<String, u64>>,
    /// `(scalar pad, is-kernel-split-launch)` per callee id. The launch
    /// flag lives next to the pad so the engine's per-frame hot path
    /// reads both under the one existing lock ([`Self::get_entry`]);
    /// flagged pads route to the dedicated launch executor instead of
    /// being served on the claiming poll worker.
    wrappers: Mutex<Vec<(Arc<WrapperFn>, bool)>>,
    /// Optional batched variants, keyed by the scalar pad's callee id.
    batch: Mutex<HashMap<u64, Arc<BatchWrapperFn>>>,
    /// Stream-pad direction per callee id (`fwrite`/`fread` pads only);
    /// drives the engine's cross-callee same-stream batch merge.
    stream: Mutex<HashMap<u64, StreamDir>>,
}

impl WrapperRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a landing pad; returns its callee enum value. Registering
    /// the same mangled name twice returns the existing id (different call
    /// sites with agreeing signatures share one landing pad).
    pub fn register(&self, mangled: &str, f: WrapperFn) -> u64 {
        let mut names = self.by_name.lock().unwrap();
        if let Some(&id) = names.get(mangled) {
            return id;
        }
        let mut ws = self.wrappers.lock().unwrap();
        let id = ws.len() as u64;
        ws.push((Arc::new(f), false));
        names.insert(mangled.to_string(), id);
        id
    }

    pub fn id_of(&self, mangled: &str) -> Option<u64> {
        self.by_name.lock().unwrap().get(mangled).copied()
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.by_name.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Reverse lookup: the landing-pad name registered under `id`
    /// (telemetry labels per-callee histograms and spans with it).
    pub fn name_of(&self, id: u64) -> Option<String> {
        self.by_name.lock().unwrap().iter().find(|(_, v)| **v == id).map(|(k, _)| k.clone())
    }

    /// Register the batched variant of an already-registered landing
    /// pad; returns its callee id, or `None` when no scalar pad exists
    /// under `mangled` (the batch pad would be unreachable).
    pub fn register_batch(&self, mangled: &str, f: BatchWrapperFn) -> Option<u64> {
        let id = self.id_of(mangled)?;
        self.batch.lock().unwrap().insert(id, Arc::new(f));
        Some(id)
    }

    /// Mark an already-registered pad as an order-preserving stream pad
    /// of direction `dir`; returns its callee id, or `None` when no pad
    /// exists under `mangled`.
    pub fn mark_stream(&self, mangled: &str, dir: StreamDir) -> Option<u64> {
        let id = self.id_of(mangled)?;
        self.stream.lock().unwrap().insert(id, dir);
        Some(id)
    }

    /// Stream-pad direction of `id`, if it was marked with
    /// [`Self::mark_stream`].
    pub(crate) fn stream_dir(&self, id: u64) -> Option<StreamDir> {
        self.stream.lock().unwrap().get(&id).copied()
    }

    /// Mark an already-registered pad as a kernel-split launch; returns
    /// its callee id, or `None` when no pad exists under `mangled`.
    pub fn mark_launch(&self, mangled: &str) -> Option<u64> {
        let id = self.id_of(mangled)?;
        self.wrappers.lock().unwrap().get_mut(id as usize)?.1 = true;
        Some(id)
    }

    /// Does `id` name a kernel-split launch pad?
    pub fn is_launch(&self, id: u64) -> bool {
        self.wrappers.lock().unwrap().get(id as usize).is_some_and(|e| e.1)
    }

    pub(crate) fn get(&self, id: u64) -> Option<Arc<WrapperFn>> {
        self.wrappers.lock().unwrap().get(id as usize).map(|e| Arc::clone(&e.0))
    }

    /// Scalar pad + launch flag in one lock acquisition — the engine's
    /// per-claimed-frame lookup.
    pub(crate) fn get_entry(&self, id: u64) -> Option<(Arc<WrapperFn>, bool)> {
        self.wrappers.lock().unwrap().get(id as usize).map(|(w, l)| (Arc::clone(w), *l))
    }

    pub(crate) fn get_batch(&self, id: u64) -> Option<Arc<BatchWrapperFn>> {
        self.batch.lock().unwrap().get(&id).cloned()
    }

    pub fn len(&self) -> usize {
        self.wrappers.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Handle to the running server thread.
pub struct RpcServer {
    shutdown: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    pub served: Arc<AtomicU64>,
}

impl RpcServer {
    /// Spawn the single server thread over `mem`, dispatching to `registry`
    /// with `env` as the host state.
    ///
    /// `mem` must carry the **legacy single-slot arena**
    /// ([`ArenaLayout::legacy`], what `Device::new` reserves): every
    /// slot this server polls — the prototype slot at `SLOT_BASE` and
    /// the one-slot launch ring right above it — is derived from that
    /// one layout value, so the legacy server and the engine can never
    /// disagree about where the slots live (pinned by the const-asserts
    /// in [`arena`] and `legacy_server_polls_the_shared_layouts_slots`
    /// below). Memory reserved for a multi-lane arena puts lane data at
    /// the ring's address — pair such devices with the engine, never
    /// this server.
    ///
    /// [`ArenaLayout::legacy`]: crate::rpc::engine::ArenaLayout::legacy
    /// [`arena`]: crate::rpc::engine::arena
    pub fn start(
        mem: Arc<DeviceMemory>,
        registry: Arc<WrapperRegistry>,
        env: Arc<HostEnv>,
    ) -> Self {
        let shutdown = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let sd = Arc::clone(&shutdown);
        let sv = Arc::clone(&served);
        let handle = std::thread::Builder::new()
            .name("rpc-server".into())
            .spawn(move || {
                // One shared layout constructor names every slot: lane 0
                // is the paper's prototype mailbox, and the launch ring
                // carries kernel-split launches — served *synchronously*
                // here (the paper's §4.4 behaviour — a kernel that
                // itself issues RPCs hangs on this server; the engine's
                // launch executor is the fix).
                let arena = crate::rpc::engine::ArenaLayout::legacy();
                let slots: Vec<Mailbox<'_>> =
                    (0..arena.slot_count()).map(|i| arena.slot(&mem, i)).collect();
                let mb = arena.lane(&mem, 0);
                let mut idle_spins = 0u64;
                loop {
                    let mut served_any = false;
                    for slot in &slots {
                        if slot.status() == ST_REQUEST {
                            Self::serve_one(slot, &registry, &env);
                            sv.fetch_add(1, Ordering::Relaxed);
                            slot.set_status(ST_DONE);
                            served_any = true;
                        }
                    }
                    if served_any {
                        idle_spins = 0;
                        continue;
                    }
                    if mb.status() == ST_SHUTDOWN || sd.load(Ordering::Relaxed) {
                        break;
                    }
                    std::hint::spin_loop();
                    idle_spins += 1;
                    // Perf (§Perf L3-1): brief hot window after the
                    // last request, then hand the core back.
                    if idle_spins > 4 {
                        std::thread::yield_now();
                    }
                }
            })
            .expect("spawn rpc server");
        Self { shutdown, handle: Some(handle), served }
    }

    fn serve_one(mb: &Mailbox<'_>, registry: &WrapperRegistry, env: &HostEnv) {
        // 1) Copy the RPCInfo to the host.
        let (callee, mut frame) = unpack_frame(mb);
        // 2) Invoke the host wrapper.
        let (ret, flags) = match registry.get(callee) {
            Some(w) => (w(&mut frame, env), 0),
            None => (-1, 1),
        };
        // 3) Copy mutated objects back into the data region + notify.
        writeback_frame(mb, &frame);
        mb.set_ret(ret);
        mb.set_flags(flags);
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Copy one slot's RPCInfo to the host (Fig. 7 "copy RPCInfo" stage):
/// reads the callee id and materializes every argument, staging REF
/// objects out of the slot's data region. Shared by the legacy server
/// and the engine's sweep dispatcher.
pub(crate) fn unpack_frame(mb: &Mailbox<'_>) -> (u64, RpcFrame) {
    let callee = mb.callee();
    let nargs = mb.nargs() as usize;
    let mut frame = RpcFrame::default();
    for i in 0..nargs {
        let w = mb.read_arg(i);
        if w.kind == KIND_REF {
            let bytes = mb.read_data(w.value, w.size as usize);
            frame.args.push(HostArg::Buf {
                bytes,
                offset: w.offset as usize,
                mode: ArgMode::decode(w.mode),
            });
        } else {
            frame.args.push(HostArg::Val(w.value));
        }
    }
    (callee, frame)
}

/// Copy the frame's mutated objects back into the slot's data region
/// (Fig. 7 "copy-back" stage). The caller still writes ret/flags and
/// rings `ST_DONE`.
pub(crate) fn writeback_frame(mb: &Mailbox<'_>, frame: &RpcFrame) {
    for i in 0..frame.args.len() {
        let w = mb.read_arg(i);
        if w.kind == KIND_REF && ArgMode::decode(w.mode).copies_back() {
            if let HostArg::Buf { bytes, .. } = &frame.args[i] {
                mb.write_data(w.value, bytes);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::memory::{MemConfig, GLOBAL_BASE};
    use crate::rpc::arginfo::{ArgMode, RpcArgInfo};
    use crate::rpc::client::RpcClient;

    fn setup() -> (Arc<DeviceMemory>, Arc<WrapperRegistry>, Arc<HostEnv>) {
        (
            Arc::new(DeviceMemory::new(MemConfig::small())),
            Arc::new(WrapperRegistry::new()),
            Arc::new(HostEnv::new()),
        )
    }

    #[test]
    fn value_only_round_trip() {
        let (mem, reg, env) = setup();
        let id = reg.register("__add_i_i", Box::new(|f, _| (f.val(0) + f.val(1)) as i64));
        let server = RpcServer::start(Arc::clone(&mem), Arc::clone(&reg), env);
        let mut client = RpcClient::new(&mem);
        let mut info = RpcArgInfo::new();
        info.add_val(30).add_val(12);
        assert_eq!(client.call(id, &info, None), 42);
        assert!(client.last.wait_ns > 0.0);
        server.stop();
    }

    #[test]
    fn ref_arg_read_and_write_back() {
        let (mem, reg, env) = setup();
        // A wrapper that reads a C string and writes its length into an
        // int* out-param (write-only object).
        let id = reg.register(
            "__strlen_out_cp_ip",
            Box::new(|f, _| {
                let s = f.cstr(0);
                f.write_i32(1, s.len() as i32);
                0
            }),
        );
        let server = RpcServer::start(Arc::clone(&mem), Arc::clone(&reg), env);

        let str_addr = GLOBAL_BASE + 256;
        mem.write_cstr(str_addr, "hello GPU First");
        let out_addr = GLOBAL_BASE + 512;
        mem.write_u32(out_addr, 0xFFFF_FFFF);

        let mut client = RpcClient::new(&mem);
        let mut info = RpcArgInfo::new();
        info.add_ref(str_addr, ArgMode::Read, 16, 0);
        info.add_ref(out_addr, ArgMode::Write, 4, 0);
        assert_eq!(client.call(id, &info, None), 0);
        assert_eq!(mem.read_u32(out_addr), 15);
        server.stop();
    }

    #[test]
    fn interior_pointer_into_struct() {
        let (mem, reg, env) = setup();
        // Mirrors Fig. 3: &s.f with offset 8 into a 12-byte struct; the
        // wrapper doubles the float through the interior pointer.
        let id = reg.register(
            "__double_fp",
            Box::new(|f, _| {
                let v = f32::from_le_bytes(f.bytes(0)[..4].try_into().unwrap());
                f.write_f32(0, v * 2.0);
                0
            }),
        );
        let server = RpcServer::start(Arc::clone(&mem), Arc::clone(&reg), env);
        let s_base = GLOBAL_BASE + 1024;
        mem.write_u32(s_base, 7); // s.a
        mem.write_u32(s_base + 4, 8); // s.b
        mem.write_f32(s_base + 8, 1.5); // s.f
        let mut client = RpcClient::new(&mem);
        let mut info = RpcArgInfo::new();
        info.add_ref(s_base + 8, ArgMode::ReadWrite, 12, 8);
        client.call(id, &info, None);
        assert_eq!(mem.read_f32(s_base + 8), 3.0);
        assert_eq!(mem.read_u32(s_base), 7, "rest of struct preserved");
        server.stop();
    }

    #[test]
    fn two_args_into_same_object_staged_once() {
        let (mem, reg, env) = setup();
        let id = reg.register(
            "__sum2_ip_ip",
            Box::new(|f, _| {
                let a = f.read_i32(0) as i64;
                let b = f.read_i32(1) as i64;
                a + b
            }),
        );
        let server = RpcServer::start(Arc::clone(&mem), Arc::clone(&reg), env);
        let base = GLOBAL_BASE + 2048;
        mem.write_u32(base, 11);
        mem.write_u32(base + 4, 31);
        let mut client = RpcClient::new(&mem);
        let mut info = RpcArgInfo::new();
        info.add_ref(base, ArgMode::Read, 8, 0);
        info.add_ref(base + 4, ArgMode::Read, 8, 4);
        assert_eq!(client.call(id, &info, None), 42);
        server.stop();
    }

    #[test]
    fn legacy_server_polls_the_shared_layouts_slots() {
        // The legacy server derives every slot it polls from
        // ArenaLayout::legacy(); this pins lane 0 to the prototype
        // Mailbox::new address and the one-slot launch ring right above
        // it, so legacy and engine layouts can never silently diverge.
        use crate::rpc::mailbox::{MAILBOX_RESERVED, SLOT_BASE};
        let mem = DeviceMemory::new(MemConfig::small());
        let arena = crate::rpc::engine::ArenaLayout::legacy();
        assert_eq!(arena.slot_count(), 2, "prototype slot + one-slot launch ring");
        assert_eq!(arena.lane(&mem, 0).base(), Mailbox::new(&mem).base());
        assert_eq!(arena.slot(&mem, 0).base(), SLOT_BASE);
        assert_eq!(arena.slot(&mem, 1).base(), SLOT_BASE + MAILBOX_RESERVED);
        assert_eq!(arena.launch_slot(&mem).base(), arena.slot(&mem, 1).base());
        assert_eq!(arena.lane(&mem, 0).data_cap(), Mailbox::new(&mem).data_cap());
    }

    #[test]
    fn unknown_callee_sets_flag() {
        let (mem, reg, env) = setup();
        let server = RpcServer::start(Arc::clone(&mem), Arc::clone(&reg), env);
        let mut client = RpcClient::new(&mem);
        let info = RpcArgInfo::new();
        assert_eq!(client.call(999, &info, None), -1);
        server.stop();
    }

    #[test]
    fn registry_batch_pad_requires_scalar_pad() {
        let reg = WrapperRegistry::new();
        assert!(
            reg.register_batch("__f_i", Box::new(|fs, _| vec![0; fs.len()])).is_none(),
            "no scalar pad registered yet"
        );
        let id = reg.register("__f_i", Box::new(|_, _| 1));
        assert_eq!(reg.register_batch("__f_i", Box::new(|fs, _| vec![2; fs.len()])), Some(id));
        assert!(reg.get_batch(id).is_some());
        assert!(reg.get_batch(id + 1).is_none());
    }

    #[test]
    fn registry_launch_flag_rides_the_wrapper_entry() {
        let reg = WrapperRegistry::new();
        assert!(reg.mark_launch("__nope").is_none(), "no pad registered yet");
        let id = reg.register("__launchish_i_i", Box::new(|_, _| 0));
        assert!(!reg.is_launch(id));
        assert_eq!(reg.mark_launch("__launchish_i_i"), Some(id));
        assert!(reg.is_launch(id));
        assert!(!reg.is_launch(id + 1), "unknown ids are not launches");
        let (pad, launch) = reg.get_entry(id).unwrap();
        assert!(launch);
        let mut frame = RpcFrame::default();
        assert_eq!(pad(&mut frame, &HostEnv::new()), 0);
        assert!(reg.get_entry(id + 1).is_none());
    }

    #[test]
    fn registry_dedups_by_mangled_name() {
        let reg = WrapperRegistry::new();
        let a = reg.register("__f_i", Box::new(|_, _| 1));
        let b = reg.register("__f_i", Box::new(|_, _| 2));
        assert_eq!(a, b);
        assert_eq!(reg.len(), 1);
        let c = reg.register("__f_ip", Box::new(|_, _| 3));
        assert_ne!(a, c);
    }

    #[test]
    fn concurrent_device_threads_serialize_on_slot() {
        let (mem, reg, env) = setup();
        let id = reg.register("__id_i", Box::new(|f, _| f.val(0) as i64));
        let server = RpcServer::start(Arc::clone(&mem), Arc::clone(&reg), env);
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let mem = &mem;
                s.spawn(move || {
                    let mut client = RpcClient::new(mem);
                    for k in 0..20u64 {
                        let mut info = RpcArgInfo::new();
                        info.add_val(t * 1000 + k);
                        assert_eq!(client.call(id, &info, None), (t * 1000 + k) as i64);
                    }
                });
            }
        });
        assert_eq!(server.served.load(Ordering::Relaxed), 160);
        server.stop();
    }
}
