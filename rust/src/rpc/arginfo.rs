//! `RPCArgInfo` — the call-site argument description (paper Fig. 3c).
//!
//! Three kinds of arguments (paper §3.2):
//! 1. **Value** arguments: integers, floats, and pointers to opaque types
//!    (e.g. `FILE*`) that are assumed to already be host values and are
//!    passed through untranslated.
//! 2. **Reference** arguments to *statically identified objects*: the pass
//!    knows the underlying object, its size, and the pointer's offset into
//!    it, plus a read/write mode that controls migration direction.
//! 3. Reference arguments resolved by **dynamic lookup** (`_FindObj`)
//!    against the allocator's tracking records; if the lookup fails the
//!    pointer degrades to a value argument.

/// Read/write behaviour of the callee w.r.t. the underlying object,
/// controlling which directions the object is copied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArgMode {
    Read,
    Write,
    ReadWrite,
}

impl ArgMode {
    pub fn copies_to_host(self) -> bool {
        matches!(self, ArgMode::Read | ArgMode::ReadWrite)
    }

    pub fn copies_back(self) -> bool {
        matches!(self, ArgMode::Write | ArgMode::ReadWrite)
    }

    pub fn encode(self) -> u64 {
        match self {
            ArgMode::Read => 0,
            ArgMode::Write => 1,
            ArgMode::ReadWrite => 2,
        }
    }

    pub fn decode(v: u64) -> ArgMode {
        match v {
            0 => ArgMode::Read,
            1 => ArgMode::Write,
            2 => ArgMode::ReadWrite,
            _ => panic!("bad ArgMode encoding {v}"),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RpcArg {
    /// Opaque value, treated as a byte sequence.
    Val(u64),
    /// Pointer into an underlying device object that must be migrated.
    Ref {
        /// The pointer value at the call site (device address).
        ptr: u64,
        mode: ArgMode,
        /// Size of the *underlying object* (not the pointed-to element).
        obj_size: u64,
        /// Offset of `ptr` into the object: object base = `ptr - offset`.
        offset: u64,
    },
}

impl RpcArg {
    pub fn obj_base(&self) -> Option<u64> {
        match self {
            RpcArg::Val(_) => None,
            RpcArg::Ref { ptr, offset, .. } => Some(ptr - offset),
        }
    }
}

/// The per-call-site argument record (`RPCArgInfo` in Fig. 3c).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RpcArgInfo {
    pub args: Vec<RpcArg>,
}

impl RpcArgInfo {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Self { args: Vec::with_capacity(n) }
    }

    /// `addValArg` (Fig. 3c line 29).
    pub fn add_val(&mut self, v: u64) -> &mut Self {
        self.args.push(RpcArg::Val(v));
        self
    }

    /// `addRefArg` (Fig. 3c lines 30-39).
    pub fn add_ref(&mut self, ptr: u64, mode: ArgMode, obj_size: u64, offset: u64) -> &mut Self {
        assert!(offset <= obj_size, "pointer offset {offset} outside object of size {obj_size}");
        self.args.push(RpcArg::Ref { ptr, mode, obj_size, offset });
        self
    }

    /// Total bytes that must be migrated to the host (deduplicated by
    /// object base, since two arguments may point into the same object).
    pub fn bytes_to_host(&self) -> u64 {
        let mut seen: Vec<(u64, u64)> = Vec::new();
        for a in &self.args {
            if let RpcArg::Ref { mode, obj_size, .. } = a {
                if mode.copies_to_host() {
                    let base = a.obj_base().unwrap();
                    if !seen.iter().any(|&(b, _)| b == base) {
                        seen.push((base, *obj_size));
                    }
                }
            }
        }
        seen.iter().map(|&(_, s)| s).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_directions() {
        assert!(ArgMode::Read.copies_to_host() && !ArgMode::Read.copies_back());
        assert!(!ArgMode::Write.copies_to_host() && ArgMode::Write.copies_back());
        assert!(ArgMode::ReadWrite.copies_to_host() && ArgMode::ReadWrite.copies_back());
    }

    #[test]
    fn mode_encoding_round_trips() {
        for m in [ArgMode::Read, ArgMode::Write, ArgMode::ReadWrite] {
            assert_eq!(ArgMode::decode(m.encode()), m);
        }
    }

    #[test]
    fn obj_base_from_interior_pointer() {
        let a = RpcArg::Ref { ptr: 0x1010, mode: ArgMode::Read, obj_size: 0x40, offset: 0x10 };
        assert_eq!(a.obj_base(), Some(0x1000));
        assert_eq!(RpcArg::Val(7).obj_base(), None);
    }

    #[test]
    fn bytes_to_host_dedups_same_object() {
        // Fig. 3a: &s.f and &s.b point into the same struct s.
        let mut ai = RpcArgInfo::new();
        ai.add_ref(0x1004, ArgMode::ReadWrite, 12, 4); // &s.b
        ai.add_ref(0x1008, ArgMode::ReadWrite, 12, 8); // &s.f
        ai.add_ref(0x2000, ArgMode::Write, 64, 0); // write-only: no copy-in
        assert_eq!(ai.bytes_to_host(), 12);
    }

    #[test]
    #[should_panic(expected = "outside object")]
    fn offset_validated() {
        RpcArgInfo::new().add_ref(0x1000, ArgMode::Read, 8, 16);
    }
}
