//! The `lower` pass: compile each function into the register-file
//! execution form ([`crate::ir::lowered`]).
//!
//! Slot assignment is a flat, per-function scan in program order: the
//! parameters first, then every definition site (`Assign`, `Alloca`,
//! `Load`, `Call`/`RpcCall`/`Intrinsic` destinations, `for` induction
//! variables) as the body is walked depth-first; a name re-defined
//! later reuses its slot. This is semantics-preserving because the
//! tree-walk interpreter pushes a value frame only per *function call*
//! (`If`/`While`/`For` share the caller frame) and the verifier rejects
//! any use outside the defining scope, so two sibling-arm locals
//! sharing one slot can never observe each other.
//!
//! Constants and global addresses are interned into a deduplicated
//! per-function pool; [`crate::ir::interp::ProgramEnv`] resolves
//! `PoolConst::Global` entries to device base addresses once at load.
//!
//! Almost everything lowers. The one remaining skip reason (recorded
//! in [`LowerReport::skipped`]) is a `launch` whose region parameters
//! are not all visible in the caller's scope (the tree-walk executor
//! reads them back by name at launch time; lowering must resolve that
//! lookup statically). Dynamic-offset RPC refs lower to
//! [`crate::ir::lowered::LowOffset::Dynamic`] — the offset is
//! recomputed at marshal time from the runtime object lookup, so those
//! functions no longer stay on the tree-walk executor.

use crate::ir::lowered::{LowExpr, LowInstr, LowOffset, LowOp, LowRpcArg, LoweredFunction, PoolConst};
use crate::ir::{Expr, Function, Instr, Module, OffsetSpec, Operand, RpcArgSpec};
use std::collections::{BTreeMap, HashMap};

/// What the pass did (→ `CompileReport.lower`, `--explain`,
/// `RunMetrics.lowered_fns`).
#[derive(Debug, Default, Clone)]
pub struct LowerReport {
    /// Functions compiled to register-file form.
    pub lowered_fns: u64,
    /// Register slots allocated across all lowered functions.
    pub total_slots: u64,
    /// Constant-pool entries interned (post-dedup) across all functions.
    pub pool_consts: u64,
    /// Functions kept on the tree-walk path: `(name, reason)`.
    pub skipped: Vec<(String, String)>,
}

impl LowerReport {
    /// One-line summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "{} function(s) lowered ({} slots, {} pool consts), {} kept on tree-walk",
            self.lowered_fns, self.total_slots, self.pool_consts, self.skipped.len()
        )
    }
}

/// Lower every function of `m` into [`Module::lowered`], replacing any
/// previous lowering wholesale. The tree bodies are untouched — the
/// lowered form lives alongside them.
pub fn run(m: &mut Module) -> LowerReport {
    let mut report = LowerReport::default();
    let mut out = BTreeMap::new();
    for (name, f) in &m.functions {
        match lower_function(m, f) {
            Ok(lf) => {
                report.lowered_fns += 1;
                report.total_slots += u64::from(lf.nslots);
                report.pool_consts += lf.pool.len() as u64;
                out.insert(name.clone(), lf);
            }
            Err(reason) => report.skipped.push((name.clone(), reason)),
        }
    }
    m.lowered = out;
    report
}

/// Dedup key for pool interning (`f64` keyed by bit pattern so `-0.0`
/// and `NaN` payloads intern exactly).
#[derive(Hash, PartialEq, Eq)]
enum PoolKey {
    I(i64),
    F(u64),
    G(String),
}

struct Lowerer<'m> {
    m: &'m Module,
    slots: HashMap<String, u32>,
    names: Vec<String>,
    pool: Vec<PoolConst>,
    pool_index: HashMap<PoolKey, u32>,
}

fn lower_function(m: &Module, f: &Function) -> Result<LoweredFunction, String> {
    let mut lw = Lowerer {
        m,
        slots: HashMap::new(),
        names: Vec::new(),
        pool: Vec::new(),
        pool_index: HashMap::new(),
    };
    let param_slots: Vec<u32> = f.params.iter().map(|p| lw.def(&p.name)).collect();
    lw.collect_defs(&f.body);
    let body = lw.lower_body(&f.body)?;
    Ok(LoweredFunction {
        nslots: lw.names.len() as u32,
        param_slots,
        pool: lw.pool,
        body,
        names: lw.names,
        fused: 0,
    })
}

impl Lowerer<'_> {
    /// Slot of `name`, allocating on first definition.
    fn def(&mut self, name: &str) -> u32 {
        if let Some(&s) = self.slots.get(name) {
            return s;
        }
        let s = self.names.len() as u32;
        self.slots.insert(name.to_string(), s);
        self.names.push(name.to_string());
        s
    }

    /// Phase 1: visit every definition site in program order so phase 2
    /// rewrites operands against a complete slot map.
    fn collect_defs(&mut self, body: &[Instr]) {
        for ins in body {
            match ins {
                Instr::Assign { dst, .. } | Instr::Alloca { dst, .. } | Instr::Load { dst, .. } => {
                    self.def(dst);
                }
                Instr::Call { dst, .. }
                | Instr::RpcCall { dst, .. }
                | Instr::Intrinsic { dst, .. } => {
                    if let Some(d) = dst {
                        self.def(d);
                    }
                }
                Instr::If { then_body, else_body, .. } => {
                    self.collect_defs(then_body);
                    self.collect_defs(else_body);
                }
                Instr::While { cond, body, .. } => {
                    self.collect_defs(cond);
                    self.collect_defs(body);
                }
                Instr::For { var, body, .. } => {
                    self.def(var);
                    self.collect_defs(body);
                }
                Instr::Parallel { body, .. } => self.collect_defs(body),
                Instr::Store { .. }
                | Instr::KernelLaunch { .. }
                | Instr::Barrier
                | Instr::Return(_) => {}
            }
        }
    }

    fn intern(&mut self, c: PoolConst) -> u32 {
        let key = match &c {
            PoolConst::I(i) => PoolKey::I(*i),
            PoolConst::F(f) => PoolKey::F(f.to_bits()),
            PoolConst::Global(g) => PoolKey::G(g.clone()),
        };
        if let Some(&idx) = self.pool_index.get(&key) {
            return idx;
        }
        let idx = self.pool.len() as u32;
        self.pool.push(c);
        self.pool_index.insert(key, idx);
        idx
    }

    fn op(&mut self, o: &Operand) -> Result<LowOp, String> {
        Ok(match o {
            Operand::Var(v) => {
                let Some(&s) = self.slots.get(v) else {
                    return Err(format!("operand %{v} has no register slot"));
                };
                LowOp::Slot(s)
            }
            Operand::ConstI(i) => LowOp::Pool(self.intern(PoolConst::I(*i))),
            Operand::ConstF(f) => LowOp::Pool(self.intern(PoolConst::F(*f))),
            Operand::Global(g) => LowOp::Pool(self.intern(PoolConst::Global(g.clone()))),
        })
    }

    fn slot(&self, name: &str) -> Result<u32, String> {
        self.slots
            .get(name)
            .copied()
            .ok_or_else(|| format!("%{name} has no register slot"))
    }

    fn expr(&mut self, e: &Expr) -> Result<LowExpr, String> {
        Ok(match e {
            Expr::Op(a) => LowExpr::Op(self.op(a)?),
            Expr::Bin(op, a, b) => LowExpr::Bin(*op, self.op(a)?, self.op(b)?),
            Expr::Gep(a, b) => LowExpr::Gep(self.op(a)?, self.op(b)?),
            Expr::Select(c, a, b) => LowExpr::Select(self.op(c)?, self.op(a)?, self.op(b)?),
            Expr::SiToFp(a) => LowExpr::SiToFp(self.op(a)?),
            Expr::FpToSi(a) => LowExpr::FpToSi(self.op(a)?),
            Expr::Tid => LowExpr::Tid,
            Expr::NumThreads => LowExpr::NumThreads,
            Expr::Sqrt(a) => LowExpr::Sqrt(self.op(a)?),
            Expr::Exp(a) => LowExpr::Exp(self.op(a)?),
            Expr::Log(a) => LowExpr::Log(self.op(a)?),
        })
    }

    fn rpc_arg(&mut self, a: &RpcArgSpec) -> Result<LowRpcArg, String> {
        Ok(match a {
            RpcArgSpec::Val(o) => LowRpcArg::Val(self.op(o)?),
            RpcArgSpec::Ref { ptr, mode, obj_size, offset } => {
                let offset = match offset {
                    OffsetSpec::Const(off) => LowOffset::Const(*off),
                    OffsetSpec::Dynamic => LowOffset::Dynamic,
                };
                LowRpcArg::Ref { ptr: self.op(ptr)?, mode: *mode, obj_size: *obj_size, offset }
            }
            RpcArgSpec::MultiRef { ptr, candidates } => LowRpcArg::MultiRef {
                ptr: self.op(ptr)?,
                candidates: candidates
                    .iter()
                    .map(|(c, mode, size, _)| Ok((self.op(c)?, *mode, *size)))
                    .collect::<Result<Vec<_>, String>>()?,
            },
            RpcArgSpec::DynRef { ptr, mode } => {
                LowRpcArg::DynRef { ptr: self.op(ptr)?, mode: *mode }
            }
        })
    }

    fn lower_body(&mut self, body: &[Instr]) -> Result<Vec<LowInstr>, String> {
        let mut out = Vec::with_capacity(body.len());
        for ins in body {
            out.push(match ins {
                Instr::Assign { dst, expr } => {
                    let expr = self.expr(expr)?;
                    LowInstr::Assign { dst: self.slot(dst)?, expr }
                }
                Instr::Alloca { dst, size } => {
                    LowInstr::Alloca { dst: self.slot(dst)?, size: *size }
                }
                Instr::Store { addr, val, width } => {
                    LowInstr::Store { addr: self.op(addr)?, val: self.op(val)?, width: *width }
                }
                Instr::Load { dst, addr, width, ty } => LowInstr::Load {
                    dst: self.slot(dst)?,
                    addr: self.op(addr)?,
                    width: *width,
                    ty: *ty,
                },
                Instr::Call { dst, callee, args } => LowInstr::Call {
                    dst: dst.as_deref().map(|d| self.slot(d)).transpose()?,
                    callee: callee.clone(),
                    args: args.iter().map(|a| self.op(a)).collect::<Result<_, _>>()?,
                },
                Instr::RpcCall { dst, callee_id, args, .. } => LowInstr::RpcCall {
                    dst: dst.as_deref().map(|d| self.slot(d)).transpose()?,
                    callee_id: *callee_id,
                    args: args.iter().map(|a| self.rpc_arg(a)).collect::<Result<_, _>>()?,
                },
                Instr::KernelLaunch { region, arg } => {
                    let Some(rf) = self.m.functions.get(region) else {
                        return Err(format!("launch of undefined region @{region}"));
                    };
                    // The tree-walk executor reads the region's params
                    // back from the caller scope *by name* at launch
                    // time; resolve that lookup to caller slots now.
                    let params = rf
                        .params
                        .iter()
                        .map(|p| {
                            self.slots.get(&p.name).map(|&s| LowOp::Slot(s)).ok_or_else(|| {
                                format!(
                                    "launch region @{region} param %{} not in caller scope",
                                    p.name
                                )
                            })
                        })
                        .collect::<Result<Vec<_>, String>>()?;
                    LowInstr::KernelLaunch {
                        region: region.clone(),
                        arg: arg.as_ref().map(|a| self.op(a)).transpose()?,
                        params,
                    }
                }
                Instr::If { cond, then_body, else_body } => LowInstr::If {
                    cond: self.op(cond)?,
                    then_body: self.lower_body(then_body)?,
                    else_body: self.lower_body(else_body)?,
                },
                Instr::While { cond_var, cond, body } => LowInstr::While {
                    cond_var: self.slot(cond_var)?,
                    cond: self.lower_body(cond)?,
                    body: self.lower_body(body)?,
                },
                Instr::For { var, lo, hi, step, schedule, body } => LowInstr::For {
                    var: self.slot(var)?,
                    lo: self.op(lo)?,
                    hi: self.op(hi)?,
                    step: self.op(step)?,
                    schedule: *schedule,
                    body: self.lower_body(body)?,
                },
                Instr::Parallel { num_threads, body } => LowInstr::Parallel {
                    num_threads: num_threads.as_ref().map(|n| self.op(n)).transpose()?,
                    body: self.lower_body(body)?,
                },
                Instr::Barrier => LowInstr::Barrier,
                Instr::Return(op) => {
                    LowInstr::Return(op.as_ref().map(|o| self.op(o)).transpose()?)
                }
                Instr::Intrinsic { dst, name, args } => LowInstr::Intrinsic {
                    dst: dst.as_deref().map(|d| self.slot(d)).transpose()?,
                    name: name.clone(),
                    args: args.iter().map(|a| self.op(a)).collect::<Result<_, _>>()?,
                },
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;
    use crate::rpc::ArgMode;

    const SRC: &str = r#"
global @buf 16

func @add(%a: i64, %b: i64) -> i64 {
  %s = add %a, %b
  return %s
}

func @main() -> i64 {
  %x = 5
  %y = call add(%x, 2)
  %p = gep @buf, 0
  store.8 %y, %p
  %z = load.8 %p
  %q = gep @buf, 0
  return %z
}
"#;

    #[test]
    fn slots_pool_and_names_line_up() {
        let mut m = parse_module(SRC).unwrap();
        let report = run(&mut m);
        assert_eq!(report.lowered_fns, 2);
        assert!(report.skipped.is_empty(), "{:?}", report.skipped);

        let add = &m.lowered["add"];
        assert_eq!(add.param_slots, vec![0, 1]);
        assert_eq!(add.nslots, 3, "a, b, s");
        assert_eq!(add.names, vec!["a", "b", "s"]);
        assert_eq!(add.fused, 0, "lowering never fuses");

        let main = &m.lowered["main"];
        assert_eq!(main.nslots as usize, main.names.len());
        // @buf and the two 0 constants intern once each; 5 and 2 once.
        let globals = main
            .pool
            .iter()
            .filter(|c| matches!(c, PoolConst::Global(g) if g == "buf"))
            .count();
        assert_eq!(globals, 1, "@buf interned once: {:?}", main.pool);
        let zeros = main.pool.iter().filter(|c| matches!(c, PoolConst::I(0))).count();
        assert_eq!(zeros, 1, "constant 0 deduplicated: {:?}", main.pool);
    }

    #[test]
    fn dynamic_ref_offset_lowers() {
        // A dynamic-offset Ref used to pin the whole function to the
        // tree-walk executor; it now lowers carrying LowOffset::Dynamic
        // for the marshal-time object lookup.
        let mut m = parse_module("func @main() -> i64 {\n  %p = alloca 8\n  return 0\n}\n").unwrap();
        let f = m.functions.get_mut("main").unwrap();
        f.body.insert(
            1,
            Instr::RpcCall {
                dst: None,
                mangled: "__fwrite_vp".into(),
                callee_id: 7,
                args: vec![RpcArgSpec::Ref {
                    ptr: Operand::var("p"),
                    mode: ArgMode::Read,
                    obj_size: 8,
                    offset: OffsetSpec::Dynamic,
                }],
            },
        );
        let report = run(&mut m);
        assert_eq!(report.lowered_fns, 1);
        assert!(report.skipped.is_empty(), "{:?}", report.skipped);
        let body = &m.lowered["main"].body;
        let has_dyn = body.iter().any(|i| {
            matches!(
                i,
                LowInstr::RpcCall { args, .. }
                    if matches!(args[0], LowRpcArg::Ref { offset: LowOffset::Dynamic, .. })
            )
        });
        assert!(has_dyn, "ref lowers with a dynamic offset: {body:?}");
    }

    #[test]
    fn rerun_replaces_previous_lowering() {
        let mut m = parse_module(SRC).unwrap();
        run(&mut m);
        let before = m.lowered.clone();
        run(&mut m);
        assert_eq!(m.lowered, before, "lowering is deterministic");
    }
}
