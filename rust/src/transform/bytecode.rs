//! The `bytecode` pass: flatten every lowered function into the linear
//! bytecode execution form ([`crate::ir::bytecode`]).
//!
//! Runs after `lower` (and `fuse`, whose superinstructions flatten to
//! fused ops) and rebuilds [`crate::ir::Module::bytecode`] wholesale
//! from [`crate::ir::Module::lowered`]. Functions the `lower` pass kept
//! on the tree-walk path simply have no bytecode either; the
//! interpreter's three-tier dispatch (bytecode → register core → tree)
//! handles them. Every flattening is re-checked with the validating
//! loader before it is installed — an encoding bug fails the compile
//! loudly instead of executing garbage.

use crate::ir::bytecode::{flatten, validate};
use crate::ir::Module;
use std::collections::BTreeMap;

/// What the pass did (→ `CompileReport.bytecode`, `--explain`,
/// `RunMetrics.bytecode_fns`).
#[derive(Debug, Default, Clone)]
pub struct BytecodeReport {
    /// Functions flattened to linear bytecode.
    pub bytecode_fns: u64,
    /// Total ops emitted across all functions.
    pub total_ops: u64,
    /// Side-table entries (call + rpc + launch + parallel sites).
    pub total_sites: u64,
}

impl BytecodeReport {
    /// One-line summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "{} function(s) flattened ({} ops, {} call/rpc/launch/par sites)",
            self.bytecode_fns, self.total_ops, self.total_sites
        )
    }
}

/// Flatten every lowered function of `m` into [`Module::bytecode`],
/// replacing any previous flattening wholesale. The lowered forms are
/// untouched — the bytecode lives alongside them (`--no-bytecode` falls
/// back to the register core).
pub fn run(m: &mut Module) -> BytecodeReport {
    let mut report = BytecodeReport::default();
    let mut out = BTreeMap::new();
    for (name, lf) in &m.lowered {
        let bf = flatten(lf);
        if let Err(e) = validate(&bf) {
            panic!("bytecode flattening of @{name} failed validation: {e}");
        }
        report.bytecode_fns += 1;
        report.total_ops += bf.code.len() as u64;
        report.total_sites +=
            (bf.calls.len() + bf.rpcs.len() + bf.launches.len() + bf.pars.len()) as u64;
        out.insert(name.clone(), bf);
    }
    m.bytecode = out;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;

    const SRC: &str = r#"
global @buf 16

func @main() -> i64 {
  %p = gep @buf, 0
  store.8 41, %p
  %v = load.8 %p
  %r = add %v, 1
  return %r
}
"#;

    #[test]
    fn pass_mirrors_the_lowered_map() {
        let mut m = parse_module(SRC).unwrap();
        crate::transform::lower::run(&mut m);
        crate::transform::fuse::run(&mut m);
        let report = run(&mut m);
        assert_eq!(report.bytecode_fns, 1);
        assert!(report.total_ops > 0);
        assert_eq!(m.bytecode.len(), m.lowered.len());
        assert!(m.bytecode.contains_key("main"));
        assert!(report.summary().contains("1 function(s) flattened"));
    }

    #[test]
    fn rerun_replaces_previous_flattening() {
        let mut m = parse_module(SRC).unwrap();
        crate::transform::lower::run(&mut m);
        run(&mut m);
        let before = m.bytecode.clone();
        run(&mut m);
        assert_eq!(m.bytecode, before, "flattening is deterministic");
    }

    #[test]
    fn no_lowered_forms_means_no_bytecode() {
        let mut m = parse_module(SRC).unwrap();
        let report = run(&mut m);
        assert_eq!(report.bytecode_fns, 0);
        assert!(m.bytecode.is_empty());
    }
}
