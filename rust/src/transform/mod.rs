//! Compile-time transformations — the paper's two compiler contributions.
//!
//! * [`rpcgen`] — automatic RPC generation (paper §3.2, Fig. 3): replaces
//!   library call sites with RPC stubs + synthesized host landing pads.
//! * [`multiteam`] — multi-team execution & kernel split (paper §3.3,
//!   Fig. 4): expands eligible `parallel` regions into grid-wide kernels
//!   launched from the host via RPC.
//! * [`pipeline`] — the "LTO pass pipeline": verify → rpcgen → multiteam →
//!   verify, i.e. what the paper's augmented compiler driver runs.

pub mod rpcgen;
pub mod multiteam;
pub mod pipeline;

pub use pipeline::{compile, CompileOptions, CompileReport};
