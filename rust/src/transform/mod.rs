//! Compile-time transformations — the paper's two compiler contributions.
//!
//! * [`rpcgen`] — automatic RPC generation (paper §3.2, Fig. 3): replaces
//!   library call sites with RPC stubs + synthesized host landing pads.
//! * [`multiteam`] — multi-team execution & kernel split (paper §3.3,
//!   Fig. 4): expands eligible `parallel` regions into grid-wide kernels
//!   launched from the host via RPC.
//! * [`constfold`] — format-string constant folding: folds format
//!   operands (copies, constant `select`s, pass-through parameters)
//!   down to constant globals so `rpcgen` stays on the precise-intent
//!   path of §3.2 instead of the copy-everything fallback.
//! * [`libcres`] — the unified libc/RPC symbol-resolution pass: builds
//!   the module-wide table classifying every external callee as
//!   device-native / host-RPC / unresolved (paper §3.2's dichotomy made
//!   a first-class compile-time artifact).
//! * [`dce`] — dead-code elimination ahead of `rpcgen`: unreachable
//!   functions and post-return code are dropped so dead library call
//!   sites never get landing pads.
//! * [`lower`] — compiles each function to the register-file execution
//!   form ([`crate::ir::lowered`]): dense slot-indexed frames and a
//!   per-function constant pool instead of string-keyed lookups.
//! * [`fuse`] — folds adjacent lowered pairs (cmp+br, gep+load,
//!   gep+store, bin+store) into superinstructions.
//! * [`bytecode`] — flattens each lowered function into the linear
//!   bytecode form ([`crate::ir::bytecode`]): one contiguous op array
//!   with resolved pc branches, executed by the interpreter's flat
//!   `pc`-loop dispatch.
//! * [`pm`] — the pass manager: the [`pm::Pass`] trait, the shared
//!   [`pm::AnalysisCache`], pipeline specs (`--passes` /
//!   `GPU_FIRST_PASSES`) and per-pass timing. Also home to the opt-in
//!   `lint` and `advise` analysis passes
//!   ([`pm::OPTIONAL_PASSES`]) backing `--advise`.
//! * [`pipeline`] — the "LTO pass pipeline" façade: verify → constfold
//!   → dce → libcres → rpcgen → multiteam → lower → fuse → bytecode →
//!   verify, i.e. what the paper's augmented compiler driver runs.

pub mod constfold;
pub mod dce;
pub mod fuse;
pub mod lower;
pub mod bytecode;
pub mod rpcgen;
pub mod multiteam;
pub mod libcres;
pub mod pm;
pub mod pipeline;

pub use bytecode::BytecodeReport;
pub use constfold::ConstFoldReport;
pub use dce::DceReport;
pub use fuse::FuseReport;
pub use libcres::{ResolutionTable, SymbolClass};
pub use lower::LowerReport;
pub use pipeline::{compile, compile_with_spec, CompileOptions, CompileReport};
pub use pm::{
    AnalysisCache, CacheStats, PadCoverage, Pass, PassManager, PassTiming, PipelineSpec,
    OPTIONAL_PASSES,
};
