//! The "augmented compilation path" of paper Fig. 2: the driver that a
//! `clang --gpu-first` invocation would run at link time.
//!
//! Since the pass-manager refactor this file is a thin façade: the
//! pipeline itself is an ordered [`PassManager`](super::pm::PassManager)
//! built either from [`CompileOptions`] (the historical boolean knobs)
//! or from an explicit [`PipelineSpec`](super::pm::PipelineSpec)
//! (`--passes` / `GPU_FIRST_PASSES`). The default pipeline is
//! `verify → constfold → dce → libcres → rpcgen → multiteam → lower →
//! fuse → bytecode → verify`; its tree-transforming prefix is
//! behaviorally identical to the pre-refactor fixed sequence, and the
//! `lower`/`fuse`/`bytecode` tail produces the sidecar execution forms
//! (register file, then linear bytecode) the interpreter prefers.

use super::bytecode::BytecodeReport;
use super::constfold::ConstFoldReport;
use super::dce::DceReport;
use super::fuse::FuseReport;
use super::lower::LowerReport;
use super::multiteam::MultiTeamReport;
use super::pm::{CacheStats, PadCoverage, PassManager, PassTiming, PipelineSpec};
use super::rpcgen::RpcGenReport;
use crate::analysis::advise::AdviseReport;
use crate::analysis::diag::Diagnostics;
use crate::ir::Module;
use crate::rpc::WrapperRegistry;
use crate::transform::libcres::ResolutionTable;

#[derive(Debug, Clone, Copy)]
pub struct CompileOptions {
    /// Fold format-string expressions to constant globals ahead of
    /// resolution so `rpcgen` derives precise buffer intents (§3.2).
    pub constfold: bool,
    /// Drop unreachable functions and post-return code before `rpcgen`
    /// so dead library call sites never get landing pads.
    pub dce: bool,
    /// Build the libc/RPC symbol-resolution table and report unresolved
    /// callees at compile time.
    pub libcres: bool,
    /// Generate RPCs for library calls (§3.2). Off = Tian et al. baseline
    /// where such calls trap.
    pub rpcgen: bool,
    /// Expand parallel regions to the whole device (§3.3). Off = original
    /// single-team direct GPU compilation.
    pub multiteam: bool,
    /// Compile functions to the register-file execution form the
    /// interpreter prefers (slot-indexed frames, interned constants).
    /// Off = tree-walk execution throughout.
    pub lower: bool,
    /// Fold adjacent lowered pairs (cmp+br, gep+load, gep+store,
    /// bin+store) into superinstructions.
    pub fuse: bool,
    /// Flatten lowered functions into the linear bytecode the
    /// interpreter prefers over the register core (flat pc-loop
    /// dispatch, batched team stepping). Off = register-core execution.
    pub bytecode: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        Self {
            constfold: true,
            dce: true,
            libcres: true,
            rpcgen: true,
            multiteam: true,
            lower: true,
            fuse: true,
            bytecode: true,
        }
    }
}

/// Everything the pipeline run produced: per-pass sections, the
/// symbol-resolution table, per-pass wall times, the analysis-cache
/// counters and the AOT pad-coverage verdict.
#[derive(Debug, Default, Clone)]
pub struct CompileReport {
    pub constfold: ConstFoldReport,
    pub dce: DceReport,
    pub rpc: RpcGenReport,
    pub multiteam: MultiTeamReport,
    /// Register-file lowering counts (functions, slots, pool size).
    pub lower: LowerReport,
    /// Superinstruction fusion counts per pair kind.
    pub fuse: FuseReport,
    /// Linear-bytecode flattening counts (functions, ops, sites).
    pub bytecode: BytecodeReport,
    /// The `libcres` table (empty when the pass did not run).
    pub resolution: ResolutionTable,
    /// Executed pass names in order.
    pub pipeline: Vec<String>,
    /// Per-pass wall time + one-line summaries.
    pub timings: Vec<PassTiming>,
    /// Analysis-cache build/hit/invalidation counters.
    pub cache: CacheStats,
    /// AOT pad-coverage check over the compiled module's RPC sites
    /// (missing pads abort the compile instead of appearing here).
    pub pad_coverage: PadCoverage,
    /// The offload advisor's ranked per-region verdicts (empty unless
    /// the opt-in `advise` pass ran).
    pub advise: AdviseReport,
    /// Located lint/advisor diagnostics (empty unless the opt-in
    /// `lint` pass ran). Serve-daemon cache hits retain both this and
    /// `advise` alongside the per-pass counters — only timings clear.
    pub diags: Diagnostics,
}

impl CompileReport {
    /// Total middle-end wall time across all passes.
    pub fn total_pass_ns(&self) -> f64 {
        self.timings.iter().map(|t| t.wall_ns).sum()
    }

    /// Human-readable per-pass lines (`--explain`, verbose runs).
    pub fn timing_lines(&self) -> Vec<String> {
        self.timings
            .iter()
            .map(|t| {
                format!(
                    "{:<10} {:>10}  {}",
                    t.pass,
                    crate::util::fmt_ns(t.wall_ns),
                    t.summary
                )
            })
            .collect()
    }
}

/// Compile with the pipeline [`CompileOptions`] selects (the default:
/// verify → constfold → dce → libcres → rpcgen → multiteam → lower →
/// fuse → bytecode → verify).
pub fn compile(
    m: &mut Module,
    registry: &WrapperRegistry,
    opts: CompileOptions,
) -> Result<CompileReport, Vec<String>> {
    PassManager::from_options(opts).run(m, registry)
}

/// Compile with an explicit pass list (the `--passes` override).
pub fn compile_with_spec(
    m: &mut Module,
    registry: &WrapperRegistry,
    spec: &PipelineSpec,
) -> Result<CompileReport, Vec<String>> {
    PassManager::from_spec(spec).run(m, registry)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;
    use crate::ir::Instr;

    const SRC: &str = r#"
global @fmt const 14 "result: %d%c"

func @main() -> i64 {
  %sum = alloca 8
  store.8 0, %sum
  parallel num_threads(64) {
    %t = tid
    for.team %i = 0 to 4096 step 1 {
      %v = load.8 %sum
    }
  }
  %r = load.8 %sum
  call printf(@fmt, %r, 10)
  return %r
}
"#;

    #[test]
    fn full_pipeline_produces_both_transforms() {
        let mut m = parse_module(SRC).unwrap();
        let reg = WrapperRegistry::new();
        let report = compile(&mut m, &reg, CompileOptions::default()).unwrap();
        assert_eq!(report.rpc.rewritten.len(), 1);
        assert_eq!(report.multiteam.regions.len(), 1);
        let body = &m.functions["main"].body;
        assert!(body.iter().any(|i| matches!(i, Instr::KernelLaunch { .. })));
        assert!(body.iter().any(|i| matches!(i, Instr::RpcCall { .. })));
        // The pass-manager surface: executed passes, timings, resolution.
        assert_eq!(
            report.pipeline,
            vec!["constfold", "dce", "libcres", "rpcgen", "multiteam", "lower", "fuse", "bytecode"]
        );
        assert_eq!(report.timings.len(), 8);
        assert!(report.total_pass_ns() >= 0.0);
        assert!(report.resolution.host_kind("printf").is_some());
        // The register-file and bytecode sidecars exist for every
        // surviving function.
        assert_eq!(report.lower.lowered_fns as usize, m.functions.len());
        assert!(m.lowered.contains_key("main"));
        assert_eq!(report.bytecode.bytecode_fns, report.lower.lowered_fns);
        assert!(m.bytecode.contains_key("main"));
        // The AOT coverage check verified the rewritten site's pads.
        assert_eq!(report.pad_coverage.sites, 1);
        assert!(report.pad_coverage.missing.is_empty());
    }

    #[test]
    fn options_disable_passes() {
        let mut m = parse_module(SRC).unwrap();
        let reg = WrapperRegistry::new();
        let report = compile(
            &mut m,
            &reg,
            CompileOptions {
                constfold: false,
                dce: false,
                libcres: false,
                rpcgen: false,
                multiteam: false,
                lower: false,
                fuse: false,
                bytecode: false,
            },
        )
        .unwrap();
        assert!(report.rpc.rewritten.is_empty());
        assert!(report.multiteam.regions.is_empty());
        assert!(report.pipeline.is_empty());
        assert!(report.resolution.symbols.is_empty());
        let body = &m.functions["main"].body;
        assert!(body.iter().any(|i| matches!(i, Instr::Parallel { .. })));
    }

    #[test]
    fn spec_pipeline_equals_options_pipeline() {
        let reg = WrapperRegistry::new();
        let mut m_opts = parse_module(SRC).unwrap();
        compile(&mut m_opts, &reg, CompileOptions::default()).unwrap();
        let reg2 = WrapperRegistry::new();
        let mut m_spec = parse_module(SRC).unwrap();
        compile_with_spec(&mut m_spec, &reg2, &PipelineSpec::default()).unwrap();
        assert_eq!(m_opts, m_spec, "options and spec construction must agree");
    }

    #[test]
    fn invalid_module_rejected_before_transform() {
        let mut m = parse_module("func @main() -> i64 {\n  return %undef\n}\n").unwrap();
        let reg = WrapperRegistry::new();
        assert!(compile(&mut m, &reg, CompileOptions::default()).is_err());
    }

    #[test]
    fn unresolved_symbols_are_compile_time_diagnostics() {
        let src = "func @main() -> i64 {\n  call dgemm(1)\n  return 0\n}\n";
        let mut m = parse_module(src).unwrap();
        let reg = WrapperRegistry::new();
        let report = compile(&mut m, &reg, CompileOptions::default()).unwrap();
        assert_eq!(report.resolution.unresolved(), vec!["dgemm"]);
        assert_eq!(report.rpc.unsupported, vec!["dgemm".to_string()]);
    }
}
