//! The "augmented compilation path" of paper Fig. 2: the driver that a
//! `clang --gpu-first` invocation would run at link time.

use super::{multiteam, rpcgen};
use crate::ir::Module;
use crate::rpc::WrapperRegistry;

#[derive(Debug, Clone, Copy)]
pub struct CompileOptions {
    /// Generate RPCs for library calls (§3.2). Off = Tian et al. baseline
    /// where such calls trap.
    pub rpcgen: bool,
    /// Expand parallel regions to the whole device (§3.3). Off = original
    /// single-team direct GPU compilation.
    pub multiteam: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        Self { rpcgen: true, multiteam: true }
    }
}

#[derive(Debug, Default, Clone)]
pub struct CompileReport {
    pub rpc: rpcgen::RpcGenReport,
    pub multiteam: multiteam::MultiTeamReport,
}

/// Verify → rpcgen → multi-team expansion → verify.
pub fn compile(
    m: &mut Module,
    registry: &WrapperRegistry,
    opts: CompileOptions,
) -> Result<CompileReport, Vec<String>> {
    m.verify()?;
    let mut report = CompileReport::default();
    if opts.rpcgen {
        report.rpc = rpcgen::run(m, registry);
    }
    if opts.multiteam {
        report.multiteam = multiteam::run(m);
    }
    m.verify()?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;
    use crate::ir::Instr;

    const SRC: &str = r#"
global @fmt const 14 "result: %d%c"

func @main() -> i64 {
  %sum = alloca 8
  store.8 0, %sum
  parallel num_threads(64) {
    %t = tid
    for.team %i = 0 to 4096 step 1 {
      %v = load.8 %sum
    }
  }
  %r = load.8 %sum
  call printf(@fmt, %r, 10)
  return %r
}
"#;

    #[test]
    fn full_pipeline_produces_both_transforms() {
        let mut m = parse_module(SRC).unwrap();
        let reg = WrapperRegistry::new();
        let report = compile(&mut m, &reg, CompileOptions::default()).unwrap();
        assert_eq!(report.rpc.rewritten.len(), 1);
        assert_eq!(report.multiteam.regions.len(), 1);
        let body = &m.functions["main"].body;
        assert!(body.iter().any(|i| matches!(i, Instr::KernelLaunch { .. })));
        assert!(body.iter().any(|i| matches!(i, Instr::RpcCall { .. })));
    }

    #[test]
    fn options_disable_passes() {
        let mut m = parse_module(SRC).unwrap();
        let reg = WrapperRegistry::new();
        let report =
            compile(&mut m, &reg, CompileOptions { rpcgen: false, multiteam: false }).unwrap();
        assert!(report.rpc.rewritten.is_empty());
        assert!(report.multiteam.regions.is_empty());
        let body = &m.functions["main"].body;
        assert!(body.iter().any(|i| matches!(i, Instr::Parallel { .. })));
    }

    #[test]
    fn invalid_module_rejected_before_transform() {
        let mut m = parse_module("func @main() -> i64 {\n  return %undef\n}\n").unwrap();
        let reg = WrapperRegistry::new();
        assert!(compile(&mut m, &reg, CompileOptions::default()).is_err());
    }
}
