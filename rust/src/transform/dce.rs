//! Dead-code elimination ahead of `rpcgen`: drop functions no execution
//! path can reach, truncate straight-line code after a `return`, and
//! evict `constfold` suffix globals (`@g__sfxK`) that no surviving
//! instruction references (user-named globals are never touched).
//!
//! Reachability is seeded from `@main` plus every extracted kernel
//! region (launched by id through the RPC executor, so they must
//! survive even when the launch site is in another function), and
//! closed over the cached [`CallGraph`] `Call` edges *plus*
//! `KernelLaunch` targets (the call graph deliberately records only
//! direct calls, so launch edges are collected by a walk here).
//!
//! The payoff is smaller than "less code runs": `rpcgen` synthesizes a
//! landing pad per library call site it sees, so removing an
//! unreachable function removes host pads from the registry's working
//! set and the AOT coverage check.
//!
//! A module with no `@main` is left untouched — bare-function corpora
//! (unit tests, benches) define no entry point, and guessing roots
//! there would delete everything.

use super::pm::AnalysisCache;
use crate::analysis::callgraph::walk;
use crate::ir::{expr_operands, Instr, Module, Operand, RpcArgSpec};
use std::collections::BTreeSet;

/// What the pass removed (→ `CompileReport.dce`, `--explain`).
#[derive(Debug, Default, Clone)]
pub struct DceReport {
    /// Unreachable functions dropped, by name.
    pub removed_fns: Vec<String>,
    /// Instructions truncated after a straight-line `return`.
    pub removed_instrs: u64,
    /// Orphaned constfold suffix globals (`@g__sfxK`) evicted because
    /// no surviving instruction references them.
    pub removed_globals: Vec<String>,
}

impl DceReport {
    /// One-line summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "{} unreachable function(s) removed, {} post-return instr(s) truncated, \
             {} suffix global(s) evicted",
            self.removed_fns.len(),
            self.removed_instrs,
            self.removed_globals.len()
        )
    }

    pub fn changed(&self) -> bool {
        !self.removed_fns.is_empty() || self.removed_instrs > 0 || !self.removed_globals.is_empty()
    }
}

/// Run DCE over `m` using the shared analysis cache for the call graph.
pub fn run_with(m: &mut Module, cache: &mut AnalysisCache) -> DceReport {
    let mut report = DceReport::default();
    if !m.functions.contains_key("main") {
        return report;
    }
    let edges = cache.callgraph(m).edges.clone();
    let mut reachable: BTreeSet<String> = BTreeSet::new();
    let mut stack: Vec<String> = m
        .functions
        .iter()
        .filter(|(n, f)| n.as_str() == "main" || f.is_kernel_region)
        .map(|(n, _)| n.clone())
        .collect();
    while let Some(cur) = stack.pop() {
        if !reachable.insert(cur.clone()) {
            continue;
        }
        if let Some(callees) = edges.get(&cur) {
            stack.extend(callees.iter().cloned());
        }
        // Launch edges are not in the call graph; collect them here.
        if let Some(f) = m.functions.get(&cur) {
            walk(&f.body, &mut |ins| {
                if let Instr::KernelLaunch { region, .. } = ins {
                    stack.push(region.clone());
                }
            });
        }
    }
    report.removed_fns =
        m.functions.keys().filter(|n| !reachable.contains(*n)).cloned().collect();
    for name in &report.removed_fns {
        m.functions.remove(name);
        m.lowered.remove(name);
    }
    for f in m.functions.values_mut() {
        report.removed_instrs += truncate_after_return(&mut f.body, true);
    }
    report.removed_globals = evict_orphaned_suffix_globals(m);
    report
}

/// `constfold` materializes folded format strings as `@g__sfxK`
/// globals. When the call site that referenced one is later removed
/// (unreachable function, post-return truncation), the global is an
/// orphan: nothing loads it, and `rpcgen` would never see it. Drop
/// every suffix global no surviving instruction references. Only
/// `__sfx<digits>`-named globals are candidates — user globals are
/// never evicted, referenced or not (the host side may map them).
fn evict_orphaned_suffix_globals(m: &mut Module) -> Vec<String> {
    let mut referenced: BTreeSet<String> = BTreeSet::new();
    for f in m.functions.values() {
        walk(&f.body, &mut |ins| {
            collect_global_refs(ins, &mut referenced);
        });
    }
    let orphans: Vec<String> = m
        .globals
        .keys()
        .filter(|g| is_suffix_global(g) && !referenced.contains(*g))
        .cloned()
        .collect();
    for g in &orphans {
        m.globals.remove(g);
    }
    orphans
}

fn is_suffix_global(name: &str) -> bool {
    name.rfind("__sfx").is_some_and(|i| {
        let digits = &name[i + "__sfx".len()..];
        !digits.is_empty() && digits.bytes().all(|b| b.is_ascii_digit())
    })
}

/// Record every `@global` operand `ins` itself mentions (nested bodies
/// are covered by the caller's `walk`).
fn collect_global_refs(ins: &Instr, out: &mut BTreeSet<String>) {
    let mut op = |o: &Operand| {
        if let Operand::Global(g) = o {
            out.insert(g.clone());
        }
    };
    match ins {
        Instr::Assign { expr, .. } => {
            for o in expr_operands(expr) {
                op(o);
            }
        }
        Instr::Store { addr, val, .. } => {
            op(addr);
            op(val);
        }
        Instr::Load { addr, .. } => op(addr),
        Instr::Call { args, .. } | Instr::Intrinsic { args, .. } => {
            for a in args {
                op(a);
            }
        }
        Instr::RpcCall { args, .. } => {
            for spec in args {
                match spec {
                    RpcArgSpec::Val(o) => op(o),
                    RpcArgSpec::Ref { ptr, .. } | RpcArgSpec::DynRef { ptr, .. } => op(ptr),
                    RpcArgSpec::MultiRef { ptr, candidates } => {
                        op(ptr);
                        for (cand, _, _, _) in candidates {
                            op(cand);
                        }
                    }
                }
            }
        }
        Instr::KernelLaunch { arg, .. } => {
            if let Some(a) = arg {
                op(a);
            }
        }
        Instr::If { cond, .. } => op(cond),
        Instr::For { lo, hi, step, .. } => {
            op(lo);
            op(hi);
            op(step);
        }
        Instr::Parallel { num_threads, .. } => {
            if let Some(n) = num_threads {
                op(n);
            }
        }
        Instr::Return(Some(o)) => op(o),
        Instr::Alloca { .. }
        | Instr::While { .. }
        | Instr::Barrier
        | Instr::Return(None) => {}
    }
}

/// Count every instruction in `body`, including nested ones.
fn count_instrs(body: &[Instr]) -> u64 {
    let mut n = 0;
    walk(body, &mut |_| n += 1);
    n
}

/// Drop everything after the first top-level `return` of each body
/// list, recursively. `allow_top` is false for `while` condition blocks:
/// their top level must keep defining the condition variable even after
/// an (unreachable) early return, or the verifier rejects the result.
fn truncate_after_return(body: &mut Vec<Instr>, allow_top: bool) -> u64 {
    let mut removed = 0;
    if allow_top {
        if let Some(pos) = body.iter().position(|i| matches!(i, Instr::Return(_))) {
            if pos + 1 < body.len() {
                let tail = body.split_off(pos + 1);
                removed += count_instrs(&tail);
            }
        }
    }
    for ins in body.iter_mut() {
        match ins {
            Instr::If { then_body, else_body, .. } => {
                removed += truncate_after_return(then_body, true);
                removed += truncate_after_return(else_body, true);
            }
            Instr::While { cond, body, .. } => {
                removed += truncate_after_return(cond, false);
                removed += truncate_after_return(body, true);
            }
            Instr::For { body, .. } | Instr::Parallel { body, .. } => {
                removed += truncate_after_return(body, true);
            }
            _ => {}
        }
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;

    #[test]
    fn unreachable_functions_are_removed() {
        let src = r#"
func @used() -> i64 {
  return 1
}

func @dead() -> i64 {
  call fprintf(2)
  return 2
}

func @also_dead() -> i64 {
  %x = call dead()
  return %x
}

func @main() -> i64 {
  %r = call used()
  return %r
}
"#;
        let mut m = parse_module(src).unwrap();
        let mut cache = AnalysisCache::default();
        let report = run_with(&mut m, &mut cache);
        assert_eq!(report.removed_fns, vec!["also_dead".to_string(), "dead".into()]);
        assert!(report.changed());
        assert!(m.functions.contains_key("used"));
        assert!(!m.functions.contains_key("dead"));
        assert!(m.verify().is_ok());
    }

    #[test]
    fn kernel_regions_and_launch_targets_survive() {
        let src = r#"
func @region(%n: i64) -> void kernel {
  return
}

func @main() -> i64 {
  %n = 4
  launch @region
  return 0
}
"#;
        let mut m = parse_module(src).unwrap();
        let mut cache = AnalysisCache::default();
        let report = run_with(&mut m, &mut cache);
        assert!(report.removed_fns.is_empty(), "{report:?}");
        assert!(!report.changed());
        assert!(m.functions.contains_key("region"));
    }

    #[test]
    fn post_return_code_is_truncated() {
        let src = r#"
func @main() -> i64 {
  if 1 {
    return 1
    %x = 2
    %y = add %x, 1
  }
  return 0
  %dead = 3
}
"#;
        let mut m = parse_module(src).unwrap();
        let mut cache = AnalysisCache::default();
        let report = run_with(&mut m, &mut cache);
        assert_eq!(report.removed_instrs, 3, "{report:?}");
        assert!(m.verify().is_ok());
        assert_eq!(m.functions["main"].body.len(), 2, "if + return survive");
    }

    #[test]
    fn orphaned_suffix_globals_are_evicted() {
        // @fmt__sfx0 is only referenced from @dead, which DCE removes;
        // @fmt__sfx1 stays referenced from @main; @user is not a suffix
        // global and survives even though nothing references it.
        let src = r#"
global @fmt__sfx0 const 4 "%d\n"
global @fmt__sfx1 const 4 "%s\n"
global @user 8

func @dead() -> i64 {
  call printf(@fmt__sfx0, 1)
  return 0
}

func @main() -> i64 {
  call printf(@fmt__sfx1, 2)
  return 0
}
"#;
        let mut m = parse_module(src).unwrap();
        let mut cache = AnalysisCache::default();
        let report = run_with(&mut m, &mut cache);
        assert_eq!(report.removed_fns, vec!["dead".to_string()]);
        assert_eq!(report.removed_globals, vec!["fmt__sfx0".to_string()]);
        assert!(report.changed());
        assert!(!m.globals.contains_key("fmt__sfx0"));
        assert!(m.globals.contains_key("fmt__sfx1"));
        assert!(m.globals.contains_key("user"), "non-suffix globals are never evicted");
        assert!(report.summary().contains("1 suffix global(s) evicted"));
        assert!(m.verify().is_ok());
    }

    #[test]
    fn referenced_suffix_globals_survive_truncation() {
        let src = r#"
global @s__sfx7 const 3 "ok"

func @main() -> i64 {
  call puts(@s__sfx7)
  return 0
  call puts(@s__sfx7)
}
"#;
        let mut m = parse_module(src).unwrap();
        let mut cache = AnalysisCache::default();
        let report = run_with(&mut m, &mut cache);
        assert_eq!(report.removed_instrs, 1);
        assert!(report.removed_globals.is_empty(), "live reference keeps the global");
        assert!(m.globals.contains_key("s__sfx7"));
    }

    #[test]
    fn modules_without_main_are_untouched() {
        let src = "func @helper() -> i64 {\n  return 0\n}\n";
        let mut m = parse_module(src).unwrap();
        let before = m.clone();
        let mut cache = AnalysisCache::default();
        let report = run_with(&mut m, &mut cache);
        assert!(!report.changed());
        assert_eq!(m, before);
    }
}
