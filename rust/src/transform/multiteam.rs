//! Multi-team execution & kernel split (paper §3.3, Fig. 4).
//!
//! The natural OpenMP offload mapping runs a `parallel` region with the
//! threads of ONE team — unusable for scaling studies. This pass converts
//! eligible parallel regions into *kernel regions*:
//!
//! * the region body is outlined into a new `__region_N` function marked
//!   `kernel`, whose parameters are the region's captured scalars (the
//!   "same arguments the parallel region would have been given");
//! * the `parallel` construct is replaced by a [`Instr::KernelLaunch`]
//!   which the interpreter lowers to a host RPC
//!   (`__gpu_first_launch_kernel`) that launches the region over the whole
//!   grid (Fig. 4 right: ① RPC → ② parallel kernel → ③ completion);
//! * automatic work-sharing loops (`for.team`, i.e. `omp for`) are
//!   rescheduled to span all teams (`for.grid`, i.e. `distribute parallel
//!   for`), and thread-id / num-threads queries keep their source
//!   semantics because the launched grid exposes *continuous* global
//!   thread ids;
//! * `barrier` becomes a cross-team barrier (global atomic counters on
//!   real GPUs; a true barrier in the simulator).

use super::pm::AnalysisCache;
use crate::ir::{expr_operands, Function, Instr, Module, Operand, Param, Schedule, Ty};

#[derive(Debug, Default, Clone)]
pub struct MultiTeamReport {
    /// (host function, region function, captured variables, had barrier).
    pub regions: Vec<RegionInfo>,
    /// Parallel regions left single-team (ineligible).
    pub skipped: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct RegionInfo {
    pub in_function: String,
    pub region: String,
    pub captures: Vec<String>,
    pub has_barrier: bool,
    /// The region's `num_threads` clause operand, if any (printed form).
    pub num_threads: Option<Operand>,
}

/// Run the pass standalone (builds its own analysis cache). The
/// pass-manager path goes through [`run_with`].
pub fn run(m: &mut Module) -> MultiTeamReport {
    run_with(m, &mut AnalysisCache::default())
}

/// Run the pass with a shared analysis cache: eligibility is judged
/// against the cached call graph. Every eligible `parallel` region is
/// outlined and split.
pub fn run_with(m: &mut Module, cache: &mut AnalysisCache) -> MultiTeamReport {
    // Eligibility is judged against the ORIGINAL module: once a function's
    // own region is outlined it no longer "contains parallel", but callers
    // must still treat it as parallel (its kernel launch would nest).
    let parallel_fns: std::collections::BTreeSet<String> = {
        let cg = cache.callgraph(m);
        m.functions
            .keys()
            .filter(|f| cg.transitively_parallel(m, f))
            .cloned()
            .collect()
    };
    let mut report = MultiTeamReport::default();
    let fnames: Vec<String> = m.functions.keys().cloned().collect();
    let mut new_fns: Vec<Function> = Vec::new();
    let mut counter = 0usize;
    for fname in fnames {
        // Kernel regions themselves are not re-expanded.
        if m.functions[&fname].is_kernel_region {
            continue;
        }
        let mut f = m.functions[&fname].clone();
        rewrite_body(
            m,
            &parallel_fns,
            &fname,
            &mut f.body,
            &mut new_fns,
            &mut counter,
            &mut report,
        );
        m.functions.insert(fname, f);
    }
    for f in new_fns {
        m.functions.insert(f.name.clone(), f);
    }
    report
}

fn rewrite_body(
    m: &Module,
    parallel_fns: &std::collections::BTreeSet<String>,
    fname: &str,
    body: &mut Vec<Instr>,
    new_fns: &mut Vec<Function>,
    counter: &mut usize,
    report: &mut MultiTeamReport,
) {
    for ins in body.iter_mut() {
        match ins {
            Instr::Parallel { num_threads, body: region_body } => {
                if !eligible(m, parallel_fns, region_body) {
                    report.skipped.push(fname.to_string());
                    continue;
                }
                let region_name = format!("__region_{}", *counter);
                *counter += 1;
                let captures = free_vars(region_body);
                let mut outlined = region_body.clone();
                reschedule(&mut outlined);
                let has_barrier = contains_barrier(&outlined);
                new_fns.push(Function {
                    name: region_name.clone(),
                    params: captures
                        .iter()
                        .map(|c| Param { name: c.clone(), ty: Ty::I64 })
                        .collect(),
                    ret: Ty::Void,
                    body: outlined,
                    is_kernel_region: true,
                });
                report.regions.push(RegionInfo {
                    in_function: fname.to_string(),
                    region: region_name.clone(),
                    captures,
                    has_barrier,
                    num_threads: num_threads.clone(),
                });
                // The launch's `arg` carries the num_threads request (the
                // coordinator picks teams × threads from it).
                *ins = Instr::KernelLaunch { region: region_name, arg: num_threads.clone() };
            }
            Instr::If { then_body, else_body, .. } => {
                rewrite_body(m, parallel_fns, fname, then_body, new_fns, counter, report);
                rewrite_body(m, parallel_fns, fname, else_body, new_fns, counter, report);
            }
            Instr::While { cond, body, .. } => {
                rewrite_body(m, parallel_fns, fname, cond, new_fns, counter, report);
                rewrite_body(m, parallel_fns, fname, body, new_fns, counter, report);
            }
            Instr::For { body, .. } => {
                rewrite_body(m, parallel_fns, fname, body, new_fns, counter, report)
            }
            _ => {}
        }
    }
}

/// Eligibility (paper: "the workload of many parallel regions can be
/// executed by multiple teams without violating the program semantics"):
/// we reject regions that call functions which are themselves parallel
/// (nested parallelism) and regions that issue RPCs — the kernel-split
/// launch occupies the single RPC slot for the whole region (paper §4.4:
/// single-threaded RPC handling), so an in-region RPC would deadlock
/// against its own launch. Such regions still run single-team, where RPCs
/// work because no launch RPC is outstanding.
fn eligible(m: &Module, parallel_fns: &std::collections::BTreeSet<String>, body: &[Instr]) -> bool {
    let mut calls_parallel = false;
    let mut has_rpcish = false;
    crate::analysis::callgraph::walk(body, &mut |ins| match ins {
        Instr::Call { callee, .. } => {
            if parallel_fns.contains(callee) {
                calls_parallel = true;
            }
            if !m.is_defined(callee) && !Module::is_native_intrinsic(callee) {
                has_rpcish = true;
            }
        }
        Instr::RpcCall { .. } => has_rpcish = true,
        _ => {}
    });
    !calls_parallel && !has_rpcish
}

/// Change `omp for` (team schedule) into `distribute parallel for` (grid
/// schedule) throughout the outlined region.
fn reschedule(body: &mut [Instr]) {
    for ins in body.iter_mut() {
        match ins {
            Instr::For { schedule, body, .. } => {
                if *schedule == Schedule::Team {
                    *schedule = Schedule::Grid;
                }
                reschedule(body);
            }
            Instr::If { then_body, else_body, .. } => {
                reschedule(then_body);
                reschedule(else_body);
            }
            Instr::While { cond, body, .. } => {
                reschedule(cond);
                reschedule(body);
            }
            _ => {}
        }
    }
}

fn contains_barrier(body: &[Instr]) -> bool {
    let mut found = false;
    crate::analysis::callgraph::walk(body, &mut |ins| {
        if matches!(ins, Instr::Barrier) {
            found = true;
        }
    });
    found
}

/// Variables used by `body` but defined outside it, in first-use order —
/// the values the kernel launch must forward.
pub fn free_vars(body: &[Instr]) -> Vec<String> {
    let mut defined: Vec<String> = Vec::new();
    let mut free: Vec<String> = Vec::new();
    collect_free(body, &mut defined, &mut free);
    free
}

fn use_op(op: &Operand, defined: &[String], free: &mut Vec<String>) {
    if let Operand::Var(v) = op {
        if !defined.contains(v) && !free.contains(v) {
            free.push(v.clone());
        }
    }
}

fn collect_free(body: &[Instr], defined: &mut Vec<String>, free: &mut Vec<String>) {
    for ins in body {
        match ins {
            Instr::Assign { dst, expr } => {
                for op in expr_operands(expr) {
                    use_op(op, defined, free);
                }
                defined.push(dst.clone());
            }
            Instr::Alloca { dst, .. } => defined.push(dst.clone()),
            Instr::Store { addr, val, .. } => {
                use_op(addr, defined, free);
                use_op(val, defined, free);
            }
            Instr::Load { dst, addr, .. } => {
                use_op(addr, defined, free);
                defined.push(dst.clone());
            }
            Instr::Call { dst, args, .. } | Instr::Intrinsic { dst, args, .. } => {
                for a in args {
                    use_op(a, defined, free);
                }
                if let Some(d) = dst {
                    defined.push(d.clone());
                }
            }
            Instr::RpcCall { dst, args, .. } => {
                for a in args {
                    match a {
                        crate::ir::RpcArgSpec::Val(o)
                        | crate::ir::RpcArgSpec::DynRef { ptr: o, .. } => use_op(o, defined, free),
                        crate::ir::RpcArgSpec::Ref { ptr, .. } => use_op(ptr, defined, free),
                        crate::ir::RpcArgSpec::MultiRef { ptr, candidates } => {
                            use_op(ptr, defined, free);
                            for (c, _, _, _) in candidates {
                                use_op(c, defined, free);
                            }
                        }
                    }
                }
                if let Some(d) = dst {
                    defined.push(d.clone());
                }
            }
            Instr::KernelLaunch { arg, .. } => {
                if let Some(a) = arg {
                    use_op(a, defined, free);
                }
            }
            Instr::If { cond, then_body, else_body } => {
                use_op(cond, defined, free);
                collect_free(then_body, defined, free);
                collect_free(else_body, defined, free);
            }
            Instr::While { cond, body, .. } => {
                collect_free(cond, defined, free);
                collect_free(body, defined, free);
            }
            Instr::For { var, lo, hi, step, body, .. } => {
                use_op(lo, defined, free);
                use_op(hi, defined, free);
                use_op(step, defined, free);
                defined.push(var.clone());
                collect_free(body, defined, free);
            }
            Instr::Parallel { num_threads, body } => {
                if let Some(n) = num_threads {
                    use_op(n, defined, free);
                }
                collect_free(body, defined, free);
            }
            Instr::Return(Some(op)) => use_op(op, defined, free),
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;

    const SRC: &str = r#"
global @out 8192

func @main() -> i64 {
  %n = 1024
  %base = gep @out, 0
  parallel num_threads(128) {
    %t = tid
    %nt = nthreads
    for.team %i = 0 to %n step 1 {
      %off = mul %i, 8
      %p = gep %base, %off
      store.8 %i, %p
    }
    barrier
  }
  return 0
}
"#;

    #[test]
    fn parallel_region_becomes_kernel_launch() {
        let mut m = parse_module(SRC).unwrap();
        let report = run(&mut m);
        m.verify().unwrap();
        assert_eq!(report.regions.len(), 1);
        let info = &report.regions[0];
        assert_eq!(info.region, "__region_0");
        assert_eq!(info.captures, vec!["n".to_string(), "base".to_string()]);
        assert!(info.has_barrier);
        assert!(matches!(info.num_threads, Some(Operand::ConstI(128))));

        // Main now launches instead of running parallel inline.
        let body = &m.functions["main"].body;
        assert!(body
            .iter()
            .any(|i| matches!(i, Instr::KernelLaunch { region, .. } if region == "__region_0")));
        assert!(!body.iter().any(|i| matches!(i, Instr::Parallel { .. })));

        // The region function exists, is a kernel, takes the captures.
        let region = &m.functions["__region_0"];
        assert!(region.is_kernel_region);
        assert_eq!(region.params.len(), 2);
        // omp for -> distribute parallel for.
        let Instr::For { schedule, .. } = &region.body[2] else { panic!() };
        assert_eq!(*schedule, Schedule::Grid);
    }

    #[test]
    fn round_trips_through_text() {
        let mut m = parse_module(SRC).unwrap();
        run(&mut m);
        let text = crate::ir::printer::print_module(&m);
        let m2 = parse_module(&text).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn nested_parallel_call_is_skipped() {
        let src = r#"
func @inner() -> void {
  parallel {
    %t = tid
  }
  return
}

func @main() -> i64 {
  parallel {
    call inner()
  }
  return 0
}
"#;
        let mut m = parse_module(src).unwrap();
        let report = run(&mut m);
        // @inner's region expands; @main's (which calls parallel code) not.
        assert_eq!(report.regions.len(), 1);
        assert_eq!(report.regions[0].in_function, "inner");
        assert_eq!(report.skipped, vec!["main".to_string()]);
    }

    #[test]
    fn rpc_plus_barrier_region_is_skipped() {
        let src = r#"
func @main() -> i64 {
  parallel {
    call fprintf(2)
    barrier
  }
  return 0
}
"#;
        let mut m = parse_module(src).unwrap();
        let report = run(&mut m);
        assert!(report.regions.is_empty());
        assert_eq!(report.skipped.len(), 1);
    }

    #[test]
    fn free_vars_order_and_shadowing() {
        let src = r#"
func @main() -> i64 {
  %a = 1
  %b = 2
  %c = 3
  parallel {
    %x = add %b, %a
    %a2 = add %x, %c
  }
  return 0
}
"#;
        let m = parse_module(src).unwrap();
        let Instr::Parallel { body, .. } = &m.functions["main"].body[3] else { panic!() };
        assert_eq!(free_vars(body), vec!["b".to_string(), "a".into(), "c".into()]);
    }
}
