//! `libcres` — the unified libc/RPC symbol-resolution *pass*.
//!
//! The paper's §3.2 dichotomy ("either resolved through our partial libc
//! GPU implementation or via automatically generated remote procedure
//! calls to the host") used to live in three disconnected places: the
//! parser's intrinsic check, `rpcgen`'s landing-pad lookup, and the
//! interpreter's string-matched intrinsic dispatch. The underlying
//! analysis — [`resolve_module`] building a module-wide
//! [`ResolutionTable`] — lives with the other interprocedural analyses
//! in [`crate::analysis::resolution`] (so the interpreter can dispatch
//! through it without depending on the middle-end); this module re-exports
//! it for the pass layer.
//!
//! The pass itself (`libcres` in [`super::pm`]) materializes the cached
//! table into the [`CompileReport`](super::CompileReport): each external
//! callee is classified *device-native* / *host-RPC* / *unresolved*,
//! unresolved symbols become compile-time diagnostics (listed in the
//! report and `--explain` instead of a runtime panic), and `rpcgen`
//! consumes the table so only host-RPC callees get landing pads.

pub use crate::analysis::resolution::{
    resolve_module, ResolutionTable, SymbolClass, SymbolInfo,
};
