//! `constfold` — format-string constant folding (widens the paper's
//! §3.2 precise-intent path).
//!
//! `rpcgen` derives *precise* per-argument intents only when a variadic
//! call's format string is a compile-time constant it can read; any
//! format it cannot resolve drops the whole call site into the
//! pessimistic "copy every buffer both ways" path (the Fig. 7 `fprintf`
//! case). The underlying-object analysis already follows plain
//! single-assignment chains, so what actually escapes precision today
//! is:
//!
//! * `select` between constant globals whose condition is itself a
//!   compile-time constant (the analysis enumerates both candidates and
//!   refuses to pick, so the format text stays unknown), and
//! * **pass-through arguments**: a wrapper function receiving the format
//!   as a parameter (`log(fmt, x)` called with a constant global at
//!   every site) — parameters classify as dynamic-origin.
//!
//! This pass folds exactly those shapes: for every call site the
//! resolution table classifies as a printf/scanf-family host RPC, the
//! format operand's def chain is folded through copies,
//! constant-offset `gep`s and constant-condition `select`s;
//! interprocedurally, a parameter that every caller binds to the *same*
//! constant — a constant global *or* an integer — is folded inside the
//! callee, so a `select` whose condition is a consistently-bound
//! integer parameter picks its side too. A successful fold rewrites the
//! format operand to the global itself, so `rpcgen`'s `parse_format`
//! sees literal text and classifies the trailing buffers precisely
//! instead of read-write. A chain landing at constant **non-zero**
//! offset `K` into a constant global `@g` (the `fmt + K` idiom — skip a
//! prefix, print the tail) synthesizes a *suffix global* `@g__sfxK`
//! initialized with `@g`'s bytes from `K` on and rewrites the operand
//! to that, so `fmt+K` call sites get precise intents too. The
//! parameter bindings are iterated to a fixed point, so constants flow
//! through nested wrappers before the single rewrite round.
//!
//! Only format operands of format-taking host-RPC callees are rewritten;
//! the pass never touches computation, so a program where nothing folds
//! is byte-identical to its unfolded compilation (the `constfold`
//! equivalence suite proves outputs match either way).

use super::libcres::{resolve_module, ResolutionTable};
use crate::analysis::callgraph::walk;
use crate::analysis::objects::def_map;
use crate::ir::{Expr, Global, Instr, Module, Operand};
use crate::rpc::wrappers::HostFnKind;
use std::collections::{BTreeMap, HashMap};

/// What the pass did — consumed by tests, `--explain` and `RunMetrics`.
#[derive(Debug, Default, Clone)]
pub struct ConstFoldReport {
    /// (function, callee, folded operand rendering, global it folded to).
    pub folded: Vec<(String, String, String, String)>,
}

impl ConstFoldReport {
    /// Format operands folded to constant globals.
    pub fn count(&self) -> u64 {
        self.folded.len() as u64
    }

    /// One-line summary for pass reports.
    pub fn summary(&self) -> String {
        format!("{} format operand(s) folded to constant globals", self.folded.len())
    }
}

/// Run standalone: builds its own resolution table. The pass-manager
/// path goes through [`run_with`] with the cached table.
pub fn run(m: &mut Module) -> ConstFoldReport {
    let table = resolve_module(m);
    run_with(m, &table)
}

/// The argument position of the format string for `kind`, for the
/// format-taking host functions (`printf`/`fprintf`/`scanf`/`fscanf`).
fn fmt_index(kind: HostFnKind) -> Option<usize> {
    match kind {
        HostFnKind::Printf { has_fd } | HostFnKind::Scanf { has_fd } => Some(usize::from(has_fd)),
        _ => None,
    }
}

/// Fold format operands across the module: compute the fixed point of
/// the pass-through parameter bindings (so constants flow through
/// nested wrappers), then rewrite every resolvable format operand.
pub fn run_with(m: &mut Module, table: &ResolutionTable) -> ConstFoldReport {
    let mut report = ConstFoldReport::default();
    let bindings = param_bindings(m);
    // Rewrites only touch format operands of *external* calls, which
    // are never binding sources, so one rewrite round after the binding
    // fixed point is complete (a folded operand becomes a direct
    // `Operand::Global`, which a further round would skip anyway).
    fold_round(m, table, &bindings, &mut report);
    report
}

/// What every call site consistently binds a parameter to: a constant
/// global (format text — the fold target) or a compile-time integer
/// (feeds `select` conditions and `gep` offsets inside the callee).
#[derive(Debug, Clone, PartialEq, Eq)]
enum Binding {
    Global(String),
    Int(i64),
}

/// For every defined function, the parameters that *every* call site in
/// the module binds to the same constant (global or integer):
/// `(function, param name) -> binding`. Iterated to a fixed point so a
/// binding in a caller lets its own call sites fold (`main →
/// outer(@fmt) → inner(%g)` binds `inner`'s parameter transitively).
/// Parameters shadowed by a local definition in the callee are
/// excluded.
fn param_bindings(m: &Module) -> HashMap<(String, String), Binding> {
    let mut bindings = HashMap::new();
    // Each round propagates constants one call-graph level deeper; 16
    // levels is far beyond any real wrapper nesting, and the early
    // break fires as soon as the set is stable.
    for _ in 0..16 {
        let next = bindings_once(m, &bindings);
        if next == bindings {
            break;
        }
        bindings = next;
    }
    bindings
}

/// One binding round: judge every call site's arguments under the
/// previous round's bindings (the caller's own parameters may already
/// be bound to globals).
fn bindings_once(
    m: &Module,
    prev: &HashMap<(String, String), Binding>,
) -> HashMap<(String, String), Binding> {
    // (callee, param index) -> Some(binding) while consistent, None once
    // two sites disagree (or a site passes something unfoldable).
    let mut seen: HashMap<(String, usize), Option<Binding>> = HashMap::new();
    for (caller, f) in &m.functions {
        let defs = def_map(f);
        let caller_params: HashMap<String, Binding> = prev
            .iter()
            .filter(|((func, _), _)| func == caller)
            .map(|((_, param), binding)| (param.clone(), binding.clone()))
            .collect();
        walk(&f.body, &mut |ins| {
            if let Instr::Call { callee, args, .. } = ins {
                if !m.is_defined(callee) {
                    return;
                }
                for (i, arg) in args.iter().enumerate() {
                    let folded = fold_operand(m, &defs, &caller_params, arg, 0)
                        // Bindings carry zero-offset globals only; a
                        // suffix global may not exist yet at binding
                        // time.
                        .and_then(|(g, k)| (k == 0).then_some(Binding::Global(g)))
                        .or_else(|| {
                            fold_const_int(&defs, &caller_params, arg, 0).map(Binding::Int)
                        });
                    seen.entry((callee.clone(), i))
                        .and_modify(|entry| {
                            if entry.as_ref() != folded.as_ref() {
                                *entry = None;
                            }
                        })
                        .or_insert(folded);
                }
            }
        });
    }
    let mut out = HashMap::new();
    for ((callee, i), binding) in seen {
        let Some(binding) = binding else { continue };
        let Some(f) = m.functions.get(&callee) else { continue };
        let Some(param) = f.params.get(i) else { continue };
        // A body instruction redefining the parameter name shadows the
        // binding — skip (the def map records instruction defs only, so
        // membership is exactly "shadowed").
        if def_map(f).contains_key(&param.name) {
            continue;
        }
        out.insert((callee.clone(), param.name.clone()), binding);
    }
    out
}

/// One fold round over every function body; returns the fold count.
fn fold_round(
    m: &mut Module,
    table: &ResolutionTable,
    bindings: &HashMap<(String, String), Binding>,
    report: &mut ConstFoldReport,
) -> u64 {
    let mut folds = 0;
    let mut pending: BTreeMap<String, Global> = BTreeMap::new();
    let fnames: Vec<String> = m.functions.keys().cloned().collect();
    for fname in fnames {
        let f = m.functions.get(&fname).unwrap();
        let defs = def_map(f);
        let my_params: HashMap<String, Binding> = bindings
            .iter()
            .filter(|((func, _), _)| *func == fname)
            .map(|((_, param), binding)| (param.clone(), binding.clone()))
            .collect();
        let mut f = f.clone();
        let n = fold_body(m, &mut f.body, &defs, &my_params, table, &fname, report, &mut pending);
        if n > 0 {
            // Unchanged functions keep their original storage.
            m.functions.insert(fname, f);
        }
        folds += n;
    }
    // Install the synthesized suffix globals the rewrites refer to.
    for (name, g) in pending {
        m.globals.insert(name, g);
    }
    folds
}

/// The constant global a `fmt + K` chain lands in, synthesized on
/// demand: `@g__sfxK`, initialized with `@g`'s bytes from offset `K`
/// on. `None` (no fold) when `@g` is not a constant global, `K` is out
/// of range, or the synthesized name is already taken by a different
/// global.
fn suffix_global(
    m: &Module,
    pending: &mut BTreeMap<String, Global>,
    g: &str,
    k: u64,
) -> Option<String> {
    let orig = m.globals.get(g)?;
    if !orig.constant || k >= orig.size {
        return None;
    }
    let name = format!("{g}__sfx{k}");
    let size = orig.size - k;
    let init = orig.init.get(k as usize..).unwrap_or(&[]).to_vec();
    if let Some(existing) = m.globals.get(&name).or_else(|| pending.get(&name)) {
        // Idempotent re-runs reuse the identical synthesis; any other
        // occupant of the name blocks the fold.
        let same = existing.constant && existing.size == size && existing.init == init;
        return same.then_some(name);
    }
    pending.insert(name.clone(), Global { name: name.clone(), size, constant: true, init });
    Some(name)
}

#[allow(clippy::too_many_arguments)]
fn fold_body(
    m: &Module,
    body: &mut Vec<Instr>,
    defs: &HashMap<String, Instr>,
    params: &HashMap<String, Binding>,
    table: &ResolutionTable,
    fname: &str,
    report: &mut ConstFoldReport,
    pending: &mut BTreeMap<String, Global>,
) -> u64 {
    let mut folds = 0;
    for ins in body.iter_mut() {
        match ins {
            Instr::Call { callee, args, .. } if !m.is_defined(callee) => {
                let Some(i) = table.host_kind(callee).and_then(fmt_index) else { continue };
                let Some(op) = args.get(i) else { continue };
                if matches!(op, Operand::Global(_)) {
                    continue; // already a direct constant reference
                }
                if let Some((g, k)) = fold_operand(m, defs, params, op, 0) {
                    let target = if k == 0 {
                        g
                    } else {
                        let Some(name) = suffix_global(m, pending, &g, k) else { continue };
                        name
                    };
                    report.folded.push((
                        fname.to_string(),
                        callee.clone(),
                        render(op),
                        target.clone(),
                    ));
                    args[i] = Operand::Global(target);
                    folds += 1;
                }
            }
            Instr::If { then_body, else_body, .. } => {
                folds += fold_body(m, then_body, defs, params, table, fname, report, pending);
                folds += fold_body(m, else_body, defs, params, table, fname, report, pending);
            }
            Instr::While { cond, body, .. } => {
                folds += fold_body(m, cond, defs, params, table, fname, report, pending);
                folds += fold_body(m, body, defs, params, table, fname, report, pending);
            }
            Instr::For { body, .. } | Instr::Parallel { body, .. } => {
                folds += fold_body(m, body, defs, params, table, fname, report, pending);
            }
            _ => {}
        }
    }
    folds
}

fn render(op: &Operand) -> String {
    match op {
        Operand::Var(v) => format!("%{v}"),
        Operand::Global(g) => format!("@{g}"),
        Operand::ConstI(i) => i.to_string(),
        Operand::ConstF(f) => f.to_string(),
    }
}

/// Fold `op` down to a constant global it provably aliases at a
/// constant byte offset, returned as `(global, offset)`: follows plain
/// copies, constant-offset `gep`s (offsets accumulate along the chain),
/// constant-condition `select`s (where the condition may itself be a
/// consistently-bound integer parameter), and parameters bound by every
/// caller (`params`).
fn fold_operand(
    m: &Module,
    defs: &HashMap<String, Instr>,
    params: &HashMap<String, Binding>,
    op: &Operand,
    depth: usize,
) -> Option<(String, u64)> {
    if depth > 32 {
        return None;
    }
    match op {
        Operand::Global(g) if m.globals.get(g).is_some_and(|gl| gl.constant) => {
            Some((g.clone(), 0))
        }
        Operand::Var(v) => match defs.get(v) {
            Some(Instr::Assign { expr, .. }) => match expr {
                Expr::Op(inner) => fold_operand(m, defs, params, inner, depth + 1),
                Expr::Gep(base, off) => {
                    let k = fold_const_int(defs, params, off, 0)?;
                    if k < 0 {
                        return None;
                    }
                    let (g, k0) = fold_operand(m, defs, params, base, depth + 1)?;
                    Some((g, k0 + k as u64))
                }
                Expr::Select(c, a, b) => {
                    let cv = fold_const_int(defs, params, c, 0)?;
                    let side = if cv != 0 { a } else { b };
                    fold_operand(m, defs, params, side, depth + 1)
                }
                _ => None,
            },
            Some(_) => None,
            // No local definition: a parameter — foldable when every
            // caller binds it to the same constant global.
            None => match params.get(v) {
                Some(Binding::Global(g)) => Some((g.clone(), 0)),
                _ => None,
            },
        },
        _ => None,
    }
}

/// Fold `op` to a compile-time integer: constants, copy chains, and
/// parameters every caller binds to the same integer (the bindings that
/// let `select` conditions fold through wrapper params).
fn fold_const_int(
    defs: &HashMap<String, Instr>,
    params: &HashMap<String, Binding>,
    op: &Operand,
    depth: usize,
) -> Option<i64> {
    if depth > 32 {
        return None;
    }
    match op {
        Operand::ConstI(i) => Some(*i),
        Operand::Var(v) => match defs.get(v) {
            Some(Instr::Assign { expr: Expr::Op(inner), .. }) => {
                fold_const_int(defs, params, inner, depth + 1)
            }
            Some(_) => None,
            None => match params.get(v) {
                Some(Binding::Int(i)) => Some(*i),
                _ => None,
            },
        },
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;

    fn fold(src: &str) -> (Module, ConstFoldReport) {
        let mut m = parse_module(src).unwrap();
        m.verify().unwrap();
        let report = run(&mut m);
        m.verify().unwrap();
        (m, report)
    }

    fn fmt_arg_of_call(m: &Module, func: &str, callee: &str, i: usize) -> Operand {
        let mut found = None;
        walk(&m.functions[func].body, &mut |ins| {
            if let Instr::Call { callee: c, args, .. } = ins {
                if c == callee {
                    found = Some(args[i].clone());
                }
            }
        });
        found.expect("call site present")
    }

    #[test]
    fn const_select_between_constant_globals_folds() {
        let src = r#"
global @f1 const 3 "%s"
global @f2 const 3 "%d"
global @buf 64

func @main() -> i64 {
  %c = 1
  %f = select %c, @f1, @f2
  %p = gep @buf, 0
  call printf(%f, %p)
  return 0
}
"#;
        let (m, report) = fold(src);
        assert_eq!(report.count(), 1);
        assert_eq!(fmt_arg_of_call(&m, "main", "printf", 0), Operand::Global("f1".into()));
        // The false branch folds the other way.
        let src0 = src.replace("%c = 1", "%c = 0");
        let mut m = parse_module(&src0).unwrap();
        run(&mut m);
        assert_eq!(fmt_arg_of_call(&m, "main", "printf", 0), Operand::Global("f2".into()));
    }

    #[test]
    fn copy_and_zero_gep_chains_fold() {
        let src = r#"
global @fmt const 6 "x=%d\n"

func @main() -> i64 {
  %a = gep @fmt, 0
  %z = 0
  %b = gep %a, %z
  call printf(%b, 7)
  return 0
}
"#;
        let (m, report) = fold(src);
        assert_eq!(report.count(), 1);
        assert_eq!(fmt_arg_of_call(&m, "main", "printf", 0), Operand::Global("fmt".into()));
    }

    #[test]
    fn pass_through_parameter_folds_when_all_sites_agree() {
        let src = r#"
global @fmt const 6 "v=%d\n"

func @log(%f: ptr, %v: i64) -> void {
  call printf(%f, %v)
  return
}

func @main() -> i64 {
  call log(@fmt, 1)
  call log(@fmt, 2)
  return 0
}
"#;
        let (m, report) = fold(src);
        assert_eq!(report.count(), 1);
        assert_eq!(fmt_arg_of_call(&m, "log", "printf", 0), Operand::Global("fmt".into()));
        let (f, callee, _, g) = &report.folded[0];
        assert_eq!((f.as_str(), callee.as_str(), g.as_str()), ("log", "printf", "fmt"));
    }

    #[test]
    fn pass_through_folds_transitively_through_two_wrappers() {
        let src = r#"
global @fmt const 6 "v=%d\n"

func @inner(%f: ptr) -> void {
  call printf(%f, 1)
  return
}

func @outer(%g: ptr) -> void {
  call inner(%g)
  return
}

func @main() -> i64 {
  call outer(@fmt)
  return 0
}
"#;
        let (m, report) = fold(src);
        // Round 1 binds outer's %g; %g flows to inner's call site as a
        // param reference, which binds inner's %f, folding the printf.
        assert_eq!(report.count(), 1, "{:?}", report.folded);
        assert_eq!(fmt_arg_of_call(&m, "inner", "printf", 0), Operand::Global("fmt".into()));
    }

    #[test]
    fn disagreeing_call_sites_do_not_fold() {
        let src = r#"
global @f1 const 3 "%d"
global @f2 const 3 "%f"

func @log(%f: ptr) -> void {
  call printf(%f, 1)
  return
}

func @main() -> i64 {
  call log(@f1)
  call log(@f2)
  return 0
}
"#;
        let (m, report) = fold(src);
        assert_eq!(report.count(), 0);
        assert_eq!(fmt_arg_of_call(&m, "log", "printf", 0), Operand::var("f"));
    }

    #[test]
    fn select_condition_folds_through_param_binding() {
        let src = r#"
global @fmt const 3 "%d"
global @alt const 3 "%f"

func @log(%f: ptr, %c: i64) -> void {
  %f = select %c, @alt, @fmt
  call printf(%f, 1)
  return
}

func @main() -> i64 {
  call log(@fmt, 0)
  call log(@fmt, 0)
  return 0
}
"#;
        // %f is shadowed by the select, so its own binding is dropped —
        // but %c is bound to 0 by every site, so the select condition
        // folds through the parameter and picks the false side.
        let (m, report) = fold(src);
        assert_eq!(report.count(), 1, "{:?}", report.folded);
        assert_eq!(fmt_arg_of_call(&m, "log", "printf", 0), Operand::Global("fmt".into()));
        // The true side folds the other way.
        let src1 = src.replace("call log(@fmt, 0)", "call log(@fmt, 1)");
        let mut m = parse_module(&src1).unwrap();
        run(&mut m);
        assert_eq!(fmt_arg_of_call(&m, "log", "printf", 0), Operand::Global("alt".into()));
    }

    #[test]
    fn shadowed_parameter_and_dynamic_select_do_not_fold() {
        let src = r#"
global @fmt const 3 "%d"
global @alt const 3 "%f"

func @log(%f: ptr, %c: i64) -> void {
  %f = select %c, @alt, @fmt
  call printf(%f, 1)
  return
}

func @main() -> i64 {
  call log(@fmt, 0)
  call log(@fmt, 1)
  return 0
}
"#;
        // %f is shadowed by the select, and the sites disagree on %c:
        // the condition stays dynamic, so neither the binding nor the
        // local chain may fold.
        let (_, report) = fold(src);
        assert_eq!(report.count(), 0, "{:?}", report.folded);
    }

    #[test]
    fn constant_nonzero_gep_offset_synthesizes_a_suffix_global() {
        let src = r#"
global @fmt const 8 "##x=%d\n"

func @main() -> i64 {
  %p = gep @fmt, 2
  call printf(%p, 7)
  return 0
}
"#;
        let (m, report) = fold(src);
        assert_eq!(report.count(), 1, "{:?}", report.folded);
        assert_eq!(
            fmt_arg_of_call(&m, "main", "printf", 0),
            Operand::Global("fmt__sfx2".into())
        );
        let sfx = &m.globals["fmt__sfx2"];
        assert!(sfx.constant);
        assert_eq!(sfx.size, 6);
        assert_eq!(sfx.init, m.globals["fmt"].init[2..].to_vec(), "tail bytes from offset 2");
        // Re-running the pass is a no-op: the operand is a direct
        // global now, and the synthesized name is reused, not doubled.
        let mut m2 = m.clone();
        let report2 = run(&mut m2);
        assert_eq!(report2.count(), 0);
        assert_eq!(m2, m);
    }

    #[test]
    fn gep_offsets_accumulate_along_the_chain() {
        let src = r#"
global @fmt const 8 "##x=%d\n"

func @main() -> i64 {
  %a = gep @fmt, 1
  %b = gep %a, 1
  call printf(%b, 7)
  return 0
}
"#;
        let (m, report) = fold(src);
        assert_eq!(report.count(), 1, "{:?}", report.folded);
        assert_eq!(
            fmt_arg_of_call(&m, "main", "printf", 0),
            Operand::Global("fmt__sfx2".into())
        );
        assert_eq!(m.globals["fmt__sfx2"].size, 6);
    }

    #[test]
    fn out_of_range_or_dynamic_gep_offset_does_not_fold() {
        let src = r#"
global @fmt const 6 "x=%d\n"

func @main(%argc: i64) -> i64 {
  %p = gep @fmt, 64
  call printf(%p, 1)
  %q = gep @fmt, %argc
  call printf(%q, 2)
  return 0
}
"#;
        let (m, report) = fold(src);
        assert_eq!(report.count(), 0, "{:?}", report.folded);
        assert!(!m.globals.contains_key("fmt__sfx64"), "no out-of-range suffix synthesized");
    }

    #[test]
    fn non_constant_global_does_not_fold() {
        let src = r#"
global @mut 8

func @log(%f: ptr) -> void {
  call printf(%f, 1)
  return
}

func @main() -> i64 {
  call log(@mut)
  return 0
}
"#;
        let (_, report) = fold(src);
        assert_eq!(report.count(), 0, "writable globals are not constant format text");
    }

    #[test]
    fn direct_global_format_is_left_untouched() {
        let src = r#"
global @fmt const 3 "%d"

func @main() -> i64 {
  call printf(@fmt, 1)
  return 0
}
"#;
        let mut m = parse_module(src).unwrap();
        let before = m.clone();
        let report = run(&mut m);
        assert_eq!(report.count(), 0);
        assert_eq!(m, before, "nothing to fold: module is untouched");
    }
}
