//! The `fuse` pass: fold common adjacent lowered-instruction pairs into
//! superinstructions so the register-file executor pays one dispatch
//! for two instructions.
//!
//! Fused pairs (greedy, left-to-right, recursing into nested bodies):
//!
//! * `%t = <cmp> a, b` + `if %t {..} else {..}` → [`LowInstr::CmpIf`]
//! * `%t = gep base, off` + `%d = load.<w> %t` → [`LowInstr::GepLoad`]
//! * `%t = gep base, off` + `store.<w> v, %t` → [`LowInstr::GepStore`]
//! * `%t = <bin> a, b` + `store.<w> %t, addr` → [`LowInstr::BinStore`]
//!
//! Fusion needs no liveness analysis: every superinstruction still
//! writes its intermediate `%t` slot, and the executor charges *both*
//! component instructions to the device counters, so fused and unfused
//! execution are observationally identical (the `tests/lowering.rs`
//! equivalence corpus proves it). The pass only rewrites
//! [`Module::lowered`] — the tree IR is untouched and `changed` stays
//! false so cached analyses survive.

use crate::ir::lowered::{LowExpr, LowInstr, LowOp};
use crate::ir::{BinOp, Module};

/// What the pass did (→ `CompileReport.fuse`, `--explain`,
/// `RunMetrics.fused_instrs`).
#[derive(Debug, Default, Clone)]
pub struct FuseReport {
    /// Total pairs folded (sum of the per-kind counters).
    pub pairs: u64,
    pub cmp_br: u64,
    pub gep_load: u64,
    pub gep_store: u64,
    pub bin_store: u64,
}

impl FuseReport {
    /// One-line summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "{} pair(s) fused ({} cmp+br, {} gep+load, {} gep+store, {} bin+store)",
            self.pairs, self.cmp_br, self.gep_load, self.gep_store, self.bin_store
        )
    }
}

/// Is `op` a comparison (result used as a branch condition)?
fn is_cmp(op: BinOp) -> bool {
    matches!(
        op,
        BinOp::Eq
            | BinOp::Ne
            | BinOp::Lt
            | BinOp::Le
            | BinOp::Gt
            | BinOp::Ge
            | BinOp::FEq
            | BinOp::FLt
            | BinOp::FLe
            | BinOp::FGt
            | BinOp::FGe
    )
}

/// Fuse every lowered function of `m` in place. A no-op (all-zero
/// report) when the `lower` pass has not run.
pub fn run(m: &mut Module) -> FuseReport {
    let mut report = FuseReport::default();
    for lf in m.lowered.values_mut() {
        let mut fused = 0u32;
        fuse_body(&mut lf.body, &mut report, &mut fused);
        lf.fused = fused;
    }
    report
}

fn fuse_body(body: &mut Vec<LowInstr>, r: &mut FuseReport, fused: &mut u32) {
    enum Kind {
        CmpIf,
        GepLoad,
        GepStore,
        BinStore,
    }
    let old = std::mem::take(body);
    let mut out: Vec<LowInstr> = Vec::with_capacity(old.len());
    let mut it = old.into_iter().peekable();
    while let Some(a) = it.next() {
        let kind = match (&a, it.peek()) {
            (
                LowInstr::Assign { dst, expr: LowExpr::Bin(op, _, _) },
                Some(LowInstr::If { cond: LowOp::Slot(c), .. }),
            ) if c == dst && is_cmp(*op) => Some(Kind::CmpIf),
            (
                LowInstr::Assign { dst, expr: LowExpr::Gep(_, _) },
                Some(LowInstr::Load { addr: LowOp::Slot(c), .. }),
            ) if c == dst => Some(Kind::GepLoad),
            (
                LowInstr::Assign { dst, expr: LowExpr::Gep(_, _) },
                Some(LowInstr::Store { addr: LowOp::Slot(c), .. }),
            ) if c == dst => Some(Kind::GepStore),
            (
                LowInstr::Assign { dst, expr: LowExpr::Bin(_, _, _) },
                Some(LowInstr::Store { val: LowOp::Slot(c), .. }),
            ) if c == dst => Some(Kind::BinStore),
            _ => None,
        };
        let Some(kind) = kind else {
            out.push(a);
            continue;
        };
        let b = it.next().expect("peeked");
        *fused += 1;
        r.pairs += 1;
        out.push(match (kind, a, b) {
            (
                Kind::CmpIf,
                LowInstr::Assign { dst, expr: LowExpr::Bin(op, x, y) },
                LowInstr::If { then_body, else_body, .. },
            ) => {
                r.cmp_br += 1;
                LowInstr::CmpIf { tmp: dst, op, a: x, b: y, then_body, else_body }
            }
            (
                Kind::GepLoad,
                LowInstr::Assign { dst: t, expr: LowExpr::Gep(base, off) },
                LowInstr::Load { dst, width, ty, .. },
            ) => {
                r.gep_load += 1;
                LowInstr::GepLoad { tmp: t, base, off, dst, width, ty }
            }
            (
                Kind::GepStore,
                LowInstr::Assign { dst: t, expr: LowExpr::Gep(base, off) },
                LowInstr::Store { val, width, .. },
            ) => {
                r.gep_store += 1;
                LowInstr::GepStore { tmp: t, base, off, val, width }
            }
            (
                Kind::BinStore,
                LowInstr::Assign { dst: t, expr: LowExpr::Bin(op, x, y) },
                LowInstr::Store { addr, width, .. },
            ) => {
                r.bin_store += 1;
                LowInstr::BinStore { tmp: t, op, a: x, b: y, addr, width }
            }
            _ => unreachable!("kind decided by the same patterns"),
        });
    }
    for ins in &mut out {
        match ins {
            LowInstr::If { then_body, else_body, .. }
            | LowInstr::CmpIf { then_body, else_body, .. } => {
                fuse_body(then_body, r, fused);
                fuse_body(else_body, r, fused);
            }
            LowInstr::While { cond, body, .. } => {
                fuse_body(cond, r, fused);
                fuse_body(body, r, fused);
            }
            LowInstr::For { body, .. } | LowInstr::Parallel { body, .. } => {
                fuse_body(body, r, fused);
            }
            _ => {}
        }
    }
    *body = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::lowered::walk_low;
    use crate::ir::parser::parse_module;

    const SRC: &str = r#"
global @arr 64

func @main() -> i64 {
  %sum = alloca 8
  store.8 0, %sum
  for %i = 0 to 8 step 1 {
    %off = mul %i, 8
    %p = gep @arr, %off
    %v = load.8 %p
    %q = gep @arr, %off
    store.8 %v, %q
    %acc = load.8 %sum
    %acc2 = add %acc, %v
    store.8 %acc2, %sum
    %big = gt %v, 100
    if %big {
      %t = tid
    }
  }
  return 0
}
"#;

    #[test]
    fn all_four_pair_kinds_fuse() {
        let mut m = parse_module(SRC).unwrap();
        super::super::lower::run(&mut m);
        let report = run(&mut m);
        assert_eq!(report.gep_load, 1, "{report:?}");
        assert_eq!(report.gep_store, 1, "{report:?}");
        assert_eq!(report.bin_store, 1, "{report:?}");
        assert_eq!(report.cmp_br, 1, "{report:?}");
        assert_eq!(report.pairs, 4);
        assert_eq!(m.lowered["main"].fused, 4);

        // The fused body carries the superinstructions and no longer the
        // plain pairs they replaced.
        let mut supers = 0;
        walk_low(&m.lowered["main"].body, &mut |i| {
            if matches!(
                i,
                LowInstr::CmpIf { .. }
                    | LowInstr::GepLoad { .. }
                    | LowInstr::GepStore { .. }
                    | LowInstr::BinStore { .. }
            ) {
                supers += 1;
            }
        });
        assert_eq!(supers, 4);
        let body = &m.lowered["main"].body;
        assert!(
            matches!(body[2], LowInstr::For { .. }),
            "shape preserved around the loop: {body:?}"
        );
    }

    #[test]
    fn no_lowered_form_is_a_noop() {
        let mut m = parse_module("func @main() -> i64 {\n  return 0\n}\n").unwrap();
        let report = run(&mut m);
        assert_eq!(report.pairs, 0);
    }

    #[test]
    fn non_cmp_bin_does_not_fuse_with_if() {
        // `%t = add ...; if %t` must stay unfused: CmpIf re-evaluates the
        // comparison, so only comparison ops are eligible.
        let src = "func @main() -> i64 {\n  %t = add 1, 0\n  if %t {\n    barrier\n  }\n  return 0\n}\n";
        let mut m = parse_module(src).unwrap();
        super::super::lower::run(&mut m);
        let report = run(&mut m);
        assert_eq!(report.cmp_br, 0);
        assert!(matches!(m.lowered["main"].body[0], LowInstr::Assign { .. }));
    }
}
