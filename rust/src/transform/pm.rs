//! The pass manager — the middle-end that replaces the hardcoded
//! rpcgen→multiteam sequence of the early reproduction.
//!
//! * [`Pass`] — one compile-time transformation or analysis
//!   materialization with a stable name, run over the module with access
//!   to a [`PassCx`] (the landing-pad registry, the shared
//!   [`AnalysisCache`], and the [`CompileReport`](super::CompileReport)
//!   under construction).
//! * [`AnalysisCache`] — lazily computed module analyses
//!   ([`CallGraph`], per-function def maps, the `libcres`
//!   [`ResolutionTable`]) shared across passes and invalidated when a
//!   pass reports it changed the module; build/hit/invalidation counters
//!   make the caching observable to tests and `--explain`.
//! * [`PipelineSpec`] — an ordered pass list parsed from the `--passes`
//!   CLI override or the `GPU_FIRST_PASSES` environment variable (the CI
//!   pass-shape matrix), or derived from
//!   [`CompileOptions`](super::CompileOptions).
//! * [`PassManager`] — verifies the module, runs the pipeline in order
//!   recording per-pass wall time and summaries, and verifies again.
//!
//! The default pipeline is `libcres → rpcgen → multiteam`; it is
//! behaviorally identical to the historical fixed sequence (proved by
//! the `pass_manager` equivalence suite).

use super::libcres::ResolutionTable;
use super::pipeline::{CompileOptions, CompileReport};
use super::{libcres, multiteam, rpcgen};
use crate::analysis::callgraph::CallGraph;
use crate::analysis::objects::def_map;
use crate::ir::{Instr, Module};
use crate::rpc::WrapperRegistry;
use std::collections::HashMap;

/// The pass names the manager knows, in default pipeline order.
pub const KNOWN_PASSES: &[&str] = &["libcres", "rpcgen", "multiteam"];

/// What one pass invocation reports back to the manager.
#[derive(Debug, Clone)]
pub struct PassOutcome {
    /// One-line, human-readable result ("3 call sites rewritten").
    pub summary: String,
    /// Did the pass mutate the module? Cached analyses are invalidated
    /// only when true.
    pub changed: bool,
}

/// Wall time + outcome of one executed pass (surfaced through
/// [`CompileReport::timings`], `--explain` and `RunMetrics`).
#[derive(Debug, Clone)]
pub struct PassTiming {
    pub pass: String,
    pub wall_ns: f64,
    pub summary: String,
    pub changed: bool,
}

/// One middle-end pass: a named unit of work over the module.
pub trait Pass {
    /// Stable name (what `--passes` and reports refer to).
    fn name(&self) -> &'static str;
    /// Run over `m`. Errors are verification-style human-readable lines.
    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>>;
}

/// Build/hit/invalidation counters of the [`AnalysisCache`] — the
/// observable half of the caching contract.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub callgraph_builds: u64,
    pub resolution_builds: u64,
    pub def_map_builds: u64,
    /// Requests answered from cache without recomputation.
    pub hits: u64,
    /// Whole-cache invalidations (one per module-mutating pass).
    pub invalidations: u64,
}

/// Lazily computed, invalidation-tracked module analyses. `CallGraph`
/// and `objects::def_map` used to be recomputed by every pass that
/// wanted them; here they are computed once and dropped only when a
/// pass actually mutates the module.
#[derive(Default)]
pub struct AnalysisCache {
    callgraph: Option<CallGraph>,
    resolution: Option<ResolutionTable>,
    def_maps: HashMap<String, HashMap<String, Instr>>,
    pub stats: CacheStats,
}

impl AnalysisCache {
    /// The module call graph, computed on first use.
    pub fn callgraph(&mut self, m: &Module) -> &CallGraph {
        if self.callgraph.is_none() {
            self.callgraph = Some(CallGraph::build(m));
            self.stats.callgraph_builds += 1;
        } else {
            self.stats.hits += 1;
        }
        self.callgraph.as_ref().unwrap()
    }

    /// The `libcres` symbol-resolution table, computed on first use.
    pub fn resolution(&mut self, m: &Module) -> &ResolutionTable {
        if self.resolution.is_none() {
            self.resolution = Some(libcres::resolve_module(m));
            self.stats.resolution_builds += 1;
        } else {
            self.stats.hits += 1;
        }
        self.resolution.as_ref().unwrap()
    }

    /// The def map of function `fname`, computed on first use. Returns
    /// `None` for functions the module does not define.
    pub fn def_map(&mut self, m: &Module, fname: &str) -> Option<&HashMap<String, Instr>> {
        if !self.def_maps.contains_key(fname) {
            let f = m.functions.get(fname)?;
            self.def_maps.insert(fname.to_string(), def_map(f));
            self.stats.def_map_builds += 1;
        } else {
            self.stats.hits += 1;
        }
        self.def_maps.get(fname)
    }

    /// Drop every cached analysis (a pass mutated the module).
    pub fn invalidate(&mut self) {
        self.callgraph = None;
        self.resolution = None;
        self.def_maps.clear();
        self.stats.invalidations += 1;
    }
}

/// What a running pass sees besides the module.
pub struct PassCx<'a> {
    /// Landing-pad registry (rpcgen registers synthesized pads here).
    pub registry: &'a WrapperRegistry,
    pub cache: AnalysisCache,
    /// The report under construction; each pass fills its section.
    pub report: CompileReport,
}

/// An ordered, validated pass list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PipelineSpec {
    names: Vec<&'static str>,
}

impl Default for PipelineSpec {
    /// The full default pipeline: `libcres → rpcgen → multiteam`.
    fn default() -> Self {
        Self { names: KNOWN_PASSES.to_vec() }
    }
}

impl PipelineSpec {
    /// Environment override consumed by the CI pass-shape matrix (and
    /// honoured by the `gpu-first` CLI below `--passes`).
    pub const ENV: &'static str = "GPU_FIRST_PASSES";

    /// Parse a comma-separated pass list (`"libcres,rpcgen"`). The
    /// keyword `default` selects the full pipeline; an empty string is
    /// the empty pipeline (verify only). Unknown and duplicate names are
    /// errors listing the known passes.
    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        if s == "default" {
            return Ok(Self::default());
        }
        let mut names: Vec<&'static str> = Vec::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some(known) = KNOWN_PASSES.iter().find(|k| **k == part) else {
                return Err(format!(
                    "unknown pass {part:?} (known passes: {})",
                    KNOWN_PASSES.join(", ")
                ));
            };
            if names.contains(known) {
                return Err(format!("pass {part:?} listed twice"));
            }
            names.push(*known);
        }
        Ok(Self { names })
    }

    /// The pipeline [`CompileOptions`] selects: the default order with
    /// disabled passes dropped.
    pub fn from_options(opts: CompileOptions) -> Self {
        let mut names = Vec::new();
        if opts.libcres {
            names.push("libcres");
        }
        if opts.rpcgen {
            names.push("rpcgen");
        }
        if opts.multiteam {
            names.push("multiteam");
        }
        Self { names }
    }

    /// The spec `GPU_FIRST_PASSES` selects, or `None` when unset. A
    /// malformed value panics — a CI matrix leg silently falling back to
    /// the default pipeline would defeat the matrix (mirrors
    /// [`crate::util::cli::EngineShape::from_env`]).
    pub fn from_env() -> Option<Self> {
        let v = std::env::var(Self::ENV).ok()?;
        Some(Self::parse(&v).unwrap_or_else(|e| panic!("{}: {e}", Self::ENV)))
    }

    /// `from_env`, defaulting to the full pipeline.
    pub fn from_env_or_default() -> Self {
        Self::from_env().unwrap_or_default()
    }

    /// Pass names in execution order.
    pub fn names(&self) -> &[&'static str] {
        &self.names
    }

    pub fn contains(&self, pass: &str) -> bool {
        self.names.iter().any(|n| *n == pass)
    }
}

/// Instantiate the pass `name` refers to. `None` for unknown names
/// (already rejected by [`PipelineSpec::parse`]).
fn make_pass(name: &str) -> Option<Box<dyn Pass>> {
    match name {
        "libcres" => Some(Box::new(LibcResPass)),
        "rpcgen" => Some(Box::new(RpcGenPass)),
        "multiteam" => Some(Box::new(MultiTeamPass)),
        _ => None,
    }
}

/// The ordered pipeline runner.
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
}

impl PassManager {
    pub fn from_spec(spec: &PipelineSpec) -> Self {
        Self {
            passes: spec
                .names()
                .iter()
                .map(|n| make_pass(n).expect("spec names are validated"))
                .collect(),
        }
    }

    pub fn from_options(opts: CompileOptions) -> Self {
        Self::from_spec(&PipelineSpec::from_options(opts))
    }

    /// Verify → run each pass in order (timing it, invalidating cached
    /// analyses after module-mutating passes) → verify. Returns the
    /// assembled report.
    pub fn run(
        &self,
        m: &mut Module,
        registry: &WrapperRegistry,
    ) -> Result<CompileReport, Vec<String>> {
        m.verify()?;
        let mut cx =
            PassCx { registry, cache: AnalysisCache::default(), report: CompileReport::default() };
        for pass in &self.passes {
            let t0 = std::time::Instant::now();
            let outcome = pass.run(m, &mut cx)?;
            if outcome.changed {
                cx.cache.invalidate();
            }
            cx.report.pipeline.push(pass.name().to_string());
            cx.report.timings.push(PassTiming {
                pass: pass.name().to_string(),
                wall_ns: t0.elapsed().as_nanos() as f64,
                summary: outcome.summary,
                changed: outcome.changed,
            });
        }
        m.verify()?;
        cx.report.cache = cx.cache.stats;
        Ok(cx.report)
    }
}

// ---- the three ported passes ----

/// Materializes the module-wide symbol-resolution table into the report
/// (pure analysis; see [`libcres`]).
struct LibcResPass;

impl Pass for LibcResPass {
    fn name(&self) -> &'static str {
        "libcres"
    }

    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>> {
        let table = cx.cache.resolution(m).clone();
        let summary = table.summary();
        cx.report.resolution = table;
        Ok(PassOutcome { summary, changed: false })
    }
}

/// Automatic RPC generation (paper §3.2) on the manager: consumes the
/// cached resolution table so only host-RPC callees get landing pads.
struct RpcGenPass;

impl Pass for RpcGenPass {
    fn name(&self) -> &'static str {
        "rpcgen"
    }

    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>> {
        let table = cx.cache.resolution(m).clone();
        let report = rpcgen::run_with(m, cx.registry, &table, &mut cx.cache);
        let changed = !report.rewritten.is_empty();
        let summary = format!(
            "{} call sites rewritten, {} unsupported",
            report.rewritten.len(),
            report.unsupported.len()
        );
        cx.report.rpc = report;
        Ok(PassOutcome { summary, changed })
    }
}

/// Multi-team expansion / kernel split (paper §3.3) on the manager:
/// judges eligibility against the cached call graph.
struct MultiTeamPass;

impl Pass for MultiTeamPass {
    fn name(&self) -> &'static str {
        "multiteam"
    }

    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>> {
        let report = multiteam::run_with(m, &mut cx.cache);
        let changed = !report.regions.is_empty();
        let summary = format!(
            "{} regions expanded, {} skipped",
            report.regions.len(),
            report.skipped.len()
        );
        cx.report.multiteam = report;
        Ok(PassOutcome { summary, changed })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;

    const SRC: &str = r#"
global @fmt const 14 "result: %d%c"

func @main() -> i64 {
  %sum = alloca 8
  store.8 0, %sum
  parallel num_threads(64) {
    for.team %i = 0 to 4096 step 1 {
      %v = load.8 %sum
    }
  }
  %r = load.8 %sum
  call printf(@fmt, %r, 10)
  return %r
}
"#;

    #[test]
    fn spec_parses_orders_and_rejects_unknown() {
        assert_eq!(PipelineSpec::default().names(), KNOWN_PASSES);
        assert_eq!(PipelineSpec::parse("default").unwrap(), PipelineSpec::default());
        let spec = PipelineSpec::parse("rpcgen, multiteam").unwrap();
        assert_eq!(spec.names(), &["rpcgen", "multiteam"]);
        // Order is preserved verbatim, not canonicalized.
        let spec = PipelineSpec::parse("multiteam,rpcgen").unwrap();
        assert_eq!(spec.names(), &["multiteam", "rpcgen"]);
        // Empty spec = verify-only pipeline.
        assert!(PipelineSpec::parse("").unwrap().names().is_empty());
        let err = PipelineSpec::parse("rpcgen,frobnicate").unwrap_err();
        assert!(err.contains("frobnicate") && err.contains("libcres"), "{err}");
        let err = PipelineSpec::parse("rpcgen,rpcgen").unwrap_err();
        assert!(err.contains("twice"), "{err}");
    }

    #[test]
    fn spec_from_options_drops_disabled_passes() {
        let opts =
            CompileOptions { libcres: true, rpcgen: true, multiteam: false };
        assert_eq!(PipelineSpec::from_options(opts).names(), &["libcres", "rpcgen"]);
        let none = CompileOptions { libcres: false, rpcgen: false, multiteam: false };
        assert!(PipelineSpec::from_options(none).names().is_empty());
        assert_eq!(PipelineSpec::from_options(CompileOptions::default()), PipelineSpec::default());
    }

    #[test]
    fn manager_times_every_pass_in_order() {
        let mut m = parse_module(SRC).unwrap();
        let reg = WrapperRegistry::new();
        let report = PassManager::from_spec(&PipelineSpec::default()).run(&mut m, &reg).unwrap();
        assert_eq!(report.pipeline, KNOWN_PASSES.to_vec());
        assert_eq!(report.timings.len(), 3);
        for t in &report.timings {
            assert!(t.wall_ns >= 0.0);
            assert!(!t.summary.is_empty());
        }
        assert!(!report.timings[0].changed, "libcres is pure analysis");
        assert!(report.timings[1].changed, "rpcgen rewrote the printf site");
        assert!(report.timings[2].changed, "multiteam expanded the region");
    }

    #[test]
    fn cache_is_reused_until_a_pass_mutates_the_module() {
        let mut m = parse_module(SRC).unwrap();
        let reg = WrapperRegistry::new();
        let report = PassManager::from_spec(&PipelineSpec::default()).run(&mut m, &reg).unwrap();
        // libcres builds the resolution table; rpcgen re-reads it from
        // cache (libcres did not mutate) — exactly one build, >= 1 hit.
        assert_eq!(report.cache.resolution_builds, 1);
        assert!(report.cache.hits >= 1, "rpcgen must hit the cached table: {:?}", report.cache);
        // rpcgen and multiteam both mutated -> two invalidations.
        assert_eq!(report.cache.invalidations, 2);
        // multiteam's call graph was built after rpcgen's invalidation.
        assert_eq!(report.cache.callgraph_builds, 1);
    }

    #[test]
    fn analysis_cache_invalidation_contract() {
        let m = parse_module(SRC).unwrap();
        let mut cache = AnalysisCache::default();
        cache.callgraph(&m);
        cache.callgraph(&m);
        assert_eq!(cache.stats.callgraph_builds, 1);
        assert_eq!(cache.stats.hits, 1);
        cache.def_map(&m, "main").unwrap();
        cache.def_map(&m, "main").unwrap();
        assert_eq!(cache.stats.def_map_builds, 1);
        assert!(cache.def_map(&m, "nope").is_none());
        cache.invalidate();
        assert_eq!(cache.stats.invalidations, 1);
        cache.callgraph(&m);
        assert_eq!(cache.stats.callgraph_builds, 2, "invalidate drops the graph");
    }

    #[test]
    fn empty_pipeline_only_verifies() {
        let mut m = parse_module(SRC).unwrap();
        let before = m.clone();
        let reg = WrapperRegistry::new();
        let report =
            PassManager::from_spec(&PipelineSpec::parse("").unwrap()).run(&mut m, &reg).unwrap();
        assert_eq!(m, before, "no pass ran, no mutation");
        assert!(report.timings.is_empty());
        let mut bad = parse_module("func @main() -> i64 {\n  return %undef\n}\n").unwrap();
        assert!(PassManager::from_spec(&PipelineSpec::parse("").unwrap())
            .run(&mut bad, &reg)
            .is_err());
    }

    #[test]
    fn reordered_pipeline_still_verifies() {
        // multiteam before rpcgen: the region's printf call makes it
        // ineligible (RPC-ish), so it stays single-team — a valid, if
        // baseline, compilation.
        let src = r#"
global @fmt const 4 "%d\n"

func @main() -> i64 {
  parallel {
    call printf(@fmt, 1)
  }
  return 0
}
"#;
        let mut m = parse_module(src).unwrap();
        let reg = WrapperRegistry::new();
        let spec = PipelineSpec::parse("multiteam,rpcgen").unwrap();
        let report = PassManager::from_spec(&spec).run(&mut m, &reg).unwrap();
        assert_eq!(report.pipeline, vec!["multiteam".to_string(), "rpcgen".into()]);
        assert!(report.multiteam.regions.is_empty());
        assert_eq!(report.rpc.rewritten.len(), 1, "rpcgen still rewrites afterwards");
    }
}
