//! The pass manager — the middle-end that replaces the hardcoded
//! rpcgen→multiteam sequence of the early reproduction.
//!
//! * [`Pass`] — one compile-time transformation or analysis
//!   materialization with a stable name, run over the module with access
//!   to a [`PassCx`] (the landing-pad registry, the shared
//!   [`AnalysisCache`], and the [`CompileReport`](super::CompileReport)
//!   under construction).
//! * [`AnalysisCache`] — lazily computed module analyses
//!   ([`CallGraph`], per-function def maps, the `libcres`
//!   [`ResolutionTable`]) shared across passes and invalidated when a
//!   pass reports it changed the module; build/hit/invalidation counters
//!   make the caching observable to tests and `--explain`.
//! * [`PipelineSpec`] — an ordered pass list parsed from the `--passes`
//!   CLI override or the `GPU_FIRST_PASSES` environment variable (the CI
//!   pass-shape matrix), or derived from
//!   [`CompileOptions`](super::CompileOptions).
//! * [`PassManager`] — verifies the module, runs the pipeline in order
//!   recording per-pass wall time and summaries, and verifies again.
//!
//! The default pipeline is `constfold → dce → libcres → rpcgen →
//! multiteam → lower → fuse → bytecode`; its tree-transforming prefix
//! is behaviorally identical to the historical fixed sequence (proved
//! by the `pass_manager` equivalence suite), and the
//! `lower`/`fuse`/`bytecode` tail only produces the sidecar execution
//! forms (register file, then linear bytecode) the interpreter prefers
//! (proved equivalent by `tests/lowering.rs`).

use super::libcres::ResolutionTable;
use super::pipeline::{CompileOptions, CompileReport};
use super::{bytecode, constfold, dce, fuse, libcres, lower, multiteam, rpcgen};
use crate::analysis::callgraph::{walk, CallGraph};
use crate::analysis::objects::def_map;
use crate::analysis::{advise, lint};
use crate::ir::{Instr, Module};
use crate::rpc::wrappers::{self, HostFnKind};
use crate::rpc::WrapperRegistry;
use std::collections::HashMap;

/// The pass names the manager knows, in default pipeline order.
pub const KNOWN_PASSES: &[&str] =
    &["constfold", "dce", "libcres", "rpcgen", "multiteam", "lower", "fuse", "bytecode"];

/// Opt-in analysis passes `--passes` (and `--advise`) may add but the
/// default pipeline never runs: the IR lints and the offload advisor.
/// Kept out of [`KNOWN_PASSES`] so the default pipeline — and every
/// invariant pinned to its 8-pass shape — is unchanged.
pub const OPTIONAL_PASSES: &[&str] = &["lint", "advise"];

/// What one pass invocation reports back to the manager.
#[derive(Debug, Clone)]
pub struct PassOutcome {
    /// One-line, human-readable result ("3 call sites rewritten").
    pub summary: String,
    /// Did the pass mutate the module? Cached analyses are invalidated
    /// only when true.
    pub changed: bool,
}

/// Wall time + outcome of one executed pass (surfaced through
/// [`CompileReport::timings`], `--explain` and `RunMetrics`).
#[derive(Debug, Clone)]
pub struct PassTiming {
    pub pass: String,
    pub wall_ns: f64,
    pub summary: String,
    pub changed: bool,
}

/// One middle-end pass: a named unit of work over the module.
pub trait Pass {
    /// Stable name (what `--passes` and reports refer to).
    fn name(&self) -> &'static str;
    /// Run over `m`. Errors are verification-style human-readable lines.
    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>>;
}

/// Build/hit/invalidation counters of the [`AnalysisCache`] — the
/// observable half of the caching contract.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub callgraph_builds: u64,
    pub resolution_builds: u64,
    pub def_map_builds: u64,
    /// Requests answered from cache without recomputation.
    pub hits: u64,
    /// Whole-cache invalidations (one per module-mutating pass).
    pub invalidations: u64,
}

/// Lazily computed, invalidation-tracked module analyses. `CallGraph`
/// and `objects::def_map` used to be recomputed by every pass that
/// wanted them; here they are computed once and dropped only when a
/// pass actually mutates the module.
#[derive(Default)]
pub struct AnalysisCache {
    callgraph: Option<CallGraph>,
    resolution: Option<ResolutionTable>,
    def_maps: HashMap<String, HashMap<String, Instr>>,
    pub stats: CacheStats,
}

impl AnalysisCache {
    /// The module call graph, computed on first use.
    pub fn callgraph(&mut self, m: &Module) -> &CallGraph {
        if self.callgraph.is_none() {
            self.callgraph = Some(CallGraph::build(m));
            self.stats.callgraph_builds += 1;
        } else {
            self.stats.hits += 1;
        }
        self.callgraph.as_ref().unwrap()
    }

    /// The `libcres` symbol-resolution table, computed on first use.
    pub fn resolution(&mut self, m: &Module) -> &ResolutionTable {
        if self.resolution.is_none() {
            self.resolution = Some(libcres::resolve_module(m));
            self.stats.resolution_builds += 1;
        } else {
            self.stats.hits += 1;
        }
        self.resolution.as_ref().unwrap()
    }

    /// The def map of function `fname`, computed on first use. Returns
    /// `None` for functions the module does not define.
    pub fn def_map(&mut self, m: &Module, fname: &str) -> Option<&HashMap<String, Instr>> {
        if !self.def_maps.contains_key(fname) {
            let f = m.functions.get(fname)?;
            self.def_maps.insert(fname.to_string(), def_map(f));
            self.stats.def_map_builds += 1;
        } else {
            self.stats.hits += 1;
        }
        self.def_maps.get(fname)
    }

    /// Drop every cached analysis (a pass mutated the module).
    pub fn invalidate(&mut self) {
        self.callgraph = None;
        self.resolution = None;
        self.def_maps.clear();
        self.stats.invalidations += 1;
    }
}

/// What a running pass sees besides the module.
pub struct PassCx<'a> {
    /// Landing-pad registry (rpcgen registers synthesized pads here).
    pub registry: &'a WrapperRegistry,
    pub cache: AnalysisCache,
    /// The report under construction; each pass fills its section.
    pub report: CompileReport,
}

/// An ordered, validated pass list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PipelineSpec {
    names: Vec<&'static str>,
}

impl Default for PipelineSpec {
    /// The full default pipeline: `constfold → dce → libcres → rpcgen →
    /// multiteam → lower → fuse → bytecode`.
    fn default() -> Self {
        Self { names: KNOWN_PASSES.to_vec() }
    }
}

impl PipelineSpec {
    /// Environment override consumed by the CI pass-shape matrix (and
    /// honoured by the `gpu-first` CLI below `--passes`).
    pub const ENV: &'static str = "GPU_FIRST_PASSES";

    /// Parse a comma-separated pass list (`"libcres,rpcgen"`). The
    /// keyword `default` selects the full pipeline; an empty string is
    /// the empty pipeline (verify only). [`OPTIONAL_PASSES`] are
    /// accepted by name. Unknown and duplicate names are errors listing
    /// the known passes.
    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        if s == "default" {
            return Ok(Self::default());
        }
        let mut names: Vec<&'static str> = Vec::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some(known) = KNOWN_PASSES.iter().chain(OPTIONAL_PASSES).find(|k| **k == part)
            else {
                return Err(format!(
                    "unknown pass {part:?} (known passes: {}; optional: {})",
                    KNOWN_PASSES.join(", "),
                    OPTIONAL_PASSES.join(", ")
                ));
            };
            if names.contains(known) {
                return Err(format!("pass {part:?} listed twice"));
            }
            names.push(*known);
        }
        Ok(Self { names })
    }

    /// This spec with the advisory tail appended: every
    /// [`OPTIONAL_PASSES`] entry not already present is pushed to the
    /// end (lints before the advisor). What `--advise` and the `advise`
    /// subcommand run; idempotent.
    pub fn with_advice(&self) -> Self {
        let mut names = self.names.clone();
        for extra in OPTIONAL_PASSES {
            if !names.contains(extra) {
                names.push(extra);
            }
        }
        Self { names }
    }

    /// The pipeline [`CompileOptions`] selects: the default order with
    /// disabled passes dropped.
    pub fn from_options(opts: CompileOptions) -> Self {
        let mut names = Vec::new();
        if opts.constfold {
            names.push("constfold");
        }
        if opts.dce {
            names.push("dce");
        }
        if opts.libcres {
            names.push("libcres");
        }
        if opts.rpcgen {
            names.push("rpcgen");
        }
        if opts.multiteam {
            names.push("multiteam");
        }
        if opts.lower {
            names.push("lower");
        }
        if opts.fuse {
            names.push("fuse");
        }
        if opts.bytecode {
            names.push("bytecode");
        }
        Self { names }
    }

    /// The spec `GPU_FIRST_PASSES` selects, or `None` when unset. A
    /// malformed value panics — a CI matrix leg silently falling back to
    /// the default pipeline would defeat the matrix (mirrors
    /// [`crate::util::cli::EngineShape::from_env`]).
    pub fn from_env() -> Option<Self> {
        let v = std::env::var(Self::ENV).ok()?;
        Some(Self::parse(&v).unwrap_or_else(|e| panic!("{}: {e}", Self::ENV)))
    }

    /// `from_env`, defaulting to the full pipeline.
    pub fn from_env_or_default() -> Self {
        Self::from_env().unwrap_or_default()
    }

    /// Pass names in execution order.
    pub fn names(&self) -> &[&'static str] {
        &self.names
    }

    pub fn contains(&self, pass: &str) -> bool {
        self.names.iter().any(|n| *n == pass)
    }
}

/// Instantiate the pass `name` refers to. `None` for unknown names
/// (already rejected by [`PipelineSpec::parse`]).
fn make_pass(name: &str) -> Option<Box<dyn Pass>> {
    match name {
        "constfold" => Some(Box::new(ConstFoldPass)),
        "dce" => Some(Box::new(DcePass)),
        "libcres" => Some(Box::new(LibcResPass)),
        "rpcgen" => Some(Box::new(RpcGenPass)),
        "multiteam" => Some(Box::new(MultiTeamPass)),
        "lower" => Some(Box::new(LowerPass)),
        "fuse" => Some(Box::new(FusePass)),
        "bytecode" => Some(Box::new(BytecodePass)),
        "lint" => Some(Box::new(LintPass)),
        "advise" => Some(Box::new(AdvisePass)),
        _ => None,
    }
}

/// The ordered pipeline runner.
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
}

impl PassManager {
    pub fn from_spec(spec: &PipelineSpec) -> Self {
        Self {
            passes: spec
                .names()
                .iter()
                .map(|n| make_pass(n).expect("spec names are validated"))
                .collect(),
        }
    }

    pub fn from_options(opts: CompileOptions) -> Self {
        Self::from_spec(&PipelineSpec::from_options(opts))
    }

    /// Verify → run each pass in order (timing it, invalidating cached
    /// analyses after module-mutating passes) → verify → AOT
    /// pad-coverage check. Returns the assembled report; a generated RPC
    /// call site whose landing pads are not registered is a compile-time
    /// error here, never a runtime failure.
    pub fn run(
        &self,
        m: &mut Module,
        registry: &WrapperRegistry,
    ) -> Result<CompileReport, Vec<String>> {
        m.verify()?;
        let mut cx =
            PassCx { registry, cache: AnalysisCache::default(), report: CompileReport::default() };
        // Snapshot the pre-pipeline resolution table: it names every
        // host-RPC callee whose call sites the pipeline may lower, which
        // is exactly what the AOT pad-coverage check below verifies
        // against (post-pipeline tables no longer list fully-rewritten
        // callees — RpcCall sites carry mangled names, not symbols).
        let aot_table = cx.cache.resolution(m).clone();
        for pass in &self.passes {
            let t0 = std::time::Instant::now();
            let outcome = pass.run(m, &mut cx)?;
            if outcome.changed {
                cx.cache.invalidate();
                // A tree-mutating pass makes any existing lowering (and
                // its bytecode flattening) stale; drop both so the
                // interpreter can never execute a sidecar form that
                // disagrees with the tree (matters only for explicit
                // specs that order `lower`/`bytecode` early).
                if !matches!(pass.name(), "lower" | "fuse" | "bytecode") {
                    m.lowered.clear();
                    m.bytecode.clear();
                }
            }
            cx.report.pipeline.push(pass.name().to_string());
            cx.report.timings.push(PassTiming {
                pass: pass.name().to_string(),
                wall_ns: t0.elapsed().as_nanos() as f64,
                summary: outcome.summary,
                changed: outcome.changed,
            });
        }
        m.verify()?;
        let coverage = check_pad_coverage(m, registry, &aot_table);
        if !coverage.missing.is_empty() {
            return Err(coverage.missing);
        }
        cx.report.pad_coverage = coverage;
        cx.report.cache = cx.cache.stats;
        Ok(cx.report)
    }
}

// ---- AOT pad-coverage verification ----

/// What the ahead-of-time pad-coverage check established about the
/// compiled module (surfaced through [`CompileReport::pad_coverage`],
/// `--explain` and the compile output).
#[derive(Debug, Default, Clone)]
pub struct PadCoverage {
    /// `RpcCall` sites checked across the module.
    pub sites: u64,
    /// Distinct landing-pad names verified to have a scalar pad.
    pub scalar_pads: u64,
    /// Distinct landing-pad names additionally verified to have the
    /// batched variant their [`HostFnKind`] model calls for.
    pub batch_pads: u64,
    /// Human-readable diagnostics; non-empty fails the compile.
    pub missing: Vec<String>,
}

impl PadCoverage {
    /// One-line summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "{} RPC site(s): {} scalar pad(s) verified, {} batched",
            self.sites, self.scalar_pads, self.batch_pads
        )
    }
}

/// The host-function model a mangled landing-pad name resolves to:
/// `__{callee}` or `__{callee}_{tags}` matched against the host-RPC
/// names `table` classified (longest name wins), falling back to the
/// [`wrappers::HOST_FUNCTIONS`] model table for pads whose call sites
/// were already lowered before this compile.
fn kind_of_mangled(mangled: &str, table: &ResolutionTable) -> Option<HostFnKind> {
    let body = mangled.strip_prefix("__")?;
    let mut best: Option<(usize, HostFnKind)> = None;
    for (name, kind) in wrappers::HOST_FUNCTIONS {
        let matches = body == *name || body.starts_with(&format!("{name}_"));
        if matches && best.is_none_or(|(len, _)| name.len() > len) {
            best = Some((name.len(), *kind));
        }
    }
    // Prefer the table's classification when it names the symbol (the
    // check is driven off the resolution table); the model table is the
    // shared source both derive from, so they can never disagree.
    if let Some((len, _)) = best {
        if let Some(kind) = table.host_kind(&body[..len]) {
            return Some(kind);
        }
    }
    best.map(|(_, kind)| kind)
}

/// Verify every generated RPC call site against the wrapper registry:
/// the mangled landing pad must be registered under the callee id the
/// instruction carries, and — when the callee's [`HostFnKind`] has a
/// batched model ([`wrappers::synthesize_batch`]) — the batched variant
/// must be registered too, so the engine's per-sweep grouping never
/// silently degrades. Previously an unregistered pad surfaced as a
/// runtime `-1`/panic inside a kernel; now it is a compile diagnostic.
pub fn check_pad_coverage(
    m: &Module,
    registry: &WrapperRegistry,
    table: &ResolutionTable,
) -> PadCoverage {
    let mut cov = PadCoverage::default();
    let mut seen: Vec<String> = Vec::new();
    for (fname, f) in &m.functions {
        walk(&f.body, &mut |ins| {
            let Instr::RpcCall { mangled, callee_id, .. } = ins else { return };
            cov.sites += 1;
            let Some(id) = registry.id_of(mangled) else {
                // Missing pads are reported once per name; the stale-id
                // check below stays per *site* (two sites can share a
                // name but disagree on the id).
                if !seen.contains(mangled) {
                    seen.push(mangled.clone());
                    cov.missing.push(format!(
                        "@{fname}: RPC call site targets {mangled} but no scalar landing pad \
                         is registered (would fail at runtime inside the kernel)"
                    ));
                }
                return;
            };
            if id != *callee_id {
                cov.missing.push(format!(
                    "@{fname}: RPC call site carries callee id {callee_id} but {mangled} \
                     is registered as id {id} (stale compile against another registry)"
                ));
                return;
            }
            if seen.contains(mangled) {
                return;
            }
            seen.push(mangled.clone());
            cov.scalar_pads += 1;
            if let Some(kind) = kind_of_mangled(mangled, table) {
                if wrappers::synthesize_batch(kind).is_some() {
                    if registry.get_batch(id).is_some() {
                        cov.batch_pads += 1;
                    } else {
                        cov.missing.push(format!(
                            "@{fname}: {mangled} ({kind:?}) coalesces per engine sweep but \
                             has no batched landing pad registered"
                        ));
                    }
                }
            }
        });
    }
    cov
}

// ---- the ported passes ----

/// Format-string constant folding ahead of `libcres`/`rpcgen`: folds
/// format operands down to constant globals so `rpcgen` derives precise
/// buffer intents instead of pessimistic read-write (see [`constfold`]).
struct ConstFoldPass;

impl Pass for ConstFoldPass {
    fn name(&self) -> &'static str {
        "constfold"
    }

    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>> {
        let table = cx.cache.resolution(m).clone();
        let report = constfold::run_with(m, &table);
        let changed = !report.folded.is_empty();
        let summary = report.summary();
        cx.report.constfold = report;
        Ok(PassOutcome { summary, changed })
    }
}

/// Dead-code elimination ahead of `rpcgen` (see [`dce`]): unreachable
/// functions never reach pad synthesis, shrinking the registry's
/// working set and the AOT coverage surface.
struct DcePass;

impl Pass for DcePass {
    fn name(&self) -> &'static str {
        "dce"
    }

    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>> {
        let report = dce::run_with(m, &mut cx.cache);
        let changed = report.changed();
        let summary = report.summary();
        cx.report.dce = report;
        Ok(PassOutcome { summary, changed })
    }
}

/// Materializes the module-wide symbol-resolution table into the report
/// (pure analysis; see [`libcres`]).
struct LibcResPass;

impl Pass for LibcResPass {
    fn name(&self) -> &'static str {
        "libcres"
    }

    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>> {
        let table = cx.cache.resolution(m).clone();
        let summary = table.summary();
        cx.report.resolution = table;
        Ok(PassOutcome { summary, changed: false })
    }
}

/// Automatic RPC generation (paper §3.2) on the manager: consumes the
/// cached resolution table so only host-RPC callees get landing pads.
struct RpcGenPass;

impl Pass for RpcGenPass {
    fn name(&self) -> &'static str {
        "rpcgen"
    }

    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>> {
        let table = cx.cache.resolution(m).clone();
        let report = rpcgen::run_with(m, cx.registry, &table, &mut cx.cache);
        let changed = !report.rewritten.is_empty();
        let summary = format!(
            "{} call sites rewritten, {} unsupported",
            report.rewritten.len(),
            report.unsupported.len()
        );
        cx.report.rpc = report;
        Ok(PassOutcome { summary, changed })
    }
}

/// Multi-team expansion / kernel split (paper §3.3) on the manager:
/// judges eligibility against the cached call graph.
struct MultiTeamPass;

impl Pass for MultiTeamPass {
    fn name(&self) -> &'static str {
        "multiteam"
    }

    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>> {
        let report = multiteam::run_with(m, &mut cx.cache);
        let changed = !report.regions.is_empty();
        let summary = format!(
            "{} regions expanded, {} skipped",
            report.regions.len(),
            report.skipped.len()
        );
        cx.report.multiteam = report;
        Ok(PassOutcome { summary, changed })
    }
}

/// Compiles every function to the register-file form the interpreter
/// prefers (see [`lower`]). Reports `changed: false` — the tree is
/// untouched and the lowered form is a sidecar, so cached tree analyses
/// stay valid.
struct LowerPass;

impl Pass for LowerPass {
    fn name(&self) -> &'static str {
        "lower"
    }

    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>> {
        let report = lower::run(m);
        // The bytecode (if any) was flattened from the *previous*
        // lowered map; drop it rather than let it drift (an explicit
        // spec may order `bytecode` before `lower`).
        m.bytecode.clear();
        let summary = report.summary();
        cx.report.lower = report;
        Ok(PassOutcome { summary, changed: false })
    }
}

/// Folds adjacent lowered pairs into superinstructions (see [`fuse`]).
/// Also `changed: false`: only the sidecar is rewritten.
struct FusePass;

impl Pass for FusePass {
    fn name(&self) -> &'static str {
        "fuse"
    }

    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>> {
        let report = fuse::run(m);
        // Fusion rewrites the lowered forms the bytecode was flattened
        // from; drop any stale flattening (only reachable via explicit
        // specs that order `bytecode` before `fuse`).
        m.bytecode.clear();
        let summary = report.summary();
        cx.report.fuse = report;
        Ok(PassOutcome { summary, changed: false })
    }
}

/// Flattens every lowered function into the linear bytecode the
/// interpreter prefers over the register core (see [`bytecode`]).
/// Also `changed: false`: only the sidecar is written.
struct BytecodePass;

impl Pass for BytecodePass {
    fn name(&self) -> &'static str {
        "bytecode"
    }

    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>> {
        let report = bytecode::run(m);
        let summary = report.summary();
        cx.report.bytecode = report;
        Ok(PassOutcome { summary, changed: false })
    }
}

/// Runs the IR lints (see [`lint`]) over the cached resolution table
/// and materializes their located diagnostics into the report. Pure
/// analysis, opt-in via [`OPTIONAL_PASSES`].
struct LintPass;

impl Pass for LintPass {
    fn name(&self) -> &'static str {
        "lint"
    }

    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>> {
        let table = cx.cache.resolution(m).clone();
        let diags = lint::run_lints(m, &table);
        let summary = diags.summary();
        cx.report.diags = diags;
        Ok(PassOutcome { summary, changed: false })
    }
}

/// Runs the compile-time offload advisor (see [`advise`]): scores every
/// parallel region A100-vs-EPYC and materializes the ranked
/// [`advise::AdviseReport`]. Pure analysis — nothing executes — and
/// opt-in via [`OPTIONAL_PASSES`].
struct AdvisePass;

impl Pass for AdvisePass {
    fn name(&self) -> &'static str {
        "advise"
    }

    fn run(&self, m: &mut Module, cx: &mut PassCx) -> Result<PassOutcome, Vec<String>> {
        let table = cx.cache.resolution(m).clone();
        let report = advise::analyze(m, &table, &advise::AdviseParams::default());
        let summary = report.summary();
        cx.report.advise = report;
        Ok(PassOutcome { summary, changed: false })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;

    const SRC: &str = r#"
global @fmt const 14 "result: %d%c"

func @main() -> i64 {
  %sum = alloca 8
  store.8 0, %sum
  parallel num_threads(64) {
    for.team %i = 0 to 4096 step 1 {
      %v = load.8 %sum
    }
  }
  %r = load.8 %sum
  call printf(@fmt, %r, 10)
  return %r
}
"#;

    #[test]
    fn spec_parses_orders_and_rejects_unknown() {
        assert_eq!(PipelineSpec::default().names(), KNOWN_PASSES);
        assert_eq!(PipelineSpec::parse("default").unwrap(), PipelineSpec::default());
        let spec = PipelineSpec::parse("rpcgen, multiteam").unwrap();
        assert_eq!(spec.names(), &["rpcgen", "multiteam"]);
        // Order is preserved verbatim, not canonicalized.
        let spec = PipelineSpec::parse("multiteam,rpcgen").unwrap();
        assert_eq!(spec.names(), &["multiteam", "rpcgen"]);
        // Empty spec = verify-only pipeline.
        assert!(PipelineSpec::parse("").unwrap().names().is_empty());
        let err = PipelineSpec::parse("rpcgen,frobnicate").unwrap_err();
        assert!(err.contains("frobnicate") && err.contains("libcres"), "{err}");
        let err = PipelineSpec::parse("rpcgen,rpcgen").unwrap_err();
        assert!(err.contains("twice"), "{err}");
    }

    #[test]
    fn optional_passes_parse_and_append() {
        let spec = PipelineSpec::parse("libcres,lint,advise").unwrap();
        assert_eq!(spec.names(), &["libcres", "lint", "advise"]);
        // Optional passes never appear in the default pipeline...
        assert!(!PipelineSpec::default().contains("lint"));
        assert!(!PipelineSpec::default().contains("advise"));
        assert_eq!(PipelineSpec::default().names().len(), KNOWN_PASSES.len());
        // ...but with_advice appends them, idempotently, in order.
        let spec = PipelineSpec::default().with_advice();
        assert_eq!(spec.names().len(), KNOWN_PASSES.len() + 2);
        assert_eq!(&spec.names()[KNOWN_PASSES.len()..], &["lint", "advise"]);
        assert_eq!(spec.with_advice(), spec);
        let err = PipelineSpec::parse("lint,lint").unwrap_err();
        assert!(err.contains("twice"), "{err}");
    }

    #[test]
    fn lint_and_advise_passes_fill_the_report_without_mutating() {
        let mut m = parse_module(SRC).unwrap();
        let before = m.clone();
        let reg = WrapperRegistry::new();
        let spec = PipelineSpec::parse("libcres,lint,advise").unwrap();
        let report = PassManager::from_spec(&spec).run(&mut m, &reg).unwrap();
        assert_eq!(m, before, "analysis passes must not mutate the module");
        assert_eq!(report.advise.regions.len(), 1, "{:?}", report.advise);
        assert!(report.timings.iter().all(|t| !t.changed));
        // The advisor also understands the post-multiteam shape: after
        // the full pipeline the region is an outlined kernel function.
        let mut m2 = parse_module(SRC).unwrap();
        let report2 = PassManager::from_spec(&PipelineSpec::default().with_advice())
            .run(&mut m2, &reg)
            .unwrap();
        assert_eq!(report2.advise.regions.len(), 1, "{:?}", report2.advise);
        assert_eq!(report2.advise.regions[0].region, "kernel");
    }

    #[test]
    fn unregistered_pad_is_a_compile_time_diagnostic() {
        // A module carrying an RpcCall whose landing pad was never
        // registered (a recompile against a fresh registry) must fail at
        // compile time with a diagnostic naming the pad — previously the
        // kernel discovered this at runtime as a -1 return.
        let mut m = Module::new();
        m.functions.insert(
            "main".into(),
            crate::ir::Function {
                name: "main".into(),
                params: vec![],
                ret: crate::ir::Ty::I64,
                body: vec![
                    Instr::RpcCall {
                        dst: None,
                        mangled: "__printf_cp".into(),
                        callee_id: 0,
                        args: vec![],
                    },
                    Instr::Return(Some(crate::ir::Operand::ConstI(0))),
                ],
                is_kernel_region: false,
            },
        );
        let reg = WrapperRegistry::new();
        let err = PassManager::from_spec(&PipelineSpec::parse("").unwrap())
            .run(&mut m, &reg)
            .unwrap_err();
        assert!(err[0].contains("__printf_cp"), "{err:?}");
        assert!(err[0].contains("no scalar landing pad"), "{err:?}");

        // Registering only the scalar pad still fails: the printf model
        // batches per sweep, so the batched variant is part of coverage.
        let id = reg.register("__printf_cp", Box::new(|_, _| 0));
        if let Some(Instr::RpcCall { callee_id, .. }) =
            m.functions.get_mut("main").unwrap().body.first_mut()
        {
            *callee_id = id;
        }
        let err = PassManager::from_spec(&PipelineSpec::parse("").unwrap())
            .run(&mut m, &reg)
            .unwrap_err();
        assert!(err[0].contains("no batched landing pad"), "{err:?}");

        // The full registration (what register_pad does) passes.
        let kind = HostFnKind::Printf { has_fd: false };
        crate::rpc::wrappers::register_pad(&reg, "__printf_cp", kind);
        let report = PassManager::from_spec(&PipelineSpec::parse("").unwrap())
            .run(&mut m, &reg)
            .unwrap();
        assert_eq!(report.pad_coverage.sites, 1);
        assert_eq!(report.pad_coverage.batch_pads, 1);
    }

    #[test]
    fn stale_callee_id_is_a_compile_time_diagnostic() {
        let reg = WrapperRegistry::new();
        let good = crate::rpc::wrappers::register_pad(&reg, "__exit_i", HostFnKind::Exit);
        // Two sites sharing the pad name: the FIRST carries the correct
        // id, the second a stale one — the per-site check must still
        // flag it (a name-level dedup before the id comparison hid it).
        let mut m = Module::new();
        m.functions.insert(
            "main".into(),
            crate::ir::Function {
                name: "main".into(),
                params: vec![],
                ret: crate::ir::Ty::I64,
                body: vec![
                    Instr::RpcCall {
                        dst: None,
                        mangled: "__exit_i".into(),
                        callee_id: good,
                        args: vec![],
                    },
                    Instr::RpcCall {
                        dst: None,
                        mangled: "__exit_i".into(),
                        callee_id: 99,
                        args: vec![],
                    },
                    Instr::Return(Some(crate::ir::Operand::ConstI(0))),
                ],
                is_kernel_region: false,
            },
        );
        let err = PassManager::from_spec(&PipelineSpec::parse("").unwrap())
            .run(&mut m, &reg)
            .unwrap_err();
        assert_eq!(err.len(), 1, "{err:?}");
        assert!(err[0].contains("stale"), "{err:?}");
    }

    #[test]
    fn spec_from_options_drops_disabled_passes() {
        let opts = CompileOptions {
            constfold: false,
            dce: false,
            libcres: true,
            rpcgen: true,
            multiteam: false,
            lower: false,
            fuse: false,
            bytecode: false,
        };
        assert_eq!(PipelineSpec::from_options(opts).names(), &["libcres", "rpcgen"]);
        let with_fold = CompileOptions {
            multiteam: false,
            lower: false,
            fuse: false,
            bytecode: false,
            ..CompileOptions::default()
        };
        assert_eq!(
            PipelineSpec::from_options(with_fold).names(),
            &["constfold", "dce", "libcres", "rpcgen"]
        );
        let none = CompileOptions {
            constfold: false,
            dce: false,
            libcres: false,
            rpcgen: false,
            multiteam: false,
            lower: false,
            fuse: false,
            bytecode: false,
        };
        assert!(PipelineSpec::from_options(none).names().is_empty());
        assert_eq!(PipelineSpec::from_options(CompileOptions::default()), PipelineSpec::default());
    }

    #[test]
    fn manager_times_every_pass_in_order() {
        let mut m = parse_module(SRC).unwrap();
        let reg = WrapperRegistry::new();
        let report = PassManager::from_spec(&PipelineSpec::default()).run(&mut m, &reg).unwrap();
        assert_eq!(report.pipeline, KNOWN_PASSES.to_vec());
        assert_eq!(report.timings.len(), 8);
        for t in &report.timings {
            assert!(t.wall_ns >= 0.0);
            assert!(!t.summary.is_empty());
        }
        assert!(!report.timings[0].changed, "direct @fmt format: nothing to fold");
        assert!(!report.timings[1].changed, "no dead code in SRC");
        assert!(!report.timings[2].changed, "libcres is pure analysis");
        assert!(report.timings[3].changed, "rpcgen rewrote the printf site");
        assert!(report.timings[4].changed, "multiteam expanded the region");
        assert!(!report.timings[5].changed, "lower only writes the sidecar");
        assert!(!report.timings[6].changed, "fuse only rewrites the sidecar");
        assert!(!report.timings[7].changed, "bytecode only writes the sidecar");
        assert!(report.lower.lowered_fns >= 1, "{:?}", report.lower);
        assert_eq!(
            report.bytecode.bytecode_fns, report.lower.lowered_fns,
            "every lowered function flattens: {:?}",
            report.bytecode
        );
        assert_eq!(m.bytecode.len(), m.lowered.len());
        // The AOT coverage check verified the generated site's pads.
        assert_eq!(report.pad_coverage.sites, 1);
        assert_eq!(report.pad_coverage.scalar_pads, 1);
        assert_eq!(report.pad_coverage.batch_pads, 1, "printf pads register batched variants");
        assert!(report.pad_coverage.missing.is_empty());
    }

    #[test]
    fn cache_is_reused_until_a_pass_mutates_the_module() {
        let mut m = parse_module(SRC).unwrap();
        let reg = WrapperRegistry::new();
        let report = PassManager::from_spec(&PipelineSpec::default()).run(&mut m, &reg).unwrap();
        // libcres builds the resolution table; rpcgen re-reads it from
        // cache (libcres did not mutate) — exactly one build, >= 1 hit.
        assert_eq!(report.cache.resolution_builds, 1);
        assert!(report.cache.hits >= 1, "rpcgen must hit the cached table: {:?}", report.cache);
        // rpcgen and multiteam both mutated -> two invalidations (dce,
        // lower and fuse change nothing on this corpus).
        assert_eq!(report.cache.invalidations, 2);
        // dce built the call graph once up front; multiteam rebuilt it
        // after rpcgen's invalidation.
        assert_eq!(report.cache.callgraph_builds, 2);
    }

    #[test]
    fn analysis_cache_invalidation_contract() {
        let m = parse_module(SRC).unwrap();
        let mut cache = AnalysisCache::default();
        cache.callgraph(&m);
        cache.callgraph(&m);
        assert_eq!(cache.stats.callgraph_builds, 1);
        assert_eq!(cache.stats.hits, 1);
        cache.def_map(&m, "main").unwrap();
        cache.def_map(&m, "main").unwrap();
        assert_eq!(cache.stats.def_map_builds, 1);
        assert!(cache.def_map(&m, "nope").is_none());
        cache.invalidate();
        assert_eq!(cache.stats.invalidations, 1);
        cache.callgraph(&m);
        assert_eq!(cache.stats.callgraph_builds, 2, "invalidate drops the graph");
    }

    #[test]
    fn empty_pipeline_only_verifies() {
        let mut m = parse_module(SRC).unwrap();
        let before = m.clone();
        let reg = WrapperRegistry::new();
        let report =
            PassManager::from_spec(&PipelineSpec::parse("").unwrap()).run(&mut m, &reg).unwrap();
        assert_eq!(m, before, "no pass ran, no mutation");
        assert!(report.timings.is_empty());
        let mut bad = parse_module("func @main() -> i64 {\n  return %undef\n}\n").unwrap();
        assert!(PassManager::from_spec(&PipelineSpec::parse("").unwrap())
            .run(&mut bad, &reg)
            .is_err());
    }

    #[test]
    fn reordered_pipeline_still_verifies() {
        // multiteam before rpcgen: the region's printf call makes it
        // ineligible (RPC-ish), so it stays single-team — a valid, if
        // baseline, compilation.
        let src = r#"
global @fmt const 4 "%d\n"

func @main() -> i64 {
  parallel {
    call printf(@fmt, 1)
  }
  return 0
}
"#;
        let mut m = parse_module(src).unwrap();
        let reg = WrapperRegistry::new();
        let spec = PipelineSpec::parse("multiteam,rpcgen").unwrap();
        let report = PassManager::from_spec(&spec).run(&mut m, &reg).unwrap();
        assert_eq!(report.pipeline, vec!["multiteam".to_string(), "rpcgen".into()]);
        assert!(report.multiteam.regions.is_empty());
        assert_eq!(report.rpc.rewritten.len(), 1, "rpcgen still rewrites afterwards");
    }
}
