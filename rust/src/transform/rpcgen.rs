//! Automatic RPC generation (paper §3.2, Fig. 3).
//!
//! A link-time pass with the complete world view: every call site whose
//! callee the [`libcres`](super::libcres) resolution table classifies as
//! **host-RPC** is replaced by an [`Instr::RpcCall`] whose argument
//! descriptors encode the underlying-object analysis results, and a
//! non-variadic host landing pad is synthesized and registered per
//! `(callee × argument-type-signature)` — variadic call sites that
//! disagree on argument types get distinct landing pads
//! (`__fscanf_ip_fp_ip`-style mangling). Device-native callees are left
//! alone (they never become RPCs) and unresolved callees are reported,
//! mirroring the table's compile-time diagnostics.

use super::libcres::{resolve_module, ResolutionTable, SymbolClass};
use super::pm::AnalysisCache;
use crate::analysis::objects::{classify_operand, ObjClass, OffKind, StaticObj};
use crate::ir::{Instr, Module, OffsetSpec, Operand, RpcArgSpec};
use crate::rpc::wrappers::{self, Conv, HostFnKind};
use crate::rpc::{ArgMode, WrapperRegistry};
use std::collections::HashMap;

/// What the pass did — consumed by tests, examples and the CLI's
/// `--explain` mode.
#[derive(Debug, Default, Clone)]
pub struct RpcGenReport {
    /// (function, original callee, mangled landing-pad name, arg summary).
    pub rewritten: Vec<(String, String, String, Vec<String>)>,
    /// Library callees we had no host model for (left as direct calls —
    /// they will trap in the interpreter, mirroring the paper's
    /// "not infallible" caveat).
    pub unsupported: Vec<String>,
    /// Arguments lowered with a read-write buffer intent — the
    /// pessimistic "copy both ways" path a resolved format avoids. The
    /// `constfold` equivalence suite asserts the folded pipeline yields
    /// strictly fewer of these on fold-y programs.
    pub rw_buffer_intents: u64,
}

/// Run RPC generation standalone: builds its own resolution table and
/// analysis cache. The pass-manager path goes through [`run_with`].
pub fn run(m: &mut Module, registry: &WrapperRegistry) -> RpcGenReport {
    let table = resolve_module(m);
    run_with(m, registry, &table, &mut AnalysisCache::default())
}

/// Run RPC generation over the module, rewriting exactly the call sites
/// `table` classifies as host-RPC and registering landing pads in
/// `registry`. Def maps come from `cache` (shared with the other passes
/// under the pass manager). Returns the report.
pub fn run_with(
    m: &mut Module,
    registry: &WrapperRegistry,
    table: &ResolutionTable,
    cache: &mut AnalysisCache,
) -> RpcGenReport {
    let mut report = RpcGenReport::default();
    let fnames: Vec<String> = m.functions.keys().cloned().collect();
    for fname in fnames {
        let mut f = m.functions.get(&fname).unwrap().clone();
        // The cached def map reflects the pre-rewrite body; rewriting
        // replaces Call with RpcCall, which classifies identically (both
        // are dynamic-origin results), so it stays valid for the whole
        // rewrite of this function. Borrowed, not cloned — the cache and
        // the module are separate objects.
        let Some(defs) = cache.def_map(m, &fname) else { continue };
        rewrite_body(m, &mut f.body, defs, registry, table, &fname, &mut report);
        m.functions.insert(fname, f);
    }
    report
}

#[allow(clippy::too_many_arguments)]
fn rewrite_body(
    m: &Module,
    body: &mut Vec<Instr>,
    defs: &HashMap<String, Instr>,
    registry: &WrapperRegistry,
    table: &ResolutionTable,
    fname: &str,
    report: &mut RpcGenReport,
) {
    for ins in body.iter_mut() {
        match ins {
            Instr::Call { dst, callee, args } if !m.is_defined(callee) => {
                let kind = match table.class_of(callee) {
                    Some(SymbolClass::HostRpc(kind)) => kind,
                    // Device-native callees never become RPCs (§3.4).
                    Some(SymbolClass::Device(_)) => continue,
                    // Unresolved (or missing from a stale table): the
                    // compile-time diagnostic; the call site is left as a
                    // direct call the interpreter traps on, mirroring the
                    // paper's "not infallible" caveat.
                    Some(SymbolClass::Unresolved) | None => {
                        if !report.unsupported.contains(callee) {
                            report.unsupported.push(callee.clone());
                        }
                        continue;
                    }
                };
                let (specs, tags, summary) = build_specs(m, defs, callee, kind, args);
                report.rw_buffer_intents += specs.iter().filter(|s| spec_is_rw(s)).count() as u64;
                let mangled = mangle(callee, &tags);
                // Registers the scalar pad, the batched variant for
                // order-preserving-append callees, and marks launch pads
                // for the engine's dedicated executor.
                let callee_id = wrappers::register_pad(registry, &mangled, kind);
                report.rewritten.push((
                    fname.to_string(),
                    callee.clone(),
                    mangled.clone(),
                    summary,
                ));
                *ins = Instr::RpcCall { dst: dst.clone(), mangled, callee_id, args: specs };
            }
            Instr::If { then_body, else_body, .. } => {
                rewrite_body(m, then_body, defs, registry, table, fname, report);
                rewrite_body(m, else_body, defs, registry, table, fname, report);
            }
            Instr::While { cond, body, .. } => {
                rewrite_body(m, cond, defs, registry, table, fname, report);
                rewrite_body(m, body, defs, registry, table, fname, report);
            }
            Instr::For { body, .. } | Instr::Parallel { body, .. } => {
                rewrite_body(m, body, defs, registry, table, fname, report)
            }
            _ => {}
        }
    }
}

/// Does the lowered argument carry a read-write (copy both ways)
/// buffer? `MultiRef` counts when any runtime candidate would round-trip.
fn spec_is_rw(spec: &RpcArgSpec) -> bool {
    match spec {
        RpcArgSpec::Ref { mode, .. } | RpcArgSpec::DynRef { mode, .. } => {
            *mode == ArgMode::ReadWrite
        }
        RpcArgSpec::MultiRef { candidates, .. } => {
            candidates.iter().any(|(_, mode, _, _)| *mode == ArgMode::ReadWrite)
        }
        RpcArgSpec::Val(_) => false,
    }
}

/// Mangle the landing-pad name from per-argument type tags
/// (`__fscanf_ip_fp_ip` in Fig. 3b: "the host wrapper function name uses
/// the variadic argument types").
pub fn mangle(callee: &str, tags: &[&'static str]) -> String {
    if tags.is_empty() {
        format!("__{callee}")
    } else {
        format!("__{callee}_{}", tags.join("_"))
    }
}

/// Per-argument intent derived from the host-function model.
#[derive(Debug, Clone, Copy, PartialEq)]
enum ArgIntent {
    /// Opaque value (FILE*, int, ...).
    OpaqueVal,
    /// Read-only string/buffer.
    ReadBuf(&'static str),
    /// Write-only out-buffer.
    WriteBuf(&'static str),
    /// Read-write buffer (unknown callee behaviour).
    RwBuf(&'static str),
    /// Numeric vararg passed by value.
    NumVal(&'static str),
}

/// Determine each argument's intent for `kind`, consulting the format
/// string (when it is a compile-time constant) for variadic calls —
/// exactly the precision the paper's pass gets from constant formats.
fn arg_intents(
    m: &Module,
    kind: HostFnKind,
    args: &[Operand],
    defs: &HashMap<String, Instr>,
) -> Vec<ArgIntent> {
    use ArgIntent::*;
    let fmt_convs = |fmt_idx: usize| -> Option<Vec<Conv>> {
        let op = args.get(fmt_idx)?;
        let defs_class = classify_operand(m, defs, op);
        if let ObjClass::Static(StaticObj { origin, constant: true, offset, .. }) = defs_class {
            if let crate::analysis::objects::ObjOrigin::Global(g) = origin {
                // Honor a constant pointer offset into the global (a
                // `gep @fmt, N` format starts mid-string); a dynamic
                // offset means the text is unknown. The string ends at
                // its NUL, not at the (zero-filled) object size.
                let start = match offset {
                    OffKind::Const(c) => c as usize,
                    OffKind::Dynamic => return None,
                };
                let init = &m.globals[&g].init;
                let end = init.iter().position(|&b| b == 0).unwrap_or(init.len());
                if start > end {
                    return None;
                }
                let text = String::from_utf8_lossy(&init[start..end]).into_owned();
                return Some(
                    wrappers::parse_format(&text)
                        .into_iter()
                        .filter_map(|(_, c)| c.map(|(conv, _, _)| conv))
                        .filter(|c| *c != Conv::Percent)
                        .collect(),
                );
            }
        }
        None
    };
    match kind {
        HostFnKind::Printf { has_fd } => {
            let fmt_i = usize::from(has_fd);
            let mut v = Vec::new();
            if has_fd {
                v.push(OpaqueVal);
            }
            v.push(ReadBuf("cp"));
            match fmt_convs(fmt_i) {
                Some(convs) => {
                    for c in convs {
                        v.push(match c {
                            Conv::Str => ReadBuf("cp"),
                            Conv::Float => NumVal("f"),
                            _ => NumVal("i"),
                        });
                    }
                    // Extra args beyond conversions: opaque.
                    while v.len() < args.len() {
                        v.push(OpaqueVal);
                    }
                }
                None => {
                    // Unknown format: buffers must be copied back and forth
                    // (the Fig. 7 `fprintf` case).
                    while v.len() < args.len() {
                        v.push(RwBuf("vp"));
                    }
                }
            }
            v
        }
        HostFnKind::Scanf { has_fd } => {
            let fmt_i = usize::from(has_fd);
            let mut v = Vec::new();
            if has_fd {
                v.push(OpaqueVal);
            }
            v.push(ReadBuf("cp"));
            match fmt_convs(fmt_i) {
                Some(convs) => {
                    for c in convs {
                        v.push(match c {
                            Conv::Float => WriteBuf("fp"),
                            Conv::Str => WriteBuf("cp"),
                            _ => WriteBuf("ip"),
                        });
                    }
                    while v.len() < args.len() {
                        v.push(RwBuf("vp"));
                    }
                }
                None => {
                    while v.len() < args.len() {
                        v.push(RwBuf("vp"));
                    }
                }
            }
            v
        }
        HostFnKind::Fopen => vec![ReadBuf("cp"), ReadBuf("cp")],
        HostFnKind::Fclose => vec![OpaqueVal],
        HostFnKind::Fread => vec![WriteBuf("vp"), NumVal("i"), NumVal("i"), OpaqueVal],
        HostFnKind::Fwrite => vec![ReadBuf("vp"), NumVal("i"), NumVal("i"), OpaqueVal],
        HostFnKind::Puts => vec![ReadBuf("cp")],
        HostFnKind::Exit => vec![NumVal("i")],
        HostFnKind::Time => vec![],
        HostFnKind::Getenv => vec![ReadBuf("cp"), WriteBuf("cp")],
        HostFnKind::LaunchKernel => vec![NumVal("i"), NumVal("i")],
    }
}

#[allow(clippy::type_complexity)]
fn build_specs(
    m: &Module,
    defs: &HashMap<String, Instr>,
    _callee: &str,
    kind: HostFnKind,
    args: &[Operand],
) -> (Vec<RpcArgSpec>, Vec<&'static str>, Vec<String>) {
    let intents = arg_intents(m, kind, args, defs);
    let mut specs = Vec::new();
    let mut tags: Vec<&'static str> = Vec::new();
    let mut summary = Vec::new();
    for (i, arg) in args.iter().enumerate() {
        let intent = intents.get(i).copied().unwrap_or(ArgIntent::RwBuf("vp"));
        let class = classify_operand(m, defs, arg);
        let (spec, tag, desc) = lower_arg(arg, intent, class);
        specs.push(spec);
        tags.push(tag);
        summary.push(desc);
    }
    (specs, tags, summary)
}

fn lower_arg(
    arg: &Operand,
    intent: ArgIntent,
    class: ObjClass,
) -> (RpcArgSpec, &'static str, String) {
    use ArgIntent::*;
    // Value intents never migrate memory.
    match intent {
        OpaqueVal => return (RpcArgSpec::Val(arg.clone()), "p", "value (opaque)".into()),
        NumVal(t) => return (RpcArgSpec::Val(arg.clone()), t, "value".into()),
        _ => {}
    }
    let (mode, tag) = match intent {
        ReadBuf(t) => (ArgMode::Read, t),
        WriteBuf(t) => (ArgMode::Write, t),
        RwBuf(t) => (ArgMode::ReadWrite, t),
        _ => unreachable!(),
    };
    let adjust = |mode: ArgMode, s: &StaticObj| -> ArgMode {
        if s.constant {
            // Constant objects are copy-in only (the format-string case).
            ArgMode::Read
        } else if mode == ArgMode::Write && !(s.offset == OffKind::Const(0) && s.size <= 8) {
            // Write-only is only safe when the pointer owns its whole small
            // object (the paper's `&i` vs `&s.b` distinction: writing a
            // field of a live struct must round-trip the struct).
            ArgMode::ReadWrite
        } else {
            mode
        }
    };
    match class {
        ObjClass::Value => (RpcArgSpec::Val(arg.clone()), tag, "value (scalar)".into()),
        ObjClass::Static(s) => {
            let mode = adjust(mode, &s);
            match s.offset {
                OffKind::Const(c) => (
                    RpcArgSpec::Ref {
                        ptr: arg.clone(),
                        mode,
                        obj_size: s.size,
                        offset: OffsetSpec::Const(c),
                    },
                    tag,
                    format!("static object {:?} size {} offset {}", s.origin, s.size, c),
                ),
                OffKind::Dynamic => (
                    RpcArgSpec::MultiRef {
                        ptr: arg.clone(),
                        candidates: vec![(
                            s.origin.base_operand(),
                            mode,
                            s.size,
                            OffsetSpec::Dynamic,
                        )],
                    },
                    tag,
                    format!("static object {:?}, dynamic offset", s.origin),
                ),
            }
        }
        ObjClass::Multi(cands) => {
            let candidates = cands
                .iter()
                .map(|s| {
                    let mode = adjust(mode, s);
                    let off = match s.offset {
                        OffKind::Const(c) => OffsetSpec::Const(c),
                        OffKind::Dynamic => OffsetSpec::Dynamic,
                    };
                    (s.origin.base_operand(), mode, s.size, off)
                })
                .collect();
            (
                RpcArgSpec::MultiRef { ptr: arg.clone(), candidates },
                tag,
                format!("{} statically enumerated candidates", cands.len()),
            )
        }
        ObjClass::Dynamic => (
            RpcArgSpec::DynRef { ptr: arg.clone(), mode },
            tag,
            "dynamic lookup (_FindObj)".into(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;

    fn run_on(src: &str) -> (Module, RpcGenReport, WrapperRegistry) {
        let mut m = parse_module(src).unwrap();
        m.verify().unwrap();
        let registry = WrapperRegistry::new();
        let report = run(&mut m, &registry);
        m.verify().unwrap();
        (m, report, registry)
    }

    const FIG3: &str = r#"
global @fmt const 9 "%f %i %i"

func @use(%s: ptr, %r: i64, %i: i64) -> void {
  return
}

func @main() -> i64 {
  %fd = 0
  %s = alloca 12
  %i = alloca 4
  %sa = load.4 %s
  %pb = gep %s, 4
  %pf = gep %s, 8
  %c = ne %sa, 0
  %p = select %c, %i, %pb
  %r = call fscanf(%fd, @fmt, %pf, %p, %i)
  call use(%s, %r, 0)
  return %r
}
"#;

    #[test]
    fn fig3_call_site_lowered_like_the_paper() {
        let (m, report, reg) = run_on(FIG3);
        // Mangled per the variadic arg types: fd, fmt, %f -> fp, %i -> ip, %i -> ip.
        assert_eq!(report.rewritten.len(), 1);
        let (_, callee, mangled, _) = &report.rewritten[0];
        assert_eq!(callee, "fscanf");
        assert_eq!(mangled, "__fscanf_p_cp_fp_ip_ip");
        assert!(reg.id_of(mangled).is_some());

        let body = &m.functions["main"].body;
        let Some(Instr::RpcCall { args, .. }) =
            body.iter().find(|i| matches!(i, Instr::RpcCall { .. }))
        else {
            panic!("no RpcCall in {body:?}")
        };
        // fd: value.
        assert!(matches!(&args[0], RpcArgSpec::Val(_)));
        // fmt: const global, read-only, size 9, offset 0.
        assert!(matches!(
            &args[1],
            RpcArgSpec::Ref { mode: ArgMode::Read, obj_size: 9, offset: OffsetSpec::Const(0), .. }
        ));
        // &s.f: inside a 12-byte live struct -> readwrite, offset 8.
        assert!(matches!(
            &args[2],
            RpcArgSpec::Ref {
                mode: ArgMode::ReadWrite,
                obj_size: 12,
                offset: OffsetSpec::Const(8),
                ..
            }
        ));
        // select(&i, &s.b): statically enumerated candidates, &i write-only
        // (owns its whole 4-byte object), &s.b readwrite.
        let RpcArgSpec::MultiRef { candidates, .. } = &args[3] else {
            panic!("{:?}", args[3])
        };
        assert_eq!(candidates.len(), 2);
        assert_eq!(candidates[0].1, ArgMode::Write);
        assert_eq!(candidates[0].2, 4);
        assert_eq!(candidates[1].1, ArgMode::ReadWrite);
        assert_eq!(candidates[1].2, 12);
        assert_eq!(candidates[1].3, OffsetSpec::Const(4));
        // &i direct: own whole object -> write-only.
        assert!(matches!(&args[4], RpcArgSpec::Ref { mode: ArgMode::Write, obj_size: 4, .. }));
        // Internal call untouched.
        assert!(body.iter().any(|i| matches!(i, Instr::Call { callee, .. } if callee == "use")));
    }

    #[test]
    fn unknown_format_makes_buffers_readwrite() {
        // The Fig. 7 experiment: fprintf with a buffer whose read/write
        // behaviour is unknown without inspecting the format.
        let src = r#"
func @main(%fmt: ptr, %buf: ptr) -> i64 {
  %r = call fprintf(2, %fmt, %buf)
  return %r
}
"#;
        let (m, report, _) = run_on(src);
        let body = &m.functions["main"].body;
        let Instr::RpcCall { args, mangled, .. } = &body[0] else { panic!() };
        // fd is opaque, the format itself is still read-only, but the
        // trailing buffer can't be classified without the format text.
        assert_eq!(mangled, "__fprintf_p_cp_vp");
        assert!(matches!(&args[1], RpcArgSpec::DynRef { mode: ArgMode::Read, .. }));
        assert!(matches!(&args[2], RpcArgSpec::DynRef { mode: ArgMode::ReadWrite, .. }));
        assert_eq!(report.rw_buffer_intents, 1, "the pessimistic buffer is counted");
    }

    #[test]
    fn const_offset_gep_format_reads_the_suffix_text() {
        // A format pointer at a constant offset into the global must
        // classify from the text at that offset — reading from byte 0
        // used to derive the prefix's conversions too, mis-typing the
        // varargs (here: an extra %d that would swallow the buffer as a
        // by-value int).
        let src = r#"
global @fmt const 9 "%d ok %s"
global @buf const 16 "hello"

func @main() -> i64 {
  %f = gep @fmt, 3
  %r = call printf(%f, @buf)
  return %r
}
"#;
        let (m, report, _) = run_on(src);
        assert_eq!(report.rewritten[0].2, "__printf_cp_cp", "only the suffix's %s counts");
        let Instr::RpcCall { args, .. } = &m.functions["main"].body[1] else { panic!() };
        assert!(matches!(
            &args[0],
            RpcArgSpec::Ref { mode: ArgMode::Read, offset: OffsetSpec::Const(3), .. }
        ));
        assert!(matches!(&args[1], RpcArgSpec::Ref { mode: ArgMode::Read, .. }));
        assert_eq!(report.rw_buffer_intents, 0);
    }

    #[test]
    fn dynamic_offset_format_stays_pessimistic() {
        let src = r#"
global @fmt const 9 "%d ok %s"
global @buf 16

func @main(%i: i64) -> i64 {
  %f = gep @fmt, %i
  %r = call printf(%f, @buf)
  return %r
}
"#;
        let (_, report, _) = run_on(src);
        assert_eq!(report.rewritten[0].2, "__printf_cp_vp");
        assert_eq!(report.rw_buffer_intents, 1, "unknown text => copy both ways");
    }

    #[test]
    fn const_format_numeric_args_pass_by_value() {
        let src = r#"
global @fmt const 12 "it=%d x=%f\n"

func @main() -> i64 {
  %x = 1.5
  %r = call printf(@fmt, 3, %x)
  return %r
}
"#;
        let (m, report, _) = run_on(src);
        assert_eq!(report.rewritten[0].2, "__printf_cp_i_f");
        let Instr::RpcCall { args, .. } = &m.functions["main"].body[1] else { panic!() };
        assert!(matches!(&args[1], RpcArgSpec::Val(Operand::ConstI(3))));
        assert!(matches!(&args[2], RpcArgSpec::Val(Operand::Var(v)) if v == "x"));
    }

    #[test]
    fn malloc_pointer_gets_dynamic_lookup() {
        let src = r#"
global @fmt const 4 "%s\n"

func @main() -> i64 {
  %p = call malloc(64)
  %r = call printf(@fmt, %p)
  return %r
}
"#;
        let (m, _, _) = run_on(src);
        let Instr::RpcCall { args, .. } = &m.functions["main"].body[1] else { panic!() };
        assert!(matches!(&args[1], RpcArgSpec::DynRef { mode: ArgMode::Read, .. }));
    }

    #[test]
    fn unmodeled_library_reported_unsupported() {
        let src = "func @main() -> i64 {\n  call dgemm(1)\n  return 0\n}\n";
        let (m, report, _) = run_on(src);
        assert_eq!(report.unsupported, vec!["dgemm"]);
        assert!(matches!(&m.functions["main"].body[0], Instr::Call { .. }));
    }

    #[test]
    fn same_signature_shares_landing_pad() {
        let src = r#"
global @f1 const 3 "%d"
global @f2 const 3 "%d"

func @main() -> i64 {
  call printf(@f1, 1)
  call printf(@f2, 2)
  return 0
}
"#;
        let (_, report, reg) = run_on(src);
        assert_eq!(report.rewritten.len(), 2);
        assert_eq!(report.rewritten[0].2, report.rewritten[1].2);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn disagreeing_variadic_sites_get_distinct_pads() {
        let src = r#"
global @f1 const 3 "%d"
global @f2 const 3 "%f"

func @main() -> i64 {
  %x = 2.5
  call printf(@f1, 1)
  call printf(@f2, %x)
  return 0
}
"#;
        let (_, report, reg) = run_on(src);
        assert_eq!(report.rewritten[0].2, "__printf_cp_i");
        assert_eq!(report.rewritten[1].2, "__printf_cp_f");
        assert_eq!(reg.len(), 2);
    }
}
