//! Per-run metrics: real wallclock + modeled device time decomposition,
//! plus the RPC engine's occupancy/batching counters when the session
//! runs the multi-lane engine.

use crate::gpu::stats::LaunchStats;
use crate::perfmodel::a100;
use crate::rpc::EngineSnapshot;

#[derive(Debug, Clone, Copy)]
pub struct RunMetrics {
    pub exit_code: i64,
    /// Real wallclock of the whole simulated run on this host.
    pub wall_ns: f64,
    /// Main-kernel (serial part, 1×1) stats.
    pub main_stats: LaunchStats,
    /// Aggregate over all launched parallel kernels.
    pub kernel_stats: LaunchStats,
    pub kernel_launches: u64,
    pub grid: (usize, usize),
    /// Engine counters; `None` on the legacy single-slot path.
    pub rpc_engine: Option<EngineSnapshot>,
}

impl RunMetrics {
    /// Modeled A100 time: serial main kernel (1 thread) + parallel kernels
    /// (whole grid) + one kernel-split RPC per launch.
    pub fn modeled_device_ns(&self) -> f64 {
        let serial = a100::device_time(&self.main_stats, 1, 1).total_ns();
        let par = a100::device_time(
            &self.kernel_stats,
            (self.grid.0 * self.grid.1) as u64,
            self.kernel_launches.max(1),
        )
        .total_ns();
        serial + par + self.kernel_launches as f64 * a100::KERNEL_SPLIT_RPC_NS
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "exit={} wall={} modeled_device={} launches={} grid={}x{} rpcs={}",
            self.exit_code,
            crate::util::fmt_ns(self.wall_ns),
            crate::util::fmt_ns(self.modeled_device_ns()),
            self.kernel_launches,
            self.grid.0,
            self.grid.1,
            self.main_stats.rpc_calls + self.kernel_stats.rpc_calls,
        );
        if let Some(e) = &self.rpc_engine {
            s.push(' ');
            s.push_str(&e.summary());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_time_includes_launch_rpc() {
        let m = RunMetrics {
            exit_code: 0,
            wall_ns: 0.0,
            main_stats: LaunchStats::default(),
            kernel_stats: LaunchStats::default(),
            kernel_launches: 3,
            grid: (4, 32),
            rpc_engine: None,
        };
        assert!(m.modeled_device_ns() >= 3.0 * a100::KERNEL_SPLIT_RPC_NS);
        assert!(m.summary().contains("launches=3"));
        assert!(!m.summary().contains("rpc_engine"));
    }

    #[test]
    fn summary_appends_engine_counters() {
        let m = RunMetrics {
            exit_code: 0,
            wall_ns: 0.0,
            main_stats: LaunchStats::default(),
            kernel_stats: LaunchStats::default(),
            kernel_launches: 0,
            grid: (1, 1),
            rpc_engine: Some(EngineSnapshot {
                lanes: 4,
                workers: 2,
                served: 10,
                batches: 2,
                batched_calls: 6,
                max_batch: 4,
                steals: 1,
                polls: 100,
                polls_busy: 25,
            }),
        };
        let s = m.summary();
        assert!(s.contains("rpc_engine lanes=4 workers=2 served=10"));
        assert!(s.contains("occupancy=0.250"));
    }
}
