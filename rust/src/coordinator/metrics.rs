//! Per-run metrics: real wallclock + modeled device time decomposition,
//! the RPC engine's occupancy/batching/launch-executor counters, the
//! host environment's file-table shard counters, and the middle-end's
//! per-pass wall times from the pass manager.

use crate::gpu::stats::LaunchStats;
use crate::obs::{EventRecord, HistSnapshot};
use crate::perfmodel::a100;
use crate::rpc::{EngineSnapshot, HostIoSnapshot};
use crate::transform::PassTiming;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct RunMetrics {
    /// Launch-session id of the program environment that produced this
    /// run (the interpreter's process-global mint; the serving daemon's
    /// `SessionHandle::id` is the same number, so daemon-side attribution
    /// and ring-slot telemetry key consistently). 0 for hand-built
    /// metrics.
    pub session: u64,
    pub exit_code: i64,
    /// Real wallclock of the whole simulated run on this host.
    pub wall_ns: f64,
    /// Main-kernel (serial part, 1×1) stats.
    pub main_stats: LaunchStats,
    /// Aggregate over all launched parallel kernels.
    pub kernel_stats: LaunchStats,
    pub kernel_launches: u64,
    pub grid: (usize, usize),
    /// Engine counters (lanes/workers/batches, launch-executor queue
    /// depth and latency). `None` only for hand-built metrics.
    pub rpc_engine: Option<EngineSnapshot>,
    /// HostEnv file-table shard counters (opens per table class, lock
    /// contention).
    pub host_io: HostIoSnapshot,
    /// Per-pass wall time + summaries from the compile that produced the
    /// executed module (empty for hand-built metrics).
    pub passes: Vec<PassTiming>,
    /// Runtime hits on symbols `libcres` classified unresolved (each
    /// degraded to a no-op).
    pub unresolved_calls: u64,
    /// Format operands the `constfold` pass folded to constant globals
    /// at compile time (each widens the §3.2 precise-intent path).
    pub folded_formats: u64,
    /// Arguments `rpcgen` lowered with the pessimistic read-write
    /// (copy both ways) buffer intent — the fig07 format corpus asserts
    /// the folded pipeline yields strictly fewer of these.
    pub rpc_rw_intents: u64,
    /// Functions the `lower` pass compiled to the register-file form
    /// (the executor the interpreter prefers); 0 = tree-walk run.
    pub lowered_fns: u64,
    /// Superinstructions the `fuse` pass created across the module.
    pub fused_instrs: u64,
    /// Functions the `bytecode` pass flattened to the linear bytecode
    /// form (the executor the interpreter prefers over the register
    /// core); 0 = register-core or tree-walk run.
    pub bytecode_fns: u64,
    /// Client-measured RPC round-trip latency over every callee
    /// (claim → doorbell; the flat `real_ns` sum decomposed into a
    /// log-bucketed histogram with percentiles).
    pub rpc_round_trip: HistSnapshot,
    /// Per-callee RPC round-trip histograms, keyed by registered
    /// landing-pad name (sorted; unresolvable ids keyed `callee N`).
    pub rpc_per_callee: Vec<(String, HistSnapshot)>,
    /// Launch-executor queue wait (enqueue → an executor thread picks
    /// the job up) as a histogram; the flat `launch_wait_ns` total in
    /// [`EngineSnapshot`] is this histogram's sum.
    pub launch_queue_wait: HistSnapshot,
    /// Launch-executor wrapper run time as a histogram (flat total:
    /// `launch_run_ns`).
    pub launch_run: HistSnapshot,
    /// Time landing pads spent blocked on contended `HostEnv` locks
    /// (open-handle tables + content-map shards). Empty while
    /// `host_io.lock_contention` and `host_io.content_contention` are 0.
    pub host_io_lock_wait: HistSnapshot,
    /// Regions the opt-in `advise` pass scored at compile time; 0 for
    /// the default pipeline (the advisor never runs implicitly).
    pub advice_regions: u64,
    /// Located diagnostics the opt-in `lint` pass emitted; 0 for the
    /// default pipeline.
    pub lint_diags: u64,
    /// Leveled warn-once diagnostics this run raised (unresolved
    /// symbols, format degradations), with per-code occurrence counts.
    pub events: Vec<EventRecord>,
    /// Spans the ring recorder dropped (oldest-first) because a shard
    /// hit capacity; 0 whenever tracing is off.
    pub spans_dropped: u64,
}

impl RunMetrics {
    /// Modeled A100 time: serial main kernel (1 thread) + parallel kernels
    /// (whole grid) + one kernel-split RPC per launch.
    pub fn modeled_device_ns(&self) -> f64 {
        let serial = a100::device_time(&self.main_stats, 1, 1).total_ns();
        let par = a100::device_time(
            &self.kernel_stats,
            (self.grid.0 * self.grid.1) as u64,
            self.kernel_launches.max(1),
        )
        .total_ns();
        serial + par + self.kernel_launches as f64 * a100::KERNEL_SPLIT_RPC_NS
    }

    /// Total middle-end wall time across the recorded passes.
    pub fn compile_ns(&self) -> f64 {
        self.passes.iter().map(|t| t.wall_ns).sum()
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "exit={} wall={} modeled_device={} launches={} grid={}x{} rpcs={}",
            self.exit_code,
            crate::util::fmt_ns(self.wall_ns),
            crate::util::fmt_ns(self.modeled_device_ns()),
            self.kernel_launches,
            self.grid.0,
            self.grid.1,
            self.main_stats.rpc_calls + self.kernel_stats.rpc_calls,
        );
        if !self.passes.is_empty() {
            s.push_str(&format!(
                " compile={} passes=[{}]",
                crate::util::fmt_ns(self.compile_ns()),
                self.passes
                    .iter()
                    .map(|t| format!("{}:{}", t.pass, crate::util::fmt_ns(t.wall_ns)))
                    .collect::<Vec<_>>()
                    .join(","),
            ));
        }
        if self.unresolved_calls > 0 {
            s.push_str(&format!(" unresolved_calls={}", self.unresolved_calls));
        }
        if self.folded_formats > 0 {
            s.push_str(&format!(" folded_formats={}", self.folded_formats));
        }
        if self.rpc_rw_intents > 0 {
            s.push_str(&format!(" rw_intents={}", self.rpc_rw_intents));
        }
        if self.lowered_fns > 0 {
            s.push_str(&format!(
                " register_core fns={} fused={}",
                self.lowered_fns, self.fused_instrs
            ));
        }
        if self.bytecode_fns > 0 {
            s.push_str(&format!(" bytecode fns={}", self.bytecode_fns));
        }
        if self.advice_regions > 0 {
            s.push_str(&format!(" advice_regions={}", self.advice_regions));
        }
        if self.lint_diags > 0 {
            s.push_str(&format!(" lint_diags={}", self.lint_diags));
        }
        if let Some(e) = &self.rpc_engine {
            s.push(' ');
            s.push_str(&e.summary());
        }
        if self.host_io.shards > 0 || self.host_io.content_contention > 0 {
            s.push_str(&format!(
                " host_io shards={} opens={}+{} contention={} files_contention={}/{}shards",
                self.host_io.shards,
                self.host_io.sharded_opens,
                self.host_io.shared_opens,
                self.host_io.lock_contention,
                self.host_io.content_contention,
                self.host_io.content_shards,
            ));
        }
        if self.host_io.batched_writes > 0 {
            s.push_str(&format!(" batched_writes={}", self.host_io.batched_writes));
        }
        if self.host_io.batched_reads > 0 {
            s.push_str(&format!(" batched_reads={}", self.host_io.batched_reads));
        }
        if self.host_io.batched_cross_callee > 0 {
            s.push_str(&format!(" batched_cross_callee={}", self.host_io.batched_cross_callee));
        }
        if self.host_io.poison_recoveries > 0 {
            s.push_str(&format!(" poison_recoveries={}", self.host_io.poison_recoveries));
        }
        if !self.rpc_round_trip.is_empty() {
            s.push_str(&format!(" rpc_rt[{}]", self.rpc_round_trip.summary()));
        }
        if !self.launch_queue_wait.is_empty() {
            s.push_str(&format!(" launch_wait[{}]", self.launch_queue_wait.summary()));
        }
        if !self.host_io_lock_wait.is_empty() {
            s.push_str(&format!(" io_lock_wait[{}]", self.host_io_lock_wait.summary()));
        }
        for e in &self.events {
            s.push_str(&format!(" event[{}:{}]={}", e.level.as_str(), e.code, e.count));
        }
        if self.spans_dropped > 0 {
            s.push_str(&format!(" spans_dropped={}", self.spans_dropped));
        }
        s
    }

    /// Machine-readable report (mirrors `summary()`, adds the per-pass
    /// breakdown for trajectory tracking).
    pub fn to_json(&self) -> Json {
        let passes: Vec<Json> = self
            .passes
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("pass", Json::str(t.pass.as_str())),
                    ("wall_ns", Json::num(t.wall_ns)),
                    ("changed", Json::num(if t.changed { 1.0 } else { 0.0 })),
                    ("summary", Json::str(t.summary.as_str())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("session", Json::uint(self.session)),
            ("exit_code", Json::num(self.exit_code as f64)),
            ("wall_ns", Json::num(self.wall_ns)),
            ("modeled_device_ns", Json::num(self.modeled_device_ns())),
            ("compile_ns", Json::num(self.compile_ns())),
            ("kernel_launches", Json::num(self.kernel_launches as f64)),
            ("teams", Json::num(self.grid.0 as f64)),
            ("threads_per_team", Json::num(self.grid.1 as f64)),
            (
                "rpcs",
                Json::num((self.main_stats.rpc_calls + self.kernel_stats.rpc_calls) as f64),
            ),
            ("unresolved_calls", Json::num(self.unresolved_calls as f64)),
            ("folded_formats", Json::num(self.folded_formats as f64)),
            ("rpc_rw_intents", Json::num(self.rpc_rw_intents as f64)),
            ("lowered_fns", Json::num(self.lowered_fns as f64)),
            ("fused_instrs", Json::num(self.fused_instrs as f64)),
            ("bytecode_fns", Json::num(self.bytecode_fns as f64)),
            ("advice_regions", Json::num(self.advice_regions as f64)),
            ("lint_diags", Json::num(self.lint_diags as f64)),
            ("batched_writes", Json::num(self.host_io.batched_writes as f64)),
            ("batched_reads", Json::num(self.host_io.batched_reads as f64)),
            ("batched_cross_callee", Json::num(self.host_io.batched_cross_callee as f64)),
            ("poison_recoveries", Json::num(self.host_io.poison_recoveries as f64)),
            ("passes", Json::Arr(passes)),
            (
                "hists",
                Json::obj(vec![
                    ("rpc_round_trip", self.rpc_round_trip.to_json()),
                    ("launch_queue_wait", self.launch_queue_wait.to_json()),
                    ("launch_run", self.launch_run.to_json()),
                    ("host_io_lock_wait", self.host_io_lock_wait.to_json()),
                ]),
            ),
            (
                "rpc_per_callee",
                Json::Obj(
                    self.rpc_per_callee
                        .iter()
                        .map(|(name, h)| (name.clone(), h.to_json()))
                        .collect(),
                ),
            ),
            (
                "events",
                Json::Arr(
                    self.events
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("level", Json::str(e.level.as_str())),
                                ("code", Json::str(e.code.as_str())),
                                ("detail", Json::str(e.detail.as_str())),
                                ("count", Json::num(e.count as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("spans_dropped", Json::num(self.spans_dropped as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> RunMetrics {
        RunMetrics {
            session: 0,
            exit_code: 0,
            wall_ns: 0.0,
            main_stats: LaunchStats::default(),
            kernel_stats: LaunchStats::default(),
            kernel_launches: 0,
            grid: (1, 1),
            rpc_engine: None,
            host_io: HostIoSnapshot::default(),
            passes: Vec::new(),
            unresolved_calls: 0,
            folded_formats: 0,
            rpc_rw_intents: 0,
            lowered_fns: 0,
            fused_instrs: 0,
            bytecode_fns: 0,
            advice_regions: 0,
            lint_diags: 0,
            rpc_round_trip: HistSnapshot::default(),
            rpc_per_callee: Vec::new(),
            launch_queue_wait: HistSnapshot::default(),
            launch_run: HistSnapshot::default(),
            host_io_lock_wait: HistSnapshot::default(),
            events: Vec::new(),
            spans_dropped: 0,
        }
    }

    #[test]
    fn modeled_time_includes_launch_rpc() {
        let m = RunMetrics { kernel_launches: 3, grid: (4, 32), ..base() };
        assert!(m.modeled_device_ns() >= 3.0 * a100::KERNEL_SPLIT_RPC_NS);
        assert!(m.summary().contains("launches=3"));
        assert!(!m.summary().contains("rpc_engine"));
        assert!(!m.summary().contains("host_io"), "unsharded runs stay quiet");
        assert!(!m.summary().contains("passes"), "hand-built metrics carry no pass data");
    }

    #[test]
    fn summary_appends_engine_and_host_io_counters() {
        let m = RunMetrics {
            rpc_engine: Some(EngineSnapshot {
                lanes: 4,
                workers: 2,
                launch_threads: 2,
                launch_slots: 2,
                served: 10,
                batches: 2,
                batched_calls: 6,
                max_batch: 4,
                steals: 1,
                launches: 2,
                launch_queue_depth: 0,
                launch_queue_peak: 1,
                launch_requeues: 0,
                launch_wait_ns: 500,
                launch_run_ns: 1500,
                ring_in_flight: 0,
                ring_peak: 2,
                polls: 100,
                polls_busy: 25,
            }),
            host_io: HostIoSnapshot {
                shards: 4,
                sharded_opens: 7,
                shared_opens: 1,
                lock_contention: 3,
                content_shards: 16,
                content_contention: 5,
                poison_recoveries: 2,
                batched_writes: 9,
                batched_reads: 4,
                batched_cross_callee: 2,
            },
            ..base()
        };
        let s = m.summary();
        assert!(s.contains("rpc_engine lanes=4 workers=2 served=10"));
        assert!(s.contains("occupancy=0.250"));
        assert!(s.contains("launches=2"), "executor counters surface: {s}");
        assert!(s.contains("ring_peak=2/2"), "ring occupancy surfaces: {s}");
        assert!(s.contains("host_io shards=4 opens=7+1 contention=3"), "{s}");
        assert!(s.contains("files_contention=5/16shards"), "content-map counters: {s}");
        assert!(s.contains("batched_writes=9"), "fwrite batch counter surfaces: {s}");
        assert!(s.contains("batched_reads=4"), "fread batch counter surfaces: {s}");
        assert!(s.contains("batched_cross_callee=2"), "cross-callee merges surface: {s}");
        assert!(s.contains("poison_recoveries=2"), "recoveries surface: {s}");
        assert_eq!(m.rpc_engine.unwrap().launch_latency_ns(), 1000.0);
    }

    #[test]
    fn summary_and_json_carry_constfold_and_intent_counters() {
        let m = RunMetrics { folded_formats: 2, rpc_rw_intents: 3, ..base() };
        let s = m.summary();
        assert!(s.contains("folded_formats=2"), "{s}");
        assert!(s.contains("rw_intents=3"), "{s}");
        let j = m.to_json().to_string();
        assert!(j.contains("\"folded_formats\":2"), "{j}");
        assert!(j.contains("\"rpc_rw_intents\":3"), "{j}");
        assert!(j.contains("\"batched_writes\":0"), "{j}");
        assert!(j.contains("\"batched_reads\":0"), "{j}");
        // Quiet runs keep the summary quiet.
        let quiet = base().summary();
        assert!(!quiet.contains("folded_formats"), "{quiet}");
        assert!(!quiet.contains("poison_recoveries"), "{quiet}");
    }

    #[test]
    fn summary_and_json_carry_register_core_counters() {
        let m = RunMetrics { lowered_fns: 3, fused_instrs: 17, bytecode_fns: 3, ..base() };
        let s = m.summary();
        assert!(s.contains("register_core fns=3 fused=17"), "{s}");
        assert!(s.contains("bytecode fns=3"), "{s}");
        let j = m.to_json().to_string();
        assert!(j.contains("\"lowered_fns\":3"), "{j}");
        assert!(j.contains("\"fused_instrs\":17"), "{j}");
        assert!(j.contains("\"bytecode_fns\":3"), "{j}");
        // A tree-walk run (nothing lowered) stays quiet.
        let quiet = base().summary();
        assert!(!quiet.contains("register_core"), "{quiet}");
        assert!(!quiet.contains("bytecode"), "{quiet}");
    }

    #[test]
    fn summary_and_json_carry_advisor_counters() {
        let m = RunMetrics { advice_regions: 2, lint_diags: 3, ..base() };
        let s = m.summary();
        assert!(s.contains("advice_regions=2"), "{s}");
        assert!(s.contains("lint_diags=3"), "{s}");
        let j = m.to_json().to_string();
        assert!(j.contains("\"advice_regions\":2"), "{j}");
        assert!(j.contains("\"lint_diags\":3"), "{j}");
        // The default pipeline never runs the advisor: quiet summaries.
        let quiet = base().summary();
        assert!(!quiet.contains("advice_regions"), "{quiet}");
        assert!(!quiet.contains("lint_diags"), "{quiet}");
    }

    #[test]
    fn summary_and_json_carry_latency_hists_and_events() {
        use crate::obs::{EventLog, Hist, Level};
        let h = Hist::new();
        for v in [100u64, 200, 400, 800] {
            h.record(v);
        }
        let events = EventLog::default();
        events.emit(Level::Warn, "unresolved-symbol", "frobnicate", "call degraded");
        events.emit(Level::Warn, "unresolved-symbol", "frobnicate", "call degraded");
        let m = RunMetrics {
            rpc_round_trip: h.snapshot(),
            rpc_per_callee: vec![("__printf_cp".into(), h.snapshot())],
            host_io_lock_wait: h.snapshot(),
            events: events.snapshot(),
            spans_dropped: 5,
            ..base()
        };
        let s = m.summary();
        assert!(s.contains("rpc_rt[n=4"), "round-trip hist surfaces: {s}");
        assert!(s.contains("io_lock_wait[n=4"), "lock-wait hist surfaces: {s}");
        assert!(s.contains("event[warn:unresolved-symbol]=2"), "{s}");
        assert!(s.contains("spans_dropped=5"), "{s}");
        let j = m.to_json();
        let rt = j.get("hists").and_then(|h| h.get("rpc_round_trip")).unwrap();
        assert_eq!(rt.get("count").and_then(Json::as_f64), Some(4.0));
        assert!(rt.get("p50_ns").and_then(Json::as_f64).unwrap() >= 100.0);
        assert!(rt.get("p99_ns").and_then(Json::as_f64).unwrap() >= 400.0);
        let pc = j.get("rpc_per_callee").and_then(|p| p.get("__printf_cp")).unwrap();
        assert_eq!(pc.get("count").and_then(Json::as_f64), Some(4.0));
        let ev = j.get("events").and_then(Json::as_arr).unwrap();
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].get("count").and_then(Json::as_f64), Some(2.0));
        // Quiet runs add none of it to the summary.
        let quiet = base().summary();
        assert!(!quiet.contains("rpc_rt["), "{quiet}");
        assert!(!quiet.contains("spans_dropped"), "{quiet}");
    }

    #[test]
    fn summary_and_json_carry_pass_timings() {
        let m = RunMetrics {
            passes: vec![
                PassTiming {
                    pass: "libcres".into(),
                    wall_ns: 1000.0,
                    summary: "1 host-rpc".into(),
                    changed: false,
                },
                PassTiming {
                    pass: "rpcgen".into(),
                    wall_ns: 2000.0,
                    summary: "1 call sites rewritten".into(),
                    changed: true,
                },
            ],
            unresolved_calls: 3,
            ..base()
        };
        assert_eq!(m.compile_ns(), 3000.0);
        let s = m.summary();
        assert!(s.contains("passes=[libcres:"), "{s}");
        assert!(s.contains("unresolved_calls=3"), "{s}");
        let j = m.to_json().to_string();
        assert!(j.contains("\"compile_ns\""), "{j}");
        assert!(j.contains("\"rpcgen\""), "{j}");
        assert!(j.contains("\"unresolved_calls\":3"), "{j}");
    }
}
