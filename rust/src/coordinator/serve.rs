//! The resident serving daemon: many sessions, one compile.
//!
//! The one-shot [`GpuFirstSession`] pays full compile + loader startup
//! per run — one module per process. [`ServeDaemon`] keeps the process
//! resident and serves *sessions* instead:
//!
//! * **Compiled-module cache** — modules are keyed by a content hash
//!   over source text + pipeline shape. The first session on a key runs
//!   the full `PassManager` pipeline (under the cache lock, so a burst
//!   of identical opens compiles exactly once); every later session
//!   clones the cached lowered module and reports **zero pipeline
//!   passes run** (its `RunMetrics.passes` is empty while the fold /
//!   intent / lowering counters still describe the cached compile).
//! * **One shared landing-pad registry** — pads registered during the
//!   original compile serve cache-hit sessions that never run the
//!   pipeline (`WrapperRegistry::register` is idempotent by mangled
//!   name, so repeat compiles are harmless).
//! * **Admission control** — at most `max_sessions` sessions run
//!   concurrently; each gets a fair share of the daemon's engine shape
//!   (`--rpc-lanes/workers/launch-slots` divided across sessions, never
//!   below 1). Beyond that, up to `queue_depth` opens **block** in FIFO
//!   fairness; past the queue, opens are rejected with
//!   [`ServeError::Saturated`] — bounded backpressure instead of
//!   oversubscribing the managed segment.
//! * **Per-tenant accounting** — admitted/queued/rejected/run counters
//!   per tenant name, so a noisy tenant is visible in the snapshot.
//! * **Per-session attribution** — every session's id is the
//!   interpreter's launch-session mint (the same number that keys its
//!   home launch-ring slot), daemon-wide queue-wait and session-latency
//!   histograms feed the serving benchmark's p50/p99, and a
//!   daemon-owned [`SpanRecorder`] records `SpanKind::Session` spans
//!   (queue-wait / compile / cache-hit / run) with the session id as
//!   the track, one timeline row per session in the exported trace.
//!
//! Each session still owns its *device*: its own simulated GPU memory,
//! RPC engine and [`crate::rpc::HostEnv`] (stdout/stderr and file tables never
//! bleed across sessions). What the daemon shares is the compiled
//! artifact and the pad registry — the HetGPU-style "compiled artifacts
//! are reusable units" argument applied to serving.

use super::config::Config;
use super::loader::GpuFirstSession;
use super::metrics::RunMetrics;
use crate::ir::parser::parse_module;
use crate::ir::Module;
use crate::obs::{Hist, HistSnapshot, SpanKind, SpanRecorder};
use crate::rpc::wrappers::register_common;
use crate::rpc::WrapperRegistry;
use crate::transform::{compile_with_spec, CompileReport, PipelineSpec};
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap};
use std::fmt;
use std::sync::{Arc, Condvar, Mutex, PoisonError};

/// Why the daemon refused (or failed) to open a session.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Source text failed to parse.
    Parse(String),
    /// The pipeline rejected the module (verifier or pass errors).
    Compile(String),
    /// Admission control: `max_sessions` running and the wait queue is
    /// full. Back off and retry.
    Saturated { active: usize, queued: usize },
    /// The daemon is shutting down; no new sessions.
    Closed,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Parse(e) => write!(f, "parse failed: {e}"),
            ServeError::Compile(e) => write!(f, "{e}"),
            ServeError::Saturated { active, queued } => write!(
                f,
                "daemon saturated: {active} active session(s) and {queued} queued; retry later"
            ),
            ServeError::Closed => write!(f, "daemon is shut down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Daemon shape: the engine budget to divide across sessions plus the
/// admission bounds.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Per-daemon budget: memory sizes, grid shape, and the engine
    /// knobs (`rpc_lanes`/`rpc_workers`/`rpc_launch_threads`/
    /// `rpc_launch_slots`) that [`ServeDaemon::session_config`] divides
    /// across `max_sessions` concurrent sessions.
    pub base: Config,
    /// Concurrent-session cap (each admitted session reserves its own
    /// device arena, so this bounds managed-segment oversubscription).
    pub max_sessions: usize,
    /// Opens allowed to block waiting for a slot before further opens
    /// are rejected with [`ServeError::Saturated`].
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { base: Config::default(), max_sessions: 4, queue_depth: 16 }
    }
}

/// One compiled artifact: the lowered module plus the report the
/// pipeline produced (cloned into every session served from the cache).
struct CachedModule {
    module: Module,
    report: CompileReport,
}

/// Admission state under the daemon's mutex; the condvar wakes FIFO
/// waiters as sessions close.
#[derive(Debug, Default)]
struct Admission {
    active: usize,
    waiting: usize,
    peak_active: usize,
    shutdown: bool,
}

/// Per-tenant fairness counters.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct TenantCounters {
    /// Sessions admitted (immediately or after queueing).
    pub admitted: u64,
    /// Admissions that had to wait in the queue first.
    pub queued: u64,
    /// Opens rejected at the queue bound.
    pub rejected: u64,
    /// Completed `run()` calls across this tenant's sessions.
    pub runs: u64,
}

/// Daemon-wide counters (monotonic; `active` is instantaneous).
#[derive(Debug, Default, Clone)]
pub struct ServeSnapshot {
    pub admitted: u64,
    pub queued: u64,
    pub rejected: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub active: usize,
    /// Opens currently blocked in the admission queue (instantaneous).
    pub waiting: usize,
    pub peak_active: usize,
    /// Wall latency of every completed session run.
    pub session_latency: HistSnapshot,
    /// Admission queue wait of every admitted session (0 entries while
    /// the daemon never saturated).
    pub queue_wait: HistSnapshot,
    pub tenants: Vec<(String, TenantCounters)>,
}

impl ServeSnapshot {
    /// Machine-readable form (the serving benchmark embeds it per load
    /// level).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("admitted", Json::uint(self.admitted)),
            ("queued", Json::uint(self.queued)),
            ("rejected", Json::uint(self.rejected)),
            ("cache_hits", Json::uint(self.cache_hits)),
            ("cache_misses", Json::uint(self.cache_misses)),
            ("active", Json::uint(self.active as u64)),
            ("waiting", Json::uint(self.waiting as u64)),
            ("peak_active", Json::uint(self.peak_active as u64)),
            ("session_latency", self.session_latency.to_json()),
            ("queue_wait", self.queue_wait.to_json()),
            (
                "tenants",
                Json::Obj(
                    self.tenants
                        .iter()
                        .map(|(name, t)| {
                            (
                                name.clone(),
                                Json::obj(vec![
                                    ("admitted", Json::uint(t.admitted)),
                                    ("queued", Json::uint(t.queued)),
                                    ("rejected", Json::uint(t.rejected)),
                                    ("runs", Json::uint(t.runs)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn summary(&self) -> String {
        format!(
            "admitted={} queued={} rejected={} cache={}hit/{}miss active={} peak={}",
            self.admitted,
            self.queued,
            self.rejected,
            self.cache_hits,
            self.cache_misses,
            self.active,
            self.peak_active,
        )
    }
}

#[derive(Debug, Default)]
struct Counters {
    admitted: u64,
    queued: u64,
    rejected: u64,
    cache_hits: u64,
    cache_misses: u64,
    tenants: BTreeMap<String, TenantCounters>,
}

/// The resident multi-tenant serving daemon (see module docs).
pub struct ServeDaemon {
    cfg: ServeConfig,
    registry: Arc<WrapperRegistry>,
    cache: Mutex<HashMap<u64, Arc<CachedModule>>>,
    adm: Mutex<Admission>,
    adm_cv: Condvar,
    counters: Mutex<Counters>,
    /// Daemon-timeline spans (`SpanKind::Session`, track = session id).
    /// Enabled when `cfg.base.trace` is set.
    pub spans: SpanRecorder,
    session_latency: Hist,
    queue_wait: Hist,
}

impl ServeDaemon {
    pub fn start(cfg: ServeConfig) -> Self {
        let registry = Arc::new(WrapperRegistry::new());
        register_common(&registry);
        let spans = SpanRecorder::new();
        if cfg.base.trace {
            spans.enable();
        }
        Self {
            cfg,
            registry,
            cache: Mutex::new(HashMap::new()),
            adm: Mutex::new(Admission::default()),
            adm_cv: Condvar::new(),
            counters: Mutex::new(Counters::default()),
            spans,
            session_latency: Hist::new(),
            queue_wait: Hist::new(),
        }
    }

    /// The per-session configuration: the daemon's base with the engine
    /// knobs divided fairly across `max_sessions` (never below 1, so a
    /// wide daemon degrades to per-session legacy shapes rather than
    /// zero-width engines).
    pub fn session_config(&self) -> Config {
        let n = self.cfg.max_sessions.max(1);
        let share = |v: usize| (v / n).max(1);
        Config {
            rpc_lanes: share(self.cfg.base.rpc_lanes),
            rpc_workers: share(self.cfg.base.rpc_workers),
            rpc_launch_threads: share(self.cfg.base.rpc_launch_threads),
            rpc_launch_slots: share(self.cfg.base.rpc_launch_slots),
            ..self.cfg.base
        }
    }

    /// Open a session on `source` under the default pipeline.
    pub fn open_session(
        &self,
        tenant: &str,
        source: &str,
    ) -> Result<SessionHandle<'_>, ServeError> {
        self.open_session_spec(tenant, source, &PipelineSpec::default())
    }

    /// Open a session: admit (block in the bounded queue if the daemon
    /// is at `max_sessions`; reject past `queue_depth`), then serve the
    /// compiled module from the cache — compiling it first iff this is
    /// the first session on its `(source, pipeline)` content hash.
    pub fn open_session_spec(
        &self,
        tenant: &str,
        source: &str,
        spec: &PipelineSpec,
    ) -> Result<SessionHandle<'_>, ServeError> {
        let t_open = self.spans.start();
        let (waited_ns, was_queued) = self.admit(tenant)?;

        // Compile-or-cache. Errors release the admission slot.
        let t_compile = self.spans.start();
        let (entry, hit) = match self.lookup_or_compile(source, spec) {
            Ok(v) => v,
            Err(e) => {
                self.release();
                return Err(e);
            }
        };

        let mut inner =
            GpuFirstSession::start_with_registry(self.session_config(), Arc::clone(&self.registry));
        let mut report = entry.report.clone();
        if hit {
            // A cache hit runs zero passes: the timing section empties
            // while the compile-derived counters (folds, intents,
            // lowered fns) — and the advisor's `advise`/`diags`
            // sections, when the cached pipeline included those opt-in
            // passes — keep describing the artifact being served.
            report.timings.clear();
        }
        inner.report = Some(report);
        inner.load(entry.module.clone());
        let id = inner.session_id();

        // Attribute the open on the session's own timeline row (the id
        // exists only now, so the spans are recorded retroactively with
        // the measured starts).
        if let Some(open_ns) = t_open {
            self.spans.record("queue-wait", SpanKind::Session, id, open_ns, waited_ns);
        }
        if let Some(compile_ns) = t_compile {
            let dur = self.spans.now_ns().saturating_sub(compile_ns);
            let name = if hit { "cache-hit" } else { "compile" };
            self.spans.record(name, SpanKind::Session, id, compile_ns, dur);
        }
        if was_queued {
            self.queue_wait.record(waited_ns);
        }

        Ok(SessionHandle {
            daemon: self,
            inner,
            id,
            tenant: tenant.to_string(),
            cache_hit: hit,
            last: None,
            released: false,
        })
    }

    /// Block until a session slot frees (FIFO via the condvar), honoring
    /// the queue bound. Returns (queue wait ns, whether it queued).
    fn admit(&self, tenant: &str) -> Result<(u64, bool), ServeError> {
        let t0 = std::time::Instant::now();
        let mut adm = self.adm.lock().unwrap_or_else(PoisonError::into_inner);
        if adm.shutdown {
            return Err(ServeError::Closed);
        }
        let mut was_queued = false;
        if adm.active >= self.cfg.max_sessions {
            if adm.waiting >= self.cfg.queue_depth {
                let (active, queued) = (adm.active, adm.waiting);
                drop(adm);
                let mut c = self.counters.lock().unwrap_or_else(PoisonError::into_inner);
                c.rejected += 1;
                c.tenants.entry(tenant.to_string()).or_default().rejected += 1;
                return Err(ServeError::Saturated { active, queued });
            }
            was_queued = true;
            adm.waiting += 1;
            while adm.active >= self.cfg.max_sessions && !adm.shutdown {
                adm = self.adm_cv.wait(adm).unwrap_or_else(PoisonError::into_inner);
            }
            adm.waiting -= 1;
            if adm.shutdown {
                drop(adm);
                self.adm_cv.notify_one();
                return Err(ServeError::Closed);
            }
        }
        adm.active += 1;
        adm.peak_active = adm.peak_active.max(adm.active);
        drop(adm);
        let mut c = self.counters.lock().unwrap_or_else(PoisonError::into_inner);
        c.admitted += 1;
        let t = c.tenants.entry(tenant.to_string()).or_default();
        t.admitted += 1;
        if was_queued {
            c.queued += 1;
            t.queued += 1;
        }
        Ok((t0.elapsed().as_nanos() as u64, was_queued))
    }

    /// Free one session slot and wake the next waiter.
    fn release(&self) {
        let mut adm = self.adm.lock().unwrap_or_else(PoisonError::into_inner);
        adm.active = adm.active.saturating_sub(1);
        drop(adm);
        self.adm_cv.notify_one();
    }

    /// Serve the compiled module for `(source, spec)` from the cache,
    /// compiling under the cache lock on the first request — "compile
    /// once" even when identical opens race.
    fn lookup_or_compile(
        &self,
        source: &str,
        spec: &PipelineSpec,
    ) -> Result<(Arc<CachedModule>, bool), ServeError> {
        let key = content_key(source, &spec.names().join(","));
        let mut cache = self.cache.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(entry) = cache.get(&key) {
            let entry = Arc::clone(entry);
            drop(cache);
            let mut c = self.counters.lock().unwrap_or_else(PoisonError::into_inner);
            c.cache_hits += 1;
            return Ok((entry, true));
        }
        let mut module = parse_module(source).map_err(ServeError::Parse)?;
        let report = compile_with_spec(&mut module, &self.registry, spec).map_err(|errs| {
            ServeError::Compile(format!("compile failed:\n  {}", errs.join("\n  ")))
        })?;
        let entry = Arc::new(CachedModule { module, report });
        cache.insert(key, Arc::clone(&entry));
        drop(cache);
        let mut c = self.counters.lock().unwrap_or_else(PoisonError::into_inner);
        c.cache_misses += 1;
        Ok((entry, false))
    }

    /// Compiled modules currently cached.
    pub fn cached_modules(&self) -> usize {
        self.cache.lock().unwrap_or_else(PoisonError::into_inner).len()
    }

    /// Sessions currently running.
    pub fn active_sessions(&self) -> usize {
        self.adm.lock().unwrap_or_else(PoisonError::into_inner).active
    }

    /// Daemon-wide counters + latency histograms + per-tenant table.
    pub fn snapshot(&self) -> ServeSnapshot {
        let adm = self.adm.lock().unwrap_or_else(PoisonError::into_inner);
        let (active, waiting, peak_active) = (adm.active, adm.waiting, adm.peak_active);
        drop(adm);
        let c = self.counters.lock().unwrap_or_else(PoisonError::into_inner);
        ServeSnapshot {
            admitted: c.admitted,
            queued: c.queued,
            rejected: c.rejected,
            cache_hits: c.cache_hits,
            cache_misses: c.cache_misses,
            active,
            waiting,
            peak_active,
            session_latency: self.session_latency.snapshot(),
            queue_wait: self.queue_wait.snapshot(),
            tenants: c.tenants.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
        }
    }

    /// Refuse new sessions and wake every queued open with
    /// [`ServeError::Closed`]. Already-open handles keep working until
    /// closed/dropped.
    pub fn shutdown(&self) {
        let mut adm = self.adm.lock().unwrap_or_else(PoisonError::into_inner);
        adm.shutdown = true;
        drop(adm);
        self.adm_cv.notify_all();
    }
}

/// A running session inside the daemon: its own device, engine and
/// host environment, sharing only the compiled artifact and the pad
/// registry. Dropping (or [`SessionHandle::close`]) releases the
/// admission slot and stops the session's engine.
pub struct SessionHandle<'d> {
    daemon: &'d ServeDaemon,
    inner: GpuFirstSession,
    id: u64,
    tenant: String,
    cache_hit: bool,
    last: Option<RunMetrics>,
    released: bool,
}

impl SessionHandle<'_> {
    /// The launch-session id (also `RunMetrics.session` of every run).
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// Was this session served from the compiled-module cache?
    pub fn cache_hit(&self) -> bool {
        self.cache_hit
    }

    /// The underlying one-shot session (host environment, device,
    /// compile report) for callers needing the legacy surface.
    pub fn session(&self) -> &GpuFirstSession {
        &self.inner
    }

    /// This session's captured stdout so far.
    pub fn stdout_string(&self) -> String {
        self.inner.host.stdout_string()
    }

    /// Run the loaded program (repeatable: the module stays loaded), and
    /// feed daemon-side accounting (session-latency histogram, run
    /// span, per-tenant run counter).
    pub fn run(&mut self, argv: &[i64]) -> (i64, RunMetrics) {
        let t0 = self.daemon.spans.start();
        let (ret, metrics) = self.inner.run(argv);
        self.daemon.session_latency.record(metrics.wall_ns as u64);
        self.daemon.spans.finish(t0, "run", SpanKind::Session, self.id);
        let mut c = self.daemon.counters.lock().unwrap_or_else(PoisonError::into_inner);
        c.tenants.entry(self.tenant.clone()).or_default().runs += 1;
        drop(c);
        self.last = Some(metrics.clone());
        (ret, metrics)
    }

    /// Metrics of the most recent [`SessionHandle::run`].
    pub fn metrics(&self) -> Option<&RunMetrics> {
        self.last.as_ref()
    }

    /// Close the session: stop its engine and release the admission
    /// slot (equivalent to dropping, but explicit at call sites).
    pub fn close(self) {}
}

impl Drop for SessionHandle<'_> {
    fn drop(&mut self) {
        if !self.released {
            self.released = true;
            self.daemon.release();
        }
    }
}

/// FNV-1a 64 over source text and pipeline shape — the module cache
/// key. A NUL joins the parts so `("a", "b,c")` and `("ab", ",c")`
/// never collide by concatenation.
fn content_key(source: &str, pipeline: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in source.bytes().chain(std::iter::once(0)).chain(pipeline.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::memory::MemConfig;

    const HELLO: &str = r#"
global @fmt const 7 "n=%d\n"

func @main(%n: i64) -> i64 {
  call printf(@fmt, %n)
  return %n
}
"#;

    fn small_serve(max_sessions: usize, queue_depth: usize) -> ServeConfig {
        let base = Config {
            mem: MemConfig::small(),
            teams: 2,
            threads_per_team: 16,
            ..Default::default()
        };
        ServeConfig { base, max_sessions, queue_depth }
    }

    #[test]
    fn second_session_hits_the_cache_and_runs_no_passes() {
        let daemon = ServeDaemon::start(small_serve(2, 2));
        let mut s1 = daemon.open_session("a", HELLO).unwrap();
        assert!(!s1.cache_hit());
        let (ret, m1) = s1.run(&[7]);
        assert_eq!(ret, 7);
        assert!(!m1.passes.is_empty(), "first session compiled");
        assert_eq!(s1.stdout_string(), "n=7\n");
        s1.close();

        let mut s2 = daemon.open_session("a", HELLO).unwrap();
        assert!(s2.cache_hit());
        let (ret, m2) = s2.run(&[9]);
        assert_eq!(ret, 9);
        assert!(m2.passes.is_empty(), "cache hit ran zero pipeline passes");
        assert_eq!(m2.lowered_fns, m1.lowered_fns, "cached compile counters survive");
        assert_eq!(s2.stdout_string(), "n=9\n", "fresh host env per session");
        assert_ne!(m1.session, m2.session, "distinct session ids");
        s2.close();

        let snap = daemon.snapshot();
        assert_eq!((snap.cache_hits, snap.cache_misses), (1, 1));
        assert_eq!(daemon.cached_modules(), 1);
        assert_eq!(snap.admitted, 2);
        assert_eq!(snap.rejected, 0);
        assert_eq!(snap.session_latency.count, 2);
    }

    #[test]
    fn saturation_rejects_past_the_queue_bound() {
        // max_sessions=1, queue_depth=0: the second concurrent open must
        // reject immediately.
        let daemon = ServeDaemon::start(small_serve(1, 0));
        let s1 = daemon.open_session("a", HELLO).unwrap();
        let err = daemon.open_session("b", HELLO).unwrap_err();
        assert_eq!(err, ServeError::Saturated { active: 1, queued: 0 });
        drop(s1);
        // The slot freed: the same open now succeeds.
        let s2 = daemon.open_session("b", HELLO).unwrap();
        assert!(s2.cache_hit(), "compile survived the rejected open");
        drop(s2);
        let snap = daemon.snapshot();
        assert_eq!(snap.rejected, 1);
        let b = snap.tenants.iter().find(|(n, _)| n == "b").unwrap();
        assert_eq!(b.1.rejected, 1);
        assert_eq!(b.1.admitted, 1);
    }

    #[test]
    fn queued_open_blocks_until_a_slot_frees() {
        let daemon = Arc::new(ServeDaemon::start(small_serve(1, 4)));
        let s1 = daemon.open_session("a", HELLO).unwrap();
        let d = Arc::clone(&daemon);
        let waiter = std::thread::spawn(move || {
            let mut s = d.open_session("b", HELLO).unwrap();
            let (ret, _) = s.run(&[3]);
            ret
        });
        // Give the waiter time to park in the queue, then free the slot.
        while daemon.snapshot().waiting == 0 {
            std::thread::yield_now();
        }
        drop(s1);
        assert_eq!(waiter.join().unwrap(), 3);
        let snap = daemon.snapshot();
        assert_eq!(snap.admitted, 2);
        assert_eq!(snap.queued, 1);
        assert_eq!(snap.queue_wait.count, 1, "queue wait recorded for the queued open");
        assert_eq!(snap.peak_active, 1);
    }

    #[test]
    fn shutdown_refuses_new_sessions_and_wakes_waiters() {
        let daemon = ServeDaemon::start(small_serve(1, 2));
        daemon.shutdown();
        assert_eq!(daemon.open_session("a", HELLO).unwrap_err(), ServeError::Closed);
    }

    #[test]
    fn engine_budget_divides_across_sessions() {
        let base = Config {
            mem: MemConfig::small(),
            teams: 2,
            threads_per_team: 16,
            rpc_lanes: 8,
            rpc_workers: 4,
            rpc_launch_threads: 2,
            rpc_launch_slots: 4,
            ..Default::default()
        };
        let daemon = ServeDaemon::start(ServeConfig { base, max_sessions: 4, queue_depth: 0 });
        let per = daemon.session_config();
        assert_eq!(per.rpc_lanes, 2);
        assert_eq!(per.rpc_workers, 1);
        assert_eq!(per.rpc_launch_threads, 1, "never below 1");
        assert_eq!(per.rpc_launch_slots, 1);
        // A daemon narrower than its session cap degrades to legacy
        // per-session shapes.
        let daemon = ServeDaemon::start(small_serve(8, 0));
        assert!(daemon.session_config().legacy_rpc());
    }

    #[test]
    fn bad_source_and_bad_module_release_the_slot() {
        let daemon = ServeDaemon::start(small_serve(1, 0));
        let err = daemon.open_session("a", "func @broken(").unwrap_err();
        assert!(matches!(err, ServeError::Parse(_)), "{err:?}");
        // The failed open released its slot: a good open succeeds.
        let s = daemon.open_session("a", HELLO).unwrap();
        assert_eq!(daemon.active_sessions(), 1);
        drop(s);
        assert_eq!(daemon.active_sessions(), 0);
    }

    #[test]
    fn serve_snapshot_json_uses_the_shared_emitter() {
        let daemon = ServeDaemon::start(small_serve(2, 2));
        let mut s = daemon.open_session("tenant-x", HELLO).unwrap();
        s.run(&[1]);
        s.close();
        let snap = daemon.snapshot();
        let j = snap.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("admitted").and_then(Json::as_f64), Some(1.0));
        assert_eq!(parsed.get("cache_misses").and_then(Json::as_f64), Some(1.0));
        let t = parsed.get("tenants").unwrap().get("tenant-x").unwrap();
        assert_eq!(t.get("runs").and_then(Json::as_f64), Some(1.0));
        assert!(snap.summary().contains("admitted=1"));
    }

    #[test]
    fn trace_enabled_daemon_records_session_spans() {
        let mut cfg = small_serve(2, 2);
        cfg.base.trace = true;
        let daemon = ServeDaemon::start(cfg);
        let mut s = daemon.open_session("a", HELLO).unwrap();
        let id = s.id();
        s.run(&[1]);
        s.close();
        let spans = daemon.spans.snapshot();
        let names: Vec<&str> = spans
            .iter()
            .filter(|sp| sp.kind == SpanKind::Session && sp.track == id)
            .map(|sp| sp.name.as_str())
            .collect();
        assert!(names.contains(&"queue-wait"), "{names:?}");
        assert!(names.contains(&"compile"), "{names:?}");
        assert!(names.contains(&"run"), "{names:?}");
        // A second session on the same module records a cache-hit span.
        let mut s2 = daemon.open_session("a", HELLO).unwrap();
        let id2 = s2.id();
        s2.run(&[2]);
        s2.close();
        let spans = daemon.spans.snapshot();
        assert!(spans.iter().any(|sp| sp.track == id2 && sp.name == "cache-hit"));
    }

    #[test]
    fn content_key_separates_source_and_pipeline() {
        assert_ne!(content_key("a", "b,c"), content_key("ab", ",c"));
        assert_ne!(content_key(HELLO, "default"), content_key(HELLO, "libcres,rpcgen"));
        assert_eq!(content_key(HELLO, "default"), content_key(HELLO, "default"));
    }
}
