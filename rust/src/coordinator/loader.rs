//! The GPU First session: compile → load → run (paper Fig. 1 & Fig. 2).
//!
//! "The loader is the entry point for the operating system and responsible
//! to setup the environment on the device": here it creates the simulated
//! device (reserving the RPC mailbox arena), starts the host RPC service
//! — always the worker-pool [`RpcEngine`] with its dedicated launch
//! executor; the paper's `lanes=1, workers=1` shape is the engine's
//! bit-identical degenerate case, now with in-kernel RPCs live —
//! registers the common landing pads (the pass registers
//! call-site-specific ones during compilation), materializes the
//! program, maps `argv` onto the device and transfers control to the
//! user's `main`.

use super::config::Config;
use super::metrics::RunMetrics;
use crate::gpu::grid::Device;
use crate::ir::interp::{ProgramEnv, Value};
use crate::ir::Module;
use crate::rpc::engine::{EngineConfig, RpcEngine};
use crate::rpc::wrappers::register_common;
use crate::rpc::{EngineSnapshot, HostEnv, WrapperRegistry};
use crate::transform::{compile, compile_with_spec, CompileOptions, CompileReport, PipelineSpec};
use std::sync::atomic::Ordering;
use std::sync::Arc;

pub struct GpuFirstSession {
    pub cfg: Config,
    pub device: Arc<Device>,
    pub registry: Arc<WrapperRegistry>,
    pub host: Arc<HostEnv>,
    server: Option<RpcEngine>,
    pub report: Option<CompileReport>,
    pub env: Option<Arc<ProgramEnv>>,
}

impl GpuFirstSession {
    /// Bring up device + host RPC engine + common landing pads.
    pub fn start(cfg: Config) -> Self {
        let registry = Arc::new(WrapperRegistry::new());
        register_common(&registry);
        Self::start_with_registry(cfg, registry)
    }

    /// `start` against a caller-owned landing-pad registry (the serving
    /// daemon shares ONE registry across every session, so pads a
    /// compile registered once serve later cache-hit sessions that
    /// never run the pipeline). The caller is responsible for
    /// [`register_common`]; registration is idempotent by mangled name,
    /// so re-registering across sessions is harmless.
    pub fn start_with_registry(cfg: Config, registry: Arc<WrapperRegistry>) -> Self {
        let arena = cfg.arena();
        let device = Arc::new(Device::with_arena(cfg.mem, cfg.allocator, arena));
        if cfg.trace {
            device.mem.obs.spans.enable();
        }
        // The open-file table shards one-to-one with the lanes serving
        // the pads; a single-lane session keeps the unsharded (legacy
        // fd numbering) shape.
        let host =
            Arc::new(HostEnv::with_shards(if cfg.rpc_lanes > 1 { cfg.rpc_lanes } else { 0 }));
        let server = RpcEngine::start(
            Arc::clone(&device.mem),
            arena,
            Arc::clone(&registry),
            Arc::clone(&host),
            EngineConfig {
                lanes: cfg.rpc_lanes,
                workers: cfg.rpc_workers,
                launch_threads: cfg.rpc_launch_threads,
                launch_slots: cfg.rpc_launch_slots,
                batch: cfg.rpc_batch,
            },
        );
        Self { cfg, device, registry, host, server: Some(server), report: None, env: None }
    }

    /// Engine counters (the engine serves every session).
    pub fn engine_snapshot(&self) -> Option<EngineSnapshot> {
        self.server.as_ref().map(|e| e.metrics.snapshot())
    }

    /// Requests the host service answered so far.
    pub fn rpc_served(&self) -> u64 {
        self.server.as_ref().map_or(0, |e| e.metrics.served.load(Ordering::Relaxed))
    }

    /// Run the compiler pipeline over `module` (in place), registering
    /// landing pads against this session's registry.
    pub fn compile(&mut self, module: &mut Module, opts: CompileOptions) -> Result<(), String> {
        let report = compile(module, &self.registry, opts)
            .map_err(|errs| format!("compile failed:\n  {}", errs.join("\n  ")))?;
        self.record_pass_spans(&report);
        self.report = Some(report);
        Ok(())
    }

    /// `compile` with an explicit pass list (the `--passes` /
    /// `GPU_FIRST_PASSES` override).
    pub fn compile_spec(&mut self, module: &mut Module, spec: &PipelineSpec) -> Result<(), String> {
        let report = compile_with_spec(module, &self.registry, spec)
            .map_err(|errs| format!("compile failed:\n  {}", errs.join("\n  ")))?;
        self.record_pass_spans(&report);
        self.report = Some(report);
        Ok(())
    }

    /// Synthesize back-to-back middle-end spans on the `passes` track
    /// from the report's per-pass wall times (the pass manager already
    /// timed them; the recorder just needs the layout). No-op unless
    /// tracing is enabled.
    fn record_pass_spans(&self, report: &CompileReport) {
        let obs = &self.device.mem.obs;
        if !obs.spans.is_enabled() {
            return;
        }
        let total: u64 = report.timings.iter().map(|t| t.wall_ns as u64).sum();
        let mut start = obs.spans.now_ns().saturating_sub(total);
        for t in &report.timings {
            let dur = t.wall_ns as u64;
            obs.spans.record(&t.pass, crate::obs::SpanKind::Pass, 0, start, dur);
            start += dur;
        }
    }

    /// Materialize the compiled module on the device.
    pub fn load(&mut self, module: Module) {
        let env = ProgramEnv::load_with_grid(
            module,
            Arc::clone(&self.device),
            Arc::clone(&self.registry),
            Arc::clone(&self.host),
            self.cfg.teams,
            self.cfg.threads_per_team,
        );
        self.env = Some(env);
    }

    /// The loaded environment's launch-session id (the interpreter's
    /// process-global mint); 0 before `load()`. The serving daemon's
    /// `SessionHandle::id` is this number.
    pub fn session_id(&self) -> u64 {
        self.env.as_ref().map_or(0, |e| e.launch_session)
    }

    /// Map argv to the device and invoke the user `main` on the GPU.
    pub fn run(&self, argv: &[i64]) -> (i64, RunMetrics) {
        let env = self.env.as_ref().expect("load() before run()");
        let args: Vec<Value> = argv.iter().map(|&v| Value::I(v)).collect();
        let t0 = std::time::Instant::now();
        let (ret, main_stats) = env.run_main(&args);
        let wall_ns = t0.elapsed().as_nanos() as f64;
        let kernel_stats = *env.kernel_stats.lock().unwrap();
        let obs = &self.device.mem.obs;
        let rpc_per_callee: Vec<(String, crate::obs::HistSnapshot)> = obs
            .per_callee_rpc()
            .into_iter()
            .map(|(id, h)| {
                let name =
                    self.registry.name_of(id).unwrap_or_else(|| format!("callee {id}"));
                (name, h)
            })
            .collect();
        let metrics = RunMetrics {
            session: env.launch_session,
            exit_code: ret,
            wall_ns,
            main_stats,
            kernel_stats,
            kernel_launches: env.kernel_launches.load(Ordering::Relaxed),
            grid: (self.cfg.teams, self.cfg.threads_per_team),
            rpc_engine: self.engine_snapshot(),
            host_io: self.host.io_snapshot(),
            passes: self.report.as_ref().map(|r| r.timings.clone()).unwrap_or_default(),
            unresolved_calls: env.unresolved_calls.load(Ordering::Relaxed),
            folded_formats: self.report.as_ref().map_or(0, |r| r.constfold.count()),
            rpc_rw_intents: self.report.as_ref().map_or(0, |r| r.rpc.rw_buffer_intents),
            lowered_fns: self.report.as_ref().map_or(0, |r| r.lower.lowered_fns),
            fused_instrs: self.report.as_ref().map_or(0, |r| r.fuse.pairs),
            bytecode_fns: self.report.as_ref().map_or(0, |r| r.bytecode.bytecode_fns),
            advice_regions: self.report.as_ref().map_or(0, |r| r.advise.regions.len() as u64),
            lint_diags: self.report.as_ref().map_or(0, |r| r.diags.len() as u64),
            rpc_round_trip: obs.rpc_round_trip.snapshot(),
            rpc_per_callee,
            launch_queue_wait: obs.launch_queue_wait.snapshot(),
            launch_run: obs.launch_run.snapshot(),
            host_io_lock_wait: self.host.io_lock_wait(),
            events: obs.events.snapshot(),
            spans_dropped: obs.spans.dropped(),
        };
        (ret, metrics)
    }

    /// Compile + load + run a parsed module in one call.
    pub fn execute(
        &mut self,
        mut module: Module,
        opts: CompileOptions,
        argv: &[i64],
    ) -> Result<(i64, RunMetrics), String> {
        self.compile(&mut module, opts)?;
        self.load(module);
        Ok(self.run(argv))
    }

    /// `execute` with an explicit pass list.
    pub fn execute_spec(
        &mut self,
        mut module: Module,
        spec: &PipelineSpec,
        argv: &[i64],
    ) -> Result<(i64, RunMetrics), String> {
        self.compile_spec(&mut module, spec)?;
        self.load(module);
        Ok(self.run(argv))
    }

    pub fn stop(mut self) {
        if let Some(s) = self.server.take() {
            s.stop();
        }
    }
}

impl Drop for GpuFirstSession {
    fn drop(&mut self) {
        if let Some(s) = self.server.take() {
            s.stop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::memory::MemConfig;

    fn small_cfg() -> Config {
        Config { mem: MemConfig::small(), teams: 4, threads_per_team: 32, ..Default::default() }
    }

    #[test]
    fn end_to_end_hello() {
        let src = r#"
global @fmt const 20 "hello from the GPU\n"

func @main() -> i64 {
  call printf(@fmt)
  return 0
}
"#;
        let module = crate::ir::parser::parse_module(src).unwrap();
        let mut session = GpuFirstSession::start(small_cfg());
        let (ret, metrics) =
            session.execute(module, CompileOptions::default(), &[]).unwrap();
        assert_eq!(ret, 0);
        assert_eq!(session.host.stdout_string(), "hello from the GPU\n");
        assert_eq!(metrics.main_stats.rpc_calls, 1);
        let snap = metrics.rpc_engine.expect("the engine serves every session");
        assert_eq!((snap.lanes, snap.workers), (1, 1), "degenerate single-slot shape");
        assert_eq!(snap.launches, 0, "no parallel region, no kernel-split launch");
        assert_eq!(metrics.host_io.shards, 0, "single-lane session stays unsharded");
        assert_eq!(session.rpc_served(), 1);
        // The pass manager's timings ride into RunMetrics.
        let names: Vec<&str> = metrics.passes.iter().map(|t| t.pass.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "constfold",
                "dce",
                "libcres",
                "rpcgen",
                "multiteam",
                "lower",
                "fuse",
                "bytecode"
            ]
        );
        assert!(metrics.compile_ns() > 0.0);
        assert_eq!(metrics.unresolved_calls, 0);
        assert_eq!(metrics.folded_formats, 0, "direct @fmt: nothing to fold");
        // The default pipeline ran `main` on the linear bytecode tier.
        assert_eq!(metrics.lowered_fns, 1);
        assert_eq!(metrics.bytecode_fns, 1);
        assert!(metrics.summary().contains("register_core fns=1"));
        assert!(metrics.summary().contains("bytecode fns=1"));
        session.stop();
    }

    #[test]
    fn session_honours_explicit_pipeline_spec() {
        let src = r#"
global @fmt const 6 "x=%d\n"

func @main() -> i64 {
  parallel {
    for.team %i = 0 to 16 step 1 {
      %x = mul %i, 2
    }
  }
  call printf(@fmt, 7)
  return 0
}
"#;
        let module = crate::ir::parser::parse_module(src).unwrap();
        let spec = crate::transform::PipelineSpec::parse("libcres,rpcgen").unwrap();
        let mut session = GpuFirstSession::start(small_cfg());
        let (ret, metrics) = session.execute_spec(module, &spec, &[]).unwrap();
        assert_eq!(ret, 0);
        assert_eq!(session.host.stdout_string(), "x=7\n");
        assert_eq!(metrics.kernel_launches, 0, "multiteam dropped from the pipeline");
        let names: Vec<&str> = metrics.passes.iter().map(|t| t.pass.as_str()).collect();
        assert_eq!(names, vec!["libcres", "rpcgen"]);
        session.stop();
    }

    #[test]
    fn config_grid_drives_kernel_launch() {
        let src = r#"
global @out 65536

func @main() -> i64 {
  parallel {
    for.team %i = 0 to 8192 step 1 {
      %off = mul %i, 8
      %p = gep @out, %off
      store.8 %i, %p
    }
  }
  %p = gep @out, 65528
  %r = load.8 %p
  return %r
}
"#;
        let module = crate::ir::parser::parse_module(src).unwrap();
        let mut session = GpuFirstSession::start(small_cfg());
        let (ret, metrics) =
            session.execute(module, CompileOptions::default(), &[]).unwrap();
        assert_eq!(ret, 8191);
        assert_eq!(metrics.kernel_launches, 1);
        assert_eq!(metrics.grid, (4, 32));
        // The launch rode the dedicated executor, even at lanes=1,workers=1.
        let snap = metrics.rpc_engine.unwrap();
        assert_eq!(snap.launches, 1);
        assert_eq!(snap.launch_queue_depth, 0, "queue drained at run end");
        session.stop();
    }

    #[test]
    fn session_with_launch_ring_runs_and_reports_ring_metrics() {
        let src = r#"
global @out 65536

func @main() -> i64 {
  parallel {
    for.team %i = 0 to 1024 step 1 {
      %off = mul %i, 8
      %p = gep @out, %off
      store.8 %i, %p
    }
  }
  return 0
}
"#;
        let module = crate::ir::parser::parse_module(src).unwrap();
        let cfg = Config { rpc_launch_slots: 2, rpc_launch_threads: 2, ..small_cfg() };
        let mut session = GpuFirstSession::start(cfg);
        let (ret, metrics) = session.execute(module, CompileOptions::default(), &[]).unwrap();
        assert_eq!(ret, 0);
        let snap = metrics.rpc_engine.unwrap();
        assert_eq!(snap.launch_slots, 2, "ring width surfaces in metrics");
        assert_eq!(snap.launches, 1);
        assert!(snap.ring_peak >= 1);
        assert_eq!(snap.ring_in_flight, 0, "nothing left running at run end");
        session.stop();
    }

    #[test]
    fn engine_session_runs_programs_and_reports_metrics() {
        let src = r#"
global @fmt const 7 "n=%d\n"

func @main() -> i64 {
  for %i = 0 to 20 step 1 {
    call printf(@fmt, %i)
  }
  return 0
}
"#;
        let module = crate::ir::parser::parse_module(src).unwrap();
        let cfg = Config { rpc_lanes: 4, rpc_workers: 2, ..small_cfg() };
        assert!(!cfg.legacy_rpc());
        let mut session = GpuFirstSession::start(cfg);
        let (ret, metrics) = session.execute(module, CompileOptions::default(), &[]).unwrap();
        assert_eq!(ret, 0);
        let out = session.host.stdout_string();
        assert_eq!(out, (0..20).map(|i| format!("n={i}\n")).collect::<String>());
        let snap = metrics.rpc_engine.expect("engine path reports metrics");
        assert_eq!(snap.lanes, 4);
        assert_eq!(snap.workers, 2);
        assert_eq!(snap.served, 20);
        assert!(metrics.summary().contains("rpc_engine"));
        session.stop();
    }
}
