//! The GPU First session: compile → load → run (paper Fig. 1 & Fig. 2).
//!
//! "The loader is the entry point for the operating system and responsible
//! to setup the environment on the device": here it creates the simulated
//! device (reserving the RPC mailbox arena), starts the host RPC service
//! — the paper's single-threaded server for `lanes=1, workers=1`, the
//! multi-lane worker-pool [`RpcEngine`] otherwise — registers the common
//! landing pads (the pass registers call-site-specific ones during
//! compilation), materializes the program, maps `argv` onto the device
//! and transfers control to the user's `main`.

use super::config::Config;
use super::metrics::RunMetrics;
use crate::gpu::grid::Device;
use crate::ir::interp::{ProgramEnv, Value};
use crate::ir::Module;
use crate::rpc::engine::{ArenaLayout, EngineConfig, RpcEngine};
use crate::rpc::wrappers::register_common;
use crate::rpc::{EngineSnapshot, HostEnv, RpcServer, WrapperRegistry};
use crate::transform::{compile, CompileOptions, CompileReport};
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Which host-side RPC service this session runs.
enum RpcService {
    /// The paper's single-threaded single-slot server (§4.4).
    Legacy(RpcServer),
    /// The multi-lane worker-pool engine.
    Engine(RpcEngine),
}

impl RpcService {
    fn stop(self) {
        match self {
            RpcService::Legacy(s) => s.stop(),
            RpcService::Engine(e) => e.stop(),
        }
    }
}

pub struct GpuFirstSession {
    pub cfg: Config,
    pub device: Arc<Device>,
    pub registry: Arc<WrapperRegistry>,
    pub host: Arc<HostEnv>,
    server: Option<RpcService>,
    pub report: Option<CompileReport>,
    pub env: Option<Arc<ProgramEnv>>,
}

impl GpuFirstSession {
    /// Bring up device + host RPC service + common landing pads.
    pub fn start(cfg: Config) -> Self {
        let arena = ArenaLayout::for_lanes(cfg.rpc_lanes);
        let device = Arc::new(Device::with_arena(cfg.mem, cfg.allocator, arena));
        let registry = Arc::new(WrapperRegistry::new());
        register_common(&registry);
        let host = Arc::new(HostEnv::new());
        let server = if cfg.legacy_rpc() {
            RpcService::Legacy(RpcServer::start(
                Arc::clone(&device.mem),
                Arc::clone(&registry),
                Arc::clone(&host),
            ))
        } else {
            RpcService::Engine(RpcEngine::start(
                Arc::clone(&device.mem),
                arena,
                Arc::clone(&registry),
                Arc::clone(&host),
                EngineConfig { lanes: cfg.rpc_lanes, workers: cfg.rpc_workers, batch: cfg.rpc_batch },
            ))
        };
        Self { cfg, device, registry, host, server: Some(server), report: None, env: None }
    }

    /// Engine counters, when the session runs the multi-lane engine.
    pub fn engine_snapshot(&self) -> Option<EngineSnapshot> {
        match &self.server {
            Some(RpcService::Engine(e)) => Some(e.metrics.snapshot()),
            _ => None,
        }
    }

    /// Requests the host service answered so far (either path).
    pub fn rpc_served(&self) -> u64 {
        match &self.server {
            Some(RpcService::Legacy(s)) => s.served.load(Ordering::Relaxed),
            Some(RpcService::Engine(e)) => e.metrics.served.load(Ordering::Relaxed),
            None => 0,
        }
    }

    /// Run the compiler pipeline over `module` (in place), registering
    /// landing pads against this session's registry.
    pub fn compile(&mut self, module: &mut Module, opts: CompileOptions) -> Result<(), String> {
        let report = compile(module, &self.registry, opts)
            .map_err(|errs| format!("verification failed:\n  {}", errs.join("\n  ")))?;
        self.report = Some(report);
        Ok(())
    }

    /// Materialize the compiled module on the device.
    pub fn load(&mut self, module: Module) {
        let env = ProgramEnv::load_with_grid(
            module,
            Arc::clone(&self.device),
            Arc::clone(&self.registry),
            Arc::clone(&self.host),
            self.cfg.teams,
            self.cfg.threads_per_team,
        );
        self.env = Some(env);
    }

    /// Map argv to the device and invoke the user `main` on the GPU.
    pub fn run(&self, argv: &[i64]) -> (i64, RunMetrics) {
        let env = self.env.as_ref().expect("load() before run()");
        let args: Vec<Value> = argv.iter().map(|&v| Value::I(v)).collect();
        let t0 = std::time::Instant::now();
        let (ret, main_stats) = env.run_main(&args);
        let wall_ns = t0.elapsed().as_nanos() as f64;
        let kernel_stats = *env.kernel_stats.lock().unwrap();
        let metrics = RunMetrics {
            exit_code: ret,
            wall_ns,
            main_stats,
            kernel_stats,
            kernel_launches: env.kernel_launches.load(Ordering::Relaxed),
            grid: (self.cfg.teams, self.cfg.threads_per_team),
            rpc_engine: self.engine_snapshot(),
        };
        (ret, metrics)
    }

    /// Compile + load + run a parsed module in one call.
    pub fn execute(
        &mut self,
        mut module: Module,
        opts: CompileOptions,
        argv: &[i64],
    ) -> Result<(i64, RunMetrics), String> {
        self.compile(&mut module, opts)?;
        self.load(module);
        Ok(self.run(argv))
    }

    pub fn stop(mut self) {
        if let Some(s) = self.server.take() {
            s.stop();
        }
    }
}

impl Drop for GpuFirstSession {
    fn drop(&mut self) {
        if let Some(s) = self.server.take() {
            s.stop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::memory::MemConfig;

    fn small_cfg() -> Config {
        Config { mem: MemConfig::small(), teams: 4, threads_per_team: 32, ..Default::default() }
    }

    #[test]
    fn end_to_end_hello() {
        let src = r#"
global @fmt const 20 "hello from the GPU\n"

func @main() -> i64 {
  call printf(@fmt)
  return 0
}
"#;
        let module = crate::ir::parser::parse_module(src).unwrap();
        let mut session = GpuFirstSession::start(small_cfg());
        let (ret, metrics) =
            session.execute(module, CompileOptions::default(), &[]).unwrap();
        assert_eq!(ret, 0);
        assert_eq!(session.host.stdout_string(), "hello from the GPU\n");
        assert_eq!(metrics.main_stats.rpc_calls, 1);
        assert!(metrics.rpc_engine.is_none(), "legacy path has no engine metrics");
        assert_eq!(session.rpc_served(), 1);
        session.stop();
    }

    #[test]
    fn config_grid_drives_kernel_launch() {
        let src = r#"
global @out 65536

func @main() -> i64 {
  parallel {
    for.team %i = 0 to 8192 step 1 {
      %off = mul %i, 8
      %p = gep @out, %off
      store.8 %i, %p
    }
  }
  %p = gep @out, 65528
  %r = load.8 %p
  return %r
}
"#;
        let module = crate::ir::parser::parse_module(src).unwrap();
        let mut session = GpuFirstSession::start(small_cfg());
        let (ret, metrics) =
            session.execute(module, CompileOptions::default(), &[]).unwrap();
        assert_eq!(ret, 8191);
        assert_eq!(metrics.kernel_launches, 1);
        assert_eq!(metrics.grid, (4, 32));
        session.stop();
    }

    #[test]
    fn engine_session_runs_programs_and_reports_metrics() {
        let src = r#"
global @fmt const 7 "n=%d\n"

func @main() -> i64 {
  for %i = 0 to 20 step 1 {
    call printf(@fmt, %i)
  }
  return 0
}
"#;
        let module = crate::ir::parser::parse_module(src).unwrap();
        let cfg = Config { rpc_lanes: 4, rpc_workers: 2, ..small_cfg() };
        assert!(!cfg.legacy_rpc());
        let mut session = GpuFirstSession::start(cfg);
        let (ret, metrics) = session.execute(module, CompileOptions::default(), &[]).unwrap();
        assert_eq!(ret, 0);
        let out = session.host.stdout_string();
        assert_eq!(out, (0..20).map(|i| format!("n={i}\n")).collect::<String>());
        let snap = metrics.rpc_engine.expect("engine path reports metrics");
        assert_eq!(snap.lanes, 4);
        assert_eq!(snap.workers, 2);
        assert_eq!(snap.served, 20);
        assert!(metrics.summary().contains("rpc_engine"));
        session.stop();
    }
}
