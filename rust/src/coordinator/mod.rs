//! The coordinator: the paper's Fig. 1 *loader* plus the host process.
//!
//! It owns process topology: the simulated device, the host RPC server
//! thread, the landing-pad registry, the PJRT runtime for offloaded
//! kernels, metrics, and the CLI-facing configuration. The request path
//! (run an application, launch kernels, serve RPCs) is pure Rust.

pub mod config;
pub mod loader;
pub mod metrics;
pub mod serve;

pub use config::{auto_lanes, auto_workers, Config, ConfigBuilder, ConfigError};
pub use loader::GpuFirstSession;
pub use metrics::RunMetrics;
pub use serve::{
    ServeConfig, ServeDaemon, ServeError, ServeSnapshot, SessionHandle, TenantCounters,
};
