//! Runtime configuration (the paper's environment knobs: allocator flag,
//! grid shape, memory sizes, RPC engine shape).

use crate::gpu::grid::AllocatorKind;
use crate::gpu::memory::MemConfig;
use crate::util::cli::Args;

#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub teams: usize,
    pub threads_per_team: usize,
    pub allocator: AllocatorKind,
    pub mem: MemConfig,
    /// RPC mailbox lanes (`--rpc-lanes`); 1 = the paper's single slot.
    pub rpc_lanes: usize,
    /// Host RPC worker threads (`--rpc-workers`); 1 = single-threaded
    /// server. `lanes=1, workers=1` selects the legacy code path.
    pub rpc_workers: usize,
    /// Coalesce same-callee requests per poll sweep (`--no-rpc-batch`
    /// disables).
    pub rpc_batch: bool,
    /// Print pass reports and per-launch stats.
    pub verbose: bool,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            teams: 64,
            threads_per_team: 128,
            allocator: AllocatorKind::Balanced(Default::default()),
            mem: MemConfig::default(),
            rpc_lanes: 1,
            rpc_workers: 1,
            rpc_batch: true,
            verbose: false,
        }
    }
}

impl Config {
    /// Build from CLI arguments:
    /// `--teams N --threads N --allocator generic|vendor|balanced[N,M]
    ///  --heap-mb N --rpc-lanes N --rpc-workers N --no-rpc-batch
    ///  --verbose`.
    pub fn from_args(args: &Args) -> Result<Self, String> {
        let mut cfg = Config::default();
        cfg.teams = args.get_usize("teams", cfg.teams);
        cfg.threads_per_team = args.get_usize("threads", cfg.threads_per_team);
        if let Some(a) = args.get("allocator") {
            cfg.allocator = AllocatorKind::parse(a)?;
        }
        let heap_mb = args.get_usize("heap-mb", 256);
        cfg.mem.global_size = (heap_mb as u64) << 20;
        cfg.rpc_lanes = args.get_usize("rpc-lanes", cfg.rpc_lanes);
        cfg.rpc_workers = args.get_usize("rpc-workers", cfg.rpc_workers);
        cfg.rpc_batch = !args.flag("no-rpc-batch");
        cfg.verbose = args.flag("verbose");
        if cfg.teams == 0 || cfg.threads_per_team == 0 {
            return Err("teams/threads must be positive".into());
        }
        if cfg.rpc_lanes == 0 || cfg.rpc_workers == 0 {
            return Err("rpc-lanes/rpc-workers must be positive".into());
        }
        // Reject arena shapes the device cannot reserve here, where it is
        // a clean CLI error rather than a panic in Device::with_arena.
        let arena = crate::rpc::engine::ArenaLayout::for_lanes(cfg.rpc_lanes);
        if arena.reserved_bytes() + (1 << 20) > cfg.mem.managed_size {
            return Err(format!(
                "--rpc-lanes {} needs {} B of managed memory (plus 1 MiB headroom) \
                 but the managed segment is {} B",
                cfg.rpc_lanes,
                arena.reserved_bytes(),
                cfg.mem.managed_size,
            ));
        }
        Ok(cfg)
    }

    /// The legacy single-slot single-thread server path (paper §4.4)?
    pub fn legacy_rpc(&self) -> bool {
        self.rpc_lanes == 1 && self.rpc_workers == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags() {
        let args = Args::parse(
            &sv(&["--teams", "8", "--threads", "32", "--allocator", "balanced[4,2]", "--heap-mb", "64", "--verbose"]),
            &[],
        );
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.teams, 8);
        assert_eq!(cfg.threads_per_team, 32);
        assert_eq!(cfg.mem.global_size, 64 << 20);
        assert!(cfg.verbose);
        assert!(matches!(cfg.allocator, AllocatorKind::Balanced(c) if c.n == 4 && c.m == 2));
        assert!(cfg.legacy_rpc(), "default RPC path is the single slot");
        assert!(cfg.rpc_batch);
    }

    #[test]
    fn parses_rpc_engine_flags() {
        let args = Args::parse(&sv(&["--rpc-lanes", "4", "--rpc-workers", "2", "--no-rpc-batch"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_lanes, 4);
        assert_eq!(cfg.rpc_workers, 2);
        assert!(!cfg.rpc_batch);
        assert!(!cfg.legacy_rpc());
    }

    #[test]
    fn rejects_bad_allocator() {
        let args = Args::parse(&sv(&["--allocator", "wat"]), &[]);
        assert!(Config::from_args(&args).is_err());
    }

    #[test]
    fn rejects_zero_lanes_or_workers() {
        let args = Args::parse(&sv(&["--rpc-lanes", "0"]), &[]);
        assert!(Config::from_args(&args).is_err());
        let args = Args::parse(&sv(&["--rpc-workers", "0"]), &[]);
        assert!(Config::from_args(&args).is_err());
    }

    #[test]
    fn rejects_arena_too_large_for_managed_segment() {
        // 200 lanes × ~257 KiB ≫ the default 32 MiB managed segment:
        // must be a clean Err, not a Device::with_arena panic.
        let args = Args::parse(&sv(&["--rpc-lanes", "200"]), &[]);
        let err = Config::from_args(&args).unwrap_err();
        assert!(err.contains("managed"), "unexpected error: {err}");
        // A modest lane count fits fine.
        let args = Args::parse(&sv(&["--rpc-lanes", "8"]), &[]);
        assert!(Config::from_args(&args).is_ok());
    }
}
