//! Runtime configuration (the paper's environment knobs: allocator flag,
//! grid shape, memory sizes).

use crate::gpu::grid::AllocatorKind;
use crate::gpu::memory::MemConfig;
use crate::util::cli::Args;

#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub teams: usize,
    pub threads_per_team: usize,
    pub allocator: AllocatorKind,
    pub mem: MemConfig,
    /// Print pass reports and per-launch stats.
    pub verbose: bool,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            teams: 64,
            threads_per_team: 128,
            allocator: AllocatorKind::Balanced(Default::default()),
            mem: MemConfig::default(),
            verbose: false,
        }
    }
}

impl Config {
    /// Build from CLI arguments:
    /// `--teams N --threads N --allocator generic|vendor|balanced[N,M]
    ///  --heap-mb N --verbose`.
    pub fn from_args(args: &Args) -> Result<Self, String> {
        let mut cfg = Config::default();
        cfg.teams = args.get_usize("teams", cfg.teams);
        cfg.threads_per_team = args.get_usize("threads", cfg.threads_per_team);
        if let Some(a) = args.get("allocator") {
            cfg.allocator = AllocatorKind::parse(a)?;
        }
        let heap_mb = args.get_usize("heap-mb", 256);
        cfg.mem.global_size = (heap_mb as u64) << 20;
        cfg.verbose = args.flag("verbose");
        if cfg.teams == 0 || cfg.threads_per_team == 0 {
            return Err("teams/threads must be positive".into());
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags() {
        let args = Args::parse(
            &sv(&["--teams", "8", "--threads", "32", "--allocator", "balanced[4,2]", "--heap-mb", "64", "--verbose"]),
            &[],
        );
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.teams, 8);
        assert_eq!(cfg.threads_per_team, 32);
        assert_eq!(cfg.mem.global_size, 64 << 20);
        assert!(cfg.verbose);
        assert!(matches!(cfg.allocator, AllocatorKind::Balanced(c) if c.n == 4 && c.m == 2));
    }

    #[test]
    fn rejects_bad_allocator() {
        let args = Args::parse(&sv(&["--allocator", "wat"]), &[]);
        assert!(Config::from_args(&args).is_err());
    }
}
