//! Runtime configuration (the paper's environment knobs: allocator flag,
//! grid shape, memory sizes, RPC engine shape).
//!
//! Construction is a validating builder: [`Config::builder`] returns a
//! [`ConfigBuilder`] whose `build()` performs every cross-field check
//! (data-cap alignment, positive engine knobs, `auto` lane/worker
//! resolution, arena-vs-managed-segment fit) and reports failures as
//! the typed [`ConfigError`] enum instead of ad-hoc strings or process
//! exits. [`Config::from_args`] survives as the CLI shim: it maps
//! `Args` parse failures onto `ConfigError` via the typed
//! [`FlagParseError`] accessor and renders the result to the historical
//! usage strings (byte-identical messages, exit codes preserved in
//! `main`).

use crate::gpu::grid::AllocatorKind;
use crate::gpu::memory::MemConfig;
use crate::util::cli::{Args, FlagParseError};
use std::fmt;

/// Why a [`ConfigBuilder::build`] (or `Config::from_args`) was refused.
/// `Display` renders the exact usage strings the string-returning
/// `from_args` always produced, so the shim is byte-compatible.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigError {
    /// A flag value failed to parse (`--teams lots`).
    Flag(FlagParseError),
    /// A knob that must be >= 1 was zero. Holds the flag-group prefix of
    /// the historical message ("teams/threads", "--rpc-lanes/--rpc-workers",
    /// "--rpc-launch-threads/--rpc-launch-slots").
    NotPositive { what: &'static str },
    /// `--rpc-data-cap` must be a positive multiple of 64 bytes.
    DataCapAlignment { cap: u64 },
    /// `--allocator` value not recognized (message from
    /// [`AllocatorKind::parse`]).
    Allocator(String),
    /// The selected mailbox arena cannot be reserved inside the managed
    /// segment.
    ArenaTooLarge {
        lanes: usize,
        launch_slots: usize,
        lane_stride: u64,
        reserved: u64,
        managed: u64,
    },
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Flag(e) => write!(f, "{e}"),
            ConfigError::NotPositive { what } => write!(f, "{what} must be positive"),
            ConfigError::DataCapAlignment { cap } => {
                write!(f, "--rpc-data-cap {cap} must be a positive multiple of 64 bytes")
            }
            ConfigError::Allocator(msg) => write!(f, "{msg}"),
            ConfigError::ArenaTooLarge { lanes, launch_slots, lane_stride, reserved, managed } => {
                write!(
                    f,
                    "the RPC arena ({lanes} lanes + a {launch_slots}-slot launch ring at \
                     {lane_stride} B each) needs {reserved} B of managed memory (plus 1 MiB \
                     headroom) but the managed segment is {managed} B; lower --rpc-lanes, \
                     --rpc-launch-slots or --rpc-data-cap"
                )
            }
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<FlagParseError> for ConfigError {
    fn from(e: FlagParseError) -> Self {
        ConfigError::Flag(e)
    }
}

impl From<ConfigError> for String {
    fn from(e: ConfigError) -> Self {
        e.to_string()
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub teams: usize,
    pub threads_per_team: usize,
    pub allocator: AllocatorKind,
    pub mem: MemConfig,
    /// RPC mailbox lanes (`--rpc-lanes`, or `--rpc-lanes auto` to size
    /// from the team count); 1 = the paper's single slot.
    pub rpc_lanes: usize,
    /// Host RPC poll worker threads (`--rpc-workers`, or `--rpc-workers
    /// auto` to run one worker per resolved lane, clamped to the host's
    /// available parallelism).
    pub rpc_workers: usize,
    /// Dedicated kernel-split launch executor threads
    /// (`--rpc-launch-threads`).
    pub rpc_launch_threads: usize,
    /// Launch ring width (`--rpc-launch-slots`): kernel-split launches
    /// that can be in flight at once; 1 = the single dedicated slot.
    pub rpc_launch_slots: usize,
    /// Per-lane mailbox DATA bytes (`--rpc-data-cap`); `None` uses the
    /// lane-count default (1 MiB legacy single lane, 256 KiB per
    /// multi-lane slot).
    pub rpc_data_cap: Option<u64>,
    /// Coalesce same-callee requests per poll sweep (`--no-rpc-batch`
    /// disables).
    pub rpc_batch: bool,
    /// Print pass reports and per-launch stats.
    pub verbose: bool,
    /// Enable the span recorder (`--trace`, or implied by
    /// `--trace-out FILE`). Off by default: `SpanRecorder::start` is a
    /// single relaxed load when disabled.
    pub trace: bool,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            teams: 64,
            threads_per_team: 128,
            allocator: AllocatorKind::Balanced(Default::default()),
            mem: MemConfig::default(),
            rpc_lanes: 1,
            rpc_workers: 1,
            rpc_launch_threads: 1,
            rpc_launch_slots: 1,
            rpc_data_cap: None,
            rpc_batch: true,
            verbose: false,
            trace: false,
        }
    }
}

/// Fixed vs `auto` sizing for the lane/worker knobs (`auto` resolves at
/// [`ConfigBuilder::build`] time, after every input it depends on is
/// known).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Sizing {
    Fixed(usize),
    Auto,
}

/// Validating builder for [`Config`]. Setters never fail; `build()`
/// runs every check once, in dependency order, and returns a typed
/// [`ConfigError`] on the first violation.
#[derive(Debug, Clone)]
pub struct ConfigBuilder {
    cfg: Config,
    lanes: Sizing,
    workers: Sizing,
}

impl Default for ConfigBuilder {
    fn default() -> Self {
        let cfg = Config::default();
        Self { lanes: Sizing::Fixed(cfg.rpc_lanes), workers: Sizing::Fixed(cfg.rpc_workers), cfg }
    }
}

impl ConfigBuilder {
    pub fn teams(mut self, n: usize) -> Self {
        self.cfg.teams = n;
        self
    }

    pub fn threads_per_team(mut self, n: usize) -> Self {
        self.cfg.threads_per_team = n;
        self
    }

    pub fn allocator(mut self, a: AllocatorKind) -> Self {
        self.cfg.allocator = a;
        self
    }

    pub fn mem(mut self, mem: MemConfig) -> Self {
        self.cfg.mem = mem;
        self
    }

    /// Size the global heap segment in MiB (the `--heap-mb` knob).
    pub fn heap_mb(mut self, mb: u64) -> Self {
        self.cfg.mem.global_size = mb << 20;
        self
    }

    pub fn rpc_lanes(mut self, n: usize) -> Self {
        self.lanes = Sizing::Fixed(n);
        self
    }

    /// Size the lanes from the team count at build time (`--rpc-lanes
    /// auto`).
    pub fn rpc_lanes_auto(mut self) -> Self {
        self.lanes = Sizing::Auto;
        self
    }

    pub fn rpc_workers(mut self, n: usize) -> Self {
        self.workers = Sizing::Fixed(n);
        self
    }

    /// One worker per resolved lane, clamped to the host (`--rpc-workers
    /// auto`).
    pub fn rpc_workers_auto(mut self) -> Self {
        self.workers = Sizing::Auto;
        self
    }

    pub fn rpc_launch_threads(mut self, n: usize) -> Self {
        self.cfg.rpc_launch_threads = n;
        self
    }

    pub fn rpc_launch_slots(mut self, n: usize) -> Self {
        self.cfg.rpc_launch_slots = n;
        self
    }

    pub fn rpc_data_cap(mut self, cap: u64) -> Self {
        self.cfg.rpc_data_cap = Some(cap);
        self
    }

    pub fn rpc_batch(mut self, on: bool) -> Self {
        self.cfg.rpc_batch = on;
        self
    }

    pub fn verbose(mut self, on: bool) -> Self {
        self.cfg.verbose = on;
        self
    }

    pub fn trace(mut self, on: bool) -> Self {
        self.cfg.trace = on;
        self
    }

    /// Validate every field and resolve the `auto` sizings. The check
    /// order is load-bearing: the data cap validates before the `auto`
    /// lane resolver feeds it into the arena constructors (whose
    /// alignment assert would otherwise turn a usage error into a
    /// panic), and lanes resolve before workers so `auto` workers size
    /// from the resolved lane count.
    pub fn build(self) -> Result<Config, ConfigError> {
        let mut cfg = self.cfg;
        if let Some(cap) = cfg.rpc_data_cap {
            if cap == 0 || cap % 64 != 0 {
                return Err(ConfigError::DataCapAlignment { cap });
            }
        }
        if cfg.rpc_launch_threads == 0 || cfg.rpc_launch_slots == 0 {
            return Err(ConfigError::NotPositive {
                what: "--rpc-launch-threads/--rpc-launch-slots",
            });
        }
        cfg.rpc_lanes = match self.lanes {
            Sizing::Auto => {
                auto_lanes(cfg.teams, &cfg.mem, cfg.rpc_launch_slots, cfg.rpc_data_cap)
            }
            Sizing::Fixed(n) => n,
        };
        cfg.rpc_workers = match self.workers {
            Sizing::Auto => auto_workers(cfg.rpc_lanes),
            Sizing::Fixed(n) => n,
        };
        if cfg.rpc_lanes == 0 || cfg.rpc_workers == 0 {
            return Err(ConfigError::NotPositive { what: "--rpc-lanes/--rpc-workers" });
        }
        if cfg.teams == 0 || cfg.threads_per_team == 0 {
            return Err(ConfigError::NotPositive { what: "teams/threads" });
        }
        // Reject arena shapes the device cannot reserve here, where it
        // is a clean typed error rather than a panic in
        // Device::with_arena.
        let arena = cfg.arena();
        if arena.reserved_bytes() + (1 << 20) > cfg.mem.managed_size {
            return Err(ConfigError::ArenaTooLarge {
                lanes: cfg.rpc_lanes,
                launch_slots: cfg.rpc_launch_slots,
                lane_stride: arena.lane_stride(),
                reserved: arena.reserved_bytes(),
                managed: cfg.mem.managed_size,
            });
        }
        Ok(cfg)
    }
}

impl Config {
    /// A validating builder over the default configuration.
    pub fn builder() -> ConfigBuilder {
        ConfigBuilder::default()
    }

    /// Build from CLI arguments:
    /// `--teams N --threads N --allocator generic|vendor|balanced[N,M]
    ///  --heap-mb N --rpc-lanes N|auto --rpc-workers N|auto
    ///  --rpc-launch-threads N --rpc-launch-slots N
    ///  --rpc-data-cap BYTES --no-rpc-batch --verbose --trace`
    /// (`--trace-out FILE` implies `--trace`).
    ///
    /// The historical string-error shim over [`Config::try_from_args`]:
    /// messages are byte-identical to the pre-builder implementation.
    pub fn from_args(args: &Args) -> Result<Self, String> {
        Self::try_from_args(args).map_err(String::from)
    }

    /// `from_args` with the typed [`ConfigError`]: every malformed flag
    /// value surfaces as [`ConfigError::Flag`] (never a mid-parse
    /// process exit) and every validation failure as its own variant.
    pub fn try_from_args(args: &Args) -> Result<Self, ConfigError> {
        let int = |name| args.try_get_typed::<usize>(name, "an integer");
        let mut b = Config::builder();
        if let Some(n) = int("teams")? {
            b = b.teams(n);
        }
        if let Some(n) = int("threads")? {
            b = b.threads_per_team(n);
        }
        if let Some(a) = args.get("allocator") {
            b = b.allocator(AllocatorKind::parse(a).map_err(ConfigError::Allocator)?);
        }
        b = b.heap_mb(int("heap-mb")?.unwrap_or(256) as u64);
        if let Some(n) = int("rpc-launch-threads")? {
            b = b.rpc_launch_threads(n);
        }
        if let Some(n) = int("rpc-launch-slots")? {
            b = b.rpc_launch_slots(n);
        }
        if let Some(cap) = args.try_get_typed::<u64>("rpc-data-cap", "a byte count")? {
            b = b.rpc_data_cap(cap);
        }
        b = match args.get("rpc-lanes") {
            Some("auto") => b.rpc_lanes_auto(),
            _ => match int("rpc-lanes")? {
                Some(n) => b.rpc_lanes(n),
                None => b,
            },
        };
        b = match args.get("rpc-workers") {
            Some("auto") => b.rpc_workers_auto(),
            _ => match int("rpc-workers")? {
                Some(n) => b.rpc_workers(n),
                None => b,
            },
        };
        b.rpc_batch(!args.flag("no-rpc-batch"))
            .verbose(args.flag("verbose"))
            .trace(args.flag("trace") || args.get("trace-out").is_some())
            .build()
    }

    /// The mailbox arena shape this configuration selects.
    pub fn arena(&self) -> crate::rpc::engine::ArenaLayout {
        arena_for(self.rpc_lanes, self.rpc_launch_slots, self.rpc_data_cap)
    }

    /// The paper's degenerate single-slot shape (`lanes=1, workers=1`)?
    /// Still served by the engine, whose 1×1 path is bit-identical to
    /// the legacy single-threaded server for kernels issuing no RPCs.
    pub fn legacy_rpc(&self) -> bool {
        self.rpc_lanes == 1 && self.rpc_workers == 1
    }
}

/// The arena a `(lanes, launch_slots, data_cap)` triple selects —
/// `Config::arena` and the `--rpc-lanes auto` resolver share this so
/// the resolved lane count is judged against the exact layout the
/// session will reserve.
fn arena_for(
    lanes: usize,
    launch_slots: usize,
    data_cap: Option<u64>,
) -> crate::rpc::engine::ArenaLayout {
    match data_cap {
        Some(cap) => crate::rpc::engine::ArenaLayout::with_ring(lanes, cap, launch_slots),
        None => crate::rpc::engine::ArenaLayout::for_shape(lanes, launch_slots),
    }
}

/// Resolve `--rpc-workers auto`: one poll worker per lane — the widest
/// shape where workers never outnumber lanes (extra pollers only add
/// steal contention; see the fig07 sweep) — clamped to the host's
/// available parallelism, and never below 1.
pub fn auto_workers(lanes: usize) -> usize {
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    lanes.clamp(1, avail.max(1))
}

/// Resolve `--rpc-lanes auto`: one lane per team — a team never waits
/// for a foreign team's mailbox — clamped so the arena (lanes + launch
/// ring + 1 MiB managed headroom) still fits the managed segment.
pub fn auto_lanes(
    teams: usize,
    mem: &MemConfig,
    launch_slots: usize,
    data_cap: Option<u64>,
) -> usize {
    let fits = |lanes: usize| {
        arena_for(lanes, launch_slots, data_cap).reserved_bytes() + (1 << 20) <= mem.managed_size
    };
    // Upper bound from raw arithmetic first (the multi-lane stride) so
    // the fit loop below never walks down from a huge team count one
    // lane at a time.
    let stride = arena_for(2, launch_slots, data_cap).lane_stride();
    let arithmetic_cap = (mem.managed_size / stride.max(1)) as usize;
    let mut lanes = teams.clamp(1, arithmetic_cap.max(1));
    while lanes > 1 && !fits(lanes) {
        lanes -= 1;
    }
    lanes
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags() {
        let args = Args::parse(
            &sv(&[
                "--teams",
                "8",
                "--threads",
                "32",
                "--allocator",
                "balanced[4,2]",
                "--heap-mb",
                "64",
                "--verbose",
            ]),
            &[],
        );
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.teams, 8);
        assert_eq!(cfg.threads_per_team, 32);
        assert_eq!(cfg.mem.global_size, 64 << 20);
        assert!(cfg.verbose);
        assert!(matches!(cfg.allocator, AllocatorKind::Balanced(c) if c.n == 4 && c.m == 2));
        assert!(cfg.legacy_rpc(), "default RPC path is the single slot");
        assert!(cfg.rpc_batch);
    }

    #[test]
    fn parses_rpc_engine_flags() {
        let args =
            Args::parse(&sv(&["--rpc-lanes", "4", "--rpc-workers", "2", "--no-rpc-batch"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_lanes, 4);
        assert_eq!(cfg.rpc_workers, 2);
        assert_eq!(cfg.rpc_launch_threads, 1, "default executor width");
        assert!(!cfg.rpc_batch);
        assert!(!cfg.legacy_rpc());
    }

    #[test]
    fn parses_launch_threads_and_data_cap() {
        let args = Args::parse(
            &sv(&["--rpc-lanes", "2", "--rpc-launch-threads", "3", "--rpc-data-cap", "131072"]),
            &[],
        );
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_launch_threads, 3);
        assert_eq!(cfg.rpc_data_cap, Some(131072));
        let arena = cfg.arena();
        assert_eq!(arena.lanes, 2);
        assert_eq!(arena.data_cap, 131072);
        // Without the flag, the lane-count default applies.
        let cfg = Config::from_args(&Args::parse(&sv(&["--rpc-lanes", "2"]), &[])).unwrap();
        assert_eq!(cfg.arena().data_cap, crate::rpc::engine::MULTI_LANE_DATA_CAP);
        assert_eq!(Config::default().arena(), crate::rpc::engine::ArenaLayout::legacy());
    }

    #[test]
    fn parses_launch_slots_ring() {
        let args = Args::parse(&sv(&["--rpc-launch-slots", "2"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_launch_slots, 2);
        let arena = cfg.arena();
        assert_eq!(arena.launch_slots, 2);
        assert_eq!(arena.lanes, 1);
        assert_eq!(arena.slot_count(), 3);
        // Ring width 0 is a clean usage error.
        let args = Args::parse(&sv(&["--rpc-launch-slots", "0"]), &[]);
        assert!(Config::from_args(&args).is_err());
        // The default stays the byte-identical legacy layout.
        let cfg = Config::from_args(&Args::parse(&[], &[])).unwrap();
        assert_eq!(cfg.rpc_launch_slots, 1);
        assert_eq!(cfg.arena(), crate::rpc::engine::ArenaLayout::legacy());
    }

    #[test]
    fn auto_lanes_follow_team_count() {
        let args = Args::parse(&sv(&["--teams", "6", "--rpc-lanes", "auto"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_lanes, 6, "one lane per team when the segment fits them");
        assert_eq!(cfg.arena().lanes, 6);
        // A single team degenerates to the legacy single slot.
        let args = Args::parse(&sv(&["--teams", "1", "--rpc-lanes", "auto"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_lanes, 1);
        assert_eq!(cfg.arena(), crate::rpc::engine::ArenaLayout::legacy());
    }

    #[test]
    fn auto_workers_follow_lanes_clamped_to_parallelism() {
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert_eq!(auto_workers(1), 1);
        assert_eq!(auto_workers(4), 4.min(avail).max(1));
        assert_eq!(auto_workers(1 << 20), avail.max(1), "huge lane counts clamp to the host");
        assert!(auto_workers(0) >= 1, "never resolves to zero workers");

        let args = Args::parse(&sv(&["--rpc-lanes", "4", "--rpc-workers", "auto"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_workers, auto_workers(4));
        assert!(cfg.rpc_workers >= 1 && cfg.rpc_workers <= 4);

        // `auto` workers compose with `auto` lanes (lanes resolve first).
        let args =
            Args::parse(&sv(&["--teams", "6", "--rpc-lanes", "auto", "--rpc-workers", "auto"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_lanes, 6);
        assert_eq!(cfg.rpc_workers, auto_workers(6));
    }

    #[test]
    fn malformed_rpc_workers_is_a_clean_usage_err() {
        for bad in ["lots", "-2", "1.5"] {
            let args = Args::parse(&sv(&["--rpc-workers", bad]), &[]);
            let err = Config::from_args(&args).unwrap_err();
            assert!(err.contains("--rpc-workers"), "names the flag: {err}");
            assert!(err.contains(bad), "echoes the value: {err}");
        }
    }

    #[test]
    fn auto_lanes_with_bad_data_cap_is_a_clean_err() {
        // `auto` feeds the cap into the arena constructor; a misaligned
        // cap must still surface as the usage Err, never as the
        // constructor's alignment panic.
        for bad in ["100", "0"] {
            let args = Args::parse(&sv(&["--rpc-lanes", "auto", "--rpc-data-cap", bad]), &[]);
            let err = Config::from_args(&args).unwrap_err();
            assert!(err.contains("multiple of 64"), "unexpected error: {err}");
        }
    }

    #[test]
    fn auto_lanes_clamp_to_the_managed_segment() {
        // The default 32 MiB managed segment fits ~120 multi-lane slots:
        // a 1000-team request must clamp to what fits (with ring +
        // headroom), never error or overrun.
        let args = Args::parse(&sv(&["--teams", "1000", "--rpc-lanes", "auto"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert!(cfg.rpc_lanes > 1, "clamped lanes still multi-lane: {}", cfg.rpc_lanes);
        assert!(cfg.rpc_lanes < 1000);
        let arena = cfg.arena();
        assert!(arena.reserved_bytes() + (1 << 20) <= cfg.mem.managed_size);
        // Adding one more lane would overflow the reservation.
        let bigger = auto_lanes(cfg.rpc_lanes + 1, &cfg.mem, 1, None);
        assert_eq!(bigger, cfg.rpc_lanes, "resolved count is maximal");
        // A wider launch ring shrinks the lane budget.
        let with_ring = auto_lanes(1000, &cfg.mem, 8, None);
        assert!(with_ring < cfg.rpc_lanes);
        assert!(with_ring >= 1);
    }

    #[test]
    fn malformed_numeric_flag_is_a_clean_err() {
        // from_args keeps its Result contract: a bad value is an Err
        // naming the flag, not a process exit from inside parsing.
        let err = Config::from_args(&Args::parse(&sv(&["--teams", "lots"]), &[])).unwrap_err();
        assert!(err.contains("--teams") && err.contains("lots"), "unexpected error: {err}");
        let err =
            Config::from_args(&Args::parse(&sv(&["--rpc-data-cap", "abc"]), &[])).unwrap_err();
        assert!(err.contains("--rpc-data-cap"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_bad_launch_threads_and_data_cap() {
        let args = Args::parse(&sv(&["--rpc-launch-threads", "0"]), &[]);
        assert!(Config::from_args(&args).is_err());
        // Not a cache-line multiple.
        let args = Args::parse(&sv(&["--rpc-data-cap", "1000"]), &[]);
        let err = Config::from_args(&args).unwrap_err();
        assert!(err.contains("multiple of 64"), "unexpected error: {err}");
        let args = Args::parse(&sv(&["--rpc-data-cap", "0"]), &[]);
        assert!(Config::from_args(&args).is_err());
    }

    #[test]
    fn rejects_bad_allocator() {
        let args = Args::parse(&sv(&["--allocator", "wat"]), &[]);
        assert!(Config::from_args(&args).is_err());
    }

    #[test]
    fn rejects_zero_lanes_or_workers() {
        let args = Args::parse(&sv(&["--rpc-lanes", "0"]), &[]);
        assert!(Config::from_args(&args).is_err());
        let args = Args::parse(&sv(&["--rpc-workers", "0"]), &[]);
        assert!(Config::from_args(&args).is_err());
    }

    #[test]
    fn builder_validates_with_typed_errors() {
        // Direct builder use (no CLI): same checks, typed variants.
        let cfg = Config::builder().teams(8).threads_per_team(32).rpc_lanes(4).build().unwrap();
        assert_eq!((cfg.teams, cfg.threads_per_team, cfg.rpc_lanes), (8, 32, 4));

        let err = Config::builder().rpc_data_cap(100).build().unwrap_err();
        assert_eq!(err, ConfigError::DataCapAlignment { cap: 100 });

        let err = Config::builder().rpc_lanes(0).build().unwrap_err();
        assert_eq!(err, ConfigError::NotPositive { what: "--rpc-lanes/--rpc-workers" });

        let err = Config::builder().teams(0).build().unwrap_err();
        assert_eq!(err, ConfigError::NotPositive { what: "teams/threads" });

        let err = Config::builder().rpc_launch_slots(0).build().unwrap_err();
        assert_eq!(
            err,
            ConfigError::NotPositive { what: "--rpc-launch-threads/--rpc-launch-slots" }
        );

        assert!(matches!(
            Config::builder().rpc_lanes(200).build().unwrap_err(),
            ConfigError::ArenaTooLarge { lanes: 200, .. }
        ));

        // `auto` sizings resolve at build time, lanes before workers.
        let cfg = Config::builder().teams(6).rpc_lanes_auto().rpc_workers_auto().build().unwrap();
        assert_eq!(cfg.rpc_lanes, 6);
        assert_eq!(cfg.rpc_workers, auto_workers(6));
    }

    #[test]
    fn typed_errors_render_the_historical_messages() {
        // The from_args shim must stay byte-compatible: every typed
        // variant renders exactly the string the old implementation
        // produced.
        let args = Args::parse(&sv(&["--teams", "lots"]), &[]);
        let typed = Config::try_from_args(&args).unwrap_err();
        assert!(matches!(&typed, ConfigError::Flag(e) if e.flag == "teams" && e.value == "lots"));
        assert_eq!(Config::from_args(&args).unwrap_err(), typed.to_string());

        assert_eq!(
            ConfigError::DataCapAlignment { cap: 100 }.to_string(),
            "--rpc-data-cap 100 must be a positive multiple of 64 bytes"
        );
        assert_eq!(
            ConfigError::NotPositive { what: "teams/threads" }.to_string(),
            "teams/threads must be positive"
        );
        let args = Args::parse(&sv(&["--rpc-lanes", "200"]), &[]);
        let typed = Config::try_from_args(&args).unwrap_err();
        let rendered = Config::from_args(&args).unwrap_err();
        assert_eq!(String::from(typed), rendered);
        assert!(rendered.starts_with("the RPC arena (200 lanes"), "message shape: {rendered}");
    }

    #[test]
    fn rejects_arena_too_large_for_managed_segment() {
        // 200 lanes × ~257 KiB ≫ the default 32 MiB managed segment:
        // must be a clean Err, not a Device::with_arena panic.
        let args = Args::parse(&sv(&["--rpc-lanes", "200"]), &[]);
        let err = Config::from_args(&args).unwrap_err();
        assert!(err.contains("managed"), "unexpected error: {err}");
        // A modest lane count fits fine.
        let args = Args::parse(&sv(&["--rpc-lanes", "8"]), &[]);
        assert!(Config::from_args(&args).is_ok());
    }
}
