//! Runtime configuration (the paper's environment knobs: allocator flag,
//! grid shape, memory sizes, RPC engine shape).

use crate::gpu::grid::AllocatorKind;
use crate::gpu::memory::MemConfig;
use crate::util::cli::Args;

#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub teams: usize,
    pub threads_per_team: usize,
    pub allocator: AllocatorKind,
    pub mem: MemConfig,
    /// RPC mailbox lanes (`--rpc-lanes`, or `--rpc-lanes auto` to size
    /// from the team count); 1 = the paper's single slot.
    pub rpc_lanes: usize,
    /// Host RPC poll worker threads (`--rpc-workers`, or `--rpc-workers
    /// auto` to run one worker per resolved lane, clamped to the host's
    /// available parallelism).
    pub rpc_workers: usize,
    /// Dedicated kernel-split launch executor threads
    /// (`--rpc-launch-threads`).
    pub rpc_launch_threads: usize,
    /// Launch ring width (`--rpc-launch-slots`): kernel-split launches
    /// that can be in flight at once; 1 = the single dedicated slot.
    pub rpc_launch_slots: usize,
    /// Per-lane mailbox DATA bytes (`--rpc-data-cap`); `None` uses the
    /// lane-count default (1 MiB legacy single lane, 256 KiB per
    /// multi-lane slot).
    pub rpc_data_cap: Option<u64>,
    /// Coalesce same-callee requests per poll sweep (`--no-rpc-batch`
    /// disables).
    pub rpc_batch: bool,
    /// Print pass reports and per-launch stats.
    pub verbose: bool,
    /// Enable the span recorder (`--trace`, or implied by
    /// `--trace-out FILE`). Off by default: `SpanRecorder::start` is a
    /// single relaxed load when disabled.
    pub trace: bool,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            teams: 64,
            threads_per_team: 128,
            allocator: AllocatorKind::Balanced(Default::default()),
            mem: MemConfig::default(),
            rpc_lanes: 1,
            rpc_workers: 1,
            rpc_launch_threads: 1,
            rpc_launch_slots: 1,
            rpc_data_cap: None,
            rpc_batch: true,
            verbose: false,
            trace: false,
        }
    }
}

impl Config {
    /// Build from CLI arguments:
    /// `--teams N --threads N --allocator generic|vendor|balanced[N,M]
    ///  --heap-mb N --rpc-lanes N|auto --rpc-workers N|auto
    ///  --rpc-launch-threads N --rpc-launch-slots N
    ///  --rpc-data-cap BYTES --no-rpc-batch --verbose --trace`
    /// (`--trace-out FILE` implies `--trace`).
    pub fn from_args(args: &Args) -> Result<Self, String> {
        // Numeric flags parse through the fallible accessor so every
        // malformed value surfaces as this function's Err (one clean
        // usage error in main), never a mid-parse process exit.
        let int = |name| args.try_get::<usize>(name, "an integer");
        let mut cfg = Config::default();
        cfg.teams = int("teams")?.unwrap_or(cfg.teams);
        cfg.threads_per_team = int("threads")?.unwrap_or(cfg.threads_per_team);
        if let Some(a) = args.get("allocator") {
            cfg.allocator = AllocatorKind::parse(a)?;
        }
        let heap_mb = int("heap-mb")?.unwrap_or(256);
        cfg.mem.global_size = (heap_mb as u64) << 20;
        cfg.rpc_launch_threads = int("rpc-launch-threads")?.unwrap_or(cfg.rpc_launch_threads);
        cfg.rpc_launch_slots = int("rpc-launch-slots")?.unwrap_or(cfg.rpc_launch_slots);
        cfg.rpc_data_cap = args.try_get::<u64>("rpc-data-cap", "a byte count")?;
        // Validate the cap before anything consumes it: `--rpc-lanes
        // auto` feeds it straight into ArenaLayout::with_ring, whose
        // alignment assert would otherwise turn this usage error into a
        // panic.
        if let Some(cap) = cfg.rpc_data_cap {
            if cap == 0 || cap % 64 != 0 {
                return Err(format!(
                    "--rpc-data-cap {cap} must be a positive multiple of 64 bytes"
                ));
            }
        }
        if cfg.rpc_launch_threads == 0 || cfg.rpc_launch_slots == 0 {
            return Err("--rpc-launch-threads/--rpc-launch-slots must be positive".into());
        }
        // Lanes before workers among the engine knobs: both `auto`
        // resolvers need earlier values — lanes sizes from the team count
        // against the (validated) ring width and data cap, workers size
        // from the resolved lane count.
        cfg.rpc_lanes = match args.get("rpc-lanes") {
            Some("auto") => {
                auto_lanes(cfg.teams, &cfg.mem, cfg.rpc_launch_slots, cfg.rpc_data_cap)
            }
            _ => int("rpc-lanes")?.unwrap_or(cfg.rpc_lanes),
        };
        cfg.rpc_workers = match args.get("rpc-workers") {
            Some("auto") => auto_workers(cfg.rpc_lanes),
            _ => int("rpc-workers")?.unwrap_or(cfg.rpc_workers),
        };
        // Lanes and workers validate together once both are resolved
        // (the launch knobs were checked above, before the `auto` lane
        // resolver fed them into the arena constructors).
        if cfg.rpc_lanes == 0 || cfg.rpc_workers == 0 {
            return Err("--rpc-lanes/--rpc-workers must be positive".into());
        }
        cfg.rpc_batch = !args.flag("no-rpc-batch");
        cfg.verbose = args.flag("verbose");
        cfg.trace = args.flag("trace") || args.get("trace-out").is_some();
        if cfg.teams == 0 || cfg.threads_per_team == 0 {
            return Err("teams/threads must be positive".into());
        }
        // Reject arena shapes the device cannot reserve here, where it is
        // a clean CLI error rather than a panic in Device::with_arena.
        let arena = cfg.arena();
        if arena.reserved_bytes() + (1 << 20) > cfg.mem.managed_size {
            return Err(format!(
                "the RPC arena ({} lanes + a {}-slot launch ring at {} B each) needs \
                 {} B of managed memory (plus 1 MiB headroom) but the managed segment \
                 is {} B; lower --rpc-lanes, --rpc-launch-slots or --rpc-data-cap",
                cfg.rpc_lanes,
                cfg.rpc_launch_slots,
                arena.lane_stride(),
                arena.reserved_bytes(),
                cfg.mem.managed_size,
            ));
        }
        Ok(cfg)
    }

    /// The mailbox arena shape this configuration selects.
    pub fn arena(&self) -> crate::rpc::engine::ArenaLayout {
        arena_for(self.rpc_lanes, self.rpc_launch_slots, self.rpc_data_cap)
    }

    /// The paper's degenerate single-slot shape (`lanes=1, workers=1`)?
    /// Still served by the engine, whose 1×1 path is bit-identical to
    /// the legacy single-threaded server for kernels issuing no RPCs.
    pub fn legacy_rpc(&self) -> bool {
        self.rpc_lanes == 1 && self.rpc_workers == 1
    }
}

/// The arena a `(lanes, launch_slots, data_cap)` triple selects —
/// `Config::arena` and the `--rpc-lanes auto` resolver share this so
/// the resolved lane count is judged against the exact layout the
/// session will reserve.
fn arena_for(
    lanes: usize,
    launch_slots: usize,
    data_cap: Option<u64>,
) -> crate::rpc::engine::ArenaLayout {
    match data_cap {
        Some(cap) => crate::rpc::engine::ArenaLayout::with_ring(lanes, cap, launch_slots),
        None => crate::rpc::engine::ArenaLayout::for_shape(lanes, launch_slots),
    }
}

/// Resolve `--rpc-workers auto`: one poll worker per lane — the widest
/// shape where workers never outnumber lanes (extra pollers only add
/// steal contention; see the fig07 sweep) — clamped to the host's
/// available parallelism, and never below 1.
pub fn auto_workers(lanes: usize) -> usize {
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    lanes.clamp(1, avail.max(1))
}

/// Resolve `--rpc-lanes auto`: one lane per team — a team never waits
/// for a foreign team's mailbox — clamped so the arena (lanes + launch
/// ring + 1 MiB managed headroom) still fits the managed segment.
pub fn auto_lanes(
    teams: usize,
    mem: &MemConfig,
    launch_slots: usize,
    data_cap: Option<u64>,
) -> usize {
    let fits = |lanes: usize| {
        arena_for(lanes, launch_slots, data_cap).reserved_bytes() + (1 << 20) <= mem.managed_size
    };
    // Upper bound from raw arithmetic first (the multi-lane stride) so
    // the fit loop below never walks down from a huge team count one
    // lane at a time.
    let stride = arena_for(2, launch_slots, data_cap).lane_stride();
    let arithmetic_cap = (mem.managed_size / stride.max(1)) as usize;
    let mut lanes = teams.clamp(1, arithmetic_cap.max(1));
    while lanes > 1 && !fits(lanes) {
        lanes -= 1;
    }
    lanes
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags() {
        let args = Args::parse(
            &sv(&[
                "--teams",
                "8",
                "--threads",
                "32",
                "--allocator",
                "balanced[4,2]",
                "--heap-mb",
                "64",
                "--verbose",
            ]),
            &[],
        );
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.teams, 8);
        assert_eq!(cfg.threads_per_team, 32);
        assert_eq!(cfg.mem.global_size, 64 << 20);
        assert!(cfg.verbose);
        assert!(matches!(cfg.allocator, AllocatorKind::Balanced(c) if c.n == 4 && c.m == 2));
        assert!(cfg.legacy_rpc(), "default RPC path is the single slot");
        assert!(cfg.rpc_batch);
    }

    #[test]
    fn parses_rpc_engine_flags() {
        let args =
            Args::parse(&sv(&["--rpc-lanes", "4", "--rpc-workers", "2", "--no-rpc-batch"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_lanes, 4);
        assert_eq!(cfg.rpc_workers, 2);
        assert_eq!(cfg.rpc_launch_threads, 1, "default executor width");
        assert!(!cfg.rpc_batch);
        assert!(!cfg.legacy_rpc());
    }

    #[test]
    fn parses_launch_threads_and_data_cap() {
        let args = Args::parse(
            &sv(&["--rpc-lanes", "2", "--rpc-launch-threads", "3", "--rpc-data-cap", "131072"]),
            &[],
        );
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_launch_threads, 3);
        assert_eq!(cfg.rpc_data_cap, Some(131072));
        let arena = cfg.arena();
        assert_eq!(arena.lanes, 2);
        assert_eq!(arena.data_cap, 131072);
        // Without the flag, the lane-count default applies.
        let cfg = Config::from_args(&Args::parse(&sv(&["--rpc-lanes", "2"]), &[])).unwrap();
        assert_eq!(cfg.arena().data_cap, crate::rpc::engine::MULTI_LANE_DATA_CAP);
        assert_eq!(Config::default().arena(), crate::rpc::engine::ArenaLayout::legacy());
    }

    #[test]
    fn parses_launch_slots_ring() {
        let args = Args::parse(&sv(&["--rpc-launch-slots", "2"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_launch_slots, 2);
        let arena = cfg.arena();
        assert_eq!(arena.launch_slots, 2);
        assert_eq!(arena.lanes, 1);
        assert_eq!(arena.slot_count(), 3);
        // Ring width 0 is a clean usage error.
        let args = Args::parse(&sv(&["--rpc-launch-slots", "0"]), &[]);
        assert!(Config::from_args(&args).is_err());
        // The default stays the byte-identical legacy layout.
        let cfg = Config::from_args(&Args::parse(&[], &[])).unwrap();
        assert_eq!(cfg.rpc_launch_slots, 1);
        assert_eq!(cfg.arena(), crate::rpc::engine::ArenaLayout::legacy());
    }

    #[test]
    fn auto_lanes_follow_team_count() {
        let args = Args::parse(&sv(&["--teams", "6", "--rpc-lanes", "auto"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_lanes, 6, "one lane per team when the segment fits them");
        assert_eq!(cfg.arena().lanes, 6);
        // A single team degenerates to the legacy single slot.
        let args = Args::parse(&sv(&["--teams", "1", "--rpc-lanes", "auto"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_lanes, 1);
        assert_eq!(cfg.arena(), crate::rpc::engine::ArenaLayout::legacy());
    }

    #[test]
    fn auto_workers_follow_lanes_clamped_to_parallelism() {
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert_eq!(auto_workers(1), 1);
        assert_eq!(auto_workers(4), 4.min(avail).max(1));
        assert_eq!(auto_workers(1 << 20), avail.max(1), "huge lane counts clamp to the host");
        assert!(auto_workers(0) >= 1, "never resolves to zero workers");

        let args = Args::parse(&sv(&["--rpc-lanes", "4", "--rpc-workers", "auto"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_workers, auto_workers(4));
        assert!(cfg.rpc_workers >= 1 && cfg.rpc_workers <= 4);

        // `auto` workers compose with `auto` lanes (lanes resolve first).
        let args =
            Args::parse(&sv(&["--teams", "6", "--rpc-lanes", "auto", "--rpc-workers", "auto"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.rpc_lanes, 6);
        assert_eq!(cfg.rpc_workers, auto_workers(6));
    }

    #[test]
    fn malformed_rpc_workers_is_a_clean_usage_err() {
        for bad in ["lots", "-2", "1.5"] {
            let args = Args::parse(&sv(&["--rpc-workers", bad]), &[]);
            let err = Config::from_args(&args).unwrap_err();
            assert!(err.contains("--rpc-workers"), "names the flag: {err}");
            assert!(err.contains(bad), "echoes the value: {err}");
        }
    }

    #[test]
    fn auto_lanes_with_bad_data_cap_is_a_clean_err() {
        // `auto` feeds the cap into the arena constructor; a misaligned
        // cap must still surface as the usage Err, never as the
        // constructor's alignment panic.
        for bad in ["100", "0"] {
            let args = Args::parse(&sv(&["--rpc-lanes", "auto", "--rpc-data-cap", bad]), &[]);
            let err = Config::from_args(&args).unwrap_err();
            assert!(err.contains("multiple of 64"), "unexpected error: {err}");
        }
    }

    #[test]
    fn auto_lanes_clamp_to_the_managed_segment() {
        // The default 32 MiB managed segment fits ~120 multi-lane slots:
        // a 1000-team request must clamp to what fits (with ring +
        // headroom), never error or overrun.
        let args = Args::parse(&sv(&["--teams", "1000", "--rpc-lanes", "auto"]), &[]);
        let cfg = Config::from_args(&args).unwrap();
        assert!(cfg.rpc_lanes > 1, "clamped lanes still multi-lane: {}", cfg.rpc_lanes);
        assert!(cfg.rpc_lanes < 1000);
        let arena = cfg.arena();
        assert!(arena.reserved_bytes() + (1 << 20) <= cfg.mem.managed_size);
        // Adding one more lane would overflow the reservation.
        let bigger = auto_lanes(cfg.rpc_lanes + 1, &cfg.mem, 1, None);
        assert_eq!(bigger, cfg.rpc_lanes, "resolved count is maximal");
        // A wider launch ring shrinks the lane budget.
        let with_ring = auto_lanes(1000, &cfg.mem, 8, None);
        assert!(with_ring < cfg.rpc_lanes);
        assert!(with_ring >= 1);
    }

    #[test]
    fn malformed_numeric_flag_is_a_clean_err() {
        // from_args keeps its Result contract: a bad value is an Err
        // naming the flag, not a process exit from inside parsing.
        let err = Config::from_args(&Args::parse(&sv(&["--teams", "lots"]), &[])).unwrap_err();
        assert!(err.contains("--teams") && err.contains("lots"), "unexpected error: {err}");
        let err =
            Config::from_args(&Args::parse(&sv(&["--rpc-data-cap", "abc"]), &[])).unwrap_err();
        assert!(err.contains("--rpc-data-cap"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_bad_launch_threads_and_data_cap() {
        let args = Args::parse(&sv(&["--rpc-launch-threads", "0"]), &[]);
        assert!(Config::from_args(&args).is_err());
        // Not a cache-line multiple.
        let args = Args::parse(&sv(&["--rpc-data-cap", "1000"]), &[]);
        let err = Config::from_args(&args).unwrap_err();
        assert!(err.contains("multiple of 64"), "unexpected error: {err}");
        let args = Args::parse(&sv(&["--rpc-data-cap", "0"]), &[]);
        assert!(Config::from_args(&args).is_err());
    }

    #[test]
    fn rejects_bad_allocator() {
        let args = Args::parse(&sv(&["--allocator", "wat"]), &[]);
        assert!(Config::from_args(&args).is_err());
    }

    #[test]
    fn rejects_zero_lanes_or_workers() {
        let args = Args::parse(&sv(&["--rpc-lanes", "0"]), &[]);
        assert!(Config::from_args(&args).is_err());
        let args = Args::parse(&sv(&["--rpc-workers", "0"]), &[]);
        assert!(Config::from_args(&args).is_err());
    }

    #[test]
    fn rejects_arena_too_large_for_managed_segment() {
        // 200 lanes × ~257 KiB ≫ the default 32 MiB managed segment:
        // must be a clean Err, not a Device::with_arena panic.
        let args = Args::parse(&sv(&["--rpc-lanes", "200"]), &[]);
        let err = Config::from_args(&args).unwrap_err();
        assert!(err.contains("managed"), "unexpected error: {err}");
        // A modest lane count fits fine.
        let args = Args::parse(&sv(&["--rpc-lanes", "8"]), &[]);
        assert!(Config::from_args(&args).is_ok());
    }
}
