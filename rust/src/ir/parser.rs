//! Recursive-descent parser for the textual IR (see [`super::printer`] for
//! the grammar by example; `;` starts a line comment).

use super::*;
use crate::rpc::ArgMode;

pub fn parse_module(src: &str) -> Result<Module, String> {
    let toks = lex(src)?;
    let mut p = P { toks, i: 0 };
    let mut m = Module::new();
    while !p.done() {
        match p.peek_word() {
            Some("global") => {
                p.bump();
                let name = p.expect_global()?;
                let constant = p.eat_word("const");
                let size = p.expect_int()? as u64;
                let init = if let Some(Tok::Str(_)) = p.peek() {
                    let Tok::Str(s) = p.bump().clone() else { unreachable!() };
                    let mut b = s.into_bytes();
                    b.push(0);
                    b
                } else {
                    Vec::new()
                };
                if init.len() as u64 > size {
                    return Err(format!("global @{name}: init longer than size"));
                }
                m.globals.insert(name.clone(), Global { name, size, constant, init });
            }
            Some("extern") => {
                p.bump();
                let name = p.expect_word()?;
                m.externals.push(name);
            }
            Some("func") => {
                let f = parse_func(&mut p)?;
                m.functions.insert(f.name.clone(), f);
            }
            other => return Err(format!("unexpected top-level token {other:?}")),
        }
    }
    Ok(m)
}

fn parse_func(p: &mut P) -> Result<Function, String> {
    p.expect_word_eq("func")?;
    let name = p.expect_global()?;
    p.expect(Tok::LParen)?;
    let mut params = Vec::new();
    while !p.eat(Tok::RParen) {
        let pname = p.expect_var()?;
        p.expect(Tok::Colon)?;
        let ty = parse_ty(&p.expect_word()?)?;
        params.push(Param { name: pname, ty });
        if !p.eat(Tok::Comma) {
            p.expect(Tok::RParen)?;
            break;
        }
    }
    p.expect(Tok::Arrow)?;
    let ret = parse_ty(&p.expect_word()?)?;
    let is_kernel_region = p.eat_word("kernel");
    let body = parse_block(p)?;
    Ok(Function { name, params, ret, body, is_kernel_region })
}

fn parse_ty(s: &str) -> Result<Ty, String> {
    match s {
        "i64" => Ok(Ty::I64),
        "f64" => Ok(Ty::F64),
        "ptr" => Ok(Ty::Ptr),
        "void" => Ok(Ty::Void),
        _ => Err(format!("unknown type {s}")),
    }
}

fn parse_block(p: &mut P) -> Result<Vec<Instr>, String> {
    p.expect(Tok::LBrace)?;
    let mut body = Vec::new();
    while !p.eat(Tok::RBrace) {
        body.push(parse_instr(p)?);
    }
    Ok(body)
}

fn parse_instr(p: &mut P) -> Result<Instr, String> {
    // Leading %dst = ...
    if let Some(Tok::Var(_)) = p.peek() {
        let dst = p.expect_var()?;
        p.expect(Tok::Assign)?;
        return parse_rhs(p, dst);
    }
    let word = p.expect_word()?;
    match word.as_str() {
        w if w.starts_with("store.") => {
            let width: Width = w[6..].parse().map_err(|_| format!("bad width {w}"))?;
            let val = parse_operand(p)?;
            p.expect(Tok::Comma)?;
            let addr = parse_operand(p)?;
            Ok(Instr::Store { addr, val, width })
        }
        "call" => {
            let callee = p.expect_word()?;
            let args = parse_args(p)?;
            if Module::is_native_intrinsic(&callee) {
                Ok(Instr::Intrinsic { dst: None, name: callee, args })
            } else {
                Ok(Instr::Call { dst: None, callee, args })
            }
        }
        "rpc" => parse_rpc(p, None),
        "launch" => {
            let region = p.expect_global()?;
            let arg = if p.eat(Tok::LParen) {
                let a = parse_operand(p)?;
                p.expect(Tok::RParen)?;
                Some(a)
            } else {
                None
            };
            Ok(Instr::KernelLaunch { region, arg })
        }
        "if" => {
            let cond = parse_operand(p)?;
            let then_body = parse_block(p)?;
            let else_body = if p.eat_word("else") { parse_block(p)? } else { Vec::new() };
            Ok(Instr::If { cond, then_body, else_body })
        }
        "while" => {
            let cond_var = p.expect_var()?;
            let cond = parse_block(p)?;
            let body = parse_block(p)?;
            Ok(Instr::While { cond_var, cond, body })
        }
        "for" | "for.team" | "for.grid" => {
            let schedule = match word.as_str() {
                "for" => Schedule::Seq,
                "for.team" => Schedule::Team,
                _ => Schedule::Grid,
            };
            let var = p.expect_var()?;
            p.expect(Tok::Assign)?;
            let lo = parse_operand(p)?;
            p.expect_word_eq("to")?;
            let hi = parse_operand(p)?;
            p.expect_word_eq("step")?;
            let step = parse_operand(p)?;
            let body = parse_block(p)?;
            Ok(Instr::For { var, lo, hi, step, schedule, body })
        }
        "parallel" => {
            let num_threads = if p.eat_word("num_threads") {
                p.expect(Tok::LParen)?;
                let n = parse_operand(p)?;
                p.expect(Tok::RParen)?;
                Some(n)
            } else {
                None
            };
            let body = parse_block(p)?;
            Ok(Instr::Parallel { num_threads, body })
        }
        "barrier" => Ok(Instr::Barrier),
        "return" => {
            // A return value must be on the same conceptual statement; an
            // operand is present unless the next token starts a new instr.
            match p.peek() {
                Some(Tok::Var(_))
                | Some(Tok::Int(_))
                | Some(Tok::Float(_))
                | Some(Tok::GlobalRef(_)) => Ok(Instr::Return(Some(parse_operand(p)?))),
                _ => Ok(Instr::Return(None)),
            }
        }
        other => Err(format!("unexpected instruction {other:?}")),
    }
}

fn parse_rhs(p: &mut P, dst: String) -> Result<Instr, String> {
    // %dst = <int|float|var|global> | <unop/binop/...> | alloca | load | call | rpc
    match p.peek() {
        Some(Tok::Int(_)) | Some(Tok::Float(_)) | Some(Tok::Var(_)) | Some(Tok::GlobalRef(_)) => {
            let o = parse_operand(p)?;
            return Ok(Instr::Assign { dst, expr: Expr::Op(o) });
        }
        _ => {}
    }
    let word = p.expect_word()?;
    match word.as_str() {
        "alloca" => {
            let size = p.expect_int()? as u64;
            Ok(Instr::Alloca { dst, size })
        }
        w if w.starts_with("load.") || w.starts_with("loadf.") => {
            let (ty, width_s) = if let Some(rest) = w.strip_prefix("loadf.") {
                (Ty::F64, rest)
            } else {
                (Ty::I64, &w[5..])
            };
            let width: Width = width_s.parse().map_err(|_| format!("bad width {w}"))?;
            let addr = parse_operand(p)?;
            Ok(Instr::Load { dst, addr, width, ty })
        }
        "call" => {
            let callee = p.expect_word()?;
            let args = parse_args(p)?;
            if Module::is_native_intrinsic(&callee) {
                Ok(Instr::Intrinsic { dst: Some(dst), name: callee, args })
            } else {
                Ok(Instr::Call { dst: Some(dst), callee, args })
            }
        }
        "rpc" => parse_rpc(p, Some(dst)),
        "gep" => {
            let a = parse_operand(p)?;
            p.expect(Tok::Comma)?;
            let b = parse_operand(p)?;
            Ok(Instr::Assign { dst, expr: Expr::Gep(a, b) })
        }
        "select" => {
            let c = parse_operand(p)?;
            p.expect(Tok::Comma)?;
            let a = parse_operand(p)?;
            p.expect(Tok::Comma)?;
            let b = parse_operand(p)?;
            Ok(Instr::Assign { dst, expr: Expr::Select(c, a, b) })
        }
        "sitofp" => Ok(Instr::Assign { dst, expr: Expr::SiToFp(parse_operand(p)?) }),
        "fptosi" => Ok(Instr::Assign { dst, expr: Expr::FpToSi(parse_operand(p)?) }),
        "tid" => Ok(Instr::Assign { dst, expr: Expr::Tid }),
        "nthreads" => Ok(Instr::Assign { dst, expr: Expr::NumThreads }),
        "sqrt" => Ok(Instr::Assign { dst, expr: Expr::Sqrt(parse_operand(p)?) }),
        "exp" => Ok(Instr::Assign { dst, expr: Expr::Exp(parse_operand(p)?) }),
        "log" => Ok(Instr::Assign { dst, expr: Expr::Log(parse_operand(p)?) }),
        other => {
            let b = binop_from_name(other).ok_or_else(|| format!("unknown rhs {other:?}"))?;
            let x = parse_operand(p)?;
            p.expect(Tok::Comma)?;
            let y = parse_operand(p)?;
            Ok(Instr::Assign { dst, expr: Expr::Bin(b, x, y) })
        }
    }
}

fn parse_rpc(p: &mut P, dst: Option<String>) -> Result<Instr, String> {
    let Tok::Str(mangled) = p.bump().clone() else {
        return Err("rpc expects mangled name string".into());
    };
    let callee_id = p.expect_int()? as u64;
    p.expect(Tok::LParen)?;
    let mut args = Vec::new();
    while !p.eat(Tok::RParen) {
        args.push(parse_spec(p)?);
        if !p.eat(Tok::Comma) {
            p.expect(Tok::RParen)?;
            break;
        }
    }
    Ok(Instr::RpcCall { dst, mangled, callee_id, args })
}

fn parse_mode(p: &mut P) -> Result<ArgMode, String> {
    match p.expect_word()?.as_str() {
        "r" => Ok(ArgMode::Read),
        "w" => Ok(ArgMode::Write),
        "rw" => Ok(ArgMode::ReadWrite),
        m => Err(format!("bad arg mode {m:?}")),
    }
}

fn parse_offset(p: &mut P) -> Result<OffsetSpec, String> {
    p.expect(Tok::Plus)?;
    if p.eat_word("dyn") {
        Ok(OffsetSpec::Dynamic)
    } else {
        Ok(OffsetSpec::Const(p.expect_int()? as u64))
    }
}

fn parse_spec(p: &mut P) -> Result<RpcArgSpec, String> {
    match p.expect_word()?.as_str() {
        "val" => Ok(RpcArgSpec::Val(parse_operand(p)?)),
        "ref" => {
            let ptr = parse_operand(p)?;
            let mode = parse_mode(p)?;
            let obj_size = p.expect_int()? as u64;
            let offset = parse_offset(p)?;
            Ok(RpcArgSpec::Ref { ptr, mode, obj_size, offset })
        }
        "dyn" => {
            let ptr = parse_operand(p)?;
            let mode = parse_mode(p)?;
            Ok(RpcArgSpec::DynRef { ptr, mode })
        }
        "multi" => {
            let ptr = parse_operand(p)?;
            p.expect(Tok::LBracket)?;
            let mut candidates = Vec::new();
            loop {
                let c = parse_operand(p)?;
                let m = parse_mode(p)?;
                let s = p.expect_int()? as u64;
                let o = parse_offset(p)?;
                candidates.push((c, m, s, o));
                if p.eat(Tok::Semi) {
                    continue;
                }
                p.expect(Tok::RBracket)?;
                break;
            }
            Ok(RpcArgSpec::MultiRef { ptr, candidates })
        }
        s => Err(format!("bad rpc arg spec {s:?}")),
    }
}

fn parse_args(p: &mut P) -> Result<Vec<Operand>, String> {
    p.expect(Tok::LParen)?;
    let mut args = Vec::new();
    while !p.eat(Tok::RParen) {
        args.push(parse_operand(p)?);
        if !p.eat(Tok::Comma) {
            p.expect(Tok::RParen)?;
            break;
        }
    }
    Ok(args)
}

fn parse_operand(p: &mut P) -> Result<Operand, String> {
    match p.bump().clone() {
        Tok::Var(v) => Ok(Operand::Var(v)),
        Tok::GlobalRef(g) => Ok(Operand::Global(g)),
        Tok::Int(i) => Ok(Operand::ConstI(i)),
        Tok::Float(f) => Ok(Operand::ConstF(f)),
        t => Err(format!("expected operand, got {t:?}")),
    }
}

fn binop_from_name(s: &str) -> Option<BinOp> {
    Some(match s {
        "add" => BinOp::Add,
        "sub" => BinOp::Sub,
        "mul" => BinOp::Mul,
        "div" => BinOp::Div,
        "rem" => BinOp::Rem,
        "and" => BinOp::And,
        "or" => BinOp::Or,
        "xor" => BinOp::Xor,
        "shl" => BinOp::Shl,
        "shr" => BinOp::Shr,
        "eq" => BinOp::Eq,
        "ne" => BinOp::Ne,
        "lt" => BinOp::Lt,
        "le" => BinOp::Le,
        "gt" => BinOp::Gt,
        "ge" => BinOp::Ge,
        "fadd" => BinOp::FAdd,
        "fsub" => BinOp::FSub,
        "fmul" => BinOp::FMul,
        "fdiv" => BinOp::FDiv,
        "flt" => BinOp::FLt,
        "fle" => BinOp::FLe,
        "fgt" => BinOp::FGt,
        "fge" => BinOp::FGe,
        "feq" => BinOp::FEq,
        _ => return None,
    })
}

// ---- lexer ----

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Word(String),
    Var(String),
    GlobalRef(String),
    Int(i64),
    Float(f64),
    Str(String),
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Comma,
    Colon,
    Semi,
    Assign,
    Arrow,
    Plus,
}

fn lex(src: &str) -> Result<Vec<Tok>, String> {
    let b: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match c {
            ';' => {
                // `;` inside rpc multi-lists is Semi; comments are `;;`? No:
                // a lone `;` followed by space inside brackets is Semi; line
                // comments start with `;` at which point we skip to EOL —
                // disambiguate: comment only if previous token closed a
                // statement. Simpler rule: `;;` comments.
                if i + 1 < b.len() && b[i + 1] == ';' {
                    while i < b.len() && b[i] != '\n' {
                        i += 1;
                    }
                } else {
                    toks.push(Tok::Semi);
                    i += 1;
                }
            }
            c if c.is_whitespace() => i += 1,
            '(' => {
                toks.push(Tok::LParen);
                i += 1;
            }
            ')' => {
                toks.push(Tok::RParen);
                i += 1;
            }
            '{' => {
                toks.push(Tok::LBrace);
                i += 1;
            }
            '}' => {
                toks.push(Tok::RBrace);
                i += 1;
            }
            '[' => {
                toks.push(Tok::LBracket);
                i += 1;
            }
            ']' => {
                toks.push(Tok::RBracket);
                i += 1;
            }
            ',' => {
                toks.push(Tok::Comma);
                i += 1;
            }
            ':' => {
                toks.push(Tok::Colon);
                i += 1;
            }
            '+' => {
                toks.push(Tok::Plus);
                i += 1;
            }
            '=' => {
                toks.push(Tok::Assign);
                i += 1;
            }
            '-' if i + 1 < b.len() && b[i + 1] == '>' => {
                toks.push(Tok::Arrow);
                i += 2;
            }
            '%' => {
                i += 1;
                let start = i;
                while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_' || b[i] == '.') {
                    i += 1;
                }
                toks.push(Tok::Var(b[start..i].iter().collect()));
            }
            '@' => {
                i += 1;
                let start = i;
                while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_' || b[i] == '.') {
                    i += 1;
                }
                toks.push(Tok::GlobalRef(b[start..i].iter().collect()));
            }
            '"' => {
                i += 1;
                let mut s = String::new();
                while i < b.len() && b[i] != '"' {
                    if b[i] == '\\' && i + 1 < b.len() {
                        i += 1;
                        s.push(match b[i] {
                            'n' => '\n',
                            't' => '\t',
                            '\\' => '\\',
                            '"' => '"',
                            c => c,
                        });
                    } else {
                        s.push(b[i]);
                    }
                    i += 1;
                }
                if i >= b.len() {
                    return Err("unterminated string".into());
                }
                i += 1;
                toks.push(Tok::Str(s));
            }
            c if c == '-' || c.is_ascii_digit() => {
                let start = i;
                i += 1;
                let mut is_float = false;
                while i < b.len()
                    && (b[i].is_ascii_digit()
                        || b[i] == '.'
                        || b[i] == 'e'
                        || b[i] == 'E'
                        || ((b[i] == '-' || b[i] == '+') && (b[i - 1] == 'e' || b[i - 1] == 'E')))
                {
                    if b[i] == '.' || b[i] == 'e' || b[i] == 'E' {
                        is_float = true;
                    }
                    i += 1;
                }
                let text: String = b[start..i].iter().collect();
                if is_float {
                    toks.push(Tok::Float(
                        text.parse().map_err(|e| format!("bad float {text}: {e}"))?,
                    ));
                } else {
                    toks.push(Tok::Int(text.parse().map_err(|e| format!("bad int {text}: {e}"))?));
                }
            }
            c if c.is_alphabetic() || c == '_' => {
                let start = i;
                while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_' || b[i] == '.') {
                    i += 1;
                }
                toks.push(Tok::Word(b[start..i].iter().collect()));
            }
            c => return Err(format!("unexpected character {c:?}")),
        }
    }
    Ok(toks)
}

struct P {
    toks: Vec<Tok>,
    i: usize,
}

impl P {
    fn done(&self) -> bool {
        self.i >= self.toks.len()
    }

    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.i)
    }

    fn peek_word(&self) -> Option<&str> {
        match self.peek() {
            Some(Tok::Word(w)) => Some(w),
            _ => None,
        }
    }

    fn bump(&mut self) -> &Tok {
        let t = &self.toks[self.i.min(self.toks.len() - 1)];
        self.i += 1;
        t
    }

    fn eat(&mut self, t: Tok) -> bool {
        if self.peek() == Some(&t) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn eat_word(&mut self, w: &str) -> bool {
        if self.peek_word() == Some(w) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect(&mut self, t: Tok) -> Result<(), String> {
        if self.eat(t.clone()) {
            Ok(())
        } else {
            Err(format!("expected {t:?}, got {:?} at token {}", self.peek(), self.i))
        }
    }

    fn expect_word(&mut self) -> Result<String, String> {
        match self.peek() {
            Some(Tok::Word(w)) => {
                let w = w.clone();
                self.i += 1;
                Ok(w)
            }
            t => Err(format!("expected word, got {t:?}")),
        }
    }

    fn expect_word_eq(&mut self, w: &str) -> Result<(), String> {
        let got = self.expect_word()?;
        if got == w {
            Ok(())
        } else {
            Err(format!("expected {w:?}, got {got:?}"))
        }
    }

    fn expect_var(&mut self) -> Result<String, String> {
        match self.peek() {
            Some(Tok::Var(v)) => {
                let v = v.clone();
                self.i += 1;
                Ok(v)
            }
            t => Err(format!("expected %var, got {t:?}")),
        }
    }

    fn expect_global(&mut self) -> Result<String, String> {
        match self.peek() {
            Some(Tok::GlobalRef(g)) => {
                let g = g.clone();
                self.i += 1;
                Ok(g)
            }
            t => Err(format!("expected @global, got {t:?}")),
        }
    }

    fn expect_int(&mut self) -> Result<i64, String> {
        match self.peek() {
            Some(Tok::Int(i)) => {
                let i = *i;
                self.i += 1;
                Ok(i)
            }
            t => Err(format!("expected integer, got {t:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::printer::print_module;

    const EXAMPLE: &str = r#"
;; the Fig. 3a example, lowered
global @fmt const 9 "%f %i %i"
global @arr 64
extern fscanf

func @use(%s: ptr, %r: i64, %i: i64) -> void {
  return
}

func @main() -> i64 {
  %s = alloca 12
  %i = alloca 4
  %fd = 0
  %sa = load.4 %s
  %pb = gep %s, 4
  %pf = gep %s, 8
  %c = ne %sa, 0
  %p = select %c, %i, %pb
  %r = call fscanf(%fd, @fmt, %pf, %p, @arr)
  call use(%s, %r, %i)
  return 0
}
"#;

    #[test]
    fn parses_example_and_verifies() {
        let m = parse_module(EXAMPLE).unwrap();
        assert_eq!(m.functions.len(), 2);
        assert_eq!(m.externals, vec!["fscanf"]);
        assert!(m.globals["fmt"].constant);
        assert_eq!(m.globals["fmt"].init, b"%f %i %i\0");
        m.verify().unwrap();
    }

    #[test]
    fn print_parse_round_trip() {
        let m = parse_module(EXAMPLE).unwrap();
        let text = print_module(&m);
        let m2 = parse_module(&text).unwrap_or_else(|e| panic!("reparse failed: {e}\n{text}"));
        assert_eq!(m, m2);
    }

    #[test]
    fn parses_parallel_constructs() {
        let src = r#"
func @main() -> i64 {
  %n = 1024
  parallel num_threads(128) {
    %t = tid
    %nt = nthreads
    for.team %i = 0 to %n step 1 {
      %x = mul %i, 2
    }
    barrier
  }
  return 0
}
"#;
        let m = parse_module(src).unwrap();
        m.verify().unwrap();
        let text = print_module(&m);
        assert_eq!(parse_module(&text).unwrap(), m);
        let Instr::Parallel { body, .. } = &m.functions["main"].body[1] else {
            panic!()
        };
        assert!(matches!(body[2], Instr::For { schedule: Schedule::Team, .. }));
    }

    #[test]
    fn parses_rpc_and_launch_forms() {
        let src = r#"
func @region0() -> void kernel {
  return
}

func @main() -> i64 {
  %p = alloca 8
  %r = rpc "__fscanf_p_cp_ip" 3 (val 0, ref %p rw 8 +0, dyn %p rw, multi %p [ %p r 8 +0 ; %p rw 8 +dyn ])
  launch @region0 (%p)
  launch @region0
  return %r
}
"#;
        let m = parse_module(src).unwrap();
        m.verify().unwrap();
        let text = print_module(&m);
        assert_eq!(parse_module(&text).unwrap(), m, "round trip:\n{text}");
    }

    #[test]
    fn parses_while_and_floats() {
        let src = r#"
func @main() -> f64 {
  %x = 1.5
  %acc = 0.0
  %i = alloca 8
  store.8 0, %i
  while %c {
    %iv = load.8 %i
    %c = lt %iv, 10
  } {
    %iv2 = load.8 %i
    %iv3 = add %iv2, 1
    store.8 %iv3, %i
    %acc2 = fadd %acc, %x
  }
  return %acc
}
"#;
        let m = parse_module(src).unwrap();
        m.verify().unwrap();
        assert_eq!(parse_module(&print_module(&m)).unwrap(), m);
    }

    #[test]
    fn comments_ignored() {
        let src = ";; top comment\nfunc @main() -> i64 {\n  ;; inner\n  return 7\n}\n";
        let m = parse_module(src).unwrap();
        assert!(m.functions.contains_key("main"));
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse_module("func @f( -> i64 { }").is_err());
        assert!(parse_module("global @g const 4 \"too long\"").is_err());
        assert!(parse_module("func @f() -> i64 { %x = bogus 1, 2 }").is_err());
    }

    #[test]
    fn native_calls_become_intrinsics() {
        let src = "func @main() -> i64 {\n  %p = call malloc(64)\n  call free(%p)\n  return 0\n}\n";
        let m = parse_module(src).unwrap();
        assert!(matches!(
            &m.functions["main"].body[0],
            Instr::Intrinsic { name, .. } if name == "malloc"
        ));
    }
}
