//! The linear bytecode execution form (third executor tier).
//!
//! The register-file form ([`super::lowered`]) removed per-operand
//! hashing, but its body is still a pointer-chasing tree: every nested
//! `if`/`while`/`for` is a `Vec<LowInstr>` the interpreter recurses
//! into, so the hot loop pays a Rust call frame and a match on the
//! *structure* per block entry. The `bytecode` pass flattens each
//! lowered function into one contiguous `Vec<Op>` of fixed-width ops —
//! u32 register/pool operands, branches as resolved absolute pc targets
//! — executed by a flat `pc` loop ([`super::interp`]): no tree
//! recursion, no block lookup, and `parallel` regions can be stepped in
//! bounded quanta across a whole team batch.
//!
//! **Counter parity is the contract.** Every op derived from a
//! `LowInstr` charges exactly what the register core charges for that
//! instruction (superinstructions still charge both components);
//! flattening artifacts ([`Op::Jump`], [`Op::BrZeroFree`],
//! [`Op::ForHead`], [`Op::ForNext`]) charge *nothing*, so modeled
//! device counters are executor-invariant and `tests/lowering.rs` can
//! hold tree == register == bytecode exactly.
//!
//! Operand encoding: one u32 per operand. Bit 31 ([`POOL_BIT`]) tags a
//! constant-pool index; otherwise the u32 is a register slot. Variable-
//! length payloads (call/RPC/launch/parallel sites) live in side tables
//! so the op stream itself stays fixed-width. [`serialize`] /
//! [`deserialize`] give AOT artifacts a runnable on-disk encoding; the
//! deserializer rejects truncated or corrupt streams and re-validates
//! the result with [`validate`], the same checker the `bytecode` pass
//! runs on freshly flattened functions.

use super::lowered::{
    low_body_has_barrier, LowExpr, LowInstr, LowOffset, LowOp, LowRpcArg, LoweredFunction,
    PoolConst,
};
use super::{BinOp, Schedule, Ty, Width};
use crate::rpc::ArgMode;

/// Bit 31 of an operand word tags a constant-pool index; clear = slot.
pub const POOL_BIT: u32 = 1 << 31;

/// Encode a lowered operand into the u32 operand word.
#[inline]
pub fn enc(op: LowOp) -> u32 {
    match op {
        LowOp::Slot(s) => s,
        LowOp::Pool(p) => p | POOL_BIT,
    }
}

/// One fixed-width bytecode op. `u32` operand fields hold [`enc`]-tagged
/// slot/pool words; `dst`/`tmp`/`var`/`*_slot` fields are always plain
/// register slots; `target`/`exit`/`head` fields are absolute pc values.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    // ---- straight-line (each charges like its LowInstr) ----
    Mov { dst: u32, src: u32 },
    Bin { dst: u32, op: BinOp, a: u32, b: u32 },
    Gep { dst: u32, base: u32, off: u32 },
    Select { dst: u32, cond: u32, a: u32, b: u32 },
    SiToFp { dst: u32, a: u32 },
    FpToSi { dst: u32, a: u32 },
    Tid { dst: u32 },
    NumThreads { dst: u32 },
    Sqrt { dst: u32, a: u32 },
    Exp { dst: u32, a: u32 },
    Log { dst: u32, a: u32 },
    Alloca { dst: u32, size: u64 },
    Store { addr: u32, val: u32, width: Width },
    Load { dst: u32, addr: u32, width: Width, ty: Ty },
    Call { site: u32 },
    Intrinsic { site: u32 },
    Rpc { site: u32 },
    Launch { site: u32 },
    Barrier,
    Return { val: u32 },
    ReturnVoid,
    // ---- control flow ----
    /// `if` lowering: carries the `If` dispatch charge; branches to
    /// `target` when the condition is falsy.
    BrZero { cond: u32, target: u32 },
    /// `while` exit test (zero charge — the construct charged once at
    /// [`Op::LoopEntry`]).
    BrZeroFree { cond: u32, target: u32 },
    /// Unconditional branch; pure flattening artifact, zero charge.
    Jump { target: u32 },
    /// `while` entry: the construct's single dispatch charge.
    LoopEntry,
    /// `for` entry: dispatch charge + evaluate `lo`/`hi`/`step` once and
    /// apply the work-sharing schedule, writing the loop's three hidden
    /// slots (`i`, bound, stride — beyond the lowered `nslots`, so the
    /// body overwriting the induction variable cannot corrupt the loop).
    ForInit {
        lo: u32,
        hi: u32,
        step: u32,
        sched: Schedule,
        i_slot: u32,
        hi_slot: u32,
        stride_slot: u32,
    },
    /// `for` head test (zero charge): bind `var` and fall through, or
    /// branch to `exit`.
    ForHead { i_slot: u32, hi_slot: u32, var: u32, exit: u32 },
    /// `for` increment + back edge (zero charge).
    ForNext { i_slot: u32, stride_slot: u32, head: u32 },
    /// `parallel` region dispatch; the body is flattened inline at
    /// `[site.body_start, site.body_end)` and the dispatching thread
    /// jumps over it.
    Par { site: u32 },
    // ---- fused superinstructions (charge both components) ----
    CmpBr { tmp: u32, op: BinOp, a: u32, b: u32, else_target: u32 },
    GepLoad { tmp: u32, base: u32, off: u32, dst: u32, width: Width, ty: Ty },
    GepStore { tmp: u32, base: u32, off: u32, val: u32, width: Width },
    BinStore { tmp: u32, op: BinOp, a: u32, b: u32, addr: u32, width: Width },
}

/// Direct-call site (shared by [`Op::Call`] and [`Op::Intrinsic`]).
#[derive(Debug, Clone, PartialEq)]
pub struct CallSite {
    pub dst: Option<u32>,
    pub callee: String,
    pub args: Vec<u32>,
}

/// RPC argument descriptor with [`enc`]-tagged operand words — the
/// bytecode twin of [`LowRpcArg`], including the dynamic-offset `Ref`
/// representation (the offset is recovered at marshal time via the
/// object lookup, like the other executors).
#[derive(Debug, Clone, PartialEq)]
pub enum BcRpcArg {
    Val(u32),
    Ref { ptr: u32, mode: ArgMode, obj_size: u64, offset: LowOffset },
    MultiRef { ptr: u32, candidates: Vec<(u32, ArgMode, u64)> },
    DynRef { ptr: u32, mode: ArgMode },
}

#[derive(Debug, Clone, PartialEq)]
pub struct RpcSite {
    pub dst: Option<u32>,
    pub callee_id: u64,
    pub args: Vec<BcRpcArg>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct LaunchSite {
    pub region: String,
    pub arg: Option<u32>,
    pub params: Vec<u32>,
}

/// A `parallel` region: worker threads execute the inline body range;
/// `has_barrier` (precomputed at flatten time) picks cooperative vs
/// batched data-parallel dispatch.
#[derive(Debug, Clone, PartialEq)]
pub struct ParSite {
    pub num_threads: Option<u32>,
    pub body_start: u32,
    pub body_end: u32,
    pub has_barrier: bool,
}

/// One function flattened to linear bytecode. Lives alongside the tree
/// and lowered forms ([`super::Module::bytecode`]); the interpreter
/// prefers it when present.
#[derive(Debug, Clone, PartialEq)]
pub struct BytecodeFunction {
    /// Register-file size of one call frame, *including* the hidden
    /// per-`for` loop slots appended by flattening.
    pub nslots: u32,
    pub param_slots: Vec<u32>,
    /// Carried verbatim from the lowered form; resolved to `Value`s at
    /// program load exactly like the register core's pool.
    pub pool: Vec<PoolConst>,
    pub code: Vec<Op>,
    pub calls: Vec<CallSite>,
    pub rpcs: Vec<RpcSite>,
    pub launches: Vec<LaunchSite>,
    pub pars: Vec<ParSite>,
    /// Diagnostics side table (`--explain`); hidden loop slots get
    /// synthesized `<for.*>` names so `names[slot]` stays total.
    pub names: Vec<String>,
    /// Superinstructions carried through from the `fuse` pass.
    pub fused: u32,
}

// ---------------------------------------------------------------------
// Flattening
// ---------------------------------------------------------------------

/// Flatten one lowered function into linear bytecode. Infallible: every
/// lowered shape has a bytecode encoding (the result still goes through
/// [`validate`] in the `bytecode` pass as an internal-consistency
/// check).
pub fn flatten(lf: &LoweredFunction) -> BytecodeFunction {
    let mut fx = Flattener {
        bf: BytecodeFunction {
            nslots: lf.nslots,
            param_slots: lf.param_slots.clone(),
            pool: lf.pool.clone(),
            code: Vec::new(),
            calls: Vec::new(),
            rpcs: Vec::new(),
            launches: Vec::new(),
            pars: Vec::new(),
            names: lf.names.clone(),
            fused: lf.fused,
        },
    };
    fx.emit_body(&lf.body);
    fx.bf
}

struct Flattener {
    bf: BytecodeFunction,
}

impl Flattener {
    fn pc(&self) -> u32 {
        self.bf.code.len() as u32
    }

    fn push(&mut self, op: Op) -> usize {
        self.bf.code.push(op);
        self.bf.code.len() - 1
    }

    /// Allocate a hidden slot beyond the lowered register file (loop
    /// state the source program can never alias).
    fn hidden_slot(&mut self, tag: &str) -> u32 {
        let s = self.bf.nslots;
        self.bf.nslots += 1;
        self.bf.names.push(format!("<{tag}>"));
        s
    }

    fn patch(&mut self, at: usize, target: u32) {
        match &mut self.bf.code[at] {
            Op::BrZero { target: t, .. }
            | Op::BrZeroFree { target: t, .. }
            | Op::Jump { target: t }
            | Op::CmpBr { else_target: t, .. }
            | Op::ForHead { exit: t, .. } => *t = target,
            other => unreachable!("patching non-branch op {other:?}"),
        }
    }

    fn rpc_arg(a: &LowRpcArg) -> BcRpcArg {
        match a {
            LowRpcArg::Val(o) => BcRpcArg::Val(enc(*o)),
            LowRpcArg::Ref { ptr, mode, obj_size, offset } => BcRpcArg::Ref {
                ptr: enc(*ptr),
                mode: *mode,
                obj_size: *obj_size,
                offset: *offset,
            },
            LowRpcArg::MultiRef { ptr, candidates } => BcRpcArg::MultiRef {
                ptr: enc(*ptr),
                candidates: candidates.iter().map(|(c, m, s)| (enc(*c), *m, *s)).collect(),
            },
            LowRpcArg::DynRef { ptr, mode } => BcRpcArg::DynRef { ptr: enc(*ptr), mode: *mode },
        }
    }

    fn emit_body(&mut self, body: &[LowInstr]) {
        for ins in body {
            self.emit(ins);
        }
    }

    /// Emit a then/else pair ending at a join point: used by both `If`
    /// (via [`Op::BrZero`]) and the fused `CmpIf` (via [`Op::CmpBr`]).
    fn emit_branch_bodies(&mut self, br: usize, then_body: &[LowInstr], else_body: &[LowInstr]) {
        self.emit_body(then_body);
        if else_body.is_empty() {
            let join = self.pc();
            self.patch(br, join);
        } else {
            let jmp = self.push(Op::Jump { target: 0 });
            let else_start = self.pc();
            self.patch(br, else_start);
            self.emit_body(else_body);
            let join = self.pc();
            self.patch(jmp, join);
        }
    }

    fn emit(&mut self, ins: &LowInstr) {
        match ins {
            LowInstr::Assign { dst, expr } => {
                let d = *dst;
                let op = match expr {
                    LowExpr::Op(o) => Op::Mov { dst: d, src: enc(*o) },
                    LowExpr::Bin(op, a, b) => Op::Bin { dst: d, op: *op, a: enc(*a), b: enc(*b) },
                    LowExpr::Gep(a, b) => Op::Gep { dst: d, base: enc(*a), off: enc(*b) },
                    LowExpr::Select(c, a, b) => {
                        Op::Select { dst: d, cond: enc(*c), a: enc(*a), b: enc(*b) }
                    }
                    LowExpr::SiToFp(a) => Op::SiToFp { dst: d, a: enc(*a) },
                    LowExpr::FpToSi(a) => Op::FpToSi { dst: d, a: enc(*a) },
                    LowExpr::Tid => Op::Tid { dst: d },
                    LowExpr::NumThreads => Op::NumThreads { dst: d },
                    LowExpr::Sqrt(a) => Op::Sqrt { dst: d, a: enc(*a) },
                    LowExpr::Exp(a) => Op::Exp { dst: d, a: enc(*a) },
                    LowExpr::Log(a) => Op::Log { dst: d, a: enc(*a) },
                };
                self.push(op);
            }
            LowInstr::Alloca { dst, size } => {
                self.push(Op::Alloca { dst: *dst, size: *size });
            }
            LowInstr::Store { addr, val, width } => {
                self.push(Op::Store { addr: enc(*addr), val: enc(*val), width: *width });
            }
            LowInstr::Load { dst, addr, width, ty } => {
                self.push(Op::Load { dst: *dst, addr: enc(*addr), width: *width, ty: *ty });
            }
            LowInstr::Call { dst, callee, args } => {
                let site = self.bf.calls.len() as u32;
                self.bf.calls.push(CallSite {
                    dst: *dst,
                    callee: callee.clone(),
                    args: args.iter().map(|&a| enc(a)).collect(),
                });
                self.push(Op::Call { site });
            }
            LowInstr::Intrinsic { dst, name, args } => {
                let site = self.bf.calls.len() as u32;
                self.bf.calls.push(CallSite {
                    dst: *dst,
                    callee: name.clone(),
                    args: args.iter().map(|&a| enc(a)).collect(),
                });
                self.push(Op::Intrinsic { site });
            }
            LowInstr::RpcCall { dst, callee_id, args } => {
                let site = self.bf.rpcs.len() as u32;
                self.bf.rpcs.push(RpcSite {
                    dst: *dst,
                    callee_id: *callee_id,
                    args: args.iter().map(Self::rpc_arg).collect(),
                });
                self.push(Op::Rpc { site });
            }
            LowInstr::KernelLaunch { region, arg, params } => {
                let site = self.bf.launches.len() as u32;
                self.bf.launches.push(LaunchSite {
                    region: region.clone(),
                    arg: arg.map(enc),
                    params: params.iter().map(|&p| enc(p)).collect(),
                });
                self.push(Op::Launch { site });
            }
            LowInstr::If { cond, then_body, else_body } => {
                let br = self.push(Op::BrZero { cond: enc(*cond), target: 0 });
                self.emit_branch_bodies(br, then_body, else_body);
            }
            LowInstr::CmpIf { tmp, op, a, b, then_body, else_body } => {
                let br = self.push(Op::CmpBr {
                    tmp: *tmp,
                    op: *op,
                    a: enc(*a),
                    b: enc(*b),
                    else_target: 0,
                });
                self.emit_branch_bodies(br, then_body, else_body);
            }
            LowInstr::While { cond_var, cond, body } => {
                self.push(Op::LoopEntry);
                let head = self.pc();
                self.emit_body(cond);
                let exit_br = self.push(Op::BrZeroFree { cond: *cond_var, target: 0 });
                self.emit_body(body);
                self.push(Op::Jump { target: head });
                let exit = self.pc();
                self.patch(exit_br, exit);
            }
            LowInstr::For { var, lo, hi, step, schedule, body } => {
                let i_slot = self.hidden_slot("for.i");
                let hi_slot = self.hidden_slot("for.hi");
                let stride_slot = self.hidden_slot("for.stride");
                self.push(Op::ForInit {
                    lo: enc(*lo),
                    hi: enc(*hi),
                    step: enc(*step),
                    sched: *schedule,
                    i_slot,
                    hi_slot,
                    stride_slot,
                });
                let head = self.pc();
                let head_op = self.push(Op::ForHead { i_slot, hi_slot, var: *var, exit: 0 });
                self.emit_body(body);
                self.push(Op::ForNext { i_slot, stride_slot, head });
                let exit = self.pc();
                self.patch(head_op, exit);
            }
            LowInstr::Parallel { num_threads, body } => {
                let site = self.bf.pars.len();
                self.bf.pars.push(ParSite {
                    num_threads: num_threads.map(enc),
                    body_start: 0,
                    body_end: 0,
                    has_barrier: low_body_has_barrier(body),
                });
                self.push(Op::Par { site: site as u32 });
                let start = self.pc();
                self.emit_body(body);
                let end = self.pc();
                self.bf.pars[site].body_start = start;
                self.bf.pars[site].body_end = end;
            }
            LowInstr::Barrier => {
                self.push(Op::Barrier);
            }
            LowInstr::Return(v) => {
                match v {
                    Some(o) => self.push(Op::Return { val: enc(*o) }),
                    None => self.push(Op::ReturnVoid),
                };
            }
            LowInstr::GepLoad { tmp, base, off, dst, width, ty } => {
                self.push(Op::GepLoad {
                    tmp: *tmp,
                    base: enc(*base),
                    off: enc(*off),
                    dst: *dst,
                    width: *width,
                    ty: *ty,
                });
            }
            LowInstr::GepStore { tmp, base, off, val, width } => {
                self.push(Op::GepStore {
                    tmp: *tmp,
                    base: enc(*base),
                    off: enc(*off),
                    val: enc(*val),
                    width: *width,
                });
            }
            LowInstr::BinStore { tmp, op, a, b, addr, width } => {
                self.push(Op::BinStore {
                    tmp: *tmp,
                    op: *op,
                    a: enc(*a),
                    b: enc(*b),
                    addr: enc(*addr),
                    width: *width,
                });
            }
        }
    }
}

// ---------------------------------------------------------------------
// Validation (the loader's checker)
// ---------------------------------------------------------------------

/// Validate internal consistency: every operand word indexes inside the
/// register file / pool, every branch target lands in `[0, code.len()]`
/// (`code.len()` = fall-off-the-end), every side-table index exists,
/// widths are legal, and `parallel` body ranges are well-formed. Run by
/// the `bytecode` pass on fresh flattenings and by [`deserialize`] on
/// loaded artifacts.
pub fn validate(bf: &BytecodeFunction) -> Result<(), String> {
    let nslots = bf.nslots as usize;
    let npool = bf.pool.len();
    let end = bf.code.len() as u32;
    if bf.names.len() != nslots {
        return Err(format!("names table has {} entries for {nslots} slots", bf.names.len()));
    }
    let operand = |x: u32, what: &str| -> Result<(), String> {
        if x & POOL_BIT != 0 {
            let i = (x & !POOL_BIT) as usize;
            if i >= npool {
                return Err(format!("{what}: pool index {i} out of range (pool size {npool})"));
            }
        } else if x as usize >= nslots {
            return Err(format!("{what}: slot {x} out of range (nslots {nslots})"));
        }
        Ok(())
    };
    let slot = |s: u32, what: &str| -> Result<(), String> {
        if s as usize >= nslots {
            return Err(format!("{what}: slot {s} out of range (nslots {nslots})"));
        }
        Ok(())
    };
    let target = |t: u32, what: &str| -> Result<(), String> {
        if t > end {
            return Err(format!("{what}: pc target {t} beyond code end {end}"));
        }
        Ok(())
    };
    let width_ok = |w: Width, what: &str| -> Result<(), String> {
        if !matches!(w, 1 | 4 | 8) {
            return Err(format!("{what}: bad access width {w}"));
        }
        Ok(())
    };
    for (i, &s) in bf.param_slots.iter().enumerate() {
        slot(s, &format!("param {i}"))?;
    }
    for (pc, op) in bf.code.iter().enumerate() {
        let at = format!("op {pc}");
        match *op {
            Op::Mov { dst, src } => {
                slot(dst, &at)?;
                operand(src, &at)?;
            }
            Op::Bin { dst, a, b, .. } => {
                slot(dst, &at)?;
                operand(a, &at)?;
                operand(b, &at)?;
            }
            Op::Gep { dst, base, off } => {
                slot(dst, &at)?;
                operand(base, &at)?;
                operand(off, &at)?;
            }
            Op::Select { dst, cond, a, b } => {
                slot(dst, &at)?;
                operand(cond, &at)?;
                operand(a, &at)?;
                operand(b, &at)?;
            }
            Op::SiToFp { dst, a }
            | Op::FpToSi { dst, a }
            | Op::Sqrt { dst, a }
            | Op::Exp { dst, a }
            | Op::Log { dst, a } => {
                slot(dst, &at)?;
                operand(a, &at)?;
            }
            Op::Tid { dst } | Op::NumThreads { dst } | Op::Alloca { dst, .. } => slot(dst, &at)?,
            Op::Store { addr, val, width } => {
                operand(addr, &at)?;
                operand(val, &at)?;
                width_ok(width, &at)?;
            }
            Op::Load { dst, addr, width, .. } => {
                slot(dst, &at)?;
                operand(addr, &at)?;
                width_ok(width, &at)?;
            }
            Op::Call { site } | Op::Intrinsic { site } => {
                if site as usize >= bf.calls.len() {
                    return Err(format!("{at}: call site {site} out of range"));
                }
            }
            Op::Rpc { site } => {
                if site as usize >= bf.rpcs.len() {
                    return Err(format!("{at}: rpc site {site} out of range"));
                }
            }
            Op::Launch { site } => {
                if site as usize >= bf.launches.len() {
                    return Err(format!("{at}: launch site {site} out of range"));
                }
            }
            Op::Barrier | Op::ReturnVoid | Op::LoopEntry => {}
            Op::Return { val } => operand(val, &at)?,
            Op::BrZero { cond, target: t } | Op::BrZeroFree { cond, target: t } => {
                operand(cond, &at)?;
                target(t, &at)?;
            }
            Op::Jump { target: t } => target(t, &at)?,
            Op::ForInit { lo, hi, step, i_slot, hi_slot, stride_slot, .. } => {
                operand(lo, &at)?;
                operand(hi, &at)?;
                operand(step, &at)?;
                slot(i_slot, &at)?;
                slot(hi_slot, &at)?;
                slot(stride_slot, &at)?;
            }
            Op::ForHead { i_slot, hi_slot, var, exit } => {
                slot(i_slot, &at)?;
                slot(hi_slot, &at)?;
                slot(var, &at)?;
                target(exit, &at)?;
            }
            Op::ForNext { i_slot, stride_slot, head } => {
                slot(i_slot, &at)?;
                slot(stride_slot, &at)?;
                target(head, &at)?;
            }
            Op::Par { site } => {
                let Some(ps) = bf.pars.get(site as usize) else {
                    return Err(format!("{at}: parallel site {site} out of range"));
                };
                if let Some(n) = ps.num_threads {
                    operand(n, &at)?;
                }
                if ps.body_start > ps.body_end || ps.body_end > end {
                    return Err(format!(
                        "{at}: parallel body [{}, {}) outside code of {end} ops",
                        ps.body_start, ps.body_end
                    ));
                }
            }
            Op::CmpBr { tmp, a, b, else_target, .. } => {
                slot(tmp, &at)?;
                operand(a, &at)?;
                operand(b, &at)?;
                target(else_target, &at)?;
            }
            Op::GepLoad { tmp, base, off, dst, width, .. } => {
                slot(tmp, &at)?;
                operand(base, &at)?;
                operand(off, &at)?;
                slot(dst, &at)?;
                width_ok(width, &at)?;
            }
            Op::GepStore { tmp, base, off, val, width } => {
                slot(tmp, &at)?;
                operand(base, &at)?;
                operand(off, &at)?;
                operand(val, &at)?;
                width_ok(width, &at)?;
            }
            Op::BinStore { tmp, a, b, addr, width, .. } => {
                slot(tmp, &at)?;
                operand(a, &at)?;
                operand(b, &at)?;
                operand(addr, &at)?;
                width_ok(width, &at)?;
            }
        }
    }
    for (i, cs) in bf.calls.iter().enumerate() {
        let at = format!("call site {i}");
        if let Some(d) = cs.dst {
            slot(d, &at)?;
        }
        for &a in &cs.args {
            operand(a, &at)?;
        }
    }
    for (i, rs) in bf.rpcs.iter().enumerate() {
        let at = format!("rpc site {i}");
        if let Some(d) = rs.dst {
            slot(d, &at)?;
        }
        for a in &rs.args {
            match a {
                BcRpcArg::Val(o) | BcRpcArg::DynRef { ptr: o, .. } => operand(*o, &at)?,
                BcRpcArg::Ref { ptr, .. } => operand(*ptr, &at)?,
                BcRpcArg::MultiRef { ptr, candidates } => {
                    operand(*ptr, &at)?;
                    for (c, _, _) in candidates {
                        operand(*c, &at)?;
                    }
                }
            }
        }
    }
    for (i, ls) in bf.launches.iter().enumerate() {
        let at = format!("launch site {i}");
        if let Some(a) = ls.arg {
            operand(a, &at)?;
        }
        for &p in &ls.params {
            operand(p, &at)?;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Serialization (the AOT artifact encoding)
// ---------------------------------------------------------------------

const MAGIC: &[u8; 4] = b"GFBC";
const VERSION: u32 = 1;

/// Serialize one bytecode function to the on-disk artifact encoding
/// (little-endian, length-prefixed tables, magic + version header).
pub fn serialize(bf: &BytecodeFunction) -> Vec<u8> {
    let mut w = Vec::with_capacity(64 + bf.code.len() * 16);
    w.extend_from_slice(MAGIC);
    put_u32(&mut w, VERSION);
    put_u32(&mut w, bf.nslots);
    put_u32(&mut w, bf.param_slots.len() as u32);
    for &s in &bf.param_slots {
        put_u32(&mut w, s);
    }
    put_u32(&mut w, bf.pool.len() as u32);
    for c in &bf.pool {
        match c {
            PoolConst::I(i) => {
                w.push(0);
                put_u64(&mut w, *i as u64);
            }
            PoolConst::F(f) => {
                w.push(1);
                put_u64(&mut w, f.to_bits());
            }
            PoolConst::Global(g) => {
                w.push(2);
                put_str(&mut w, g);
            }
        }
    }
    put_u32(&mut w, bf.code.len() as u32);
    for op in &bf.code {
        put_op(&mut w, op);
    }
    put_u32(&mut w, bf.calls.len() as u32);
    for cs in &bf.calls {
        put_opt_u32(&mut w, cs.dst);
        put_str(&mut w, &cs.callee);
        put_u32(&mut w, cs.args.len() as u32);
        for &a in &cs.args {
            put_u32(&mut w, a);
        }
    }
    put_u32(&mut w, bf.rpcs.len() as u32);
    for rs in &bf.rpcs {
        put_opt_u32(&mut w, rs.dst);
        put_u64(&mut w, rs.callee_id);
        put_u32(&mut w, rs.args.len() as u32);
        for a in &rs.args {
            match a {
                BcRpcArg::Val(o) => {
                    w.push(0);
                    put_u32(&mut w, *o);
                }
                BcRpcArg::Ref { ptr, mode, obj_size, offset } => {
                    w.push(1);
                    put_u32(&mut w, *ptr);
                    w.push(mode_code(*mode));
                    put_u64(&mut w, *obj_size);
                    match offset {
                        LowOffset::Const(c) => {
                            w.push(0);
                            put_u64(&mut w, *c);
                        }
                        LowOffset::Dynamic => w.push(1),
                    }
                }
                BcRpcArg::MultiRef { ptr, candidates } => {
                    w.push(2);
                    put_u32(&mut w, *ptr);
                    put_u32(&mut w, candidates.len() as u32);
                    for (c, m, s) in candidates {
                        put_u32(&mut w, *c);
                        w.push(mode_code(*m));
                        put_u64(&mut w, *s);
                    }
                }
                BcRpcArg::DynRef { ptr, mode } => {
                    w.push(3);
                    put_u32(&mut w, *ptr);
                    w.push(mode_code(*mode));
                }
            }
        }
    }
    put_u32(&mut w, bf.launches.len() as u32);
    for ls in &bf.launches {
        put_str(&mut w, &ls.region);
        put_opt_u32(&mut w, ls.arg);
        put_u32(&mut w, ls.params.len() as u32);
        for &p in &ls.params {
            put_u32(&mut w, p);
        }
    }
    put_u32(&mut w, bf.pars.len() as u32);
    for ps in &bf.pars {
        put_opt_u32(&mut w, ps.num_threads);
        put_u32(&mut w, ps.body_start);
        put_u32(&mut w, ps.body_end);
        w.push(ps.has_barrier as u8);
    }
    put_u32(&mut w, bf.names.len() as u32);
    for n in &bf.names {
        put_str(&mut w, n);
    }
    put_u32(&mut w, bf.fused);
    w
}

/// Deserialize + validate a function artifact. Any truncation, trailing
/// garbage, unknown tag, or out-of-range index is a hard error — a
/// corrupt artifact can never reach the executor.
pub fn deserialize(buf: &[u8]) -> Result<BytecodeFunction, String> {
    let mut r = Reader { buf, pos: 0 };
    let magic = r.bytes(4)?;
    if magic != MAGIC {
        return Err(format!("bad magic {magic:?} (want {MAGIC:?})"));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(format!("unsupported bytecode version {version} (want {VERSION})"));
    }
    let nslots = r.u32()?;
    let param_slots = r.vec_u32("param slots")?;
    let npool = r.len("pool")?;
    let mut pool = Vec::with_capacity(npool);
    for _ in 0..npool {
        pool.push(match r.u8()? {
            0 => PoolConst::I(r.u64()? as i64),
            1 => PoolConst::F(f64::from_bits(r.u64()?)),
            2 => PoolConst::Global(r.str()?),
            t => return Err(format!("bad pool tag {t}")),
        });
    }
    let ncode = r.len("code")?;
    let mut code = Vec::with_capacity(ncode);
    for _ in 0..ncode {
        code.push(get_op(&mut r)?);
    }
    let ncalls = r.len("call table")?;
    let mut calls = Vec::with_capacity(ncalls);
    for _ in 0..ncalls {
        let dst = r.opt_u32()?;
        let callee = r.str()?;
        let args = r.vec_u32("call args")?;
        calls.push(CallSite { dst, callee, args });
    }
    let nrpcs = r.len("rpc table")?;
    let mut rpcs = Vec::with_capacity(nrpcs);
    for _ in 0..nrpcs {
        let dst = r.opt_u32()?;
        let callee_id = r.u64()?;
        let nargs = r.len("rpc args")?;
        let mut args = Vec::with_capacity(nargs);
        for _ in 0..nargs {
            args.push(match r.u8()? {
                0 => BcRpcArg::Val(r.u32()?),
                1 => {
                    let ptr = r.u32()?;
                    let mode = mode_from(r.u8()?)?;
                    let obj_size = r.u64()?;
                    let offset = match r.u8()? {
                        0 => LowOffset::Const(r.u64()?),
                        1 => LowOffset::Dynamic,
                        t => return Err(format!("bad offset tag {t}")),
                    };
                    BcRpcArg::Ref { ptr, mode, obj_size, offset }
                }
                2 => {
                    let ptr = r.u32()?;
                    let n = r.len("multiref candidates")?;
                    let mut candidates = Vec::with_capacity(n);
                    for _ in 0..n {
                        let c = r.u32()?;
                        let m = mode_from(r.u8()?)?;
                        let s = r.u64()?;
                        candidates.push((c, m, s));
                    }
                    BcRpcArg::MultiRef { ptr, candidates }
                }
                3 => {
                    let ptr = r.u32()?;
                    let mode = mode_from(r.u8()?)?;
                    BcRpcArg::DynRef { ptr, mode }
                }
                t => return Err(format!("bad rpc-arg tag {t}")),
            });
        }
        rpcs.push(RpcSite { dst, callee_id, args });
    }
    let nlaunches = r.len("launch table")?;
    let mut launches = Vec::with_capacity(nlaunches);
    for _ in 0..nlaunches {
        let region = r.str()?;
        let arg = r.opt_u32()?;
        let params = r.vec_u32("launch params")?;
        launches.push(LaunchSite { region, arg, params });
    }
    let npars = r.len("parallel table")?;
    let mut pars = Vec::with_capacity(npars);
    for _ in 0..npars {
        let num_threads = r.opt_u32()?;
        let body_start = r.u32()?;
        let body_end = r.u32()?;
        let has_barrier = match r.u8()? {
            0 => false,
            1 => true,
            t => return Err(format!("bad barrier flag {t}")),
        };
        pars.push(ParSite { num_threads, body_start, body_end, has_barrier });
    }
    let nnames = r.len("names table")?;
    let mut names = Vec::with_capacity(nnames);
    for _ in 0..nnames {
        names.push(r.str()?);
    }
    let fused = r.u32()?;
    if r.pos != r.buf.len() {
        return Err(format!("{} trailing bytes after function", r.buf.len() - r.pos));
    }
    let bf = BytecodeFunction {
        nslots,
        param_slots,
        pool,
        code,
        calls,
        rpcs,
        launches,
        pars,
        names,
        fused,
    };
    validate(&bf)?;
    Ok(bf)
}

// Op wire encoding: one kind byte, then the fields in declaration order.
fn put_op(w: &mut Vec<u8>, op: &Op) {
    match *op {
        Op::Mov { dst, src } => {
            w.push(0);
            put_u32(w, dst);
            put_u32(w, src);
        }
        Op::Bin { dst, op, a, b } => {
            w.push(1);
            put_u32(w, dst);
            w.push(binop_code(op));
            put_u32(w, a);
            put_u32(w, b);
        }
        Op::Gep { dst, base, off } => {
            w.push(2);
            put_u32(w, dst);
            put_u32(w, base);
            put_u32(w, off);
        }
        Op::Select { dst, cond, a, b } => {
            w.push(3);
            put_u32(w, dst);
            put_u32(w, cond);
            put_u32(w, a);
            put_u32(w, b);
        }
        Op::SiToFp { dst, a } => {
            w.push(4);
            put_u32(w, dst);
            put_u32(w, a);
        }
        Op::FpToSi { dst, a } => {
            w.push(5);
            put_u32(w, dst);
            put_u32(w, a);
        }
        Op::Tid { dst } => {
            w.push(6);
            put_u32(w, dst);
        }
        Op::NumThreads { dst } => {
            w.push(7);
            put_u32(w, dst);
        }
        Op::Sqrt { dst, a } => {
            w.push(8);
            put_u32(w, dst);
            put_u32(w, a);
        }
        Op::Exp { dst, a } => {
            w.push(9);
            put_u32(w, dst);
            put_u32(w, a);
        }
        Op::Log { dst, a } => {
            w.push(10);
            put_u32(w, dst);
            put_u32(w, a);
        }
        Op::Alloca { dst, size } => {
            w.push(11);
            put_u32(w, dst);
            put_u64(w, size);
        }
        Op::Store { addr, val, width } => {
            w.push(12);
            put_u32(w, addr);
            put_u32(w, val);
            w.push(width);
        }
        Op::Load { dst, addr, width, ty } => {
            w.push(13);
            put_u32(w, dst);
            put_u32(w, addr);
            w.push(width);
            w.push(ty_code(ty));
        }
        Op::Call { site } => {
            w.push(14);
            put_u32(w, site);
        }
        Op::Intrinsic { site } => {
            w.push(15);
            put_u32(w, site);
        }
        Op::Rpc { site } => {
            w.push(16);
            put_u32(w, site);
        }
        Op::Launch { site } => {
            w.push(17);
            put_u32(w, site);
        }
        Op::Barrier => w.push(18),
        Op::Return { val } => {
            w.push(19);
            put_u32(w, val);
        }
        Op::ReturnVoid => w.push(20),
        Op::BrZero { cond, target } => {
            w.push(21);
            put_u32(w, cond);
            put_u32(w, target);
        }
        Op::BrZeroFree { cond, target } => {
            w.push(22);
            put_u32(w, cond);
            put_u32(w, target);
        }
        Op::Jump { target } => {
            w.push(23);
            put_u32(w, target);
        }
        Op::LoopEntry => w.push(24),
        Op::ForInit { lo, hi, step, sched, i_slot, hi_slot, stride_slot } => {
            w.push(25);
            put_u32(w, lo);
            put_u32(w, hi);
            put_u32(w, step);
            w.push(sched_code(sched));
            put_u32(w, i_slot);
            put_u32(w, hi_slot);
            put_u32(w, stride_slot);
        }
        Op::ForHead { i_slot, hi_slot, var, exit } => {
            w.push(26);
            put_u32(w, i_slot);
            put_u32(w, hi_slot);
            put_u32(w, var);
            put_u32(w, exit);
        }
        Op::ForNext { i_slot, stride_slot, head } => {
            w.push(27);
            put_u32(w, i_slot);
            put_u32(w, stride_slot);
            put_u32(w, head);
        }
        Op::Par { site } => {
            w.push(28);
            put_u32(w, site);
        }
        Op::CmpBr { tmp, op, a, b, else_target } => {
            w.push(29);
            put_u32(w, tmp);
            w.push(binop_code(op));
            put_u32(w, a);
            put_u32(w, b);
            put_u32(w, else_target);
        }
        Op::GepLoad { tmp, base, off, dst, width, ty } => {
            w.push(30);
            put_u32(w, tmp);
            put_u32(w, base);
            put_u32(w, off);
            put_u32(w, dst);
            w.push(width);
            w.push(ty_code(ty));
        }
        Op::GepStore { tmp, base, off, val, width } => {
            w.push(31);
            put_u32(w, tmp);
            put_u32(w, base);
            put_u32(w, off);
            put_u32(w, val);
            w.push(width);
        }
        Op::BinStore { tmp, op, a, b, addr, width } => {
            w.push(32);
            put_u32(w, tmp);
            w.push(binop_code(op));
            put_u32(w, a);
            put_u32(w, b);
            put_u32(w, addr);
            w.push(width);
        }
    }
}

fn get_op(r: &mut Reader) -> Result<Op, String> {
    Ok(match r.u8()? {
        0 => Op::Mov { dst: r.u32()?, src: r.u32()? },
        1 => Op::Bin { dst: r.u32()?, op: binop_from(r.u8()?)?, a: r.u32()?, b: r.u32()? },
        2 => Op::Gep { dst: r.u32()?, base: r.u32()?, off: r.u32()? },
        3 => Op::Select { dst: r.u32()?, cond: r.u32()?, a: r.u32()?, b: r.u32()? },
        4 => Op::SiToFp { dst: r.u32()?, a: r.u32()? },
        5 => Op::FpToSi { dst: r.u32()?, a: r.u32()? },
        6 => Op::Tid { dst: r.u32()? },
        7 => Op::NumThreads { dst: r.u32()? },
        8 => Op::Sqrt { dst: r.u32()?, a: r.u32()? },
        9 => Op::Exp { dst: r.u32()?, a: r.u32()? },
        10 => Op::Log { dst: r.u32()?, a: r.u32()? },
        11 => Op::Alloca { dst: r.u32()?, size: r.u64()? },
        12 => Op::Store { addr: r.u32()?, val: r.u32()?, width: r.u8()? },
        13 => Op::Load { dst: r.u32()?, addr: r.u32()?, width: r.u8()?, ty: ty_from(r.u8()?)? },
        14 => Op::Call { site: r.u32()? },
        15 => Op::Intrinsic { site: r.u32()? },
        16 => Op::Rpc { site: r.u32()? },
        17 => Op::Launch { site: r.u32()? },
        18 => Op::Barrier,
        19 => Op::Return { val: r.u32()? },
        20 => Op::ReturnVoid,
        21 => Op::BrZero { cond: r.u32()?, target: r.u32()? },
        22 => Op::BrZeroFree { cond: r.u32()?, target: r.u32()? },
        23 => Op::Jump { target: r.u32()? },
        24 => Op::LoopEntry,
        25 => Op::ForInit {
            lo: r.u32()?,
            hi: r.u32()?,
            step: r.u32()?,
            sched: sched_from(r.u8()?)?,
            i_slot: r.u32()?,
            hi_slot: r.u32()?,
            stride_slot: r.u32()?,
        },
        26 => Op::ForHead { i_slot: r.u32()?, hi_slot: r.u32()?, var: r.u32()?, exit: r.u32()? },
        27 => Op::ForNext { i_slot: r.u32()?, stride_slot: r.u32()?, head: r.u32()? },
        28 => Op::Par { site: r.u32()? },
        29 => Op::CmpBr {
            tmp: r.u32()?,
            op: binop_from(r.u8()?)?,
            a: r.u32()?,
            b: r.u32()?,
            else_target: r.u32()?,
        },
        30 => Op::GepLoad {
            tmp: r.u32()?,
            base: r.u32()?,
            off: r.u32()?,
            dst: r.u32()?,
            width: r.u8()?,
            ty: ty_from(r.u8()?)?,
        },
        31 => Op::GepStore {
            tmp: r.u32()?,
            base: r.u32()?,
            off: r.u32()?,
            val: r.u32()?,
            width: r.u8()?,
        },
        32 => Op::BinStore {
            tmp: r.u32()?,
            op: binop_from(r.u8()?)?,
            a: r.u32()?,
            b: r.u32()?,
            addr: r.u32()?,
            width: r.u8()?,
        },
        k => return Err(format!("bad op kind {k}")),
    })
}

const BINOPS: [BinOp; 25] = [
    BinOp::Add,
    BinOp::Sub,
    BinOp::Mul,
    BinOp::Div,
    BinOp::Rem,
    BinOp::And,
    BinOp::Or,
    BinOp::Xor,
    BinOp::Shl,
    BinOp::Shr,
    BinOp::Eq,
    BinOp::Ne,
    BinOp::Lt,
    BinOp::Le,
    BinOp::Gt,
    BinOp::Ge,
    BinOp::FAdd,
    BinOp::FSub,
    BinOp::FMul,
    BinOp::FDiv,
    BinOp::FLt,
    BinOp::FLe,
    BinOp::FGt,
    BinOp::FGe,
    BinOp::FEq,
];

fn binop_code(op: BinOp) -> u8 {
    BINOPS.iter().position(|&o| o == op).expect("binop in table") as u8
}

fn binop_from(c: u8) -> Result<BinOp, String> {
    BINOPS.get(c as usize).copied().ok_or_else(|| format!("bad binop code {c}"))
}

fn ty_code(t: Ty) -> u8 {
    match t {
        Ty::I64 => 0,
        Ty::F64 => 1,
        Ty::Ptr => 2,
        Ty::Void => 3,
    }
}

fn ty_from(c: u8) -> Result<Ty, String> {
    Ok(match c {
        0 => Ty::I64,
        1 => Ty::F64,
        2 => Ty::Ptr,
        3 => Ty::Void,
        _ => return Err(format!("bad type code {c}")),
    })
}

fn sched_code(s: Schedule) -> u8 {
    match s {
        Schedule::Seq => 0,
        Schedule::Team => 1,
        Schedule::Grid => 2,
    }
}

fn sched_from(c: u8) -> Result<Schedule, String> {
    Ok(match c {
        0 => Schedule::Seq,
        1 => Schedule::Team,
        2 => Schedule::Grid,
        _ => return Err(format!("bad schedule code {c}")),
    })
}

fn mode_code(m: ArgMode) -> u8 {
    match m {
        ArgMode::Read => 0,
        ArgMode::Write => 1,
        ArgMode::ReadWrite => 2,
    }
}

fn mode_from(c: u8) -> Result<ArgMode, String> {
    Ok(match c {
        0 => ArgMode::Read,
        1 => ArgMode::Write,
        2 => ArgMode::ReadWrite,
        _ => return Err(format!("bad arg-mode code {c}")),
    })
}

fn put_u32(w: &mut Vec<u8>, v: u32) {
    w.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(w: &mut Vec<u8>, v: u64) {
    w.extend_from_slice(&v.to_le_bytes());
}

fn put_str(w: &mut Vec<u8>, s: &str) {
    put_u32(w, s.len() as u32);
    w.extend_from_slice(s.as_bytes());
}

fn put_opt_u32(w: &mut Vec<u8>, v: Option<u32>) {
    match v {
        Some(x) => {
            w.push(1);
            put_u32(w, x);
        }
        None => w.push(0),
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!(
                "truncated stream: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            ));
        }
        let b = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(b)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    /// A length prefix, sanity-bounded by the bytes actually remaining
    /// so a corrupt length can't trigger a huge allocation.
    fn len(&mut self, what: &str) -> Result<usize, String> {
        let n = self.u32()? as usize;
        if n > self.buf.len() - self.pos {
            return Err(format!("corrupt {what} length {n} exceeds remaining stream"));
        }
        Ok(n)
    }

    fn str(&mut self) -> Result<String, String> {
        let n = self.len("string")?;
        let b = self.bytes(n)?;
        String::from_utf8(b.to_vec()).map_err(|e| format!("bad utf-8 string: {e}"))
    }

    fn opt_u32(&mut self) -> Result<Option<u32>, String> {
        Ok(match self.u8()? {
            0 => None,
            1 => Some(self.u32()?),
            t => return Err(format!("bad option tag {t}")),
        })
    }

    fn vec_u32(&mut self, what: &str) -> Result<Vec<u32>, String> {
        let n = self.len(what)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;

    fn flatten_fn(src: &str, name: &str) -> BytecodeFunction {
        let mut m = parse_module(src).unwrap();
        let report = crate::transform::lower::run(&mut m);
        assert!(report.skipped.is_empty(), "{:?}", report.skipped);
        crate::transform::fuse::run(&mut m);
        flatten(&m.lowered[name])
    }

    const LOOPY: &str = r#"
global @buf 64

func @main() -> i64 {
  %sum = alloca 8
  store.8 0, %sum
  for %i = 0 to 8 step 1 {
    %off = mul %i, 8
    %p = gep @buf, %off
    store.8 %i, %p
    %s = load.8 %sum
    %s2 = add %s, %i
    store.8 %s2, %sum
  }
  %c = lt 1, 2
  if %c {
    %x = 7
  }
  %r = load.8 %sum
  return %r
}
"#;

    #[test]
    fn flattening_validates_and_resolves_branches() {
        let bf = flatten_fn(LOOPY, "main");
        validate(&bf).unwrap();
        // Three hidden slots for the single for loop.
        let m = {
            let mut m = parse_module(LOOPY).unwrap();
            crate::transform::lower::run(&mut m);
            m
        };
        assert_eq!(bf.nslots, m.lowered["main"].nslots + 3);
        assert_eq!(bf.names.len(), bf.nslots as usize);
        assert!(bf.names.iter().any(|n| n == "<for.i>"));
        // The loop flattened to init/head/next with a back edge.
        assert!(bf.code.iter().any(|o| matches!(o, Op::ForInit { .. })));
        let (head_pc, exit) = bf
            .code
            .iter()
            .enumerate()
            .find_map(|(pc, o)| match o {
                Op::ForHead { exit, .. } => Some((pc as u32, *exit)),
                _ => None,
            })
            .unwrap();
        let back = bf
            .code
            .iter()
            .find_map(|o| match o {
                Op::ForNext { head, .. } => Some(*head),
                _ => None,
            })
            .unwrap();
        assert_eq!(back, head_pc, "ForNext jumps back to the head");
        assert!(exit > head_pc && exit <= bf.code.len() as u32);
        // No tree recursion left: nothing nests.
        assert!(bf.code.len() > 8);
    }

    #[test]
    fn fused_ops_carry_through() {
        let bf = flatten_fn(LOOPY, "main");
        assert!(bf.fused > 0, "corpus fuses");
        let has_super = bf.code.iter().any(|o| {
            matches!(
                o,
                Op::CmpBr { .. } | Op::GepLoad { .. } | Op::GepStore { .. } | Op::BinStore { .. }
            )
        });
        assert!(has_super, "superinstructions survive flattening: {:?}", bf.code);
    }

    #[test]
    fn parallel_body_is_an_inline_range() {
        let src = r#"
func @main() -> i64 {
  parallel num_threads(4) {
    %t = tid
  }
  return 0
}
"#;
        let mut m = parse_module(src).unwrap();
        crate::transform::lower::run(&mut m);
        let bf = flatten(&m.lowered["main"]);
        validate(&bf).unwrap();
        assert_eq!(bf.pars.len(), 1);
        let ps = &bf.pars[0];
        assert!(ps.body_start < ps.body_end, "non-empty inline body");
        assert!(!ps.has_barrier);
        let par_pc = bf
            .code
            .iter()
            .position(|o| matches!(o, Op::Par { .. }))
            .unwrap() as u32;
        assert_eq!(ps.body_start, par_pc + 1, "body flattened right after the dispatch op");
    }

    #[test]
    fn round_trip_is_identity() {
        let bf = flatten_fn(LOOPY, "main");
        let bytes = serialize(&bf);
        let back = deserialize(&bytes).unwrap();
        assert_eq!(bf, back);
    }

    #[test]
    fn truncated_and_corrupt_streams_are_rejected() {
        let bf = flatten_fn(LOOPY, "main");
        let bytes = serialize(&bf);
        // Every strict prefix is rejected (truncation never panics).
        for cut in [0, 3, 4, 8, bytes.len() / 2, bytes.len() - 1] {
            assert!(deserialize(&bytes[..cut]).is_err(), "prefix of {cut} bytes must fail");
        }
        // Trailing garbage is rejected.
        let mut long = bytes.clone();
        long.push(0);
        assert!(deserialize(&long).unwrap_err().contains("trailing"));
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(deserialize(&bad).unwrap_err().contains("magic"));
        // A corrupt op-kind byte (first op starts right after the fixed
        // header + param/pool tables; flip it to an invalid kind).
        let mut corrupt = bytes.clone();
        // Find the code-section length prefix by re-serializing a copy
        // with a recognizable op count; simpler: flip a byte in the
        // middle and expect *an* error (decode or validation).
        let mid = corrupt.len() / 2;
        corrupt[mid] ^= 0xFF;
        // Either the stream fails to decode or validation catches the
        // inconsistency; silently succeeding with different content is
        // only possible for bytes in string payloads, which LOOPY's
        // mid-stream region (op stream) does not contain.
        match deserialize(&corrupt) {
            Err(_) => {}
            Ok(back) => assert_ne!(back, bf, "corruption must not round-trip silently"),
        }
    }

    #[test]
    fn validate_rejects_out_of_range_indices() {
        let mut bf = flatten_fn(LOOPY, "main");
        let ok = bf.clone();
        validate(&ok).unwrap();
        // Slot out of range.
        bf.code[0] = Op::Mov { dst: bf.nslots + 7, src: 0 };
        assert!(validate(&bf).unwrap_err().contains("out of range"));
        // Pool index out of range.
        let mut bf2 = ok.clone();
        bf2.code[0] = Op::Mov { dst: 0, src: POOL_BIT | 10_000 };
        assert!(validate(&bf2).unwrap_err().contains("pool index"));
        // Branch target beyond code end.
        let mut bf3 = ok.clone();
        bf3.code[0] = Op::Jump { target: bf3.code.len() as u32 + 1 };
        assert!(validate(&bf3).unwrap_err().contains("beyond code end"));
        // Call site out of range.
        let mut bf4 = ok.clone();
        bf4.code[0] = Op::Call { site: 99 };
        assert!(validate(&bf4).unwrap_err().contains("call site"));
    }
}
