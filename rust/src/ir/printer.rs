//! Textual IR emission. `parse(print(m)) == m` is property-tested.

use super::*;

pub fn print_module(m: &Module) -> String {
    let mut out = String::new();
    for g in m.globals.values() {
        out.push_str("global @");
        out.push_str(&g.name);
        if g.constant {
            out.push_str(" const");
        }
        if !g.init.is_empty() && g.init.iter().any(|&b| b != 0) {
            // String-initialized global (init includes the NUL).
            let text = String::from_utf8_lossy(&g.init[..g.init.len().saturating_sub(1)]);
            out.push_str(&format!(" {} \"{}\"", g.size, escape(&text)));
        } else {
            out.push_str(&format!(" {}", g.size));
        }
        out.push('\n');
    }
    for e in &m.externals {
        out.push_str(&format!("extern {e}\n"));
    }
    for f in m.functions.values() {
        out.push_str(&format!("\nfunc @{}(", f.name));
        for (i, p) in f.params.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("%{}: {}", p.name, p.ty));
        }
        out.push_str(&format!(") -> {}", f.ret));
        if f.is_kernel_region {
            out.push_str(" kernel");
        }
        out.push_str(" {\n");
        print_body(&mut out, &f.body, 1);
        out.push_str("}\n");
    }
    out
}

fn ind(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn print_body(out: &mut String, body: &[Instr], depth: usize) {
    for ins in body {
        ind(out, depth);
        match ins {
            Instr::Assign { dst, expr } => {
                out.push_str(&format!("%{dst} = {}", print_expr(expr)));
            }
            Instr::Alloca { dst, size } => out.push_str(&format!("%{dst} = alloca {size}")),
            Instr::Store { addr, val, width } => {
                out.push_str(&format!("store.{width} {}, {}", op(val), op(addr)))
            }
            Instr::Load { dst, addr, width, ty } => {
                let m = if *ty == Ty::F64 { "loadf" } else { "load" };
                out.push_str(&format!("%{dst} = {m}.{width} {}", op(addr)));
            }
            Instr::Call { dst, callee, args } => {
                if let Some(d) = dst {
                    out.push_str(&format!("%{d} = "));
                }
                out.push_str(&format!("call {callee}("));
                out.push_str(&args.iter().map(op).collect::<Vec<_>>().join(", "));
                out.push(')');
            }
            Instr::RpcCall { dst, mangled, callee_id, args } => {
                if let Some(d) = dst {
                    out.push_str(&format!("%{d} = "));
                }
                out.push_str(&format!("rpc \"{mangled}\" {callee_id} ("));
                out.push_str(&args.iter().map(print_spec).collect::<Vec<_>>().join(", "));
                out.push(')');
            }
            Instr::KernelLaunch { region, arg } => {
                out.push_str(&format!("launch @{region}"));
                if let Some(a) = arg {
                    out.push_str(&format!(" ({})", op(a)));
                }
            }
            Instr::If { cond, then_body, else_body } => {
                out.push_str(&format!("if {} {{\n", op(cond)));
                print_body(out, then_body, depth + 1);
                ind(out, depth);
                out.push('}');
                if !else_body.is_empty() {
                    out.push_str(" else {\n");
                    print_body(out, else_body, depth + 1);
                    ind(out, depth);
                    out.push('}');
                }
            }
            Instr::While { cond_var, cond, body } => {
                out.push_str(&format!("while %{cond_var} {{\n"));
                print_body(out, cond, depth + 1);
                ind(out, depth);
                out.push_str("} {\n");
                print_body(out, body, depth + 1);
                ind(out, depth);
                out.push('}');
            }
            Instr::For { var, lo, hi, step, schedule, body } => {
                let sched = match schedule {
                    Schedule::Seq => "for",
                    Schedule::Team => "for.team",
                    Schedule::Grid => "for.grid",
                };
                out.push_str(&format!(
                    "{sched} %{var} = {} to {} step {} {{\n",
                    op(lo),
                    op(hi),
                    op(step)
                ));
                print_body(out, body, depth + 1);
                ind(out, depth);
                out.push('}');
            }
            Instr::Parallel { num_threads, body } => {
                out.push_str("parallel");
                if let Some(n) = num_threads {
                    out.push_str(&format!(" num_threads({})", op(n)));
                }
                out.push_str(" {\n");
                print_body(out, body, depth + 1);
                ind(out, depth);
                out.push('}');
            }
            Instr::Barrier => out.push_str("barrier"),
            Instr::Return(v) => match v {
                Some(v) => out.push_str(&format!("return {}", op(v))),
                None => out.push_str("return"),
            },
            Instr::Intrinsic { dst, name, args } => {
                if let Some(d) = dst {
                    out.push_str(&format!("%{d} = "));
                }
                out.push_str(&format!("call {name}("));
                out.push_str(&args.iter().map(op).collect::<Vec<_>>().join(", "));
                out.push(')');
            }
        }
        out.push('\n');
    }
}

pub fn op(o: &Operand) -> String {
    match o {
        Operand::Var(v) => format!("%{v}"),
        Operand::ConstI(i) => i.to_string(),
        Operand::ConstF(f) => {
            if f.fract() == 0.0 && f.is_finite() {
                format!("{f:.1}")
            } else {
                format!("{f}")
            }
        }
        Operand::Global(g) => format!("@{g}"),
    }
}

fn print_expr(e: &Expr) -> String {
    match e {
        Expr::Op(a) => op(a),
        Expr::Bin(b, x, y) => format!("{} {}, {}", binop_name(*b), op(x), op(y)),
        Expr::Gep(b, o) => format!("gep {}, {}", op(b), op(o)),
        Expr::Select(c, a, b) => format!("select {}, {}, {}", op(c), op(a), op(b)),
        Expr::SiToFp(a) => format!("sitofp {}", op(a)),
        Expr::FpToSi(a) => format!("fptosi {}", op(a)),
        Expr::Tid => "tid".into(),
        Expr::NumThreads => "nthreads".into(),
        Expr::Sqrt(a) => format!("sqrt {}", op(a)),
        Expr::Exp(a) => format!("exp {}", op(a)),
        Expr::Log(a) => format!("log {}", op(a)),
    }
}

pub fn binop_name(b: BinOp) -> &'static str {
    match b {
        BinOp::Add => "add",
        BinOp::Sub => "sub",
        BinOp::Mul => "mul",
        BinOp::Div => "div",
        BinOp::Rem => "rem",
        BinOp::And => "and",
        BinOp::Or => "or",
        BinOp::Xor => "xor",
        BinOp::Shl => "shl",
        BinOp::Shr => "shr",
        BinOp::Eq => "eq",
        BinOp::Ne => "ne",
        BinOp::Lt => "lt",
        BinOp::Le => "le",
        BinOp::Gt => "gt",
        BinOp::Ge => "ge",
        BinOp::FAdd => "fadd",
        BinOp::FSub => "fsub",
        BinOp::FMul => "fmul",
        BinOp::FDiv => "fdiv",
        BinOp::FLt => "flt",
        BinOp::FLe => "fle",
        BinOp::FGt => "fgt",
        BinOp::FGe => "fge",
        BinOp::FEq => "feq",
    }
}

fn print_spec(s: &RpcArgSpec) -> String {
    let mode = |m: crate::rpc::ArgMode| match m {
        crate::rpc::ArgMode::Read => "r",
        crate::rpc::ArgMode::Write => "w",
        crate::rpc::ArgMode::ReadWrite => "rw",
    };
    let off = |o: &OffsetSpec| match o {
        OffsetSpec::Const(c) => format!("+{c}"),
        OffsetSpec::Dynamic => "+dyn".into(),
    };
    match s {
        RpcArgSpec::Val(o) => format!("val {}", op(o)),
        RpcArgSpec::Ref { ptr, mode: m, obj_size, offset } => {
            format!("ref {} {} {} {}", op(ptr), mode(*m), obj_size, off(offset))
        }
        RpcArgSpec::DynRef { ptr, mode: m } => format!("dyn {} {}", op(ptr), mode(*m)),
        RpcArgSpec::MultiRef { ptr, candidates } => {
            let cands = candidates
                .iter()
                .map(|(c, m, s, o)| format!("{} {} {} {}", op(c), mode(*m), s, off(o)))
                .collect::<Vec<_>>()
                .join(" ; ");
            format!("multi {} [ {cands} ]", op(ptr))
        }
    }
}

fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            '\n' => vec!['\\', 'n'],
            '\t' => vec!['\\', 't'],
            c => vec![c],
        })
        .collect()
}
