//! Textual IR emission. `parse(print(m)) == m` is property-tested.

use super::*;

pub fn print_module(m: &Module) -> String {
    let mut out = String::new();
    for g in m.globals.values() {
        out.push_str("global @");
        out.push_str(&g.name);
        if g.constant {
            out.push_str(" const");
        }
        if !g.init.is_empty() && g.init.iter().any(|&b| b != 0) {
            // String-initialized global (init includes the NUL).
            let text = String::from_utf8_lossy(&g.init[..g.init.len().saturating_sub(1)]);
            out.push_str(&format!(" {} \"{}\"", g.size, escape(&text)));
        } else {
            out.push_str(&format!(" {}", g.size));
        }
        out.push('\n');
    }
    for e in &m.externals {
        out.push_str(&format!("extern {e}\n"));
    }
    for f in m.functions.values() {
        out.push_str(&format!("\nfunc @{}(", f.name));
        for (i, p) in f.params.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("%{}: {}", p.name, p.ty));
        }
        out.push_str(&format!(") -> {}", f.ret));
        if f.is_kernel_region {
            out.push_str(" kernel");
        }
        out.push_str(" {\n");
        print_body(&mut out, &f.body, 1);
        out.push_str("}\n");
    }
    out
}

fn ind(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn print_body(out: &mut String, body: &[Instr], depth: usize) {
    for ins in body {
        ind(out, depth);
        match ins {
            Instr::Assign { dst, expr } => {
                out.push_str(&format!("%{dst} = {}", print_expr(expr)));
            }
            Instr::Alloca { dst, size } => out.push_str(&format!("%{dst} = alloca {size}")),
            Instr::Store { addr, val, width } => {
                out.push_str(&format!("store.{width} {}, {}", op(val), op(addr)))
            }
            Instr::Load { dst, addr, width, ty } => {
                let m = if *ty == Ty::F64 { "loadf" } else { "load" };
                out.push_str(&format!("%{dst} = {m}.{width} {}", op(addr)));
            }
            Instr::Call { dst, callee, args } => {
                if let Some(d) = dst {
                    out.push_str(&format!("%{d} = "));
                }
                out.push_str(&format!("call {callee}("));
                out.push_str(&args.iter().map(op).collect::<Vec<_>>().join(", "));
                out.push(')');
            }
            Instr::RpcCall { dst, mangled, callee_id, args } => {
                if let Some(d) = dst {
                    out.push_str(&format!("%{d} = "));
                }
                out.push_str(&format!("rpc \"{mangled}\" {callee_id} ("));
                out.push_str(&args.iter().map(print_spec).collect::<Vec<_>>().join(", "));
                out.push(')');
            }
            Instr::KernelLaunch { region, arg } => {
                out.push_str(&format!("launch @{region}"));
                if let Some(a) = arg {
                    out.push_str(&format!(" ({})", op(a)));
                }
            }
            Instr::If { cond, then_body, else_body } => {
                out.push_str(&format!("if {} {{\n", op(cond)));
                print_body(out, then_body, depth + 1);
                ind(out, depth);
                out.push('}');
                if !else_body.is_empty() {
                    out.push_str(" else {\n");
                    print_body(out, else_body, depth + 1);
                    ind(out, depth);
                    out.push('}');
                }
            }
            Instr::While { cond_var, cond, body } => {
                out.push_str(&format!("while %{cond_var} {{\n"));
                print_body(out, cond, depth + 1);
                ind(out, depth);
                out.push_str("} {\n");
                print_body(out, body, depth + 1);
                ind(out, depth);
                out.push('}');
            }
            Instr::For { var, lo, hi, step, schedule, body } => {
                let sched = match schedule {
                    Schedule::Seq => "for",
                    Schedule::Team => "for.team",
                    Schedule::Grid => "for.grid",
                };
                out.push_str(&format!(
                    "{sched} %{var} = {} to {} step {} {{\n",
                    op(lo),
                    op(hi),
                    op(step)
                ));
                print_body(out, body, depth + 1);
                ind(out, depth);
                out.push('}');
            }
            Instr::Parallel { num_threads, body } => {
                out.push_str("parallel");
                if let Some(n) = num_threads {
                    out.push_str(&format!(" num_threads({})", op(n)));
                }
                out.push_str(" {\n");
                print_body(out, body, depth + 1);
                ind(out, depth);
                out.push('}');
            }
            Instr::Barrier => out.push_str("barrier"),
            Instr::Return(v) => match v {
                Some(v) => out.push_str(&format!("return {}", op(v))),
                None => out.push_str("return"),
            },
            Instr::Intrinsic { dst, name, args } => {
                if let Some(d) = dst {
                    out.push_str(&format!("%{d} = "));
                }
                out.push_str(&format!("call {name}("));
                out.push_str(&args.iter().map(op).collect::<Vec<_>>().join(", "));
                out.push(')');
            }
        }
        out.push('\n');
    }
}

/// One-line rendering of an instruction head for diagnostics: leaf
/// instructions render exactly as `print_module` would; block
/// instructions render their header with `{ ... }` standing in for the
/// body.
pub fn render_instr(ins: &Instr) -> String {
    match ins {
        Instr::Assign { dst, expr } => format!("%{dst} = {}", print_expr(expr)),
        Instr::Alloca { dst, size } => format!("%{dst} = alloca {size}"),
        Instr::Store { addr, val, width } => {
            format!("store.{width} {}, {}", op(val), op(addr))
        }
        Instr::Load { dst, addr, width, ty } => {
            let m = if *ty == Ty::F64 { "loadf" } else { "load" };
            format!("%{dst} = {m}.{width} {}", op(addr))
        }
        Instr::Call { dst, callee, args } | Instr::Intrinsic { dst, name: callee, args } => {
            let head = match dst {
                Some(d) => format!("%{d} = "),
                None => String::new(),
            };
            format!(
                "{head}call {callee}({})",
                args.iter().map(op).collect::<Vec<_>>().join(", ")
            )
        }
        Instr::RpcCall { dst, mangled, callee_id, .. } => {
            let head = match dst {
                Some(d) => format!("%{d} = "),
                None => String::new(),
            };
            format!("{head}rpc \"{mangled}\" {callee_id} (...)")
        }
        Instr::KernelLaunch { region, .. } => format!("launch @{region}"),
        Instr::If { cond, .. } => format!("if {} {{ ... }}", op(cond)),
        Instr::While { cond_var, .. } => format!("while %{cond_var} {{ ... }}"),
        Instr::For { var, lo, hi, step, schedule, .. } => {
            let sched = match schedule {
                Schedule::Seq => "for",
                Schedule::Team => "for.team",
                Schedule::Grid => "for.grid",
            };
            format!(
                "{sched} %{var} = {} to {} step {} {{ ... }}",
                op(lo),
                op(hi),
                op(step)
            )
        }
        Instr::Parallel { .. } => "parallel { ... }".into(),
        Instr::Barrier => "barrier".into(),
        Instr::Return(Some(v)) => format!("return {}", op(v)),
        Instr::Return(None) => "return".into(),
    }
}

pub fn op(o: &Operand) -> String {
    match o {
        Operand::Var(v) => format!("%{v}"),
        Operand::ConstI(i) => i.to_string(),
        Operand::ConstF(f) => {
            if f.fract() == 0.0 && f.is_finite() {
                format!("{f:.1}")
            } else {
                format!("{f}")
            }
        }
        Operand::Global(g) => format!("@{g}"),
    }
}

fn print_expr(e: &Expr) -> String {
    match e {
        Expr::Op(a) => op(a),
        Expr::Bin(b, x, y) => format!("{} {}, {}", binop_name(*b), op(x), op(y)),
        Expr::Gep(b, o) => format!("gep {}, {}", op(b), op(o)),
        Expr::Select(c, a, b) => format!("select {}, {}, {}", op(c), op(a), op(b)),
        Expr::SiToFp(a) => format!("sitofp {}", op(a)),
        Expr::FpToSi(a) => format!("fptosi {}", op(a)),
        Expr::Tid => "tid".into(),
        Expr::NumThreads => "nthreads".into(),
        Expr::Sqrt(a) => format!("sqrt {}", op(a)),
        Expr::Exp(a) => format!("exp {}", op(a)),
        Expr::Log(a) => format!("log {}", op(a)),
    }
}

pub fn binop_name(b: BinOp) -> &'static str {
    match b {
        BinOp::Add => "add",
        BinOp::Sub => "sub",
        BinOp::Mul => "mul",
        BinOp::Div => "div",
        BinOp::Rem => "rem",
        BinOp::And => "and",
        BinOp::Or => "or",
        BinOp::Xor => "xor",
        BinOp::Shl => "shl",
        BinOp::Shr => "shr",
        BinOp::Eq => "eq",
        BinOp::Ne => "ne",
        BinOp::Lt => "lt",
        BinOp::Le => "le",
        BinOp::Gt => "gt",
        BinOp::Ge => "ge",
        BinOp::FAdd => "fadd",
        BinOp::FSub => "fsub",
        BinOp::FMul => "fmul",
        BinOp::FDiv => "fdiv",
        BinOp::FLt => "flt",
        BinOp::FLe => "fle",
        BinOp::FGt => "fgt",
        BinOp::FGe => "fge",
        BinOp::FEq => "feq",
    }
}

fn print_spec(s: &RpcArgSpec) -> String {
    let mode = |m: crate::rpc::ArgMode| match m {
        crate::rpc::ArgMode::Read => "r",
        crate::rpc::ArgMode::Write => "w",
        crate::rpc::ArgMode::ReadWrite => "rw",
    };
    let off = |o: &OffsetSpec| match o {
        OffsetSpec::Const(c) => format!("+{c}"),
        OffsetSpec::Dynamic => "+dyn".into(),
    };
    match s {
        RpcArgSpec::Val(o) => format!("val {}", op(o)),
        RpcArgSpec::Ref { ptr, mode: m, obj_size, offset } => {
            format!("ref {} {} {} {}", op(ptr), mode(*m), obj_size, off(offset))
        }
        RpcArgSpec::DynRef { ptr, mode: m } => format!("dyn {} {}", op(ptr), mode(*m)),
        RpcArgSpec::MultiRef { ptr, candidates } => {
            let cands = candidates
                .iter()
                .map(|(c, m, s, o)| format!("{} {} {} {}", op(c), mode(*m), s, off(o)))
                .collect::<Vec<_>>()
                .join(" ; ");
            format!("multi {} [ {cands} ]", op(ptr))
        }
    }
}

fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            '\n' => vec!['\\', 'n'],
            '\t' => vec!['\\', 't'],
            c => vec![c],
        })
        .collect()
}

// ---------------------------------------------------------------------
// Lowered (register-file) form dump. Diagnostics only — this is NOT
// part of the parse/print round trip: the lowered form is a derived
// artifact the `lower` pass re-creates from the tree IR, so
// `print_module` never emits it.

use super::lowered::{LowExpr, LowInstr, LowOffset, LowOp, LowRpcArg, LoweredFunction, PoolConst};

/// Render every lowered function in `m` (slots as `rN`, pool operands
/// as `cN`, superinstructions flagged `fused`) for `--explain` and
/// `compile` diagnostics.
pub fn print_lowered_module(m: &Module) -> String {
    let mut out = String::new();
    for (name, lf) in &m.lowered {
        out.push_str(&print_lowered_fn(name, lf));
    }
    out
}

/// Render one function's lowered form, with a slot legend mapping each
/// register back to the source-level name it was assigned for.
pub fn print_lowered_fn(name: &str, lf: &LoweredFunction) -> String {
    let mut out = String::new();
    let params =
        lf.param_slots.iter().map(|s| format!("r{s}")).collect::<Vec<_>>().join(", ");
    out.push_str(&format!(
        "lowered @{name}({params}) slots={} fused={} {{\n",
        lf.nslots, lf.fused
    ));
    if !lf.names.is_empty() {
        let legend = lf
            .names
            .iter()
            .enumerate()
            .map(|(i, n)| format!("r{i}=%{n}"))
            .collect::<Vec<_>>()
            .join(" ");
        out.push_str(&format!("  ; slots: {legend}\n"));
    }
    for (i, c) in lf.pool.iter().enumerate() {
        let v = match c {
            PoolConst::I(x) => x.to_string(),
            PoolConst::F(x) => format!("{x}"),
            PoolConst::Global(g) => format!("@{g}"),
        };
        out.push_str(&format!("  c{i} = {v}\n"));
    }
    print_low_body(&mut out, &lf.body, 1);
    out.push_str("}\n");
    out
}

fn lop(o: LowOp) -> String {
    match o {
        LowOp::Slot(s) => format!("r{s}"),
        LowOp::Pool(p) => format!("c{p}"),
    }
}

fn print_low_expr(e: &LowExpr) -> String {
    match e {
        LowExpr::Op(a) => lop(*a),
        LowExpr::Bin(b, x, y) => format!("{} {}, {}", binop_name(*b), lop(*x), lop(*y)),
        LowExpr::Gep(b, o) => format!("gep {}, {}", lop(*b), lop(*o)),
        LowExpr::Select(c, a, b) => {
            format!("select {}, {}, {}", lop(*c), lop(*a), lop(*b))
        }
        LowExpr::SiToFp(a) => format!("sitofp {}", lop(*a)),
        LowExpr::FpToSi(a) => format!("fptosi {}", lop(*a)),
        LowExpr::Tid => "tid".into(),
        LowExpr::NumThreads => "nthreads".into(),
        LowExpr::Sqrt(a) => format!("sqrt {}", lop(*a)),
        LowExpr::Exp(a) => format!("exp {}", lop(*a)),
        LowExpr::Log(a) => format!("log {}", lop(*a)),
    }
}

fn print_low_spec(s: &LowRpcArg) -> String {
    let mode = |m: crate::rpc::ArgMode| match m {
        crate::rpc::ArgMode::Read => "r",
        crate::rpc::ArgMode::Write => "w",
        crate::rpc::ArgMode::ReadWrite => "rw",
    };
    let off = |o: &LowOffset| match o {
        LowOffset::Const(c) => format!("+{c}"),
        LowOffset::Dynamic => "+dyn".into(),
    };
    match s {
        LowRpcArg::Val(o) => format!("val {}", lop(*o)),
        LowRpcArg::Ref { ptr, mode: m, obj_size, offset } => {
            format!("ref {} {} {} {}", lop(*ptr), mode(*m), obj_size, off(offset))
        }
        LowRpcArg::DynRef { ptr, mode: m } => format!("dyn {} {}", lop(*ptr), mode(*m)),
        LowRpcArg::MultiRef { ptr, candidates } => {
            let cands = candidates
                .iter()
                .map(|(c, m, s)| format!("{} {} {}", lop(*c), mode(*m), s))
                .collect::<Vec<_>>()
                .join(" ; ");
            format!("multi {} [ {cands} ]", lop(*ptr))
        }
    }
}

fn print_low_body(out: &mut String, body: &[LowInstr], depth: usize) {
    for ins in body {
        ind(out, depth);
        match ins {
            LowInstr::Assign { dst, expr } => {
                out.push_str(&format!("r{dst} = {}", print_low_expr(expr)))
            }
            LowInstr::Alloca { dst, size } => {
                out.push_str(&format!("r{dst} = alloca {size}"))
            }
            LowInstr::Store { addr, val, width } => {
                out.push_str(&format!("store.{width} {}, {}", lop(*val), lop(*addr)))
            }
            LowInstr::Load { dst, addr, width, ty } => {
                let m = if *ty == Ty::F64 { "loadf" } else { "load" };
                out.push_str(&format!("r{dst} = {m}.{width} {}", lop(*addr)));
            }
            LowInstr::Call { dst, callee, args } => {
                if let Some(d) = dst {
                    out.push_str(&format!("r{d} = "));
                }
                out.push_str(&format!("call {callee}("));
                out.push_str(&args.iter().map(|a| lop(*a)).collect::<Vec<_>>().join(", "));
                out.push(')');
            }
            LowInstr::RpcCall { dst, callee_id, args } => {
                if let Some(d) = dst {
                    out.push_str(&format!("r{d} = "));
                }
                out.push_str(&format!("rpc {callee_id} ("));
                out.push_str(&args.iter().map(print_low_spec).collect::<Vec<_>>().join(", "));
                out.push(')');
            }
            LowInstr::KernelLaunch { region, arg, params } => {
                out.push_str(&format!("launch @{region}"));
                if let Some(a) = arg {
                    out.push_str(&format!(" ({})", lop(*a)));
                }
                if !params.is_empty() {
                    let ps = params.iter().map(|p| lop(*p)).collect::<Vec<_>>().join(", ");
                    out.push_str(&format!(" params [{ps}]"));
                }
            }
            LowInstr::If { cond, then_body, else_body } => {
                out.push_str(&format!("if {} {{\n", lop(*cond)));
                print_low_body(out, then_body, depth + 1);
                ind(out, depth);
                out.push('}');
                if !else_body.is_empty() {
                    out.push_str(" else {\n");
                    print_low_body(out, else_body, depth + 1);
                    ind(out, depth);
                    out.push('}');
                }
            }
            LowInstr::While { cond_var, cond, body } => {
                out.push_str(&format!("while r{cond_var} {{\n"));
                print_low_body(out, cond, depth + 1);
                ind(out, depth);
                out.push_str("} {\n");
                print_low_body(out, body, depth + 1);
                ind(out, depth);
                out.push('}');
            }
            LowInstr::For { var, lo, hi, step, schedule, body } => {
                let sched = match schedule {
                    Schedule::Seq => "for",
                    Schedule::Team => "for.team",
                    Schedule::Grid => "for.grid",
                };
                out.push_str(&format!(
                    "{sched} r{var} = {} to {} step {} {{\n",
                    lop(*lo),
                    lop(*hi),
                    lop(*step)
                ));
                print_low_body(out, body, depth + 1);
                ind(out, depth);
                out.push('}');
            }
            LowInstr::Parallel { num_threads, body } => {
                out.push_str("parallel");
                if let Some(n) = num_threads {
                    out.push_str(&format!(" num_threads({})", lop(*n)));
                }
                out.push_str(" {\n");
                print_low_body(out, body, depth + 1);
                ind(out, depth);
                out.push('}');
            }
            LowInstr::Barrier => out.push_str("barrier"),
            LowInstr::Return(v) => match v {
                Some(v) => out.push_str(&format!("return {}", lop(*v))),
                None => out.push_str("return"),
            },
            LowInstr::Intrinsic { dst, name, args } => {
                if let Some(d) = dst {
                    out.push_str(&format!("r{d} = "));
                }
                out.push_str(&format!("call {name}("));
                out.push_str(&args.iter().map(|a| lop(*a)).collect::<Vec<_>>().join(", "));
                out.push(')');
            }
            LowInstr::CmpIf { tmp, op, a, b, then_body, else_body } => {
                out.push_str(&format!(
                    "fused cmp.if r{tmp} = {} {}, {} {{\n",
                    binop_name(*op),
                    lop(*a),
                    lop(*b)
                ));
                print_low_body(out, then_body, depth + 1);
                ind(out, depth);
                out.push('}');
                if !else_body.is_empty() {
                    out.push_str(" else {\n");
                    print_low_body(out, else_body, depth + 1);
                    ind(out, depth);
                    out.push('}');
                }
            }
            LowInstr::GepLoad { tmp, base, off, dst, width, ty } => {
                let m = if *ty == Ty::F64 { "loadf" } else { "load" };
                out.push_str(&format!(
                    "fused r{dst} = {m}.{width} [{} + {}] via r{tmp}",
                    lop(*base),
                    lop(*off)
                ));
            }
            LowInstr::GepStore { tmp, base, off, val, width } => {
                out.push_str(&format!(
                    "fused store.{width} {}, [{} + {}] via r{tmp}",
                    lop(*val),
                    lop(*base),
                    lop(*off)
                ));
            }
            LowInstr::BinStore { tmp, op, a, b, addr, width } => {
                out.push_str(&format!(
                    "fused store.{width} ({} {}, {} -> r{tmp}), {}",
                    binop_name(*op),
                    lop(*a),
                    lop(*b),
                    lop(*addr)
                ));
            }
        }
        out.push('\n');
    }
}

// ---------------------------------------------------------------------
// Bytecode dump. Diagnostics only, like the lowered dump: the linear
// form is a derived artifact the `bytecode` pass re-creates, so
// `print_module` never emits it. (The *runnable* encoding is
// `bytecode::serialize`, not this listing.)

use super::bytecode::{BcRpcArg, BytecodeFunction, Op, POOL_BIT};

/// Render every bytecode function in `m` (pc-numbered flat listing plus
/// the call/rpc/launch/parallel side tables) for `--explain`.
pub fn print_bytecode_module(m: &Module) -> String {
    let mut out = String::new();
    for (name, bf) in &m.bytecode {
        out.push_str(&print_bytecode_fn(name, bf));
    }
    out
}

/// Operand-word render: pool bit picks `cN` vs `rN`.
fn bop(x: u32) -> String {
    if x & POOL_BIT != 0 {
        format!("c{}", x & !POOL_BIT)
    } else {
        format!("r{x}")
    }
}

/// Render one function's linear bytecode with resolved pc targets.
pub fn print_bytecode_fn(name: &str, bf: &BytecodeFunction) -> String {
    let mut out = String::new();
    let params =
        bf.param_slots.iter().map(|s| format!("r{s}")).collect::<Vec<_>>().join(", ");
    out.push_str(&format!(
        "bytecode @{name}({params}) slots={} ops={} fused={} {{\n",
        bf.nslots,
        bf.code.len(),
        bf.fused
    ));
    if !bf.names.is_empty() {
        let legend = bf
            .names
            .iter()
            .enumerate()
            .map(|(i, n)| format!("r{i}=%{n}"))
            .collect::<Vec<_>>()
            .join(" ");
        out.push_str(&format!("  ; slots: {legend}\n"));
    }
    for (i, c) in bf.pool.iter().enumerate() {
        let v = match c {
            PoolConst::I(x) => x.to_string(),
            PoolConst::F(x) => format!("{x}"),
            PoolConst::Global(g) => format!("@{g}"),
        };
        out.push_str(&format!("  c{i} = {v}\n"));
    }
    for (pc, op) in bf.code.iter().enumerate() {
        out.push_str(&format!("  {pc:>4}: {}\n", print_bc_op(bf, *op)));
    }
    for (i, cs) in bf.calls.iter().enumerate() {
        let dst = cs.dst.map(|d| format!("r{d} = ")).unwrap_or_default();
        let args = cs.args.iter().map(|&a| bop(a)).collect::<Vec<_>>().join(", ");
        out.push_str(&format!("  ; call{i}: {dst}{}({args})\n", cs.callee));
    }
    for (i, rs) in bf.rpcs.iter().enumerate() {
        let dst = rs.dst.map(|d| format!("r{d} = ")).unwrap_or_default();
        let args = rs.args.iter().map(print_bc_spec).collect::<Vec<_>>().join(", ");
        out.push_str(&format!("  ; rpc{i}: {dst}rpc {} ({args})\n", rs.callee_id));
    }
    for (i, ls) in bf.launches.iter().enumerate() {
        let arg = ls.arg.map(|a| format!(" ({})", bop(a))).unwrap_or_default();
        let ps = ls.params.iter().map(|&p| bop(p)).collect::<Vec<_>>().join(", ");
        out.push_str(&format!("  ; launch{i}: @{}{arg} params [{ps}]\n", ls.region));
    }
    for (i, ps) in bf.pars.iter().enumerate() {
        let n = ps.num_threads.map(|o| bop(o)).unwrap_or_else(|| "default".into());
        out.push_str(&format!(
            "  ; par{i}: num_threads={n} body=[{}, {}) barrier={}\n",
            ps.body_start, ps.body_end, ps.has_barrier
        ));
    }
    out.push_str("}\n");
    out
}

fn print_bc_op(bf: &BytecodeFunction, op: Op) -> String {
    match op {
        Op::Mov { dst, src } => format!("r{dst} = mov {}", bop(src)),
        Op::Bin { dst, op, a, b } => {
            format!("r{dst} = {} {}, {}", binop_name(op), bop(a), bop(b))
        }
        Op::Gep { dst, base, off } => format!("r{dst} = gep {}, {}", bop(base), bop(off)),
        Op::Select { dst, cond, a, b } => {
            format!("r{dst} = select {}, {}, {}", bop(cond), bop(a), bop(b))
        }
        Op::SiToFp { dst, a } => format!("r{dst} = sitofp {}", bop(a)),
        Op::FpToSi { dst, a } => format!("r{dst} = fptosi {}", bop(a)),
        Op::Tid { dst } => format!("r{dst} = tid"),
        Op::NumThreads { dst } => format!("r{dst} = nthreads"),
        Op::Sqrt { dst, a } => format!("r{dst} = sqrt {}", bop(a)),
        Op::Exp { dst, a } => format!("r{dst} = exp {}", bop(a)),
        Op::Log { dst, a } => format!("r{dst} = log {}", bop(a)),
        Op::Alloca { dst, size } => format!("r{dst} = alloca {size}"),
        Op::Store { addr, val, width } => {
            format!("store.{width} {}, {}", bop(val), bop(addr))
        }
        Op::Load { dst, addr, width, ty } => {
            let m = if ty == Ty::F64 { "loadf" } else { "load" };
            format!("r{dst} = {m}.{width} {}", bop(addr))
        }
        Op::Call { site } => format!("call call{site} ({})", bf.calls[site as usize].callee),
        Op::Intrinsic { site } => {
            format!("intrinsic call{site} ({})", bf.calls[site as usize].callee)
        }
        Op::Rpc { site } => format!("rpc rpc{site}"),
        Op::Launch { site } => {
            format!("launch launch{site} (@{})", bf.launches[site as usize].region)
        }
        Op::Barrier => "barrier".into(),
        Op::Return { val } => format!("return {}", bop(val)),
        Op::ReturnVoid => "return".into(),
        Op::BrZero { cond, target } => format!("brz {} -> {target}", bop(cond)),
        Op::BrZeroFree { cond, target } => format!("brz.free r{cond} -> {target}"),
        Op::Jump { target } => format!("jump -> {target}"),
        Op::LoopEntry => "loop.entry".into(),
        Op::ForInit { lo, hi, step, sched, i_slot, hi_slot, stride_slot } => {
            let s = match sched {
                Schedule::Seq => "seq",
                Schedule::Team => "team",
                Schedule::Grid => "grid",
            };
            format!(
                "for.init.{s} r{i_slot},r{hi_slot},r{stride_slot} = {} to {} step {}",
                bop(lo),
                bop(hi),
                bop(step)
            )
        }
        Op::ForHead { i_slot, hi_slot, var, exit } => {
            format!("for.head r{var} = r{i_slot} < r{hi_slot} else -> {exit}")
        }
        Op::ForNext { i_slot, stride_slot, head } => {
            format!("for.next r{i_slot} += r{stride_slot} -> {head}")
        }
        Op::Par { site } => format!("par par{site}"),
        Op::CmpBr { tmp, op, a, b, else_target } => format!(
            "fused cmp.br r{tmp} = {} {}, {} else -> {else_target}",
            binop_name(op),
            bop(a),
            bop(b)
        ),
        Op::GepLoad { tmp, base, off, dst, width, ty } => {
            let m = if ty == Ty::F64 { "loadf" } else { "load" };
            format!("fused r{dst} = {m}.{width} [{} + {}] via r{tmp}", bop(base), bop(off))
        }
        Op::GepStore { tmp, base, off, val, width } => format!(
            "fused store.{width} {}, [{} + {}] via r{tmp}",
            bop(val),
            bop(base),
            bop(off)
        ),
        Op::BinStore { tmp, op, a, b, addr, width } => format!(
            "fused store.{width} ({} {}, {} -> r{tmp}), {}",
            binop_name(op),
            bop(a),
            bop(b),
            bop(addr)
        ),
    }
}

fn print_bc_spec(s: &BcRpcArg) -> String {
    let mode = |m: crate::rpc::ArgMode| match m {
        crate::rpc::ArgMode::Read => "r",
        crate::rpc::ArgMode::Write => "w",
        crate::rpc::ArgMode::ReadWrite => "rw",
    };
    let off = |o: &LowOffset| match o {
        LowOffset::Const(c) => format!("+{c}"),
        LowOffset::Dynamic => "+dyn".into(),
    };
    match s {
        BcRpcArg::Val(o) => format!("val {}", bop(*o)),
        BcRpcArg::Ref { ptr, mode: m, obj_size, offset } => {
            format!("ref {} {} {} {}", bop(*ptr), mode(*m), obj_size, off(offset))
        }
        BcRpcArg::DynRef { ptr, mode: m } => format!("dyn {} {}", bop(*ptr), mode(*m)),
        BcRpcArg::MultiRef { ptr, candidates } => {
            let cands = candidates
                .iter()
                .map(|(c, m, s)| format!("{} {} {}", bop(*c), mode(*m), s))
                .collect::<Vec<_>>()
                .join(" ; ");
            format!("multi {} [ {cands} ]", bop(*ptr))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lowered_dump_shows_slots_pool_and_superinstructions() {
        let lf = LoweredFunction {
            nslots: 3,
            param_slots: vec![0],
            pool: vec![PoolConst::I(7), PoolConst::Global("buf".into())],
            body: vec![
                LowInstr::Assign {
                    dst: 1,
                    expr: LowExpr::Bin(BinOp::Add, LowOp::Slot(0), LowOp::Pool(0)),
                },
                LowInstr::GepLoad {
                    tmp: 2,
                    base: LowOp::Pool(1),
                    off: LowOp::Slot(1),
                    dst: 1,
                    width: 8,
                    ty: Ty::I64,
                },
                LowInstr::Return(Some(LowOp::Slot(1))),
            ],
            names: vec!["n".into(), "x".into(), "t".into()],
            fused: 1,
        };
        let s = print_lowered_fn("f", &lf);
        assert!(s.contains("lowered @f(r0) slots=3 fused=1 {"), "{s}");
        assert!(s.contains("; slots: r0=%n r1=%x r2=%t"), "{s}");
        assert!(s.contains("c0 = 7"), "{s}");
        assert!(s.contains("c1 = @buf"), "{s}");
        assert!(s.contains("r1 = add r0, c0"), "{s}");
        assert!(s.contains("fused r1 = load.8 [c1 + r1] via r2"), "{s}");
        assert!(s.contains("return r1"), "{s}");
    }

    #[test]
    fn bytecode_dump_numbers_pcs_and_shows_loop_artifacts() {
        let src = "global @buf 64\n\nfunc @main() -> i64 {\n  for %i = 0 to 4 step 1 {\n    %off = mul %i, 8\n    %p = gep @buf, %off\n    store.8 %i, %p\n  }\n  return 0\n}\n";
        let mut m = crate::ir::parser::parse_module(src).unwrap();
        crate::transform::lower::run(&mut m);
        crate::transform::fuse::run(&mut m);
        crate::transform::bytecode::run(&mut m);
        let dump = print_bytecode_module(&m);
        assert!(dump.contains("bytecode @main"), "{dump}");
        assert!(dump.contains("   0: "), "pc-numbered listing: {dump}");
        assert!(dump.contains("for.init.seq"), "{dump}");
        assert!(dump.contains("for.head"), "{dump}");
        assert!(dump.contains("for.next"), "{dump}");
        assert!(dump.contains("=%<for.i>"), "hidden slots in the legend: {dump}");
        // Derived artifact: never part of the parse/print round trip.
        let printed = print_module(&m);
        assert!(!printed.contains("bytecode"), "{printed}");
        crate::ir::parser::parse_module(&printed).unwrap();
    }

    #[test]
    fn round_trip_output_never_includes_lowered_form() {
        // The lowered form is a derived artifact: print_module must stay
        // parseable, so the dump lives only in print_lowered_module.
        let src = "func @main() -> i64 {\n  %a = add 1, 2\n  return %a\n}\n";
        let mut m = crate::ir::parser::parse_module(src).unwrap();
        crate::transform::lower::run(&mut m);
        assert!(!m.lowered.is_empty());
        let printed = print_module(&m);
        assert!(!printed.contains("lowered"), "{printed}");
        assert!(!printed.contains("slots"), "{printed}");
        let dump = print_lowered_module(&m);
        assert!(dump.contains("lowered @main"), "{dump}");
        crate::ir::parser::parse_module(&printed).unwrap();
    }
}
