//! The lowered (register-file) execution form.
//!
//! The tree-walk IR resolves every operand through a
//! `HashMap<String, Value>` frame — a string hash per operand on the
//! interpreter's hottest path. The `lower` pass
//! ([`crate::transform::lower`]) compiles each function into this form
//! instead: every local gets a dense **register slot** (an index into a
//! per-call `Vec<Value>`), constants and global addresses are interned
//! into a per-function **constant pool** resolved once at load time, and
//! operands become [`LowOp`]s — two machine words, no strings, no
//! hashing. A follow-on `fuse` pass ([`crate::transform::fuse`]) folds
//! the common adjacent pairs (cmp+branch, gep+load, gep+store,
//! bin+store) into superinstructions so one dispatch covers two
//! instructions.
//!
//! The lowered form lives *alongside* the tree IR
//! ([`super::Module::lowered`]): the printer round-trip and every
//! tree-level pass are untouched, the interpreter simply prefers the
//! lowered body when one exists, and the tree-walk path remains the
//! equivalence baseline (`tests/lowering.rs`). Instruction/flop/memory
//! counters are mirrored exactly — a superinstruction charges both of
//! its component instructions — so modeled device time is identical
//! across the executors. The `bytecode` pass
//! ([`crate::transform::bytecode`]) consumes this form in turn,
//! flattening it into the linear bytecode ([`super::bytecode`]) the
//! interpreter runs by default.

use super::{Schedule, Ty, Width};
use crate::rpc::ArgMode;

/// A lowered operand: a register slot or a constant-pool index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LowOp {
    /// Index into the call frame's register file.
    Slot(u32),
    /// Index into the function's constant pool.
    Pool(u32),
}

/// One interned constant-pool entry. `Global` is resolved to the
/// global's device base address when the program is loaded
/// ([`crate::ir::interp::ProgramEnv`] materializes the pool as
/// `Vec<Value>` per function).
#[derive(Debug, Clone, PartialEq)]
pub enum PoolConst {
    I(i64),
    F(f64),
    /// Address of a module global, by name.
    Global(String),
}

/// [`super::Expr`] with slot/pool leaves.
#[derive(Debug, Clone, PartialEq)]
pub enum LowExpr {
    Op(LowOp),
    Bin(super::BinOp, LowOp, LowOp),
    Gep(LowOp, LowOp),
    Select(LowOp, LowOp, LowOp),
    SiToFp(LowOp),
    FpToSi(LowOp),
    Tid,
    NumThreads,
    Sqrt(LowOp),
    Exp(LowOp),
    Log(LowOp),
}

/// A `Ref`'s offset into its underlying object — the lowered twin of
/// [`super::OffsetSpec`]. `Dynamic` is recomputed at marshal time as
/// `ptr - base(object)` via the runtime object lookup, exactly like
/// `MultiRef` candidates, so dynamic-offset refs no longer pin a
/// function to the tree-walk executor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LowOffset {
    Const(u64),
    Dynamic,
}

/// A lowered RPC argument descriptor. `MultiRef` candidate offsets are
/// dropped: the runtime recomputes `ptr - base` for the matching
/// candidate exactly like the tree-walk executor.
#[derive(Debug, Clone, PartialEq)]
pub enum LowRpcArg {
    Val(LowOp),
    Ref { ptr: LowOp, mode: ArgMode, obj_size: u64, offset: LowOffset },
    MultiRef { ptr: LowOp, candidates: Vec<(LowOp, ArgMode, u64)> },
    DynRef { ptr: LowOp, mode: ArgMode },
}

/// Lowered instructions: [`super::Instr`] with slot destinations and
/// [`LowOp`] operands, plus the fused superinstructions the `fuse` pass
/// produces. Every superinstruction still writes its intermediate
/// `tmp` slot (a plain `Vec` store) so fusion never needs a liveness
/// analysis to stay semantics-preserving.
#[derive(Debug, Clone, PartialEq)]
pub enum LowInstr {
    Assign { dst: u32, expr: LowExpr },
    Alloca { dst: u32, size: u64 },
    Store { addr: LowOp, val: LowOp, width: Width },
    Load { dst: u32, addr: LowOp, width: Width, ty: Ty },
    /// Direct call, dispatched by name (the callee may itself be lowered,
    /// tree-walk, device-native, or unresolved — `call_function` decides).
    Call { dst: Option<u32>, callee: String, args: Vec<LowOp> },
    RpcCall { dst: Option<u32>, callee_id: u64, args: Vec<LowRpcArg> },
    /// Kernel-split launch with the region's parameters pre-resolved to
    /// caller slots (the tree-walk executor reads them back by *name*
    /// from the caller's scope; lowering resolves that lookup once).
    KernelLaunch { region: String, arg: Option<LowOp>, params: Vec<LowOp> },
    If { cond: LowOp, then_body: Vec<LowInstr>, else_body: Vec<LowInstr> },
    While { cond_var: u32, cond: Vec<LowInstr>, body: Vec<LowInstr> },
    For { var: u32, lo: LowOp, hi: LowOp, step: LowOp, schedule: Schedule, body: Vec<LowInstr> },
    Parallel { num_threads: Option<LowOp>, body: Vec<LowInstr> },
    Barrier,
    Return(Option<LowOp>),
    Intrinsic { dst: Option<u32>, name: String, args: Vec<LowOp> },
    /// `tmp = a <op> b; if tmp { then } else { else }` (cmp+br fusion).
    CmpIf {
        tmp: u32,
        op: super::BinOp,
        a: LowOp,
        b: LowOp,
        then_body: Vec<LowInstr>,
        else_body: Vec<LowInstr>,
    },
    /// `tmp = gep base, off; dst = load.<w> tmp`.
    GepLoad { tmp: u32, base: LowOp, off: LowOp, dst: u32, width: Width, ty: Ty },
    /// `tmp = gep base, off; store.<w> val, tmp`.
    GepStore { tmp: u32, base: LowOp, off: LowOp, val: LowOp, width: Width },
    /// `tmp = a <op> b; store.<w> tmp, addr`.
    BinStore { tmp: u32, op: super::BinOp, a: LowOp, b: LowOp, addr: LowOp, width: Width },
}

/// One function compiled to register-file form.
#[derive(Debug, Clone, PartialEq)]
pub struct LoweredFunction {
    /// Register-file size of one call frame.
    pub nslots: u32,
    /// Slot of each parameter, in declaration order.
    pub param_slots: Vec<u32>,
    /// Interned constants (deduplicated); `PoolConst::Global` entries
    /// resolve to device addresses at program load.
    pub pool: Vec<PoolConst>,
    pub body: Vec<LowInstr>,
    /// Diagnostics side table: `names[slot]` is the source-level name
    /// the slot was assigned for (`--explain` and the lowered printer
    /// read it; execution never does).
    pub names: Vec<String>,
    /// Superinstructions the `fuse` pass created in this function.
    pub fused: u32,
}

/// Depth-first visit of every lowered instruction, recursing into
/// nested bodies (including superinstruction branch bodies).
pub fn walk_low(body: &[LowInstr], f: &mut impl FnMut(&LowInstr)) {
    for ins in body {
        f(ins);
        match ins {
            LowInstr::If { then_body, else_body, .. }
            | LowInstr::CmpIf { then_body, else_body, .. } => {
                walk_low(then_body, f);
                walk_low(else_body, f);
            }
            LowInstr::While { cond, body, .. } => {
                walk_low(cond, f);
                walk_low(body, f);
            }
            LowInstr::For { body, .. } | LowInstr::Parallel { body, .. } => walk_low(body, f),
            _ => {}
        }
    }
}

/// Whether a lowered body (or anything nested in it) contains a
/// barrier — the lowered twin of [`super::interp::body_has_barrier`],
/// deciding cooperative vs independent launch for parallel regions.
pub fn low_body_has_barrier(body: &[LowInstr]) -> bool {
    let mut found = false;
    walk_low(body, &mut |i| {
        if matches!(i, LowInstr::Barrier) {
            found = true;
        }
    });
    found
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walk_reaches_superinstruction_bodies() {
        let body = vec![LowInstr::CmpIf {
            tmp: 0,
            op: crate::ir::BinOp::Lt,
            a: LowOp::Slot(1),
            b: LowOp::Pool(0),
            then_body: vec![LowInstr::Barrier],
            else_body: vec![],
        }];
        assert!(low_body_has_barrier(&body));
        let mut n = 0;
        walk_low(&body, &mut |_| n += 1);
        assert_eq!(n, 2, "CmpIf + nested Barrier");
    }

    #[test]
    fn barrier_detection_matches_plain_bodies() {
        assert!(!low_body_has_barrier(&[LowInstr::Return(None)]));
        let body = vec![LowInstr::Parallel { num_threads: None, body: vec![LowInstr::Barrier] }];
        assert!(low_body_has_barrier(&body));
    }
}
