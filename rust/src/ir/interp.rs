//! IR interpreter: executes a compiled module on the simulated GPU.
//!
//! The execution model mirrors paper Fig. 4:
//!
//! * `main` runs as the **main kernel** — one team, one thread
//!   (`launch_coop(1,1)`), because "for the sequential part of the
//!   original application we still utilize a single team";
//! * an (un-expanded) `parallel` region executes single-team, with the
//!   threads of that one team — the natural OpenMP offload mapping;
//! * a [`Instr::KernelLaunch`] (produced by the multi-team pass) issues a
//!   host RPC; the host-side launcher runs the outlined region function
//!   over a multi-team grid with continuous global thread ids;
//! * [`Instr::RpcCall`]s marshal arguments per their compile-time
//!   descriptors, resolving `MultiRef` candidates by pointer comparison
//!   and `DynRef` via the allocator's `_FindObj` lookup, then block on the
//!   mailbox.
//!
//! Three executors share this model. The historical **tree-walk** path
//! resolves operands through `HashMap<String, Value>` frames; the
//! **register-file** path executes the [`lowered`] form the `lower`
//! pass produces — `Vec<Value>` frames indexed by slot, constants
//! fetched from a pool resolved once at load, superinstructions from
//! the `fuse` pass dispatched in one step; the **bytecode** path
//! executes the [`bytecode`] form — one flat `Vec<Op>` per function
//! driven by a `pc` loop with resolved branch targets, no tree
//! recursion or block lookup, and `parallel` regions stepped in bounded
//! quanta across the whole team batch
//! ([`crate::gpu::grid::Device::launch_batched`]). Dispatch prefers
//! bytecode over lowered over tree, per function. All paths charge
//! identical instruction/flop/memory counters — a superinstruction
//! charges both of its component instructions, flattening artifacts
//! charge nothing — so modeled device time is the same, and
//! `tests/lowering.rs` holds the outputs equal.

use super::bytecode::{BcRpcArg, BytecodeFunction, Op, RpcSite, POOL_BIT};
use super::lowered::{
    low_body_has_barrier, LowExpr, LowInstr, LowOffset, LowOp, LowRpcArg, LoweredFunction,
    PoolConst,
};
use super::*;
use crate::gpu::grid::{Device, GridCtx, LaunchConfig};
use crate::gpu::stats::{LaunchStats, Pattern};
use crate::libc_gpu::rand::DeviceRand;
use crate::libc_gpu::registry::DeviceFn;
use crate::libc_gpu::{stdlib as dstdlib, string as dstring};
use crate::analysis::resolution::{resolve_module, ResolutionTable, SymbolClass};
use crate::rpc::{RpcArgInfo, RpcClient, WrapperRegistry};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

pub const PER_THREAD_STACK: u64 = 8 << 10;

/// Process-wide monotonic launch-session mint. Launch clients used to
/// key their home ring slot by the issuing *team* id, so two sessions
/// sharing one device aliased the same slot whenever their team ids
/// collided; every loaded program now draws a distinct session id here
/// and consecutive sessions spread over the launch ring by construction.
static NEXT_LAUNCH_SESSION: AtomicU64 = AtomicU64::new(0);

/// A loaded program: module + device + host-side registry, with globals
/// materialized in device memory. Shared by every simulated thread.
pub struct ProgramEnv {
    pub module: Module,
    pub device: Arc<Device>,
    pub registry: Arc<WrapperRegistry>,
    pub host: Arc<crate::rpc::HostEnv>,
    /// name -> (base address, size) of materialized globals.
    pub globals: HashMap<String, (u64, u64)>,
    /// The compile-time symbol-resolution table (libcres): every external
    /// callee classified device-native / host-RPC / unresolved. The
    /// interpreter dispatches through it — no string matching on the
    /// execution path.
    pub resolution: ResolutionTable,
    /// Call sites that reached an unresolved symbol at runtime (each
    /// degrades to a no-op returning 0, warned once per symbol through
    /// the device's [`crate::obs::EventLog`]).
    pub unresolved_calls: AtomicU64,
    /// This loaded program's launch-session id (minted by the loader
    /// from [`NEXT_LAUNCH_SESSION`]); keys the home launch-ring slot so
    /// concurrent sessions sharing a device never alias one slot.
    pub launch_session: u64,
    /// Kernel-region name -> launch id used in the launch RPC.
    pub region_ids: HashMap<String, u64>,
    region_names: Vec<String>,
    /// Per-function constant pools of the lowered form, resolved at load
    /// time (`PoolConst::Global` entries become device base addresses).
    /// Keyed like [`Module::lowered`]; empty when the `lower` pass did
    /// not run.
    pub pools: HashMap<String, Vec<Value>>,
    /// Same resolution for the bytecode forms (keyed like
    /// [`Module::bytecode`]). Separate from [`Self::pools`] because a
    /// module loaded from an AOT artifact may carry bytecode without
    /// its lowered twin.
    pub bpools: HashMap<String, Vec<Value>>,
    /// Captures for the in-flight kernel launch (single RPC slot ⇒ one).
    pending: Mutex<Option<PendingLaunch>>,
    stack_bump: AtomicU64,
    stack_slots: u64,
    /// Default grid for expanded regions without a num_threads clause.
    pub default_teams: usize,
    pub default_team_size: usize,
    /// Aggregated stats of all launched parallel kernels.
    pub kernel_stats: Mutex<LaunchStats>,
    /// Launch count of parallel kernels.
    pub kernel_launches: AtomicU64,
}

struct PendingLaunch {
    region: String,
    values: Vec<Value>,
    cfg: LaunchConfig,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Value {
    I(i64),
    F(f64),
}

impl Value {
    pub fn as_i(&self) -> i64 {
        match self {
            Value::I(i) => *i,
            Value::F(f) => *f as i64,
        }
    }

    pub fn as_f(&self) -> f64 {
        match self {
            Value::I(i) => *i as f64,
            Value::F(f) => *f,
        }
    }

    pub fn as_addr(&self) -> u64 {
        self.as_i() as u64
    }

    pub fn truthy(&self) -> bool {
        match self {
            Value::I(i) => *i != 0,
            Value::F(f) => *f != 0.0,
        }
    }
}

enum Flow {
    Normal,
    Returned(Option<Value>),
}

impl ProgramEnv {
    /// Materialize the module on `device`: allocate + initialize globals,
    /// assign region launch ids, and install the kernel-split launcher
    /// into the host environment.
    pub fn load(
        module: Module,
        device: Arc<Device>,
        registry: Arc<WrapperRegistry>,
        host: Arc<crate::rpc::HostEnv>,
    ) -> Arc<Self> {
        Self::load_with_grid(module, device, registry, host, 64, 128)
    }

    /// `load` with an explicit default grid for expanded regions.
    pub fn load_with_grid(
        module: Module,
        device: Arc<Device>,
        registry: Arc<WrapperRegistry>,
        host: Arc<crate::rpc::HostEnv>,
        default_teams: usize,
        default_team_size: usize,
    ) -> Arc<Self> {
        let mut globals = HashMap::new();
        for g in module.globals.values() {
            let base = device
                .heap
                .malloc(crate::alloc::AllocCtx::default(), g.size.max(1))
                .expect("global allocation");
            if !g.init.is_empty() {
                device.mem.write_bytes(base, &g.init);
            }
            globals.insert(g.name.clone(), (base, g.size));
        }
        let mut region_ids = HashMap::new();
        let mut region_names = Vec::new();
        for (name, f) in &module.functions {
            if f.is_kernel_region {
                region_ids.insert(name.clone(), region_names.len() as u64);
                region_names.push(name.clone());
            }
        }
        let stack_slots = device.mem.config().stack_size / PER_THREAD_STACK;
        // The load-time resolution table: identical to the one the
        // `libcres` pass reports at compile time (same pure analysis), so
        // dispatch agrees with the compile-time classification even for
        // modules loaded without the full pipeline.
        let resolution = resolve_module(&module);
        // Resolve each lowered/bytecode function's constant pool once,
        // here, so the register-file and bytecode executors never touch
        // the globals map (or any other string-keyed table) on the hot
        // path.
        let resolve_pool = |pool: &[PoolConst]| -> Vec<Value> {
            pool.iter()
                .map(|c| match c {
                    PoolConst::I(i) => Value::I(*i),
                    PoolConst::F(f) => Value::F(*f),
                    PoolConst::Global(g) => Value::I(
                        globals
                            .get(g)
                            .unwrap_or_else(|| panic!("unknown global @{g} in pool"))
                            .0 as i64,
                    ),
                })
                .collect()
        };
        let mut pools = HashMap::new();
        for (name, lf) in &module.lowered {
            pools.insert(name.clone(), resolve_pool(&lf.pool));
        }
        let mut bpools = HashMap::new();
        for (name, bf) in &module.bytecode {
            bpools.insert(name.clone(), resolve_pool(&bf.pool));
        }
        let env = Arc::new(Self {
            module,
            device,
            registry,
            host,
            globals,
            resolution,
            unresolved_calls: AtomicU64::new(0),
            launch_session: NEXT_LAUNCH_SESSION.fetch_add(1, Ordering::Relaxed),
            region_ids,
            region_names,
            pools,
            bpools,
            pending: Mutex::new(None),
            stack_bump: AtomicU64::new(0),
            stack_slots,
            default_teams,
            default_team_size,
            kernel_stats: Mutex::new(LaunchStats::default()),
            kernel_launches: AtomicU64::new(0),
        });
        // Install the host-side kernel launcher (Fig. 4 ①→②).
        let weak = Arc::downgrade(&env);
        *env.host.region_launcher.lock().unwrap() = Some(Box::new(move |_region_id, _arg| {
            let Some(env) = weak.upgrade() else { return -1 };
            let Some(pending) = env.pending.lock().unwrap().take() else { return -2 };
            let stats = env.run_region(&pending.region, &pending.values, pending.cfg);
            let mut agg = env.kernel_stats.lock().unwrap();
            *agg = agg.add(&stats);
            env.kernel_launches.fetch_add(1, Ordering::Relaxed);
            0
        }));
        env
    }

    /// Kernel-region names in launch-id order.
    pub fn region_names(&self) -> &[String] {
        &self.region_names
    }

    /// Record one runtime hit on an unresolved symbol: count it and warn
    /// once per symbol through the device event log. The call degrades
    /// to a no-op returning 0 (the PR 2 `snprintf` idiom) instead of
    /// panicking — `libcres` already reported the symbol at compile
    /// time.
    fn unresolved_trap(&self, name: &str) {
        self.unresolved_calls.fetch_add(1, Ordering::Relaxed);
        self.device.mem.obs.events.emit(
            crate::obs::Level::Warn,
            "unresolved-symbol",
            name,
            &format!(
                "call to unresolved symbol '{name}' degraded to a no-op \
                 (libcres classifies it neither device-native nor host-RPC)"
            ),
        );
    }

    fn global_addr(&self, name: &str) -> u64 {
        self.globals.get(name).unwrap_or_else(|| panic!("unknown global @{name}")).0
    }

    /// `_FindObj` fallback for globals (the allocator tracks heap objects;
    /// globals are statically known to the compiler-generated tables).
    pub fn find_object(&self, addr: u64) -> Option<(u64, u64)> {
        if let Some(rec) = self.device.heap.lookup(addr) {
            return Some((rec.base, rec.size));
        }
        self.globals
            .values()
            .find(|(b, s)| addr >= *b && addr < b + s.max(&1))
            .copied()
    }

    fn stack_base(&self) -> u64 {
        let slot = self.stack_bump.fetch_add(1, Ordering::Relaxed) % self.stack_slots;
        crate::gpu::memory::STACK_BASE + slot * PER_THREAD_STACK
    }

    /// Execute `main` as the main kernel (1 team × 1 thread). Returns
    /// (exit value, main-kernel stats).
    pub fn run_main(self: &Arc<Self>, args: &[Value]) -> (i64, LaunchStats) {
        let result = Mutex::new(0i64);
        let stats = self.device.launch_coop(LaunchConfig::new(1, 1), |g| {
            let mut interp = Interp::new(self, g);
            let ret = interp.call_function("main", args.to_vec());
            *result.lock().unwrap() = ret.map(|v| v.as_i()).unwrap_or(0);
        });
        let r = *result.lock().unwrap();
        (r, stats)
    }

    /// Host-side execution of an expanded region over a grid.
    fn run_region(
        self: &Arc<Self>,
        region: &str,
        values: &[Value],
        cfg: LaunchConfig,
    ) -> LaunchStats {
        let f = &self.module.functions[region];
        // Kernel threads run the bytecode when the region was flattened
        // (the default pipeline), else the register core when it was
        // lowered, else they tree-walk.
        let bytecode = self.module.bytecode.get(region);
        let lowered = self.module.lowered.get(region);
        let has_barrier = match (bytecode, lowered) {
            (Some(bf), _) => bc_has_barrier(bf),
            (None, Some(lf)) => low_body_has_barrier(&lf.body),
            (None, None) => body_has_barrier(&f.body),
        };
        let body = |g: &mut GridCtx| {
            let mut interp = Interp::new(self, g);
            if let Some(bf) = bytecode {
                let pool = self.bpools[region].as_slice();
                let mut regs = vec![Value::I(0); bf.nslots as usize];
                for (slot, v) in bf.param_slots.iter().zip(values.iter()) {
                    regs[*slot as usize] = *v;
                }
                interp.enter_bytecode(bf, pool, &mut regs);
            } else if let Some(lf) = lowered {
                let pool = self.pools[region].as_slice();
                let mut regs = vec![Value::I(0); lf.nslots as usize];
                for (slot, v) in lf.param_slots.iter().zip(values.iter()) {
                    regs[*slot as usize] = *v;
                }
                interp.enter_lowered(pool, &mut regs, &lf.body);
            } else {
                let bindings: Vec<(String, Value)> = f
                    .params
                    .iter()
                    .zip(values.iter())
                    .map(|(p, v)| (p.name.clone(), *v))
                    .collect();
                interp.exec_function_body(&f.body, bindings);
            }
        };
        let obs = &self.device.mem.obs;
        let span = obs.spans.start();
        let stats = if has_barrier {
            let total = cfg.total_threads().min(1024);
            let cfg = LaunchConfig::new(
                (total / cfg.threads_per_team).max(1),
                cfg.threads_per_team.min(total),
            );
            self.device.launch_coop(cfg, body)
        } else {
            self.device.launch(cfg, body)
        };
        if span.is_some() {
            let name = format!("kernel {region}");
            let track = self.region_ids.get(region).copied().unwrap_or(0);
            obs.spans.finish(span, &name, crate::obs::SpanKind::Interp, track);
        }
        stats
    }
}

/// Barrier scan over flat bytecode: `parallel` bodies are inline ranges
/// of the same op array, so one linear pass sees everything `walk_low`
/// reaches in the lowered form.
pub(crate) fn bc_has_barrier(bf: &BytecodeFunction) -> bool {
    bf.code.iter().any(|op| matches!(op, Op::Barrier))
}

pub(crate) fn body_has_barrier(body: &[Instr]) -> bool {
    let mut found = false;
    crate::analysis::callgraph::walk(body, &mut |i| {
        if matches!(i, Instr::Barrier) {
            found = true;
        }
    });
    found
}

/// One simulated thread executing IR.
pub struct Interp<'e, 'g, 'd> {
    env: &'e Arc<ProgramEnv>,
    g: &'g mut GridCtx<'d>,
    frames: Vec<HashMap<String, Value>>,
    sp: u64,
    stack_end: u64,
    rand: DeviceRand,
    depth: usize,
}

impl<'e, 'g, 'd> Interp<'e, 'g, 'd> {
    pub fn new(env: &'e Arc<ProgramEnv>, g: &'g mut GridCtx<'d>) -> Self {
        let base = env.stack_base();
        let tid = g.global_tid() as u64;
        Self {
            env,
            g,
            frames: vec![HashMap::new()],
            sp: base,
            stack_end: base + PER_THREAD_STACK,
            rand: DeviceRand::for_thread(0xD00D, tid),
            depth: 0,
        }
    }

    fn frame(&mut self) -> &mut HashMap<String, Value> {
        self.frames.last_mut().unwrap()
    }

    fn set(&mut self, name: &str, v: Value) {
        self.frame().insert(name.to_string(), v);
    }

    fn get(&self, name: &str) -> Value {
        for f in self.frames.iter().rev() {
            if let Some(v) = f.get(name) {
                return *v;
            }
        }
        panic!("undefined variable %{name}")
    }

    fn eval(&mut self, op: &Operand) -> Value {
        match op {
            Operand::Var(v) => self.get(v),
            Operand::ConstI(i) => Value::I(*i),
            Operand::ConstF(f) => Value::F(*f),
            Operand::Global(g) => Value::I(self.env.global_addr(g) as i64),
        }
    }

    pub fn call_function(&mut self, name: &str, args: Vec<Value>) -> Option<Value> {
        // Three-tier dispatch: prefer the flat bytecode (pc-loop, no
        // tree recursion), then the register-file form (slot-indexed
        // frame, pool constants), then the tree walk.
        let env = self.env;
        if let Some(bf) = env.module.bytecode.get(name) {
            assert_eq!(bf.param_slots.len(), args.len(), "arity mismatch calling {name}");
            let pool = env.bpools.get(name).map_or(&[][..], |p| p.as_slice());
            return self.call_bytecode(bf, pool, args);
        }
        if let Some(lf) = env.module.lowered.get(name) {
            assert_eq!(lf.param_slots.len(), args.len(), "arity mismatch calling {name}");
            let pool = env.pools.get(name).map_or(&[][..], |p| p.as_slice());
            return self.call_lowered(lf, pool, args);
        }
        let Some(f) = env.module.functions.get(name) else {
            // Undefined callee: dispatch through the compile-time
            // resolution table instead of panicking on an unknown name.
            return self.external_call(name, &args);
        };
        let f = f.clone();
        assert_eq!(f.params.len(), args.len(), "arity mismatch calling {name}");
        let bindings: Vec<(String, Value)> =
            f.params.iter().zip(args).map(|(p, v)| (p.name.clone(), v)).collect();
        self.exec_function_body(&f.body, bindings)
    }

    /// A call to a function the module does not define, resolved through
    /// the `libcres` table: device-native symbols run on the device,
    /// host-RPC symbols trap (they should have been lowered to
    /// [`Instr::RpcCall`] by the `rpcgen` pass — leaving them direct is
    /// the Tian et al. baseline where such calls trap), and unresolved
    /// symbols degrade to a counted, warned no-op.
    fn external_call(&mut self, name: &str, args: &[Value]) -> Option<Value> {
        match self.env.resolution.class_of(name) {
            Some(SymbolClass::Device(dev)) => Some(self.device_fn(dev, args)),
            Some(SymbolClass::HostRpc(_)) => panic!(
                "host-RPC callee {name} reached the interpreter unlowered \
                 (run the 'rpcgen' pass; direct library calls trap in the baseline)"
            ),
            Some(SymbolClass::Unresolved) | None => {
                self.env.unresolved_trap(name);
                Some(Value::I(0))
            }
        }
    }

    fn exec_function_body(
        &mut self,
        body: &[Instr],
        bindings: Vec<(String, Value)>,
    ) -> Option<Value> {
        self.depth += 1;
        assert!(self.depth < 128, "interpreter call depth exceeded");
        let saved_sp = self.sp;
        let mut frame = HashMap::new();
        for (k, v) in bindings {
            frame.insert(k, v);
        }
        self.frames.push(frame);
        let flow = self.exec_body(body);
        self.frames.pop();
        self.sp = saved_sp;
        self.depth -= 1;
        match flow {
            Flow::Returned(v) => v,
            Flow::Normal => None,
        }
    }

    fn exec_body(&mut self, body: &[Instr]) -> Flow {
        for ins in body {
            match self.exec_instr(ins) {
                Flow::Normal => {}
                ret => return ret,
            }
        }
        Flow::Normal
    }

    fn exec_instr(&mut self, ins: &Instr) -> Flow {
        self.g.counters.int_ops += 1;
        match ins {
            Instr::Assign { dst, expr } => {
                let v = self.eval_expr(expr);
                self.set(dst, v);
            }
            Instr::Alloca { dst, size } => {
                let addr = crate::alloc::align_up(self.sp, 16);
                assert!(addr + size <= self.stack_end, "device stack overflow");
                self.sp = addr + size;
                self.set(dst, Value::I(addr as i64));
            }
            Instr::Store { addr, val, width } => {
                let a = self.eval(addr).as_addr();
                let v = self.eval(val);
                self.mem_store(a, v, *width);
            }
            Instr::Load { dst, addr, width, ty } => {
                let a = self.eval(addr).as_addr();
                let v = self.mem_load(a, *width, *ty);
                self.set(dst, v);
            }
            Instr::Call { dst, callee, args } => {
                let vals: Vec<Value> = args.iter().map(|a| self.eval(a)).collect();
                let ret = self.call_function(callee, vals);
                if let Some(d) = dst {
                    self.set(d, ret.unwrap_or(Value::I(0)));
                }
            }
            Instr::Intrinsic { dst, name, args } => {
                let vals: Vec<Value> = args.iter().map(|a| self.eval(a)).collect();
                // Resolved through the table built at load time — never a
                // string match with a panic fallback. (Host-RPC symbols
                // cannot legally appear as intrinsics — verify() rejects
                // them — so that arm is a loud malformed-module trap, not
                // a silent no-op with a false "unresolved" diagnostic.)
                let ret = match self.env.resolution.class_of(name) {
                    Some(SymbolClass::Device(dev)) => self.device_fn(dev, &vals),
                    Some(SymbolClass::HostRpc(_)) => panic!(
                        "intrinsic {name} resolves host-RPC, not device-native \
                         (malformed module: verify() would reject it)"
                    ),
                    Some(SymbolClass::Unresolved) | None => {
                        self.env.unresolved_trap(name);
                        Value::I(0)
                    }
                };
                if let Some(d) = dst {
                    self.set(d, ret);
                }
            }
            Instr::RpcCall { dst, callee_id, args, .. } => {
                let ret = self.issue_rpc(*callee_id, args);
                if let Some(d) = dst {
                    self.set(d, Value::I(ret));
                }
            }
            Instr::KernelLaunch { region, arg } => {
                self.kernel_launch(region, arg.as_ref());
            }
            Instr::If { cond, then_body, else_body } => {
                let c = self.eval(cond).truthy();
                let flow =
                    if c { self.exec_body(then_body) } else { self.exec_body(else_body) };
                if let Flow::Returned(_) = flow {
                    return flow;
                }
            }
            Instr::While { cond_var, cond, body } => loop {
                if let Flow::Returned(v) = self.exec_body(cond) {
                    return Flow::Returned(v);
                }
                if !self.get(cond_var).truthy() {
                    break;
                }
                if let Flow::Returned(v) = self.exec_body(body) {
                    return Flow::Returned(v);
                }
            },
            Instr::For { var, lo, hi, step, schedule, body } => {
                let lo = self.eval(lo).as_i();
                let hi = self.eval(hi).as_i();
                let step = self.eval(step).as_i().max(1);
                let (start, stride) = match schedule {
                    Schedule::Seq => (lo, step),
                    // omp for: cyclic over the encountering team's threads.
                    Schedule::Team => {
                        let t = self.g.thread_id as i64;
                        let n = self.g.cfg.threads_per_team as i64;
                        (lo + t * step, n * step)
                    }
                    // distribute parallel for: cyclic over the whole grid,
                    // continuous thread ids across teams (paper Fig. 4).
                    Schedule::Grid => {
                        let t = self.g.global_tid() as i64;
                        let n = self.g.num_threads_global() as i64;
                        (lo + t * step, n * step)
                    }
                };
                let mut i = start;
                while i < hi {
                    self.set(var, Value::I(i));
                    if let Flow::Returned(v) = self.exec_body(body) {
                        return Flow::Returned(v);
                    }
                    i += stride;
                }
            }
            Instr::Parallel { num_threads, body } => {
                // Un-expanded region: single-team execution (the Tian et
                // al. baseline the paper improves on).
                let n = num_threads
                    .as_ref()
                    .map(|o| self.eval(o).as_i() as usize)
                    .unwrap_or(128)
                    .clamp(1, 1024);
                let snapshot: HashMap<String, Value> = self
                    .frames
                    .iter()
                    .flat_map(|f| f.iter().map(|(k, v)| (k.clone(), *v)))
                    .collect();
                let env = self.env;
                let has_barrier = body_has_barrier(body);
                let cfg = LaunchConfig::new(1, n);
                let runner = |g: &mut GridCtx| {
                    let mut interp = Interp::new(env, g);
                    let bindings: Vec<(String, Value)> =
                        snapshot.iter().map(|(k, v)| (k.clone(), *v)).collect();
                    interp.exec_function_body(body, bindings);
                };
                let obs = &env.device.mem.obs;
                let span = obs.spans.start();
                let stats = if has_barrier {
                    env.device.launch_coop(cfg, runner)
                } else {
                    env.device.launch(cfg, runner)
                };
                obs.spans.finish(
                    span,
                    "parallel-region [tree]",
                    crate::obs::SpanKind::Interp,
                    self.g.team_id as u64,
                );
                let mut agg = env.kernel_stats.lock().unwrap();
                *agg = agg.add(&stats);
            }
            Instr::Barrier => {
                if self.g.num_threads_global() > 1 {
                    self.g.barrier_global();
                } else {
                    self.g.counters.barriers_global += 1;
                }
            }
            Instr::Return(v) => {
                let val = v.as_ref().map(|o| self.eval(o));
                return Flow::Returned(val);
            }
        }
        Flow::Normal
    }

    fn eval_expr(&mut self, e: &Expr) -> Value {
        match e {
            Expr::Op(o) => self.eval(o),
            Expr::Bin(op, a, b) => {
                let x = self.eval(a);
                let y = self.eval(b);
                if op.is_float() {
                    self.g.counters.flops_f64 += 1;
                } else {
                    self.g.counters.int_ops += 1;
                }
                eval_bin(*op, x, y)
            }
            Expr::Gep(base, off) => {
                let b = self.eval(base).as_i();
                let o = self.eval(off).as_i();
                Value::I(b + o)
            }
            Expr::Select(c, a, b) => {
                if self.eval(c).truthy() {
                    self.eval(a)
                } else {
                    self.eval(b)
                }
            }
            Expr::SiToFp(a) => Value::F(self.eval(a).as_i() as f64),
            Expr::FpToSi(a) => Value::I(self.eval(a).as_f() as i64),
            Expr::Tid => Value::I(self.g.global_tid() as i64),
            Expr::NumThreads => Value::I(self.g.num_threads_global() as i64),
            Expr::Sqrt(a) => {
                self.g.counters.flops_f64 += 4;
                Value::F(self.eval(a).as_f().sqrt())
            }
            Expr::Exp(a) => {
                self.g.counters.flops_f64 += 8;
                Value::F(self.eval(a).as_f().exp())
            }
            Expr::Log(a) => {
                self.g.counters.flops_f64 += 8;
                Value::F(self.eval(a).as_f().ln())
            }
        }
    }

    /// Execute one device-native libc function (paper §3.4). The match is
    /// total over [`DeviceFn`] — a symbol that resolves device-native can
    /// never trap here.
    fn device_fn(&mut self, f: DeviceFn, args: &[Value]) -> Value {
        let mem = &self.env.device.mem;
        match f {
            DeviceFn::Malloc => {
                let size = args[0].as_i().max(0) as u64;
                let addr = self.g.malloc(size).unwrap_or_else(|e| panic!("malloc: {e}"));
                Value::I(addr as i64)
            }
            DeviceFn::Free => {
                let addr = args[0].as_addr();
                if addr != 0 {
                    self.g.free(addr).unwrap_or_else(|e| panic!("free: {e}"));
                }
                Value::I(0)
            }
            DeviceFn::Realloc => {
                let old = args[0].as_addr();
                let new_size = args[1].as_i().max(0) as u64;
                let new = self.g.malloc(new_size).unwrap_or_else(|e| panic!("realloc: {e}"));
                if old != 0 {
                    if let Some(rec) = self.env.device.heap.lookup(old) {
                        dstring::memcpy(mem, new, old, rec.size.min(new_size));
                    }
                    self.g.free(old).ok();
                }
                Value::I(new as i64)
            }
            DeviceFn::Strlen => Value::I(dstring::strlen(mem, args[0].as_addr()) as i64),
            DeviceFn::Strcpy => {
                Value::I(dstring::strcpy(mem, args[0].as_addr(), args[1].as_addr()) as i64)
            }
            DeviceFn::Strcmp => {
                Value::I(dstring::strcmp(mem, args[0].as_addr(), args[1].as_addr()) as i64)
            }
            DeviceFn::Strcat => {
                Value::I(dstring::strcat(mem, args[0].as_addr(), args[1].as_addr()) as i64)
            }
            DeviceFn::Memcpy => Value::I(dstring::memcpy(
                mem,
                args[0].as_addr(),
                args[1].as_addr(),
                args[2].as_i() as u64,
            ) as i64),
            DeviceFn::Memset => Value::I(dstring::memset(
                mem,
                args[0].as_addr(),
                args[1].as_i() as u8,
                args[2].as_i() as u64,
            ) as i64),
            DeviceFn::Strtod => Value::F(dstdlib::strtod(mem, args[0].as_addr()).0),
            DeviceFn::Atoi => Value::I(dstdlib::atoi(mem, args[0].as_addr())),
            DeviceFn::Rand => Value::I(self.rand.rand() as i64),
            DeviceFn::Srand => {
                self.rand =
                    DeviceRand::for_thread(args[0].as_i() as u64, self.g.global_tid() as u64);
                Value::I(0)
            }
            DeviceFn::Sqrt => Value::F(args[0].as_f().sqrt()),
            DeviceFn::Fabs => Value::F(args[0].as_f().abs()),
        }
    }

    fn issue_rpc(&mut self, callee_id: u64, specs: &[RpcArgSpec]) -> i64 {
        let mut info = RpcArgInfo::with_capacity(specs.len());
        for spec in specs {
            match spec {
                RpcArgSpec::Val(op) => {
                    let v = self.eval(op);
                    let bits = match v {
                        Value::I(i) => i as u64,
                        Value::F(f) => f.to_bits(),
                    };
                    info.add_val(bits);
                }
                RpcArgSpec::Ref { ptr, mode, obj_size, offset } => {
                    let p = self.eval(ptr).as_addr();
                    let off = match offset {
                        OffsetSpec::Const(c) => *c,
                        // Dynamic offset within a statically identified
                        // object: recover it at marshal time from the
                        // object's base (`_FindObj`; 0 when the pointer
                        // doesn't resolve — the host copies from the
                        // object start).
                        OffsetSpec::Dynamic => {
                            self.env.find_object(p).map(|(base, _)| p - base).unwrap_or(0)
                        }
                    };
                    info.add_ref(p, *mode, *obj_size, off);
                }
                RpcArgSpec::MultiRef { ptr, candidates } => {
                    // Fig. 3c lines 34-39: identify the object at runtime
                    // by comparing the pointer against candidate bases.
                    let p = self.eval(ptr).as_addr();
                    let mut matched = false;
                    for (cand, mode, size, _off) in candidates {
                        let base = self.eval(cand).as_addr();
                        if p >= base && p < base + size.max(&1) {
                            info.add_ref(p, *mode, *size, p - base);
                            matched = true;
                            break;
                        }
                    }
                    if !matched {
                        info.add_val(p);
                    }
                }
                RpcArgSpec::DynRef { ptr, mode } => {
                    // _FindObj against allocation tracking + global tables;
                    // on failure the pointer degrades to a value (paper:
                    // "we will treat the pointer as a value").
                    let p = self.eval(ptr).as_addr();
                    match self.env.find_object(p) {
                        Some((base, size)) => {
                            info.add_ref(p, *mode, size, p - base);
                        }
                        None => {
                            info.add_val(p);
                        }
                    }
                }
            }
        }
        self.dispatch_rpc(callee_id, &info)
    }

    /// Shared RPC tail of both executors: lane selection by team id —
    /// threads of different teams use different arena lanes and only
    /// serialize when the arena is narrower than the set of
    /// concurrently-calling teams.
    fn dispatch_rpc(&mut self, callee_id: u64, info: &RpcArgInfo) -> i64 {
        let obs = &self.env.device.mem.obs;
        let span = obs.spans.start();
        let mut client =
            RpcClient::for_team(&self.env.device.mem, self.env.device.arena(), self.g.team_id);
        let ret = client.call(callee_id, info, Some(&mut self.g.counters));
        if span.is_some() {
            // Spans are enabled: the name lookup is off the default path.
            let label = self
                .env
                .registry
                .name_of(callee_id)
                .unwrap_or_else(|| format!("callee {callee_id}"));
            let name = format!("rpc-wait {label}");
            obs.spans.finish(span, &name, crate::obs::SpanKind::Interp, self.g.team_id as u64);
        }
        ret
    }

    fn kernel_launch(&mut self, region: &str, num_threads: Option<&Operand>) {
        let f = &self.env.module.functions[region];
        let requested = num_threads.map(|o| self.eval(o).as_i() as usize);
        let values: Vec<Value> = f.params.iter().map(|p| self.get(&p.name)).collect();
        self.kernel_launch_with(region, values, requested);
    }

    /// Shared kernel-launch tail of both executors: grid selection,
    /// pending-capture hand-off, and the launch RPC itself.
    fn kernel_launch_with(&mut self, region: &str, values: Vec<Value>, requested: Option<usize>) {
        let cfg = match requested {
            Some(n) if n > 0 => {
                let per_team = n.min(self.env.default_team_size);
                LaunchConfig::new(n.div_ceil(per_team), per_team)
            }
            _ => LaunchConfig::new(self.env.default_teams, self.env.default_team_size),
        };
        *self.env.pending.lock().unwrap() = Some(PendingLaunch {
            region: region.to_string(),
            values,
            cfg,
        });
        // Fig. 4 ①: RPC to the host to launch the parallel kernel. The
        // launch rides the arena's *launch ring* — never a regular
        // lane — so every lane stays free for the RPCs the kernel
        // itself issues (live even at `--rpc-lanes 1`). The home ring
        // slot is keyed by the program's loader-minted session id (NOT
        // the issuing team: team ids restart at 0 in every session, so
        // two sessions sharing a device would always collide on slot 0),
        // spreading concurrent sessions over the ring.
        let launch_id = self
            .env
            .registry
            .id_of("__launch_kernel_i_i")
            .expect("launch wrapper not registered (coordinator::register_common)");
        let region_id = self.env.region_ids[region];
        let mut info = RpcArgInfo::new();
        info.add_val(region_id);
        info.add_val(0);
        let obs = &self.env.device.mem.obs;
        let span = obs.spans.start();
        let mut client = RpcClient::for_launch_session(
            &self.env.device.mem,
            self.env.device.arena(),
            self.env.launch_session as usize,
        );
        let ret = client.call(launch_id, &info, Some(&mut self.g.counters));
        if span.is_some() {
            let name = format!("kernel-launch {region}");
            obs.spans.finish(span, &name, crate::obs::SpanKind::Interp, self.g.team_id as u64);
        }
        assert_eq!(ret, 0, "kernel launch RPC failed for {region}");
    }

    /// Width-dispatched device store shared by both executors (the
    /// memory-traffic counter charge included).
    fn mem_store(&mut self, a: u64, v: Value, width: Width) {
        self.g.mem(width as u64, Pattern::Strided);
        match (v, width) {
            (Value::F(f), 8) => self.env.device.mem.write_f64(a, f),
            (Value::F(f), 4) => self.env.device.mem.write_f32(a, f as f32),
            (v, 8) => self.env.device.mem.write_i64(a, v.as_i()),
            (v, 4) => self.env.device.mem.write_u32(a, v.as_i() as u32),
            (v, 1) => self.env.device.mem.write_u8(a, v.as_i() as u8),
            (_, w) => panic!("bad store width {w}"),
        }
    }

    /// Width/type-dispatched device load shared by both executors.
    fn mem_load(&mut self, a: u64, width: Width, ty: Ty) -> Value {
        self.g.mem(width as u64, Pattern::Strided);
        match (ty, width) {
            (Ty::F64, 8) => Value::F(self.env.device.mem.read_f64(a)),
            (Ty::F64, 4) => Value::F(self.env.device.mem.read_f32(a) as f64),
            (_, 8) => Value::I(self.env.device.mem.read_i64(a)),
            (_, 4) => Value::I(self.env.device.mem.read_u32(a) as i32 as i64),
            (_, 1) => Value::I(self.env.device.mem.read_u8(a) as i64),
            (_, w) => panic!("bad load width {w}"),
        }
    }

    // ----- the register-file executor -------------------------------

    /// Call a lowered function: allocate its register file, bind
    /// parameters by slot, and run the body.
    fn call_lowered(
        &mut self,
        lf: &LoweredFunction,
        pool: &[Value],
        args: Vec<Value>,
    ) -> Option<Value> {
        let mut regs = vec![Value::I(0); lf.nslots as usize];
        for (slot, v) in lf.param_slots.iter().zip(args) {
            regs[*slot as usize] = v;
        }
        self.enter_lowered(pool, &mut regs, &lf.body)
    }

    /// The lowered twin of [`Self::exec_function_body`]: same call-depth
    /// and stack-pointer bookkeeping, but the frame is the caller-built
    /// register file instead of a fresh `HashMap`.
    fn enter_lowered(
        &mut self,
        pool: &[Value],
        regs: &mut [Value],
        body: &[LowInstr],
    ) -> Option<Value> {
        self.depth += 1;
        assert!(self.depth < 128, "interpreter call depth exceeded");
        let saved_sp = self.sp;
        let flow = self.exec_low_body(pool, regs, body);
        self.sp = saved_sp;
        self.depth -= 1;
        match flow {
            Flow::Returned(v) => v,
            Flow::Normal => None,
        }
    }

    fn exec_low_body(&mut self, pool: &[Value], regs: &mut [Value], body: &[LowInstr]) -> Flow {
        for ins in body {
            match self.exec_low_instr(pool, regs, ins) {
                Flow::Normal => {}
                ret => return ret,
            }
        }
        Flow::Normal
    }

    /// One lowered instruction. Counter discipline mirrors
    /// [`Self::exec_instr`] exactly: one `int_ops` charge per
    /// instruction up front, and each superinstruction charges its
    /// *second* component too, so fused and unfused runs model the same
    /// device time.
    fn exec_low_instr(&mut self, pool: &[Value], regs: &mut [Value], ins: &LowInstr) -> Flow {
        self.g.counters.int_ops += 1;
        match ins {
            LowInstr::Assign { dst, expr } => {
                let v = self.eval_low_expr(pool, regs, expr);
                regs[*dst as usize] = v;
            }
            LowInstr::Alloca { dst, size } => {
                let addr = crate::alloc::align_up(self.sp, 16);
                assert!(addr + size <= self.stack_end, "device stack overflow");
                self.sp = addr + size;
                regs[*dst as usize] = Value::I(addr as i64);
            }
            LowInstr::Store { addr, val, width } => {
                let a = lv(pool, regs, *addr).as_addr();
                let v = lv(pool, regs, *val);
                self.mem_store(a, v, *width);
            }
            LowInstr::Load { dst, addr, width, ty } => {
                let a = lv(pool, regs, *addr).as_addr();
                let v = self.mem_load(a, *width, *ty);
                regs[*dst as usize] = v;
            }
            LowInstr::Call { dst, callee, args } => {
                let vals: Vec<Value> = args.iter().map(|&a| lv(pool, regs, a)).collect();
                let ret = self.call_function(callee, vals);
                if let Some(d) = dst {
                    regs[*d as usize] = ret.unwrap_or(Value::I(0));
                }
            }
            LowInstr::Intrinsic { dst, name, args } => {
                let vals: Vec<Value> = args.iter().map(|&a| lv(pool, regs, a)).collect();
                let ret = match self.env.resolution.class_of(name) {
                    Some(SymbolClass::Device(dev)) => self.device_fn(dev, &vals),
                    Some(SymbolClass::HostRpc(_)) => panic!(
                        "intrinsic {name} resolves host-RPC, not device-native \
                         (malformed module: verify() would reject it)"
                    ),
                    Some(SymbolClass::Unresolved) | None => {
                        self.env.unresolved_trap(name);
                        Value::I(0)
                    }
                };
                if let Some(d) = dst {
                    regs[*d as usize] = ret;
                }
            }
            LowInstr::RpcCall { dst, callee_id, args } => {
                let ret = self.issue_rpc_lowered(pool, regs, *callee_id, args);
                if let Some(d) = dst {
                    regs[*d as usize] = Value::I(ret);
                }
            }
            LowInstr::KernelLaunch { region, arg, params } => {
                let values: Vec<Value> = params.iter().map(|&p| lv(pool, regs, p)).collect();
                let requested = arg.as_ref().map(|&o| lv(pool, regs, o).as_i() as usize);
                self.kernel_launch_with(region, values, requested);
            }
            LowInstr::If { cond, then_body, else_body } => {
                let c = lv(pool, regs, *cond).truthy();
                let flow = if c {
                    self.exec_low_body(pool, regs, then_body)
                } else {
                    self.exec_low_body(pool, regs, else_body)
                };
                if let Flow::Returned(_) = flow {
                    return flow;
                }
            }
            LowInstr::While { cond_var, cond, body } => loop {
                if let Flow::Returned(v) = self.exec_low_body(pool, regs, cond) {
                    return Flow::Returned(v);
                }
                if !regs[*cond_var as usize].truthy() {
                    break;
                }
                if let Flow::Returned(v) = self.exec_low_body(pool, regs, body) {
                    return Flow::Returned(v);
                }
            },
            LowInstr::For { var, lo, hi, step, schedule, body } => {
                let lo = lv(pool, regs, *lo).as_i();
                let hi = lv(pool, regs, *hi).as_i();
                let step = lv(pool, regs, *step).as_i().max(1);
                let (start, stride) = match schedule {
                    Schedule::Seq => (lo, step),
                    Schedule::Team => {
                        let t = self.g.thread_id as i64;
                        let n = self.g.cfg.threads_per_team as i64;
                        (lo + t * step, n * step)
                    }
                    Schedule::Grid => {
                        let t = self.g.global_tid() as i64;
                        let n = self.g.num_threads_global() as i64;
                        (lo + t * step, n * step)
                    }
                };
                let mut i = start;
                while i < hi {
                    regs[*var as usize] = Value::I(i);
                    if let Flow::Returned(v) = self.exec_low_body(pool, regs, body) {
                        return Flow::Returned(v);
                    }
                    i += stride;
                }
            }
            LowInstr::Parallel { num_threads, body } => {
                let n = num_threads
                    .as_ref()
                    .map(|&o| lv(pool, regs, o).as_i() as usize)
                    .unwrap_or(128)
                    .clamp(1, 1024);
                // The register-file analogue of the tree-walk frame
                // snapshot: every thread starts from a copy of the
                // current registers (verify() guarantees the body only
                // reads names in scope, i.e. slots of this function).
                let snapshot: Vec<Value> = regs.to_vec();
                let env = self.env;
                let has_barrier = low_body_has_barrier(body);
                let cfg = LaunchConfig::new(1, n);
                let runner = |g: &mut GridCtx| {
                    let mut interp = Interp::new(env, g);
                    let mut thread_regs = snapshot.clone();
                    interp.enter_lowered(pool, &mut thread_regs, body);
                };
                let obs = &env.device.mem.obs;
                let span = obs.spans.start();
                let stats = if has_barrier {
                    env.device.launch_coop(cfg, runner)
                } else {
                    env.device.launch(cfg, runner)
                };
                obs.spans.finish(
                    span,
                    "parallel-region [register]",
                    crate::obs::SpanKind::Interp,
                    self.g.team_id as u64,
                );
                let mut agg = env.kernel_stats.lock().unwrap();
                *agg = agg.add(&stats);
            }
            LowInstr::Barrier => {
                if self.g.num_threads_global() > 1 {
                    self.g.barrier_global();
                } else {
                    self.g.counters.barriers_global += 1;
                }
            }
            LowInstr::Return(v) => {
                let val = v.as_ref().map(|&o| lv(pool, regs, o));
                return Flow::Returned(val);
            }
            LowInstr::CmpIf { tmp, op, a, b, then_body, else_body } => {
                let x = lv(pool, regs, *a);
                let y = lv(pool, regs, *b);
                if op.is_float() {
                    self.g.counters.flops_f64 += 1;
                } else {
                    self.g.counters.int_ops += 1;
                }
                let c = eval_bin(*op, x, y);
                regs[*tmp as usize] = c;
                // The fused branch still charges its instruction slot.
                self.g.counters.int_ops += 1;
                let flow = if c.truthy() {
                    self.exec_low_body(pool, regs, then_body)
                } else {
                    self.exec_low_body(pool, regs, else_body)
                };
                if let Flow::Returned(_) = flow {
                    return flow;
                }
            }
            LowInstr::GepLoad { tmp, base, off, dst, width, ty } => {
                let b = lv(pool, regs, *base).as_i();
                let o = lv(pool, regs, *off).as_i();
                let addr = Value::I(b + o);
                regs[*tmp as usize] = addr;
                // The fused load's instruction charge.
                self.g.counters.int_ops += 1;
                let v = self.mem_load(addr.as_addr(), *width, *ty);
                regs[*dst as usize] = v;
            }
            LowInstr::GepStore { tmp, base, off, val, width } => {
                let b = lv(pool, regs, *base).as_i();
                let o = lv(pool, regs, *off).as_i();
                let addr = Value::I(b + o);
                regs[*tmp as usize] = addr;
                // The fused store's instruction charge. `val` is read
                // *after* tmp is written, matching the unfused order
                // (the assign retires before the store evaluates).
                self.g.counters.int_ops += 1;
                let v = lv(pool, regs, *val);
                self.mem_store(addr.as_addr(), v, *width);
            }
            LowInstr::BinStore { tmp, op, a, b, addr, width } => {
                let x = lv(pool, regs, *a);
                let y = lv(pool, regs, *b);
                if op.is_float() {
                    self.g.counters.flops_f64 += 1;
                } else {
                    self.g.counters.int_ops += 1;
                }
                let v = eval_bin(*op, x, y);
                regs[*tmp as usize] = v;
                // The fused store's instruction charge; the address is
                // evaluated after tmp is written (unfused order).
                self.g.counters.int_ops += 1;
                let a_addr = lv(pool, regs, *addr).as_addr();
                self.mem_store(a_addr, v, *width);
            }
        }
        Flow::Normal
    }

    /// The lowered twin of [`Self::eval_expr`]: identical flop/int
    /// charges, operand fetches are two array indexes.
    fn eval_low_expr(&mut self, pool: &[Value], regs: &[Value], e: &LowExpr) -> Value {
        match e {
            LowExpr::Op(o) => lv(pool, regs, *o),
            LowExpr::Bin(op, a, b) => {
                let x = lv(pool, regs, *a);
                let y = lv(pool, regs, *b);
                if op.is_float() {
                    self.g.counters.flops_f64 += 1;
                } else {
                    self.g.counters.int_ops += 1;
                }
                eval_bin(*op, x, y)
            }
            LowExpr::Gep(base, off) => {
                Value::I(lv(pool, regs, *base).as_i() + lv(pool, regs, *off).as_i())
            }
            LowExpr::Select(c, a, b) => {
                if lv(pool, regs, *c).truthy() {
                    lv(pool, regs, *a)
                } else {
                    lv(pool, regs, *b)
                }
            }
            LowExpr::SiToFp(a) => Value::F(lv(pool, regs, *a).as_i() as f64),
            LowExpr::FpToSi(a) => Value::I(lv(pool, regs, *a).as_f() as i64),
            LowExpr::Tid => Value::I(self.g.global_tid() as i64),
            LowExpr::NumThreads => Value::I(self.g.num_threads_global() as i64),
            LowExpr::Sqrt(a) => {
                self.g.counters.flops_f64 += 4;
                Value::F(lv(pool, regs, *a).as_f().sqrt())
            }
            LowExpr::Exp(a) => {
                self.g.counters.flops_f64 += 8;
                Value::F(lv(pool, regs, *a).as_f().exp())
            }
            LowExpr::Log(a) => {
                self.g.counters.flops_f64 += 8;
                Value::F(lv(pool, regs, *a).as_f().ln())
            }
        }
    }

    /// The lowered twin of [`Self::issue_rpc`]: identical marshaling
    /// (MultiRef candidate matching, DynRef `_FindObj` fallback), then
    /// the shared [`Self::dispatch_rpc`] tail.
    fn issue_rpc_lowered(
        &mut self,
        pool: &[Value],
        regs: &[Value],
        callee_id: u64,
        specs: &[LowRpcArg],
    ) -> i64 {
        let mut info = RpcArgInfo::with_capacity(specs.len());
        for spec in specs {
            match spec {
                LowRpcArg::Val(op) => {
                    let bits = match lv(pool, regs, *op) {
                        Value::I(i) => i as u64,
                        Value::F(f) => f.to_bits(),
                    };
                    info.add_val(bits);
                }
                LowRpcArg::Ref { ptr, mode, obj_size, offset } => {
                    let p = lv(pool, regs, *ptr).as_addr();
                    let off = match offset {
                        LowOffset::Const(c) => *c,
                        LowOffset::Dynamic => {
                            self.env.find_object(p).map(|(base, _)| p - base).unwrap_or(0)
                        }
                    };
                    info.add_ref(p, *mode, *obj_size, off);
                }
                LowRpcArg::MultiRef { ptr, candidates } => {
                    let p = lv(pool, regs, *ptr).as_addr();
                    let mut matched = false;
                    for (cand, mode, size) in candidates {
                        let base = lv(pool, regs, *cand).as_addr();
                        if p >= base && p < base + size.max(&1) {
                            info.add_ref(p, *mode, *size, p - base);
                            matched = true;
                            break;
                        }
                    }
                    if !matched {
                        info.add_val(p);
                    }
                }
                LowRpcArg::DynRef { ptr, mode } => {
                    let p = lv(pool, regs, *ptr).as_addr();
                    match self.env.find_object(p) {
                        Some((base, size)) => {
                            info.add_ref(p, *mode, size, p - base);
                        }
                        None => {
                            info.add_val(p);
                        }
                    }
                }
            }
        }
        self.dispatch_rpc(callee_id, &info)
    }

    // ----- the bytecode executor ------------------------------------

    /// Call a bytecode function: allocate its register file (including
    /// the hidden loop slots appended by flattening), bind parameters by
    /// slot, and run the flat pc loop.
    fn call_bytecode(
        &mut self,
        bf: &BytecodeFunction,
        pool: &[Value],
        args: Vec<Value>,
    ) -> Option<Value> {
        let mut regs = vec![Value::I(0); bf.nslots as usize];
        for (slot, v) in bf.param_slots.iter().zip(args) {
            regs[*slot as usize] = v;
        }
        self.enter_bytecode(bf, pool, &mut regs)
    }

    /// The bytecode twin of [`Self::enter_lowered`]: same call-depth and
    /// stack-pointer bookkeeping around the dispatch loop.
    fn enter_bytecode(
        &mut self,
        bf: &BytecodeFunction,
        pool: &[Value],
        regs: &mut [Value],
    ) -> Option<Value> {
        self.depth += 1;
        assert!(self.depth < 128, "interpreter call depth exceeded");
        let saved_sp = self.sp;
        let ret = self.run_bytecode(bf, pool, regs, 0, bf.code.len());
        self.sp = saved_sp;
        self.depth -= 1;
        ret
    }

    /// The flat dispatch loop: execute `[start, end)` until a return or
    /// until the pc falls off `end` (a void return — validated branch
    /// targets may equal `code.len()`).
    fn run_bytecode(
        &mut self,
        bf: &BytecodeFunction,
        pool: &[Value],
        regs: &mut [Value],
        start: usize,
        end: usize,
    ) -> Option<Value> {
        let mut pc = start;
        while pc < end {
            match self.exec_bc_op(bf, pool, regs, pc) {
                BcFlow::Next => pc += 1,
                BcFlow::Jump(t) => pc = t as usize,
                BcFlow::Returned(v) => return v,
            }
        }
        None
    }

    /// Advance one batched lane by at most `quantum` dispatched ops
    /// (nested calls, RPC waits and kernel launches run to completion
    /// inside their op). Returns true when the lane finished its body
    /// range.
    fn step_bytecode(
        &mut self,
        bf: &BytecodeFunction,
        pool: &[Value],
        t: &mut BcThread,
        end: usize,
        quantum: usize,
    ) -> bool {
        for _ in 0..quantum {
            if t.pc >= end {
                return true;
            }
            match self.exec_bc_op(bf, pool, &mut t.regs, t.pc) {
                BcFlow::Next => t.pc += 1,
                BcFlow::Jump(p) => t.pc = p as usize,
                BcFlow::Returned(_) => {
                    t.pc = end;
                    return true;
                }
            }
        }
        t.pc >= end
    }

    /// One bytecode op. Counter discipline mirrors
    /// [`Self::exec_low_instr`] exactly: one `int_ops` charge per op
    /// derived from a lowered instruction, superinstructions charge
    /// their second component too, and pure flattening artifacts
    /// ([`Op::Jump`], [`Op::BrZeroFree`], [`Op::ForHead`],
    /// [`Op::ForNext`]) charge nothing — so modeled device counters are
    /// executor-invariant.
    fn exec_bc_op(
        &mut self,
        bf: &BytecodeFunction,
        pool: &[Value],
        regs: &mut [Value],
        pc: usize,
    ) -> BcFlow {
        let op = bf.code[pc];
        // Zero-charge flattening artifacts first: they have no lowered
        // counterpart, so they must not perturb counter parity.
        match op {
            Op::Jump { target } => return BcFlow::Jump(target),
            Op::BrZeroFree { cond, target } => {
                return if regs[cond as usize].truthy() {
                    BcFlow::Next
                } else {
                    BcFlow::Jump(target)
                };
            }
            Op::ForHead { i_slot, hi_slot, var, exit } => {
                let i = regs[i_slot as usize].as_i();
                return if i < regs[hi_slot as usize].as_i() {
                    regs[var as usize] = Value::I(i);
                    BcFlow::Next
                } else {
                    BcFlow::Jump(exit)
                };
            }
            Op::ForNext { i_slot, stride_slot, head } => {
                let next = regs[i_slot as usize].as_i() + regs[stride_slot as usize].as_i();
                regs[i_slot as usize] = Value::I(next);
                return BcFlow::Jump(head);
            }
            _ => {}
        }
        self.g.counters.int_ops += 1;
        match op {
            Op::Mov { dst, src } => regs[dst as usize] = bv(pool, regs, src),
            Op::Bin { dst, op, a, b } => {
                let x = bv(pool, regs, a);
                let y = bv(pool, regs, b);
                if op.is_float() {
                    self.g.counters.flops_f64 += 1;
                } else {
                    self.g.counters.int_ops += 1;
                }
                regs[dst as usize] = eval_bin(op, x, y);
            }
            Op::Gep { dst, base, off } => {
                regs[dst as usize] =
                    Value::I(bv(pool, regs, base).as_i() + bv(pool, regs, off).as_i());
            }
            Op::Select { dst, cond, a, b } => {
                regs[dst as usize] = if bv(pool, regs, cond).truthy() {
                    bv(pool, regs, a)
                } else {
                    bv(pool, regs, b)
                };
            }
            Op::SiToFp { dst, a } => {
                regs[dst as usize] = Value::F(bv(pool, regs, a).as_i() as f64)
            }
            Op::FpToSi { dst, a } => {
                regs[dst as usize] = Value::I(bv(pool, regs, a).as_f() as i64)
            }
            Op::Tid { dst } => regs[dst as usize] = Value::I(self.g.global_tid() as i64),
            Op::NumThreads { dst } => {
                regs[dst as usize] = Value::I(self.g.num_threads_global() as i64)
            }
            Op::Sqrt { dst, a } => {
                self.g.counters.flops_f64 += 4;
                regs[dst as usize] = Value::F(bv(pool, regs, a).as_f().sqrt());
            }
            Op::Exp { dst, a } => {
                self.g.counters.flops_f64 += 8;
                regs[dst as usize] = Value::F(bv(pool, regs, a).as_f().exp());
            }
            Op::Log { dst, a } => {
                self.g.counters.flops_f64 += 8;
                regs[dst as usize] = Value::F(bv(pool, regs, a).as_f().ln());
            }
            Op::Alloca { dst, size } => {
                let addr = crate::alloc::align_up(self.sp, 16);
                assert!(addr + size <= self.stack_end, "device stack overflow");
                self.sp = addr + size;
                regs[dst as usize] = Value::I(addr as i64);
            }
            Op::Store { addr, val, width } => {
                let a = bv(pool, regs, addr).as_addr();
                let v = bv(pool, regs, val);
                self.mem_store(a, v, width);
            }
            Op::Load { dst, addr, width, ty } => {
                let a = bv(pool, regs, addr).as_addr();
                regs[dst as usize] = self.mem_load(a, width, ty);
            }
            Op::Call { site } => {
                let cs = &bf.calls[site as usize];
                let vals: Vec<Value> = cs.args.iter().map(|&a| bv(pool, regs, a)).collect();
                let ret = self.call_function(&cs.callee, vals);
                if let Some(d) = cs.dst {
                    regs[d as usize] = ret.unwrap_or(Value::I(0));
                }
            }
            Op::Intrinsic { site } => {
                let cs = &bf.calls[site as usize];
                let vals: Vec<Value> = cs.args.iter().map(|&a| bv(pool, regs, a)).collect();
                let ret = match self.env.resolution.class_of(&cs.callee) {
                    Some(SymbolClass::Device(dev)) => self.device_fn(dev, &vals),
                    Some(SymbolClass::HostRpc(_)) => panic!(
                        "intrinsic {} resolves host-RPC, not device-native \
                         (malformed module: verify() would reject it)",
                        cs.callee
                    ),
                    Some(SymbolClass::Unresolved) | None => {
                        self.env.unresolved_trap(&cs.callee);
                        Value::I(0)
                    }
                };
                if let Some(d) = cs.dst {
                    regs[d as usize] = ret;
                }
            }
            Op::Rpc { site } => {
                let rs = &bf.rpcs[site as usize];
                let ret = self.issue_rpc_bytecode(pool, regs, rs);
                if let Some(d) = rs.dst {
                    regs[d as usize] = Value::I(ret);
                }
            }
            Op::Launch { site } => {
                let ls = &bf.launches[site as usize];
                let values: Vec<Value> = ls.params.iter().map(|&p| bv(pool, regs, p)).collect();
                let requested = ls.arg.map(|o| bv(pool, regs, o).as_i() as usize);
                self.kernel_launch_with(&ls.region, values, requested);
            }
            Op::Barrier => {
                if self.g.num_threads_global() > 1 {
                    self.g.barrier_global();
                } else {
                    self.g.counters.barriers_global += 1;
                }
            }
            Op::Return { val } => return BcFlow::Returned(Some(bv(pool, regs, val))),
            Op::ReturnVoid => return BcFlow::Returned(None),
            Op::BrZero { cond, target } => {
                return if bv(pool, regs, cond).truthy() {
                    BcFlow::Next
                } else {
                    BcFlow::Jump(target)
                };
            }
            Op::LoopEntry => {}
            Op::ForInit { lo, hi, step, sched, i_slot, hi_slot, stride_slot } => {
                let lo = bv(pool, regs, lo).as_i();
                let hi = bv(pool, regs, hi).as_i();
                let step = bv(pool, regs, step).as_i().max(1);
                let (start, stride) = match sched {
                    Schedule::Seq => (lo, step),
                    Schedule::Team => {
                        let t = self.g.thread_id as i64;
                        let n = self.g.cfg.threads_per_team as i64;
                        (lo + t * step, n * step)
                    }
                    Schedule::Grid => {
                        let t = self.g.global_tid() as i64;
                        let n = self.g.num_threads_global() as i64;
                        (lo + t * step, n * step)
                    }
                };
                regs[i_slot as usize] = Value::I(start);
                regs[hi_slot as usize] = Value::I(hi);
                regs[stride_slot as usize] = Value::I(stride);
            }
            Op::Par { site } => {
                self.bc_parallel(bf, pool, regs, site);
                // The dispatching thread skips the inline body range.
                return BcFlow::Jump(bf.pars[site as usize].body_end);
            }
            Op::CmpBr { tmp, op, a, b, else_target } => {
                let x = bv(pool, regs, a);
                let y = bv(pool, regs, b);
                if op.is_float() {
                    self.g.counters.flops_f64 += 1;
                } else {
                    self.g.counters.int_ops += 1;
                }
                let c = eval_bin(op, x, y);
                regs[tmp as usize] = c;
                // The fused branch still charges its instruction slot.
                self.g.counters.int_ops += 1;
                return if c.truthy() { BcFlow::Next } else { BcFlow::Jump(else_target) };
            }
            Op::GepLoad { tmp, base, off, dst, width, ty } => {
                let addr = Value::I(bv(pool, regs, base).as_i() + bv(pool, regs, off).as_i());
                regs[tmp as usize] = addr;
                // The fused load's instruction charge.
                self.g.counters.int_ops += 1;
                regs[dst as usize] = self.mem_load(addr.as_addr(), width, ty);
            }
            Op::GepStore { tmp, base, off, val, width } => {
                let addr = Value::I(bv(pool, regs, base).as_i() + bv(pool, regs, off).as_i());
                regs[tmp as usize] = addr;
                // The fused store's instruction charge; `val` is read
                // *after* tmp is written, matching the unfused order.
                self.g.counters.int_ops += 1;
                let v = bv(pool, regs, val);
                self.mem_store(addr.as_addr(), v, width);
            }
            Op::BinStore { tmp, op, a, b, addr, width } => {
                let x = bv(pool, regs, a);
                let y = bv(pool, regs, b);
                if op.is_float() {
                    self.g.counters.flops_f64 += 1;
                } else {
                    self.g.counters.int_ops += 1;
                }
                let v = eval_bin(op, x, y);
                regs[tmp as usize] = v;
                // The fused store's instruction charge; the address is
                // evaluated after tmp is written (unfused order).
                self.g.counters.int_ops += 1;
                let a_addr = bv(pool, regs, addr).as_addr();
                self.mem_store(a_addr, v, width);
            }
            Op::Jump { .. } | Op::BrZeroFree { .. } | Op::ForHead { .. } | Op::ForNext { .. } => {
                unreachable!("zero-charge ops handled above")
            }
        }
        BcFlow::Next
    }

    /// `parallel` dispatch from bytecode. The barrier-free case uses the
    /// engine's **batched team stepping** ([`Device::launch_batched`]):
    /// every lane of a worker's chunk is materialized once, then all
    /// lanes advance round-robin through bounded op quanta — one
    /// dispatch round amortizes frame setup and RPC-wait polling across
    /// the whole team loop instead of re-entering the interpreter per
    /// team per step. Barrier bodies keep one real thread per lane
    /// (`launch_coop`): a lane blocked in a barrier cannot yield its
    /// quantum cooperatively.
    fn bc_parallel(&mut self, bf: &BytecodeFunction, pool: &[Value], regs: &[Value], site: u32) {
        let ps = &bf.pars[site as usize];
        let n = ps
            .num_threads
            .map(|o| bv(pool, regs, o).as_i() as usize)
            .unwrap_or(128)
            .clamp(1, 1024);
        let snapshot: Vec<Value> = regs.to_vec();
        let env = self.env;
        let cfg = LaunchConfig::new(1, n);
        let (start, end) = (ps.body_start as usize, ps.body_end as usize);
        let obs = &env.device.mem.obs;
        let span = obs.spans.start();
        let stats = if ps.has_barrier {
            env.device.launch_coop(cfg, |g| {
                let mut interp = Interp::new(env, g);
                let mut thread_regs = snapshot.clone();
                interp.run_bytecode(bf, pool, &mut thread_regs, start, end);
            })
        } else {
            env.device.launch_batched(
                cfg,
                |g| {
                    let base = env.stack_base();
                    BcThread {
                        regs: snapshot.clone(),
                        pc: start,
                        sp: base,
                        stack_end: base + PER_THREAD_STACK,
                        rand: DeviceRand::for_thread(0xD00D, g.global_tid() as u64),
                    }
                },
                |g, t: &mut BcThread| {
                    // A transient interpreter per quantum: cheap (the
                    // HashMap frame stays empty on the bytecode path)
                    // and it restores the lane's stack pointer and RNG
                    // from the persisted lane state.
                    let mut interp = Interp {
                        env,
                        g,
                        frames: vec![HashMap::new()],
                        sp: t.sp,
                        stack_end: t.stack_end,
                        rand: t.rand,
                        depth: 0,
                    };
                    let done = interp.step_bytecode(bf, pool, t, end, BC_PAR_QUANTUM);
                    t.sp = interp.sp;
                    t.rand = interp.rand;
                    done
                },
            )
        };
        obs.spans.finish(
            span,
            "parallel-region [bytecode]",
            crate::obs::SpanKind::Interp,
            self.g.team_id as u64,
        );
        let mut agg = env.kernel_stats.lock().unwrap();
        *agg = agg.add(&stats);
    }

    /// The bytecode twin of [`Self::issue_rpc_lowered`], marshaling from
    /// tagged operand words (including the dynamic-offset `Ref` form,
    /// recovered through the object lookup at marshal time).
    fn issue_rpc_bytecode(&mut self, pool: &[Value], regs: &[Value], site: &RpcSite) -> i64 {
        let mut info = RpcArgInfo::with_capacity(site.args.len());
        for spec in &site.args {
            match spec {
                BcRpcArg::Val(o) => {
                    let bits = match bv(pool, regs, *o) {
                        Value::I(i) => i as u64,
                        Value::F(f) => f.to_bits(),
                    };
                    info.add_val(bits);
                }
                BcRpcArg::Ref { ptr, mode, obj_size, offset } => {
                    let p = bv(pool, regs, *ptr).as_addr();
                    let off = match offset {
                        LowOffset::Const(c) => *c,
                        LowOffset::Dynamic => {
                            self.env.find_object(p).map(|(base, _)| p - base).unwrap_or(0)
                        }
                    };
                    info.add_ref(p, *mode, *obj_size, off);
                }
                BcRpcArg::MultiRef { ptr, candidates } => {
                    let p = bv(pool, regs, *ptr).as_addr();
                    let mut matched = false;
                    for (cand, mode, size) in candidates {
                        let base = bv(pool, regs, *cand).as_addr();
                        if p >= base && p < base + size.max(&1) {
                            info.add_ref(p, *mode, *size, p - base);
                            matched = true;
                            break;
                        }
                    }
                    if !matched {
                        info.add_val(p);
                    }
                }
                BcRpcArg::DynRef { ptr, mode } => {
                    let p = bv(pool, regs, *ptr).as_addr();
                    match self.env.find_object(p) {
                        Some((base, size)) => info.add_ref(p, *mode, size, p - base),
                        None => info.add_val(p),
                    }
                }
            }
        }
        self.dispatch_rpc(site.callee_id, &info)
    }
}

/// Flow result of one bytecode op.
enum BcFlow {
    Next,
    Jump(u32),
    Returned(Option<Value>),
}

/// Per-lane state of a batched `parallel` dispatch: everything a lane
/// needs to resume where its last quantum left off.
struct BcThread {
    regs: Vec<Value>,
    pc: usize,
    sp: u64,
    stack_end: u64,
    rand: DeviceRand,
}

/// Ops per lane per batched dispatch round: large enough to amortize
/// the per-quantum interpreter setup, small enough that lanes of a
/// chunk interleave rather than run to completion one after another.
const BC_PAR_QUANTUM: usize = 256;

/// Bytecode-operand fetch: [`POOL_BIT`] picks pool vs slot — two array
/// indexes, like [`lv`].
#[inline(always)]
fn bv(pool: &[Value], regs: &[Value], x: u32) -> Value {
    if x & POOL_BIT != 0 {
        pool[(x & !POOL_BIT) as usize]
    } else {
        regs[x as usize]
    }
}

/// Lowered-operand fetch: a slot read or a pool read — two array
/// indexes, no string hashing (the point of the register-file core).
#[inline(always)]
fn lv(pool: &[Value], regs: &[Value], op: LowOp) -> Value {
    match op {
        LowOp::Slot(s) => regs[s as usize],
        LowOp::Pool(p) => pool[p as usize],
    }
}

fn eval_bin(op: BinOp, x: Value, y: Value) -> Value {
    use BinOp::*;
    match op {
        Add => Value::I(x.as_i().wrapping_add(y.as_i())),
        Sub => Value::I(x.as_i().wrapping_sub(y.as_i())),
        Mul => Value::I(x.as_i().wrapping_mul(y.as_i())),
        Div => Value::I(x.as_i().checked_div(y.as_i()).unwrap_or(0)),
        Rem => Value::I(x.as_i().checked_rem(y.as_i()).unwrap_or(0)),
        And => Value::I(x.as_i() & y.as_i()),
        Or => Value::I(x.as_i() | y.as_i()),
        Xor => Value::I(x.as_i() ^ y.as_i()),
        Shl => Value::I(x.as_i().wrapping_shl(y.as_i() as u32)),
        Shr => Value::I((x.as_i() as u64 >> (y.as_i() as u32 & 63)) as i64),
        Eq => Value::I((x.as_i() == y.as_i()) as i64),
        Ne => Value::I((x.as_i() != y.as_i()) as i64),
        Lt => Value::I((x.as_i() < y.as_i()) as i64),
        Le => Value::I((x.as_i() <= y.as_i()) as i64),
        Gt => Value::I((x.as_i() > y.as_i()) as i64),
        Ge => Value::I((x.as_i() >= y.as_i()) as i64),
        FAdd => Value::F(x.as_f() + y.as_f()),
        FSub => Value::F(x.as_f() - y.as_f()),
        FMul => Value::F(x.as_f() * y.as_f()),
        FDiv => Value::F(x.as_f() / y.as_f()),
        FLt => Value::I((x.as_f() < y.as_f()) as i64),
        FLe => Value::I((x.as_f() <= y.as_f()) as i64),
        FGt => Value::I((x.as_f() > y.as_f()) as i64),
        FGe => Value::I((x.as_f() >= y.as_f()) as i64),
        FEq => Value::I((x.as_f() == y.as_f()) as i64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::grid::AllocatorKind;
    use crate::gpu::memory::MemConfig;
    use crate::rpc::wrappers::register_common;
    use crate::rpc::RpcServer;

    fn setup(src: &str, opts: crate::transform::CompileOptions) -> (Arc<ProgramEnv>, RpcServer) {
        let mut module = crate::ir::parser::parse_module(src).unwrap();
        let registry = Arc::new(WrapperRegistry::new());
        register_common(&registry);
        crate::transform::compile(&mut module, &registry, opts).unwrap();
        let device = Arc::new(Device::new(MemConfig::small(), AllocatorKind::Generic));
        let host = Arc::new(crate::rpc::HostEnv::new());
        let server = RpcServer::start(
            Arc::clone(&device.mem),
            Arc::clone(&registry),
            Arc::clone(&host),
        );
        let env = ProgramEnv::load(module, device, registry, host);
        (env, server)
    }

    #[test]
    fn arithmetic_and_control_flow() {
        let src = r#"
func @fib(%n: i64) -> i64 {
  %c = lt %n, 2
  if %c {
    return %n
  }
  %a = sub %n, 1
  %b = sub %n, 2
  %x = call fib(%a)
  %y = call fib(%b)
  %r = add %x, %y
  return %r
}

func @main() -> i64 {
  %r = call fib(10)
  return %r
}
"#;
        let (env, server) = setup(src, crate::transform::CompileOptions::default());
        let (ret, _) = env.run_main(&[]);
        assert_eq!(ret, 55);
        server.stop();
    }

    #[test]
    fn memory_and_intrinsics() {
        let src = r#"
func @main() -> i64 {
  %p = call malloc(64)
  store.8 12345, %p
  %q = gep %p, 8
  store.4 7, %q
  %a = load.8 %p
  %b = load.4 %q
  %s = add %a, %b
  call free(%p)
  return %s
}
"#;
        let (env, server) = setup(src, crate::transform::CompileOptions::default());
        let (ret, _) = env.run_main(&[]);
        assert_eq!(ret, 12352);
        server.stop();
    }

    #[test]
    fn rpc_printf_reaches_host_stdout() {
        let src = r#"
global @fmt const 16 "value: %d done"

func @main() -> i64 {
  call printf(@fmt, 42)
  return 0
}
"#;
        let (env, server) = setup(src, crate::transform::CompileOptions::default());
        let (ret, stats) = env.run_main(&[]);
        assert_eq!(ret, 0);
        assert_eq!(env.host.stdout_string(), "value: 42 done");
        assert_eq!(stats.rpc_calls, 1);
        server.stop();
    }

    #[test]
    fn multiteam_kernel_split_executes_whole_grid() {
        // Sum 0..N over the grid using atomic-free per-slot writes, then a
        // serial reduction in the main kernel.
        let src = r#"
global @acc 32768

func @main() -> i64 {
  %n = 4096
  parallel num_threads(256) {
    for.team %i = 0 to %n step 1 {
      %off = mul %i, 8
      %p = gep @acc, %off
      store.8 %i, %p
    }
  }
  %sum = alloca 8
  store.8 0, %sum
  for %i = 0 to %n step 1 {
    %off = mul %i, 8
    %p = gep @acc, %off
    %v = load.8 %p
    %s = load.8 %sum
    %s2 = add %s, %v
    store.8 %s2, %sum
  }
  %r = load.8 %sum
  return %r
}
"#;
        let (env, server) = setup(src, crate::transform::CompileOptions::default());
        let (ret, _) = env.run_main(&[]);
        assert_eq!(ret, 4096 * 4095 / 2);
        // The region really was kernel-split and multi-team launched.
        assert_eq!(env.kernel_launches.load(Ordering::Relaxed), 1);
        let ks = env.kernel_stats.lock().unwrap();
        assert!(ks.bytes_coalesced + ks.bytes_strided + ks.bytes_random > 0);
        server.stop();
    }

    #[test]
    fn single_team_mode_matches_multiteam_result() {
        let src = r#"
global @out 8192

func @main() -> i64 {
  parallel num_threads(64) {
    %t = tid
    %n = nthreads
    for.team %i = 0 to 1024 step 1 {
      %off = mul %i, 8
      %p = gep @out, %off
      %v = mul %i, 3
      store.8 %v, %p
    }
  }
  %p = gep @out, 8176
  %r = load.8 %p
  return %r
}
"#;
        let opts_multi = crate::transform::CompileOptions::default();
        let (env, server) = setup(src, opts_multi);
        let (multi, _) = env.run_main(&[]);
        server.stop();

        let opts_single = crate::transform::CompileOptions {
            multiteam: false,
            ..Default::default()
        };
        let (env2, server2) = setup(src, opts_single);
        let (single, _) = env2.run_main(&[]);
        server2.stop();

        assert_eq!(multi, 1022 * 3);
        assert_eq!(single, multi, "expansion must preserve semantics");
        assert_eq!(env2.kernel_launches.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn unresolved_symbol_degrades_to_counted_noop() {
        // Pre-refactor this panicked ("call to undefined function");
        // now libcres reports it at compile time and the runtime hit is
        // a counted no-op returning 0.
        let src = "func @main() -> i64 {\n  %r = call dgemm(1)\n  %x = call dgemm(2)\n  return %r\n}\n";
        let (env, server) = setup(src, crate::transform::CompileOptions::default());
        assert!(matches!(
            env.resolution.class_of("dgemm"),
            Some(crate::transform::SymbolClass::Unresolved)
        ));
        let (ret, _) = env.run_main(&[]);
        assert_eq!(ret, 0);
        assert_eq!(env.unresolved_calls.load(Ordering::Relaxed), 2);
        server.stop();
    }

    #[test]
    fn device_native_direct_call_dispatches_through_table() {
        // A hand-built module can carry Instr::Call to a device symbol
        // (bypassing the parser's intrinsic lowering); the table routes
        // it to the device libc rather than panicking.
        let src = "func @main() -> i64 {\n  %p = call malloc(32)\n  store.8 7, %p\n  %v = load.8 %p\n  call free(%p)\n  return %v\n}\n";
        let mut m = crate::ir::parser::parse_module(src).unwrap();
        // Re-introduce direct calls in place of the parsed intrinsics.
        let body = &mut m.functions.get_mut("main").unwrap().body;
        for ins in body.iter_mut() {
            if let Instr::Intrinsic { dst, name, args } = ins {
                *ins = Instr::Call { dst: dst.clone(), callee: name.clone(), args: args.clone() };
            }
        }
        let registry = Arc::new(WrapperRegistry::new());
        let device = Arc::new(Device::new(
            crate::gpu::memory::MemConfig::small(),
            crate::gpu::grid::AllocatorKind::Generic,
        ));
        let host = Arc::new(crate::rpc::HostEnv::new());
        let env = ProgramEnv::load(m, device, registry, host);
        let (ret, _) = env.run_main(&[]);
        assert_eq!(ret, 7);
        assert_eq!(env.unresolved_calls.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn loaded_sessions_mint_distinct_launch_sessions() {
        // Two programs loaded against one device must not alias a launch
        // ring slot: the loader mints a fresh monotonic session id per
        // load (pre-fix both were keyed by team id, which restarts at 0
        // in every session).
        let src = "func @main() -> i64 {\n  return 0\n}\n";
        let registry = Arc::new(WrapperRegistry::new());
        let device = Arc::new(Device::new(MemConfig::small(), AllocatorKind::Generic));
        let host = Arc::new(crate::rpc::HostEnv::new());
        let m1 = crate::ir::parser::parse_module(src).unwrap();
        let m2 = crate::ir::parser::parse_module(src).unwrap();
        let e1 =
            ProgramEnv::load(m1, Arc::clone(&device), Arc::clone(&registry), Arc::clone(&host));
        let e2 = ProgramEnv::load(m2, device, registry, host);
        // Strictly monotonic (other tests may mint concurrently, so the
        // gap can exceed 1 — never zero).
        assert!(e2.launch_session > e1.launch_session, "monotonic mint");
        // Consecutive session ids home onto distinct slots of a
        // multi-slot ring by construction (session % launch_slots).
        let mem = crate::gpu::memory::DeviceMemory::new(MemConfig::small());
        let arena = crate::rpc::engine::ArenaLayout::for_shape(1, 2);
        let c1 = RpcClient::for_launch_session(&mem, arena, 6);
        let c2 = RpcClient::for_launch_session(&mem, arena, 7);
        assert_ne!(c1.home_lane(), c2.home_lane(), "sessions spread over the ring");
    }

    #[test]
    fn fscanf_round_trip_via_host_file() {
        let src = r#"
global @path const 10 "input.txt"
global @mode const 2 "r"
global @fmt const 6 "%d %d"

func @main() -> i64 {
  %fd = call fopen(@path, @mode)
  %a = alloca 4
  %b = alloca 4
  %n = call fscanf(%fd, @fmt, %a, %b)
  call fclose(%fd)
  %x = load.4 %a
  %y = load.4 %b
  %s = add %x, %y
  %r = mul %s, %n
  return %r
}
"#;
        let (env, server) = setup(src, crate::transform::CompileOptions::default());
        env.host.put_file("input.txt", b"30 12");
        let (ret, _) = env.run_main(&[]);
        assert_eq!(ret, (30 + 12) * 2);
        server.stop();
    }

    /// A sequential corpus that exercises every fusion kind plus calls,
    /// loops, floats and intrinsics — deterministic counters, so the
    /// tree-walk and register-file executors must agree *exactly*.
    const EQUIV_SRC: &str = r#"
global @acc 800

func @step(%x: i64) -> i64 {
  %d = mul %x, 2
  return %d
}

func @main() -> i64 {
  %sum = alloca 8
  store.8 0, %sum
  for %i = 0 to 100 step 1 {
    %off = mul %i, 8
    %p = gep @acc, %off
    %v = call step(%i)
    store.8 %v, %p
    %q = gep @acc, %off
    %w = load.8 %q
    %s = load.8 %sum
    %s2 = add %s, %w
    store.8 %s2, %sum
  }
  %c = lt 1, 2
  if %c {
    %f = sitofp 9
    %r = sqrt %f
  }
  %total = load.8 %sum
  return %total
}
"#;

    #[test]
    fn all_three_executors_match_exactly() {
        // Bytecode leg: the default pipeline ends in `bytecode`.
        let (env, server) = setup(EQUIV_SRC, crate::transform::CompileOptions::default());
        assert!(env.module.bytecode.contains_key("main"), "default pipeline flattens");
        assert!(env.bpools.contains_key("main"), "bytecode pool resolved at load");
        assert!(env.module.bytecode["main"].fused > 0, "fusion carries through");
        let (bc_ret, bc_stats) = env.run_main(&[]);
        server.stop();

        // Register leg: `--no-bytecode` falls back to the lowered form.
        let reg = crate::transform::CompileOptions { bytecode: false, ..Default::default() };
        let (env1, server1) = setup(EQUIV_SRC, reg);
        assert!(env1.module.bytecode.is_empty(), "no-bytecode leg stays on the register core");
        assert!(env1.module.lowered.contains_key("main"), "register leg lowers");
        assert!(env1.module.lowered["main"].fused > 0, "fusable corpus fused");
        let (reg_ret, reg_stats) = env1.run_main(&[]);
        server1.stop();

        let tree = crate::transform::CompileOptions {
            lower: false,
            fuse: false,
            bytecode: false,
            ..Default::default()
        };
        let (env2, server2) = setup(EQUIV_SRC, tree);
        assert!(env2.module.lowered.is_empty(), "no-lower leg stays tree-walk");
        let (tree_ret, tree_stats) = env2.run_main(&[]);
        server2.stop();

        assert_eq!(bc_ret, 2 * (99 * 100 / 2));
        assert_eq!(bc_ret, reg_ret, "executors must agree on the result");
        assert_eq!(bc_ret, tree_ret, "executors must agree on the result");
        // Counter discipline is mirrored exactly (superinstructions
        // charge both components, flattening artifacts charge nothing),
        // so modeled work is identical across all three executors.
        assert_eq!(bc_stats.int_ops, reg_stats.int_ops, "int-op parity (bc vs reg)");
        assert_eq!(reg_stats.int_ops, tree_stats.int_ops, "int-op parity (reg vs tree)");
        assert_eq!(bc_stats.flops_f64, tree_stats.flops_f64, "flop parity");
        assert_eq!(
            bc_stats.bytes_strided, tree_stats.bytes_strided,
            "memory-traffic parity"
        );
    }

    #[test]
    fn fusion_off_still_runs_the_register_core() {
        let opts = crate::transform::CompileOptions {
            fuse: false,
            bytecode: false,
            ..Default::default()
        };
        let (env, server) = setup(EQUIV_SRC, opts);
        assert_eq!(env.module.lowered["main"].fused, 0);
        let (ret, _) = env.run_main(&[]);
        assert_eq!(ret, 2 * (99 * 100 / 2));
        server.stop();
    }

    #[test]
    fn fusion_off_bytecode_still_flattens() {
        // `bytecode` does not require `fuse`: the flattening simply has
        // no superinstructions.
        let opts = crate::transform::CompileOptions { fuse: false, ..Default::default() };
        let (env, server) = setup(EQUIV_SRC, opts);
        assert_eq!(env.module.bytecode["main"].fused, 0);
        let (ret, _) = env.run_main(&[]);
        assert_eq!(ret, 2 * (99 * 100 / 2));
        server.stop();
    }

    #[test]
    fn batched_parallel_lanes_persist_state_across_quanta() {
        // Each lane allocas a private accumulator and runs a loop far
        // longer than one step quantum ([`BC_PAR_QUANTUM`]); lane state
        // (registers, stack pointer, pc) must survive the round-robin
        // batched stepping. multiteam is off so the `parallel` op stays
        // un-expanded and dispatches through the batched path.
        let src = r#"
global @out 1024

func @main() -> i64 {
  parallel num_threads(128) {
    %acc = alloca 8
    store.8 0, %acc
    %t = tid
    for %i = 0 to 200 step 1 {
      %s = load.8 %acc
      %s2 = add %s, %i
      store.8 %s2, %acc
    }
    %off = mul %t, 8
    %p = gep @out, %off
    %v = load.8 %acc
    store.8 %v, %p
  }
  %p = gep @out, 504
  %r = load.8 %p
  return %r
}
"#;
        let opts = crate::transform::CompileOptions { multiteam: false, ..Default::default() };
        let (env, server) = setup(src, opts);
        assert!(env.module.bytecode.contains_key("main"), "runs on the bytecode tier");
        let (ret, _) = env.run_main(&[]);
        assert_eq!(ret, 199 * 200 / 2, "every lane accumulated its full loop");
        server.stop();
    }

    #[test]
    fn lowered_parallel_region_and_launch_resolve_slots() {
        // The multiteam pass extracts the region *before* lowering, so
        // the launch site carries params pre-resolved to caller slots
        // and the region itself runs on the register core per-thread.
        let src = r#"
global @out 2048

func @main() -> i64 {
  %n = 256
  parallel num_threads(64) {
    for.team %i = 0 to %n step 1 {
      %off = mul %i, 8
      %p = gep @out, %off
      store.8 %i, %p
    }
  }
  %p = gep @out, 2040
  %r = load.8 %p
  return %r
}
"#;
        let (env, server) = setup(src, crate::transform::CompileOptions::default());
        // Both main and the extracted region are lowered.
        assert_eq!(env.module.lowered.len(), env.module.functions.len());
        let (ret, _) = env.run_main(&[]);
        assert_eq!(ret, 255);
        assert_eq!(env.kernel_launches.load(Ordering::Relaxed), 1);
        server.stop();
    }
}
