//! The compiler IR.
//!
//! A small, typed, structured IR standing in for LLVM-IR in the
//! reproduction (DESIGN.md §2). It keeps exactly the features the paper's
//! passes reason about: address-taken stack objects (`alloca`), globals
//! with constness, pointer arithmetic (`gep`), `select` between pointers,
//! `malloc`-like calls, calls to *undefined* (library) functions, OpenMP
//! `parallel` regions with work-sharing loops and barriers, and thread-id
//! queries.
//!
//! Control flow is structured (if/while/for) rather than a CFG — the
//! paper's transforms (RPC generation §3.2, multi-team expansion §3.3)
//! operate on call sites and region structure, not on basic blocks, so a
//! structured IR keeps every pass and the interpreter small without losing
//! the analyses the paper needs.
//!
//! Text round-trip: [`parser`] and [`printer`]; program execution on the
//! simulated device: [`interp`].

pub mod parser;
pub mod printer;
pub mod interp;
pub mod lowered;
pub mod bytecode;

use std::collections::BTreeMap;
use std::fmt;

/// Value types. Pointers are untyped addresses (as in LLVM with opaque
/// pointers); object sizes live on the allocation site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ty {
    I64,
    F64,
    Ptr,
    Void,
}

impl fmt::Display for Ty {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Ty::I64 => write!(f, "i64"),
            Ty::F64 => write!(f, "f64"),
            Ty::Ptr => write!(f, "ptr"),
            Ty::Void => write!(f, "void"),
        }
    }
}

/// An operand: a local variable, a constant, or a global's address.
#[derive(Debug, Clone, PartialEq)]
pub enum Operand {
    Var(String),
    ConstI(i64),
    ConstF(f64),
    Global(String),
}

impl Operand {
    pub fn var(s: &str) -> Self {
        Operand::Var(s.to_string())
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    And,
    Or,
    Xor,
    Shl,
    Shr,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    FAdd,
    FSub,
    FMul,
    FDiv,
    FLt,
    FLe,
    FGt,
    FGe,
    FEq,
}

impl BinOp {
    pub fn is_float(self) -> bool {
        matches!(
            self,
            BinOp::FAdd
                | BinOp::FSub
                | BinOp::FMul
                | BinOp::FDiv
                | BinOp::FLt
                | BinOp::FLe
                | BinOp::FGt
                | BinOp::FGe
                | BinOp::FEq
        )
    }
}

/// Pure expressions assigned to locals.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Op(Operand),
    Bin(BinOp, Operand, Operand),
    /// Pointer arithmetic: `base + offset` (bytes).
    Gep(Operand, Operand),
    /// `select cond, a, b` — the pointer-`select` of Fig. 3a line 5.
    Select(Operand, Operand, Operand),
    /// Int→float / float→int conversions.
    SiToFp(Operand),
    FpToSi(Operand),
    /// OpenMP queries: thread id / team size, as the source observes them.
    Tid,
    NumThreads,
    /// sqrt/exp/log for the numeric benchmarks.
    Sqrt(Operand),
    Exp(Operand),
    Log(Operand),
}

/// Load/store access width in bytes (1, 4, or 8).
pub type Width = u8;

/// Work-sharing schedule of a `for` inside a parallel region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Sequential loop (not work-shared).
    Seq,
    /// `omp for`: distributed over the threads of the encountering team —
    /// the natural single-team offload mapping (paper §3.3).
    Team,
    /// After multi-team expansion: distributed over ALL threads of ALL
    /// teams (`omp distribute parallel for` semantics).
    Grid,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Instr {
    /// `%dst = <expr>`
    Assign { dst: String, expr: Expr },
    /// `%dst = alloca <size>` — a stack object (statically identified).
    Alloca { dst: String, size: u64 },
    /// `store.<w> <val>, <addr>`
    Store { addr: Operand, val: Operand, width: Width },
    /// `%dst = load.<w> <addr>`
    Load { dst: String, addr: Operand, width: Width, ty: Ty },
    /// Direct call. Calls to names with no definition in the module are
    /// *library calls* — the RPC pass's targets.
    Call { dst: Option<String>, callee: String, args: Vec<Operand> },
    /// Post-rpcgen call: issue through the RPC client (Fig. 3c).
    RpcCall { dst: Option<String>, mangled: String, callee_id: u64, args: Vec<RpcArgSpec> },
    /// Post-multiteam kernel split: launch region `region` with the grid
    /// config chosen by the coordinator, passing `arg` (a pointer to the
    /// shared-environment struct).
    KernelLaunch { region: String, arg: Option<Operand> },
    If { cond: Operand, then_body: Vec<Instr>, else_body: Vec<Instr> },
    While { cond_var: String, cond: Vec<Instr>, body: Vec<Instr> },
    /// `for %v = lo to hi step s { body }` (half-open `[lo, hi)`).
    For {
        var: String,
        lo: Operand,
        hi: Operand,
        step: Operand,
        schedule: Schedule,
        body: Vec<Instr>,
    },
    /// `parallel num_threads(n) { body }`
    Parallel { num_threads: Option<Operand>, body: Vec<Instr> },
    Barrier,
    Return(Option<Operand>),
    /// Device-native libc intrinsics (paper §3.4) — NOT RPCs.
    Intrinsic { dst: Option<String>, name: String, args: Vec<Operand> },
}

/// Argument descriptor of a generated RPC call site (Fig. 3c lines 27-44).
#[derive(Debug, Clone, PartialEq)]
pub enum RpcArgSpec {
    /// Opaque value, treated as a byte sequence.
    Val(Operand),
    /// Pointer to a statically identified object.
    Ref { ptr: Operand, mode: crate::rpc::ArgMode, obj_size: u64, offset: OffsetSpec },
    /// Statically enumerable candidates resolved by a pointer compare at
    /// runtime (Fig. 3c lines 34-39).
    MultiRef { ptr: Operand, candidates: Vec<(Operand, crate::rpc::ArgMode, u64, OffsetSpec)> },
    /// Statically unknown object: `_FindObj` against allocation tracking,
    /// degrading to a value if the lookup fails.
    DynRef { ptr: Operand, mode: crate::rpc::ArgMode },
}

/// The pointer's offset into its underlying object.
#[derive(Debug, Clone, PartialEq)]
pub enum OffsetSpec {
    Const(u64),
    /// offset = ptr - base(candidate); computed at runtime.
    Dynamic,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Global {
    pub name: String,
    pub size: u64,
    pub constant: bool,
    /// Initializer bytes (zero-filled to `size`).
    pub init: Vec<u8>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    pub name: String,
    pub ty: Ty,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Function {
    pub name: String,
    pub params: Vec<Param>,
    pub ret: Ty,
    pub body: Vec<Instr>,
    /// Set by the multi-team pass on extracted region functions.
    pub is_kernel_region: bool,
}

/// A translation unit after "LTO": the complete world view the RPC pass
/// requires (paper §3.2: "the benefit over per translation unit reasoning
/// is the complete world view").
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Module {
    pub globals: BTreeMap<String, Global>,
    pub functions: BTreeMap<String, Function>,
    /// Declared-but-undefined functions (candidate library calls).
    pub externals: Vec<String>,
    /// Register-file execution forms produced by the `lower` pass,
    /// keyed by function name. Empty until the pass runs; the
    /// interpreter prefers a function's lowered body when present. Not
    /// part of the textual round-trip (the printer emits the tree IR
    /// only), and cleared whenever a later pass mutates the tree so a
    /// stale lowering can never execute.
    pub lowered: BTreeMap<String, lowered::LoweredFunction>,
    /// Linear bytecode forms produced by the `bytecode` pass from the
    /// lowered forms, keyed by function name. The interpreter prefers
    /// a function's bytecode over its lowered body over the tree.
    /// Cleared together with `lowered` whenever a later pass mutates
    /// the tree, so a stale flattening can never execute.
    pub bytecode: BTreeMap<String, bytecode::BytecodeFunction>,
}

impl Module {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_defined(&self, name: &str) -> bool {
        self.functions.contains_key(name)
    }

    /// Device-native libc (paper §3.4): these never become RPCs. Backed
    /// by the [`crate::libc_gpu::registry`] resolvable-symbol table —
    /// the same table the `libcres` pass and the interpreter's intrinsic
    /// dispatch consult, so the three can never disagree.
    pub fn is_native_intrinsic(name: &str) -> bool {
        crate::libc_gpu::registry::lookup(name).is_some()
    }

    /// Verify structural invariants; returns human-readable errors.
    pub fn verify(&self) -> Result<(), Vec<String>> {
        let mut errs = Vec::new();
        for (name, f) in &self.functions {
            if name != &f.name {
                errs.push(format!("function key {name} != name {}", f.name));
            }
            let mut defined: Vec<String> = f.params.iter().map(|p| p.name.clone()).collect();
            verify_body(self, &f.body, &mut defined, &mut errs, &f.name, f.is_kernel_region);
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs)
        }
    }
}

fn verify_body(
    m: &Module,
    body: &[Instr],
    defined: &mut Vec<String>,
    errs: &mut Vec<String>,
    fname: &str,
    in_parallel: bool,
) {
    let check_op = |op: &Operand, defined: &Vec<String>, errs: &mut Vec<String>| {
        match op {
            Operand::Var(v) => {
                if !defined.contains(v) {
                    errs.push(format!("{fname}: use of undefined %{v}"));
                }
            }
            Operand::Global(g) => {
                if !m.globals.contains_key(g) {
                    errs.push(format!("{fname}: use of undefined @{g}"));
                }
            }
            _ => {}
        }
    };
    for ins in body {
        match ins {
            Instr::Assign { dst, expr } => {
                for op in expr_operands(expr) {
                    check_op(op, defined, errs);
                }
                defined.push(dst.clone());
            }
            Instr::Alloca { dst, size } => {
                if *size == 0 {
                    errs.push(format!("{fname}: zero-size alloca %{dst}"));
                }
                defined.push(dst.clone());
            }
            Instr::Store { addr, val, width } => {
                if !matches!(width, 1 | 4 | 8) {
                    errs.push(format!("{fname}: bad store width {width}"));
                }
                check_op(addr, defined, errs);
                check_op(val, defined, errs);
            }
            Instr::Load { dst, addr, width, .. } => {
                if !matches!(width, 1 | 4 | 8) {
                    errs.push(format!("{fname}: bad load width {width}"));
                }
                check_op(addr, defined, errs);
                defined.push(dst.clone());
            }
            Instr::Call { dst, callee, args } => {
                for a in args {
                    check_op(a, defined, errs);
                }
                if let Some(f) = m.functions.get(callee) {
                    if f.params.len() != args.len() {
                        errs.push(format!(
                            "{fname}: call {callee} arity {} != {}",
                            args.len(),
                            f.params.len()
                        ));
                    }
                }
                if let Some(d) = dst {
                    defined.push(d.clone());
                }
            }
            Instr::RpcCall { dst, args, .. } => {
                for a in args {
                    match a {
                        RpcArgSpec::Val(op) | RpcArgSpec::DynRef { ptr: op, .. } => {
                            check_op(op, defined, errs)
                        }
                        RpcArgSpec::Ref { ptr, .. } => check_op(ptr, defined, errs),
                        RpcArgSpec::MultiRef { ptr, candidates } => {
                            check_op(ptr, defined, errs);
                            for (c, _, _, _) in candidates {
                                check_op(c, defined, errs);
                            }
                        }
                    }
                }
                if let Some(d) = dst {
                    defined.push(d.clone());
                }
            }
            Instr::KernelLaunch { region, arg } => {
                if !m.is_defined(region) {
                    errs.push(format!("{fname}: kernel launch of undefined region {region}"));
                }
                if let Some(a) = arg {
                    check_op(a, defined, errs);
                }
            }
            Instr::If { cond, then_body, else_body } => {
                check_op(cond, defined, errs);
                let mut d1 = defined.clone();
                verify_body(m, then_body, &mut d1, errs, fname, in_parallel);
                let mut d2 = defined.clone();
                verify_body(m, else_body, &mut d2, errs, fname, in_parallel);
            }
            Instr::While { cond_var, cond, body } => {
                let mut d = defined.clone();
                verify_body(m, cond, &mut d, errs, fname, in_parallel);
                if !d.contains(cond_var) {
                    errs.push(format!(
                        "{fname}: while condition %{cond_var} not defined by cond block"
                    ));
                }
                verify_body(m, body, &mut d, errs, fname, in_parallel);
            }
            Instr::For { var, lo, hi, step, schedule, body } => {
                check_op(lo, defined, errs);
                check_op(hi, defined, errs);
                check_op(step, defined, errs);
                if matches!(schedule, Schedule::Team | Schedule::Grid) && !in_parallel {
                    errs.push(format!("{fname}: work-shared for outside parallel region"));
                }
                let mut d = defined.clone();
                d.push(var.clone());
                verify_body(m, body, &mut d, errs, fname, in_parallel);
            }
            Instr::Parallel { num_threads, body } => {
                if let Some(n) = num_threads {
                    check_op(n, defined, errs);
                }
                if in_parallel {
                    errs.push(format!("{fname}: nested parallel regions unsupported"));
                }
                let mut d = defined.clone();
                verify_body(m, body, &mut d, errs, fname, true);
            }
            Instr::Barrier => {}
            Instr::Return(op) => {
                if let Some(o) = op {
                    check_op(o, defined, errs);
                }
            }
            Instr::Intrinsic { dst, name, args } => {
                if !Module::is_native_intrinsic(name) {
                    errs.push(format!("{fname}: unknown intrinsic {name}"));
                }
                for a in args {
                    check_op(a, defined, errs);
                }
                if let Some(d) = dst {
                    defined.push(d.clone());
                }
            }
        }
    }
}

pub(crate) fn expr_operands(e: &Expr) -> Vec<&Operand> {
    match e {
        Expr::Op(a)
        | Expr::SiToFp(a)
        | Expr::FpToSi(a)
        | Expr::Sqrt(a)
        | Expr::Exp(a)
        | Expr::Log(a) => vec![a],
        Expr::Bin(_, a, b) | Expr::Gep(a, b) => vec![a, b],
        Expr::Select(c, a, b) => vec![c, a, b],
        Expr::Tid | Expr::NumThreads => vec![],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_fn(name: &str, body: Vec<Instr>) -> Function {
        Function { name: name.into(), params: vec![], ret: Ty::I64, body, is_kernel_region: false }
    }

    #[test]
    fn verify_accepts_wellformed() {
        let mut m = Module::new();
        m.functions.insert(
            "main".into(),
            mk_fn(
                "main",
                vec![
                    Instr::Alloca { dst: "p".into(), size: 8 },
                    Instr::Assign { dst: "x".into(), expr: Expr::Op(Operand::ConstI(5)) },
                    Instr::Store { addr: Operand::var("p"), val: Operand::var("x"), width: 8 },
                    Instr::Return(Some(Operand::var("x"))),
                ],
            ),
        );
        assert!(m.verify().is_ok());
    }

    #[test]
    fn verify_rejects_undefined_var() {
        let mut m = Module::new();
        m.functions
            .insert("main".into(), mk_fn("main", vec![Instr::Return(Some(Operand::var("nope")))]));
        let errs = m.verify().unwrap_err();
        assert!(errs[0].contains("undefined %nope"));
    }

    #[test]
    fn verify_rejects_workshared_for_outside_parallel() {
        let mut m = Module::new();
        m.functions.insert(
            "main".into(),
            mk_fn(
                "main",
                vec![Instr::For {
                    var: "i".into(),
                    lo: Operand::ConstI(0),
                    hi: Operand::ConstI(10),
                    step: Operand::ConstI(1),
                    schedule: Schedule::Team,
                    body: vec![],
                }],
            ),
        );
        let errs = m.verify().unwrap_err();
        assert!(errs[0].contains("work-shared for outside parallel"));
    }

    #[test]
    fn verify_rejects_nested_parallel() {
        let mut m = Module::new();
        m.functions.insert(
            "main".into(),
            mk_fn(
                "main",
                vec![Instr::Parallel {
                    num_threads: None,
                    body: vec![Instr::Parallel { num_threads: None, body: vec![] }],
                }],
            ),
        );
        assert!(m.verify().is_err());
    }

    #[test]
    fn verify_checks_call_arity() {
        let mut m = Module::new();
        m.functions.insert(
            "f".into(),
            Function {
                name: "f".into(),
                params: vec![Param { name: "a".into(), ty: Ty::I64 }],
                ret: Ty::I64,
                body: vec![Instr::Return(Some(Operand::var("a")))],
                is_kernel_region: false,
            },
        );
        m.functions.insert(
            "main".into(),
            mk_fn("main", vec![Instr::Call { dst: None, callee: "f".into(), args: vec![] }]),
        );
        assert!(m.verify().unwrap_err()[0].contains("arity"));
    }

    #[test]
    fn native_intrinsics_listed() {
        assert!(Module::is_native_intrinsic("malloc"));
        assert!(Module::is_native_intrinsic("strtod"));
        assert!(!Module::is_native_intrinsic("fscanf"));
    }
}
