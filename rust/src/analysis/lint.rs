//! IR lints: the advisor's anti-pattern detectors.
//!
//! Three heuristics over the structured IR, each emitting a located
//! [`Diag`](super::diag::Diag) into the compile report:
//!
//! * [`BARRIER_DIVERGENT`] — a `barrier` under divergent control flow
//!   (`if`/`while`) inside a parallel region: threads that skip the
//!   branch never arrive and the region deadlocks.
//! * [`SHARED_WRITE_RACE`] — a store inside a parallel region whose
//!   address is uniform across threads (a global, or a constant-offset
//!   `gep` from one): every thread writes the same location with no
//!   synchronization, a cross-team race.
//! * [`RPC_HOT_LOOP`] — a host-RPC callee (or an already-generated
//!   `rpc` site) inside a loop that is statically hot (constant trip
//!   count ≥ [`HOT_TRIPS`], or unknown bounds): each iteration pays the
//!   full modeled round-trip, the advisor's top anti-pattern.
//!
//! These are heuristics: they warn, never error, and false positives
//! are acceptable (e.g. a uniform store that is in fact idempotent).
//! Lints run only when the opt-in `lint` pass is in the pipeline.

use std::collections::HashMap;

use super::advise::const_trips;
use super::diag::{Diagnostics, Severity};
use super::resolution::{ResolutionTable, SymbolClass};
use crate::ir::printer::render_instr;
use crate::ir::{Expr, Function, Instr, Module, Operand};

pub const BARRIER_DIVERGENT: &str = "barrier-divergent-flow";
pub const SHARED_WRITE_RACE: &str = "shared-global-race";
pub const RPC_HOT_LOOP: &str = "rpc-hot-loop";

/// Every code a lint can emit, for docs and schema checks.
pub const CODES: &[&str] = &[BARRIER_DIVERGENT, RPC_HOT_LOOP, SHARED_WRITE_RACE];

/// Loops at or beyond this static trip count are "hot" for
/// [`RPC_HOT_LOOP`]; unknown-bound loops count as hot (worst case).
pub const HOT_TRIPS: u64 = 64;

/// How many def links the uniform-address check chases.
const UNIFORM_CHASE_DEPTH: usize = 4;

struct LintCx<'a> {
    table: &'a ResolutionTable,
    diags: &'a mut Diagnostics,
    function: &'a str,
    path: Vec<String>,
    /// Flat per-function def map (heuristic: ignores shadowing across
    /// sibling blocks, which the verifier's SSA-ish discipline already
    /// makes rare).
    defs: HashMap<String, Expr>,
}

impl LintCx<'_> {
    fn emit(&mut self, code: &'static str, ins: &Instr, message: String, hint: &str) {
        let mut loc = self.path.join(" > ");
        if !loc.is_empty() {
            loc.push_str(" > ");
        }
        loc.push_str(&render_instr(ins));
        self.diags.emit(
            Severity::Warning,
            code,
            self.function,
            loc,
            message,
            hint.to_string(),
        );
    }

    /// If `o` resolves to the same address on every thread, the global
    /// it points into. Chases `%v = gep <uniform>, <const>` and plain
    /// copies up to a small depth.
    fn uniform_global(&self, o: &Operand, depth: usize) -> Option<String> {
        match o {
            Operand::Global(g) => Some(g.clone()),
            Operand::Var(v) if depth > 0 => match self.defs.get(v) {
                Some(Expr::Op(inner)) => self.uniform_global(inner, depth - 1),
                Some(Expr::Gep(base, off)) if matches!(off, Operand::ConstI(_)) => {
                    self.uniform_global(base, depth - 1)
                }
                _ => None,
            },
            _ => None,
        }
    }

    /// Is `callee` (an external symbol) served by a host RPC?
    fn is_host_rpc(&self, callee: &str) -> bool {
        matches!(self.table.class_of(callee), Some(SymbolClass::HostRpc(_)))
    }
}

/// Run all lints over `m`, classified against `table`. Pure analysis.
pub fn run_lints(m: &Module, table: &ResolutionTable) -> Diagnostics {
    let mut diags = Diagnostics::default();
    for f in m.functions.values() {
        lint_function(f, table, &mut diags);
    }
    diags
}

fn lint_function(f: &Function, table: &ResolutionTable, diags: &mut Diagnostics) {
    let mut cx =
        LintCx { table, diags, function: &f.name, path: Vec::new(), defs: HashMap::new() };
    let mut parallel_seen = 0usize;
    if f.is_kernel_region {
        // Outlined kernel regions execute with every thread inside.
        cx.path.push("kernel".into());
        lint_body(&mut cx, &f.body, &mut parallel_seen, true, 0, 0);
    } else {
        lint_body(&mut cx, &f.body, &mut parallel_seen, false, 0, 0);
    }
}

/// `divergent` counts enclosing thread-divergent constructs inside the
/// parallel region; `hot` counts enclosing statically-hot loops.
fn lint_body(
    cx: &mut LintCx<'_>,
    body: &[Instr],
    parallel_seen: &mut usize,
    in_parallel: bool,
    divergent: usize,
    hot: usize,
) {
    for ins in body {
        match ins {
            Instr::Assign { dst, expr } => {
                cx.defs.insert(dst.clone(), expr.clone());
            }
            Instr::Barrier => {
                if in_parallel && divergent > 0 {
                    cx.emit(
                        BARRIER_DIVERGENT,
                        ins,
                        "barrier under divergent control flow: threads that skip the branch \
                         never arrive, deadlocking the region"
                            .into(),
                        "hoist the barrier out of the branch, or make the condition uniform \
                         across threads",
                    );
                }
            }
            Instr::Store { addr, .. } => {
                if in_parallel {
                    if let Some(g) = cx.uniform_global(addr, UNIFORM_CHASE_DEPTH) {
                        cx.emit(
                            SHARED_WRITE_RACE,
                            ins,
                            format!(
                                "every thread writes the same address in @{g} with no \
                                 synchronization (cross-team race)"
                            ),
                            "index the store by tid or a work-shared loop variable, or guard \
                             it so a single thread writes",
                        );
                    }
                }
            }
            Instr::Call { callee, .. } => {
                if hot > 0 && cx.is_host_rpc(callee) {
                    cx.emit(
                        RPC_HOT_LOOP,
                        ins,
                        format!(
                            "host-RPC callee `{callee}` inside a hot loop: every iteration \
                             pays the full modeled round-trip"
                        ),
                        "hoist the call out of the loop, batch the I/O, or buffer into \
                         device memory and flush once",
                    );
                }
            }
            Instr::RpcCall { mangled, .. } => {
                if hot > 0 {
                    cx.emit(
                        RPC_HOT_LOOP,
                        ins,
                        format!(
                            "generated RPC `{mangled}` inside a hot loop: every iteration \
                             pays the full modeled round-trip"
                        ),
                        "hoist the call out of the loop, batch the I/O, or buffer into \
                         device memory and flush once",
                    );
                }
            }
            Instr::If { then_body, else_body, .. } => {
                cx.path.push("if-then".into());
                lint_body(cx, then_body, parallel_seen, in_parallel, divergent + 1, hot);
                cx.path.pop();
                if !else_body.is_empty() {
                    cx.path.push("if-else".into());
                    lint_body(cx, else_body, parallel_seen, in_parallel, divergent + 1, hot);
                    cx.path.pop();
                }
            }
            Instr::While { cond_var, cond, body, .. } => {
                // Unknown trip count: hot by assumption, and divergent
                // (the condition is thread-dependent in general).
                cx.path.push(format!("while %{cond_var}"));
                lint_body(cx, cond, parallel_seen, in_parallel, divergent + 1, hot + 1);
                lint_body(cx, body, parallel_seen, in_parallel, divergent + 1, hot + 1);
                cx.path.pop();
            }
            Instr::For { var, lo, hi, step, body, .. } => {
                let is_hot = const_trips(lo, hi, step).map_or(true, |t| t >= HOT_TRIPS);
                cx.path.push(format!("for %{var}"));
                lint_body(
                    cx,
                    body,
                    parallel_seen,
                    in_parallel,
                    divergent,
                    hot + usize::from(is_hot),
                );
                cx.path.pop();
            }
            Instr::Parallel { body, .. } => {
                let k = *parallel_seen;
                *parallel_seen += 1;
                cx.path.push(format!("parallel#{k}"));
                lint_body(cx, body, parallel_seen, true, 0, hot);
                cx.path.pop();
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::resolution::resolve_module;
    use crate::ir::parser::parse_module;

    fn lint(src: &str) -> Diagnostics {
        let m = parse_module(src).unwrap();
        let table = resolve_module(&m);
        run_lints(&m, &table)
    }

    #[test]
    fn barrier_under_divergence_fires_once() {
        let d = lint(
            r#"
func @main() -> i64 {
  parallel {
    %t = tid
    %c = eq %t, 0
    if %c {
      barrier
    }
    barrier
  }
  return 0
}
"#,
        );
        assert_eq!(d.count_of(BARRIER_DIVERGENT), 1, "{:?}", d.lines());
        let diag = &d.diags[0];
        assert_eq!(diag.function, "main");
        assert!(diag.location.contains("parallel#0 > if-then > barrier"), "{}", diag.location);
    }

    #[test]
    fn uniform_store_in_parallel_is_a_race() {
        let d = lint(
            r#"
global @acc 8

func @main() -> i64 {
  parallel {
    %p = gep @acc, 0
    store.8 1, %p
  }
  return 0
}
"#,
        );
        assert_eq!(d.count_of(SHARED_WRITE_RACE), 1, "{:?}", d.lines());
        assert!(d.diags[0].message.contains("@acc"));
    }

    #[test]
    fn tid_indexed_store_is_clean() {
        let d = lint(
            r#"
global @buf 1024

func @main() -> i64 {
  parallel {
    %t = tid
    %p = gep @buf, %t
    store.8 1, %p
  }
  return 0
}
"#,
        );
        assert_eq!(d.count_of(SHARED_WRITE_RACE), 0, "{:?}", d.lines());
    }

    #[test]
    fn rpc_in_hot_loop_fires_once() {
        let d = lint(
            r#"
global @fmt const 4 "%d\n"

func @main() -> i64 {
  %p = gep @fmt, 0
  call printf(%p, 1)
  for %i = 0 to 1000 step 1 {
    call printf(%p, %i)
  }
  for %j = 0 to 4 step 1 {
    call printf(%p, %j)
  }
  return 0
}
"#,
        );
        // The 1000-trip loop is hot; the 4-trip loop and the straight-
        // line call are not.
        assert_eq!(d.count_of(RPC_HOT_LOOP), 1, "{:?}", d.lines());
        assert!(d.diags[0].location.contains("for %i"));
    }

    #[test]
    fn clean_program_lints_clean() {
        let d = lint(
            r#"
global @buf 1024

func @main() -> i64 {
  parallel {
    for.team %i = 0 to 128 step 1 {
      %p = gep @buf, %i
      store.8 %i, %p
    }
    barrier
  }
  return 0
}
"#,
        );
        assert!(d.is_empty(), "{:?}", d.lines());
    }
}
