//! Interprocedural analyses backing the RPC generation pass.
//!
//! * [`objects`] — the underlying-object analysis (the reproduction's
//!   stand-in for LLVM's Attributor-based reasoning in paper §3.2): for a
//!   pointer operand at a call site, determine the object(s) it may point
//!   into, their sizes, the pointer's offset, and whether the set is a
//!   single static object, a statically enumerable set, or requires a
//!   dynamic lookup.
//! * [`callgraph`] — call-graph construction over the module (used to
//!   decide which calls are library calls and for multi-team eligibility).
//! * [`resolution`] — the libc/RPC symbol-resolution table (paper
//!   §3.2/§3.4): every external callee classified device-native,
//!   host-RPC, or unresolved, with per-symbol modeled cost annotations.
//!   Materialized by the `libcres` pass, consumed by `rpcgen`, the
//!   interpreter's dispatch, and the advisor.
//! * [`advise`] — the compile-time offload advisor: static per-region
//!   cost estimation scored A100-vs-EPYC, producing a ranked
//!   [`AdviseReport`] (the opt-in `advise` pass).
//! * [`diag`] — the located-diagnostics framework (severity, code,
//!   function/instruction location, fix hint) shared by the advisor
//!   and the lints.
//! * [`lint`] — IR anti-pattern lints (barrier-under-divergence,
//!   shared-global race heuristic, RPC-inside-hot-loop), emitted as
//!   diagnostics by the opt-in `lint` pass.
//!
//! These analyses are cached by the pass manager's
//! [`crate::transform::AnalysisCache`]: computed once per module state
//! and invalidated only when a pass reports mutating the module.

pub mod advise;
pub mod callgraph;
pub mod diag;
pub mod lint;
pub mod objects;
pub mod resolution;

pub use advise::{analyze, AdviseParams, AdviseReport, RegionAdvice};
pub use diag::{Diag, Diagnostics, Severity};
pub use lint::run_lints;
pub use objects::{classify_operand, def_map, ObjClass, ObjOrigin, OffKind};
pub use resolution::{resolve_module, ResolutionTable, SymbolClass};
