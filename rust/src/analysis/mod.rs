//! Interprocedural analyses backing the RPC generation pass.
//!
//! * [`objects`] — the underlying-object analysis (the reproduction's
//!   stand-in for LLVM's Attributor-based reasoning in paper §3.2): for a
//!   pointer operand at a call site, determine the object(s) it may point
//!   into, their sizes, the pointer's offset, and whether the set is a
//!   single static object, a statically enumerable set, or requires a
//!   dynamic lookup.
//! * [`callgraph`] — call-graph construction over the module (used to
//!   decide which calls are library calls and for multi-team eligibility).

pub mod objects;
pub mod callgraph;

pub use objects::{classify_operand, ObjClass, ObjOrigin, OffKind};
