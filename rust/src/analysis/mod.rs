//! Interprocedural analyses backing the RPC generation pass.
//!
//! * [`objects`] — the underlying-object analysis (the reproduction's
//!   stand-in for LLVM's Attributor-based reasoning in paper §3.2): for a
//!   pointer operand at a call site, determine the object(s) it may point
//!   into, their sizes, the pointer's offset, and whether the set is a
//!   single static object, a statically enumerable set, or requires a
//!   dynamic lookup.
//! * [`callgraph`] — call-graph construction over the module (used to
//!   decide which calls are library calls and for multi-team eligibility).
//! * [`resolution`] — the libc/RPC symbol-resolution table (paper
//!   §3.2/§3.4): every external callee classified device-native,
//!   host-RPC, or unresolved. Materialized by the `libcres` pass,
//!   consumed by `rpcgen` and the interpreter's dispatch.
//!
//! These analyses are cached by the pass manager's
//! [`crate::transform::AnalysisCache`]: computed once per module state
//! and invalidated only when a pass reports mutating the module.

pub mod objects;
pub mod callgraph;
pub mod resolution;

pub use objects::{classify_operand, def_map, ObjClass, ObjOrigin, OffKind};
pub use resolution::{resolve_module, ResolutionTable, SymbolClass};
