//! Underlying-object analysis (paper §3.2).
//!
//! Classifies a pointer-valued operand at a call site into the paper's
//! three argument kinds:
//!
//! 1. a **value** (integer constant, or a pointer of unknown host origin
//!    treated as opaque),
//! 2. a pointer into a **statically identified object** — an `alloca` or a
//!    global — with known size and (constant or dynamic) offset,
//! 3. a **statically enumerable set** of such objects (through `select`),
//! 4. a pointer requiring **dynamic lookup** (`malloc` results, loads,
//!    parameters) resolved at runtime against allocation tracking.
//!
//! The walk follows single-assignment def chains through `gep`, `select`
//! and plain copies, accumulating constant offsets.

use crate::ir::{Expr, Function, Instr, Operand};
use std::collections::HashMap;

/// Where a statically identified object lives.
#[derive(Debug, Clone, PartialEq)]
pub enum ObjOrigin {
    /// `alloca` result variable (stack memory).
    Alloca(String),
    /// Module global (global/constant memory).
    Global(String),
}

impl ObjOrigin {
    /// The operand that evaluates to the object's base address.
    pub fn base_operand(&self) -> Operand {
        match self {
            ObjOrigin::Alloca(v) => Operand::Var(v.clone()),
            ObjOrigin::Global(g) => Operand::Global(g.clone()),
        }
    }
}

/// Offset of the pointer into its object.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffKind {
    Const(u64),
    Dynamic,
}

impl OffKind {
    fn add(self, other: OffKind) -> OffKind {
        match (self, other) {
            (OffKind::Const(a), OffKind::Const(b)) => OffKind::Const(a + b),
            _ => OffKind::Dynamic,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct StaticObj {
    pub origin: ObjOrigin,
    pub size: u64,
    pub constant: bool,
    pub offset: OffKind,
}

#[derive(Debug, Clone, PartialEq)]
pub enum ObjClass {
    /// Not a pointer (or a compile-time scalar): pass by value.
    Value,
    /// Exactly one statically identified object.
    Static(StaticObj),
    /// A statically enumerable candidate set (Fig. 3c lines 34-39).
    Multi(Vec<StaticObj>),
    /// Underlying object only resolvable at runtime (`_FindObj`).
    Dynamic,
}

/// Map from local name to its defining instruction, collected over the
/// whole (structured) function body. The IR is written single-assignment
/// per name; later defs shadow earlier ones conservatively.
pub fn def_map(f: &Function) -> HashMap<String, Instr> {
    let mut map = HashMap::new();
    collect(&f.body, &mut map);
    map
}

fn collect(body: &[Instr], map: &mut HashMap<String, Instr>) {
    for ins in body {
        match ins {
            Instr::Assign { dst, .. }
            | Instr::Alloca { dst, .. }
            | Instr::Load { dst, .. } => {
                map.insert(dst.clone(), ins.clone());
            }
            Instr::Call { dst: Some(d), .. }
            | Instr::RpcCall { dst: Some(d), .. }
            | Instr::Intrinsic { dst: Some(d), .. } => {
                map.insert(d.clone(), ins.clone());
            }
            Instr::If { then_body, else_body, .. } => {
                collect(then_body, map);
                collect(else_body, map);
            }
            Instr::While { cond, body, .. } => {
                collect(cond, map);
                collect(body, map);
            }
            Instr::For { body, .. } => collect(body, map),
            Instr::Parallel { body, .. } => collect(body, map),
            _ => {}
        }
    }
}

/// Classify `op` as a call-site pointer argument within function `f` of
/// module `m`.
pub fn classify_operand(
    m: &crate::ir::Module,
    defs: &HashMap<String, Instr>,
    op: &Operand,
) -> ObjClass {
    classify_rec(m, defs, op, 0)
}

fn classify_rec(
    m: &crate::ir::Module,
    defs: &HashMap<String, Instr>,
    op: &Operand,
    depth: usize,
) -> ObjClass {
    if depth > 32 {
        return ObjClass::Dynamic;
    }
    match op {
        Operand::ConstI(_) | Operand::ConstF(_) => ObjClass::Value,
        Operand::Global(g) => match m.globals.get(g) {
            Some(gl) => ObjClass::Static(StaticObj {
                origin: ObjOrigin::Global(g.clone()),
                size: gl.size,
                constant: gl.constant,
                offset: OffKind::Const(0),
            }),
            None => ObjClass::Dynamic,
        },
        Operand::Var(v) => match defs.get(v) {
            Some(Instr::Alloca { size, .. }) => ObjClass::Static(StaticObj {
                origin: ObjOrigin::Alloca(v.clone()),
                size: *size,
                constant: false,
                offset: OffKind::Const(0),
            }),
            Some(Instr::Assign { expr, .. }) => match expr {
                Expr::Op(inner) => classify_rec(m, defs, inner, depth + 1),
                Expr::Gep(base, off) => {
                    let off_kind = match off {
                        Operand::ConstI(c) if *c >= 0 => OffKind::Const(*c as u64),
                        _ => OffKind::Dynamic,
                    };
                    match classify_rec(m, defs, base, depth + 1) {
                        ObjClass::Static(s) => {
                            ObjClass::Static(StaticObj { offset: s.offset.add(off_kind), ..s })
                        }
                        ObjClass::Multi(cands) => ObjClass::Multi(
                            cands
                                .into_iter()
                                .map(|s| StaticObj { offset: s.offset.add(off_kind), ..s })
                                .collect(),
                        ),
                        other => other,
                    }
                }
                Expr::Select(_, a, b) => {
                    let ca = classify_rec(m, defs, a, depth + 1);
                    let cb = classify_rec(m, defs, b, depth + 1);
                    let mut cands = Vec::new();
                    for c in [ca, cb] {
                        match c {
                            ObjClass::Static(s) => cands.push(s),
                            ObjClass::Multi(mut cs) => cands.append(&mut cs),
                            // One unknown side poisons enumerability.
                            _ => return ObjClass::Dynamic,
                        }
                    }
                    ObjClass::Multi(cands)
                }
                // Arithmetic on ints is a value; anything else unknown.
                Expr::Bin(b, _, _) if !b.is_float() => ObjClass::Value,
                Expr::Tid | Expr::NumThreads => ObjClass::Value,
                _ => ObjClass::Value,
            },
            // malloc-like results: tracked at runtime by the allocator
            // (per the device-native registry, not a name match).
            Some(Instr::Intrinsic { name, .. })
                if crate::libc_gpu::registry::lookup(name)
                    .is_some_and(|f| f.returns_tracked_pointer()) =>
            {
                ObjClass::Dynamic
            }
            Some(Instr::Intrinsic { .. }) => ObjClass::Value,
            // Loaded pointers / call results / RPC results: unknown origin.
            Some(Instr::Load { .. }) | Some(Instr::Call { .. }) | Some(Instr::RpcCall { .. }) => {
                ObjClass::Dynamic
            }
            Some(_) => ObjClass::Dynamic,
            // Parameters: unknown origin (the paper's inter-procedural
            // Attributor could refine this; we fall back to dynamic lookup).
            None => ObjClass::Dynamic,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse_module;

    fn classify_in_main(src: &str, var: &str) -> ObjClass {
        let m = parse_module(src).unwrap();
        let f = &m.functions["main"];
        let defs = def_map(f);
        classify_operand(&m, &defs, &Operand::var(var))
    }

    const FIG3: &str = r#"
global @fmt const 9 "%f %i %i"

func @main() -> i64 {
  %s = alloca 12
  %i = alloca 4
  %sa = load.4 %s
  %pb = gep %s, 4
  %pf = gep %s, 8
  %c = ne %sa, 0
  %p = select %c, %i, %pb
  %h = call malloc(64)
  %q = load.8 %h
  %off = mul %sa, 4
  %dynp = gep %s, %off
  return 0
}
"#;

    #[test]
    fn alloca_is_static_with_const_offset() {
        match classify_in_main(FIG3, "pf") {
            ObjClass::Static(s) => {
                assert_eq!(s.origin, ObjOrigin::Alloca("s".into()));
                assert_eq!(s.size, 12);
                assert_eq!(s.offset, OffKind::Const(8));
                assert!(!s.constant);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn global_is_static_and_const() {
        let m = parse_module(FIG3).unwrap();
        let defs = def_map(&m.functions["main"]);
        match classify_operand(&m, &defs, &Operand::Global("fmt".into())) {
            ObjClass::Static(s) => {
                assert_eq!(s.origin, ObjOrigin::Global("fmt".into()));
                assert!(s.constant);
                assert_eq!(s.size, 9);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn select_enumerates_candidates() {
        // %p = select %c, %i, %pb — the paper's (s.a ? &i : &s.b).
        match classify_in_main(FIG3, "p") {
            ObjClass::Multi(cands) => {
                assert_eq!(cands.len(), 2);
                assert_eq!(cands[0].origin, ObjOrigin::Alloca("i".into()));
                assert_eq!(cands[0].offset, OffKind::Const(0));
                assert_eq!(cands[1].origin, ObjOrigin::Alloca("s".into()));
                assert_eq!(cands[1].offset, OffKind::Const(4));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malloc_result_is_dynamic() {
        assert_eq!(classify_in_main(FIG3, "h"), ObjClass::Dynamic);
    }

    #[test]
    fn loaded_pointer_is_dynamic() {
        assert_eq!(classify_in_main(FIG3, "q"), ObjClass::Dynamic);
    }

    #[test]
    fn variable_offset_gep_is_static_with_dynamic_offset() {
        match classify_in_main(FIG3, "dynp") {
            ObjClass::Static(s) => {
                assert_eq!(s.origin, ObjOrigin::Alloca("s".into()));
                assert_eq!(s.offset, OffKind::Dynamic);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn int_arithmetic_is_value() {
        assert_eq!(classify_in_main(FIG3, "off"), ObjClass::Value);
        assert_eq!(classify_in_main(FIG3, "c"), ObjClass::Value);
    }

    #[test]
    fn params_are_dynamic() {
        let src = "func @main(%p: ptr) -> i64 {\n  return 0\n}\n";
        let m = parse_module(src).unwrap();
        let defs = def_map(&m.functions["main"]);
        assert_eq!(classify_operand(&m, &defs, &Operand::var("p")), ObjClass::Dynamic);
    }
}
